//! End-to-end validation driver (DESIGN.md §5, deliverable (b)/(e2e)):
//! train a Mamba LM for a few hundred steps on the synthetic corpus through
//! the AOT train-step executable, log the loss curve, then run the full
//! zero-shot suite dense vs UTRC-reduced and print the comparison — all
//! three layers composing on a real workload, with python nowhere at runtime.
//!
//! ```sh
//! cargo run --release --features pjrt --example train_e2e -- --model mamba-small --steps 300
//! ```
//!
//! The fused train step only exists on the pjrt backend, so `--backend`
//! defaults to `pjrt` here (requires the cargo feature + real artifacts).

use anyhow::Result;

use tor_ssm::bench::Ctx;
use tor_ssm::eval::scoring::Scheme;
use tor_ssm::manifest::Manifest;
use tor_ssm::runtime::Runtime;
use tor_ssm::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env(&["skip-train"]);
    let artifacts = args.get_or("artifacts", &tor_ssm::artifacts_dir());
    let model = args.get_or("model", "mamba-small");
    let backend = args.get_or("backend", "pjrt");
    let man = Manifest::load(&artifacts)?;
    let steps = args.usize_or("steps", man.train_total_steps);
    let items = args.usize_or("items", 40);

    // ---- phase 1: train ----------------------------------------------------
    let me = man.model(&model)?.clone();
    if !args.flag("skip-train") {
        let rt = Runtime::from_name(&backend)?;
        println!(
            "training {model} ({} params) for {steps} steps on the synthetic corpus...",
            me.param_count
        );
        let report = tor_ssm::train::train(&rt, &man, &me, steps, 42, 10)?;
        println!(
            "\nloss curve: {:.4} -> {:.4} over {} steps ({:.1}s, {:.0} tok/s)",
            report.losses[0],
            report.losses[report.losses.len() - 1],
            report.steps,
            report.wall_s,
            report.tokens_seen as f64 / report.wall_s
        );
        // Print a terminal sparkline of the loss curve.
        println!("loss: {}", sparkline(&report.losses));
        println!("checkpoint: {:?}", report.checkpoint);
        anyhow::ensure!(
            report.losses[report.losses.len() - 1] < report.losses[0] * 0.8,
            "training did not reduce loss by 20% — something is wrong"
        );
    }

    // ---- phase 2: zero-shot eval dense vs reduced ---------------------------
    let mut ctx = Ctx::with_backend(&artifacts, items, false, &backend)?;
    println!("\nzero-shot evaluation ({items} items/task):");
    let mut rows = Vec::new();
    for (label, method, ratio) in [
        ("dense", "dense", 0.0),
        ("UTRC @10%", "utrc", 0.10),
        ("UTRC @20%", "utrc", 0.20),
    ] {
        let e = match ctx.find_eval_entry(&model, method, ratio, None, None, None, None) {
            Ok(e) => e,
            Err(_) => continue, // small models export 10/20 only
        };
        let r = ctx.eval_variant(&model, &e)?;
        rows.push((label, r));
    }
    println!("\n| variant | PPL (trunc) | avg acc (trunc) | avg acc (aligned) |");
    println!("|---|---|---|---|");
    for (label, r) in &rows {
        println!(
            "| {label} | {:.2} | {:.1}% | {:.1}% |",
            r.lambada_ppl(Scheme::Truncated),
            r.avg_acc(Scheme::Truncated) * 100.0,
            r.avg_acc(Scheme::Aligned) * 100.0
        );
    }
    println!("\ne2e OK: trained + evaluated through the AOT runtime (no python).");
    Ok(())
}

fn sparkline(xs: &[f32]) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let (lo, hi) = xs.iter().fold((f32::MAX, f32::MIN), |(l, h), &x| (l.min(x), h.max(x)));
    let span = (hi - lo).max(1e-9);
    // Downsample to ~60 chars.
    let stride = (xs.len() / 60).max(1);
    xs.iter()
        .step_by(stride)
        .map(|&x| BARS[(((x - lo) / span) * 7.0).round() as usize])
        .collect()
}
