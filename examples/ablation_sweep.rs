//! Ablation sweep: every exported UTRC design point on one model — metric ×
//! schedule × (q_hidden, q_residual) × ratio — in one run, printed as a
//! sortable table. This is the exploratory companion to Tables 3/4/5.
//!
//! ```sh
//! cargo run --release --example ablation_sweep -- --model mamba2-base --items 30
//! ```

use anyhow::Result;

use tor_ssm::bench::Ctx;
use tor_ssm::eval::scoring::Scheme;
use tor_ssm::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env(&["fresh"]);
    let artifacts = args.get_or("artifacts", &tor_ssm::artifacts_dir());
    let model = args.get_or("model", "mamba2-base");
    let items = args.usize_or("items", 30);
    // The sweep needs real AOT exports, so it defaults to the pjrt backend.
    let backend = args.get_or("backend", "pjrt");
    let mut ctx = Ctx::with_backend(&artifacts, items, args.flag("fresh"), &backend)?;

    let me = ctx.man.model(&model)?.clone();
    let mut entries: Vec<_> = me
        .hlo
        .values()
        .filter(|e| e.kind == "eval")
        .cloned()
        .collect();
    entries.sort_by(|a, b| a.tag.cmp(&b.tag));
    println!("{} eval variants exported for {model}\n", entries.len());

    let mut rows = Vec::new();
    for e in &entries {
        let r = ctx.eval_variant(&model, e)?;
        let red = e.reduction.clone().unwrap_or_default();
        rows.push((
            red.method.clone(),
            red.flops_reduction,
            red.metric.clone(),
            red.q_hidden,
            red.q_residual,
            format!("{:?}", red.locations),
            r.lambada_ppl(Scheme::Truncated),
            r.avg_acc(Scheme::Truncated) * 100.0,
            r.avg_acc(Scheme::Aligned) * 100.0,
        ));
    }
    // Sort by avg accuracy (desc) to surface the best design points.
    rows.sort_by(|a, b| b.7.partial_cmp(&a.7).unwrap());

    println!(
        "| {:<6} | {:>5} | {:<6} | {:>4} | {:>4} | {:<14} | {:>9} | {:>6} | {:>8} |",
        "method", "FLOPs", "metric", "qh", "qr", "locations", "PPL", "avg", "avg(al)"
    );
    println!("|{}", "---|".repeat(9));
    for (m, fr, metric, qh, qr, loc, ppl, acc, acc_a) in rows {
        println!(
            "| {m:<6} | {:>4.0}% | {metric:<6} | {qh:>4.1} | {qr:>4.1} | {loc:<14} | {ppl:>9.2} | {acc:>6.1} | {acc_a:>8.1} |",
            fr * 100.0
        );
    }
    Ok(())
}
