//! Serving demo: the full coordinator path — router → dynamic batcher →
//! engine (prefill + decode) — on a synthetic request trace, reporting
//! latency percentiles and throughput for dense vs token-reduced lanes.
//!
//! Hermetic by default: with no `artifacts/` directory it generates a
//! synthetic fixture and serves it on the reference backend.
//!
//! ```sh
//! cargo run --release --example serve -- --requests 24 --gen-tokens 24
//! ```

use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use tor_ssm::coordinator::batcher::Batcher;
use tor_ssm::coordinator::engine::Engine;
use tor_ssm::coordinator::metrics::Metrics;
use tor_ssm::coordinator::router::{Policy, Router};
use tor_ssm::coordinator::Request;
use tor_ssm::fixtures;
use tor_ssm::runtime::Runtime;
use tor_ssm::train::load_best_weights;
use tor_ssm::util::cli::Args;
use tor_ssm::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::from_env(&[]);
    let n_requests = args.usize_or("requests", 24);
    let gen_tokens = args.usize_or("gen-tokens", 24);

    // An explicitly passed --artifacts must load (a typo'd path should be an
    // error, not a silent fall-back to the toy fixture); only the default
    // location falls back to the synthetic fixture.
    let (man, synthetic) = match args.get("artifacts") {
        Some(dir) => (tor_ssm::manifest::Manifest::load(dir)?, false),
        None => fixtures::manifest_or_fixture(&tor_ssm::artifacts_dir())?,
    };
    let rt = Runtime::from_name(&args.get_or("backend", "reference"))?;
    let default_model = man.models.keys().next().context("manifest has no models")?.clone();
    let model = args.get_or("model", &default_model);
    let me = man.model(&model)?.clone();
    let (w, trained) = load_best_weights(&man, &me)?;
    println!(
        "serving {model} ({}; {}; {} requests, {gen_tokens} gen tokens each)",
        if trained { "trained weights" } else { "INIT weights" },
        if synthetic { "synthetic fixture" } else { "real artifacts" },
        n_requests
    );

    let lanes = ["dense", "utrc@0.2"];
    let engines: Vec<Engine> = lanes
        .iter()
        .map(|v| Engine::new(&rt, &man, &me, &w, v))
        .collect::<Result<_>>()?;
    println!(
        "lanes: {lanes:?} (batch {}, prompt frame {})",
        engines[0].batch, engines[0].prefill_len
    );

    let mut router = Router::new(Policy::CostAware { long_prompt: man.prefill_seq_len / 2 }, &lanes);
    let mut batchers: Vec<Batcher> = engines
        .iter()
        .map(|e| Batcher::new(e.batch, Duration::from_millis(2)))
        .collect();
    let mut per_lane: Vec<Metrics> = lanes.iter().map(|_| Metrics::default()).collect();

    let mut rng = Rng::new(11);
    let t0 = Instant::now();
    for i in 0..n_requests {
        // Bimodal prompt lengths: short chat-like vs long document-like.
        let plen = if rng.f64() < 0.5 { man.prefill_seq_len } else { man.prefill_seq_len / 4 };
        let prompt: Vec<i32> = (4..4 + plen).map(|t| (t % me.vocab_size) as i32).collect();
        let req = Request {
            id: i as u64,
            prompt,
            gen_tokens,
            variant: String::new(),
            arrived_us: t0.elapsed().as_micros() as u64,
        };
        let lane = router.route(&req)?;
        let li = lanes.iter().position(|l| *l == lane).unwrap();
        router.note_enqueued(&lane);
        batchers[li].push(req);

        for (bi, b) in batchers.iter_mut().enumerate() {
            while let Some(batch) = b.poll(Instant::now()) {
                run_batch(&engines[bi], &batch, &mut per_lane[bi], &mut router, &lanes[bi], t0)?;
            }
        }
    }
    for (bi, b) in batchers.iter_mut().enumerate() {
        while let Some(batch) = b.drain() {
            run_batch(&engines[bi], &batch, &mut per_lane[bi], &mut router, &lanes[bi], t0)?;
        }
    }

    let wall = t0.elapsed();
    println!("\nper-lane results:");
    for (lane, m) in lanes.iter().zip(per_lane.iter_mut()) {
        m.wall = wall;
        println!("  {lane:<10} {}", m.summary());
    }
    let total_gen: u64 = per_lane.iter().map(|m| m.generated_tokens).sum();
    println!(
        "\naggregate: {n_requests} requests, {total_gen} tokens generated in {:.2}s -> {:.1} tok/s",
        wall.as_secs_f64(),
        total_gen as f64 / wall.as_secs_f64()
    );
    Ok(())
}

fn run_batch(
    engine: &Engine,
    batch: &[Request],
    metrics: &mut Metrics,
    router: &mut Router,
    lane: &str,
    t0: Instant,
) -> Result<()> {
    let responses = engine.serve_batch(batch)?;
    for (req, resp) in batch.iter().zip(&responses) {
        let queue_us = t0.elapsed().as_micros() as u64 - req.arrived_us;
        metrics.requests += 1;
        metrics.record(
            req.prompt.len(),
            resp.generated.len(),
            resp.prefill_us,
            resp.decode_us,
            queue_us,
        );
        router.note_done(lane);
    }
    Ok(())
}
