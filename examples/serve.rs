//! Serving demo: the full coordinator path — router → continuous-batching
//! scheduler (iteration-level prefill admission + decode) — on a synthetic
//! request trace with mixed generation lengths, reporting per-lane latency
//! percentiles, throughput, and the decode-step count against the lock-step
//! baseline (`Engine::serve_batch`).
//!
//! Hermetic by default: with no `artifacts/` directory it generates a
//! synthetic fixture and serves it on the reference backend.
//!
//! ```sh
//! cargo run --release --example serve -- --requests 24 --gen-tokens 24
//! ```

use std::sync::atomic::Ordering;
use std::time::Instant;

use anyhow::{Context, Result};

use tor_ssm::coordinator::engine::Engine;
use tor_ssm::coordinator::metrics::Metrics;
use tor_ssm::coordinator::router::{Policy, Router};
use tor_ssm::coordinator::scheduler::Scheduler;
use tor_ssm::coordinator::Request;
use tor_ssm::fixtures;
use tor_ssm::runtime::Runtime;
use tor_ssm::train::load_best_weights;
use tor_ssm::util::cli::Args;
use tor_ssm::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::from_env(&[]);
    let n_requests = args.usize_or("requests", 24);
    let max_gen = args.usize_or("gen-tokens", 24);

    // An explicitly passed --artifacts must load (a typo'd path should be an
    // error, not a silent fall-back to the toy fixture); only the default
    // location falls back to the synthetic fixture.
    let (man, synthetic) = match args.get("artifacts") {
        Some(dir) => (tor_ssm::manifest::Manifest::load(dir)?, false),
        None => fixtures::manifest_or_fixture(&tor_ssm::artifacts_dir())?,
    };
    let rt = Runtime::from_name(&args.get_or("backend", "reference"))?;
    let default_model = man.models.keys().next().context("manifest has no models")?.clone();
    let model = args.get_or("model", &default_model);
    let me = man.model(&model)?.clone();
    let (w, trained) = load_best_weights(&man, &me)?;
    println!(
        "serving {model} ({}; {}; {} requests, 1..={max_gen} gen tokens uniform)",
        if trained { "trained weights" } else { "INIT weights" },
        if synthetic { "synthetic fixture" } else { "real artifacts" },
        n_requests
    );
    println!("exec: {}", tor_ssm::runtime::kernels::exec_summary());

    let lanes = ["dense", "utrc@0.2"];
    let engines: Vec<Engine> = lanes
        .iter()
        .map(|v| Engine::new(&rt, &man, &me, &w, v))
        .collect::<Result<_>>()?;
    println!(
        "lanes: {lanes:?} (prefill batch {}, decode lanes {}, prompt frame {})",
        engines[0].batch, engines[0].decode_batch, engines[0].prefill_len
    );

    // Build the trace once so the continuous and lock-step runs serve the
    // exact same requests (shared workload shape — see fixtures::synth_requests).
    // Length-aware lanes take multi-frame prompts (chunked prefill).
    let max_prompt = fixtures::trace_max_prompt(&engines);
    let mut rng = Rng::new(11);
    let trace: Vec<Request> = fixtures::synth_requests(
        &mut rng,
        n_requests,
        max_gen,
        man.prefill_seq_len,
        max_prompt,
        me.vocab_size,
        &[], // fully router-driven: keeps the two serving modes comparable
    );

    // ---- continuous batching ------------------------------------------
    let mut router = Router::new(Policy::CostAware { long_prompt: man.prefill_seq_len / 2 }, &lanes);
    let mut schedulers: Vec<Scheduler> = engines.iter().map(Scheduler::new).collect();
    let mut per_lane: Vec<Metrics> = lanes.iter().map(|_| Metrics::default()).collect();
    let mut assignment: Vec<Vec<Request>> = lanes.iter().map(|_| Vec::new()).collect();

    let cont_calls0: u64 = engines.iter().map(|e| e.decode_calls.load(Ordering::Relaxed)).sum();
    let t0 = Instant::now();
    for req in trace.iter().cloned() {
        let lane = router.route(&req)?;
        let li = lanes.iter().position(|l| *l == lane).unwrap();
        router.note_enqueued(&lane);
        per_lane[li].requests += 1;
        assignment[li].push(req.clone());
        schedulers[li].submit(req);
        for (si, s) in schedulers.iter_mut().enumerate() {
            for resp in s.step()? {
                per_lane[si].record_response(&resp);
                router.note_done(lanes[si]);
            }
        }
    }
    for (si, s) in schedulers.iter_mut().enumerate() {
        for resp in s.drain()? {
            per_lane[si].record_response(&resp);
            router.note_done(lanes[si]);
        }
    }
    let wall = t0.elapsed();
    let cont_steps: u64 =
        engines.iter().map(|e| e.decode_calls.load(Ordering::Relaxed)).sum::<u64>() - cont_calls0;

    println!("\nper-lane results (continuous batching):");
    for ((lane, m), s) in lanes.iter().zip(per_lane.iter_mut()).zip(&schedulers) {
        m.wall = wall;
        println!("  {lane:<10} {}", m.summary());
        println!(
            "  {:<10} prefills={} decode_steps={} peak_state={} slots ({} B)",
            "", s.prefill_calls, s.decode_steps, s.store().high_water(), s.store().peak_bytes()
        );
    }
    let total_gen: u64 = per_lane.iter().map(|m| m.generated_tokens).sum();
    println!(
        "\naggregate: {n_requests} requests, {total_gen} tokens generated in {:.2}s -> {:.1} tok/s",
        wall.as_secs_f64(),
        total_gen as f64 / wall.as_secs_f64()
    );

    // ---- lock-step baseline on the same per-lane assignment -----------
    let lock_calls0: u64 = engines.iter().map(|e| e.decode_calls.load(Ordering::Relaxed)).sum();
    let t1 = Instant::now();
    let mut lock_gen: u64 = 0;
    for (li, reqs) in assignment.iter().enumerate() {
        for chunk in reqs.chunks(engines[li].max_batch()) {
            for resp in engines[li].serve_batch(chunk)? {
                lock_gen += resp.generated.len() as u64;
            }
        }
    }
    let lock_wall = t1.elapsed();
    let lock_steps: u64 =
        engines.iter().map(|e| e.decode_calls.load(Ordering::Relaxed)).sum::<u64>() - lock_calls0;
    println!(
        "\nlock-step baseline: {lock_gen} tokens in {:.2}s -> {:.1} tok/s; \
         decode steps {lock_steps} vs {cont_steps} continuous ({:.2}x fewer)",
        lock_wall.as_secs_f64(),
        lock_gen as f64 / lock_wall.as_secs_f64(),
        lock_steps as f64 / (cont_steps.max(1)) as f64
    );
    Ok(())
}
