//! Quickstart: load a compiled Mamba variant, run one reduced vs dense
//! forward on a real task prompt, and print what token reduction did.
//!
//! Hermetic by default: when no `artifacts/` directory exists this
//! generates a deterministic synthetic fixture and runs it on the pure-Rust
//! reference backend — no Python, no XLA.
//!
//! ```sh
//! cargo run --release --example quickstart
//! # or against real AOT artifacts:
//! make artifacts && cargo run --release --features pjrt --example quickstart -- --backend pjrt
//! ```

use anyhow::{Context, Result};

use tor_ssm::data::load_tasks;
use tor_ssm::eval::scoring::SeqLogits;
use tor_ssm::fixtures;
use tor_ssm::runtime::{HostTensor, Runtime};
use tor_ssm::tokenizer::Tokenizer;
use tor_ssm::train::load_best_weights;
use tor_ssm::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::from_env(&[]);
    // An explicitly passed --artifacts must load (a typo'd path should be an
    // error, not a silent fall-back to the toy fixture); only the default
    // location falls back to the synthetic fixture.
    let (man, synthetic) = match args.get("artifacts") {
        Some(dir) => (tor_ssm::manifest::Manifest::load(dir)?, false),
        None => fixtures::manifest_or_fixture(&tor_ssm::artifacts_dir())?,
    };
    let rt = Runtime::from_name(&args.get_or("backend", "reference"))?;
    println!(
        "platform: {} ({})",
        rt.platform(),
        if synthetic { "synthetic fixture" } else { "real artifacts" }
    );

    let default_model = man.models.keys().next().context("manifest has no models")?.clone();
    let model = man.model(&args.get_or("model", &default_model))?.clone();
    let (weights, trained) = load_best_weights(&man, &model)?;
    println!(
        "model: {} ({} params, {} weights)",
        model.name,
        model.param_count,
        if trained { "trained" } else { "INIT — run `repro train` for meaningful predictions" }
    );
    let dw = rt.upload_weights(&model, &weights)?;

    // A real task prompt from the benchmark set.
    let tok = Tokenizer::load(man.path(&man.vocab_file))?;
    let tasks = load_tasks(man.path(&man.tasks_file))?;
    let item = &tasks[0].items[0]; // s-lambada cloze
    println!("\nprompt: \"{} ...\"", &item.context[..item.context.len().min(120)]);
    println!("cloze target: {:?}", item.target);

    let ids: Vec<i32> = tok.encode(&item.context).iter().map(|&x| x as i32).collect();
    let pos = ids.len(); // position whose prediction we inspect

    for (label, method, ratio) in [
        ("dense", "dense", 0.0),
        ("UTRC @20% FLOPs", "utrc", 0.20),
    ] {
        let entry = model.find_eval(method, ratio, None, None, None, None)?;
        let exe = rt.load_entry(&man, &model, entry)?;
        let mut tokens = ids.clone();
        tokens.resize(entry.seq_len, 0);
        let mut flat = Vec::new();
        for _ in 0..entry.batch {
            flat.extend_from_slice(&tokens);
        }
        let tok_t = HostTensor::i32(vec![entry.batch, entry.seq_len], flat);

        let t0 = std::time::Instant::now();
        let outs = exe.execute(&dw, &[tok_t]).context("forward")?;
        let dt = t0.elapsed();

        let logits = outs[0].as_f32()?;
        let kept = outs[1].as_i32()?;
        let out_len = entry.out_len;
        let v = model.vocab_size;
        let sl =
            SeqLogits { logits: &logits[..out_len * v], out_len, vocab: v, kept: &kept[..out_len] };
        let pred = sl.aligned_argmax(pos).unwrap_or(-1);
        println!(
            "\n[{label}] tokens {} -> {} surviving | forward {dt:?}\n  predicted next word: {:?} (target {:?})",
            entry.seq_len,
            out_len,
            tok.word(pred.max(0) as u32).unwrap_or("?"),
            item.target,
        );
    }

    println!("\nSee `repro demo` for the hermetic serve+eval loop, `repro table all` for the paper's experiments.");
    Ok(())
}
