//! Quickstart: load an AOT-compiled Mamba variant, run one reduced vs dense
//! forward on a real task prompt, and print what token reduction did.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use anyhow::{Context, Result};

use tor_ssm::data::load_tasks;
use tor_ssm::eval::scoring::SeqLogits;
use tor_ssm::manifest::Manifest;
use tor_ssm::runtime::{HostTensor, Runtime};
use tor_ssm::tokenizer::Tokenizer;
use tor_ssm::train::load_best_weights;

fn main() -> Result<()> {
    let man = Manifest::load(tor_ssm::artifacts_dir())?;
    let rt = Runtime::cpu()?;
    println!("platform: {}", rt.platform());

    let model = man.model("mamba-small")?.clone();
    let (weights, trained) = load_best_weights(&man, &model)?;
    println!(
        "model: {} ({} params, {} weights)",
        model.name,
        model.param_count,
        if trained { "trained" } else { "INIT — run `repro train --model mamba-small`" }
    );
    let dw = rt.upload_weights(&man, &model, &weights)?;

    // A real task prompt from the benchmark set.
    let tok = Tokenizer::load(man.path(&man.vocab_file))?;
    let tasks = load_tasks(man.path(&man.tasks_file))?;
    let item = &tasks[0].items[0]; // s-lambada cloze
    println!("\nprompt: \"{} ...\"", &item.context[..item.context.len().min(120)]);
    println!("cloze target: {:?}", item.target);

    let ids: Vec<i32> = tok.encode(&item.context).iter().map(|&x| x as i32).collect();
    let pos = ids.len(); // position whose prediction we inspect

    for (label, method, ratio) in [
        ("dense", "dense", 0.0),
        ("UTRC @20% FLOPs", "utrc", 0.20),
    ] {
        let entry = model.find_eval(method, ratio, None, None, None, None)?;
        let exe = rt.load_entry(&man, entry)?;
        let mut tokens = ids.clone();
        tokens.resize(entry.seq_len, 0);
        let mut flat = Vec::new();
        for _ in 0..entry.batch {
            flat.extend_from_slice(&tokens);
        }
        let tok_buf = rt.upload(&HostTensor::i32(vec![entry.batch, entry.seq_len], flat))?;
        let mut args: Vec<&xla::PjRtBuffer> = dw.buffers.iter().collect();
        args.push(&tok_buf);

        let t0 = std::time::Instant::now();
        let outs = exe.run_b(&args).context("forward")?;
        let dt = t0.elapsed();

        let logits = outs[0].as_f32()?;
        let kept = outs[1].as_i32()?;
        let out_len = entry.out_len;
        let v = model.vocab_size;
        let sl = SeqLogits { logits: &logits[..out_len * v], out_len, vocab: v, kept: &kept[..out_len] };
        let pred = sl.aligned_argmax(pos).unwrap_or(-1);
        println!(
            "\n[{label}] tokens {} -> {} surviving | forward {dt:?}\n  predicted next word: {:?} (target {:?})",
            entry.seq_len,
            out_len,
            tok.word(pred.max(0) as u32).unwrap_or("?"),
            item.target,
        );
    }

    println!("\nSee `repro table all` / `repro figure all` for the paper's experiments.");
    Ok(())
}
