pub fn blend(a: f32, b: f32, c: f32) -> f32 {
    a.mul_add(b, c)
}

pub fn cosine(x: &[f32], y: &[f32]) -> f32 {
    dot8(x, y)
}
