use std::sync::atomic::{AtomicUsize, Ordering};

static N: AtomicUsize = AtomicUsize::new(0);

pub fn tick() -> usize {
    // ORDERING: Relaxed — monotonic tally; nothing else is published.
    N.fetch_add(1, Ordering::Relaxed)
}
