pub fn twice(v: &[u32]) -> u32 {
    // tor-lint: allow(panic-serving) -- fixture: prove one annotation suppresses one finding
    let a = v[0];
    let b = v[1];
    a + b
}
