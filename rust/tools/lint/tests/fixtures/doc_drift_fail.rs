//! Implements the frobnicator (DESIGN.md §99 state machine); see the
//! PERFORMANCE.md bench notes for tuning.

pub fn knob() -> usize {
    std::env::var("TOR_SSM_PHANTOM_KNOB").ok().and_then(|v| v.parse().ok()).unwrap_or(1)
}
