use std::sync::atomic::{AtomicU64, Ordering};

pub struct Counters {
    seq: AtomicU64,
}

impl Counters {
    pub fn bad_epoch(&self) -> u64 {
        self.seq.load(Ordering::Relaxed)
    }
}
