pub fn nope(v: &[u32]) -> u32 {
    // tor-lint: allow(unsafe-audit) -- wrong check id on purpose
    v[0]
}
