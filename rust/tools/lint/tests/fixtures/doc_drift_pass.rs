//! Implements the frobnicator (DESIGN.md §1 state machine).

pub fn knob() -> usize {
    std::env::var("TOR_SSM_DOCUMENTED_KNOB").ok().and_then(|v| v.parse().ok()).unwrap_or(1)
}
