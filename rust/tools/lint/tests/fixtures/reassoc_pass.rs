pub fn dot8(x: &[f32], y: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (a, b) in x.iter().zip(y) {
        acc = a.mul_add(*b, acc);
    }
    acc
}

pub fn head_norm_logits(x: &[f32], y: &[f32]) -> f32 {
    dot8(x, y)
}
