pub fn peek(v: &[u8]) -> u8 {
    // SAFETY: length is checked by every caller.
    unsafe { *v.get_unchecked(0) }
}
