pub fn first(x: &[f32]) -> f32 {
    unsafe { *x.get_unchecked(0) }
}
