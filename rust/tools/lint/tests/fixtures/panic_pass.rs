pub fn first_or_zero(v: &[u32]) -> u32 {
    v.first().copied().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    #[test]
    fn panics_allowed_here() {
        let v = vec![1u32, 2];
        assert_eq!(v[0], 1);
        let _ = v.get(1).unwrap();
    }
}
