/// Exclusive view over `p .. p + n`.
///
/// # Safety
/// Caller guarantees the range is live, exclusively owned, and aligned.
pub unsafe fn view<'a>(p: *mut f32, n: usize) -> &'a mut [f32] {
    // SAFETY: forwarded contract — see the `# Safety` section above.
    unsafe { std::slice::from_raw_parts_mut(p, n) }
}
