pub fn kaboom(v: &[u32], m: &std::collections::HashMap<u32, u32>) -> u32 {
    let first = v[0];
    let looked = *m.get(&first).unwrap();
    if looked > 9000 {
        panic!("over nine thousand");
    }
    v.iter().next().expect("nonempty") + looked
}
