//! Fixture battery (DESIGN.md §16): every check is proven *live* by a
//! failing fixture with an exact finding count, and proven quiet by a
//! passing one. Checks 1–4 drive [`tor_lint::lint_source`] with synthetic
//! repo-relative labels (the path-scoped rules key off the label); check 5
//! needs a whole tree, so it drives [`tor_lint::run`] over a temp root.

use tor_lint::checks::Finding;
use tor_lint::{lint_source, report};

fn by_check<'a>(findings: &'a [Finding], check: &str) -> Vec<&'a Finding> {
    findings.iter().filter(|f| f.check == check).collect()
}

fn unsuppressed(findings: &[Finding]) -> usize {
    findings.iter().filter(|f| !f.suppressed).count()
}

// ---------------------------------------------------------------------------
// Check 1 — unsafe audit
// ---------------------------------------------------------------------------

#[test]
fn unsafe_pass_is_clean() {
    let f = lint_source(
        "rust/src/runtime/tensor.rs",
        include_str!("fixtures/unsafe_pass.rs"),
        false,
    );
    assert!(f.is_empty(), "expected no findings, got {f:?}");
}

#[test]
fn unsafe_outside_allowlist_fails_even_with_comment() {
    let f = lint_source(
        "rust/src/reduction/policy.rs",
        include_str!("fixtures/unsafe_fail_outside.rs"),
        false,
    );
    let hits = by_check(&f, "unsafe-audit");
    assert_eq!(hits.len(), 1, "{f:?}");
    assert_eq!(hits[0].line, 3);
    assert!(hits[0].message.contains("allowlist"), "{}", hits[0].message);
    assert_eq!(f.len(), 1, "no other checks should fire: {f:?}");
}

#[test]
fn unsafe_without_safety_comment_fails_inside_allowlist() {
    let f = lint_source(
        "rust/src/runtime/kernels.rs",
        include_str!("fixtures/unsafe_fail_nocomment.rs"),
        false,
    );
    let hits = by_check(&f, "unsafe-audit");
    assert_eq!(hits.len(), 1, "{f:?}");
    assert_eq!(hits[0].line, 2);
    assert!(hits[0].message.contains("SAFETY"), "{}", hits[0].message);
}

// ---------------------------------------------------------------------------
// Check 2 — float-reassociation guard
// ---------------------------------------------------------------------------

#[test]
fn reassoc_pass_is_clean() {
    let f = lint_source(
        "rust/src/runtime/kernels.rs",
        include_str!("fixtures/reassoc_pass.rs"),
        false,
    );
    assert!(f.is_empty(), "expected no findings, got {f:?}");
}

#[test]
fn reassoc_fail_flags_prim_and_head_call() {
    let f = lint_source(
        "rust/src/reduction/policy.rs",
        include_str!("fixtures/reassoc_fail.rs"),
        false,
    );
    let hits = by_check(&f, "float-reassoc");
    assert_eq!(hits.len(), 2, "{f:?}");
    assert_eq!(hits[0].line, 2, "mul_add outside kernels.rs");
    assert!(hits[0].message.contains("mul_add"));
    assert_eq!(hits[1].line, 6, "dot8( call from a non-whitelisted fn");
    assert!(hits[1].message.contains("dot8"));
    assert_eq!(f.len(), 2);
}

// ---------------------------------------------------------------------------
// Check 3 — atomics-ordering audit
// ---------------------------------------------------------------------------

#[test]
fn ordering_pass_is_clean() {
    let f = lint_source(
        "rust/src/runtime/counter.rs",
        include_str!("fixtures/ordering_pass.rs"),
        false,
    );
    assert!(f.is_empty(), "expected no findings, got {f:?}");
}

#[test]
fn ordering_fail_flags_missing_comment_and_relaxed_seqlock() {
    let f = lint_source(
        "rust/src/coordinator/http.rs",
        include_str!("fixtures/ordering_fail.rs"),
        false,
    );
    let hits = by_check(&f, "atomics-ordering");
    assert_eq!(hits.len(), 2, "{f:?}");
    assert!(hits.iter().all(|h| h.line == 9));
    assert!(
        hits.iter().any(|h| h.message.contains("seqlock")),
        "the targeted seqlock rule must fire: {f:?}"
    );
    assert!(
        hits.iter().any(|h| h.message.contains("ORDERING:")),
        "the missing-justification rule must fire: {f:?}"
    );
}

// ---------------------------------------------------------------------------
// Check 4 — panic-freedom in serving paths
// ---------------------------------------------------------------------------

#[test]
fn panic_pass_is_clean() {
    let f = lint_source(
        "rust/src/coordinator/scheduler.rs",
        include_str!("fixtures/panic_pass.rs"),
        false,
    );
    assert!(f.is_empty(), "unwrap_or and test-mod panics must not flag: {f:?}");
}

#[test]
fn panic_pass_file_outside_serving_paths_is_ignored() {
    // The same panicking source under a non-serving label is out of scope.
    let f = lint_source(
        "rust/src/runtime/kernels_helpers.rs",
        include_str!("fixtures/panic_fail.rs"),
        false,
    );
    assert!(by_check(&f, "panic-serving").is_empty(), "{f:?}");
}

#[test]
fn panic_fail_flags_each_site_exactly_once() {
    let f = lint_source(
        "rust/src/coordinator/http.rs",
        include_str!("fixtures/panic_fail.rs"),
        false,
    );
    let hits = by_check(&f, "panic-serving");
    let lines: Vec<usize> = hits.iter().map(|h| h.line).collect();
    assert_eq!(lines, vec![2, 3, 5, 7], "index, unwrap, panic!, expect: {f:?}");
    assert_eq!(f.len(), 4);
}

// ---------------------------------------------------------------------------
// Escape hatch — one annotation suppresses exactly one finding
// ---------------------------------------------------------------------------

#[test]
fn allow_annotation_suppresses_exactly_one_finding() {
    let f = lint_source(
        "rust/src/coordinator/http.rs",
        include_str!("fixtures/allow_one.rs"),
        false,
    );
    assert_eq!(f.len(), 2, "{f:?}");
    let kept: Vec<&Finding> = f.iter().filter(|x| !x.suppressed).collect();
    let dropped: Vec<&Finding> = f.iter().filter(|x| x.suppressed).collect();
    assert_eq!(dropped.len(), 1, "one annotation → one suppression: {f:?}");
    assert_eq!(dropped[0].line, 3);
    assert_eq!(
        dropped[0].allow_reason.as_deref(),
        Some("fixture: prove one annotation suppresses one finding")
    );
    assert_eq!(kept.len(), 1);
    assert_eq!(kept[0].line, 4, "the second index on the next line stays live");
}

#[test]
fn allow_with_wrong_check_id_does_not_suppress() {
    let f = lint_source(
        "rust/src/coordinator/http.rs",
        include_str!("fixtures/allow_wrong_id.rs"),
        false,
    );
    assert_eq!(f.len(), 1, "{f:?}");
    assert!(!f[0].suppressed, "annotation names a different check: {f:?}");
}

// ---------------------------------------------------------------------------
// Check 5 — doc/knob drift (needs a tree → drive `run` over a temp root)
// ---------------------------------------------------------------------------

fn temp_root(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("tor-lint-fixtures-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(d.join("rust/src")).unwrap();
    d
}

#[test]
fn doc_drift_flags_stale_citation_missing_doc_and_undocumented_knob() {
    let root = temp_root("drift");
    std::fs::write(
        root.join("rust/src/doc_drift_fail.rs"),
        include_str!("fixtures/doc_drift_fail.rs"),
    )
    .unwrap();
    std::fs::write(
        root.join("rust/src/doc_drift_pass.rs"),
        include_str!("fixtures/doc_drift_pass.rs"),
    )
    .unwrap();
    std::fs::write(root.join("DESIGN.md"), "# Design\n\n## §1 Overview\n\nWords.\n").unwrap();
    std::fs::write(
        root.join("README.md"),
        "Knobs: `TOR_SSM_DOCUMENTED_KNOB` controls the frobnicator.\n",
    )
    .unwrap();
    // No PERFORMANCE.md on purpose — the fail fixture cites it.

    let (findings, files_scanned) = tor_lint::run(&root).unwrap();
    assert_eq!(files_scanned, 2);
    assert!(
        findings.iter().all(|f| f.check == "doc-drift"),
        "only check 5 should fire on these sources: {findings:?}"
    );
    assert!(
        findings.iter().all(|f| f.file.ends_with("doc_drift_fail.rs")),
        "the pass fixture must stay clean: {findings:?}"
    );
    let msgs: Vec<&str> = findings.iter().map(|f| f.message.as_str()).collect();
    assert_eq!(findings.len(), 3, "{msgs:?}");
    assert!(msgs.iter().any(|m| m.contains("§99")), "{msgs:?}");
    assert!(msgs.iter().any(|m| m.contains("PERFORMANCE.md")), "{msgs:?}");
    assert!(msgs.iter().any(|m| m.contains("TOR_SSM_PHANTOM_KNOB")), "{msgs:?}");

    let _ = std::fs::remove_dir_all(&root);
}

#[test]
fn doc_drift_pass_tree_is_clean() {
    let root = temp_root("clean");
    std::fs::write(
        root.join("rust/src/doc_drift_pass.rs"),
        include_str!("fixtures/doc_drift_pass.rs"),
    )
    .unwrap();
    std::fs::write(root.join("DESIGN.md"), "# Design\n\n## §1 Overview\n\nWords.\n").unwrap();
    std::fs::write(
        root.join("README.md"),
        "Knobs: `TOR_SSM_DOCUMENTED_KNOB` controls the frobnicator.\n",
    )
    .unwrap();

    let (findings, files_scanned) = tor_lint::run(&root).unwrap();
    assert_eq!(files_scanned, 1);
    assert!(findings.is_empty(), "{findings:?}");

    let _ = std::fs::remove_dir_all(&root);
}

// ---------------------------------------------------------------------------
// JSON report shape
// ---------------------------------------------------------------------------

#[test]
fn json_report_has_stable_shape_counts_and_reasons() {
    let findings = lint_source(
        "rust/src/coordinator/http.rs",
        include_str!("fixtures/allow_one.rs"),
        false,
    );
    let json = report::to_json(&findings, 1);
    assert!(json.contains("\"version\": 1"), "{json}");
    assert!(json.contains("\"files_scanned\": 1"), "{json}");
    // Every check id appears in counts even when zero.
    for id in tor_lint::checks::CHECK_IDS {
        assert!(json.contains(&format!("\"{id}\": ")), "missing count for {id}: {json}");
    }
    assert!(json.contains("\"panic-serving\": 1"), "one unsuppressed finding: {json}");
    assert!(json.contains("\"suppressed\": 1"), "{json}");
    assert!(
        json.contains("\"allow_reason\": \"fixture: prove one annotation suppresses one finding\""),
        "{json}"
    );
    assert_eq!(unsuppressed(&findings), 1);
}

#[test]
fn json_report_sorts_findings_by_file_line_check() {
    let findings = lint_source(
        "rust/src/coordinator/http.rs",
        include_str!("fixtures/panic_fail.rs"),
        false,
    );
    let json = report::to_json(&findings, 1);
    let positions: Vec<usize> = [2usize, 3, 5, 7]
        .iter()
        .map(|l| json.find(&format!("\"line\": {l},")).unwrap_or(usize::MAX))
        .collect();
    assert!(
        positions.windows(2).all(|w| w[0] < w[1]),
        "findings must render in (file, line, check) order: {json}"
    );
}
