//! `tor-lint` — the in-repo invariant checker (DESIGN.md §16).
//!
//! Tokenizes the workspace's Rust sources with a purpose-built lexer
//! ([`lexer`]) and runs the five project-invariant checks ([`checks`]):
//! unsafe audit, float-reassociation guard, atomics-ordering audit,
//! panic-freedom in serving paths, and doc/knob drift. Exposed as a
//! library so the fixture tests can drive individual checks with
//! synthetic path labels.

pub mod checks;
pub mod lexer;
pub mod report;

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};

use checks::{DocDriftInput, Finding};

/// Directories scanned for token-level checks (1–4) and raw scans (5),
/// relative to the repo root. `benches/` and `tests/` are harness code:
/// they are force-marked as test scope so only check 5 (doc/knob drift)
/// applies to them. Vendored crates (`rust/crates/`) and this tool are
/// excluded — the invariants govern the serving crate, not the shims.
const SCAN_DIRS: [(&str, bool); 3] = [
    ("rust/src", false),
    ("rust/benches", true),
    ("rust/tests", true),
];

fn walk_rs(dir: &Path, into: &mut Vec<PathBuf>) {
    let Ok(rd) = std::fs::read_dir(dir) else {
        return;
    };
    let mut entries: Vec<PathBuf> = rd.flatten().map(|e| e.path()).collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk_rs(&p, into);
        } else if p.extension().is_some_and(|e| e == "rs") {
            into.push(p);
        }
    }
}

/// Run every check over the tree rooted at `root`. Returns the findings
/// (suppressions already applied) and the number of files scanned.
pub fn run(root: &Path) -> std::io::Result<(Vec<Finding>, usize)> {
    let mut findings = Vec::new();
    let mut lx_by_file: BTreeMap<String, lexer::Lexed> = BTreeMap::new();
    let mut sources = Vec::new();
    let mut env = BTreeMap::new();

    for (dir, force_test) in SCAN_DIRS {
        let mut files = Vec::new();
        walk_rs(&root.join(dir), &mut files);
        for path in files {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .to_string_lossy()
                .replace('\\', "/");
            let text = std::fs::read_to_string(&path)?;
            let lx = lexer::lex(&text, force_test);
            checks::check_unsafe(&rel, &lx, &mut findings);
            checks::check_reassoc(&rel, &lx, &mut findings);
            checks::check_ordering(&rel, &lx, &mut findings);
            checks::check_panic(&rel, &lx, &mut findings);
            checks::env_reads(&rel, &lx, &mut env);
            sources.push((rel.clone(), text));
            lx_by_file.insert(rel, lx);
        }
    }

    let read_doc = |name: &str| std::fs::read_to_string(root.join(name)).unwrap_or_default();
    let mut existing_docs = BTreeSet::new();
    for doc in ["DESIGN.md", "PERFORMANCE.md", "README.md"] {
        if root.join(doc).is_file() {
            existing_docs.insert(doc.to_string());
        }
    }
    let input = DocDriftInput {
        sources,
        design: read_doc("DESIGN.md"),
        knob_docs: format!("{}\n{}", read_doc("README.md"), read_doc("PERFORMANCE.md")),
        existing_docs,
        env_reads: env,
    };
    checks::check_doc_drift(&input, &mut findings);

    checks::apply_allows(&lx_by_file, &mut findings);
    let files_scanned = lx_by_file.len();
    Ok((findings, files_scanned))
}

/// Lint a single in-memory source under a synthetic repo-relative label
/// (the path-scoped rules key off the label). Test-only entry point for
/// the fixture suite; check 5 needs the tree-level [`run`].
pub fn lint_source(label: &str, text: &str, force_test: bool) -> Vec<Finding> {
    let lx = lexer::lex(text, force_test);
    let mut findings = Vec::new();
    checks::check_unsafe(label, &lx, &mut findings);
    checks::check_reassoc(label, &lx, &mut findings);
    checks::check_ordering(label, &lx, &mut findings);
    checks::check_panic(label, &lx, &mut findings);
    let mut by_file = BTreeMap::new();
    by_file.insert(label.to_string(), lx);
    checks::apply_allows(&by_file, &mut findings);
    findings
}
