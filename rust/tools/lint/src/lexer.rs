//! A purpose-built Rust lexer: just enough of the language to audit the
//! project invariants (DESIGN.md §16) — strings, comments, attributes,
//! lifetimes-vs-char-literals — with **no** rustc plumbing. It does not
//! parse; a second pass annotates every token with its enclosing function
//! name and whether it sits in test scope (`#[cfg(test)] mod` / `#[test]
//! fn`), which is all the checks need.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Ident,
    Punct,
    Literal,
    Lifetime,
}

#[derive(Debug, Clone)]
pub struct Tok {
    pub text: String,
    pub kind: Kind,
    /// 1-based source line.
    pub line: usize,
    /// Name of the innermost enclosing `fn`, if any.
    pub fn_name: Option<String>,
    /// Inside `#[cfg(test)]` / `#[test]` scope (or a file force-marked as
    /// test code, e.g. everything under `tests/` and `benches/`).
    pub in_test: bool,
    /// Inside a `use …;` item (import paths are not executable code).
    pub in_use: bool,
}

#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    /// line → concatenated comment text appearing on that line (line
    /// comments, doc comments, and each line of a block comment).
    pub comments: BTreeMap<usize, String>,
}

impl Lexed {
    /// True if any comment on a line in `[line-span ..= line]` contains any
    /// of `markers`.
    pub fn comment_near(&self, line: usize, span: usize, markers: &[&str]) -> bool {
        let lo = line.saturating_sub(span);
        self.comments
            .range(lo..=line)
            .any(|(_, c)| markers.iter().any(|m| c.contains(m)))
    }
}

/// Tokenize `src`. `force_test` marks every token as test scope (used for
/// files under `tests/` / `benches/`, which are test harness code wholesale).
pub fn lex(src: &str, force_test: bool) -> Lexed {
    let mut lx = Lexed::default();
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut i = 0;
    let mut line = 1usize;

    let push_comment = |lx: &mut Lexed, line: usize, text: &str| {
        let e = lx.comments.entry(line).or_default();
        if !e.is_empty() {
            e.push(' ');
        }
        e.push_str(text);
    };

    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment (incl. /// and //!).
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            let start = i;
            while i < n && b[i] != '\n' {
                i += 1;
            }
            let text: String = b[start..i].iter().collect();
            push_comment(&mut lx, line, &text);
            continue;
        }
        // Block comment, nested.
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let mut depth = 1;
            let mut seg = String::from("/*");
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    seg.push_str("/*");
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    seg.push_str("*/");
                    i += 2;
                } else if b[i] == '\n' {
                    push_comment(&mut lx, line, &seg);
                    seg.clear();
                    line += 1;
                    i += 1;
                } else {
                    seg.push(b[i]);
                    i += 1;
                }
            }
            if !seg.is_empty() {
                push_comment(&mut lx, line, &seg);
            }
            continue;
        }
        // Raw / byte strings: r"…", r#"…"#, b"…", br#"…"#.
        if (c == 'r' || c == 'b') && is_raw_string_start(&b, i) {
            let start_line = line;
            let mut j = i + 1;
            if c == 'b' && j < n && b[j] == 'r' {
                j += 1;
            }
            let mut hashes = 0;
            while j < n && b[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && b[j] == '"' {
                j += 1;
                let body_start = j;
                // scan for `"` followed by `hashes` #'s
                loop {
                    if j >= n {
                        break;
                    }
                    if b[j] == '\n' {
                        line += 1;
                        j += 1;
                        continue;
                    }
                    if b[j] == '"' && b[j + 1..].iter().take_while(|&&h| h == '#').count() >= hashes
                    {
                        break;
                    }
                    j += 1;
                }
                let text: String = b[body_start..j.min(n)].iter().collect();
                lx.toks.push(raw_tok(text, Kind::Literal, start_line));
                i = (j + 1 + hashes).min(n);
                continue;
            }
            // Fall through: plain ident starting with r/b.
        }
        // Plain (possibly byte) string.
        if c == '"' || (c == 'b' && i + 1 < n && b[i + 1] == '"') {
            let start_line = line;
            let mut j = if c == 'b' { i + 2 } else { i + 1 };
            let body_start = j;
            while j < n {
                match b[j] {
                    '\\' => j += 2,
                    '"' => break,
                    '\n' => {
                        line += 1;
                        j += 1;
                    }
                    _ => j += 1,
                }
            }
            let text: String = b[body_start..j.min(n)].iter().collect();
            lx.toks.push(raw_tok(text, Kind::Literal, start_line));
            i = (j + 1).min(n);
            continue;
        }
        // Char literal vs lifetime.
        if c == '\'' {
            // 'x' or '\n' → char literal; 'ident (no closing quote) → lifetime.
            let mut j = i + 1;
            if j < n && b[j] == '\\' {
                // escaped char literal
                j += 2;
                while j < n && b[j] != '\'' {
                    j += 1;
                }
                lx.toks.push(raw_tok(String::new(), Kind::Literal, line));
                i = (j + 1).min(n);
                continue;
            }
            if j + 1 < n && b[j + 1] == '\'' {
                lx.toks
                    .push(raw_tok(b[j].to_string(), Kind::Literal, line));
                i = j + 2;
                continue;
            }
            // lifetime
            let start = j;
            while j < n && (b[j].is_alphanumeric() || b[j] == '_') {
                j += 1;
            }
            let text: String = b[start..j].iter().collect();
            lx.toks.push(raw_tok(text, Kind::Lifetime, line));
            i = j;
            continue;
        }
        // Number literal.
        if c.is_ascii_digit() {
            let start = i;
            while i < n && (b[i].is_alphanumeric() || b[i] == '_' || b[i] == '.') {
                // `0..n` range: stop before a second consecutive dot.
                if b[i] == '.' && i + 1 < n && b[i + 1] == '.' {
                    break;
                }
                i += 1;
            }
            let text: String = b[start..i].iter().collect();
            lx.toks.push(raw_tok(text, Kind::Literal, line));
            continue;
        }
        // Identifier / keyword.
        if c.is_alphabetic() || c == '_' {
            let start = i;
            while i < n && (b[i].is_alphanumeric() || b[i] == '_') {
                i += 1;
            }
            let text: String = b[start..i].iter().collect();
            lx.toks.push(raw_tok(text, Kind::Ident, line));
            continue;
        }
        // Single-char punctuation (`::` arrives as two `:` tokens).
        lx.toks.push(raw_tok(c.to_string(), Kind::Punct, line));
        i += 1;
    }

    annotate_scopes(&mut lx.toks, force_test);
    lx
}

fn raw_tok(text: String, kind: Kind, line: usize) -> Tok {
    Tok {
        text,
        kind,
        line,
        fn_name: None,
        in_test: false,
        in_use: false,
    }
}

fn is_raw_string_start(b: &[char], i: usize) -> bool {
    // r" r# b" br" br# — an ident char right after r/b means plain ident.
    let mut j = i + 1;
    if b[i] == 'b' && j < b.len() && b[j] == 'r' {
        j += 1;
    }
    while j < b.len() && b[j] == '#' {
        j += 1;
    }
    j < b.len() && b[j] == '"'
}

#[derive(Clone)]
struct Frame {
    fn_name: Option<String>,
    is_test: bool,
}

/// Second pass: brace-depth scope stack with pending-attribute attachment.
/// `#[test]` / `#[cfg(test)]` (any attr whose idents include `test` but not
/// `not`) marks the next `fn`/`mod` item — and everything inside its braces
/// — as test scope. `use …;` spans set `in_use`.
fn annotate_scopes(toks: &mut [Tok], force_test: bool) {
    let mut stack: Vec<Frame> = vec![Frame {
        fn_name: None,
        is_test: force_test,
    }];
    let mut pending_attr_test = false;
    // (fn name or None for mod, test flag) for an item header seen but
    // whose `{` has not arrived yet.
    let mut pending_item: Option<(Option<String>, bool)> = None;
    let mut in_use = false;

    let mut i = 0;
    while i < toks.len() {
        // Annotate from the current top frame first.
        {
            let top = stack.last().cloned().unwrap_or(Frame {
                fn_name: None,
                is_test: force_test,
            });
            toks[i].fn_name = top.fn_name;
            toks[i].in_test = top.is_test || force_test;
            toks[i].in_use = in_use;
        }
        let text = toks[i].text.clone();
        let kind = toks[i].kind;
        match (kind, text.as_str()) {
            (Kind::Punct, "#") => {
                // Attribute: scan the bracketed group for `test` idents.
                if i + 1 < toks.len() && toks[i + 1].text == "[" {
                    let mut depth = 0;
                    let mut j = i + 1;
                    let mut saw_test = false;
                    let mut saw_not = false;
                    while j < toks.len() {
                        match toks[j].text.as_str() {
                            "[" => depth += 1,
                            "]" => {
                                depth -= 1;
                                if depth == 0 {
                                    break;
                                }
                            }
                            "test" if toks[j].kind == Kind::Ident => saw_test = true,
                            "not" if toks[j].kind == Kind::Ident => saw_not = true,
                            _ => {}
                        }
                        // Attribute interiors keep the enclosing scope.
                        toks[j].fn_name = toks[i].fn_name.clone();
                        toks[j].in_test = toks[i].in_test;
                        j += 1;
                    }
                    if saw_test && !saw_not {
                        pending_attr_test = true;
                    }
                    i = j + 1;
                    continue;
                }
            }
            (Kind::Ident, "fn") => {
                let name = toks
                    .get(i + 1)
                    .filter(|t| t.kind == Kind::Ident)
                    .map(|t| t.text.clone());
                pending_item = Some((name, pending_attr_test));
                pending_attr_test = false;
            }
            (Kind::Ident, "mod") => {
                pending_item = Some((None, pending_attr_test));
                pending_attr_test = false;
            }
            (Kind::Ident, "use") => in_use = true,
            (Kind::Punct, ";") => {
                in_use = false;
                pending_item = None; // trait method decl without a body
            }
            (Kind::Punct, "{") => {
                let top = stack.last().cloned().unwrap_or(Frame {
                    fn_name: None,
                    is_test: force_test,
                });
                let frame = match pending_item.take() {
                    Some((name, t)) => Frame {
                        // A mod resets the fn context; a fn names it.
                        fn_name: name.or(None),
                        is_test: top.is_test || t,
                    },
                    // Plain block / struct body / match arm: inherit.
                    None => top,
                };
                stack.push(frame);
            }
            (Kind::Punct, "}") => {
                if stack.len() > 1 {
                    stack.pop();
                }
            }
            _ => {}
        }
        i += 1;
    }
}
