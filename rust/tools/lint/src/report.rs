//! Hand-rolled JSON emitter for the lint report (stdlib only, same policy
//! as `util/json.rs` in the main crate — no serde).

use std::collections::BTreeMap;

use crate::checks::{Finding, CHECK_IDS};

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Serialize the report: stable field order, findings sorted by
/// (file, line, check), per-check unsuppressed counts, suppression total.
pub fn to_json(findings: &[Finding], files_scanned: usize) -> String {
    let mut sorted: Vec<&Finding> = findings.iter().collect();
    sorted.sort_by(|a, b| {
        (&a.file, a.line, a.check).cmp(&(&b.file, b.line, b.check))
    });

    let mut counts: BTreeMap<&str, usize> = CHECK_IDS.iter().map(|&c| (c, 0)).collect();
    let mut suppressed = 0usize;
    for f in &sorted {
        if f.suppressed {
            suppressed += 1;
        } else {
            *counts.entry(f.check).or_insert(0) += 1;
        }
    }

    let mut s = String::new();
    s.push_str("{\n  \"version\": 1,\n");
    s.push_str(&format!("  \"files_scanned\": {files_scanned},\n"));
    s.push_str("  \"counts\": {");
    let mut first = true;
    for id in CHECK_IDS {
        if !first {
            s.push_str(", ");
        }
        first = false;
        s.push_str(&format!("\"{id}\": {}", counts.get(id).copied().unwrap_or(0)));
    }
    s.push_str("},\n");
    s.push_str(&format!("  \"suppressed\": {suppressed},\n"));
    s.push_str("  \"findings\": [\n");
    for (i, f) in sorted.iter().enumerate() {
        let reason = match &f.allow_reason {
            Some(r) => format!("\"{}\"", esc(r)),
            None => "null".to_string(),
        };
        s.push_str(&format!(
            "    {{\"check\": \"{}\", \"file\": \"{}\", \"line\": {}, \"message\": \"{}\", \
             \"suppressed\": {}, \"allow_reason\": {}}}{}\n",
            f.check,
            esc(&f.file),
            f.line,
            esc(&f.message),
            f.suppressed,
            reason,
            if i + 1 == sorted.len() { "" } else { "," }
        ));
    }
    s.push_str("  ]\n}\n");
    s
}
