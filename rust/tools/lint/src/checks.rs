//! The project-invariant check registry (DESIGN.md §16). Each check walks
//! the token stream produced by [`crate::lexer`] and emits [`Finding`]s;
//! the annotation escape hatch (`// tor-lint: allow(<check-id>) -- reason`)
//! is applied afterwards by [`apply_allows`] and suppresses **exactly one**
//! finding per annotation.

use std::collections::{BTreeMap, BTreeSet};

use crate::lexer::{Kind, Lexed, Tok};

pub const CHECK_IDS: [&str; 5] = [
    "unsafe-audit",
    "float-reassoc",
    "atomics-ordering",
    "panic-serving",
    "doc-drift",
];

#[derive(Debug, Clone)]
pub struct Finding {
    pub check: &'static str,
    pub file: String,
    pub line: usize,
    pub message: String,
    pub suppressed: bool,
    pub allow_reason: Option<String>,
}

fn finding(check: &'static str, file: &str, line: usize, message: String) -> Finding {
    Finding {
        check,
        file: file.to_string(),
        line,
        message,
        suppressed: false,
        allow_reason: None,
    }
}

fn ends_with(file: &str, suffix: &str) -> bool {
    file.replace('\\', "/").ends_with(suffix)
}

/// Check 1 — unsafe audit. Every `unsafe` token needs an adjacent
/// `// SAFETY:` (or rustdoc `# Safety` section) within 8 lines above, and
/// `unsafe` is only permitted at all in the allowlisted files (the tensor
/// lane-chunk views, the SIMD kernels, and main.rs signal registration).
pub fn check_unsafe(file: &str, lx: &Lexed, out: &mut Vec<Finding>) {
    const ALLOW_FILES: [&str; 3] = ["runtime/tensor.rs", "runtime/kernels.rs", "src/main.rs"];
    for t in &lx.toks {
        if t.kind != Kind::Ident || t.text != "unsafe" {
            continue;
        }
        if !ALLOW_FILES.iter().any(|s| ends_with(file, s)) {
            out.push(finding(
                "unsafe-audit",
                file,
                t.line,
                "`unsafe` outside the allowlist (runtime/tensor.rs, runtime/kernels.rs, \
                 src/main.rs)"
                    .into(),
            ));
            continue;
        }
        if !lx.comment_near(t.line, 8, &["SAFETY:", "Safety:", "# Safety"]) {
            out.push(finding(
                "unsafe-audit",
                file,
                t.line,
                "`unsafe` without an adjacent `// SAFETY:` comment".into(),
            ));
        }
    }
}

/// Check 2 — float-reassociation guard. Reassociating primitives
/// (`mul_add`, FMA/horizontal-add intrinsics, the `hsum8` tree) are
/// confined to the `dot8` family in runtime/kernels.rs, and the chunked
/// heads themselves (`dot8(` / `dot8_i8(` call sites) may additionally be
/// called only from the whitelisted logit heads. This is what protects the
/// `2·d·ε` error-bound contract (DESIGN.md §13).
pub fn check_reassoc(file: &str, lx: &Lexed, out: &mut Vec<Finding>) {
    const PRIM_FNS: [&str; 5] = [
        "dot8",
        "dot8_portable",
        "dot8_i8",
        "dot8_i8_portable",
        "hsum8",
    ];
    const HEAD_CALLERS: [&str; 5] = [
        "dot8",
        "dot8_i8",
        "hsum8",
        "head_norm_logits", // kernels.rs f32/int8 logit head
        "head_logits",      // reference.rs int8 logit head
    ];
    let toks = &lx.toks;
    for (i, t) in toks.iter().enumerate() {
        if t.kind != Kind::Ident || t.in_test || t.in_use {
            continue;
        }
        let after_fn = i > 0 && toks[i - 1].kind == Kind::Ident && toks[i - 1].text == "fn";
        let is_prim = t.text == "mul_add"
            || t.text == "hsum8"
            || t.text.contains("fmadd")
            || t.text.contains("hadd")
            || t.text.contains("dp_ps");
        if is_prim && !after_fn {
            let in_whitelist = ends_with(file, "runtime/kernels.rs")
                && t.fn_name.as_deref().is_some_and(|f| PRIM_FNS.contains(&f));
            if !in_whitelist {
                out.push(finding(
                    "float-reassoc",
                    file,
                    t.line,
                    format!(
                        "reassociating primitive `{}` outside the dot8 head in \
                         runtime/kernels.rs",
                        t.text
                    ),
                ));
            }
            continue;
        }
        // Head-call tier: `dot8(`-family call sites.
        let is_head_call = PRIM_FNS.contains(&t.text.as_str())
            && toks.get(i + 1).is_some_and(|n| n.text == "(")
            && !after_fn;
        if is_head_call {
            let caller_ok = t
                .fn_name
                .as_deref()
                .is_some_and(|f| HEAD_CALLERS.contains(&f))
                && (ends_with(file, "runtime/kernels.rs")
                    || ends_with(file, "runtime/reference.rs"));
            if !caller_ok {
                out.push(finding(
                    "float-reassoc",
                    file,
                    t.line,
                    format!(
                        "`{}` called outside the whitelisted logit heads \
                         (dot8 family, head_norm_logits, head_logits)",
                        t.text
                    ),
                ));
            }
        }
    }
}

const ATOMIC_VARIANTS: [&str; 5] = ["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];

/// True if `toks[i]` is the `Ordering` ident of an atomic `Ordering::X`
/// path (filters out `std::cmp::Ordering::{Less,Equal,Greater}`).
fn atomic_ordering_at(toks: &[Tok], i: usize) -> Option<&'static str> {
    let t = &toks[i];
    if t.kind != Kind::Ident || t.text != "Ordering" {
        return None;
    }
    if !(toks.get(i + 1).is_some_and(|a| a.text == ":")
        && toks.get(i + 2).is_some_and(|a| a.text == ":"))
    {
        return None;
    }
    let v = toks.get(i + 3)?;
    ATOMIC_VARIANTS.iter().find(|&&s| s == v.text).copied()
}

/// Check 3 — atomics-ordering audit. Every atomic `Ordering::` use outside
/// tests needs a `// ORDERING:` justification within 6 lines, and the
/// seqlock epoch counter in coordinator/http.rs must never be accessed
/// `Relaxed` (its loads are Acquire and its bumps AcqRel — the
/// Relaxed-epoch bug class would let torn `/stats` snapshots through).
pub fn check_ordering(file: &str, lx: &Lexed, out: &mut Vec<Finding>) {
    let toks = &lx.toks;
    for i in 0..toks.len() {
        let Some(variant) = atomic_ordering_at(toks, i) else {
            continue;
        };
        let t = &toks[i];
        if t.in_test || t.in_use {
            continue;
        }
        if !lx.comment_near(t.line, 6, &["ORDERING:"]) {
            out.push(finding(
                "atomics-ordering",
                file,
                t.line,
                format!("`Ordering::{variant}` without an adjacent `// ORDERING:` justification"),
            ));
        }
        // Targeted seqlock rule: an access chain mentioning the `seq`
        // atomic within the preceding few tokens must not be Relaxed.
        if ends_with(file, "coordinator/http.rs") && variant == "Relaxed" {
            let lo = i.saturating_sub(8);
            if toks[lo..i]
                .iter()
                .any(|p| p.kind == Kind::Ident && p.text == "seq")
            {
                out.push(finding(
                    "atomics-ordering",
                    file,
                    t.line,
                    "seqlock epoch access uses Ordering::Relaxed (loads must be Acquire, \
                     bumps AcqRel/Release)"
                        .into(),
                ));
            }
        }
    }
}

/// Check 4 — panic-freedom in serving paths. In coordinator/http.rs,
/// coordinator/replica.rs and coordinator/scheduler.rs non-test code,
/// `unwrap()` / `expect(` / `panic!` (and friends) / index-or-slice
/// expressions are errors: a handler-thread panic kills a live connection
/// silently. (`unwrap_or*` are different identifiers and stay allowed.)
pub fn check_panic(file: &str, lx: &Lexed, out: &mut Vec<Finding>) {
    const SERVING_FILES: [&str; 3] = [
        "coordinator/http.rs",
        "coordinator/replica.rs",
        "coordinator/scheduler.rs",
    ];
    if !SERVING_FILES.iter().any(|s| ends_with(file, s)) {
        return;
    }
    // `[` after one of these closes an index/slice expression target.
    const KEYWORDS_NOT_INDEX: [&str; 14] = [
        "let", "in", "mut", "ref", "return", "else", "match", "if", "while", "for", "move", "as",
        "break", "continue",
    ];
    let toks = &lx.toks;
    for (i, t) in toks.iter().enumerate() {
        if t.in_test {
            continue;
        }
        match (t.kind, t.text.as_str()) {
            (Kind::Ident, "unwrap") | (Kind::Ident, "expect") => {
                if toks.get(i + 1).is_some_and(|n| n.text == "(") {
                    out.push(finding(
                        "panic-serving",
                        file,
                        t.line,
                        format!("`.{}(` in a serving path can panic a handler thread", t.text),
                    ));
                }
            }
            (Kind::Ident, "panic")
            | (Kind::Ident, "unreachable")
            | (Kind::Ident, "todo")
            | (Kind::Ident, "unimplemented")
            | (Kind::Ident, "assert") => {
                if toks.get(i + 1).is_some_and(|n| n.text == "!") {
                    out.push(finding(
                        "panic-serving",
                        file,
                        t.line,
                        format!("`{}!` in a serving path can panic a handler thread", t.text),
                    ));
                }
            }
            (Kind::Punct, "[") => {
                let Some(prev) = i.checked_sub(1).map(|p| &toks[p]) else {
                    continue;
                };
                let is_index_target = match prev.kind {
                    Kind::Ident => !KEYWORDS_NOT_INDEX.contains(&prev.text.as_str()),
                    Kind::Punct => prev.text == "]" || prev.text == ")" || prev.text == "?",
                    _ => false,
                };
                if is_index_target {
                    out.push(finding(
                        "panic-serving",
                        file,
                        t.line,
                        "index/slice expression in a serving path can panic; use `.get()` \
                         or a pattern"
                            .into(),
                    ));
                }
            }
            _ => {}
        }
    }
}

/// Inputs for check 5 that span the whole tree rather than one token
/// stream: raw source texts plus the doc files they must agree with.
pub struct DocDriftInput {
    /// (repo-relative path, raw content) for every scanned rust source.
    pub sources: Vec<(String, String)>,
    /// DESIGN.md content ("" if the file is absent).
    pub design: String,
    /// README.md + PERFORMANCE.md content concatenated.
    pub knob_docs: String,
    /// Repo-relative doc files that exist (e.g. {"DESIGN.md", …}).
    pub existing_docs: BTreeSet<String>,
    /// Env-var names read in source (string literals `TOR_SSM_*` /
    /// `REPRO_BENCH_*`), with one representative (file, line) each.
    pub env_reads: BTreeMap<String, (String, usize)>,
}

/// Check 5 — doc/knob drift. Cited `DESIGN.md §N` headings and cited doc
/// file paths must exist, and every `TOR_SSM_*`/`REPRO_BENCH_*` env var
/// read in source must appear in README.md or PERFORMANCE.md. This absorbs
/// (and retires) the ad-hoc shell-grep gate that used to live in ci.yml.
pub fn check_doc_drift(input: &DocDriftInput, out: &mut Vec<Finding>) {
    // §N citations → `## §N ` headings.
    for (file, text) in &input.sources {
        for (line_no, line) in text.lines().enumerate() {
            let mut rest = line;
            while let Some(pos) = rest.find("DESIGN.md §") {
                rest = &rest[pos + "DESIGN.md §".len()..];
                let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
                if digits.is_empty() {
                    continue;
                }
                let heading = format!("## §{digits} ");
                if !input.design.lines().any(|l| l.starts_with(&heading)) {
                    out.push(finding(
                        "doc-drift",
                        file,
                        line_no + 1,
                        format!(
                            "cites DESIGN.md §{digits} but no `## §{digits} ` heading exists"
                        ),
                    ));
                }
            }
            // Cited doc files must exist.
            for doc in ["DESIGN.md", "PERFORMANCE.md", "README.md"] {
                if line.contains(doc) && !input.existing_docs.contains(doc) {
                    out.push(finding(
                        "doc-drift",
                        file,
                        line_no + 1,
                        format!("cites {doc} but it does not exist"),
                    ));
                }
            }
        }
    }
    // Every env knob read in source is documented.
    for (var, (file, line)) in &input.env_reads {
        if !input.knob_docs.contains(var.as_str()) {
            out.push(finding(
                "doc-drift",
                file,
                *line,
                format!("env var `{var}` is read here but documented in neither README.md nor \
                         PERFORMANCE.md"),
            ));
        }
    }
}

/// Extract `TOR_SSM_*` / `REPRO_BENCH_*` env-var names from a token
/// stream's string literals.
pub fn env_reads(file: &str, lx: &Lexed, into: &mut BTreeMap<String, (String, usize)>) {
    for t in &lx.toks {
        if t.kind != Kind::Literal {
            continue;
        }
        let s = t.text.as_str();
        let looks_like_var = (s.starts_with("TOR_SSM_") || s.starts_with("REPRO_BENCH_"))
            && s.chars().all(|c| c.is_ascii_uppercase() || c.is_ascii_digit() || c == '_');
        if looks_like_var {
            into.entry(s.to_string())
                .or_insert_with(|| (file.to_string(), t.line));
        }
    }
}

/// Apply the annotation escape hatch: `// tor-lint: allow(<check-id>)` on
/// the finding's line or the line above suppresses that finding. Each
/// annotation suppresses **exactly one** finding (the first, in file
/// order); a `-- reason` suffix is recorded in the report.
pub fn apply_allows(lx_by_file: &BTreeMap<String, Lexed>, findings: &mut [Finding]) {
    // (file, annotation line) → already used.
    let mut used: BTreeSet<(String, usize)> = BTreeSet::new();
    for f in findings.iter_mut() {
        let Some(lx) = lx_by_file.get(&f.file) else {
            continue;
        };
        for line in [f.line.saturating_sub(1), f.line] {
            let Some(comment) = lx.comments.get(&line) else {
                continue;
            };
            let Some(rest) = comment.split("tor-lint: allow(").nth(1) else {
                continue;
            };
            let Some(end) = rest.find(')') else { continue };
            if rest[..end].trim() != f.check {
                continue;
            }
            if used.contains(&(f.file.clone(), line)) {
                continue; // one suppression per annotation
            }
            used.insert((f.file.clone(), line));
            f.suppressed = true;
            f.allow_reason = rest[end + 1..]
                .split_once("--")
                .map(|(_, r)| r.trim().to_string());
            break;
        }
    }
}
