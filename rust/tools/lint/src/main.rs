//! CLI: `cargo run -p tor-lint -- --check [--json lint_report.json]
//! [--root <dir>]`. Exit 0 iff no unsuppressed findings.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut json_out: Option<PathBuf> = None;
    let mut do_check = false;

    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--check" => do_check = true,
            "--json" => match args.next() {
                Some(p) => json_out = Some(PathBuf::from(p)),
                None => return usage("--json needs a path"),
            },
            "--root" => match args.next() {
                Some(p) => root = PathBuf::from(p),
                None => return usage("--root needs a path"),
            },
            other => return usage(&format!("unknown argument {other:?}")),
        }
    }
    if !do_check {
        return usage("nothing to do");
    }

    let (findings, files_scanned) = match tor_lint::run(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("tor-lint: io error: {e}");
            return ExitCode::from(2);
        }
    };

    if let Some(path) = &json_out {
        let json = tor_lint::report::to_json(&findings, files_scanned);
        if let Err(e) = std::fs::write(path, json) {
            eprintln!("tor-lint: cannot write {}: {e}", path.display());
            return ExitCode::from(2);
        }
    }

    let mut unsuppressed = 0usize;
    let mut suppressed = 0usize;
    for f in &findings {
        if f.suppressed {
            suppressed += 1;
            continue;
        }
        unsuppressed += 1;
        eprintln!("{}:{}: [{}] {}", f.file, f.line, f.check, f.message);
    }
    eprintln!(
        "tor-lint: {files_scanned} files, {unsuppressed} finding(s), {suppressed} suppressed"
    );
    if unsuppressed > 0 {
        ExitCode::from(1)
    } else {
        ExitCode::SUCCESS
    }
}

fn usage(msg: &str) -> ExitCode {
    eprintln!("tor-lint: {msg}");
    eprintln!("usage: tor-lint --check [--json <path>] [--root <dir>]");
    ExitCode::from(2)
}
