//! Prefix-state cache + preemption bit-identity suite (DESIGN.md §12;
//! template: `tests/prefill_invariance.rs`). What it pins:
//!
//! * **warm vs cold**: prefilling a prompt through a warm [`PrefixCache`]
//!   (resume from the longest chunk-aligned cached prefix, compute only the
//!   remainder) produces the identical `PrefilledSeq` — conv, ssm, logits,
//!   bit for bit — as a cold full prefill with no cache, for dense AND all
//!   four reduction policies × two ratios, including prompts that share
//!   only part of their prefix before diverging;
//! * **preempt/resume**: a sequence swapped out of its decode lane by a
//!   higher-priority arrival and resumed later generates exactly the tokens
//!   of the uninterrupted all-Normal run, in every cell of the execution
//!   matrix — {scalar, fused, simd} kernels × {f32, int8} weights ×
//!   threads 1..=4 — with the baseline recomputed per (mode, format),
//!   since cross-config outputs may legitimately differ (DESIGN.md §13);
//! * **eviction**: under a byte budget tight enough to evict constantly,
//!   the cache never serves a stale or truncated snapshot — every warm
//!   result still equals its cold baseline (entries verify their stored
//!   prefix tokens, so a hit is always the right state or no state).
//!
//! Snapshot/restore and the cache itself are format- and tier-agnostic (a
//! state copy is a state copy), so the warm-vs-cold pin also runs under
//! the simd tier and the int8 weight format.
//!
//! The kernel/worker/format knobs are process-wide, so every test here
//! serialises on a mutex and states the configuration it runs under.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Mutex};

use tor_ssm::coordinator::engine::{Engine, PrefilledSeq};
use tor_ssm::coordinator::prefix_cache::PrefixCache;
use tor_ssm::coordinator::scheduler::Scheduler;
use tor_ssm::coordinator::{Priority, Request, Response};
use tor_ssm::fixtures::generate_default;
use tor_ssm::manifest::Manifest;
use tor_ssm::runtime::kernels::{self, KernelMode};
use tor_ssm::runtime::weights::{set_format, WeightFormat};
use tor_ssm::runtime::{pool, Runtime, Weights};

/// The process-wide kernel/worker/format knobs must not race between the
/// tests in this binary: the simd and int8 arms produce *different* (still
/// self-consistent) outputs, so a concurrent test flipping a knob mid-run
/// would compare apples to oranges.
static EXEC_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    EXEC_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn set_exec(mode: KernelMode, threads: usize) {
    kernels::set_mode(mode);
    pool::set_workers(threads);
}

fn fixture(tag: &str) -> (PathBuf, Manifest) {
    let dir = std::env::temp_dir().join(format!("tor-ssm-scache-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let man = generate_default(&dir).expect("fixture generation");
    (dir, man)
}

fn cleanup(dir: &Path) {
    let _ = std::fs::remove_dir_all(dir);
}

fn rq(id: u64, prompt: Vec<i32>) -> Request {
    Request {
        id,
        prompt,
        gen_tokens: 1,
        variant: String::new(),
        arrived_us: 0,
        priority: Priority::Normal,
    }
}

fn prompt(len: usize, salt: usize, vocab: usize) -> Vec<i32> {
    (0..len).map(|t| ((t * 7 + salt * 13 + 3) % vocab) as i32).collect()
}

fn assert_seq_eq(a: &PrefilledSeq, b: &PrefilledSeq, what: &str) {
    assert_eq!(a.conv, b.conv, "{what}: conv state diverged");
    assert_eq!(a.ssm, b.ssm, "{what}: ssm state diverged");
    assert_eq!(a.logits, b.logits, "{what}: last-token logits diverged");
}

fn by_id(resps: &[Response]) -> BTreeMap<u64, Vec<i32>> {
    let map: BTreeMap<u64, Vec<i32>> =
        resps.iter().map(|r| (r.id, r.generated.clone())).collect();
    assert_eq!(map.len(), resps.len(), "duplicate response ids");
    map
}

const VARIANTS: [&str; 9] = [
    "dense",
    "unified@0.1",
    "unified@0.2",
    "prune@0.1",
    "prune@0.2",
    "merge@0.1",
    "merge@0.2",
    "random@0.1",
    "random@0.2",
];

/// Warm-cache resume vs cold full prefill: identical states + logits for
/// dense and every policy × ratio, on both archs. Covers full-prefix reuse
/// (same prompt twice), a longer prompt extending a cached prefix, and a
/// prompt that shares one frame then diverges (resumes from the shorter
/// boundary only).
#[test]
fn warm_cache_resume_is_bit_identical_to_cold_prefill() {
    let _g = lock();
    set_exec(KernelMode::Fused, 1);
    set_format(WeightFormat::F32);
    let (dir, man) = fixture("warm");
    let rt = Runtime::reference().unwrap();
    let plen = man.prefill_seq_len;
    for model_name in ["ref-mamba", "ref-mamba2"] {
        let model = man.model(model_name).unwrap().clone();
        let w = Weights::load_init(&man, &model).unwrap();
        let vocab = model.vocab_size;
        // Shared 2-frame system prefix; three continuations:
        // a) prefix + half-frame tail (the cached-resume workhorse),
        // b) prefix + 1 token (minimal remainder),
        // c) one shared frame then divergent content (partial prefix hit).
        let prefix = prompt(2 * plen, 1, vocab);
        let mk = |tail: Vec<i32>| {
            let mut p = prefix.clone();
            p.extend(tail);
            p
        };
        let pa = mk(prompt(plen / 2, 2, vocab));
        let pb = mk(prompt(1, 3, vocab));
        let mut pc = prefix[..plen].to_vec();
        pc.extend(prompt(plen + 3, 4, vocab));
        for variant in VARIANTS {
            let cold = Engine::new(&rt, &man, &model, &w, variant).unwrap();
            let mut warm = Engine::new(&rt, &man, &model, &w, variant).unwrap();
            let cache = Arc::new(PrefixCache::new(1 << 22));
            warm.attach_prefix_cache(Arc::clone(&cache));
            let what = |p: &str| format!("{model_name}/{variant}/{p}");

            // Seed the cache: one cold pass through the warm engine inserts
            // every chunk-boundary snapshot; results must already equal the
            // cache-less engine's (cache insertion is observation-only).
            let (seed, _) = warm.prefill(&[rq(0, pa.clone())]).unwrap();
            let (want_a, _) = cold.prefill(&[rq(0, pa.clone())]).unwrap();
            assert_seq_eq(&seed[0], &want_a[0], &what("seed pass"));
            assert_eq!(warm.resumed_tokens.load(Ordering::Relaxed), 0, "nothing cached yet");

            // Warm pass A: same prompt resumes from its longest proper
            // boundary (2 frames) and recomputes only the tail.
            let fed0 = warm.prefill_tokens.load(Ordering::Relaxed);
            let (got_a, _) = warm.prefill(&[rq(0, pa.clone())]).unwrap();
            assert_seq_eq(&got_a[0], &want_a[0], &what("warm resume"));
            assert_eq!(
                warm.resumed_tokens.load(Ordering::Relaxed),
                2 * plen as u64,
                "{}: should resume from the 2-frame boundary",
                what("warm resume")
            );
            assert_eq!(
                warm.prefill_tokens.load(Ordering::Relaxed) - fed0,
                (pa.len() - 2 * plen) as u64,
                "{}: fed + resumed must cover the prompt exactly",
                what("warm resume")
            );

            // Warm pass B: different tail, same cached prefix.
            let (want_b, _) = cold.prefill(&[rq(1, pb.clone())]).unwrap();
            let (got_b, _) = warm.prefill(&[rq(1, pb.clone())]).unwrap();
            assert_seq_eq(&got_b[0], &want_b[0], &what("minimal remainder"));

            // Warm pass C: shares only the first frame, then diverges — may
            // resume from the 1-frame boundary only, never the 2-frame one.
            let resumed0 = warm.resumed_tokens.load(Ordering::Relaxed);
            let (want_c, _) = cold.prefill(&[rq(2, pc.clone())]).unwrap();
            let (got_c, _) = warm.prefill(&[rq(2, pc.clone())]).unwrap();
            assert_seq_eq(&got_c[0], &want_c[0], &what("divergent tail"));
            assert_eq!(
                warm.resumed_tokens.load(Ordering::Relaxed) - resumed0,
                plen as u64,
                "{}: divergent prompt must resume from the shared frame only",
                what("divergent tail")
            );

            // Mixed warm/cold batch: a resumed lane next to a cold lane.
            let fresh = prompt(plen + 5, 9, vocab);
            let (want_mix, _) =
                cold.prefill(&[rq(3, pa.clone()), rq(4, fresh.clone())]).unwrap();
            let (got_mix, _) = warm.prefill(&[rq(3, pa.clone()), rq(4, fresh.clone())]).unwrap();
            assert_seq_eq(&got_mix[0], &want_mix[0], &what("mixed batch, warm lane"));
            assert_seq_eq(&got_mix[1], &want_mix[1], &what("mixed batch, cold lane"));

            let s = cache.stats();
            assert!(s.hits >= 4, "{model_name}/{variant}: expected warm hits, got {s:?}");
            assert!(s.hit_rate() > 0.0);
        }
    }
    cleanup(&dir);
}

/// Preempt-then-resume equals uninterrupted decode, token for token, in
/// every cell of the execution matrix: {scalar, fused, simd} kernels ×
/// {f32, int8} weights × threads 1..=4. The invariant lives *within* a
/// cell — the all-Normal baseline is recomputed per (mode, format),
/// because simd×f32 logits differ from scalar×f32 by the reassociated
/// head's rounding and int8 differs from f32 by quantization error
/// (DESIGN.md §13); what must never differ is preempted-vs-uninterrupted
/// under the same configuration. Comparing each thread count against the
/// 1-thread baseline of the same (mode, format) also pins
/// thread-invariance for the simd tier and the int8 format. The priority
/// run must actually preempt (asserted), and the baseline must not.
#[test]
fn preempt_then_resume_is_token_identical_across_modes_threads_and_formats() {
    let _g = lock();
    let (dir, man) = fixture("preempt");
    let rt = Runtime::reference().unwrap();
    let model = man.model("ref-mamba").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let vocab = model.vocab_size;
    let plen = man.prefill_seq_len;

    for fmt in [WeightFormat::F32, WeightFormat::Int8] {
        set_format(fmt);
        // Engine::new uploads weights, and the upload snapshots the format
        // knob — so the engine must be built *after* set_format.
        let engine = Engine::new(&rt, &man, &model, &w, "dense").unwrap();
        let lanes = engine.decode_batch;
        assert!(lanes >= 2, "fixture decode frame too narrow for preemption");

        // Long-running low-priority residents fill every lane; then a burst
        // of high-priority arrivals must swap them out and finish first.
        let low: Vec<Request> = (0..lanes as u64)
            .map(|i| {
                let mut r = rq(i, prompt(plen / 2 + i as usize, i as usize, vocab));
                r.gen_tokens = 10 + i as usize;
                r.priority = Priority::Low;
                r
            })
            .collect();
        let high: Vec<Request> = (0..2u64)
            .map(|i| {
                let mut r = rq(100 + i, prompt(plen / 3 + i as usize, 7 + i as usize, vocab));
                r.gen_tokens = 3;
                r.priority = Priority::High;
                r
            })
            .collect();
        let as_normal = |reqs: &[Request]| -> Vec<Request> {
            reqs.iter()
                .cloned()
                .map(|mut r| {
                    r.priority = Priority::Normal;
                    r
                })
                .collect()
        };

        // Same submission timeline in both runs: lows, one step (they
        // become resident), then the high burst, then drain.
        let run = |lows: Vec<Request>, highs: Vec<Request>| -> (BTreeMap<u64, Vec<i32>>, u64) {
            let mut sched = Scheduler::new(&engine);
            let mut out = Vec::new();
            for r in lows {
                sched.submit(r);
            }
            out.extend(sched.step().unwrap());
            for r in highs {
                sched.submit(r);
            }
            out.extend(sched.drain().unwrap());
            assert_eq!(sched.store().live(), 0, "slots leaked");
            (by_id(&out), sched.preemptions)
        };

        for mode in [KernelMode::Scalar, KernelMode::Fused, KernelMode::Simd] {
            set_exec(mode, 1);
            let (want, base_preempts) = run(as_normal(&low), as_normal(&high));
            assert_eq!(
                base_preempts,
                0,
                "{} × {}: all-Normal trace must never preempt",
                mode.name(),
                fmt.name()
            );
            assert_eq!(want.len(), low.len() + high.len());
            for threads in 1..=4usize {
                set_exec(mode, threads);
                let (got, preempts) = run(low.clone(), high.clone());
                assert!(
                    preempts > 0,
                    "{} kernels × {threads} threads × {} weights: priority burst did not preempt",
                    mode.name(),
                    fmt.name()
                );
                assert_eq!(
                    want,
                    got,
                    "{} kernels × {threads} threads × {} weights: preempt/resume changed \
                     generated tokens",
                    mode.name(),
                    fmt.name()
                );
            }
        }
    }
    set_format(WeightFormat::F32);
    set_exec(KernelMode::Fused, 1);
    cleanup(&dir);
}

/// Warm-cache resume under the new execution cells: snapshot/restore is a
/// state copy, so warm-vs-cold bit-identity must hold verbatim under the
/// simd tier and the int8 weight format (each compared within its own
/// configuration). A compact sweep — the exhaustive policy matrix above
/// already covers the cache logic itself under the default config.
#[test]
fn warm_cache_resume_holds_under_simd_and_int8() {
    let _g = lock();
    let (dir, man) = fixture("warm-cells");
    let rt = Runtime::reference().unwrap();
    let plen = man.prefill_seq_len;
    let model = man.model("ref-mamba2").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let vocab = model.vocab_size;
    let cells = [
        (KernelMode::Simd, WeightFormat::F32),
        (KernelMode::Fused, WeightFormat::Int8),
        (KernelMode::Simd, WeightFormat::Int8),
    ];
    for (mode, fmt) in cells {
        set_format(fmt);
        set_exec(mode, 2);
        for variant in ["dense", "unified@0.2"] {
            let what = format!("{}/{}/{variant}", mode.name(), fmt.name());
            // Engines built after set_format (upload snapshots the knob).
            let cold = Engine::new(&rt, &man, &model, &w, variant).unwrap();
            let mut warm = Engine::new(&rt, &man, &model, &w, variant).unwrap();
            let cache = Arc::new(PrefixCache::new(1 << 22));
            warm.attach_prefix_cache(Arc::clone(&cache));

            let mut p = prompt(2 * plen, 31, vocab);
            p.extend(prompt(plen / 2 + 1, 32, vocab));
            let (want, _) = cold.prefill(&[rq(0, p.clone())]).unwrap();
            let (seed, _) = warm.prefill(&[rq(0, p.clone())]).unwrap();
            assert_seq_eq(&seed[0], &want[0], &format!("{what}: seed pass"));
            let (got, _) = warm.prefill(&[rq(1, p.clone())]).unwrap();
            assert_seq_eq(&got[0], &want[0], &format!("{what}: warm resume"));
            assert_eq!(
                warm.resumed_tokens.load(Ordering::Relaxed),
                2 * plen as u64,
                "{what}: should resume from the 2-frame boundary"
            );
            assert!(cache.stats().hits >= 1, "{what}: warm pass must hit the cache");
        }
    }
    set_format(WeightFormat::F32);
    set_exec(KernelMode::Fused, 1);
    cleanup(&dir);
}

/// A byte budget so tight the cache evicts on almost every insert must
/// degrade only hit-rate, never correctness: every warm prefill still
/// matches its cold baseline bit for bit, and evictions really happened.
#[test]
fn tight_budget_eviction_never_serves_stale_or_truncated_snapshots() {
    let _g = lock();
    set_exec(KernelMode::Fused, 1);
    set_format(WeightFormat::F32);
    let (dir, man) = fixture("evict");
    let rt = Runtime::reference().unwrap();
    let plen = man.prefill_seq_len;
    let model = man.model("ref-mamba2").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let vocab = model.vocab_size;
    for variant in ["dense", "unified@0.2"] {
        let cold = Engine::new(&rt, &man, &model, &w, variant).unwrap();
        let mut warm = Engine::new(&rt, &man, &model, &w, variant).unwrap();
        let (nl, conv_row, ssm_row) = warm.state_dims();
        // Room for roughly two single-frame entries: every multi-boundary
        // prompt overflows it and churns the LRU.
        let entry = 4 * (plen + nl * conv_row + nl * ssm_row);
        let cache = Arc::new(PrefixCache::new(2 * entry + entry / 2));
        warm.attach_prefix_cache(Arc::clone(&cache));

        // Distinct multi-frame prompts, interleaved twice each: second
        // passes may hit (if the boundary survived) or miss (evicted) —
        // either way the result must equal the cold engine's.
        let prompts: Vec<Vec<i32>> =
            (0..5).map(|k| prompt(2 * plen + 1 + k * 3, 20 + k, vocab)).collect();
        for round in 0..2 {
            for (k, p) in prompts.iter().enumerate() {
                let id = (round * 10 + k) as u64;
                let (want, _) = cold.prefill(&[rq(id, p.clone())]).unwrap();
                let (got, _) = warm.prefill(&[rq(id, p.clone())]).unwrap();
                assert_seq_eq(
                    &got[0],
                    &want[0],
                    &format!("{variant}: prompt {k} round {round} under tight budget"),
                );
            }
        }
        let s = cache.stats();
        assert!(s.evictions > 0, "{variant}: tight budget should evict, got {s:?}");
        assert!(
            s.used_bytes <= cache.budget_bytes(),
            "{variant}: cache exceeded its byte budget: {s:?}"
        );
    }
    cleanup(&dir);
}

/// End-to-end through the scheduler: a shared-system-prompt trace served
/// with a warm cache produces exactly the tokens of the cache-less serve,
/// while resuming most prompt tokens from snapshots.
#[test]
fn scheduler_serve_with_warm_cache_matches_uncached_serve() {
    let _g = lock();
    set_exec(KernelMode::Fused, 1);
    set_format(WeightFormat::F32);
    let (dir, man) = fixture("serve");
    let rt = Runtime::reference().unwrap();
    let plen = man.prefill_seq_len;
    let model = man.model("ref-mamba").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let vocab = model.vocab_size;
    let mut rng = tor_ssm::util::rng::Rng::new(17);
    let trace = tor_ssm::fixtures::synth_shared_prefix_requests(
        &mut rng,
        12,
        6,
        plen,
        2,
        vocab,
    );
    let expected: u64 = trace.iter().map(|r| r.prompt.len() as u64).sum();

    let plain = Engine::new(&rt, &man, &model, &w, "dense").unwrap();
    let want = by_id(&Scheduler::new(&plain).run(trace.clone()).unwrap());
    assert_eq!(plain.prefill_tokens.load(Ordering::Relaxed), expected, "uncached truncation");

    let mut cached = Engine::new(&rt, &man, &model, &w, "dense").unwrap();
    let cache = Arc::new(PrefixCache::new(1 << 22));
    cached.attach_prefix_cache(Arc::clone(&cache));
    // Cold serve (fills the cache), then warm serve (lives off it).
    let cold_run = by_id(&Scheduler::new(&cached).run(trace.clone()).unwrap());
    assert_eq!(want, cold_run, "cold cached serve diverged from uncached serve");
    let warm0 = cached.resumed_tokens.load(Ordering::Relaxed);
    let fed0 = cached.prefill_tokens.load(Ordering::Relaxed);
    let warm_run = by_id(&Scheduler::new(&cached).run(trace).unwrap());
    assert_eq!(want, warm_run, "warm cached serve diverged from uncached serve");
    let resumed = cached.resumed_tokens.load(Ordering::Relaxed) - warm0;
    let fed = cached.prefill_tokens.load(Ordering::Relaxed) - fed0;
    assert_eq!(fed + resumed, expected, "fed + resumed must cover every prompt token");
    assert!(resumed >= 12 * 2 * plen as u64, "warm serve should resume every shared prefix");
    assert!(cache.stats().hit_rate() > 0.0);
    cleanup(&dir);
}
