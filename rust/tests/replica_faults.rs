//! Fault-injection battery for the replica pool (DESIGN.md §15).
//!
//! Failures are injected through the [`Engine`]'s deterministic
//! [`FailurePlan`] seam (fail the k-th lifetime prefill/decode call), so
//! every scenario is reproducible:
//!
//! * a replica that dies **before** any of its requests prefill loses
//!   nothing — its queue re-routes and the tokens stay bit-identical to
//!   the single-engine baseline;
//! * a replica that dies **mid-decode** fails its in-flight sequences
//!   typed (sinks already fired; replaying would duplicate observed
//!   tokens) and never hangs the pool;
//! * `Draining` replicas finish their residents but admit nothing new;
//! * a rolling registry upgrade completes with zero dropped requests and
//!   never mixes two weight versions inside one sequence.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use tor_ssm::coordinator::engine::{Engine, FailurePlan};
use tor_ssm::coordinator::prefix_cache::PrefixCache;
use tor_ssm::coordinator::replica::{Health, Placement, ReplicaPool};
use tor_ssm::coordinator::scheduler::Scheduler;
use tor_ssm::coordinator::{Priority, Request};
use tor_ssm::fixtures::generate_default;
use tor_ssm::manifest::Manifest;
use tor_ssm::runtime::registry::Registry;
use tor_ssm::runtime::{HostTensor, Runtime, Weights};

fn fixture(tag: &str) -> (PathBuf, Manifest) {
    let dir = std::env::temp_dir().join(format!("tor-ssm-faults-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let man = generate_default(&dir).expect("fixture generation");
    (dir, man)
}

fn cleanup(dir: &Path) {
    let _ = std::fs::remove_dir_all(dir);
}

fn prompt_tokens(id: usize, plen: usize, vocab: usize) -> Vec<i32> {
    (0..plen).map(|t| ((t * 7 + id) % vocab) as i32).collect()
}

fn cases(plen: usize, vocab: usize) -> Vec<(Vec<i32>, usize)> {
    vec![
        (prompt_tokens(1, plen / 4, vocab), 5),
        (prompt_tokens(2, plen / 2, vocab), 4),
        (prompt_tokens(3, plen, vocab), 5),
        (prompt_tokens(4, 2 * plen, vocab), 6),
    ]
}

fn request(id: u64, prompt: Vec<i32>, gen: usize) -> Request {
    Request {
        id,
        prompt,
        gen_tokens: gen,
        variant: "dense".to_string(),
        arrived_us: 0,
        priority: Priority::Normal,
    }
}

fn baseline(
    rt: &Runtime,
    man: &Manifest,
    w: &Weights,
    cases: &[(Vec<i32>, usize)],
) -> Vec<Vec<i32>> {
    let model = man.model("ref-mamba").unwrap().clone();
    let engine = Engine::new(rt, man, &model, w, "dense").unwrap();
    let mut sched = Scheduler::new(&engine);
    let reqs: Vec<Request> =
        cases.iter().enumerate().map(|(i, (p, g))| request(i as u64, p.clone(), *g)).collect();
    let mut by_case = vec![Vec::new(); cases.len()];
    for r in sched.run(reqs).unwrap() {
        by_case[r.id as usize] = r.generated;
    }
    by_case
}

fn build_replicas(
    rt: &Runtime,
    man: &Manifest,
    w: &Weights,
    n: usize,
) -> Vec<Engine> {
    let model = man.model("ref-mamba").unwrap().clone();
    (0..n)
        .map(|_| {
            let mut e = Engine::new(rt, man, &model, w, "dense").unwrap();
            e.attach_prefix_cache(Arc::new(PrefixCache::new(4 << 20)));
            e
        })
        .collect()
}

/// A replica whose very first prefill call fails dies before any of its
/// requests have emitted a token, so failover is lossless: everything
/// re-routes and the pooled tokens still match the single-engine
/// baseline exactly. A later [`ReplicaPool::revive`] puts the replica
/// back in service with a clean scheduler.
#[test]
fn prefill_death_reroutes_losslessly_then_revives() {
    let (dir, man) = fixture("prefill");
    let rt = Runtime::reference().unwrap();
    let model = man.model("ref-mamba").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let probe = cases(man.prefill_seq_len, model.vocab_size);
    let expect = baseline(&rt, &man, &w, &probe);

    let engines = build_replicas(&rt, &man, &w, 2);
    // Replica 0 dies on its first prefill — before anything it holds has
    // decoded a single token.
    engines[0].set_failure_plan(Some(FailurePlan {
        fail_prefill_calls: vec![1],
        fail_decode_calls: vec![],
    }));
    let mut pool = ReplicaPool::new(&engines, Placement::LeastLoaded).unwrap();
    for (i, (p, g)) in probe.iter().enumerate() {
        pool.submit(request(i as u64, p.clone(), *g)).unwrap();
    }
    let mut got = vec![Vec::new(); probe.len()];
    for r in pool.drain() {
        got[r.id as usize] = r.generated;
    }
    assert_eq!(pool.health(0), Health::Down, "failing replica must be marked Down");
    assert_eq!(pool.health(1), Health::Up);
    assert!(pool.reroutes >= 1, "queued work must have moved off the dead replica");
    assert!(
        pool.take_failures().is_empty(),
        "pre-prefill death must lose no requests"
    );
    for (ci, exp) in expect.iter().enumerate() {
        assert_eq!(&got[ci], exp, "case {ci}: re-routed tokens diverged from baseline");
    }

    // Revive and serve again: the plan only poisoned call #1, so the
    // replica is healthy now.
    pool.revive(0);
    assert_eq!(pool.health(0), Health::Up);
    pool.submit(request(99, probe[0].0.clone(), probe[0].1)).unwrap();
    let after = pool.drain();
    assert_eq!(after.len(), 1);
    assert_eq!(after[0].generated, expect[0], "revived pool must serve baseline tokens");
    assert!(pool.take_failures().is_empty());
    cleanup(&dir);
}

/// A replica that dies mid-decode has already streamed tokens for its
/// resident sequences, so those fail **typed** — named replica, named
/// injected error, no hang, no silent drop — while work still queued
/// re-routes and every other request completes against baseline.
#[test]
fn decode_death_fails_residents_typed_without_hanging() {
    let (dir, man) = fixture("decode");
    let rt = Runtime::reference().unwrap();
    let model = man.model("ref-mamba").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let probe = cases(man.prefill_seq_len, model.vocab_size);
    let expect = baseline(&rt, &man, &w, &probe);

    let engines = build_replicas(&rt, &man, &w, 2);
    engines[0].set_failure_plan(Some(FailurePlan {
        fail_prefill_calls: vec![],
        fail_decode_calls: vec![2],
    }));
    let mut pool = ReplicaPool::new(&engines, Placement::LeastLoaded).unwrap();
    let mut placed_on_0 = Vec::new();
    for (i, (p, g)) in probe.iter().enumerate() {
        let r = pool.submit(request(i as u64, p.clone(), *g)).unwrap();
        if r == 0 {
            placed_on_0.push(i as u64);
        }
    }
    assert!(!placed_on_0.is_empty(), "least-loaded left replica 0 empty");

    // drain() terminating IS the no-hang assertion.
    let done = pool.drain();
    let failures = pool.take_failures();
    assert_eq!(pool.health(0), Health::Down);
    assert!(!failures.is_empty(), "mid-decode death must surface typed failures");
    for f in &failures {
        assert_eq!(f.replica, 0);
        assert!(
            f.error.contains("replica 0 down") && f.error.contains("injected failure"),
            "failure must name the replica and the root cause, got: {}",
            f.error
        );
        assert!(placed_on_0.contains(&f.id), "only replica 0's residents may fail");
    }
    // Every request is accounted for exactly once: completed or failed.
    let mut seen: Vec<u64> = done.iter().map(|r| r.id).chain(failures.iter().map(|f| f.id)).collect();
    seen.sort_unstable();
    assert_eq!(seen, (0..probe.len() as u64).collect::<Vec<_>>(), "dropped or duplicated ids");
    // Survivors are still bit-identical to baseline.
    for r in &done {
        assert_eq!(
            r.generated, expect[r.id as usize],
            "request {} survived the fault but its tokens diverged",
            r.id
        );
    }
    cleanup(&dir);
}

/// `Draining` semantics: residents run to completion, but the replica
/// admits nothing new — and a pool with no admitting replica refuses
/// submission with a typed error instead of queueing into a void.
#[test]
fn draining_finishes_residents_but_admits_nothing() {
    let (dir, man) = fixture("drain");
    let rt = Runtime::reference().unwrap();
    let model = man.model("ref-mamba").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let probe = cases(man.prefill_seq_len, model.vocab_size);
    let expect = baseline(&rt, &man, &w, &probe);

    let engines = build_replicas(&rt, &man, &w, 2);
    let mut pool = ReplicaPool::new(&engines, Placement::LeastLoaded).unwrap();
    // Make request 0 resident on replica 0, then start draining it.
    assert_eq!(pool.submit(request(0, probe[0].0.clone(), probe[0].1)).unwrap(), 0);
    let resident = pool.step(); // prefills on replica 0
    pool.set_draining(0);
    assert_eq!(pool.health(0), Health::Draining);
    // Everything submitted from now on must land on replica 1.
    for (i, (p, g)) in probe.iter().enumerate().skip(1) {
        assert_eq!(
            pool.submit(request(i as u64, p.clone(), *g)).unwrap(),
            1,
            "a draining replica admitted new work"
        );
    }
    let mut got = vec![Vec::new(); probe.len()];
    for r in resident.into_iter().chain(pool.drain()) {
        got[r.id as usize] = r.generated;
    }
    for (ci, exp) in expect.iter().enumerate() {
        assert_eq!(&got[ci], exp, "case {ci} diverged under drain");
    }
    assert!(pool.take_failures().is_empty());
    // Explicit drains never auto-recover.
    assert_eq!(pool.health(0), Health::Draining);

    // With every replica draining, submission fails typed.
    pool.set_draining(1);
    let err = pool.submit(request(50, probe[0].0.clone(), 2)).unwrap_err();
    assert!(
        format!("{err:#}").contains("no healthy replica"),
        "expected a typed no-capacity error, got: {err:#}"
    );
    cleanup(&dir);
}

/// Rolling upgrade through the content-addressed registry: publish the
/// serving weights as `base` and a perturbed set as `v2`, then advance
/// the upgrade one tick at a time while a live trace flows. Every
/// response must be bit-identical to either the old-weights baseline or
/// the new-weights baseline — never a mixture — with zero dropped
/// requests, and the pool ends with every replica tagged `v2`.
#[test]
fn rolling_upgrade_drops_nothing_and_never_mixes_weights() {
    let (dir, man) = fixture("upgrade");
    let rt = Runtime::reference().unwrap();
    let model = man.model("ref-mamba").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let probe = cases(man.prefill_seq_len, model.vocab_size);

    // A substantive perturbation so old/new baselines genuinely differ.
    let w2 = Weights {
        tensors: w
            .tensors
            .iter()
            .map(|t| {
                let data: Vec<f32> = t
                    .as_f32()
                    .unwrap()
                    .iter()
                    .enumerate()
                    .map(|(i, x)| x * 1.25 + 0.01 * ((i % 7) as f32 - 3.0))
                    .collect();
                HostTensor::f32(t.shape.clone(), data)
            })
            .collect(),
        quant: None,
    };
    let old_base = baseline(&rt, &man, &w, &probe);
    let new_base = baseline(&rt, &man, &w2, &probe);
    assert_ne!(old_base, new_base, "perturbed weights produced identical tokens — vacuous");

    let reg = Registry::open(dir.join("registry"));
    reg.publish(&model, "base", &w, 2).unwrap();
    reg.publish(&model, "v2", &w2, 2).unwrap();

    let engines = build_replicas(&rt, &man, &w, 2);
    let mut pool = ReplicaPool::new(&engines, Placement::LeastLoaded).unwrap();

    // Interleave: submit one request, advance the upgrade a tick, step.
    let mut responses = Vec::new();
    let mut upgraded = false;
    let mut next = 0usize;
    let mut tick = 0usize;
    while next < probe.len() || !pool.is_idle() || !upgraded {
        if next < probe.len() {
            let (p, g) = &probe[next];
            pool.submit(request(next as u64, p.clone(), *g)).unwrap();
            next += 1;
        }
        if !upgraded {
            upgraded = pool.advance_upgrade("v2", || reg.hot_load(&rt, &model, "v2")).unwrap();
        }
        responses.extend(pool.step());
        tick += 1;
        assert!(tick < 10_000, "rolling upgrade failed to converge");
    }
    responses.extend(pool.drain());

    assert!(pool.take_failures().is_empty(), "rolling upgrade dropped requests");
    assert_eq!(responses.len(), probe.len(), "request lost during upgrade");
    for r in &responses {
        let ci = r.id as usize;
        assert!(
            r.generated == old_base[ci] || r.generated == new_base[ci],
            "request {ci} matches neither weight version — versions mixed in one sequence"
        );
    }
    for e in &engines {
        assert_eq!(e.weights_tag(), "v2", "upgrade finished with a stale replica");
    }
    // Post-upgrade traffic serves the new weights.
    pool.submit(request(77, probe[0].0.clone(), probe[0].1)).unwrap();
    let after = pool.drain();
    assert_eq!(after[0].generated, new_base[0]);
    cleanup(&dir);
}
