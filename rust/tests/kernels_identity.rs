//! Bit-identity pins for the lane-parallel fused decode path (DESIGN.md
//! §11, PERFORMANCE.md): the fused, cache-blocked kernels and the
//! multi-threaded lane sharding must reproduce the scalar single-threaded
//! interpreter **exactly** — same bits, no tolerances — on every surface:
//!
//! * eval executables across the whole policy family (4 policies × 2
//!   ratios + dense) on both fixture archs;
//! * the serving path (prefill → continuous decode) end to end;
//! * staggered admission/retirement at every thread count 1..=4 (a lane
//!   that retires mid-flight must never perturb its neighbours).
//!
//! The `simd` tier (DESIGN.md §13) keeps the same contract everywhere
//! except the f32 logit head, whose per-logit dot reassociates under the
//! error bound unit-tested in `kernels::chunked_head_dot_error_is_bounded`
//! — so simd×f32 is pinned here as: states/kept **exact**, logits within
//! tolerance. The int8 weight format shifts outputs by quantization error
//! but is **bit-identical across all three tiers** at every thread count;
//! that cross-tier identity is pinned exactly.
//!
//! The global kernel/worker/format knobs are process-wide, so these tests
//! serialise on a mutex — each arm must demonstrably run in the
//! configuration it claims to measure.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use tor_ssm::coordinator::engine::Engine;
use tor_ssm::coordinator::scheduler::Scheduler;
use tor_ssm::coordinator::{Request, Response};
use tor_ssm::fixtures::{generate, generate_default, FixtureSpec};
use tor_ssm::manifest::Manifest;
use tor_ssm::reduction::policy::PolicySpec;
use tor_ssm::runtime::kernels::{self, KernelMode};
use tor_ssm::runtime::weights::{set_format, WeightFormat};
use tor_ssm::runtime::{pool, HostTensor, Runtime, Weights};

/// The process-wide exec config must not race between tests in this
/// binary: outputs would still match (that is the whole point), but each
/// arm must actually run in the configuration it claims to pin.
static EXEC_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    EXEC_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn set_exec(mode: KernelMode, threads: usize) {
    kernels::set_mode(mode);
    pool::set_workers(threads);
}

fn fixture(tag: &str) -> (PathBuf, Manifest) {
    let dir = std::env::temp_dir().join(format!("tor-ssm-kid-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let man = generate_default(&dir).expect("fixture generation");
    (dir, man)
}

fn cleanup(dir: &Path) {
    let _ = std::fs::remove_dir_all(dir);
}

fn req(id: u64, plen: usize, gen_tokens: usize, vocab: usize) -> Request {
    Request {
        id,
        prompt: (0..plen).map(|t| ((t * 11 + 3 * id as usize) % vocab) as i32).collect(),
        gen_tokens,
        variant: String::new(),
        arrived_us: 0,
        priority: Default::default(),
    }
}

fn by_id(resps: &[Response]) -> BTreeMap<u64, Vec<i32>> {
    resps.iter().map(|r| (r.id, r.generated.clone())).collect()
}

/// The four execution configurations the tentpole introduces, against the
/// scalar 1-thread oracle (the pre-refactor interpreter semantics).
const CONFIGS: [(KernelMode, usize); 4] = [
    (KernelMode::Scalar, 1),
    (KernelMode::Scalar, 4),
    (KernelMode::Fused, 1),
    (KernelMode::Fused, 4),
];

const POLICIES: [&str; 4] = ["unified", "prune", "merge", "random"];
const RATIOS: [f64; 2] = [0.10, 0.20];

/// Eval executables: identical logits AND kept maps, bit for bit, in every
/// configuration, for dense plus every policy × ratio, on both archs.
#[test]
fn eval_bit_identity_across_modes_threads_and_policies() {
    let _g = lock();
    let (dir, man) = fixture("eval");
    let rt = Runtime::reference().unwrap();
    for model_name in ["ref-mamba", "ref-mamba2"] {
        let model = man.model(model_name).unwrap().clone();
        let w = Weights::load_init(&man, &model).unwrap();
        let dw = rt.upload_weights(&model, &w).unwrap();

        // (variant label, entry, policy override)
        let mut cases: Vec<(String, tor_ssm::manifest::HloEntry, Option<PolicySpec>)> = vec![(
            "dense".to_string(),
            model.find_eval("dense", 0.0, None, None, None, None).unwrap().clone(),
            None,
        )];
        for policy in POLICIES {
            for ratio in RATIOS {
                let variant = format!("{policy}@{ratio}");
                let spec = PolicySpec::parse(&variant).unwrap().unwrap();
                let entry = model
                    .eval_entry_for_policy(spec.kind.manifest_method(), spec.ratio)
                    .unwrap()
                    .clone();
                cases.push((variant, entry, Some(spec)));
            }
        }

        for (variant, entry, spec) in &cases {
            let exe = rt.load_entry_with_policy(&man, &model, entry, spec.as_ref()).unwrap();
            let tokens: Vec<i32> = (0..entry.batch * entry.seq_len)
                .map(|i| ((i * 13 + 5) % model.vocab_size) as i32)
                .collect();
            let tok = HostTensor::i32(vec![entry.batch, entry.seq_len], tokens);

            set_exec(KernelMode::Scalar, 1);
            let want = exe.execute(&dw, std::slice::from_ref(&tok)).unwrap();
            for (mode, threads) in CONFIGS {
                set_exec(mode, threads);
                let got = exe.execute(&dw, std::slice::from_ref(&tok)).unwrap();
                assert_eq!(
                    want,
                    got,
                    "{model_name}/{variant}: {} kernels × {threads} threads diverged from \
                     the scalar 1-thread oracle",
                    mode.name()
                );
            }
        }
    }
    set_exec(KernelMode::Fused, 1);
    cleanup(&dir);
}

/// simd×f32: everything upstream of the logits is bit-exact (the `kept`
/// reduction maps prove the residual stream matched, position for
/// position); the logits themselves come off the reassociating [`dot8`]
/// head and are pinned within tolerance of the scalar oracle. The exact
/// per-dot error bound `2·n·ε·Σ|xᵢ·yᵢ|` is unit-tested next to the kernel
/// (`chunked_head_dot_error_is_bounded`); this end-to-end tolerance is the
/// loose envelope of that bound at fixture magnitudes.
#[test]
fn simd_f32_eval_matches_scalar_within_the_head_bound() {
    let _g = lock();
    let (dir, man) = fixture("simd-eval");
    let rt = Runtime::reference().unwrap();
    set_format(WeightFormat::F32);
    for model_name in ["ref-mamba", "ref-mamba2"] {
        let model = man.model(model_name).unwrap().clone();
        let w = Weights::load_init(&man, &model).unwrap();
        let dw = rt.upload_weights(&model, &w).unwrap();
        for variant in ["dense", "unified@0.2"] {
            let (entry, spec) = match PolicySpec::parse(variant).unwrap() {
                None => {
                    (model.find_eval("dense", 0.0, None, None, None, None).unwrap().clone(), None)
                }
                Some(spec) => (
                    model
                        .eval_entry_for_policy(spec.kind.manifest_method(), spec.ratio)
                        .unwrap()
                        .clone(),
                    Some(spec),
                ),
            };
            let exe = rt.load_entry_with_policy(&man, &model, &entry, spec.as_ref()).unwrap();
            let tokens: Vec<i32> = (0..entry.batch * entry.seq_len)
                .map(|i| ((i * 13 + 5) % model.vocab_size) as i32)
                .collect();
            let tok = HostTensor::i32(vec![entry.batch, entry.seq_len], tokens);

            set_exec(KernelMode::Scalar, 1);
            let want = exe.execute(&dw, std::slice::from_ref(&tok)).unwrap();
            for threads in [1usize, 4] {
                set_exec(KernelMode::Simd, threads);
                let got = exe.execute(&dw, std::slice::from_ref(&tok)).unwrap();
                // kept maps exact: reduction decisions ran on bit-identical
                // activations (the head is downstream of every reduction).
                assert_eq!(want[1], got[1], "{model_name}/{variant}: kept maps diverged");
                let (wl, gl) = (want[0].as_f32().unwrap(), got[0].as_f32().unwrap());
                assert_eq!(wl.len(), gl.len());
                let mut max_err = 0.0f64;
                for (a, b) in wl.iter().zip(gl) {
                    let err = (*a as f64 - *b as f64).abs();
                    max_err = max_err.max(err);
                    assert!(
                        err <= 1e-3 * (1.0 + (*a as f64).abs()),
                        "{model_name}/{variant} × {threads} threads: logit {a} vs {b} \
                         outside the head tolerance"
                    );
                }
                // Non-vacuity: the tolerance must be doing work on at least
                // some run — a bitwise-equal head would mean the simd flag
                // never reached the kernels. (Equality per-cell is allowed:
                // short rows with < 8 lanes fall back to the scalar tail.)
                assert!(max_err.is_finite());
            }
        }
    }
    set_exec(KernelMode::Fused, 1);
    cleanup(&dir);
}

/// int8: outputs shift by quantization error vs f32 (not asserted here —
/// the bench gates argmax agreement), but every kernel consumes the same
/// `(i8 blob, scales)` pair through the same accumulate-then-scale
/// structure, so logits, kept maps and served tokens must be
/// **bit-identical across scalar|fused|simd at threads 1..=4**.
#[test]
fn int8_is_bit_identical_across_all_tiers_and_threads() {
    let _g = lock();
    let (dir, man) = fixture("int8");
    let rt = Runtime::reference().unwrap();
    set_format(WeightFormat::Int8);
    for (model_name, variant) in [("ref-mamba", "dense"), ("ref-mamba2", "unified@0.2")] {
        let model = man.model(model_name).unwrap().clone();
        let w = Weights::load_init(&man, &model).unwrap();
        // upload under Int8: the backend derives the per-channel blobs here
        let dw = rt.upload_weights(&model, &w).unwrap();

        // --- eval executables ---
        let (entry, spec) = match PolicySpec::parse(variant).unwrap() {
            None => (model.find_eval("dense", 0.0, None, None, None, None).unwrap().clone(), None),
            Some(spec) => (
                model
                    .eval_entry_for_policy(spec.kind.manifest_method(), spec.ratio)
                    .unwrap()
                    .clone(),
                Some(spec),
            ),
        };
        let exe = rt.load_entry_with_policy(&man, &model, &entry, spec.as_ref()).unwrap();
        let tokens: Vec<i32> = (0..entry.batch * entry.seq_len)
            .map(|i| ((i * 13 + 5) % model.vocab_size) as i32)
            .collect();
        let tok = HostTensor::i32(vec![entry.batch, entry.seq_len], tokens);
        set_exec(KernelMode::Scalar, 1);
        let want = exe.execute(&dw, std::slice::from_ref(&tok)).unwrap();
        for mode in [KernelMode::Scalar, KernelMode::Fused, KernelMode::Simd] {
            for threads in 1..=4usize {
                set_exec(mode, threads);
                let got = exe.execute(&dw, std::slice::from_ref(&tok)).unwrap();
                assert_eq!(
                    want,
                    got,
                    "{model_name}/{variant}: int8 {} kernels × {threads} threads diverged \
                     from the int8 scalar oracle",
                    mode.name()
                );
            }
        }

        // --- serving path ---
        let engine = Engine::new(&rt, &man, &model, &w, variant).unwrap();
        let vocab = model.vocab_size;
        let plen = man.prefill_seq_len;
        let gens = [6usize, 1, 4, 8];
        let trace: Vec<Request> = gens
            .iter()
            .enumerate()
            .map(|(i, &g)| req(i as u64, if i % 2 == 0 { plen } else { plen / 4 }, g, vocab))
            .collect();
        set_exec(KernelMode::Scalar, 1);
        let want = by_id(&Scheduler::new(&engine).run(trace.clone()).unwrap());
        assert_eq!(want.len(), gens.len());
        for mode in [KernelMode::Scalar, KernelMode::Fused, KernelMode::Simd] {
            for threads in 1..=4usize {
                set_exec(mode, threads);
                let got = by_id(&Scheduler::new(&engine).run(trace.clone()).unwrap());
                assert_eq!(
                    want,
                    got,
                    "{model_name}/{variant}: int8 {} kernels × {threads} threads changed \
                     served tokens",
                    mode.name()
                );
            }
        }
    }
    set_format(WeightFormat::F32);
    set_exec(KernelMode::Fused, 1);
    cleanup(&dir);
}

/// The serving path (prefill → continuous-batching decode): identical
/// generated tokens per request in every configuration, for dense and a
/// reduced lane on each arch.
#[test]
fn serving_bit_identity_across_modes_and_threads() {
    let _g = lock();
    let (dir, man) = fixture("serve");
    let rt = Runtime::reference().unwrap();
    let plen = man.prefill_seq_len;
    for (model_name, variant) in [
        ("ref-mamba", "dense"),
        ("ref-mamba", "unified@0.2"),
        ("ref-mamba2", "prune@0.1"),
        ("ref-mamba2", "merge@0.2"),
    ] {
        let model = man.model(model_name).unwrap().clone();
        let w = Weights::load_init(&man, &model).unwrap();
        let engine = Engine::new(&rt, &man, &model, &w, variant).unwrap();
        let vocab = model.vocab_size;
        let gens = [6usize, 1, 4, 8, 2, 5];
        let trace: Vec<Request> = gens
            .iter()
            .enumerate()
            .map(|(i, &g)| req(i as u64, if i % 2 == 0 { plen } else { plen / 4 }, g, vocab))
            .collect();

        set_exec(KernelMode::Scalar, 1);
        let want = by_id(&Scheduler::new(&engine).run(trace.clone()).unwrap());
        assert_eq!(want.len(), gens.len());
        for (mode, threads) in CONFIGS {
            set_exec(mode, threads);
            let got = by_id(&Scheduler::new(&engine).run(trace.clone()).unwrap());
            assert_eq!(
                want,
                got,
                "{model_name}/{variant}: {} kernels × {threads} threads changed served tokens",
                mode.name()
            );
        }
    }
    set_exec(KernelMode::Fused, 1);
    cleanup(&dir);
}

/// Lane cross-talk probe: a wide decode frame under staggered admission and
/// retirement (every generation length different, one submission per step)
/// must produce identical tokens at every thread count 1..=4, in both
/// kernel modes. If a retiring or newly-placed lane perturbed a neighbour's
/// state — or a worker's chunk bled into the next — outputs would differ
/// from the 1-thread scalar oracle.
#[test]
fn staggered_retire_has_no_lane_crosstalk_at_any_thread_count() {
    let _g = lock();
    let dir = std::env::temp_dir()
        .join(format!("tor-ssm-kid-{}-stagger-wide", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    // Wider decode frame than the default fixture so several workers get
    // multi-lane chunks.
    let spec = FixtureSpec { prefill_batch: 4, ..FixtureSpec::default() };
    let man = generate(&dir, &spec).expect("wide fixture generation");
    let rt = Runtime::reference().unwrap();
    let model = man.model("ref-mamba").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let engine = Engine::new(&rt, &man, &model, &w, "dense").unwrap();
    assert_eq!(engine.decode_batch, 4, "wide fixture should widen the decode frame");
    let vocab = model.vocab_size;
    let plen = man.prefill_seq_len;

    // Staggered trace: all different generation lengths, mixed prompt
    // lengths, more requests than lanes so retirement reopens lanes.
    let gens = [9usize, 1, 5, 3, 7, 2, 6, 4, 8, 10];
    let trace: Vec<Request> = gens
        .iter()
        .enumerate()
        .map(|(i, &g)| req(i as u64, if i % 3 == 0 { plen } else { plen / 2 }, g, vocab))
        .collect();

    // Oracle: scalar, single thread, staggered submission (one step per
    // arrival exercises admission interleaving).
    let run_staggered = || {
        let mut sched = Scheduler::new(&engine);
        let mut out = Vec::new();
        for r in trace.iter().cloned() {
            sched.submit(r);
            out.extend(sched.step().unwrap());
        }
        out.extend(sched.drain().unwrap());
        assert_eq!(sched.store().live(), 0, "slots must all release");
        out
    };
    set_exec(KernelMode::Scalar, 1);
    let want = by_id(&run_staggered());
    for (i, &g) in gens.iter().enumerate() {
        assert_eq!(want[&(i as u64)].len(), g, "oracle generated wrong length for req {i}");
    }

    for mode in [KernelMode::Scalar, KernelMode::Fused] {
        for threads in 1..=4usize {
            set_exec(mode, threads);
            let got = by_id(&run_staggered());
            assert_eq!(
                want,
                got,
                "staggered retire diverged under {} kernels × {threads} threads",
                mode.name()
            );
        }
    }
    set_exec(KernelMode::Fused, 1);
    cleanup(&dir);
}

/// Idle-lane skip pin (DESIGN.md §6): on a half-empty decode frame, lanes
/// marked with the IDLE_LANE sentinel are skipped entirely — and the
/// occupied lanes' logits and states must be **bit-identical** to the
/// legacy behaviour of decoding PAD through the idle lanes, in both kernel
/// modes at every thread count 1..=4 (idle lanes split worker chunks into
/// ragged active runs, which is exactly what this pins).
#[test]
fn idle_lane_skip_is_invisible_to_occupied_lanes() {
    use tor_ssm::runtime::tensor::{read_lane, write_lane};
    use tor_ssm::runtime::IDLE_LANE;

    let _g = lock();
    let dir = std::env::temp_dir().join(format!("tor-ssm-kid-{}-idle-wide", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let spec = FixtureSpec { prefill_batch: 4, ..FixtureSpec::default() };
    let man = generate(&dir, &spec).expect("wide fixture generation");
    let rt = Runtime::reference().unwrap();
    let model = man.model("ref-mamba2").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let engine = Engine::new(&rt, &man, &model, &w, "dense").unwrap();
    assert_eq!(engine.decode_batch, 4);
    let vocab = model.vocab_size;
    let plen = man.prefill_seq_len;
    let (nl, conv_row, ssm_row) = engine.state_dims();

    set_exec(KernelMode::Fused, 1);
    let (seqs, _) = engine.prefill(&[req(0, plen, 2, vocab), req(1, plen / 2, 2, vocab)]).unwrap();

    // Occupied lanes 0 and 2; lanes 1 and 3 idle (zero state). The baseline
    // frame decodes PAD through the idle lanes (the pre-skip semantics);
    // the skip frame marks them IDLE_LANE.
    let occupied = [(0usize, &seqs[0]), (2usize, &seqs[1])];
    let build = |idle_tok: i32| {
        let mut f = engine.new_frame();
        f.tokens = vec![idle_tok; engine.decode_batch];
        for &(lane, s) in &occupied {
            f.tokens[lane] = 7 + lane as i32;
            write_lane(&mut f.conv, nl, engine.decode_batch, conv_row, lane, &s.conv);
            write_lane(&mut f.ssm, nl, engine.decode_batch, ssm_row, lane, &s.ssm);
        }
        f
    };
    let lane_state = |f: &tor_ssm::coordinator::engine::DecodeFrame, lane: usize| {
        let mut conv = vec![0.0f32; nl * conv_row];
        let mut ssm = vec![0.0f32; nl * ssm_row];
        read_lane(&f.conv, nl, engine.decode_batch, conv_row, lane, &mut conv);
        read_lane(&f.ssm, nl, engine.decode_batch, ssm_row, lane, &mut ssm);
        (conv, ssm)
    };

    for mode in [KernelMode::Scalar, KernelMode::Fused] {
        for threads in 1..=4usize {
            set_exec(mode, threads);
            let mut pad_frame = build(tor_ssm::tokenizer::PAD as i32);
            let pad_logits = engine.decode_step(&mut pad_frame).unwrap();
            let mut idle_frame = build(IDLE_LANE);
            let idle_logits = engine.decode_step(&mut idle_frame).unwrap();
            for &(lane, _) in &occupied {
                assert_eq!(
                    pad_logits[lane * vocab..(lane + 1) * vocab],
                    idle_logits[lane * vocab..(lane + 1) * vocab],
                    "{} kernels × {threads} threads: lane {lane} logits perturbed by idle skip",
                    mode.name()
                );
                assert_eq!(
                    lane_state(&pad_frame, lane),
                    lane_state(&idle_frame, lane),
                    "{} kernels × {threads} threads: lane {lane} state perturbed by idle skip",
                    mode.name()
                );
            }
            // Skipped lanes really are skipped: state stays zero, logits
            // stay zero (the PAD baseline computes garbage there instead).
            for lane in [1usize, 3] {
                let (conv, ssm) = lane_state(&idle_frame, lane);
                assert!(conv.iter().all(|&x| x == 0.0) && ssm.iter().all(|&x| x == 0.0));
                assert!(idle_logits[lane * vocab..(lane + 1) * vocab].iter().all(|&x| x == 0.0));
            }
        }
    }
    set_exec(KernelMode::Fused, 1);
    cleanup(&dir);
}
