//! End-to-end coverage of the token-reduction policy subsystem (DESIGN.md
//! §10) on the hermetic fixture — the acceptance suite for the policy
//! family:
//!
//! * `unified@<r>` with its default metric is **bit-identical** to the
//!   legacy `utrc@<r>` lane, on both the eval executables and the serving
//!   path (the policy refactor must not move a single bit);
//! * all four policies (`prune`, `merge`, `unified`, `random`) run end to
//!   end through the eval harness AND the continuous-batching scheduler at
//!   two ratios each, honouring the kept-map contract;
//! * metric-suffixed variants (`unified@r:clip`, `prune@r:l1`, ...) build
//!   and serve;
//! * policy dispatch is deterministic: identical inputs → identical outputs
//!   across engines constructed separately.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use tor_ssm::bench::Ctx;
use tor_ssm::coordinator::engine::Engine;
use tor_ssm::coordinator::scheduler::Scheduler;
use tor_ssm::coordinator::{Request, Response};
use tor_ssm::fixtures::generate_default;
use tor_ssm::manifest::Manifest;
use tor_ssm::reduction::policy::PolicySpec;
use tor_ssm::runtime::{HostTensor, Runtime, Weights};

fn fixture(tag: &str) -> (PathBuf, Manifest) {
    let dir = std::env::temp_dir().join(format!("tor-ssm-pol-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let man = generate_default(&dir).expect("fixture generation");
    (dir, man)
}

fn cleanup(dir: &Path) {
    let _ = std::fs::remove_dir_all(dir);
}

fn req(id: u64, plen: usize, gen_tokens: usize, vocab: usize) -> Request {
    Request {
        id,
        prompt: (0..plen).map(|t| ((t * 7 + id as usize) % vocab) as i32).collect(),
        gen_tokens,
        variant: String::new(),
        arrived_us: 0,
        priority: Default::default(),
    }
}

fn by_id(resps: &[Response]) -> BTreeMap<u64, Vec<i32>> {
    resps.iter().map(|r| (r.id, r.generated.clone())).collect()
}

/// The four ratio-bearing policies at the two ratios the fixture exports
/// both eval and prefill plans for.
const POLICIES: [&str; 4] = ["unified", "prune", "merge", "random"];
const RATIOS: [f64; 2] = [0.10, 0.20];

#[test]
fn unified_default_is_bit_identical_to_utrc_eval() {
    let (dir, man) = fixture("unified-bits");
    let rt = Runtime::reference().unwrap();
    for model_name in ["ref-mamba", "ref-mamba2"] {
        let model = man.model(model_name).unwrap().clone();
        let w = Weights::load_init(&man, &model).unwrap();
        let dw = rt.upload_weights(&model, &w).unwrap();
        for ratio in RATIOS {
            let entry = model.find_eval("utrc", ratio, None, None, None, None).unwrap().clone();
            let tokens: Vec<i32> = (0..entry.batch * entry.seq_len)
                .map(|i| ((i * 13 + 5) % model.vocab_size) as i32)
                .collect();
            let tok = HostTensor::i32(vec![entry.batch, entry.seq_len], tokens);

            // Legacy path: the entry's manifest-resolved policy.
            let legacy = rt.load_entry(&man, &model, &entry).unwrap();
            let want = legacy.execute(&dw, &[tok.clone()]).unwrap();

            // Policy path: an explicit unified@<r> override (default metric).
            let spec = PolicySpec::parse(&format!("unified@{ratio}")).unwrap().unwrap();
            let unified = rt.load_entry_with_policy(&man, &model, &entry, Some(&spec)).unwrap();
            let got = unified.execute(&dw, &[tok]).unwrap();

            assert_eq!(want.len(), got.len());
            for (w_t, g_t) in want.iter().zip(&got) {
                assert_eq!(w_t, g_t, "{model_name}@{ratio}: unified default diverged from utrc");
            }
        }
    }
    cleanup(&dir);
}

#[test]
fn unified_engine_matches_utrc_engine_on_the_serve_path() {
    let (dir, man) = fixture("unified-serve");
    let rt = Runtime::reference().unwrap();
    let model = man.model("ref-mamba").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let vocab = model.vocab_size;
    let plen = man.prefill_seq_len;
    for ratio in RATIOS {
        let reqs: Vec<Request> = (0..4)
            .map(|i| req(i, if i % 2 == 0 { plen } else { plen / 4 }, 3 + i as usize, vocab))
            .collect();
        let serve = |variant: &str| -> BTreeMap<u64, Vec<i32>> {
            let engine = Engine::new(&rt, &man, &model, &w, variant).unwrap();
            by_id(&Scheduler::new(&engine).run(reqs.clone()).unwrap())
        };
        assert_eq!(
            serve(&format!("utrc@{ratio}")),
            serve(&format!("unified@{ratio}")),
            "serve outputs diverged at ratio {ratio}"
        );
    }
    cleanup(&dir);
}

#[test]
fn all_policies_serve_through_continuous_batching_at_two_ratios() {
    let (dir, man) = fixture("all-serve");
    let rt = Runtime::reference().unwrap();
    let model = man.model("ref-mamba").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let vocab = model.vocab_size;
    let plen = man.prefill_seq_len;

    for policy in POLICIES {
        for ratio in RATIOS {
            let variant = format!("{policy}@{ratio}");
            let engine = Engine::new(&rt, &man, &model, &w, &variant)
                .unwrap_or_else(|e| panic!("{variant}: engine build failed: {e:#}"));
            let reqs: Vec<Request> = (0..5)
                .map(|i| req(i, if i % 2 == 0 { plen } else { plen / 4 }, 1 + i as usize, vocab))
                .collect();
            let mut sched = Scheduler::new(&engine);
            let resps = sched.run(reqs).unwrap_or_else(|e| panic!("{variant}: serve: {e:#}"));
            assert_eq!(resps.len(), 5, "{variant}: lost responses");
            for r in &resps {
                assert_eq!(r.generated.len(), 1 + r.id as usize, "{variant}: truncated gen");
                assert!(
                    r.generated.iter().all(|&t| t >= 0 && (t as usize) < vocab),
                    "{variant}: token outside vocab"
                );
                assert_eq!(r.variant, variant);
            }
            // Determinism: a second engine + scheduler reproduces the tokens.
            let engine2 = Engine::new(&rt, &man, &model, &w, &variant).unwrap();
            let reqs2: Vec<Request> = (0..5)
                .map(|i| req(i, if i % 2 == 0 { plen } else { plen / 4 }, 1 + i as usize, vocab))
                .collect();
            let resps2 = Scheduler::new(&engine2).run(reqs2).unwrap();
            assert_eq!(by_id(&resps), by_id(&resps2), "{variant}: non-deterministic");
        }
    }
    cleanup(&dir);
}

#[test]
fn all_policies_eval_end_to_end_at_two_ratios() {
    let (dir, man) = fixture("all-eval");
    let items = 2;
    let mut ctx = Ctx::new(&dir.to_string_lossy(), items, true).unwrap();
    let model = "ref-mamba";
    let me = man.model(model).unwrap().clone();
    let dense = {
        let e = ctx.find_eval_entry(model, "dense", 0.0, None, None, None, None).unwrap();
        ctx.eval_variant(model, &e).unwrap()
    };
    for policy in POLICIES {
        for ratio in RATIOS {
            let variant = format!("{policy}@{ratio}");
            let spec = PolicySpec::parse(&variant).unwrap().unwrap();
            let entry =
                me.eval_entry_for_policy(spec.kind.manifest_method(), spec.ratio).unwrap().clone();
            let r = ctx
                .eval_policy_variant(model, &entry, Some(&spec))
                .unwrap_or_else(|e| panic!("{variant}: eval failed: {e:#}"));
            assert_eq!(r.variant, spec.to_variant());
            assert_eq!(r.tasks.len(), dense.tasks.len(), "{variant}: task coverage");
            assert!(r.sequences > 0);
            for t in &r.tasks {
                assert!((0.0..=1.0).contains(&t.acc_truncated), "{variant} {}", t.name);
                assert!((0.0..=1.0).contains(&t.acc_aligned), "{variant} {}", t.name);
            }
            let ppl = r.lambada_ppl(tor_ssm::eval::scoring::Scheme::Truncated);
            assert!(ppl.is_finite() && ppl > 0.0, "{variant}: ppl = {ppl}");
        }
    }
    cleanup(&dir);
}

#[test]
fn metric_suffixed_variants_build_and_serve() {
    let (dir, man) = fixture("metrics");
    let rt = Runtime::reference().unwrap();
    let model = man.model("ref-mamba2").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let vocab = model.vocab_size;
    let plen = man.prefill_seq_len;
    for variant in ["unified@0.2:clip", "unified@0.2:l1", "prune@0.2:noclip", "prune@0.2:l2"] {
        let engine = Engine::new(&rt, &man, &model, &w, variant)
            .unwrap_or_else(|e| panic!("{variant}: {e:#}"));
        let resps =
            Scheduler::new(&engine).run(vec![req(0, plen, 3, vocab)]).unwrap();
        assert_eq!(resps.len(), 1);
        assert_eq!(resps[0].generated.len(), 3, "{variant}");
    }
    // Unknown policies and misplaced metrics fail at engine construction
    // with a parse error (never a manifest-lookup error).
    for bad in ["bogus@0.2", "merge@0.2:l2", "prune@0.2:l9"] {
        let err = Engine::new(&rt, &man, &model, &w, bad).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("unknown") || msg.contains("metric"),
            "{bad}: expected a grammar error, got {msg}"
        );
    }
    cleanup(&dir);
}
