//! Cross-replica bit-identity battery (DESIGN.md §15).
//!
//! The pool's correctness contract: because every serving path samples
//! with greedy first-max-wins argmax and sequences are frame-independent
//! (DESIGN.md §6), **placement is bit-invisible** — the tokens a request
//! generates cannot depend on which replica served it, how many replicas
//! exist, or how they were picked. This battery drives one length-diverse
//! trace through every cell of
//! `replicas ∈ {1, 2, 4} × placement ∈ {least-loaded, hash} ×
//! variant ∈ {dense, unified@0.2}` and requires token-identical output vs
//! a single-engine [`Scheduler`] baseline — in-process and over a real
//! HTTP socket with SSE streaming.

use std::net::{SocketAddr, TcpListener};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use tor_ssm::coordinator::engine::Engine;
use tor_ssm::coordinator::http::{self, client, HttpConfig, PoolConfig};
use tor_ssm::coordinator::prefix_cache::PrefixCache;
use tor_ssm::coordinator::replica::{Placement, ReplicaPool};
use tor_ssm::coordinator::router::Policy;
use tor_ssm::coordinator::scheduler::Scheduler;
use tor_ssm::coordinator::{Priority, Request};
use tor_ssm::fixtures::generate_default;
use tor_ssm::manifest::Manifest;
use tor_ssm::runtime::{Runtime, Weights};

fn fixture(tag: &str) -> (PathBuf, Manifest) {
    let dir = std::env::temp_dir().join(format!("tor-ssm-pool-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let man = generate_default(&dir).expect("fixture generation");
    (dir, man)
}

fn cleanup(dir: &Path) {
    let _ = std::fs::remove_dir_all(dir);
}

fn prompt_tokens(id: usize, plen: usize, vocab: usize) -> Vec<i32> {
    (0..plen).map(|t| ((t * 7 + id) % vocab) as i32).collect()
}

/// Length-diverse probe set: short, mid, full-frame, and a two-frame
/// chunked-prefill prompt; varied generation lengths.
fn cases(plen: usize, vocab: usize) -> Vec<(Vec<i32>, usize)> {
    vec![
        (prompt_tokens(1, plen / 4, vocab), 5),
        (prompt_tokens(2, plen / 2, vocab), 3),
        (prompt_tokens(3, plen, vocab), 4),
        (prompt_tokens(4, 2 * plen, vocab), 6),
        (prompt_tokens(5, plen / 2, vocab), 2),
        (prompt_tokens(6, plen / 3 + 1, vocab), 5),
    ]
}

fn requests(cases: &[(Vec<i32>, usize)], variant: &str) -> Vec<Request> {
    cases
        .iter()
        .enumerate()
        .map(|(i, (p, g))| Request {
            id: i as u64,
            prompt: p.clone(),
            gen_tokens: *g,
            variant: variant.to_string(),
            arrived_us: 0,
            priority: Priority::Normal,
        })
        .collect()
}

/// Single-engine ground truth: tokens per case id.
fn baseline(
    rt: &Runtime,
    man: &Manifest,
    w: &Weights,
    variant: &str,
    cases: &[(Vec<i32>, usize)],
) -> Vec<Vec<i32>> {
    let model = man.model("ref-mamba").unwrap().clone();
    let engine = Engine::new(rt, man, &model, w, variant).unwrap();
    let mut sched = Scheduler::new(&engine);
    let mut by_case = vec![Vec::new(); cases.len()];
    for r in sched.run(requests(cases, variant)).unwrap() {
        by_case[r.id as usize] = r.generated;
    }
    by_case
}

/// The acceptance matrix: every (replicas, placement, variant) cell must
/// reproduce the single-engine token streams exactly, with zero failures
/// and zero re-routes (no faults are injected here).
#[test]
fn pool_tokens_identical_across_replica_counts_and_placements() {
    let (dir, man) = fixture("identity");
    let rt = Runtime::reference().unwrap();
    let model = man.model("ref-mamba").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let probe = cases(man.prefill_seq_len, model.vocab_size);

    for variant in ["dense", "unified@0.2"] {
        let expect = baseline(&rt, &man, &w, variant, &probe);
        for replicas in [1usize, 2, 4] {
            for placement in [Placement::LeastLoaded, Placement::PrefixHash] {
                let mut engines: Vec<Engine> = (0..replicas)
                    .map(|_| Engine::new(&rt, &man, &model, &w, variant).unwrap())
                    .collect();
                for e in &mut engines {
                    e.attach_prefix_cache(Arc::new(PrefixCache::new(4 << 20)));
                }
                let mut pool = ReplicaPool::new(&engines, placement).unwrap();
                for req in requests(&probe, variant) {
                    pool.submit(req).unwrap();
                }
                let mut got = vec![Vec::new(); probe.len()];
                for r in pool.drain() {
                    got[r.id as usize] = r.generated;
                }
                assert!(pool.take_failures().is_empty(), "healthy pool failed requests");
                assert_eq!(pool.reroutes, 0, "healthy pool re-routed");
                for (ci, exp) in expect.iter().enumerate() {
                    assert_eq!(
                        &got[ci], exp,
                        "{variant} x{replicas} {placement:?} case {ci}: tokens diverged \
                         from the single-engine baseline"
                    );
                }
                // Non-vacuity: with more requests than replicas,
                // least-loaded must actually spread the work.
                if replicas > 1 && placement == Placement::LeastLoaded {
                    let used = pool.replica_stats().iter().filter(|s| s.completed > 0).count();
                    assert!(used > 1, "x{replicas} least-loaded served everything on one replica");
                }
            }
        }
    }
    cleanup(&dir);
}

/// Hash placement is deterministic (same trace → same replica per
/// request) and prefix-affine: two requests sharing a first-chunk prefix
/// land on the same replica, so its prefix cache serves the second one.
#[test]
fn hash_placement_is_deterministic_and_prefix_affine() {
    let (dir, man) = fixture("affine");
    let rt = Runtime::reference().unwrap();
    let model = man.model("ref-mamba").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let plen = man.prefill_seq_len;
    let vocab = model.vocab_size;

    let run = |record: &mut Vec<usize>| {
        let mut engines: Vec<Engine> = (0..3)
            .map(|_| Engine::new(&rt, &man, &model, &w, "dense").unwrap())
            .collect();
        for e in &mut engines {
            e.attach_prefix_cache(Arc::new(PrefixCache::new(4 << 20)));
        }
        let mut pool = ReplicaPool::new(&engines, Placement::PrefixHash).unwrap();
        // Two prompts sharing their whole first chunk, one unrelated.
        let shared = prompt_tokens(9, 2 * plen, vocab);
        let mut sibling = shared.clone();
        let last = sibling.len() - 1;
        sibling[last] = (sibling[last] + 1) % vocab as i32; // tail differs, first chunk equal
        let other = prompt_tokens(23, plen, vocab);
        for (id, p) in [shared, sibling, other].into_iter().enumerate() {
            let r = pool
                .submit(Request {
                    id: id as u64,
                    prompt: p,
                    gen_tokens: 3,
                    variant: "dense".into(),
                    arrived_us: 0,
                    priority: Priority::Normal,
                })
                .unwrap();
            record.push(r);
        }
        pool.drain();
        let hits: u64 = engines.iter().filter_map(|e| e.prefix_cache()).map(|c| c.stats().hits).sum();
        hits
    };
    let (mut first, mut second) = (Vec::new(), Vec::new());
    let hits1 = run(&mut first);
    let hits2 = run(&mut second);
    assert_eq!(first, second, "hash placement must be a pure function of the prompt");
    assert_eq!(first[0], first[1], "shared first chunk must land on one replica");
    assert!(hits1 > 0, "prefix-affine placement produced no cache hits");
    assert_eq!(hits1, hits2);
    cleanup(&dir);
}

/// Run `body` against a live pooled server on a loopback socket.
fn with_pooled_server<F, R>(
    engines: &[Engine],
    lanes: &[String],
    pool: PoolConfig,
    cfg: HttpConfig,
    body: F,
) -> (R, http::ServeReport)
where
    F: FnOnce(SocketAddr) -> R,
{
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().unwrap();
    let shutdown = AtomicBool::new(false);
    std::thread::scope(|s| {
        let server = s.spawn(|| {
            http::serve_pooled(engines, lanes, Policy::Explicit, pool, listener, cfg, &shutdown)
        });
        let out = body(addr);
        shutdown.store(true, Ordering::SeqCst);
        let report = server.join().expect("server thread").expect("serve returned an error");
        (out, report)
    })
}

/// The socket-level half of the contract: streamed SSE token order and
/// non-streamed completions from a multi-replica server are identical to
/// the single-engine baseline, for both placements.
#[test]
fn http_streams_identical_across_pool_topologies() {
    let (dir, man) = fixture("http");
    let rt = Runtime::reference().unwrap();
    let model = man.model("ref-mamba").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let probe = cases(man.prefill_seq_len, model.vocab_size);
    let lanes = ["dense", "unified@0.2"];
    let expect: Vec<Vec<Vec<i32>>> =
        lanes.iter().map(|v| baseline(&rt, &man, &w, v, &probe)).collect();

    for placement in [Placement::LeastLoaded, Placement::PrefixHash] {
        let replicas = 2usize;
        // Lane-major: both of dense's replicas, then both of unified's.
        let mut engines: Vec<Engine> = Vec::new();
        for v in &lanes {
            for _ in 0..replicas {
                engines.push(Engine::new(&rt, &man, &model, &w, v).unwrap());
            }
        }
        for e in &mut engines {
            e.attach_prefix_cache(Arc::new(PrefixCache::new(4 << 20)));
        }
        let lane_names: Vec<String> = lanes.iter().map(|s| s.to_string()).collect();
        let pool = PoolConfig { replicas, placement };
        let ((), report) = with_pooled_server(
            &engines,
            &lane_names,
            pool,
            HttpConfig::default(),
            |addr| {
                for (li, lane) in lanes.iter().enumerate() {
                    for (ci, (prompt, gen)) in probe.iter().enumerate() {
                        let body = format!(
                            "{{\"prompt\":{prompt:?},\"variant\":\"{lane}\",\
                             \"max_tokens\":{gen},\"stream\":true}}"
                        );
                        let resp = client::post_json(addr, "/v1/generate", &body).unwrap();
                        assert_eq!(resp.status, 200, "{}", resp.body_str());
                        let (tokens, done) = client::sse_tokens(&resp.body).unwrap();
                        assert_eq!(
                            tokens, expect[li][ci],
                            "{lane} x{replicas} {placement:?} case {ci}: streamed tokens \
                             diverged from the single-engine baseline"
                        );
                        assert!(done.is_some(), "stream missing its completion document");
                    }
                }
                // The stats document reports the pool topology.
                let stats = client::get(addr, "/stats").unwrap().body_json().unwrap();
                assert_eq!(stats.expect("replicas_per_lane").as_usize(), Some(replicas));
                assert_eq!(stats.expect("placement").as_str(), Some(placement.name()));
            },
        );
        assert_eq!(report.metrics.completed as usize, lanes.len() * probe.len());
    }
    cleanup(&dir);
}
