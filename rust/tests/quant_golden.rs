//! Golden lockstep test: the load-time int8 weight quantization
//! (`runtime::tensor::{quantize_rows, quantize_cols}`, DESIGN.md §13) vs
//! the stdlib-only python generator `python/compile/quant_golden.py`.
//!
//! The fixture `tests/data/quant_golden.json` carries both the inputs and
//! the expected (scales, q) pairs. Unusually for these fixtures the
//! generator emulates f32 bit-exactly, so the q comparison is **integer
//! equality** — tie cases (`.5` ratios under the half-away-from-zero rule)
//! and ±127 saturation included, not merely "close". If either side's
//! scheme changes, regenerate:
//!
//! ```text
//! PYTHONPATH=python python3 python/compile/quant_golden.py
//! ```
//!
//! Alongside the golden pin, a hand-rolled property test checks the
//! scheme's defining guarantees on random matrices: per-weight round-trip
//! error ≤ scale/2 (to f32 rounding), q within the symmetric ±127 grid,
//! zero channels quantizing to exact zeros, and every nonzero channel's
//! peak landing on the end of the grid.

use tor_ssm::runtime::tensor::{quantize_cols, quantize_rows, QuantAxis, QuantTensor};
use tor_ssm::util::json::Json;
use tor_ssm::util::rng::Rng;

fn load_golden() -> Json {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/data/quant_golden.json");
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("missing checked-in fixture {path}: {e}"));
    Json::parse(&text).expect("fixture parses")
}

/// Flatten a JSON matrix (array of equal-length rows) into row-major f32,
/// returning `(data, rows, cols)`.
fn matrix(j: &Json, key: &str) -> (Vec<f32>, usize, usize) {
    let rows = j.expect(key).as_arr().unwrap_or_else(|| panic!("{key} not an array"));
    let cols = rows[0].as_arr().expect("matrix row").len();
    let mut out = Vec::with_capacity(rows.len() * cols);
    for row in rows {
        let vals = row.as_arr().expect("matrix row");
        assert_eq!(vals.len(), cols, "{key}: ragged row");
        out.extend(vals.iter().map(|v| v.as_f64().expect("number") as f32));
    }
    (out, rows.len(), cols)
}

fn floats(j: &Json, key: &str) -> Vec<f64> {
    j.expect(key)
        .as_arr()
        .unwrap_or_else(|| panic!("{key} not an array"))
        .iter()
        .map(|v| v.as_f64().expect("number"))
        .collect()
}

#[test]
fn quantization_matches_python_generator_exactly() {
    let g = load_golden();
    let cases = g.expect("cases").as_arr().expect("cases array");
    assert!(cases.len() >= 4, "fixture lost cases");
    for case in cases {
        let name = case.str_of("name");
        let (data, rows, cols) = matrix(case, "data");
        let qt = match case.str_of("axis").as_str() {
            "row" => quantize_rows(&data, rows, cols),
            "col" => quantize_cols(&data, rows, cols),
            other => panic!("{name}: unknown axis {other:?}"),
        };
        let want_scales = floats(case, "scales");
        assert_eq!(qt.scales.len(), want_scales.len(), "{name}: scales length");
        for (i, (s, w)) in qt.scales.iter().zip(&want_scales).enumerate() {
            // The generator emulates f32 exactly and JSON round-trips f64
            // losslessly, so this is equality up to parse noise.
            assert!(
                (*s as f64 - w).abs() <= w.abs() * 1e-9,
                "{name}: scale[{i}] rust {s} vs python {w}"
            );
        }
        let (want_q, qr, qc) = matrix(case, "q");
        assert_eq!((qr, qc), (rows, cols), "{name}: q shape");
        for (i, (got, want)) in qt.q.iter().zip(&want_q).enumerate() {
            assert_eq!(
                *got as i64, *want as i64,
                "{name}: q[{i}] diverged (input {}, scale {})",
                data[i],
                qt.scales[match qt.axis {
                    QuantAxis::Row => i / cols,
                    QuantAxis::Col => i % cols,
                }]
            );
        }
    }
}

#[test]
fn fixture_exercises_ties_saturation_and_zero_channels() {
    let g = load_golden();
    let cases = g.expect("cases").as_arr().expect("cases array");
    let (mut sat_pos, mut sat_neg, mut zero_channel, mut tie) = (false, false, false, false);
    for case in cases {
        let (data, _, _) = matrix(case, "data");
        let (q, _, _) = matrix(case, "q");
        sat_pos |= q.iter().any(|&v| v as i64 == 127);
        sat_neg |= q.iter().any(|&v| v as i64 == -127);
        let scales = floats(case, "scales");
        zero_channel |= scales.iter().any(|&s| s == 0.0);
        // A `.5` ratio resolved away from zero leaves |q·scale| > |input|
        // at exactly half a step; the edge case plants one (-1.27 at scale
        // 0.02 -> -63.5 -> -64 under the away-from-zero rule).
        tie |= data.contains(&-1.27);
    }
    assert!(sat_pos && sat_neg, "fixture must saturate both grid ends");
    assert!(zero_channel, "fixture must carry an all-zero channel");
    assert!(tie, "fixture must carry the planted .5-ratio tie case");
}

/// Hand-rolled property test (same style as the schedule-solver proptests):
/// the scheme's guarantees hold on random matrices of random shapes.
#[test]
fn round_trip_error_is_bounded_by_half_a_step() {
    let mut rng = Rng::new(0x0807_2026);
    for trial in 0..200 {
        let rows = 1 + rng.below(24);
        let cols = 1 + rng.below(24);
        let amp = [1e-4, 1.0, 37.5][rng.below(3)] as f32;
        let mut data: Vec<f32> =
            (0..rows * cols).map(|_| amp * rng.normal() as f32).collect();
        // Sometimes zero out a whole row and column: scale-0 channels must
        // quantize to exact zeros, not NaNs.
        if trial % 3 == 0 {
            let zr = rng.below(rows);
            let zc = rng.below(cols);
            for c in 0..cols {
                data[zr * cols + c] = 0.0;
            }
            for r in 0..rows {
                data[r * cols + zc] = 0.0;
            }
        }
        for qt in [quantize_rows(&data, rows, cols), quantize_cols(&data, rows, cols)] {
            check_quant_invariants(&qt, &data, rows, cols, trial);
        }
    }
}

fn check_quant_invariants(qt: &QuantTensor, data: &[f32], rows: usize, cols: usize, trial: usize) {
    assert_eq!(qt.shape, [rows, cols]);
    let scale_of = |i: usize| match qt.axis {
        QuantAxis::Row => qt.scales[i / cols],
        QuantAxis::Col => qt.scales[i % cols],
    };
    for (i, (&q, &v)) in qt.q.iter().zip(data).enumerate() {
        let s = scale_of(i) as f64;
        assert!((-127..=127).contains(&(q as i64)), "trial {trial}: q {q} off the grid");
        if s == 0.0 {
            assert_eq!(q, 0, "trial {trial}: zero-scale channel produced q {q}");
            assert_eq!(v, 0.0, "trial {trial}: zero scale from nonzero weight {v}");
            continue;
        }
        // Round-to-nearest leaves ≤ half a step; the f32 division computing
        // the ratio adds at most ~127·ε of slack before rounding.
        let bound = s * 0.5 * (1.0 + 1e-3);
        let err = (q as f64 * s - v as f64).abs();
        assert!(
            err <= bound,
            "trial {trial}: |{q}·{s} - {v}| = {err} exceeds half a step {bound}"
        );
    }
    // The peak of every nonzero channel defines its scale, so it must land
    // exactly on the end of the grid.
    for (ch, &s) in qt.scales.iter().enumerate() {
        if s == 0.0 {
            continue;
        }
        let peak = match qt.axis {
            QuantAxis::Row => (0..cols).map(|c| qt.q[ch * cols + c].abs()).max(),
            QuantAxis::Col => (0..rows).map(|r| qt.q[r * cols + ch].abs()).max(),
        };
        assert_eq!(peak, Some(127), "trial {trial}: channel {ch} peak missed the grid end");
    }
}
