//! Property battery for rendezvous-hash placement (DESIGN.md §15).
//!
//! The pool's hash placement uses highest-random-weight (HRW) hashing
//! with index-stable per-replica seeds, which buys the classic minimal-
//! disruption guarantees this file pins:
//!
//! * **join**: adding replica N moves a key only if N wins its rendezvous
//!   — every moved key lands on the joiner, nothing else shuffles;
//! * **leave**: removing a replica moves exactly the keys it owned;
//! * the number of moved keys stays near K/N (bounded here well under
//!   ceil(K/3) for the K=256 / 3→4 trace — validated offline against an
//!   independent reimplementation of the hash chain);
//! * placement is order-independent in the eligible set and spreads load
//!   within 2x of fair share;
//! * `placement_key` keys on the first prefill frame only, so prompts
//!   sharing a cached prefix land on the same replica.
//!
//! All traces are seeded — these are exhaustive checks of fixed traces,
//! not flaky samples.

use tor_ssm::coordinator::replica::{hrw_score, mix64, pick_hrw, placement_key, replica_seed};
use tor_ssm::util::rng::Rng;

fn keys(seed: u64, n: usize) -> Vec<u64> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.next_u64()).collect()
}

/// mix64 is a bijection finalizer: distinct inputs map to distinct
/// outputs across a structured probe set (small ints, single bits, and a
/// seeded random batch — deduplicated first, since powers of two appear
/// in both the range and the bit sweep).
#[test]
fn mix64_is_injective_on_probe_set() {
    let mut probe: Vec<u64> = (0..4096u64)
        .chain((0..64).map(|i| 1u64 << i))
        .chain(keys(0xA5A5, 4096))
        .collect();
    probe.sort_unstable();
    probe.dedup();
    let mut seen: Vec<u64> = probe.iter().map(|&x| mix64(x)).collect();
    let n = seen.len();
    seen.sort_unstable();
    seen.dedup();
    assert_eq!(seen.len(), n, "mix64 collided on the probe set");
}

/// Replica seeds depend only on the index — the membership-independence
/// that makes HRW joins/leaves minimal — and are pairwise distinct.
#[test]
fn replica_seeds_are_stable_and_distinct() {
    let a: Vec<u64> = (0..64).map(replica_seed).collect();
    let b: Vec<u64> = (0..64).map(replica_seed).collect();
    assert_eq!(a, b);
    let mut s = a.clone();
    s.sort_unstable();
    s.dedup();
    assert_eq!(s.len(), 64, "replica seeds collided");
}

/// Join disruption is minimal: growing {0,1,2} to {0,1,2,3} moves a key
/// iff the joiner wins its rendezvous, so every moved key lands on
/// replica 3 — and the moved count for this K=256 trace stays under
/// ceil(K/3) (the offline-validated figure is 79 ≈ K/4).
#[test]
fn join_moves_only_keys_won_by_the_joiner() {
    let ks = keys(0xD1CE, 256);
    let mut moved = 0usize;
    for &k in &ks {
        let before = pick_hrw(k, &[0, 1, 2]).unwrap();
        let after = pick_hrw(k, &[0, 1, 2, 3]).unwrap();
        if before != after {
            moved += 1;
            assert_eq!(after, 3, "a key moved between survivors on join");
        }
    }
    assert!(moved > 0, "a 256-key trace where the joiner wins nothing is vacuous");
    let bound = (256 + 3 - 1) / 3; // ceil(K / N_before)
    assert!(moved <= bound, "join moved {moved} keys; minimal disruption allows at most {bound}");
}

/// Leave disruption is exact: removing replica 1 from {0,1,2,3} moves
/// precisely the keys replica 1 owned — survivors' keys never shuffle.
#[test]
fn leave_moves_exactly_the_departed_replicas_keys() {
    let ks = keys(0xD1CE, 256);
    let mut departed = 0usize;
    for &k in &ks {
        let before = pick_hrw(k, &[0, 1, 2, 3]).unwrap();
        let after = pick_hrw(k, &[0, 2, 3]).unwrap();
        if before == 1 {
            departed += 1;
            assert_ne!(after, 1);
        } else {
            assert_eq!(before, after, "a survivor's key moved on leave");
        }
    }
    assert!(departed > 0, "replica 1 owned nothing — vacuous trace");
}

/// The winner is a pure function of (key, eligible-set), not of the
/// order the eligible set is enumerated in.
#[test]
fn pick_is_order_independent() {
    let ks = keys(0xFACE, 512);
    let orders: [&[usize]; 3] = [&[0, 1, 2, 3], &[3, 1, 0, 2], &[2, 3, 1, 0]];
    for &k in &ks {
        let picks: Vec<usize> = orders.iter().map(|o| pick_hrw(k, o).unwrap()).collect();
        assert!(picks.windows(2).all(|w| w[0] == w[1]), "pick depends on enumeration order");
    }
    assert_eq!(pick_hrw(42, &[]), None);
    assert_eq!(pick_hrw(42, &[7]), Some(7));
}

/// Load spread over a 4096-key trace: every replica holds within
/// [fair/2, 2*fair] of the K/N fair share (the offline-validated counts
/// are 994–1062 around fair=1024 — this bound has wide margin and pins
/// gross skew, not sampling noise).
#[test]
fn load_spread_is_within_twice_fair_share() {
    let ks = keys(0xBEEF, 4096);
    let mut counts = [0usize; 4];
    for &k in &ks {
        counts[pick_hrw(k, &[0, 1, 2, 3]).unwrap()] += 1;
    }
    let fair = ks.len() / 4;
    for (i, &c) in counts.iter().enumerate() {
        assert!(
            c >= fair / 2 && c <= fair * 2,
            "replica {i} holds {c} keys; fair share is {fair}"
        );
    }
}

/// Placement keys on the first prefill frame only: prompts sharing their
/// first `chunk` tokens key identically (prefix-cache affinity), longer
/// tails are invisible, and `chunk == 0` degrades to whole-prompt keying.
#[test]
fn placement_key_is_first_frame_only() {
    let chunk = 32usize;
    let base: Vec<i32> = (0..(3 * chunk as i32)).collect();
    let mut tail_differs = base.clone();
    *tail_differs.last_mut().unwrap() = -1;
    assert_eq!(
        placement_key(&base, chunk),
        placement_key(&tail_differs, chunk),
        "tokens past the first frame must not affect placement"
    );
    assert_eq!(placement_key(&base, chunk), placement_key(&base[..chunk], chunk));

    let mut head_differs = base.clone();
    head_differs[0] = -1;
    assert_ne!(placement_key(&base, chunk), placement_key(&head_differs, chunk));

    // Shorter-than-frame prompts key on their full contents.
    assert_ne!(placement_key(&base[..5], chunk), placement_key(&base[..6], chunk));
    // chunk == 0 means no frame bound: the whole prompt is the key.
    assert_ne!(placement_key(&base, 0), placement_key(&tail_differs, 0));
}

/// hrw_score feeds max-comparison directly, so distinct (key, seed)
/// pairs colliding would silently merge replicas; spot-check avalanche
/// over a dense grid.
#[test]
fn hrw_scores_do_not_collide_across_replica_grid() {
    let ks = keys(0x5EED, 512);
    let mut scores: Vec<u64> = Vec::with_capacity(ks.len() * 8);
    for &k in &ks {
        for r in 0..8 {
            scores.push(hrw_score(k, replica_seed(r)));
        }
    }
    let n = scores.len();
    scores.sort_unstable();
    scores.dedup();
    assert_eq!(scores.len(), n, "hrw_score collided on the grid");
}
