//! Content-addressed model registry battery (DESIGN.md §15).
//!
//! Pins the registry's durability contract on the real fixture weights:
//!
//! * schema round-trips are lossless — V1 (one legacy blob) and V2
//!   (named per-param blobs) reconstruct bit-identical weight bytes, and
//!   `convert` between them changes layout, never content;
//! * every load verifies every blob against its manifest digest: one
//!   flipped byte on disk is a typed [`RegistryError::DigestMismatch`]
//!   that *names* the expected and actual digests;
//! * a missing blob and an unknown `schemaVersion` fail typed too —
//!   never a panic, never a half-read V1 guess;
//! * V2 publishing is content-addressed: tags sharing params share blob
//!   files on disk;
//! * `hot_load` lands registry weights in an engine whose tokens are
//!   bit-identical to one built from the original weights.

use std::path::{Path, PathBuf};

use tor_ssm::coordinator::engine::Engine;
use tor_ssm::coordinator::scheduler::Scheduler;
use tor_ssm::coordinator::{Priority, Request};
use tor_ssm::fixtures::generate_default;
use tor_ssm::manifest::Manifest;
use tor_ssm::runtime::registry::{digest_of, Registry, RegistryError, RegistryManifest};
use tor_ssm::runtime::{Runtime, Weights};

fn fixture(tag: &str) -> (PathBuf, Manifest) {
    let dir = std::env::temp_dir().join(format!("tor-ssm-registry-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let man = generate_default(&dir).expect("fixture generation");
    (dir, man)
}

fn cleanup(dir: &Path) {
    let _ = std::fs::remove_dir_all(dir);
}

fn registry_err(e: &anyhow::Error) -> &RegistryError {
    e.downcast_ref::<RegistryError>()
        .unwrap_or_else(|| panic!("expected a typed RegistryError, got: {e:#}"))
}

/// Manifest path layout is part of the on-disk contract.
fn manifest_path(reg: &Registry, name: &str, tag: &str) -> PathBuf {
    reg.root().join("manifests").join(name).join(format!("{tag}.json"))
}

fn blob_file(reg: &Registry, digest: &str) -> PathBuf {
    reg.root().join("blobs").join(digest.split(':').nth(1).expect("fnv64:<hex> digest"))
}

#[test]
fn digest_constants_are_pinned() {
    // FNV-1a 64 offset basis: the digest of zero bytes.
    assert_eq!(digest_of(&[]), "fnv64:cbf29ce484222325");
    // One-byte avalanche sanity.
    assert_ne!(digest_of(b"a"), digest_of(b"b"));
}

/// V1↔V2 round-trips are lossless on the real fixture weights: every
/// schema and every `convert` direction reconstructs bit-identical param
/// bytes, and manifest render/parse is an exact inverse.
#[test]
fn schema_round_trips_are_lossless() {
    let (dir, man) = fixture("roundtrip");
    let model = man.model("ref-mamba").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let original = w.to_bytes(&model).unwrap();
    let reg = Registry::open(dir.join("registry"));

    for schema in [1u64, 2] {
        let tag = format!("s{schema}");
        let m = reg.publish(&model, &tag, &w, schema).unwrap();
        assert_eq!(m.schema_version(), schema);
        assert_eq!((m.name(), m.tag()), (model.name.as_str(), tag.as_str()));
        // Render/parse is an exact inverse.
        assert_eq!(RegistryManifest::parse(&m.render()).unwrap(), m);
        // Disk round-trip reconstructs the exact bytes.
        let loaded = reg.load(&model, &tag).unwrap();
        assert_eq!(loaded.to_bytes(&model).unwrap(), original, "schema {schema} lost bytes");
    }

    // Cross-schema conversion: V1 → V2 → V1, content never changes.
    let v2 = reg.convert(&model, "s1", 2).unwrap();
    assert_eq!(v2.schema_version(), 2);
    assert_eq!(reg.load(&model, "s1").unwrap().to_bytes(&model).unwrap(), original);
    let v1 = reg.convert(&model, "s2", 1).unwrap();
    assert_eq!(v1.schema_version(), 1);
    assert_eq!(reg.load(&model, "s2").unwrap().to_bytes(&model).unwrap(), original);
    cleanup(&dir);
}

/// V2 blobs are content-addressed: two tags of identical weights share
/// every blob file, and the store holds exactly one copy per distinct
/// param content.
#[test]
fn identical_params_share_blob_files() {
    let (dir, man) = fixture("dedup");
    let model = man.model("ref-mamba").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let reg = Registry::open(dir.join("registry"));

    let a = reg.publish(&model, "a", &w, 2).unwrap();
    let b = reg.publish(&model, "b", &w, 2).unwrap();
    let (RegistryManifest::V2(a), RegistryManifest::V2(b)) = (a, b) else {
        panic!("schema 2 publish must yield V2 manifests");
    };
    assert_eq!(a.blobs, b.blobs, "identical content must digest identically");
    let distinct: std::collections::BTreeSet<&str> =
        a.blobs.iter().map(|e| e.digest.as_str()).collect();
    let on_disk = std::fs::read_dir(reg.root().join("blobs")).unwrap().count();
    assert_eq!(on_disk, distinct.len(), "blob store holds duplicates");
    cleanup(&dir);
}

/// One flipped byte in a stored blob is caught at load and named: the
/// error is a typed `DigestMismatch` carrying the manifest digest and
/// the actual hash of the poisoned bytes.
#[test]
fn flipped_byte_is_rejected_with_named_digest() {
    let (dir, man) = fixture("flip");
    let model = man.model("ref-mamba").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let reg = Registry::open(dir.join("registry"));
    let m = reg.publish(&model, "t", &w, 2).unwrap();
    let RegistryManifest::V2(m) = m else { panic!("expected V2") };

    // Poison the second param's blob so the failure names a specific one.
    let victim = &m.blobs[1];
    let path = blob_file(&reg, &victim.digest);
    let mut bytes = std::fs::read(&path).unwrap();
    bytes[0] ^= 0x01;
    std::fs::write(&path, &bytes).unwrap();

    let err = reg.load(&model, "t").unwrap_err();
    match registry_err(&err) {
        RegistryError::DigestMismatch { name, expected, actual } => {
            assert_eq!(name, &victim.param);
            assert_eq!(expected, &victim.digest);
            assert_eq!(actual, &digest_of(&bytes));
            assert_ne!(expected, actual);
        }
        other => panic!("expected DigestMismatch, got {other}"),
    }
    // The digest appears in the rendered message (greppability contract).
    assert!(format!("{err:#}").contains(&victim.digest), "message must name the digest");

    // V1 verifies the whole blob the same way.
    reg.publish(&model, "t1", &w, 1).unwrap();
    let legacy = reg.root().join("legacy").join(format!("{}-t1.bin", model.name));
    let mut lb = std::fs::read(&legacy).unwrap();
    let mid = lb.len() / 2;
    lb[mid] ^= 0x80;
    std::fs::write(&legacy, &lb).unwrap();
    let err = reg.load(&model, "t1").unwrap_err();
    assert!(
        matches!(registry_err(&err), RegistryError::DigestMismatch { .. }),
        "V1 corruption must be a DigestMismatch, got: {err:#}"
    );
    cleanup(&dir);
}

/// A deleted blob fails typed with the digest that cannot be read.
#[test]
fn missing_blob_fails_typed() {
    let (dir, man) = fixture("missing");
    let model = man.model("ref-mamba").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let reg = Registry::open(dir.join("registry"));
    let RegistryManifest::V2(m) = reg.publish(&model, "t", &w, 2).unwrap() else {
        panic!("expected V2")
    };
    let victim = &m.blobs[0];
    std::fs::remove_file(blob_file(&reg, &victim.digest)).unwrap();
    // Another tag may still reference surviving blobs; this load must not.
    let err = reg.load(&model, "t").unwrap_err();
    match registry_err(&err) {
        RegistryError::MissingBlob { name, digest, .. } => {
            assert_eq!(name, &victim.param);
            assert_eq!(digest, &victim.digest);
        }
        other => panic!("expected MissingBlob, got {other}"),
    }
    cleanup(&dir);
}

/// Version dispatch happens before field parsing: a manifest from the
/// future fails as `UnknownSchema { 9 }` even though its body would
/// parse fine under schema 1 — and publishing an unknown schema is
/// rejected the same way.
#[test]
fn unknown_schema_versions_fail_typed() {
    let (dir, man) = fixture("schema");
    let model = man.model("ref-mamba").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let reg = Registry::open(dir.join("registry"));
    reg.publish(&model, "t", &w, 1).unwrap();

    // Hand-write a future manifest whose body is a perfectly valid V1.
    let future = format!(
        "{{\"schemaVersion\":9,\"name\":\"{}\",\"tag\":\"f\",\"blob\":\"legacy/x.bin\",\
         \"digest\":\"fnv64:0000000000000000\",\"totalBytes\":0}}",
        model.name
    );
    std::fs::write(manifest_path(&reg, &model.name, "f"), &future).unwrap();
    let err = reg.load(&model, "f").unwrap_err();
    assert_eq!(registry_err(&err), &RegistryError::UnknownSchema { version: 9 });
    assert!(format!("{err:#}").contains("schema version 9"));

    // Parse-level dispatch agrees.
    assert_eq!(
        RegistryManifest::parse(&future).unwrap_err(),
        RegistryError::UnknownSchema { version: 9 }
    );
    // Publishing an unknown schema is refused up front.
    let err = reg.publish(&model, "t9", &w, 9).unwrap_err();
    assert_eq!(registry_err(&err), &RegistryError::UnknownSchema { version: 9 });
    // Garbage text is InvalidManifest, not a panic.
    assert!(matches!(
        RegistryManifest::parse("not json").unwrap_err(),
        RegistryError::InvalidManifest { .. }
    ));
    cleanup(&dir);
}

/// `hot_load` ties the registry into the serving path: an engine swapped
/// to registry-loaded weights generates tokens bit-identical to an
/// engine built from the original weights.
#[test]
fn hot_loaded_weights_serve_identical_tokens() {
    let (dir, man) = fixture("hotload");
    let rt = Runtime::reference().unwrap();
    let model = man.model("ref-mamba").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let reg = Registry::open(dir.join("registry"));
    reg.publish(&model, "prod", &w, 2).unwrap();

    let prompt: Vec<i32> =
        (0..man.prefill_seq_len).map(|t| ((t * 7 + 1) % model.vocab_size) as i32).collect();
    let req = |id| Request {
        id,
        prompt: prompt.clone(),
        gen_tokens: 5,
        variant: "dense".to_string(),
        arrived_us: 0,
        priority: Priority::Normal,
    };

    let run = |engine: &Engine| {
        let mut sched = Scheduler::new(engine);
        sched.run(vec![req(0)]).unwrap().remove(0).generated
    };
    let direct = Engine::new(&rt, &man, &model, &w, "dense").unwrap();
    let expect = run(&direct);

    let swapped = Engine::new(&rt, &man, &model, &w, "dense").unwrap();
    let dev = reg.hot_load(&rt, &model, "prod").unwrap();
    swapped.hot_swap_weights(dev, "prod");
    assert_eq!(swapped.weights_tag(), "prod");
    assert_eq!(run(&swapped), expect, "registry weights diverged from the originals");
    cleanup(&dir);
}
