//! Prefill padding-invariance suite — the regression fence for the
//! PAD-pollution bug (DESIGN.md §6).
//!
//! An SSM integrates *every* scanned position into its recurrent state, so
//! the old prefill — right-pad each prompt to the frame and scan the PAD
//! tail like real tokens — polluted every short prompt's conv/ssm state,
//! sampled its first token from logits at a PAD position, and fed PAD rows
//! to every reduction policy's importance/merge metrics. With per-sequence
//! lengths threaded to the backend, a prompt's `PrefilledSeq` (conv, ssm,
//! logits) must be **bit-identical** whether it is prefilled:
//!
//! * alone or in a mixed-length batch (batch-composition independence);
//! * in a frame with any amount of trailing padding, or in a frame of
//!   exactly its own length (padding invariance) — for dense AND all four
//!   reduction policies at two ratios;
//! * with literal 0 tokens (the PAD vocab id) inside the prompt — PAD is an
//!   ordinary word, not a semantic marker;
//! * in one wide frame or as frame-sized chunks with carried state
//!   (chunked-prefill identity on the dense path).
//!
//! Engines that cannot be length-aware (AOT entries without a `lengths`
//! input) must refuse over-long prompts loudly instead of truncating.

use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;

use tor_ssm::coordinator::engine::{Engine, PrefilledSeq};
use tor_ssm::coordinator::Request;
use tor_ssm::fixtures::{generate, FixtureSpec};
use tor_ssm::manifest::Manifest;
use tor_ssm::runtime::{Runtime, Weights};

/// Unique per-test fixture dir with a custom prefill frame length.
fn fixture(tag: &str, prefill_seq_len: usize) -> (PathBuf, Manifest) {
    let dir = std::env::temp_dir().join(format!("tor-ssm-pinv-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let spec = FixtureSpec { prefill_seq_len, ..FixtureSpec::default() };
    let man = generate(&dir, &spec).expect("fixture generation");
    (dir, man)
}

fn cleanup(dir: &Path) {
    let _ = std::fs::remove_dir_all(dir);
}

fn rq(id: u64, prompt: Vec<i32>) -> Request {
    Request {
        id,
        prompt,
        gen_tokens: 1,
        variant: String::new(),
        arrived_us: 0,
        priority: Default::default(),
    }
}

fn prompt(len: usize, salt: usize, vocab: usize) -> Vec<i32> {
    (0..len).map(|t| ((t * 7 + salt * 13 + 3) % vocab) as i32).collect()
}

fn assert_seq_eq(a: &PrefilledSeq, b: &PrefilledSeq, what: &str) {
    assert_eq!(a.conv, b.conv, "{what}: conv state diverged");
    assert_eq!(a.ssm, b.ssm, "{what}: ssm state diverged");
    assert_eq!(a.logits, b.logits, "{what}: last-token logits diverged");
}

/// Dense + all four policies × two ratios: a 16-token prompt's prefill
/// result is identical alone, in a mixed-length batch, and in a frame of
/// exactly 16 tokens (zero padding) — i.e. independent of batch
/// composition and of the amount of frame padding behind it.
#[test]
fn short_prompt_prefill_is_padding_and_batch_invariant() {
    let (dir_a, man_a) = fixture("pad32", 32); // default frame: 16 PAD slots behind the prompt
    let (dir_b, man_b) = fixture("pad16", 16); // exact-length frame: no padding at all
    // The weight streams do not depend on the frame geometry, so the two
    // fixtures are the same model — that is what makes the comparison
    // meaningful (and this assert keeps it honest).
    for blob in ["init_ref-mamba.bin", "init_ref-mamba2.bin"] {
        assert_eq!(
            std::fs::read(dir_a.join(blob)).unwrap(),
            std::fs::read(dir_b.join(blob)).unwrap(),
            "{blob}: fixtures diverged — frame length leaked into the weight stream"
        );
    }
    let rt = Runtime::reference().unwrap();
    let variants = [
        "dense",
        "unified@0.1",
        "unified@0.2",
        "prune@0.1",
        "prune@0.2",
        "merge@0.1",
        "merge@0.2",
        "random@0.1",
        "random@0.2",
    ];
    for model_name in ["ref-mamba", "ref-mamba2"] {
        let model_a = man_a.model(model_name).unwrap().clone();
        let model_b = man_b.model(model_name).unwrap().clone();
        let w_a = Weights::load_init(&man_a, &model_a).unwrap();
        let w_b = Weights::load_init(&man_b, &model_b).unwrap();
        let vocab = model_a.vocab_size;
        let short = prompt(16, 1, vocab);
        let full = prompt(32, 2, vocab);
        for variant in variants {
            let engine_a = Engine::new(&rt, &man_a, &model_a, &w_a, variant).unwrap();
            let engine_b = Engine::new(&rt, &man_b, &model_b, &w_b, variant).unwrap();
            assert!(engine_a.length_aware && engine_b.length_aware);

            let (alone, _) = engine_a.prefill(&[rq(0, short.clone())]).unwrap();
            let (mixed, _) =
                engine_a.prefill(&[rq(1, full.clone()), rq(0, short.clone())]).unwrap();
            let (exact, _) = engine_b.prefill(&[rq(0, short.clone())]).unwrap();

            let what = format!("{model_name}/{variant}");
            assert_seq_eq(&alone[0], &mixed[1], &format!("{what} (alone vs mixed batch)"));
            assert_seq_eq(&alone[0], &exact[0], &format!("{what} (padded vs exact frame)"));
        }
    }
    cleanup(&dir_a);
    cleanup(&dir_b);
}

/// Regression for PAD = vocab id 0: a prompt *containing* literal 0 tokens
/// prefills identically in a padded frame (trailing 0-fill behind it) and
/// in an exact-length frame — legitimate 0 tokens are scanned as ordinary
/// words while frame padding is never scanned at all.
#[test]
fn literal_pad_id_tokens_are_ordinary_vocabulary() {
    let (dir_a, man_a) = fixture("zeros32", 32);
    let (dir_b, man_b) = fixture("zeros16", 16);
    let rt = Runtime::reference().unwrap();
    let model_a = man_a.model("ref-mamba").unwrap().clone();
    let model_b = man_b.model("ref-mamba").unwrap().clone();
    let w_a = Weights::load_init(&man_a, &model_a).unwrap();
    let w_b = Weights::load_init(&man_b, &model_b).unwrap();

    // 16 tokens, a third of them the PAD id (0), including the last one —
    // indistinguishable from frame padding by value alone.
    let mut p = prompt(16, 3, model_a.vocab_size);
    for i in [0usize, 3, 7, 11, 15] {
        p[i] = 0;
    }
    for variant in ["dense", "unified@0.2"] {
        let engine_a = Engine::new(&rt, &man_a, &model_a, &w_a, variant).unwrap();
        let engine_b = Engine::new(&rt, &man_b, &model_b, &w_b, variant).unwrap();
        let (padded, _) = engine_a.prefill(&[rq(0, p.clone())]).unwrap();
        let (exact, _) = engine_b.prefill(&[rq(0, p.clone())]).unwrap();
        assert_seq_eq(&padded[0], &exact[0], &format!("{variant}: prompt with literal 0 tokens"));
        // The in-prompt zeros are real tokens: dropping them must change
        // the state (guards against a "trim all zeros" pseudo-fix).
        let trimmed: Vec<i32> = p.iter().copied().filter(|&t| t != 0).collect();
        let (t_out, _) = engine_a.prefill(&[rq(1, trimmed)]).unwrap();
        assert_ne!(
            t_out[0].ssm,
            padded[0].ssm,
            "{variant}: stripping in-prompt 0 tokens should change the state"
        );
    }
    cleanup(&dir_a);
    cleanup(&dir_b);
}

/// Acceptance: chunked prefill at chunk sizes {prefill_len, full} is
/// bit-identical on the dense path — a 96-token prompt through a 32-token
/// frame (3 carried chunks) equals the same prompt through a 96-token
/// frame (1 chunk), and likewise for a ragged 80-token prompt (32+32+16).
#[test]
fn chunked_prefill_matches_single_frame_dense() {
    let (dir_a, man_a) = fixture("chunk32", 32);
    let (dir_c, man_c) = fixture("chunk96", 96);
    let rt = Runtime::reference().unwrap();
    for model_name in ["ref-mamba", "ref-mamba2"] {
        let model_a = man_a.model(model_name).unwrap().clone();
        let model_c = man_c.model(model_name).unwrap().clone();
        let w_a = Weights::load_init(&man_a, &model_a).unwrap();
        let w_c = Weights::load_init(&man_c, &model_c).unwrap();
        let vocab = model_a.vocab_size;
        let engine_a = Engine::new(&rt, &man_a, &model_a, &w_a, "dense").unwrap();
        let engine_c = Engine::new(&rt, &man_c, &model_c, &w_c, "dense").unwrap();
        for (salt, len) in [(5usize, 96usize), (6, 80)] {
            let p = prompt(len, salt, vocab);
            let fed0 = engine_a.prefill_tokens.load(Ordering::Relaxed);
            let (chunked, _) = engine_a.prefill(&[rq(0, p.clone())]).unwrap();
            // The fed-token counter (the zero-truncation gate's measured
            // quantity) counts every true prompt token exactly once across
            // chunks — never the frame padding around ragged chunks.
            assert_eq!(
                engine_a.prefill_tokens.load(Ordering::Relaxed) - fed0,
                len as u64,
                "{model_name}: chunked prefill fed a wrong token count"
            );
            let (whole, _) = engine_c.prefill(&[rq(0, p)]).unwrap();
            assert_seq_eq(
                &chunked[0],
                &whole[0],
                &format!("{model_name}: {len}-token prompt, 32-chunked vs one frame"),
            );
        }
    }
    cleanup(&dir_a);
    cleanup(&dir_c);
}

/// An engine whose prefill entry takes no `lengths` input (the AOT shape)
/// cannot chunk: prompts longer than the frame must be a hard error — the
/// silent `resize`+slice truncation is gone.
#[test]
fn non_length_aware_engine_refuses_overlong_prompts() {
    let (dir, man) = fixture("legacy", 32);
    let rt = Runtime::reference().unwrap();
    let mut model = man.model("ref-mamba").unwrap().clone();
    for e in model.hlo.values_mut() {
        e.takes_lengths = false; // simulate an AOT export without lengths
    }
    let w = Weights::load_init(&man, &model).unwrap();
    let engine = Engine::new(&rt, &man, &model, &w, "dense").unwrap();
    assert!(!engine.length_aware);

    // Exactly one frame still works (no padding involved)…
    let full = prompt(32, 1, model.vocab_size);
    engine.prefill(&[rq(0, full)]).unwrap();
    // …and the legacy padded path feeds the measured-token counter too.
    assert_eq!(engine.prefill_tokens.load(Ordering::Relaxed), 32);
    // …one token more is refused, loudly, naming the mismatch.
    let over = prompt(33, 2, model.vocab_size);
    let err = engine.prefill(&[rq(1, over)]).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("refusing to truncate"),
        "over-long prompt must fail with a truncation-refusal error, got: {msg}"
    );
    // Empty prompts are rejected on every path (an all-PAD frame is not a
    // prompt).
    let err = engine.prefill(&[rq(2, vec![])]).unwrap_err();
    assert!(format!("{err:#}").contains("empty prompt"));
    cleanup(&dir);
}
