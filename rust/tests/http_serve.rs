//! Protocol-level battery for the HTTP/1.1 serving front-end (DESIGN.md
//! §14), hermetic over real loopback sockets:
//!
//! * conformance — tokens served over the socket are bit-identical to an
//!   in-process [`Scheduler`] run on the same (prompt, variant), for dense
//!   AND a reduced lane; streamed token concatenation equals the
//!   non-streamed completion; chunked framing is validated strictly
//!   (well-formed size lines, terminal `0\r\n\r\n`) by the test client;
//! * malformed-input battery — truncated/oversized heads, bad
//!   `Content-Length`, invalid UTF-8, malformed vs unserved variants
//!   (400 vs 404, the Router's typed distinction), empty prompts,
//!   slowloris dribble → clean errors, listener still serving after each;
//! * backpressure + drain — a saturated admission queue answers 429 +
//!   `Retry-After` without dropping admitted work; graceful drain rejects
//!   new work with 503 while every admitted stream runs to completion.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

use tor_ssm::coordinator::engine::Engine;
use tor_ssm::coordinator::http::{self, client, HttpConfig};
use tor_ssm::coordinator::router::Policy;
use tor_ssm::coordinator::scheduler::Scheduler;
use tor_ssm::coordinator::{Priority, Request};
use tor_ssm::fixtures::generate_default;
use tor_ssm::manifest::Manifest;
use tor_ssm::runtime::{Runtime, Weights};
use tor_ssm::util::json::Json;

fn i32s(j: &Json) -> Vec<i32> {
    j.as_arr()
        .expect("expected a JSON array")
        .iter()
        .map(|x| x.as_f64().expect("expected a number") as i32)
        .collect()
}

/// Unique per-test fixture dir (tests run in parallel threads).
fn fixture(tag: &str) -> (PathBuf, Manifest) {
    let dir = std::env::temp_dir().join(format!("tor-ssm-http-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let man = generate_default(&dir).expect("fixture generation");
    (dir, man)
}

fn cleanup(dir: &Path) {
    let _ = std::fs::remove_dir_all(dir);
}

fn prompt_tokens(id: usize, plen: usize, vocab: usize) -> Vec<i32> {
    (0..plen).map(|t| ((t * 7 + id) % vocab) as i32).collect()
}

fn gen_body(prompt: &[i32], variant: &str, max_tokens: usize, stream: bool) -> String {
    format!(
        "{{\"prompt\":{prompt:?},\"variant\":\"{variant}\",\"max_tokens\":{max_tokens},\"stream\":{stream}}}"
    )
}

/// Run `body` against a live server on a loopback socket; returns the
/// closure's result plus the drained [`http::ServeReport`]. The server
/// runs on a scoped thread, the test body on the caller's; `shutdown` is
/// raised after `body` returns (tests that exercise drain raise it
/// themselves, earlier).
fn with_server<F, R>(
    engines: &[Engine],
    lanes: &[String],
    policy: Policy,
    cfg: HttpConfig,
    body: F,
) -> (R, http::ServeReport)
where
    F: FnOnce(SocketAddr, &AtomicBool) -> R,
{
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().unwrap();
    let shutdown = AtomicBool::new(false);
    std::thread::scope(|s| {
        let server = s.spawn(|| http::serve(engines, lanes, policy, listener, cfg, &shutdown));
        let out = body(addr, &shutdown);
        shutdown.store(true, Ordering::SeqCst);
        let report = server.join().expect("server thread").expect("serve returned an error");
        (out, report)
    })
}

fn build_engines(
    rt: &Runtime,
    man: &Manifest,
    w: &Weights,
    lanes: &[&str],
) -> (Vec<Engine>, Vec<String>) {
    let model = man.model("ref-mamba").unwrap().clone();
    let engines: Vec<Engine> = lanes
        .iter()
        .map(|v| Engine::new(rt, man, &model, w, v).expect("engine"))
        .collect();
    (engines, lanes.iter().map(|s| s.to_string()).collect())
}

// ---------------------------------------------------------------------------
// Conformance
// ---------------------------------------------------------------------------

/// The acceptance test: tokens POSTed over a real socket are bit-identical
/// to the in-process Scheduler for the same (prompt, variant), streamed
/// concatenation equals the non-streamed completion, and the chunked
/// framing round-trips under a strict parser — for dense and unified@0.2.
#[test]
fn socket_tokens_bit_identical_to_in_process_scheduler() {
    let (dir, man) = fixture("conform");
    let rt = Runtime::reference().unwrap();
    let model = man.model("ref-mamba").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let vocab = model.vocab_size;
    let plen = man.prefill_seq_len;
    let lanes = ["dense", "unified@0.2"];
    let (engines, lane_names) = build_engines(&rt, &man, &w, &lanes);

    // Length-diverse probe set: short, full-frame, and (length-aware
    // fixture) a two-frame chunked-prefill prompt.
    let cases: Vec<(Vec<i32>, usize)> = vec![
        (prompt_tokens(1, plen / 2, vocab), 5),
        (prompt_tokens(2, plen, vocab), 3),
        (prompt_tokens(3, 2 * plen, vocab), 6),
    ];

    // In-process ground truth: a fresh engine + scheduler per lane.
    let mut expected: Vec<Vec<Vec<i32>>> = Vec::new(); // [lane][case] -> tokens
    for lane in &lanes {
        let engine = Engine::new(&rt, &man, &model, &w, lane).unwrap();
        let mut sched = Scheduler::new(&engine);
        let reqs: Vec<Request> = cases
            .iter()
            .enumerate()
            .map(|(i, (p, g))| Request {
                id: i as u64,
                prompt: p.clone(),
                gen_tokens: *g,
                variant: lane.to_string(),
                arrived_us: 0,
                priority: Priority::Normal,
            })
            .collect();
        let resps = sched.run(reqs).unwrap();
        let mut by_case = vec![Vec::new(); cases.len()];
        for r in resps {
            by_case[r.id as usize] = r.generated;
        }
        expected.push(by_case);
    }

    let (_, report) = with_server(&engines, &lane_names, Policy::Explicit, HttpConfig::default(), |addr, _| {
        for (li, lane) in lanes.iter().enumerate() {
            for (ci, (prompt, gen)) in cases.iter().enumerate() {
                // Non-streamed completion.
                let resp = client::post_json(addr, "/v1/generate", &gen_body(prompt, lane, *gen, false))
                    .expect("request");
                assert_eq!(resp.status, 200, "{lane} case {ci}: {}", resp.body_str());
                assert!(!resp.chunked, "non-streamed must use Content-Length");
                let doc = resp.body_json().unwrap();
                let plain: Vec<i32> = i32s(doc.expect("tokens"));
                assert_eq!(
                    plain, expected[li][ci],
                    "{lane} case {ci}: socket tokens differ from in-process scheduler"
                );
                let usage = doc.expect("usage");
                assert_eq!(usage.expect("prompt_tokens").as_usize(), Some(prompt.len()));
                assert_eq!(usage.expect("generated_tokens").as_usize(), Some(*gen));

                // Streamed: same tokens, one data: event per token, strict
                // chunked framing (parse_response errors on any deviation).
                let t = client::post_json_timed(addr, "/v1/generate", &gen_body(prompt, lane, *gen, true))
                    .expect("streamed request");
                assert_eq!(t.resp.status, 200);
                assert!(t.resp.chunked, "streamed must use chunked transfer encoding");
                assert!(!t.resp.chunks.is_empty());
                let (tokens, done) = client::sse_tokens(&t.resp.body).expect("SSE stream");
                assert_eq!(
                    tokens, expected[li][ci],
                    "{lane} case {ci}: streamed tokens differ from in-process scheduler"
                );
                let done = done.expect("missing final done event");
                let done_tokens = i32s(done.expect("tokens"));
                assert_eq!(done_tokens, tokens, "done event must carry the full token list");
                assert!(t.ttft_us > 0 && t.ttft_us <= t.e2e_us, "TTFT must precede e2e");
            }
        }
    });
    // Every case ran twice (plain + streamed) per lane, all completed.
    assert_eq!(report.metrics.completed as usize, 2 * lanes.len() * cases.len());
    cleanup(&dir);
}

/// Priority strings map onto the scheduler's classes and unknown request
/// fields are ignored (lazy extraction only reads what it needs).
#[test]
fn priority_and_unknown_fields() {
    let (dir, man) = fixture("prio");
    let rt = Runtime::reference().unwrap();
    let model = man.model("ref-mamba").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let vocab = model.vocab_size;
    let plen = man.prefill_seq_len;
    let (engines, lane_names) = build_engines(&rt, &man, &w, &["dense"]);

    let (_, report) =
        with_server(&engines, &lane_names, Policy::Explicit, HttpConfig::default(), |addr, _| {
            let prompt = prompt_tokens(9, plen / 2, vocab);
            for prio in ["low", "normal", "high"] {
                let body = format!(
                    "{{\"prompt\":{prompt:?},\"variant\":\"dense\",\"max_tokens\":2,\
                     \"priority\":\"{prio}\",\"ignored_field\":{{\"nested\":[1,2,3]}}}}"
                );
                let resp = client::post_json(addr, "/v1/generate", &body).unwrap();
                assert_eq!(resp.status, 200, "priority {prio}: {}", resp.body_str());
            }
            let resp = client::post_json(
                addr,
                "/v1/generate",
                &format!("{{\"prompt\":{prompt:?},\"variant\":\"dense\",\"priority\":\"urgent\"}}"),
            )
            .unwrap();
            assert_eq!(resp.status, 400, "unknown priority must be rejected");
        });
    assert_eq!(report.metrics.completed, 3);
    cleanup(&dir);
}

// ---------------------------------------------------------------------------
// Malformed-input battery
// ---------------------------------------------------------------------------

/// Raw-socket sender for requests that are deliberately broken at the
/// byte level (the structured client refuses to produce them).
fn raw_exchange(addr: SocketAddr, bytes: &[u8]) -> client::RawResponse {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    s.write_all(bytes).expect("send");
    s.shutdown(std::net::Shutdown::Write).expect("half-close");
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).expect("read response");
    client::parse_response(&buf).expect("parse response")
}

#[test]
fn malformed_input_battery_leaves_listener_serving() {
    let (dir, man) = fixture("malformed");
    let rt = Runtime::reference().unwrap();
    let model = man.model("ref-mamba").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let vocab = model.vocab_size;
    let plen = man.prefill_seq_len;
    let (engines, lane_names) = build_engines(&rt, &man, &w, &["dense", "unified@0.2"]);
    let cfg = HttpConfig { read_timeout: Duration::from_millis(300), ..HttpConfig::default() };

    let ((), _report) = with_server(&engines, &lane_names, Policy::Explicit, cfg, |addr, _| {
        let ok_prompt = prompt_tokens(4, plen / 2, vocab);
        let assert_status = |name: &str, resp: &client::RawResponse, want: u16| {
            assert_eq!(resp.status, want, "{name}: {}", resp.body_str());
            // Every error is a JSON document naming the problem.
            assert!(
                resp.body_json().map(|j| j.get("error").is_some()).unwrap_or(false),
                "{name}: error body must be JSON with an \"error\" field, got {:?}",
                resp.body_str()
            );
            // …and the listener must still be serving afterwards.
            let health = client::get(addr, "/healthz").expect("healthz after error");
            assert_eq!(health.status, 200, "{name}: listener died");
        };

        // Truncated request head (client hangs up mid-head).
        let r = raw_exchange(addr, b"POST /v1/generate HTTP/1.1\r\nContent-Le");
        assert_status("truncated head", &r, 400);

        // Oversized header block.
        let mut big = b"POST /v1/generate HTTP/1.1\r\n".to_vec();
        while big.len() < 10 * 1024 {
            big.extend_from_slice(b"X-Padding: aaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaa\r\n");
        }
        let r = raw_exchange(addr, &big);
        assert_status("oversized head", &r, 431);

        // Unparseable Content-Length value vs missing Content-Length.
        let r = raw_exchange(addr, b"POST /v1/generate HTTP/1.1\r\nContent-Length: nope\r\n\r\n");
        assert_status("bad content-length", &r, 400);
        let r = raw_exchange(addr, b"POST /v1/generate HTTP/1.1\r\n\r\n");
        assert_status("missing content-length", &r, 411);

        // Body larger than the cap is refused before it is read.
        let r = raw_exchange(addr, b"POST /v1/generate HTTP/1.1\r\nContent-Length: 999999999\r\n\r\n");
        assert_status("oversized body", &r, 413);

        // Invalid UTF-8 body.
        let mut bad_utf8 = b"POST /v1/generate HTTP/1.1\r\nContent-Length: 4\r\n\r\n".to_vec();
        bad_utf8.extend_from_slice(&[0xff, 0xfe, 0x80, 0x81]);
        let r = raw_exchange(addr, &bad_utf8);
        assert_status("invalid utf-8", &r, 400);

        // Malformed JSON, malformed fields, empty prompt (PR 5 contract).
        for (name, body) in [
            ("bad json", "{\"prompt\":[1,2,}".to_string()),
            ("prompt not array", "{\"prompt\":\"abc\",\"variant\":\"dense\"}".to_string()),
            ("empty prompt", "{\"prompt\":[],\"variant\":\"dense\"}".to_string()),
            ("max_tokens zero", gen_body(&ok_prompt, "dense", 0, false)),
            (
                "token out of range",
                format!("{{\"prompt\":[{vocab}],\"variant\":\"dense\"}}"),
            ),
            ("negative token", "{\"prompt\":[-1],\"variant\":\"dense\"}".to_string()),
        ] {
            let r = client::post_json(addr, "/v1/generate", &body).unwrap();
            assert_status(name, &r, 400);
        }

        // Router's typed distinction: a variant that fails the grammar is
        // the client's mistake (400); a well-formed variant this server
        // simply doesn't run is 404.
        let r = client::post_json(addr, "/v1/generate", &gen_body(&ok_prompt, "bogus@0.5", 2, false))
            .unwrap();
        assert_status("malformed variant", &r, 400);
        assert!(r.body_str().contains("invalid variant"), "{}", r.body_str());
        let r = client::post_json(addr, "/v1/generate", &gen_body(&ok_prompt, "prune@0.3", 2, false))
            .unwrap();
        assert_status("unserved variant", &r, 404);
        assert!(r.body_str().contains("no lane serves"), "{}", r.body_str());
        // Explicit policy with no variant named at all.
        let r = client::post_json(addr, "/v1/generate", &format!("{{\"prompt\":{ok_prompt:?}}}"))
            .unwrap();
        assert_status("missing variant", &r, 400);

        // Unknown paths and methods.
        let r = client::get(addr, "/nope").unwrap();
        assert_status("unknown path", &r, 404);
        let r = client::request(addr, "DELETE", "/v1/generate", &[], b"").unwrap();
        assert_status("bad method", &r, 405);

        // Slowloris: dribble a few header bytes, then stall past the read
        // timeout. The server must answer 408 rather than hold the socket.
        {
            let mut s = TcpStream::connect(addr).unwrap();
            s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
            s.write_all(b"POST /v1/gen").unwrap();
            std::thread::sleep(Duration::from_millis(700));
            let mut buf = Vec::new();
            s.read_to_end(&mut buf).expect("read 408");
            let r = client::parse_response(&buf).unwrap();
            assert_status("slowloris", &r, 408);
        }

        // After the whole battery, a real request still serves end to end.
        let r = client::post_json(addr, "/v1/generate", &gen_body(&ok_prompt, "dense", 3, false))
            .unwrap();
        assert_eq!(r.status, 200, "listener must serve real work after the battery");
        assert_eq!(i32s(r.body_json().unwrap().expect("tokens")).len(), 3);
    });
    cleanup(&dir);
}

// ---------------------------------------------------------------------------
// Backpressure + drain
// ---------------------------------------------------------------------------

/// Saturating the admission queue yields 429 + Retry-After for the
/// overflow — while every admitted request still completes with its full
/// token stream (no hang, no dropped work).
#[test]
fn backpressure_rejects_with_429_without_dropping_admitted_work() {
    let (dir, man) = fixture("backpressure");
    let rt = Runtime::reference().unwrap();
    let model = man.model("ref-mamba").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let vocab = model.vocab_size;
    let plen = man.prefill_seq_len;
    let (engines, lane_names) = build_engines(&rt, &man, &w, &["dense"]);
    let cfg = HttpConfig { queue_cap: 1, ..HttpConfig::default() };
    const CLIENTS: usize = 6;

    let (admitted, report) = with_server(&engines, &lane_names, Policy::Explicit, cfg, |addr, _| {
        let barrier = std::sync::Barrier::new(CLIENTS);
        let results: Vec<(u16, Option<String>, usize)> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..CLIENTS)
                .map(|i| {
                    let barrier = &barrier;
                    let prompt = prompt_tokens(100 + i, plen / 2, vocab);
                    s.spawn(move || {
                        barrier.wait(); // fire simultaneously against queue_cap=1
                        let resp = client::post_json(
                            addr,
                            "/v1/generate",
                            &gen_body(&prompt, "dense", 8, true),
                        )
                        .expect("request");
                        let tokens = if resp.status == 200 {
                            client::sse_tokens(&resp.body).expect("stream intact").0.len()
                        } else {
                            0
                        };
                        (resp.status, resp.header("Retry-After").map(|v| v.to_string()), tokens)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });

        let ok = results.iter().filter(|(s, _, _)| *s == 200).count();
        let rejected = results.iter().filter(|(s, _, _)| *s == 429).count();
        assert_eq!(ok + rejected, CLIENTS, "unexpected statuses: {results:?}");
        assert!(ok >= 1, "at least one request must be admitted");
        assert!(rejected >= 1, "queue_cap=1 under {CLIENTS} simultaneous clients must 429");
        for (status, retry, tokens) in &results {
            match status {
                200 => assert_eq!(*tokens, 8, "admitted work lost part of its token stream"),
                429 => {
                    let retry = retry.as_deref().expect("429 must carry Retry-After");
                    assert!(retry.parse::<u64>().is_ok(), "Retry-After {retry:?} not numeric");
                }
                other => panic!("unexpected status {other}"),
            }
        }
        ok
    });
    assert!(report.rejected_429 >= 1);
    // Server-side accounting matches the client's view: exactly the
    // admitted requests completed, nothing was dropped.
    assert_eq!(report.metrics.completed as usize, admitted);
    assert_eq!(report.rejected_429 as usize, CLIENTS - admitted);
    cleanup(&dir);
}

/// Graceful drain mid-stream: once shutdown is raised, new work is turned
/// away with 503 + Retry-After, but the in-flight streamed request keeps
/// producing tokens and ends with a well-formed terminal chunk before its
/// socket closes.
#[test]
fn drain_completes_admitted_streams_and_rejects_new_work() {
    let (dir, man) = fixture("drain");
    let rt = Runtime::reference().unwrap();
    let model = man.model("ref-mamba").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let vocab = model.vocab_size;
    let plen = man.prefill_seq_len;
    let (engines, lane_names) = build_engines(&rt, &man, &w, &["dense"]);
    const GEN: usize = 48;

    let (_, report) =
        with_server(&engines, &lane_names, Policy::Explicit, HttpConfig::default(), |addr, shutdown| {
            // Open the long-running stream by hand so we can observe the
            // first token *before* raising shutdown.
            let prompt = prompt_tokens(7, plen / 2, vocab);
            let body = gen_body(&prompt, "dense", GEN, true);
            let mut s = TcpStream::connect(addr).unwrap();
            s.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
            s.write_all(
                format!(
                    "POST /v1/generate HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\
                     Content-Length: {}\r\n\r\n{body}",
                    body.len()
                )
                .as_bytes(),
            )
            .unwrap();
            let mut buf = Vec::new();
            let mut chunk = [0u8; 1024];
            while !buf.windows(5).any(|w| w == b"data:") {
                let n = s.read(&mut chunk).expect("stream read");
                assert!(n > 0, "stream closed before the first token");
                buf.extend_from_slice(&chunk[..n]);
            }

            // Mid-stream: drain. The very next request must be 503.
            shutdown.store(true, Ordering::SeqCst);
            let probe = client::post_json(addr, "/v1/generate", &gen_body(&prompt, "dense", 2, false))
                .expect("probe during drain");
            assert_eq!(probe.status, 503, "drain must reject new work: {}", probe.body_str());
            let retry = probe.header("Retry-After").expect("503 must carry Retry-After");
            assert!(retry.parse::<u64>().is_ok());
            let health = client::get(addr, "/healthz").expect("healthz during drain");
            assert!(health.body_str().contains("draining"), "{}", health.body_str());

            // The admitted stream still runs to completion: full token
            // count, done event, valid terminal framing.
            loop {
                match s.read(&mut chunk) {
                    Ok(0) => break,
                    Ok(n) => buf.extend_from_slice(&chunk[..n]),
                    Err(e) => panic!("stream broken during drain: {e}"),
                }
            }
            let resp = client::parse_response(&buf).expect("strict framing after drain");
            assert_eq!(resp.status, 200);
            let (tokens, done) = client::sse_tokens(&resp.body).expect("SSE intact");
            assert_eq!(tokens.len(), GEN, "drain truncated an admitted stream");
            assert!(done.is_some(), "drain dropped the final done event");
        });
    assert!(report.rejected_503 >= 1, "the drain probe must be counted");
    assert_eq!(report.metrics.completed, 1, "exactly the admitted stream completed");
    cleanup(&dir);
}

// ---------------------------------------------------------------------------
// Introspection endpoints
// ---------------------------------------------------------------------------

#[test]
fn healthz_and_stats_report_serving_state() {
    let (dir, man) = fixture("stats");
    let rt = Runtime::reference().unwrap();
    let model = man.model("ref-mamba").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let vocab = model.vocab_size;
    let plen = man.prefill_seq_len;
    let lanes = ["dense", "unified@0.2"];
    let (engines, lane_names) = build_engines(&rt, &man, &w, &lanes);

    let ((), _report) = with_server(&engines, &lane_names, Policy::Explicit, HttpConfig::default(), |addr, _| {
        let health = client::get(addr, "/healthz").unwrap();
        assert_eq!(health.status, 200);
        let h = health.body_json().unwrap();
        assert_eq!(h.expect("status").as_str(), Some("ok"));
        let listed: Vec<&str> = match h.expect("lanes") {
            tor_ssm::util::json::Json::Arr(xs) => xs.iter().filter_map(|x| x.as_str()).collect(),
            _ => panic!("lanes not an array"),
        };
        assert_eq!(listed, lanes);

        for lane in &lanes {
            let prompt = prompt_tokens(11, plen / 2, vocab);
            let r = client::post_json(addr, "/v1/generate", &gen_body(&prompt, lane, 2, false))
                .unwrap();
            assert_eq!(r.status, 200, "{}", r.body_str());
        }
        // The stats document refreshes from inside the scheduler loop;
        // give it a beat after the last completion.
        std::thread::sleep(Duration::from_millis(50));
        let stats = client::get(addr, "/stats").unwrap();
        assert_eq!(stats.status, 200);
        let j = stats.body_json().unwrap();
        assert_eq!(j.expect("completed").as_usize(), Some(2));
        assert_eq!(j.expect("draining").as_bool(), Some(false));
        assert!(j.expect("gen_tok_s").as_f64().unwrap() > 0.0);
        match j.expect("lanes") {
            tor_ssm::util::json::Json::Arr(xs) => {
                assert_eq!(xs.len(), lanes.len());
                for lane_stats in xs {
                    assert!(lane_stats.get("decode_steps").is_some());
                    assert!(lane_stats.get("cache").is_some(), "CacheStats must be exported");
                }
            }
            _ => panic!("stats.lanes not an array"),
        }
    });
    cleanup(&dir);
}

/// Regression: `/stats` counters are snapshotted under one seqlock read,
/// so `admitted == completed + failed + in_flight` holds in EVERY
/// response — including ones raced against a burst of concurrent
/// generations. The pre-seqlock implementation read each counter
/// independently and could observe a completion without its admission.
#[test]
fn stats_counters_stay_consistent_under_concurrent_burst() {
    let (dir, man) = fixture("hammer");
    let rt = Runtime::reference().unwrap();
    let model = man.model("ref-mamba").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let vocab = model.vocab_size;
    let plen = man.prefill_seq_len;
    let (engines, lane_names) = build_engines(&rt, &man, &w, &["dense"]);
    const BURST: usize = 16;

    let ((), _report) =
        with_server(&engines, &lane_names, Policy::Explicit, HttpConfig::default(), |addr, _| {
            std::thread::scope(|s| {
                let workers: Vec<_> = (0..4)
                    .map(|t| {
                        s.spawn(move || {
                            for i in 0..BURST / 4 {
                                let prompt = prompt_tokens(t * 31 + i, plen / 2, vocab);
                                let r = client::post_json(
                                    addr,
                                    "/v1/generate",
                                    &gen_body(&prompt, "dense", 6, false),
                                )
                                .unwrap();
                                assert_eq!(r.status, 200, "{}", r.body_str());
                            }
                        })
                    })
                    .collect();

                // Hammer /stats for the whole burst: the identity must
                // hold in every single document.
                let mut polls = 0u32;
                while workers.iter().any(|w| !w.is_finished()) || polls < 8 {
                    let doc = client::get(addr, "/stats").unwrap().body_json().unwrap();
                    let admitted = doc.expect("admitted").as_usize().unwrap();
                    let completed = doc.expect("completed").as_usize().unwrap();
                    let failed = doc.expect("failed").as_usize().unwrap();
                    let in_flight = doc.expect("in_flight").as_usize().unwrap();
                    assert_eq!(
                        admitted,
                        completed + failed + in_flight,
                        "torn counter snapshot at poll {polls}"
                    );
                    polls += 1;
                }
                for w in workers {
                    w.join().unwrap();
                }

                // Settled: everything admitted completed; nothing failed.
                let doc = client::get(addr, "/stats").unwrap().body_json().unwrap();
                assert_eq!(doc.expect("admitted").as_usize(), Some(BURST));
                assert_eq!(doc.expect("completed").as_usize(), Some(BURST));
                assert_eq!(doc.expect("failed").as_usize(), Some(0));
                assert_eq!(doc.expect("in_flight").as_usize(), Some(0));
            });
        });
    cleanup(&dir);
}
