//! Property tests for the schedule solver, in the style of
//! `prop_coordinator.rs` (seeded random-case runner with failure-seed
//! reporting): random `ModelDims` and location sets, broader than the
//! coordinator suite's solver property — it also randomises expand/vocab
//! geometry, covers the dense/degenerate paths, and pins the
//! tolerance-or-error contract.
//!
//! Invariants, for every feasible solve:
//! * `seg_lens` has exactly `locations.len() + 1` entries, starts at
//!   `seq_len`, is monotone non-increasing, and every post-reduction
//!   segment length is even;
//! * `removed[i] == seg_lens[i] - seg_lens[i+1]` and never exceeds half the
//!   incoming segment (the M_A-set limit);
//! * the achieved FLOPs reduction lands within the 0.05 tolerance of the
//!   target — or `solve_schedule` returns an error (never a silently-bad
//!   plan);
//! * `final_len`/`len_at_layer` agree with the segment structure.

use tor_ssm::reduction::{solve_schedule, total_flops, Arch, ModelDims};
use tor_ssm::util::rng::Rng;

const CASES: u64 = 300;

fn for_cases(name: &str, mut f: impl FnMut(&mut Rng)) {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            panic!("property {name} failed at seed {seed}: {e:?}");
        }
    }
}

fn random_dims(rng: &mut Rng) -> ModelDims {
    let arch = if rng.f64() < 0.5 { Arch::Mamba } else { Arch::Mamba2 };
    ModelDims {
        name: "prop-schedule".into(),
        arch,
        vocab_size: 256 + rng.below(8192),
        d_model: 64 * (1 + rng.below(10)),
        n_layer: 8 + rng.below(56),
        d_state: 8 * (1 + rng.below(16)),
        expand: 1 + rng.below(2),
        d_conv: 4,
        headdim: 64,
        chunk: 64 * (1 + rng.below(4)),
    }
}

#[test]
fn prop_solver_invariants_and_tolerance() {
    for_cases("solver", |rng| {
        let dims = random_dims(rng);
        let seq_len = 32 * (1 + rng.below(64));
        let start = 2 + rng.below(dims.n_layer / 2);
        let stride = 2 + rng.below(5);
        let k = 1 + rng.below(6);
        let locations: Vec<usize> = (0..k)
            .map(|i| start + stride * i)
            .filter(|&l| l < dims.n_layer)
            .collect();
        if locations.is_empty() {
            return;
        }
        let target = 0.05 + rng.f64() * 0.30;

        let plan = match solve_schedule(&dims, seq_len, &locations, target) {
            Ok(p) => p,
            // The error path IS the contract for infeasible targets: the
            // solver must refuse rather than return an off-target plan.
            Err(_) => return,
        };

        assert_eq!(plan.seq_len, seq_len);
        assert_eq!(plan.locations, locations);
        assert_eq!(plan.seg_lens.len(), locations.len() + 1, "one segment per site + entry");
        assert_eq!(plan.seg_lens[0], seq_len, "first segment sees the full sequence");
        for w in plan.seg_lens.windows(2) {
            assert!(w[1] <= w[0], "seg lens must not grow: {:?}", plan.seg_lens);
            assert_eq!(w[1] % 2, 0, "post-reduction lens must be even: {:?}", plan.seg_lens);
        }
        assert_eq!(plan.removed.len(), locations.len());
        for (i, &r) in plan.removed.iter().enumerate() {
            assert_eq!(plan.seg_lens[i] - plan.seg_lens[i + 1], r, "removed bookkeeping");
            assert!(
                r <= plan.seg_lens[i] / 2,
                "half-removal limit violated: removed {r} of {}",
                plan.seg_lens[i]
            );
        }
        assert!(
            (plan.flops_reduction - target).abs() <= 0.05,
            "solver returned an off-target plan: achieved {} for target {target}",
            plan.flops_reduction
        );

        // len_at_layer is consistent with the segment structure + total
        // FLOPs recomputed from it matches the plan's achieved reduction.
        assert_eq!(plan.final_len(), *plan.seg_lens.last().unwrap());
        assert_eq!(plan.len_at_layer(0), seq_len);
        let last_layer = dims.n_layer - 1;
        if let Some(&last_loc) = locations.last() {
            if last_layer > last_loc {
                assert_eq!(plan.len_at_layer(last_layer), plan.final_len());
            }
        }
        let dense_lens = vec![seq_len; locations.len() + 1];
        let dense = total_flops(&dims, &locations, &dense_lens);
        let got = total_flops(&dims, &locations, &plan.seg_lens);
        let recomputed = 1.0 - got / dense;
        assert!(
            (recomputed - plan.flops_reduction).abs() < 1e-12,
            "plan's achieved ratio must match its own seg_lens"
        );
    });
}

#[test]
fn prop_dense_and_degenerate_paths() {
    for_cases("dense-degenerate", |rng| {
        let dims = random_dims(rng);
        let seq_len = 32 * (1 + rng.below(32));

        // Zero target or no locations → identity plan.
        let dense = solve_schedule(&dims, seq_len, &[], 0.0).unwrap();
        assert_eq!(dense.seg_lens, vec![seq_len]);
        assert_eq!(dense.flops_reduction, 0.0);
        assert!(dense.removed.is_empty());

        let no_sites = solve_schedule(&dims, seq_len, &[], 0.25).unwrap();
        assert_eq!(no_sites.seg_lens, vec![seq_len], "no sites → nothing to remove");

        // seq_len = 0 always errors, whatever the rest of the input.
        let locs = [2 + rng.below(dims.n_layer - 2)];
        assert!(solve_schedule(&dims, 0, &locs, 0.2).is_err());
        assert!(solve_schedule(&dims, 0, &[], 0.0).is_err());

        // Out-of-range locations always error.
        assert!(solve_schedule(&dims, seq_len, &[dims.n_layer], 0.2).is_err());
    });
}
