//! Integration tests over real artifacts (require `make artifacts`, or at
//! least `make artifacts-quick`). Each test that needs artifacts skips
//! gracefully when they are absent so `cargo test` works in any state.
//! The execution tests additionally need a backend that can run real AOT
//! exports (the pjrt feature + extension); on the default reference backend
//! they skip when weight binding rejects the AOT param layout.
//!
//! The hermetic (artifact-free) suite lives in `tests/fixtures.rs`.

use tor_ssm::data::{check_tasks_closed, load_tasks, Corpus};
use tor_ssm::manifest::Manifest;
use tor_ssm::reduction::{solve_schedule, ModelDims};
use tor_ssm::runtime::{HostTensor, Runtime, Weights};
use tor_ssm::tokenizer::Tokenizer;

fn manifest() -> Option<Manifest> {
    Manifest::load(tor_ssm::artifacts_dir()).ok()
}

macro_rules! need {
    ($e:expr) => {
        match $e {
            Some(v) => v,
            None => {
                eprintln!("SKIP: artifacts missing (run `make artifacts`)");
                return;
            }
        }
    };
}

#[test]
fn manifest_loads_and_is_consistent() {
    let man = need!(manifest());
    assert!(!man.models.is_empty());
    for (name, m) in &man.models {
        assert_eq!(name, &m.name);
        // Param metadata must be contiguous and non-overlapping.
        let mut expect_offset = 0usize;
        for p in &m.params {
            assert_eq!(p.offset, expect_offset, "{name}:{} offset", p.name);
            assert_eq!(p.bytes, p.shape.iter().product::<usize>() * 4);
            expect_offset += p.bytes;
        }
        // Every model exports the core variants.
        assert!(m.hlo.contains_key("dense"), "{name} missing dense");
        assert!(m.hlo.contains_key("decode_step"));
        assert!(m.hlo.contains_key("train_step"));
        assert!(m.find_eval("utrc", 0.20, None, None, None, None).is_ok());
    }
}

#[test]
fn vocab_and_tasks_are_closed() {
    let man = need!(manifest());
    let tok = Tokenizer::load(man.path(&man.vocab_file)).unwrap();
    assert!(tok.len() >= 100);
    let tasks = load_tasks(man.path(&man.tasks_file)).unwrap();
    assert_eq!(tasks.len(), 6);
    for t in &tasks {
        assert!(!t.items.is_empty(), "{} empty", t.name);
        for it in &t.items {
            assert!(it.answer < it.choices.len().max(1));
        }
    }
    check_tasks_closed(&tasks, &tok).unwrap();
}

#[test]
fn corpus_tokens_in_vocab() {
    let man = need!(manifest());
    let tok = Tokenizer::load(man.path(&man.vocab_file)).unwrap();
    let corpus = Corpus::load(man.path(&man.train_file)).unwrap();
    assert!(corpus.tokens.len() > 10_000);
    corpus.validate(tok.len()).unwrap();
}

#[test]
fn schedule_plans_match_python_exports() {
    // The rust solver must re-derive exactly the seg_lens/removed that
    // python baked into every exported plan (the two implementations are
    // mirrors; this is the cross-language lockstep test).
    let man = need!(manifest());
    for m in man.models.values() {
        let dims = ModelDims::from_manifest(m);
        for e in m.hlo.values() {
            let (Some(r), Some(plan)) = (&e.reduction, &e.plan) else { continue };
            let ours = solve_schedule(&dims, plan.seq_len, &r.locations, r.flops_reduction)
                .unwrap_or_else(|err| panic!("{}/{}: {err:#}", m.name, e.tag));
            assert_eq!(ours.seg_lens, plan.seg_lens, "{}/{} seg_lens", m.name, e.tag);
            assert_eq!(ours.removed, plan.removed, "{}/{} removed", m.name, e.tag);
            assert!(
                (ours.flops_reduction - plan.flops_reduction).abs() < 1e-9,
                "{}/{} achieved ratio: rust {} vs python {}",
                m.name,
                e.tag,
                ours.flops_reduction,
                plan.flops_reduction
            );
        }
    }
}

#[test]
fn param_count_matches_dims_model() {
    let man = need!(manifest());
    for m in man.models.values() {
        let dims = ModelDims::from_manifest(m);
        assert_eq!(
            dims.param_bytes(),
            m.param_count * 4,
            "{}: rust param model vs python param_count",
            m.name
        );
    }
}

#[test]
fn golden_numerics_cross_check() {
    let man = need!(manifest());
    let rt = Runtime::cpu().unwrap();
    // The golden fixture pins AOT numerics; it is only meaningful on a
    // backend that executes the AOT exports.
    if rt.upload_weights(
        man.model("mamba-small").unwrap(),
        &Weights::load_init(&man, man.model("mamba-small").unwrap()).unwrap(),
    )
    .is_err()
    {
        eprintln!("SKIP: default backend cannot execute AOT artifacts (build with --features pjrt)");
        return;
    }
    let report = tor_ssm::bench::harness::golden_check(&rt, &man).unwrap();
    assert!(report.contains("golden OK"), "{report}");
}

#[test]
fn reduced_forward_shapes_and_kept_map() {
    // Execute a reduced variant and verify the kept-index contract:
    // ascending original positions, count == out_len < seq_len.
    let man = need!(manifest());
    let rt = Runtime::cpu().unwrap();
    let model = man.model("mamba-small").unwrap().clone();
    let entry = model.find_eval("utrc", 0.20, None, None, None, None).unwrap().clone();
    assert!(entry.out_len < entry.seq_len);

    let w = Weights::load_init(&man, &model).unwrap();
    let Ok(dw) = rt.upload_weights(&model, &w) else {
        eprintln!("SKIP: default backend cannot execute AOT artifacts (build with --features pjrt)");
        return;
    };
    let exe = rt.load_entry(&man, &model, &entry).unwrap();
    let tokens: Vec<i32> = (0..entry.batch * entry.seq_len)
        .map(|i| ((i * 13 + 5) % model.vocab_size) as i32)
        .collect();
    let tok = HostTensor::i32(vec![entry.batch, entry.seq_len], tokens);
    let outs = exe.execute(&dw, &[tok]).unwrap();

    assert_eq!(outs[0].shape, vec![entry.batch, entry.out_len, model.vocab_size]);
    assert_eq!(outs[1].shape, vec![entry.batch, entry.out_len]);
    let kept = outs[1].as_i32().unwrap();
    for b in 0..entry.batch {
        let row = &kept[b * entry.out_len..(b + 1) * entry.out_len];
        for wdw in row.windows(2) {
            assert!(wdw[0] < wdw[1], "kept not strictly ascending: {wdw:?}");
        }
        assert!(*row.last().unwrap() < entry.seq_len as i32);
        assert!(row[0] >= 0);
    }
    // Logits must be finite.
    let lg = outs[0].as_f32().unwrap();
    assert!(lg.iter().all(|x| x.is_finite()));
}

#[test]
fn dense_and_reduced_agree_on_prefix() {
    // The dense run's kept map must be the identity (no position removed).
    let man = need!(manifest());
    let rt = Runtime::cpu().unwrap();
    let model = man.model("mamba-small").unwrap().clone();
    let entry = model.find_eval("dense", 0.0, None, None, None, None).unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let Ok(dw) = rt.upload_weights(&model, &w) else {
        eprintln!("SKIP: default backend cannot execute AOT artifacts (build with --features pjrt)");
        return;
    };
    let exe = rt.load_entry(&man, &model, &entry).unwrap();
    let tokens: Vec<i32> = vec![7; entry.batch * entry.seq_len];
    let tok = HostTensor::i32(vec![entry.batch, entry.seq_len], tokens);
    let outs = exe.execute(&dw, &[tok]).unwrap();
    let kept = outs[1].as_i32().unwrap();
    for b in 0..entry.batch {
        for i in 0..entry.seq_len {
            assert_eq!(kept[b * entry.seq_len + i], i as i32);
        }
    }
}

#[test]
fn weights_roundtrip_through_save() {
    let man = need!(manifest());
    let model = man.model("mamba-small").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let tmp = std::env::temp_dir().join("tor_ssm_test_weights.bin");
    w.save(&model, &tmp).unwrap();
    let bytes = std::fs::read(&tmp).unwrap();
    let w2 = Weights::from_bytes(&model, &bytes).unwrap();
    for (a, b) in w.tensors.iter().zip(&w2.tensors) {
        assert_eq!(a, b);
    }
    std::fs::remove_file(&tmp).ok();
}
