//! Hermetic integration tests: the synthetic-fixture generator plus the
//! pure-Rust reference backend, end to end — no `artifacts/` directory, no
//! Python, no XLA. This is the suite that keeps tier-1 green from a clean
//! checkout.
//!
//! Covered here:
//! * fixture generation round-trips through the ordinary Manifest /
//!   Tokenizer / Corpus / Weights loaders and honours their contracts;
//! * the reference backend's eval programs honour the kept-map contract
//!   (dense = identity; reduced = strictly ascending, `out_len` survivors);
//! * the serving coordinator (router → batcher → engine) runs its
//!   prefill → decode loop end to end on the reference backend;
//! * the zero-shot eval harness produces six task results hermetically;
//! * decode is deterministic and consumes exactly the states prefill
//!   produced;
//! * the reference backend rejects the (pjrt-only) train step loudly.

use std::path::PathBuf;
use std::time::Duration;

use tor_ssm::coordinator::batcher::Batcher;
use tor_ssm::coordinator::engine::Engine;
use tor_ssm::coordinator::router::{Policy, Router};
use tor_ssm::coordinator::Request;
use tor_ssm::data::{check_tasks_closed, load_tasks, Corpus};
use tor_ssm::fixtures::{generate_default, FixtureSpec};
use tor_ssm::manifest::Manifest;
use tor_ssm::runtime::{HostTensor, Runtime, Weights};
use tor_ssm::tokenizer::Tokenizer;

/// Unique per-test fixture dir (tests run in parallel threads).
fn fixture(tag: &str) -> (PathBuf, Manifest) {
    let dir = std::env::temp_dir().join(format!("tor-ssm-test-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let man = generate_default(&dir).expect("fixture generation");
    (dir, man)
}

fn cleanup(dir: &PathBuf) {
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn fixture_roundtrips_through_loaders() {
    let (dir, man) = fixture("roundtrip");
    assert_eq!(man.models.len(), 2, "fixture exports two substrates");

    let tok = Tokenizer::load(man.path(&man.vocab_file)).unwrap();
    assert!(tok.len() >= 100);
    let tasks = load_tasks(man.path(&man.tasks_file)).unwrap();
    assert_eq!(tasks.len(), 6);
    for t in &tasks {
        assert!(!t.items.is_empty(), "{} empty", t.name);
        for it in &t.items {
            assert!(it.answer < it.choices.len().max(1));
        }
    }
    check_tasks_closed(&tasks, &tok).unwrap();

    let corpus = Corpus::load(man.path(&man.train_file)).unwrap();
    corpus.validate(tok.len()).unwrap();

    for (name, m) in &man.models {
        assert_eq!(name, &m.name);
        // Param metadata contiguous + weights blob loadable.
        let mut expect_offset = 0usize;
        for p in &m.params {
            assert_eq!(p.offset, expect_offset, "{name}:{} offset", p.name);
            assert_eq!(p.bytes, p.shape.iter().product::<usize>() * 4);
            expect_offset += p.bytes;
        }
        let w = Weights::load_init(&man, m).unwrap();
        assert_eq!(w.tensors.len(), m.params.len());
        // Every model exports the core variants.
        assert!(m.hlo.contains_key("dense"), "{name} missing dense");
        assert!(m.hlo.contains_key("decode_step"));
        assert!(m.hlo.contains_key("train_step"));
        assert!(m.find_eval("utrc", 0.20, None, None, None, None).is_ok());
        assert!(m.prefill_entry("dense", 0.0).is_ok());
        assert!(m.prefill_entry("utrc", 0.20).is_ok());
        // Prefill entries are length-aware (DESIGN.md §6): the serving
        // engine relies on the manifest flag to enable true-length prefill
        // and chunking; eval/decode entries stay fixed-arity.
        assert!(m.prefill_entry("dense", 0.0).unwrap().takes_lengths);
        assert!(m.prefill_entry("utrc", 0.20).unwrap().takes_lengths);
        assert!(!m.decode_entry().unwrap().takes_lengths);
        assert!(!m.find_eval("dense", 0.0, None, None, None, None).unwrap().takes_lengths);
    }
    cleanup(&dir);
}

#[test]
fn reference_eval_honours_kept_contract() {
    let (dir, man) = fixture("kept");
    let rt = Runtime::reference().unwrap();
    for model_name in ["ref-mamba", "ref-mamba2"] {
        let model = man.model(model_name).unwrap().clone();
        let w = Weights::load_init(&man, &model).unwrap();
        let dw = rt.upload_weights(&model, &w).unwrap();

        // Dense: kept is the identity, logits full-length and finite.
        let dense = model.find_eval("dense", 0.0, None, None, None, None).unwrap().clone();
        let tokens: Vec<i32> = (0..dense.batch * dense.seq_len)
            .map(|i| ((i * 13 + 5) % model.vocab_size) as i32)
            .collect();
        let tok = HostTensor::i32(vec![dense.batch, dense.seq_len], tokens);
        let exe = rt.load_entry(&man, &model, &dense).unwrap();
        let outs = exe.execute(&dw, &[tok.clone()]).unwrap();
        assert_eq!(outs[0].shape, vec![dense.batch, dense.seq_len, model.vocab_size]);
        let kept = outs[1].as_i32().unwrap();
        for b in 0..dense.batch {
            for i in 0..dense.seq_len {
                assert_eq!(kept[b * dense.seq_len + i], i as i32, "{model_name} dense kept");
            }
        }
        assert!(outs[0].as_f32().unwrap().iter().all(|x| x.is_finite()));

        // Reduced: out_len < seq_len survivors, strictly ascending positions.
        let red = model.find_eval("utrc", 0.20, None, None, None, None).unwrap().clone();
        assert!(red.out_len < red.seq_len, "{model_name} utrc out_len");
        let exe = rt.load_entry(&man, &model, &red).unwrap();
        let outs = exe.execute(&dw, &[tok]).unwrap();
        assert_eq!(outs[0].shape, vec![red.batch, red.out_len, model.vocab_size]);
        assert_eq!(outs[1].shape, vec![red.batch, red.out_len]);
        let kept = outs[1].as_i32().unwrap();
        for b in 0..red.batch {
            let row = &kept[b * red.out_len..(b + 1) * red.out_len];
            assert!(row[0] >= 0);
            for w2 in row.windows(2) {
                assert!(w2[0] < w2[1], "{model_name} kept not ascending: {w2:?}");
            }
            assert!(*row.last().unwrap() < red.seq_len as i32);
        }
        assert!(outs[0].as_f32().unwrap().iter().all(|x| x.is_finite()));
    }
    cleanup(&dir);
}

#[test]
fn coordinator_prefill_decode_loop_end_to_end() {
    // The acceptance path: router → batcher → engine prefill → decode loop,
    // entirely on the reference backend, from a clean checkout.
    let (dir, man) = fixture("e2e");
    let rt = Runtime::reference().unwrap();
    let model = man.model("ref-mamba").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();

    let lanes = ["dense", "utrc@0.2"];
    let engines: Vec<Engine> = lanes
        .iter()
        .map(|v| Engine::new(&rt, &man, &model, &w, v).unwrap())
        .collect();
    let mut router = Router::new(Policy::CostAware { long_prompt: man.prefill_seq_len / 2 }, &lanes);
    let mut batchers: Vec<Batcher> =
        engines.iter().map(|e| Batcher::new(e.batch, Duration::from_millis(0))).collect();

    let gen_tokens: usize = 4;
    let n_requests: usize = 5;
    let mut served = 0usize;
    for i in 0..n_requests {
        // Mixed prompt lengths so the cost-aware router uses both lanes.
        let plen = if i % 2 == 0 { man.prefill_seq_len } else { man.prefill_seq_len / 4 };
        let prompt: Vec<i32> = (0..plen).map(|t| ((t * 7 + i) % model.vocab_size) as i32).collect();
        let req = Request {
            id: i as u64,
            prompt,
            gen_tokens,
            variant: String::new(),
            arrived_us: 0,
            priority: Default::default(),
        };
        let lane = router.route(&req).unwrap();
        let li = lanes.iter().position(|l| *l == lane).unwrap();
        router.note_enqueued(&lane);
        batchers[li].push(req);
        for (bi, b) in batchers.iter_mut().enumerate() {
            while let Some(batch) = b.poll(std::time::Instant::now()) {
                let responses = engines[bi].serve_batch(&batch).unwrap();
                assert_eq!(responses.len(), batch.len());
                for (req, resp) in batch.iter().zip(&responses) {
                    assert_eq!(resp.id, req.id);
                    assert_eq!(resp.generated.len(), gen_tokens, "full generation");
                    for &t in &resp.generated {
                        assert!(t >= 0 && (t as usize) < model.vocab_size);
                    }
                    assert_eq!(resp.variant, lanes[bi]);
                    router.note_done(&lanes[bi]);
                    served += 1;
                }
            }
        }
    }
    for (bi, b) in batchers.iter_mut().enumerate() {
        for batch in b.drain() {
            let responses = engines[bi].serve_batch(&batch).unwrap();
            for resp in &responses {
                assert_eq!(resp.generated.len(), gen_tokens);
                router.note_done(&lanes[bi]);
                served += 1;
            }
        }
    }
    assert_eq!(served, n_requests, "every request served exactly once");
    // Both lanes drained back to empty.
    for lane in &lanes {
        assert_eq!(router.depth(lane), 0);
    }
    cleanup(&dir);
}

#[test]
fn eval_harness_runs_hermetically() {
    let (dir, _man) = fixture("eval");
    let items = 2;
    let mut ctx = tor_ssm::bench::Ctx::new(&dir.to_string_lossy(), items, true).unwrap();
    for (method, ratio) in [("dense", 0.0), ("utrc", 0.20)] {
        let e = ctx
            .find_eval_entry("ref-mamba", method, ratio, None, None, None, None)
            .unwrap();
        let r = ctx.eval_variant("ref-mamba", &e).unwrap();
        assert_eq!(r.tasks.len(), 6);
        assert!(r.sequences > 0);
        for t in &r.tasks {
            assert!(t.n_items > 0 && t.n_items <= items);
            assert!((0.0..=1.0).contains(&t.acc_truncated), "{method} {}", t.name);
            assert!((0.0..=1.0).contains(&t.acc_aligned));
        }
        // s-lambada reports a finite perplexity.
        let ppl = r.lambada_ppl(tor_ssm::eval::scoring::Scheme::Truncated);
        assert!(ppl.is_finite() && ppl > 0.0, "{method} ppl = {ppl}");
    }
    cleanup(&dir);
}

#[test]
fn decode_is_deterministic_and_continues_prefill() {
    let (dir, man) = fixture("decode");
    let rt = Runtime::reference().unwrap();
    let model = man.model("ref-mamba2").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let dw = rt.upload_weights(&model, &w).unwrap();

    let pf = model.prefill_entry("dense", 0.0).unwrap().clone();
    let dec = model.decode_entry().unwrap().clone();
    let prefill = rt.load_entry(&man, &model, &pf).unwrap();
    let decode = rt.load_entry(&man, &model, &dec).unwrap();

    let tokens: Vec<i32> = (0..pf.batch * pf.seq_len)
        .map(|i| ((i * 11 + 3) % model.vocab_size) as i32)
        .collect();
    let tok = HostTensor::i32(vec![pf.batch, pf.seq_len], tokens);
    let outs = prefill.execute(&dw, &[tok]).unwrap();
    assert_eq!(outs.len(), 3, "prefill returns (logits, conv, ssm)");
    let (logits, conv, ssm) = (&outs[0], &outs[1], &outs[2]);
    assert_eq!(logits.shape, vec![pf.batch, model.vocab_size]);
    // States are non-trivial after a real prompt.
    assert!(ssm.as_f32().unwrap().iter().any(|&x| x != 0.0), "ssm state all zero");

    let step_tok = HostTensor::i32(vec![pf.batch], vec![9; pf.batch]);
    let a = decode
        .execute(&dw, &[step_tok.clone(), conv.clone(), ssm.clone()])
        .unwrap();
    let b = decode
        .execute(&dw, &[step_tok, conv.clone(), ssm.clone()])
        .unwrap();
    assert_eq!(a.len(), 3);
    // Deterministic: identical inputs → identical outputs.
    assert_eq!(a[0], b[0]);
    assert_eq!(a[1], b[1]);
    assert_eq!(a[2], b[2]);
    // State evolves: the new ssm differs from the input ssm.
    assert_ne!(a[2].as_f32().unwrap(), ssm.as_f32().unwrap());
    // Shapes preserved for the next step.
    assert_eq!(a[1].shape, conv.shape);
    assert_eq!(a[2].shape, ssm.shape);
    cleanup(&dir);
}

#[test]
fn reference_backend_rejects_train_step() {
    let (dir, man) = fixture("train");
    let rt = Runtime::reference().unwrap();
    let model = man.model("ref-mamba").unwrap().clone();
    let err = tor_ssm::train::train(&rt, &man, &model, 1, 1, 0).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("pjrt"), "error should point at the pjrt backend: {msg}");
    cleanup(&dir);
}

#[test]
fn fixture_spec_is_deterministic() {
    // Same seed → byte-identical weight blobs (the whole hermetic suite
    // depends on this reproducibility).
    let dir_a = std::env::temp_dir().join(format!("tor-ssm-det-a-{}", std::process::id()));
    let dir_b = std::env::temp_dir().join(format!("tor-ssm-det-b-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
    let spec = FixtureSpec::default();
    tor_ssm::fixtures::generate(&dir_a, &spec).unwrap();
    tor_ssm::fixtures::generate(&dir_b, &spec).unwrap();
    for file in ["manifest.json", "init_ref-mamba.bin", "train.bin", "tasks.json"] {
        let a = std::fs::read(dir_a.join(file)).unwrap();
        let b = std::fs::read(dir_b.join(file)).unwrap();
        assert_eq!(a, b, "{file} not deterministic");
    }
    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
}
