//! Property-based tests for coordinator invariants (proptest substitute:
//! seeded random-case runner with failure-seed reporting).
//!
//! Invariants covered (DESIGN.md §7):
//! * batcher: no loss, no duplication, FIFO order, capacity bound, deadline;
//! * state pool: never exceeds capacity, alloc/free balanced, no double-free
//!   acceptance, high-water correctness;
//! * state store: slot-backed tensors survive arbitrary admit/retire churn
//!   uncorrupted — no leaks, no double-frees, no cross-slot bleed;
//! * router: always routes to a known lane; cost-aware respects thresholds;
//! * schedule solver: hits targets, monotone/even seg_lens, half-limit;
//! * JSON: parse∘serialize is identity on random documents.

use std::time::Duration;

use tor_ssm::coordinator::batcher::Batcher;
use tor_ssm::coordinator::router::{Policy, Router};
use tor_ssm::coordinator::state_pool::StatePool;
use tor_ssm::coordinator::state_store::StateStore;
use tor_ssm::coordinator::Request;
use tor_ssm::reduction::{solve_schedule, Arch, ModelDims};
use tor_ssm::util::json::Json;
use tor_ssm::util::rng::Rng;

const CASES: u64 = 200;

fn for_cases(name: &str, mut f: impl FnMut(&mut Rng)) {
    for seed in 0..CASES {
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            panic!("property {name} failed at seed {seed}: {e:?}");
        }
    }
}

fn req(id: u64, plen: usize) -> Request {
    Request {
        id,
        prompt: vec![0; plen],
        gen_tokens: 1,
        variant: String::new(),
        arrived_us: 0,
        priority: Default::default(),
    }
}

#[test]
fn prop_batcher_no_loss_no_dup_fifo() {
    for_cases("batcher", |rng| {
        let cap = 1 + rng.below(16);
        let n = rng.below(200);
        let mut b = Batcher::new(cap, Duration::from_millis(0));
        let mut out = Vec::new();
        for i in 0..n as u64 {
            b.push(req(i, 4));
            if rng.f64() < 0.5 {
                while let Some(batch) = b.poll(std::time::Instant::now()) {
                    assert!(batch.len() <= cap, "capacity violated");
                    out.extend(batch.into_iter().map(|r| r.id));
                }
            }
        }
        for batch in b.drain() {
            assert!(batch.len() <= cap);
            out.extend(batch.into_iter().map(|r| r.id));
        }
        // FIFO + exactly-once.
        assert_eq!(out.len(), n);
        for (i, id) in out.iter().enumerate() {
            assert_eq!(*id, i as u64, "order broken");
        }
        assert_eq!(b.enqueued, n as u64);
        assert_eq!(b.dispatched, n as u64);
    });
}

#[test]
fn prop_batcher_deadline_flush() {
    for_cases("batcher_deadline", |rng| {
        let cap = 2 + rng.below(8);
        let wait = Duration::from_millis(rng.below(20) as u64);
        let mut b = Batcher::new(cap, wait);
        let t_push = std::time::Instant::now();
        b.push(req(0, 4));
        // A poll before the deadline must NOT flush a partial batch; one
        // at/after the deadline must. (If `wait` already elapsed between
        // push and poll, flushing is correct.)
        let first = b.poll(std::time::Instant::now());
        if let Some(batch) = first {
            assert!(t_push.elapsed() >= wait, "flushed early");
            assert_eq!(batch.len(), 1);
        } else {
            let later = std::time::Instant::now() + wait + Duration::from_millis(1);
            assert!(b.poll(later).is_some(), "deadline flush missed");
        }
    });
}

#[test]
fn prop_state_pool_invariants() {
    for_cases("state_pool", |rng| {
        let cap = 1 + rng.below(32);
        let mut p = StatePool::new(cap, 64);
        let mut live = Vec::new();
        let mut peak = 0usize;
        for _ in 0..500 {
            if rng.f64() < 0.55 {
                match p.alloc() {
                    Ok(s) => {
                        assert!(live.len() < cap, "alloc past capacity");
                        live.push(s);
                        peak = peak.max(live.len());
                    }
                    Err(_) => assert_eq!(live.len(), cap, "spurious exhaustion"),
                }
            } else if let Some(i) = (!live.is_empty()).then(|| rng.below(live.len())) {
                let s = live.swap_remove(i);
                p.release(s).unwrap();
                // releasing again must fail
                assert!(p.release(s).is_err());
            }
            assert_eq!(p.live(), live.len());
        }
        assert_eq!(p.high_water, peak);
    });
}

#[test]
fn prop_state_store_no_leak_no_double_free_no_corruption() {
    for_cases("state_store", |rng| {
        let cap = 1 + rng.below(8);
        let n_layer = 1 + rng.below(3);
        let conv_row = 1 + rng.below(6);
        let ssm_row = 1 + rng.below(6);
        let mut store = StateStore::new(cap, n_layer, conv_row, ssm_row);
        // Each live slot remembers the unique tag it was admitted with so
        // recycling can never silently corrupt a neighbour.
        let mut live: Vec<(tor_ssm::coordinator::state_pool::Slot, f32)> = Vec::new();
        let mut next_tag = 1.0f32;
        for _ in 0..300 {
            if rng.f64() < 0.55 {
                let conv = vec![next_tag; n_layer * conv_row];
                let ssm = vec![-next_tag; n_layer * ssm_row];
                match store.admit(&conv, &ssm) {
                    Ok(slot) => {
                        assert!(live.len() < cap, "admitted past capacity");
                        live.push((slot, next_tag));
                        next_tag += 1.0;
                    }
                    Err(_) => assert_eq!(live.len(), cap, "spurious exhaustion"),
                }
            } else if !live.is_empty() {
                let i = rng.below(live.len());
                let (slot, _) = live.swap_remove(i);
                store.retire(slot).unwrap();
                assert!(store.retire(slot).is_err(), "double free accepted");
            }
            assert_eq!(store.live(), live.len(), "live-count drift (leak or lost slot)");
            assert_eq!(store.free_slots(), cap - live.len());
            for (slot, tag) in &live {
                let (c, s) = store.state_of(*slot);
                assert!(c.iter().all(|&x| x == *tag), "conv state corrupted for tag {tag}");
                assert!(s.iter().all(|&x| x == -*tag), "ssm state corrupted for tag {tag}");
            }
        }
        // Full drain: everything still releasable exactly once.
        for (slot, _) in live.drain(..) {
            store.retire(slot).unwrap();
        }
        assert_eq!(store.live(), 0);
        assert_eq!(store.free_slots(), cap);
    });
}

#[test]
fn prop_state_store_gather_scatter_roundtrip() {
    for_cases("state_store_frames", |rng| {
        let n_layer = 1 + rng.below(3);
        let conv_row = 1 + rng.below(5);
        let ssm_row = 1 + rng.below(5);
        let lanes_n = 1 + rng.below(4);
        let mut store = StateStore::new(lanes_n + 2, n_layer, conv_row, ssm_row);
        // Random lane map: each lane occupied (fresh slot) or idle.
        let lanes: Vec<Option<tor_ssm::coordinator::state_pool::Slot>> = (0..lanes_n)
            .map(|i| {
                (rng.f64() < 0.7).then(|| {
                    let v = (i + 1) as f32;
                    store
                        .admit(&vec![v; n_layer * conv_row], &vec![-v; n_layer * ssm_row])
                        .unwrap()
                })
            })
            .collect();
        let mut conv_frame = vec![f32::NAN; n_layer * lanes_n * conv_row];
        let mut ssm_frame = vec![f32::NAN; n_layer * lanes_n * ssm_row];
        store.gather(&lanes, &mut conv_frame, &mut ssm_frame);
        // Frame holds per-lane values; idle lanes zeroed (never stale NaN).
        assert!(conv_frame.iter().all(|x| x.is_finite()));
        assert!(ssm_frame.iter().all(|x| x.is_finite()));
        // A "decode step": shift every value, scatter back, re-gather.
        for x in conv_frame.iter_mut() {
            *x += 10.0;
        }
        for x in ssm_frame.iter_mut() {
            *x -= 10.0;
        }
        store.scatter(&lanes, &conv_frame, &ssm_frame);
        for (i, slot) in lanes.iter().enumerate() {
            if let Some(s) = slot {
                let v = (i + 1) as f32;
                let (c, m) = store.state_of(*s);
                assert!(c.iter().all(|&x| x == v + 10.0), "lane {i} conv roundtrip");
                assert!(m.iter().all(|&x| x == -v - 10.0), "lane {i} ssm roundtrip");
            }
        }
    });
}

#[test]
fn prop_router_always_known_lane() {
    for_cases("router", |rng| {
        let lanes = ["dense", "utrc@0.1", "utrc@0.2", "utrc@0.3"];
        let k = 1 + rng.below(lanes.len());
        let active: Vec<&str> = lanes[..k].to_vec();
        let policy = match rng.below(2) {
            0 => Policy::LeastLoaded,
            _ => Policy::CostAware { long_prompt: 64 + rng.below(512) },
        };
        let mut r = Router::new(policy, &active);
        for i in 0..100u64 {
            let q = req(i, rng.below(1024));
            let lane = r.route(&q).unwrap();
            assert!(active.contains(&lane.as_str()), "unknown lane {lane}");
            r.note_enqueued(&lane);
            if rng.f64() < 0.7 {
                r.note_done(&lane);
            }
        }
    });
}

#[test]
fn prop_router_least_loaded_minimizes() {
    for_cases("router_ll", |rng| {
        let lanes = ["a", "b", "c"];
        let mut r = Router::new(Policy::LeastLoaded, &lanes);
        // Load lanes unevenly, then route: must pick a minimum-depth lane.
        for _ in 0..rng.below(20) {
            let lane = lanes[rng.below(3)];
            r.note_enqueued(lane);
        }
        let min_depth = lanes.iter().map(|l| r.depth(l)).min().unwrap();
        let got = r.route(&req(0, 8)).unwrap();
        assert_eq!(r.depth(&got), min_depth);
    });
}

#[test]
fn prop_schedule_solver() {
    for_cases("schedule", |rng| {
        let arch = if rng.f64() < 0.5 { Arch::Mamba } else { Arch::Mamba2 };
        let n_layer = 12 + rng.below(40);
        let dims = ModelDims {
            name: "prop".into(),
            arch,
            vocab_size: 512 + rng.below(4096),
            d_model: 64 * (1 + rng.below(8)),
            n_layer,
            d_state: 8 * (1 + rng.below(3)),
            expand: 2,
            d_conv: 4,
            headdim: 64,
            chunk: 64,
        };
        let seq_len = 64 * (1 + rng.below(32));
        let start = 4 + rng.below(n_layer / 2);
        let k = 1 + rng.below(4);
        let locations: Vec<usize> = (0..k)
            .map(|i| start + 5 * i)
            .filter(|&l| l < n_layer)
            .collect();
        if locations.is_empty() {
            return;
        }
        let target = [0.10, 0.15, 0.20, 0.25, 0.30][rng.below(5)];
        let Ok(plan) = solve_schedule(&dims, seq_len, &locations, target) else {
            return; // legitimately infeasible (few locations, tight target)
        };
        // Invariants regardless of target feasibility:
        assert_eq!(plan.seg_lens.len(), locations.len() + 1);
        assert_eq!(plan.seg_lens[0], seq_len);
        for w in plan.seg_lens.windows(2) {
            assert!(w[1] <= w[0], "seg lens must not grow");
            assert_eq!(w[1] % 2, 0, "seg lens must be even");
        }
        for (i, &r) in plan.removed.iter().enumerate() {
            assert_eq!(plan.seg_lens[i] - plan.seg_lens[i + 1], r);
            assert!(r <= plan.seg_lens[i] / 2, "half-limit violated");
        }
        assert!((plan.flops_reduction - target).abs() <= 0.05);
    });
}

#[test]
fn prop_json_roundtrip() {
    fn gen_value(rng: &mut Rng, depth: usize) -> Json {
        match if depth > 3 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.f64() < 0.5),
            2 => Json::Num((rng.below(1_000_000) as f64) / 64.0 - 500.0),
            3 => {
                let n = rng.below(12);
                Json::Str((0..n).map(|_| "ab\"\\\nc€日ß ".chars().nth(rng.below(9)).unwrap()).collect())
            }
            4 => Json::Arr((0..rng.below(6)).map(|_| gen_value(rng, depth + 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(6))
                    .map(|i| (format!("k{i}"), gen_value(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    for_cases("json", |rng| {
        let v = gen_value(rng, 0);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap_or_else(|e| panic!("parse back: {e}\n{text}"));
        assert_eq!(v, back, "roundtrip mismatch for {text}");
    });
}
