//! Continuous-batching end-to-end tests on the reference backend (hermetic:
//! synthetic fixture, no artifacts). What they pin down (DESIGN.md §6):
//!
//! * staggered arrivals: short requests complete and release their slot the
//!   moment they hit `gen_tokens`, while long ones keep decoding;
//! * the scheduler's responses are bit-identical to the lock-step
//!   `Engine::serve_batch` path for identical inputs, on the dense and the
//!   token-reduced lane alike — on a **length-diverse** trace including
//!   prompts longer than the prefill frame (chunked prefill, DESIGN.md §6);
//! * a prompt of 3× the prefill frame serves end to end through the
//!   continuous scheduler without truncation;
//! * with mixed generation lengths a 64-request trace completes in strictly
//!   fewer decode-frame executions than lock-step (the acceptance number
//!   reported in BENCH_coordinator.json).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;

use tor_ssm::coordinator::engine::Engine;
use tor_ssm::coordinator::scheduler::Scheduler;
use tor_ssm::coordinator::{Request, Response};
use tor_ssm::fixtures::generate_default;
use tor_ssm::manifest::Manifest;
use tor_ssm::runtime::{Runtime, Weights};
use tor_ssm::util::rng::Rng;

/// Unique per-test fixture dir (tests run in parallel threads).
fn fixture(tag: &str) -> (PathBuf, Manifest) {
    let dir = std::env::temp_dir().join(format!("tor-ssm-cont-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let man = generate_default(&dir).expect("fixture generation");
    (dir, man)
}

fn cleanup(dir: &Path) {
    let _ = std::fs::remove_dir_all(dir);
}

fn req(id: u64, plen: usize, gen_tokens: usize, vocab: usize) -> Request {
    Request {
        id,
        prompt: (0..plen).map(|t| ((t * 7 + id as usize) % vocab) as i32).collect(),
        gen_tokens,
        variant: String::new(),
        arrived_us: 0,
        priority: Default::default(),
    }
}

fn by_id(resps: &[Response]) -> BTreeMap<u64, Vec<i32>> {
    let map: BTreeMap<u64, Vec<i32>> =
        resps.iter().map(|r| (r.id, r.generated.clone())).collect();
    assert_eq!(map.len(), resps.len(), "duplicate response ids");
    map
}

#[test]
fn staggered_arrivals_retire_short_requests_early() {
    let (dir, man) = fixture("stagger");
    let rt = Runtime::reference().unwrap();
    let model = man.model("ref-mamba").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let engine = Engine::new(&rt, &man, &model, &w, "dense").unwrap();
    assert!(engine.decode_batch >= 2, "fixture decode frame too narrow for this test");

    let vocab = model.vocab_size;
    let plen = man.prefill_seq_len;
    let mut sched = Scheduler::new(&engine);
    sched.submit(req(0, plen, 12, vocab)); // long
    sched.submit(req(1, plen / 2, 2, vocab)); // short

    // First step: both prefilled + placed, one decode step; the short
    // request hits gen_tokens=2 and must retire immediately.
    let done = sched.step().unwrap();
    assert_eq!(done.len(), 1, "short request should complete on the first decode step");
    assert_eq!(done[0].id, 1);
    assert_eq!(done[0].generated.len(), 2);
    // Its slot is already free while the long request still decodes.
    assert_eq!(sched.store().live(), 1, "finished slot must be released immediately");
    assert!(!sched.is_idle());

    // A new arrival takes the freed lane while the long request continues.
    sched.submit(req(2, plen / 2, 3, vocab));
    let mut rest = sched.step().unwrap();
    // (id 2 needs two more decode steps after admission; nothing may have
    // finished yet this step, depending on interleave — just drain.)
    rest.extend(sched.drain().unwrap());
    assert!(sched.is_idle());
    assert_eq!(sched.store().live(), 0, "all slots released at drain");
    assert_eq!(sched.completed, 3);

    let all = by_id(&rest);
    assert_eq!(all[&0].len(), 12);
    assert_eq!(all[&2].len(), 3);
    // Honest timing: the long request accumulated decode time over many
    // steps; queue time was measured (not hardcoded 0 — it may legitimately
    // round to 0µs only for instant admission).
    let long = rest.iter().find(|r| r.id == 0).unwrap();
    assert!(long.decode_us > 0);
    assert_eq!(long.prompt_tokens, plen);
    cleanup(&dir);
}

#[test]
fn continuous_matches_lockstep_bit_for_bit() {
    let (dir, man) = fixture("identical");
    let rt = Runtime::reference().unwrap();
    let model = man.model("ref-mamba2").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let vocab = model.vocab_size;
    let plen = man.prefill_seq_len;

    for variant in ["dense", "utrc@0.2"] {
        let engine = Engine::new(&rt, &man, &model, &w, variant).unwrap();
        // More requests than decode lanes; a length-diverse trace: short,
        // odd-length, full-frame, AND longer-than-frame prompts (the last
        // two run as chunked prefill), plus a 1-token-generation request
        // that never takes a slot.
        let gens = [5usize, 1, 8, 3, 6];
        let lens = [plen, plen / 4, 3 * plen, plen / 2 + 1, 2 * plen];
        let reqs: Vec<Request> = gens
            .iter()
            .zip(lens)
            .enumerate()
            .map(|(i, (&g, l))| req(i as u64, l, g, vocab))
            .collect();

        // Lock-step reference: arrival-order batches.
        let mut lock = Vec::new();
        for chunk in reqs.chunks(engine.max_batch()) {
            lock.extend(engine.serve_batch(chunk).unwrap());
        }

        // Continuous: same trace, staggered submission (submit one, step
        // once) to exercise admission interleaving.
        let mut sched = Scheduler::new(&engine);
        let mut cont = Vec::new();
        for r in reqs.iter().cloned() {
            sched.submit(r);
            cont.extend(sched.step().unwrap());
        }
        cont.extend(sched.drain().unwrap());

        let lock_map = by_id(&lock);
        let cont_map = by_id(&cont);
        assert_eq!(lock_map.len(), reqs.len());
        for (id, gen) in &lock_map {
            assert_eq!(
                cont_map.get(id),
                Some(gen),
                "{variant}: request {id} generated different tokens under continuous batching"
            );
            assert_eq!(gen.len(), gens[*id as usize], "{variant}: wrong generation length");
        }
    }
    cleanup(&dir);
}

#[test]
fn mixed_gen_trace_uses_fewer_decode_steps_than_lockstep() {
    // The acceptance trace: 64 requests, gen_tokens uniform in 1..=16.
    // Lock-step decodes every batch for max(gen) steps; continuous retires
    // lanes the moment they finish, so the same trace must need strictly
    // fewer decode-frame executions — with identical outputs.
    let (dir, man) = fixture("steps");
    let rt = Runtime::reference().unwrap();
    let model = man.model("ref-mamba").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let engine = Engine::new(&rt, &man, &model, &w, "dense").unwrap();
    let vocab = model.vocab_size;
    let plen = man.prefill_seq_len;

    let mut rng = Rng::new(3);
    let reqs: Vec<Request> = (0..64)
        .map(|i| {
            let l = match rng.below(4) {
                0 => plen,
                1 => plen / 4,
                2 => 1 + rng.below(plen),
                _ => plen + 1 + rng.below(2 * plen), // chunked prefill
            };
            req(i as u64, l, 1 + rng.below(16), vocab)
        })
        .collect();

    // Lock-step pass, counted via the engine's decode-call counter.
    let calls0 = engine.decode_calls.load(Ordering::Relaxed);
    let mut lock = Vec::new();
    for chunk in reqs.chunks(engine.max_batch()) {
        lock.extend(engine.serve_batch(chunk).unwrap());
    }
    let lock_steps = engine.decode_calls.load(Ordering::Relaxed) - calls0;

    // Continuous pass over the identical trace.
    let calls1 = engine.decode_calls.load(Ordering::Relaxed);
    let mut sched = Scheduler::new(&engine);
    let cont = sched.run(reqs.clone()).unwrap();
    let cont_steps = engine.decode_calls.load(Ordering::Relaxed) - calls1;

    assert_eq!(cont_steps, sched.decode_steps, "scheduler step counter drifted");
    assert!(
        cont_steps < lock_steps,
        "continuous must finish the mixed-gen trace in fewer decode steps: \
         continuous={cont_steps} lock-step={lock_steps}"
    );

    // And with identical generated tokens per request.
    let lock_map = by_id(&lock);
    let cont_map = by_id(&cont);
    assert_eq!(lock_map, cont_map, "continuous changed generated tokens");
    // Exactly the requested number of tokens for every request.
    for (r, (_, gen)) in reqs.iter().zip(&lock_map) {
        assert_eq!(gen.len(), r.gen_tokens);
    }
    // No state leaked.
    assert_eq!(sched.store().live(), 0);
    assert_eq!(sched.completed, 64);
    cleanup(&dir);
}

/// Regression for the dead ready-ahead capacity: the admit loop used to
/// stop the moment `ready.len() >= free_lanes`, so once every lane was
/// occupied (`free_lanes == 0`) admission halted entirely and the
/// `decode_batch + batch` slots `Scheduler::new` allocates for ready-ahead
/// were unreachable — every retirement then stalled on a full prefill
/// before the lane could refill. Now admission runs ahead by up to one
/// prefill batch beyond the free lanes: with 2 lanes and 4 queued
/// requests, BOTH prefill batches run in the very first step, the store's
/// high-water mark exceeds the decode frame, and when a lane frees it is
/// refilled from `ready` in the next iteration with no further prefill.
#[test]
fn admission_runs_ahead_so_freed_lanes_refill_without_prefill() {
    let (dir, man) = fixture("readyahead");
    let rt = Runtime::reference().unwrap();
    let model = man.model("ref-mamba").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let engine = Engine::new(&rt, &man, &model, &w, "dense").unwrap();
    let vocab = model.vocab_size;
    let plen = man.prefill_seq_len;
    assert_eq!(engine.decode_batch, 2, "test assumes the default 2-lane fixture");
    assert_eq!(engine.batch, 2, "test assumes the default 2-wide prefill frame");

    let mut sched = Scheduler::new(&engine);
    assert_eq!(sched.store().capacity(), engine.decode_batch + engine.batch);
    // Distinct generation lengths so exactly one sequence retires first.
    for (i, g) in [3usize, 5, 4, 6].into_iter().enumerate() {
        sched.submit(req(i as u64, plen / 2 + i, g, vocab));
    }
    let done = sched.step().unwrap();
    assert!(done.is_empty(), "nothing completes on the first step");
    // Ready-ahead: both prefill batches ran up front — the second one while
    // the lanes were already spoken for (the old bound stopped at one).
    assert_eq!(sched.prefill_calls, 2, "admission must prefill ahead of free lanes");
    assert_eq!(sched.ready_ahead(), 2, "one full prefill batch waits beyond the lanes");
    assert_eq!(sched.store().high_water(), 4);
    assert!(
        sched.store().high_water() > engine.decode_batch,
        "ready-ahead must actually use the store slots beyond the decode frame"
    );

    // Drive to the first retirement (id 0, gen_tokens = 3).
    let mut done = Vec::new();
    while done.is_empty() {
        done.extend(sched.step().unwrap());
    }
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].id, 0);
    let refills_before = sched.prefill_calls;
    // The freed lane refills from the ready-ahead queue on the very next
    // iteration — no new prefill call stands between retirement and
    // placement (the stall the old admission bound forced every time).
    sched.step().unwrap();
    assert_eq!(
        sched.prefill_calls, refills_before,
        "freed lane must be refilled from ready-ahead, not via a fresh prefill"
    );
    assert_eq!(sched.ready_ahead(), 1, "one ready sequence took the lane, one still waits");

    let rest = sched.drain().unwrap();
    assert_eq!(sched.prefill_calls, 2, "the whole trace needs exactly two prefill calls");
    assert_eq!(sched.completed, 4);
    assert_eq!(sched.store().live(), 0, "slots leaked");
    let mut all = by_id(&done);
    all.extend(by_id(&rest));
    for (i, g) in [3usize, 5, 4, 6].into_iter().enumerate() {
        assert_eq!(all[&(i as u64)].len(), g, "request {i}: wrong generation length");
    }
    cleanup(&dir);
}

/// Acceptance: a prompt of 3× the prefill frame is served end to end
/// through the continuous scheduler — chunked prefill, no truncation — on
/// the dense and a reduced lane, alongside ordinary-length traffic.
#[test]
fn three_frame_prompt_serves_end_to_end_without_truncation() {
    let (dir, man) = fixture("long");
    let rt = Runtime::reference().unwrap();
    let model = man.model("ref-mamba").unwrap().clone();
    let w = Weights::load_init(&man, &model).unwrap();
    let vocab = model.vocab_size;
    let plen = man.prefill_seq_len;

    for variant in ["dense", "unified@0.2"] {
        let engine = Engine::new(&rt, &man, &model, &w, variant).unwrap();
        assert!(engine.length_aware, "fixture prefill entries must be length-aware");
        let reqs =
            vec![req(0, 3 * plen, 6, vocab), req(1, plen / 2, 4, vocab), req(2, plen, 3, vocab)];

        let mut sched = Scheduler::new(&engine);
        let resps = sched.run(reqs.clone()).unwrap();
        assert_eq!(resps.len(), 3, "{variant}: lost responses");
        let by = by_id(&resps);
        for r in &reqs {
            assert_eq!(by[&r.id].len(), r.gen_tokens, "{variant}: wrong generation length");
        }
        let long_resp = resps.iter().find(|r| r.id == 0).unwrap();
        assert_eq!(long_resp.prompt_tokens, 3 * plen, "{variant}: 3-frame prompt truncated");
        assert_eq!(sched.store().live(), 0, "{variant}: slots leaked");

        // The lock-step baseline shares the chunked prefill, so it must
        // produce the identical tokens for the same requests.
        let lock = engine.serve_batch(&reqs[..engine.max_batch().min(reqs.len())]).unwrap();
        for l in &lock {
            assert_eq!(by[&l.id], l.generated, "{variant}: lock-step diverged on request {}", l.id);
        }
    }
    cleanup(&dir);
}
