//! Rust training loop over the AOT train-step executable.
//!
//! Python never runs here: the fused fwd+bwd+AdamW step was lowered once by
//! `aot.py`; this loop just streams (params, opt state, batch) through it,
//! samples corpus windows, logs the loss curve, and writes checkpoints that
//! the eval/serve paths consume. ABI: inputs `p[0..n], m[0..n], v[0..n],
//! step, tokens`, outputs the same plus the scalar loss (see
//! training.train_step).
//!
//! Training runs through [`Executable::execute_raw`], which only the `pjrt`
//! backend implements today — the reference backend rejects it with a clear
//! error (interpreting the fused backward pass is out of scope for the
//! hermetic path; see runtime/reference.rs).

use std::path::PathBuf;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::data::Corpus;
use crate::manifest::{Manifest, ModelEntry};
use crate::runtime::{HostTensor, Runtime, Weights};
use crate::util::rng::Rng;

pub struct TrainReport {
    pub steps: usize,
    pub losses: Vec<f32>,
    pub wall_s: f64,
    pub checkpoint: PathBuf,
    pub tokens_seen: u64,
}

pub fn checkpoint_path(man: &Manifest, model: &str) -> PathBuf {
    man.root.join("checkpoints").join(format!("{model}.bin"))
}

pub fn loss_log_path(man: &Manifest, model: &str) -> PathBuf {
    man.root.join("logs").join(format!("train_{model}.csv"))
}

/// Load trained weights if a checkpoint exists, else the init blob.
pub fn load_best_weights(man: &Manifest, model: &ModelEntry) -> Result<(Weights, bool)> {
    let ckpt = checkpoint_path(man, &model.name);
    if ckpt.exists() {
        let bytes = std::fs::read(&ckpt)?;
        Ok((Weights::from_bytes(model, &bytes)?, true))
    } else {
        Ok((Weights::load_init(man, model)?, false))
    }
}

pub fn train(
    rt: &Runtime,
    man: &Manifest,
    model: &ModelEntry,
    steps: usize,
    seed: u64,
    log_every: usize,
) -> Result<TrainReport> {
    let entry = model.train_entry()?;
    let exe = rt.load_entry(man, model, entry)?;
    let n = model.params.len();
    let corpus = Corpus::load(man.path(&man.train_file))?;
    corpus.validate(model.vocab_size)?;
    let mut rng = Rng::new(seed);

    let weights = Weights::load_init(man, model)?;
    let mut params: Vec<HostTensor> = weights.tensors.clone();
    let mut m: Vec<HostTensor> = weights
        .tensors
        .iter()
        .map(|t| HostTensor::zeros_f32(t.shape.clone()))
        .collect();
    let mut v: Vec<HostTensor> = weights
        .tensors
        .iter()
        .map(|t| HostTensor::zeros_f32(t.shape.clone()))
        .collect();
    let mut step_t = HostTensor::scalar_i32(0);

    let t0 = Instant::now();
    let mut losses = Vec::with_capacity(steps);
    let mut tokens_seen = 0u64;

    for step in 0..steps {
        let batch = corpus.sample_batch(&mut rng, entry.batch, entry.seq_len);
        tokens_seen += batch.len() as u64;
        let tokens = HostTensor::i32(vec![entry.batch, entry.seq_len], batch);

        // Borrow, don't clone: params/opt state stay owned across steps and
        // only references cross the trait boundary.
        let mut args: Vec<&HostTensor> = Vec::with_capacity(3 * n + 2);
        args.extend(params.iter());
        args.extend(m.iter());
        args.extend(v.iter());
        args.push(&step_t);
        args.push(&tokens);

        let outs = exe.execute_raw(&args).context("train step")?;
        ensure!(outs.len() == 3 * n + 2, "train step returned {} outputs", outs.len());

        let loss = outs[3 * n + 1].as_f32()?[0];
        ensure!(loss.is_finite(), "loss diverged at step {step}: {loss}");
        losses.push(loss);

        params = outs[..n].to_vec();
        m = outs[n..2 * n].to_vec();
        v = outs[2 * n..3 * n].to_vec();
        step_t = outs[3 * n].clone();

        if log_every > 0 && (step % log_every == 0 || step + 1 == steps) {
            println!(
                "[train {}] step {step:4} loss {loss:.4} ({:.2}s, {:.0} tok/s)",
                model.name,
                t0.elapsed().as_secs_f64(),
                tokens_seen as f64 / t0.elapsed().as_secs_f64().max(1e-9),
            );
            use std::io::Write;
            std::io::stdout().flush().ok(); // visible through pipes
        }
    }

    // Save checkpoint (params only).
    let trained = Weights { tensors: params, quant: None };
    let ckpt = checkpoint_path(man, &model.name);
    trained.save(model, &ckpt)?;

    // Loss-curve CSV.
    let log = loss_log_path(man, &model.name);
    if let Some(dir) = log.parent() {
        std::fs::create_dir_all(dir).ok();
    }
    let mut csv = String::from("step,loss\n");
    for (i, l) in losses.iter().enumerate() {
        csv.push_str(&format!("{i},{l}\n"));
    }
    std::fs::write(&log, csv)?;

    Ok(TrainReport {
        steps,
        losses,
        wall_s: t0.elapsed().as_secs_f64(),
        checkpoint: ckpt,
        tokens_seen,
    })
}
