//! Content-addressed model registry (DESIGN.md §15): model weights as
//! first-class, schema-versioned, digest-verified artifacts.
//!
//! Layout under one registry root:
//!
//! ```text
//! <root>/manifests/<model>/<tag>.json   schema-versioned manifest
//! <root>/blobs/<hex>                    V2 content-addressed param blobs
//! <root>/legacy/<model>-<tag>.bin       V1 single concatenated blob
//! ```
//!
//! Two manifest schemas coexist, trow-ManifestV1/V2 style (each with its
//! own parse function, unknown versions a **typed** error, never a silent
//! best-effort):
//!
//! * **V1** — the legacy layout: one unnamed blob per `(model, tag)`
//!   holding the manifest's whole concatenated little-endian f32 param
//!   buffer, digested as a unit.
//! * **V2** — named blobs: one content-addressed blob per *param*, stored
//!   at `blobs/<digest-hex>` and therefore shared across tags and models
//!   whenever bytes coincide (publishing a tag that changes one param
//!   writes one new blob).
//!
//! Digests are `fnv64:<16 hex>` over raw bytes (same FNV-1a-64 constants
//! as the prefix cache's token hashing). Every blob is re-hashed **at
//! load** and compared against its manifest digest — a flipped byte
//! anywhere fails with [`RegistryError::DigestMismatch`] naming the
//! expected digest, so a poisoned blob can be located by grep. Conversion
//! between schemas is lossless both ways (bytes are carried verbatim;
//! pinned by `tests/registry.rs`).
//!
//! [`Registry::hot_load`] is the replica pool's rolling-upgrade loader:
//! verify + reassemble + upload in one call, handed to
//! [`ReplicaPool::advance_upgrade`](crate::coordinator::replica::ReplicaPool::advance_upgrade)
//! so replicas swap models atomically without a process restart.

use std::fmt;
use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::manifest::ModelEntry;
use crate::util::json::{num, obj, s, Json};

use super::{DeviceWeights, Runtime, Weights};

/// FNV-1a 64-bit over raw bytes — the registry's digest primitive. Same
/// constants as `coordinator::prefix_cache::fnv1a_tokens`; collisions are
/// a staleness risk, not a correctness one (digests *verify* bytes that a
/// manifest already names, they do not deduplicate adversarial input).
pub fn fnv1a_bytes(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Render the digest string of `bytes`: `fnv64:` + 16 lowercase hex digits.
pub fn digest_of(bytes: &[u8]) -> String {
    format!("fnv64:{:016x}", fnv1a_bytes(bytes))
}

/// Typed registry failures — the error contract `tests/registry.rs` pins:
/// schema and integrity problems are *named*, never stringly guessed at.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RegistryError {
    /// Manifest text that does not parse, or parses but is missing /
    /// mistypes a required field.
    InvalidManifest { err: String },
    /// A `schemaVersion` this build does not understand. Failing typed
    /// here is the point of versioning: a future schema must be rejected
    /// loudly, not half-read as whatever V1 fields happen to match.
    UnknownSchema { version: u64 },
    /// A blob whose bytes no longer hash to the manifest's digest. The
    /// expected digest is part of the message so the poisoned blob can be
    /// located by grep.
    DigestMismatch { name: String, expected: String, actual: String },
    /// A digest the blob store has no readable bytes for.
    MissingBlob { name: String, digest: String, err: String },
}

impl fmt::Display for RegistryError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RegistryError::InvalidManifest { err } => {
                write!(f, "invalid registry manifest: {err}")
            }
            RegistryError::UnknownSchema { version } => {
                write!(
                    f,
                    "unknown registry schema version {version} (this build understands 1 and 2)"
                )
            }
            RegistryError::DigestMismatch { name, expected, actual } => {
                write!(
                    f,
                    "blob {name:?} failed digest verification: manifest says {expected}, \
                     bytes hash to {actual}"
                )
            }
            RegistryError::MissingBlob { name, digest, err } => {
                write!(f, "blob {name:?} ({digest}) unreadable: {err}")
            }
        }
    }
}

impl std::error::Error for RegistryError {}

/// Schema 1: one unnamed blob per `(model, tag)`, digested as a unit.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ManifestV1 {
    pub name: String,
    pub tag: String,
    /// Registry-relative path of the single blob.
    pub blob: String,
    pub digest: String,
    pub total_bytes: u64,
}

/// One named, content-addressed param blob of a [`ManifestV2`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BlobEntry {
    pub param: String,
    pub digest: String,
    pub bytes: u64,
}

/// Schema 2: named per-param blobs at `blobs/<digest-hex>`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ManifestV2 {
    pub name: String,
    pub tag: String,
    pub blobs: Vec<BlobEntry>,
}

/// A parsed registry manifest of either schema.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RegistryManifest {
    V1(ManifestV1),
    V2(ManifestV2),
}

fn str_field(doc: &Json, key: &str) -> Result<String, RegistryError> {
    doc.get(key)
        .and_then(|v| v.as_str())
        .map(str::to_string)
        .ok_or_else(|| RegistryError::InvalidManifest { err: format!("missing string {key:?}") })
}

fn u64_field(doc: &Json, key: &str) -> Result<u64, RegistryError> {
    doc.get(key)
        .and_then(|v| v.as_f64())
        .filter(|v| v.is_finite() && *v >= 0.0)
        .map(|v| v as u64)
        .ok_or_else(|| RegistryError::InvalidManifest { err: format!("missing number {key:?}") })
}

impl RegistryManifest {
    pub fn schema_version(&self) -> u64 {
        match self {
            RegistryManifest::V1(_) => 1,
            RegistryManifest::V2(_) => 2,
        }
    }

    pub fn name(&self) -> &str {
        match self {
            RegistryManifest::V1(m) => &m.name,
            RegistryManifest::V2(m) => &m.name,
        }
    }

    pub fn tag(&self) -> &str {
        match self {
            RegistryManifest::V1(m) => &m.tag,
            RegistryManifest::V2(m) => &m.tag,
        }
    }

    /// Parse manifest text. Version dispatch happens first: an unknown
    /// `schemaVersion` is [`RegistryError::UnknownSchema`] even if the
    /// rest of the document would parse under some known schema.
    pub fn parse(text: &str) -> Result<RegistryManifest, RegistryError> {
        let doc = Json::parse(text)
            .map_err(|e| RegistryError::InvalidManifest { err: e.to_string() })?;
        match u64_field(&doc, "schemaVersion")? {
            1 => Self::schema_1(&doc),
            2 => Self::schema_2(&doc),
            version => Err(RegistryError::UnknownSchema { version }),
        }
    }

    fn schema_1(doc: &Json) -> Result<RegistryManifest, RegistryError> {
        Ok(RegistryManifest::V1(ManifestV1 {
            name: str_field(doc, "name")?,
            tag: str_field(doc, "tag")?,
            blob: str_field(doc, "blob")?,
            digest: str_field(doc, "digest")?,
            total_bytes: u64_field(doc, "totalBytes")?,
        }))
    }

    fn schema_2(doc: &Json) -> Result<RegistryManifest, RegistryError> {
        let arr = doc
            .get("blobs")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| RegistryError::InvalidManifest { err: "missing array \"blobs\"".into() })?;
        let mut blobs = Vec::with_capacity(arr.len());
        for b in arr {
            blobs.push(BlobEntry {
                param: str_field(b, "param")?,
                digest: str_field(b, "digest")?,
                bytes: u64_field(b, "bytes")?,
            });
        }
        Ok(RegistryManifest::V2(ManifestV2 {
            name: str_field(doc, "name")?,
            tag: str_field(doc, "tag")?,
            blobs,
        }))
    }

    /// Render back to manifest JSON (inverse of [`Self::parse`]).
    pub fn render(&self) -> String {
        match self {
            RegistryManifest::V1(m) => obj(vec![
                ("schemaVersion", num(1.0)),
                ("name", s(&m.name)),
                ("tag", s(&m.tag)),
                ("blob", s(&m.blob)),
                ("digest", s(&m.digest)),
                ("totalBytes", num(m.total_bytes as f64)),
            ])
            .to_string(),
            RegistryManifest::V2(m) => obj(vec![
                ("schemaVersion", num(2.0)),
                ("name", s(&m.name)),
                ("tag", s(&m.tag)),
                (
                    "blobs",
                    Json::Arr(
                        m.blobs
                            .iter()
                            .map(|b| {
                                obj(vec![
                                    ("param", s(&b.param)),
                                    ("digest", s(&b.digest)),
                                    ("bytes", num(b.bytes as f64)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ])
            .to_string(),
        }
    }
}

/// On-disk registry rooted at one directory (see module docs for layout).
pub struct Registry {
    root: PathBuf,
}

impl Registry {
    /// Open (or lazily create) a registry rooted at `root`. Directories
    /// are created on first publish, so opening is infallible.
    pub fn open(root: impl Into<PathBuf>) -> Registry {
        Registry { root: root.into() }
    }

    pub fn root(&self) -> &std::path::Path {
        &self.root
    }

    fn manifest_path(&self, name: &str, tag: &str) -> PathBuf {
        self.root.join("manifests").join(name).join(format!("{tag}.json"))
    }

    fn blob_path(&self, digest: &str) -> PathBuf {
        // `fnv64:<hex>` → file named by the hex part alone.
        let hex = digest.split(':').nth(1).unwrap_or(digest);
        self.root.join("blobs").join(hex)
    }

    /// Publish `w` as `(model.name, tag)` in schema `schema` (1 or 2).
    /// Returns the manifest written. V2 blob writes are content-addressed:
    /// a blob whose digest already exists on disk is not rewritten, so
    /// tags sharing params share bytes.
    pub fn publish(
        &self,
        model: &ModelEntry,
        tag: &str,
        w: &Weights,
        schema: u64,
    ) -> Result<RegistryManifest> {
        let bytes = w.to_bytes(model)?;
        let man = match schema {
            1 => {
                let rel = format!("legacy/{}-{}.bin", model.name, tag);
                let path = self.root.join(&rel);
                if let Some(dir) = path.parent() {
                    std::fs::create_dir_all(dir)?;
                }
                std::fs::write(&path, &bytes)
                    .with_context(|| format!("writing registry blob {path:?}"))?;
                RegistryManifest::V1(ManifestV1 {
                    name: model.name.clone(),
                    tag: tag.to_string(),
                    blob: rel,
                    digest: digest_of(&bytes),
                    total_bytes: bytes.len() as u64,
                })
            }
            2 => {
                let mut blobs = Vec::with_capacity(model.params.len());
                for p in &model.params {
                    let chunk = &bytes[p.offset..p.offset + p.bytes];
                    let digest = digest_of(chunk);
                    let path = self.blob_path(&digest);
                    if let Some(dir) = path.parent() {
                        std::fs::create_dir_all(dir)?;
                    }
                    if !path.exists() {
                        std::fs::write(&path, chunk)
                            .with_context(|| format!("writing registry blob {path:?}"))?;
                    }
                    blobs.push(BlobEntry {
                        param: p.name.clone(),
                        digest,
                        bytes: p.bytes as u64,
                    });
                }
                RegistryManifest::V2(ManifestV2 {
                    name: model.name.clone(),
                    tag: tag.to_string(),
                    blobs,
                })
            }
            version => return Err(RegistryError::UnknownSchema { version }.into()),
        };
        let mpath = self.manifest_path(&model.name, tag);
        if let Some(dir) = mpath.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(&mpath, man.render())
            .with_context(|| format!("writing registry manifest {mpath:?}"))?;
        Ok(man)
    }

    /// Read + parse the stored manifest for `(name, tag)`.
    pub fn manifest(&self, name: &str, tag: &str) -> Result<RegistryManifest> {
        let path = self.manifest_path(name, tag);
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading registry manifest {path:?}"))?;
        Ok(RegistryManifest::parse(&text)?)
    }

    /// Load `(model.name, tag)`: read every blob, **verify each against
    /// its manifest digest**, reassemble the param buffer in manifest
    /// layout order, and parse it into [`Weights`]. Any integrity problem
    /// is a typed [`RegistryError`].
    pub fn load(&self, model: &ModelEntry, tag: &str) -> Result<Weights> {
        let man = self.manifest(&model.name, tag)?;
        let bytes = self.verified_bytes(model, &man)?;
        Weights::from_bytes(model, &bytes)
    }

    fn verified_bytes(&self, model: &ModelEntry, man: &RegistryManifest) -> Result<Vec<u8>> {
        match man {
            RegistryManifest::V1(v1) => {
                let path = self.root.join(&v1.blob);
                let bytes = std::fs::read(&path).map_err(|e| RegistryError::MissingBlob {
                    name: v1.blob.clone(),
                    digest: v1.digest.clone(),
                    err: e.to_string(),
                })?;
                let actual = digest_of(&bytes);
                if actual != v1.digest {
                    return Err(RegistryError::DigestMismatch {
                        name: v1.blob.clone(),
                        expected: v1.digest.clone(),
                        actual,
                    }
                    .into());
                }
                Ok(bytes)
            }
            RegistryManifest::V2(v2) => {
                for p in &model.params {
                    if !v2.blobs.iter().any(|b| b.param == p.name) {
                        return Err(RegistryError::InvalidManifest {
                            err: format!("manifest lists no blob for param {:?}", p.name),
                        }
                        .into());
                    }
                }
                let total: usize = model.params.iter().map(|p| p.bytes).sum();
                let mut out = vec![0u8; total];
                for b in &v2.blobs {
                    let Some(p) = model.param(&b.param) else {
                        return Err(RegistryError::InvalidManifest {
                            err: format!("manifest names blob for unknown param {:?}", b.param),
                        }
                        .into());
                    };
                    let path = self.blob_path(&b.digest);
                    let bytes = std::fs::read(&path).map_err(|e| RegistryError::MissingBlob {
                        name: b.param.clone(),
                        digest: b.digest.clone(),
                        err: e.to_string(),
                    })?;
                    let actual = digest_of(&bytes);
                    if actual != b.digest {
                        return Err(RegistryError::DigestMismatch {
                            name: b.param.clone(),
                            expected: b.digest.clone(),
                            actual,
                        }
                        .into());
                    }
                    if bytes.len() != p.bytes {
                        return Err(RegistryError::InvalidManifest {
                            err: format!(
                                "blob for {:?} is {} bytes, param layout expects {}",
                                b.param,
                                bytes.len(),
                                p.bytes
                            ),
                        }
                        .into());
                    }
                    out[p.offset..p.offset + p.bytes].copy_from_slice(&bytes);
                }
                Ok(out)
            }
        }
    }

    /// Republish `(model.name, tag)` in the other schema. Bytes are
    /// carried verbatim (and digest-verified on the way through), so
    /// V1 ↔ V2 conversion is lossless in both directions.
    pub fn convert(&self, model: &ModelEntry, tag: &str, to_schema: u64) -> Result<RegistryManifest> {
        let w = self.load(model, tag)?;
        self.publish(model, tag, &w, to_schema)
    }

    /// Verify + load + upload in one call — the loader
    /// [`ReplicaPool::advance_upgrade`](crate::coordinator::replica::ReplicaPool::advance_upgrade)
    /// wants: the same upload path `Engine::new` uses (including load-time
    /// int8 quantization when that format is in effect), so hot-swapped
    /// weights behave exactly like construction-time ones.
    pub fn hot_load(&self, rt: &Runtime, model: &ModelEntry, tag: &str) -> Result<DeviceWeights> {
        let w = self.load(model, tag)?;
        rt.upload_weights(model, &w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digest_format_is_pinned() {
        // Empty input → the FNV-1a-64 offset basis; format is fnv64:<16hex>.
        assert_eq!(digest_of(&[]), "fnv64:cbf29ce484222325");
        assert_eq!(digest_of(b"a").len(), "fnv64:".len() + 16);
        assert_ne!(digest_of(b"ab"), digest_of(b"ba"));
    }

    #[test]
    fn unknown_schema_is_typed_not_guessed() {
        let text = r#"{"schemaVersion":3,"name":"m","tag":"t","blob":"x","digest":"d","totalBytes":4}"#;
        match RegistryManifest::parse(text) {
            Err(RegistryError::UnknownSchema { version: 3 }) => {}
            other => panic!("expected UnknownSchema{{3}}, got {other:?}"),
        }
    }

    #[test]
    fn malformed_manifests_are_invalid_manifest() {
        for bad in [
            "not json",
            r#"{"name":"m"}"#,                                // no schemaVersion
            r#"{"schemaVersion":"one"}"#,                     // mistyped version
            r#"{"schemaVersion":1,"name":"m","tag":"t"}"#,    // V1 missing blob/digest
            r#"{"schemaVersion":2,"name":"m","tag":"t"}"#,    // V2 missing blobs
            r#"{"schemaVersion":2,"name":"m","tag":"t","blobs":[{"param":"p"}]}"#,
        ] {
            match RegistryManifest::parse(bad) {
                Err(RegistryError::InvalidManifest { .. }) => {}
                other => panic!("{bad:?}: expected InvalidManifest, got {other:?}"),
            }
        }
    }

    #[test]
    fn render_parse_roundtrip_both_schemas() {
        let v1 = RegistryManifest::V1(ManifestV1 {
            name: "m".into(),
            tag: "base".into(),
            blob: "legacy/m-base.bin".into(),
            digest: "fnv64:0123456789abcdef".into(),
            total_bytes: 128,
        });
        let v2 = RegistryManifest::V2(ManifestV2 {
            name: "m".into(),
            tag: "base".into(),
            blobs: vec![
                BlobEntry { param: "embedding".into(), digest: "fnv64:00ff".into(), bytes: 64 },
                BlobEntry { param: "head".into(), digest: "fnv64:11aa".into(), bytes: 64 },
            ],
        });
        for man in [v1, v2] {
            let back = RegistryManifest::parse(&man.render()).unwrap();
            assert_eq!(back, man);
        }
    }

    #[test]
    fn error_messages_name_the_digest() {
        let e = RegistryError::DigestMismatch {
            name: "embedding".into(),
            expected: "fnv64:deadbeefdeadbeef".into(),
            actual: "fnv64:0000000000000000".into(),
        };
        let msg = e.to_string();
        assert!(msg.contains("fnv64:deadbeefdeadbeef"), "{msg}");
        assert!(msg.contains("embedding"), "{msg}");
    }
}
