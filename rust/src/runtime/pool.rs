//! Lane-parallel worker pool for decode-frame sharding (PERFORMANCE.md;
//! DESIGN.md §11).
//!
//! Continuous batching advances `B` independent sequences per decode step.
//! Because lanes never exchange state inside a step, the frame shards into
//! contiguous lane ranges that `min(B, workers)` threads advance
//! concurrently — each worker owns its lanes' conv/ssm rows through the
//! no-copy chunk views of [`tensor`](super::tensor), runs the exact
//! per-lane math, and the step joins before the frame is read again.
//!
//! ## Threading model
//!
//! * **Scoped, not detached** — workers run under [`std::thread::scope`],
//!   so they may borrow the frame directly and are joined before
//!   [`run_sharded`] returns; a worker panic propagates to the caller at
//!   scope exit. No job ever outlives its decode step.
//! * **One shard per worker, caller participates** — the caller's thread
//!   runs the first shard itself, so `workers == 1` spawns nothing and is
//!   *exactly* the single-threaded path (no pool overhead to subtract when
//!   comparing 1-thread vs N-thread bench arms).
//! * **Determinism** — sharding decides *which thread* computes a lane,
//!   never *what* is computed: results are bit-identical for every worker
//!   count (pinned by `tests/kernels_identity.rs`).
//!
//! The process-wide width comes from [`workers`] (env `TOR_SSM_THREADS`,
//! else the machine's available parallelism) and is overridable at run time
//! via [`set_workers`] — the `--threads` CLI flag and the bench matrix use
//! that hook.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Process-wide decode worker count. 0 = unset (resolve on first read).
static WORKERS: AtomicUsize = AtomicUsize::new(0);

/// The configured decode worker count (≥ 1). The first read honours
/// `TOR_SSM_THREADS=n`, falling back to the machine's available
/// parallelism; [`set_workers`] overrides at any time. A decode step uses
/// `min(B, workers())` threads — lanes, not cores, bound the useful width.
pub fn workers() -> usize {
    // ORDERING: Relaxed — idempotent env resolution; racing first reads
    // compute the same value, so publication order is irrelevant.
    let w = WORKERS.load(Ordering::Relaxed);
    if w != 0 {
        return w;
    }
    let default = || std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let resolved = match std::env::var("TOR_SSM_THREADS") {
        Ok(v) => match v.parse::<usize>() {
            Ok(n) if n >= 1 => n,
            // A typo'd env var must not silently measure the wrong width.
            _ => {
                eprintln!("[warn] ignoring TOR_SSM_THREADS={v:?} (want a count >= 1)");
                default()
            }
        },
        Err(_) => default(),
    };
    // ORDERING: Relaxed — same idempotent-resolution cache as the load above.
    WORKERS.store(resolved, Ordering::Relaxed);
    resolved
}

/// Override the process-wide decode worker count (clamped to ≥ 1).
///
/// ```
/// use tor_ssm::runtime::pool::{set_workers, workers};
/// set_workers(3);
/// assert_eq!(workers(), 3);
/// set_workers(0); // clamps
/// assert_eq!(workers(), 1);
/// ```
pub fn set_workers(n: usize) {
    // ORDERING: Relaxed — a standalone knob write; callers that need the new
    // width to be visible sequence it themselves (set before spawning).
    WORKERS.store(n.max(1), Ordering::Relaxed);
}

/// Split `0..n` into `parts` contiguous, balanced ranges (the first
/// `n % parts` ranges take one extra item). `parts` is clamped to
/// `1..=max(n, 1)`, so every returned range is non-empty when `n > 0`.
///
/// ```
/// use tor_ssm::runtime::pool::partition;
/// assert_eq!(partition(7, 3), vec![0..3, 3..5, 5..7]);
/// assert_eq!(partition(2, 8), vec![0..1, 1..2]); // never more parts than items
/// assert_eq!(partition(4, 1), vec![0..4]);
/// ```
pub fn partition(n: usize, parts: usize) -> Vec<Range<usize>> {
    let parts = parts.clamp(1, n.max(1));
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0usize;
    for p in 0..parts {
        let len = base + usize::from(p < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Run one task per shard: task 0 on the calling thread, the rest on
/// scoped worker threads that are joined before this returns. Tasks carry
/// their own (disjoint) mutable views, so `f` only needs `Sync`; a single
/// task runs inline with zero threading machinery.
///
/// ```
/// use tor_ssm::runtime::pool::{partition, run_sharded};
/// let mut data = vec![0u64; 10];
/// let bounds = partition(data.len(), 4);
/// // hand each shard its own disjoint sub-slice
/// let mut shards: Vec<(usize, &mut [u64])> = Vec::new();
/// let mut rest = data.as_mut_slice();
/// for r in &bounds {
///     let (head, tail) = rest.split_at_mut(r.len());
///     shards.push((r.start, head));
///     rest = tail;
/// }
/// run_sharded(shards, |(start, shard)| {
///     for (i, v) in shard.iter_mut().enumerate() {
///         *v = (start + i) as u64 * 2;
///     }
/// });
/// assert_eq!(data, (0..10).map(|i| i * 2).collect::<Vec<u64>>());
/// ```
pub fn run_sharded<T, F>(mut tasks: Vec<T>, f: F)
where
    T: Send,
    F: Fn(T) + Sync,
{
    if tasks.len() <= 1 {
        if let Some(t) = tasks.pop() {
            f(t);
        }
        return;
    }
    let rest = tasks.split_off(1);
    let first = tasks.pop().expect("first shard");
    std::thread::scope(|scope| {
        let f = &f;
        for t in rest {
            scope.spawn(move || f(t));
        }
        f(first);
        // scope exit joins every worker; a worker panic re-raises here.
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn partition_covers_exactly_without_overlap() {
        for n in [0usize, 1, 2, 5, 16, 17] {
            for parts in [1usize, 2, 3, 8, 32] {
                let ranges = partition(n, parts);
                assert!(!ranges.is_empty());
                assert_eq!(ranges[0].start, 0);
                for w in ranges.windows(2) {
                    assert_eq!(w[0].end, w[1].start, "gap/overlap at n={n} parts={parts}");
                }
                assert_eq!(ranges.last().unwrap().end, n);
                assert!(ranges.len() <= parts.max(1));
                if n > 0 {
                    assert!(ranges.iter().all(|r| !r.is_empty()));
                    // balanced: lengths differ by at most one
                    let lens: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
                    let (mn, mx) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
                    assert!(mx - mn <= 1);
                }
            }
        }
    }

    #[test]
    fn run_sharded_executes_every_task_once() {
        let hits = AtomicU64::new(0);
        for n_tasks in [0usize, 1, 2, 7] {
            hits.store(0, Ordering::SeqCst);
            let tasks: Vec<usize> = (0..n_tasks).collect();
            run_sharded(tasks, |i| {
                hits.fetch_add(1 << (i * 8), Ordering::SeqCst);
            });
            let want = (0..n_tasks).fold(0u64, |a, i| a + (1 << (i * 8)));
            assert_eq!(hits.load(Ordering::SeqCst), want, "n_tasks={n_tasks}");
        }
    }

    #[test]
    fn run_sharded_disjoint_writes_land() {
        let mut data = vec![0u32; 101];
        let bounds = partition(data.len(), 4);
        let mut shards: Vec<(usize, &mut [u32])> = Vec::new();
        let mut rest = data.as_mut_slice();
        for r in &bounds {
            let (head, tail) = rest.split_at_mut(r.len());
            shards.push((r.start, head));
            rest = tail;
        }
        run_sharded(shards, |(start, shard)| {
            for (i, v) in shard.iter_mut().enumerate() {
                *v = (start + i) as u32 + 1;
            }
        });
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i as u32 + 1);
        }
    }

    #[test]
    fn workers_is_overridable_and_clamped() {
        set_workers(5);
        assert_eq!(workers(), 5);
        set_workers(0);
        assert_eq!(workers(), 1);
        set_workers(2);
        assert_eq!(workers(), 2);
    }
}
