//! Pure-Rust reference backend: a deterministic interpreter for the small
//! op set our Mamba/Mamba-2 models need, with plan-driven intra-layer token
//! reduction. This is the hermetic execution path — no `artifacts/`
//! directory, no Python, no XLA — used by the zero-artifact test suite,
//! `repro demo`, and the bench harness on synthetic fixtures.
//!
//! ## Model semantics
//!
//! Per block (layer), on the residual stream `x ∈ R^d`:
//!
//! 1. `xn = RMSNorm(x) ⊙ norm`
//! 2. in-projection: mamba → `[u_pre(di), z(di)]`; mamba2 →
//!    `[u_pre(di), z(di), b_pre(n), c_pre(n)]`
//! 3. depthwise causal conv (width `d_conv`) over `u_pre` (mamba) or over
//!    `u_pre ++ b_pre ++ c_pre` (mamba2, matching the wider conv state the
//!    real architecture carries), then `u = silu(conv)`
//! 4. selectivity: mamba derives `B, C ∈ R^n` from `u` via `bc_proj`;
//!    mamba2 takes them from the conv output channels
//! 5. selective scan `h[i][j] = λ[i][j]·h[i][j] + u[i]·B[j]` with
//!    `λ = sigmoid(a_log)`, emit `y[i] = Σ_j h[i][j]·C[j] + D[i]·u[i]`
//! 6. gate `y ⊙ silu(z)`, out-project back into the residual stream
//!
//! Logits use a final RMSNorm and the tied embedding head.
//!
//! ## Execution paths
//!
//! The same math runs in three interchangeable forms, selected by
//! [`kernels::mode`] (DESIGN.md §11/§13, PERFORMANCE.md):
//!
//! * **scalar** — the plain one-token-at-a-time loops below
//!   (`layer_step`/`head_logits`): the oracle the fused path is pinned
//!   against, and the baseline arm of `benches/runtime.rs`;
//! * **fused** *(default)* — the cache-blocked kernels of
//!   [`kernels`](super::kernels): token blocks move through fused stages so
//!   every weight matrix streams once per block instead of once per token;
//! * **simd** — the fused pipeline with vectorized inner loops (AVX2+FMA
//!   when the CPU has them, bit-identical portable fallbacks otherwise).
//!
//! Decode frames additionally shard across the lane-parallel worker pool
//! ([`pool`](super::pool)): `B` resident sequences advance on
//! `min(B, workers)` threads through the no-copy lane-chunk views of
//! [`tensor`](super::tensor); eval/prefill batches parallelise per
//! sequence. Both axes are **bit-identical** to the single-threaded scalar
//! interpreter for scalar/fused — blocking never reassociates an
//! accumulation and threading never moves arithmetic across lanes — so
//! every golden/policy/continuous test doubles as a correctness oracle
//! (`tests/kernels_identity.rs` pins it explicitly). The simd tier keeps
//! that contract everywhere *except* the f32 logit head, whose per-logit
//! dot reassociates under a documented error bound (see
//! `kernels::head_norm_logits`).
//!
//! ## Weight formats
//!
//! [`upload_weights`](Backend::upload_weights) honours the process/manifest
//! [`WeightFormat`] knob: `Int8` derives per-channel i8 blobs for the big
//! matmul operands at upload time ([`Weights::ensure_quant`]) and every
//! tier then runs the quantized operands through a shared
//! accumulate-then-scale structure, making int8 outputs bit-identical
//! across scalar|fused|simd at any thread count. Activations, the conv
//! path, `bc_proj`, norms and the SSM state stay f32.
//!
//! ## Token reduction
//!
//! Eval/prefill programs with a [`Plan`](crate::manifest::Plan) reduce the
//! live set right after each `locations[i]` layer down to `seg_lens[i+1]`
//! positions by dispatching the program's
//! [`ReductionPolicy`](crate::reduction::policy::ReductionPolicy) — the
//! paper's unified method, its prune/merge baselines, or the random control
//! (DESIGN.md §10). The policy resolves from the manifest entry's reduction
//! method, or from the serving lane's `<policy>@<ratio>[:<metric>]` variant
//! via [`Runtime::load_entry_with_policy`](crate::runtime::Runtime::load_entry_with_policy);
//! entries with a plan but no policy fall back to the legacy unified/`l2`
//! semantics ([`policy::legacy_default`](crate::reduction::policy::legacy_default)).
//! Surviving original positions are reported through the `kept` output
//! exactly like the AOT-lowered graphs do.
//!
//! ## Variable-length prefill
//!
//! Prefill programs are **length-aware** (DESIGN.md §6): an optional
//! per-sequence `lengths: [b]` input stops each sequence's conv window and
//! scan at its true end (frame padding is never scanned — PAD is an
//! ordinary vocab id, not a semantic marker), takes the logits row at the
//! true last token, and re-solves the reduction schedule on the true
//! length. An optional `(conv0, ssm0)` resume pair makes the forward
//! chunkable: the engine splits prompts longer than the frame into
//! frame-sized chunks and carries the O(1) recurrent state across them.
//! Decode frames honour the [`IDLE_LANE`] sentinel: unoccupied lanes are
//! skipped instead of decoding a phantom token.
//!
//! ## Parameter layout
//!
//! The backend binds weights **by name** from the manifest's param list
//! (`embedding`, `layers.{l}.in_proj`, ..., `norm_f` — see
//! [`crate::fixtures`], which emits this layout). Pointing it at real AOT
//! artifacts fails with a clear error: those blobs follow the `aot.py`
//! layout and belong to the `pjrt` backend.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{bail, ensure, Context, Result};

use crate::manifest::{ModelEntry, Plan};
use crate::reduction::policy::{self, ReductionPolicy};
use crate::reduction::{solve_schedule, ModelDims};
use crate::runtime::{
    Backend, DeviceWeights, Executable, HostTensor, ProgramKind, ProgramSpec, Weights, IDLE_LANE,
};

use super::kernels::{self, rmsnorm, sigmoid, silu, KernelMode, MatRef};
use super::pool;
use super::tensor::{lane_chunks_mut, read_lane, LaneChunkMut, QuantAxis};
use super::weights::{effective_format, WeightFormat};

/// Conv window width; matches the d_conv=4 convention used across the repo.
pub const D_CONV: usize = 4;
/// Mamba-2 head width used for the ssm-state shape convention.
pub const HEADDIM: usize = 64;

pub struct ReferenceBackend;

impl ReferenceBackend {
    pub fn new() -> ReferenceBackend {
        ReferenceBackend
    }
}

impl Default for ReferenceBackend {
    fn default() -> Self {
        ReferenceBackend::new()
    }
}

impl Backend for ReferenceBackend {
    fn platform(&self) -> String {
        "reference-cpu".to_string()
    }

    fn compile(&self, spec: &ProgramSpec) -> Result<Arc<dyn Executable>> {
        let m = &spec.model;
        if m.arch != "mamba" {
            ensure!(
                m.d_inner % HEADDIM == 0,
                "reference backend: {} d_inner {} not divisible by headdim {HEADDIM}",
                m.name,
                m.d_inner
            );
        }
        if let Some(plan) = &spec.plan {
            ensure!(
                plan.seg_lens.len() == plan.locations.len() + 1,
                "plan for {} has {} seg_lens for {} locations",
                spec.tag,
                plan.seg_lens.len(),
                plan.locations.len()
            );
        }
        // Bind the reduction algorithm once at compile time. A plan without
        // a policy (hand-built spec) gets the legacy unified/l2 semantics.
        let policy = match (&spec.plan, &spec.policy) {
            (Some(_), Some(p)) => Some(p.build()),
            (Some(_), None) => Some(policy::legacy_default()),
            _ => None,
        };
        Ok(Arc::new(ReferenceExecutable {
            spec: spec.clone(),
            policy,
            plans: Mutex::new(HashMap::new()),
        }))
    }

    fn upload_weights(&self, model: &ModelEntry, w: &Weights) -> Result<DeviceWeights> {
        let mut w = w.clone();
        // Derive the int8 blobs at upload time when the effective format
        // asks for them (explicit knob > manifest default > f32) — uploads
        // snapshot the knob, so flipping it later re-uploads, it never
        // mutates a live engine (DESIGN.md §13).
        if effective_format(model) == WeightFormat::Int8 {
            w.ensure_quant(model)
                .with_context(|| format!("quantizing weights for {}", model.name))?;
        }
        // Validate the layout eagerly so failures name the model, not a
        // later execute call.
        RefModel::bind(model, &w)
            .with_context(|| format!("binding reference-layout weights for {}", model.name))?;
        Ok(DeviceWeights::Host(w))
    }

    fn interprets_policies(&self) -> bool {
        true // reduction policies are dispatched per plan boundary at run time
    }

    fn interprets_lengths(&self) -> bool {
        true // per-sequence prefill lengths + the IDLE_LANE decode sentinel
    }
}

pub struct ReferenceExecutable {
    spec: ProgramSpec,
    /// Reduction algorithm dispatched at the plan's layer boundaries
    /// (None for dense programs). See DESIGN.md §10.
    policy: Option<Box<dyn ReductionPolicy>>,
    /// Runtime-solved schedule plans keyed by true sequence length
    /// (DESIGN.md §6/§10): the exported plan only fits `spec.seq_len`, so a
    /// length-aware prefill re-solves the same (locations, target-ratio)
    /// schedule on each distinct true length it serves. `None` = the length
    /// is too short for the solver to hit the ratio within tolerance, and
    /// the sequence runs dense instead of being refused.
    plans: Mutex<HashMap<usize, Option<Arc<Plan>>>>,
}

impl Executable for ReferenceExecutable {
    fn name(&self) -> &str {
        &self.spec.tag
    }

    fn execute(&self, weights: &DeviceWeights, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let w = weights.host().context("reference backend executes host weights")?;
        // Re-binding per call is O(param-count) metadata work plus the
        // decay sigmoids — negligible next to the scan at fixture dims,
        // and it keeps DeviceWeights free of self-referential borrows.
        let model = RefModel::bind(&self.spec.model, w)?;
        match self.spec.kind {
            ProgramKind::Eval => self.eval(&model, inputs),
            ProgramKind::Prefill => self.prefill(&model, inputs),
            ProgramKind::Decode => self.decode(&model, inputs),
            ProgramKind::Train => bail!(
                "the reference backend does not implement the fused train step; \
                 train with the pjrt backend and real artifacts"
            ),
        }
    }

    fn execute_raw(&self, _inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        bail!(
            "raw (train-step) execution is not supported by the reference backend; \
             build with --features pjrt and run against real artifacts"
        )
    }
}

impl ReferenceExecutable {
    /// The reduction schedule for a sequence of true length `len`
    /// (DESIGN.md §6/§10). Dense programs return `None`. For reduced
    /// programs, a full-frame sequence uses the exported plan verbatim
    /// (bit-compatibility with the fixed-length path); any other length
    /// re-solves the same `(locations, target ratio)` schedule on the true
    /// length — the target is the variant's ratio, so a short prompt
    /// prefilled in a padded frame gets the *identical* plan an exact-length
    /// export would carry. For the legacy no-policy case (a hand-built spec
    /// with a plan but no reduction block) the variant ratio does not
    /// exist; the exported plan's *achieved* `flops_reduction` stands in as
    /// the target — a documented approximation (the original solve target
    /// is not recorded in the manifest), within solver tolerance of it by
    /// construction. Lengths the solver cannot reduce within tolerance (a
    /// 2-token prompt cannot shed 20% of its FLOPs) fall back to dense
    /// rather than failing the request. Solutions are cached per length.
    fn plan_for_len(&self, len: usize) -> Option<Arc<Plan>> {
        let base = self.spec.plan.as_ref()?;
        let mut cache = self.plans.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(hit) = cache.get(&len) {
            return hit.clone();
        }
        let solved = if len == base.seq_len {
            Some(Arc::new(base.clone()))
        } else {
            let dims = ModelDims::from_manifest(&self.spec.model);
            let ratio = self.spec.policy.as_ref().map(|p| p.ratio).unwrap_or(base.flops_reduction);
            solve_schedule(&dims, len, &base.locations, ratio).ok().map(|sp| {
                Arc::new(Plan {
                    seq_len: sp.seq_len,
                    locations: sp.locations,
                    seg_lens: sp.seg_lens,
                    removed: sp.removed,
                    flops_reduction: sp.flops_reduction,
                })
            })
        };
        cache.insert(len, solved.clone());
        solved
    }

    fn eval(&self, m: &RefModel, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let spec = &self.spec;
        ensure!(inputs.len() == 1, "eval executable expects one (tokens) input");
        let toks = inputs[0].as_i32()?;
        let (b, l, out_len, v) = (spec.batch, spec.seq_len, spec.out_len, m.vocab);
        ensure!(
            inputs[0].shape == vec![b, l],
            "tokens shape {:?} != [{b}, {l}]",
            inputs[0].shape
        );
        let mode = kernels::mode();
        // Sequences are independent: fan the batch out across the worker
        // pool (ordered collection keeps output identity at any width).
        let seqs = crate::util::pool::par_map(b, pool::workers().min(b.max(1)), |bi| {
            let fwd = forward(
                m,
                &toks[bi * l..(bi + 1) * l],
                spec.plan.as_ref(),
                self.policy.as_deref(),
                None,
            )?;
            ensure!(
                fwd.kept.len() == out_len,
                "{}: reduction left {} surviving positions, spec says {out_len}",
                spec.tag,
                fwd.kept.len()
            );
            let mut logits = vec![0.0f32; out_len * v];
            head_rows(m, mode, &fwd.xs[..out_len * m.d], &mut logits);
            Ok((fwd.kept, logits))
        });
        let mut logits = vec![0.0f32; b * out_len * v];
        let mut kept_out = vec![0i32; b * out_len];
        for (bi, seq) in seqs.into_iter().enumerate() {
            let (kept, lg) = seq?;
            for (t, &pos) in kept.iter().enumerate() {
                kept_out[bi * out_len + t] = pos as i32;
            }
            logits[bi * out_len * v..(bi + 1) * out_len * v].copy_from_slice(&lg);
        }
        Ok(vec![
            HostTensor::f32(vec![b, out_len, v], logits),
            HostTensor::i32(vec![b, out_len], kept_out),
        ])
    }

    /// Prefill one frame: `(tokens[b, l][, lengths[b][, conv0, ssm0]])` →
    /// `(logits[b, v], conv, ssm)` (DESIGN.md §6).
    ///
    /// * `lengths[i]` is sequence `i`'s true token count within the frame
    ///   (`0..=l`). The conv window and scan stop at that true end, the
    ///   logits row is taken at the true last token, and the reduction
    ///   schedule is re-solved on the true length ([`Self::plan_for_len`]).
    ///   A length of 0 marks an idle lane: its state/logits outputs are
    ///   zero and the caller ignores them. Without a lengths input every
    ///   sequence spans the full frame (the legacy single-input contract —
    ///   AOT parity, and what eval-style direct callers use).
    /// * `conv0`/`ssm0` (frame-shaped, as returned by this call) resume a
    ///   chunked prefill: each lane's per-layer conv tail + scan state
    ///   carry in from the previous chunk instead of starting at zero.
    ///   An all-zero lane in the resume frames is bit-identical to passing
    ///   no resume input at all (the forward seeds zero state either way),
    ///   which is what lets the engine mix resumed and cold lanes in one
    ///   frame, and what the prefix-state cache (DESIGN.md §12) relies on:
    ///   a snapshot captured at a chunk boundary, written back here later,
    ///   reproduces the uninterrupted run exactly.
    fn prefill(&self, m: &RefModel, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let spec = &self.spec;
        ensure!(
            matches!(inputs.len(), 1 | 2 | 4),
            "prefill executable expects (tokens[, lengths[, conv0, ssm0]]), got {} inputs",
            inputs.len()
        );
        let toks = inputs[0].as_i32()?;
        let (b, l, v) = (spec.batch, spec.seq_len, m.vocab);
        ensure!(
            inputs[0].shape == vec![b, l],
            "tokens shape {:?} != [{b}, {l}]",
            inputs[0].shape
        );
        let (conv_shape, ssm_shape) = crate::runtime::decode_state_shapes(&self.spec.model, b);
        let k1 = D_CONV - 1;
        let conv_row = m.conv_ch * k1;
        let ssm_row = m.di * m.n;
        let lengths: Vec<usize> = if inputs.len() >= 2 {
            ensure!(inputs[1].shape == vec![b], "lengths shape {:?} != [{b}]", inputs[1].shape);
            let lv = inputs[1].as_i32()?;
            for &x in lv {
                ensure!(
                    x >= 0 && (x as usize) <= l,
                    "sequence length {x} outside the prefill frame 0..={l}"
                );
            }
            lv.iter().map(|&x| x as usize).collect()
        } else {
            vec![l; b]
        };
        let init = if inputs.len() == 4 {
            ensure!(
                inputs[2].shape == conv_shape,
                "resume conv state shape {:?} != {:?}",
                inputs[2].shape,
                conv_shape
            );
            ensure!(
                inputs[3].shape == ssm_shape,
                "resume ssm state shape {:?} != {:?}",
                inputs[3].shape,
                ssm_shape
            );
            Some((inputs[2].as_f32()?, inputs[3].as_f32()?))
        } else {
            None
        };
        let mode = kernels::mode();
        let seqs = crate::util::pool::par_map(b, pool::workers().min(b.max(1)), |bi| {
            let len = lengths[bi];
            if len == 0 {
                return Ok(None); // idle lane: zero state + logits, ignored
            }
            let plan = self.plan_for_len(len);
            let init_seq = init.map(|(cf, sf)| {
                let mut c = vec![0.0f32; m.n_layer * conv_row];
                read_lane(cf, m.n_layer, b, conv_row, bi, &mut c);
                let mut s = vec![0.0f32; m.n_layer * ssm_row];
                read_lane(sf, m.n_layer, b, ssm_row, bi, &mut s);
                (c, s)
            });
            let fwd = forward(
                m,
                &toks[bi * l..bi * l + len],
                plan.as_deref(),
                self.policy.as_deref(),
                init_seq.as_ref().map(|(c, s)| (c.as_slice(), s.as_slice())),
            )?;
            ensure!(!fwd.kept.is_empty(), "prefill reduced the sequence to nothing");
            let last = fwd.kept.len() - 1;
            let mut logits = vec![0.0f32; v];
            head_rows(m, mode, &fwd.xs[last * m.d..(last + 1) * m.d], &mut logits);
            Ok(Some((fwd.states, logits)))
        });
        let mut logits = vec![0.0f32; b * v];
        let mut conv = vec![0.0f32; m.n_layer * b * conv_row];
        let mut ssm = vec![0.0f32; m.n_layer * b * ssm_row];
        for (bi, seq) in seqs.into_iter().enumerate() {
            let Some((states, lg)) = seq? else { continue };
            logits[bi * v..(bi + 1) * v].copy_from_slice(&lg);
            for (li, (tail, h)) in states.iter().enumerate() {
                let cstart = (li * b + bi) * conv_row;
                conv[cstart..cstart + conv_row].copy_from_slice(tail);
                let sstart = (li * b + bi) * ssm_row;
                ssm[sstart..sstart + ssm_row].copy_from_slice(h);
            }
        }
        Ok(vec![
            HostTensor::f32(vec![b, v], logits),
            HostTensor::f32(conv_shape, conv),
            HostTensor::f32(ssm_shape, ssm),
        ])
    }

    fn decode(&self, m: &RefModel, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let spec = &self.spec;
        ensure!(inputs.len() == 3, "decode executable expects (tokens, conv, ssm)");
        let tokens = inputs[0].as_i32()?;
        let b = spec.batch;
        let v = m.vocab;
        ensure!(
            inputs[0].shape == vec![b],
            "decode tokens shape {:?} != [{b}]",
            inputs[0].shape
        );
        let (conv_shape, ssm_shape) = crate::runtime::decode_state_shapes(&self.spec.model, b);
        ensure!(
            inputs[1].shape == conv_shape,
            "conv state shape {:?} != {:?}",
            inputs[1].shape,
            conv_shape
        );
        ensure!(
            inputs[2].shape == ssm_shape,
            "ssm state shape {:?} != {:?}",
            inputs[2].shape,
            ssm_shape
        );
        // Validate every lane before any state mutates, so a bad token
        // cannot leave a half-advanced frame behind. IDLE_LANE marks a lane
        // with no resident sequence: it is skipped entirely by decode_lanes
        // (state untouched, logits zero) instead of decoding a phantom
        // token through the full model.
        for &t in tokens {
            ensure!(
                t == IDLE_LANE || (t >= 0 && (t as usize) < v),
                "decode token {t} outside vocab {v}"
            );
        }
        let mut conv = inputs[1].as_f32()?.to_vec();
        let mut ssm = inputs[2].as_f32()?.to_vec();
        let k1 = D_CONV - 1;
        let conv_row = m.conv_ch * k1;
        let ssm_row = m.di * m.n;
        let mut logits = vec![0.0f32; b * v];

        // Shard the frame's lanes across the worker pool: each worker owns
        // a contiguous lane range of every layer (no-copy chunk views) and
        // advances its lanes with per-lane math only — bit-identical at
        // every worker count (PERFORMANCE.md).
        let mode = kernels::mode();
        let bounds = pool::partition(b, pool::workers().min(b.max(1)));
        let conv_chunks = lane_chunks_mut(&mut conv, m.n_layer, b, conv_row, &bounds);
        let ssm_chunks = lane_chunks_mut(&mut ssm, m.n_layer, b, ssm_row, &bounds);
        let mut logit_chunks = Vec::with_capacity(bounds.len());
        let mut rest = logits.as_mut_slice();
        for r in &bounds {
            let (head, tail) = rest.split_at_mut(r.len() * v);
            logit_chunks.push(head);
            rest = tail;
        }
        let tasks: Vec<_> = bounds
            .iter()
            .cloned()
            .zip(conv_chunks)
            .zip(ssm_chunks)
            .zip(logit_chunks)
            .map(|(((lanes, cv), sv), lg)| (lanes, cv, sv, lg))
            .collect();
        pool::run_sharded(tasks, |(lanes, mut cv, mut sv, lg)| {
            decode_lanes(m, mode, &tokens[lanes], &mut cv, &mut sv, lg);
        });

        Ok(vec![
            HostTensor::f32(vec![b, v], logits),
            HostTensor::f32(conv_shape, conv),
            HostTensor::f32(ssm_shape, ssm),
        ])
    }
}

// ---------------------------------------------------------------------------
// Bound model view + math kernels
// ---------------------------------------------------------------------------

/// A param's quantized view: `(i8 blob, per-channel scales)`, present when
/// the uploaded weights carry int8 blobs for it.
type QuantRef<'a> = (&'a [i8], &'a [f32]);

struct RefLayer<'a> {
    norm: &'a [f32],
    in_proj: &'a [f32],
    /// int8 view of `in_proj` (per-column scales), when quantized.
    in_proj_q: Option<QuantRef<'a>>,
    conv_w: &'a [f32],
    conv_b: &'a [f32],
    /// mamba only: maps post-conv `u` to `[B, C]`.
    bc_proj: Option<&'a [f32]>,
    d_skip: &'a [f32],
    out_proj: &'a [f32],
    /// int8 view of `out_proj` (per-column scales), when quantized.
    out_proj_q: Option<QuantRef<'a>>,
    /// sigmoid(a_log), precomputed: per-(channel, state) decay in (0, 1).
    decay: Vec<f32>,
}

impl<'a> RefLayer<'a> {
    fn in_proj_ref(&self) -> MatRef<'a> {
        match self.in_proj_q {
            Some((q, scales)) => MatRef::I8 { q, scales },
            None => MatRef::F32(self.in_proj),
        }
    }

    fn out_proj_ref(&self) -> MatRef<'a> {
        match self.out_proj_q {
            Some((q, scales)) => MatRef::I8 { q, scales },
            None => MatRef::F32(self.out_proj),
        }
    }
}

struct RefModel<'a> {
    d: usize,
    di: usize,
    n: usize,
    vocab: usize,
    n_layer: usize,
    mamba2: bool,
    /// conv channels: di (mamba) or di + 2n (mamba2).
    conv_ch: usize,
    /// in-projection width: 2di (mamba) or 2di + 2n (mamba2).
    proj_w: usize,
    embed: &'a [f32],
    /// int8 view of the tied embedding (per-row scales — one scale serves
    /// both the head dot and the embedding-row lookup), when quantized.
    embed_q: Option<QuantRef<'a>>,
    norm_f: &'a [f32],
    layers: Vec<RefLayer<'a>>,
}

impl<'a> RefModel<'a> {
    fn bind(me: &ModelEntry, w: &'a Weights) -> Result<RefModel<'a>> {
        ensure!(
            w.tensors.len() == me.params.len(),
            "{}: {} weight tensors for {} manifest params",
            me.name,
            w.tensors.len(),
            me.params.len()
        );
        let mut index: HashMap<&str, usize> = HashMap::new();
        for (i, p) in me.params.iter().enumerate() {
            index.insert(p.name.as_str(), i);
        }
        let get = |name: &str, shape: &[usize]| -> Result<&'a [f32]> {
            let i = *index.get(name).with_context(|| {
                format!(
                    "param {name:?} not in {}'s layout — the reference backend needs \
                     reference-layout weights (see fixtures); AOT artifact blobs \
                     belong to the pjrt backend",
                    me.name
                )
            })?;
            let t = &w.tensors[i];
            ensure!(
                t.shape == shape,
                "param {name}: shape {:?} != expected {shape:?}",
                t.shape
            );
            t.as_f32()
        };
        // The optional int8 view of a quantized param, validated against
        // the same expected shape (per-row or per-column scales).
        let getq = |name: &str, rows: usize, cols: usize| -> Result<Option<QuantRef<'a>>> {
            let Some(qt) = w.quant_of(name) else { return Ok(None) };
            ensure!(
                qt.shape == [rows, cols],
                "quant param {name}: shape {:?} != expected [{rows}, {cols}]",
                qt.shape
            );
            let want_scales = match qt.axis {
                QuantAxis::Row => rows,
                QuantAxis::Col => cols,
            };
            ensure!(
                qt.q.len() == rows * cols && qt.scales.len() == want_scales,
                "quant param {name}: blob {} / scales {} sized wrong",
                qt.q.len(),
                qt.scales.len()
            );
            Ok(Some((qt.q.as_slice(), qt.scales.as_slice())))
        };

        let (d, di, n, vocab, nl) = (me.d_model, me.d_inner, me.d_state, me.vocab_size, me.n_layer);
        let mamba2 = me.arch != "mamba";
        let conv_ch = if mamba2 { di + 2 * n } else { di };
        let proj_w = if mamba2 { 2 * di + 2 * n } else { 2 * di };

        let embed = get("embedding", &[vocab, d])?;
        let embed_q = getq("embedding", vocab, d)?;
        let norm_f = get("norm_f", &[d])?;
        let mut layers = Vec::with_capacity(nl);
        for l in 0..nl {
            let a_log = get(&format!("layers.{l}.a_log"), &[di, n])?;
            layers.push(RefLayer {
                norm: get(&format!("layers.{l}.norm"), &[d])?,
                in_proj: get(&format!("layers.{l}.in_proj"), &[d, proj_w])?,
                in_proj_q: getq(&format!("layers.{l}.in_proj"), d, proj_w)?,
                conv_w: get(&format!("layers.{l}.conv_w"), &[conv_ch, D_CONV])?,
                conv_b: get(&format!("layers.{l}.conv_b"), &[conv_ch])?,
                bc_proj: if mamba2 {
                    None
                } else {
                    Some(get(&format!("layers.{l}.bc_proj"), &[di, 2 * n])?)
                },
                d_skip: get(&format!("layers.{l}.d_skip"), &[di])?,
                out_proj: get(&format!("layers.{l}.out_proj"), &[di, d])?,
                out_proj_q: getq(&format!("layers.{l}.out_proj"), di, d)?,
                decay: a_log.iter().map(|&a| sigmoid(a)).collect(),
            });
        }
        Ok(RefModel {
            d,
            di,
            n,
            vocab,
            n_layer: nl,
            mamba2,
            conv_ch,
            proj_w,
            embed,
            embed_q,
            norm_f,
            layers,
        })
    }

    fn embed_ref(&self) -> MatRef<'a> {
        match self.embed_q {
            Some((q, scales)) => MatRef::I8 { q, scales },
            None => MatRef::F32(self.embed),
        }
    }

    /// Write token `tok`'s embedding row into `dst` — the f32 row verbatim,
    /// or the dequantized int8 row (`scale[tok] · q[tok][c]`) so the
    /// residual stream every tier seeds from is the same under int8.
    fn embed_row(&self, tok: usize, dst: &mut [f32]) {
        let d = self.d;
        match self.embed_q {
            Some((q, scales)) => {
                let row = &q[tok * d..(tok + 1) * d];
                let s = scales[tok];
                for (o, &v) in dst.iter_mut().zip(row) {
                    *o = s * v as f32;
                }
            }
            None => dst.copy_from_slice(&self.embed[tok * d..(tok + 1) * d]),
        }
    }

    /// [`Self::embed_row`], appending to a growing buffer (prefill path).
    fn push_embed_row(&self, tok: usize, out: &mut Vec<f32>) {
        let d = self.d;
        match self.embed_q {
            Some((q, scales)) => {
                let s = scales[tok];
                out.extend(q[tok * d..(tok + 1) * d].iter().map(|&v| s * v as f32));
            }
            None => out.extend_from_slice(&self.embed[tok * d..(tok + 1) * d]),
        }
    }
}

/// Single-token scratch for the scalar path.
struct Scratch {
    xn: Vec<f32>,
    proj: Vec<f32>,
    conv: Vec<f32>,
    u: Vec<f32>,
    b: Vec<f32>,
    c: Vec<f32>,
    y: Vec<f32>,
    /// int8 out-projection accumulator (unscaled), `d` floats.
    oacc: Vec<f32>,
}

impl Scratch {
    fn new(m: &RefModel) -> Scratch {
        Scratch {
            xn: vec![0.0; m.d],
            proj: vec![0.0; m.proj_w],
            conv: vec![0.0; m.conv_ch],
            u: vec![0.0; m.di],
            b: vec![0.0; m.n],
            c: vec![0.0; m.n],
            y: vec![0.0; m.di],
            oacc: vec![0.0; m.d],
        }
    }
}

/// Block scratch for the fused path: one buffer per fusion stage, sized
/// for `nt` rows (tokens of a sequence block, or lanes of a decode chunk).
struct BlockScratch {
    inv: Vec<f32>,
    proj: Vec<f32>,
    conv: Vec<f32>,
    u: Vec<f32>,
    b: Vec<f32>,
    c: Vec<f32>,
    y: Vec<f32>,
    /// int8 out-projection accumulator (unscaled), `nt × d` floats.
    oacc: Vec<f32>,
    nt: usize,
}

impl BlockScratch {
    fn new(m: &RefModel, nt: usize) -> BlockScratch {
        BlockScratch {
            inv: vec![0.0; nt],
            proj: vec![0.0; nt * m.proj_w],
            conv: vec![0.0; nt * m.conv_ch],
            u: vec![0.0; nt * m.di],
            b: vec![0.0; nt * m.n],
            c: vec![0.0; nt * m.n],
            y: vec![0.0; nt * m.di],
            oacc: vec![0.0; nt * m.d],
            nt,
        }
    }
}

/// One token through one layer, updating the residual `x`, the conv tail,
/// and the scan state in place — the scalar oracle the fused kernels are
/// pinned against bit-for-bit.
fn layer_step(m: &RefModel, l: usize, x: &mut [f32], tail: &mut [f32], h: &mut [f32], s: &mut Scratch) {
    let (d, di, n) = (m.d, m.di, m.n);
    let layer = &m.layers[l];
    let k1 = D_CONV - 1;

    rmsnorm(x, layer.norm, &mut s.xn);

    // in-projection. The int8 arm accumulates the unscaled i8 rank-1
    // updates in the same ascending order as f32, then applies the
    // per-column scales once at the end — the exact structure of the fused
    // kernel's I8 arm, so int8 is bit-identical across tiers.
    let pw = m.proj_w;
    for p in s.proj.iter_mut() {
        *p = 0.0;
    }
    match layer.in_proj_ref() {
        MatRef::F32(wp) => {
            for c in 0..d {
                let xc = s.xn[c];
                let row = &wp[c * pw..(c + 1) * pw];
                for j in 0..pw {
                    s.proj[j] += xc * row[j];
                }
            }
        }
        MatRef::I8 { q, scales } => {
            for c in 0..d {
                let xc = s.xn[c];
                let row = &q[c * pw..(c + 1) * pw];
                for j in 0..pw {
                    s.proj[j] += xc * row[j] as f32;
                }
            }
            for j in 0..pw {
                s.proj[j] *= scales[j];
            }
        }
    }

    // depthwise causal conv + tail update
    for ch in 0..m.conv_ch {
        let cur = if ch < di { s.proj[ch] } else { s.proj[2 * di + (ch - di)] };
        let w = &layer.conv_w[ch * D_CONV..(ch + 1) * D_CONV];
        let t = &mut tail[ch * k1..(ch + 1) * k1];
        let mut acc = layer.conv_b[ch] + w[k1] * cur;
        for j in 0..k1 {
            acc += w[j] * t[j];
        }
        for j in 0..k1 - 1 {
            t[j] = t[j + 1];
        }
        t[k1 - 1] = cur;
        s.conv[ch] = acc;
    }

    // activations + selectivity parameters
    for i in 0..di {
        s.u[i] = silu(s.conv[i]);
    }
    if m.mamba2 {
        s.b.copy_from_slice(&s.conv[di..di + n]);
        s.c.copy_from_slice(&s.conv[di + n..di + 2 * n]);
    } else {
        let bc = layer.bc_proj.expect("mamba layer carries bc_proj");
        for j in 0..n {
            s.b[j] = 0.0;
            s.c[j] = 0.0;
        }
        for i in 0..di {
            let ui = s.u[i];
            let row = &bc[i * 2 * n..(i + 1) * 2 * n];
            for j in 0..n {
                s.b[j] += ui * row[j];
                s.c[j] += ui * row[n + j];
            }
        }
    }

    // selective scan + emit, gated by silu(z)
    for i in 0..di {
        let ui = s.u[i];
        let hrow = &mut h[i * n..(i + 1) * n];
        let drow = &layer.decay[i * n..(i + 1) * n];
        let mut acc = 0.0f32;
        for j in 0..n {
            hrow[j] = drow[j] * hrow[j] + ui * s.b[j];
            acc += hrow[j] * s.c[j];
        }
        let z = s.proj[di + i];
        s.y[i] = (acc + layer.d_skip[i] * ui) * silu(z);
    }

    // out-projection back into the residual stream (int8: unscaled
    // accumulate into `oacc`, per-column scale on the way into `x` —
    // mirrors `kernels::outproj_acc`'s I8 arm).
    match layer.out_proj_ref() {
        MatRef::F32(wp) => {
            for i in 0..di {
                let yi = s.y[i];
                let row = &wp[i * d..(i + 1) * d];
                for c in 0..d {
                    x[c] += yi * row[c];
                }
            }
        }
        MatRef::I8 { q, scales } => {
            let oacc = &mut s.oacc[..d];
            oacc.fill(0.0);
            for i in 0..di {
                let yi = s.y[i];
                let row = &q[i * d..(i + 1) * d];
                for c in 0..d {
                    oacc[c] += yi * row[c] as f32;
                }
            }
            for c in 0..d {
                x[c] += oacc[c] * scales[c];
            }
        }
    }
}

/// How a fused block's `nt` rows relate to the layer state:
/// `Seq` — sequential tokens of one sequence; the conv window (`conv_ch ×
/// k1`) and scan state (`di × n`) evolve across rows and carry in/out;
/// `Batch` — independent decode lanes; each row owns its own window/state
/// row inside contiguous `nt ×`-sized chunk slices.
#[derive(Clone, Copy)]
enum BlockKind {
    Seq,
    Batch,
}

/// A block of `nt` rows through one layer via the fused kernels — the one
/// 6-stage pipeline both the sequence (prefill/eval) and the decode-chunk
/// paths share; only the conv and scan kernels dispatch on `kind`, so the
/// seq-vs-batch bit-identity contract has a single pipeline to drift from.
#[allow(clippy::too_many_arguments)]
fn layer_block(
    m: &RefModel,
    l: usize,
    kind: BlockKind,
    xs: &mut [f32],
    conv_state: &mut [f32],
    ssm_state: &mut [f32],
    s: &mut BlockScratch,
    nt: usize,
    simd: bool,
) {
    debug_assert!(nt <= s.nt);
    let layer = &m.layers[l];
    let (pw, di, n) = (m.proj_w, m.di, m.n);
    let proj = &mut s.proj[..nt * pw];
    kernels::fused_rmsnorm_inproj(
        xs,
        layer.norm,
        layer.in_proj_ref(),
        nt,
        m.d,
        pw,
        proj,
        &mut s.inv,
        simd,
    );
    let conv = &mut s.conv[..nt * m.conv_ch];
    match kind {
        BlockKind::Seq => {
            kernels::causal_conv_seq(proj, pw, di, layer.conv_w, layer.conv_b, conv_state, conv, nt)
        }
        BlockKind::Batch => kernels::causal_conv_batch(
            proj,
            pw,
            di,
            layer.conv_w,
            layer.conv_b,
            conv_state,
            conv,
            nt,
        ),
    }
    let u = &mut s.u[..nt * di];
    kernels::silu_channels(conv, m.conv_ch, di, u, nt);
    let (bs, cs) = (&mut s.b[..nt * n], &mut s.c[..nt * n]);
    if m.mamba2 {
        kernels::copy_bc_channels(conv, m.conv_ch, di, n, bs, cs, nt);
    } else {
        let bc = layer.bc_proj.expect("mamba layer carries bc_proj");
        kernels::bc_project(u, bc, n, bs, cs, nt, simd);
    }
    let y = &mut s.y[..nt * di];
    match kind {
        BlockKind::Seq => kernels::scan_gate_seq(
            u,
            bs,
            cs,
            proj,
            pw,
            &layer.decay,
            layer.d_skip,
            n,
            ssm_state,
            y,
            nt,
            simd,
        ),
        BlockKind::Batch => kernels::scan_gate_batch(
            u,
            bs,
            cs,
            proj,
            pw,
            &layer.decay,
            layer.d_skip,
            n,
            ssm_state,
            y,
            nt,
            simd,
        ),
    }
    kernels::outproj_acc(y, layer.out_proj_ref(), m.d, xs, &mut s.oacc, nt, simd);
}

/// Maximal runs of non-idle lanes in a decode chunk: the sub-ranges the
/// fused path feeds through the batch kernels. A fully-occupied chunk is a
/// single run covering every lane — the pre-skip code path, verbatim.
fn active_runs(toks: &[i32]) -> Vec<std::ops::Range<usize>> {
    let mut runs = Vec::new();
    let mut start = None;
    for (i, &t) in toks.iter().enumerate() {
        if t == IDLE_LANE {
            if let Some(s) = start.take() {
                runs.push(s..i);
            }
        } else if start.is_none() {
            start = Some(i);
        }
    }
    if let Some(s) = start {
        runs.push(s..toks.len());
    }
    runs
}

/// Advance `nt` decode lanes one token each. Every lane's per-layer conv
/// window and scan state live in the chunk views; logits land in `lg`
/// (`nt × vocab`). Tokens are pre-validated by the caller.
///
/// Lanes whose token is [`IDLE_LANE`] hold no sequence and are skipped
/// outright: their state stays untouched and their logits row stays zero.
/// Because the batch kernels are per-lane independent (pinned by the
/// no-crosstalk kernel tests), skipping an idle lane is bit-invisible to
/// every occupied lane — it only removes the wasted full-model decode of a
/// phantom token that idle frame slots used to pay each step.
fn decode_lanes(
    m: &RefModel,
    mode: KernelMode,
    toks: &[i32],
    conv: &mut LaneChunkMut,
    ssm: &mut LaneChunkMut,
    lg: &mut [f32],
) {
    let nt = toks.len();
    if nt == 0 {
        return;
    }
    let (d, v) = (m.d, m.vocab);
    let k1 = D_CONV - 1;
    let conv_row = m.conv_ch * k1;
    let ssm_row = m.di * m.n;
    match mode {
        KernelMode::Scalar => {
            let mut scratch = Scratch::new(m);
            let mut xn = vec![0.0f32; d];
            let mut x = vec![0.0f32; d];
            for (t, &tok) in toks.iter().enumerate() {
                if tok == IDLE_LANE {
                    continue;
                }
                m.embed_row(tok as usize, &mut x);
                for li in 0..m.n_layer {
                    let tails = conv.layer_mut(li);
                    let hs = ssm.layer_mut(li);
                    layer_step(
                        m,
                        li,
                        &mut x,
                        &mut tails[t * conv_row..(t + 1) * conv_row],
                        &mut hs[t * ssm_row..(t + 1) * ssm_row],
                        &mut scratch,
                    );
                }
                head_logits(m, &x, &mut xn, &mut lg[t * v..(t + 1) * v]);
            }
        }
        KernelMode::Fused | KernelMode::Simd => {
            let simd = matches!(mode, KernelMode::Simd);
            let runs = active_runs(toks);
            let Some(max_run) = runs.iter().map(|r| r.len()).max() else {
                return; // every lane idle: nothing to decode
            };
            let mut s = BlockScratch::new(m, max_run);
            let mut xs = vec![0.0f32; nt * d];
            for r in &runs {
                for t in r.clone() {
                    m.embed_row(toks[t] as usize, &mut xs[t * d..(t + 1) * d]);
                }
            }
            for li in 0..m.n_layer {
                let tails = conv.layer_mut(li);
                let hs = ssm.layer_mut(li);
                for r in &runs {
                    layer_block(
                        m,
                        li,
                        BlockKind::Batch,
                        &mut xs[r.start * d..r.end * d],
                        &mut tails[r.start * conv_row..r.end * conv_row],
                        &mut hs[r.start * ssm_row..r.end * ssm_row],
                        &mut s,
                        r.len(),
                        simd,
                    );
                }
            }
            for r in &runs {
                head_rows(m, mode, &xs[r.start * d..r.end * d], &mut lg[r.start * v..r.end * v]);
            }
        }
    }
}

/// Final RMSNorm + tied embedding head for one residual row (scalar path).
/// The int8 arm is `dot8_i8 · scale[v]` — the exact expression every tier's
/// head uses for quantized embeddings, so int8 logits are tier-identical.
fn head_logits(m: &RefModel, x: &[f32], xn: &mut [f32], out: &mut [f32]) {
    rmsnorm(x, m.norm_f, xn);
    match m.embed_ref() {
        MatRef::F32(embed) => {
            for v in 0..m.vocab {
                let row = &embed[v * m.d..(v + 1) * m.d];
                let mut acc = 0.0f32;
                for c in 0..m.d {
                    acc += xn[c] * row[c];
                }
                out[v] = acc;
            }
        }
        MatRef::I8 { q, scales } => {
            for v in 0..m.vocab {
                let row = &q[v * m.d..(v + 1) * m.d];
                out[v] = kernels::dot8_i8(xn, row) * scales[v];
            }
        }
    }
}

/// Head logits for `xs.len()/d` contiguous residual rows, honouring the
/// kernel mode: scalar streams the embedding per row, fused streams it once
/// per [`kernels::TOKEN_BLOCK`] rows. Bit-identical either way.
fn head_rows(m: &RefModel, mode: KernelMode, xs: &[f32], out: &mut [f32]) {
    let nt = xs.len() / m.d;
    match mode {
        KernelMode::Scalar => {
            let mut xn = vec![0.0f32; m.d];
            for t in 0..nt {
                head_logits(
                    m,
                    &xs[t * m.d..(t + 1) * m.d],
                    &mut xn,
                    &mut out[t * m.vocab..(t + 1) * m.vocab],
                );
            }
        }
        KernelMode::Fused | KernelMode::Simd => {
            let simd = matches!(mode, KernelMode::Simd);
            let cap = nt.min(kernels::TOKEN_BLOCK).max(1);
            let mut xn = vec![0.0f32; cap * m.d];
            let mut at = 0usize;
            while at < nt {
                let bs = (nt - at).min(kernels::TOKEN_BLOCK);
                kernels::head_norm_logits(
                    &xs[at * m.d..(at + bs) * m.d],
                    m.norm_f,
                    m.embed_ref(),
                    m.vocab,
                    &mut out[at * m.vocab..(at + bs) * m.vocab],
                    &mut xn,
                    bs,
                    simd,
                );
                at += bs;
            }
        }
    }
}

struct ForwardOut {
    /// Final residual stream: `kept.len() × d`, row-major.
    xs: Vec<f32>,
    /// Surviving original positions, ascending.
    kept: Vec<usize>,
    /// Per-layer final (conv tail, scan state) for decode continuation.
    states: Vec<(Vec<f32>, Vec<f32>)>,
}

/// Per-mode forward scratch: exactly one of the two is allocated.
enum FwdScratch {
    Scalar(Scratch),
    Fused(BlockScratch),
}

/// Layer-major forward over one sequence, dispatching `policy` at the plan's
/// layer boundaries (DESIGN.md §10): after layer `locations[i]`, the live
/// set shrinks to `seg_lens[i+1]` rows, `kept` tracks surviving original
/// positions, and `merged` carries per-row fold weights across sites.
///
/// `init` makes the forward **resumable** (chunked prefill, DESIGN.md §6):
/// per-layer initial `(conv tails, scan states)` as contiguous
/// `[n_layer, conv_row]` / `[n_layer, ssm_row]` slices, carried in from a
/// previous chunk instead of starting at zero. Because the conv window and
/// the scan recurrence carry token-sequentially (and the residual stream is
/// per-token), splitting a dense sequence into chunks and resuming is
/// bit-identical to one uninterrupted forward — the same invariance the
/// block-boundary kernel tests pin within a call.
///
/// In fused mode each layer walks the live set in [`kernels::TOKEN_BLOCK`]
/// chunks through the staged kernels; the conv window and scan state carry
/// across chunks, so blocking is invisible in the results.
fn forward(
    m: &RefModel,
    tokens: &[i32],
    plan: Option<&Plan>,
    policy: Option<&dyn ReductionPolicy>,
    init: Option<(&[f32], &[f32])>,
) -> Result<ForwardOut> {
    let d = m.d;
    ensure!(!tokens.is_empty(), "empty token sequence");
    let k1 = D_CONV - 1;
    let conv_row = m.conv_ch * k1;
    let ssm_row = m.di * m.n;
    if let Some((c0, h0)) = init {
        ensure!(
            c0.len() == m.n_layer * conv_row && h0.len() == m.n_layer * ssm_row,
            "resume state sized [{}, {}], expected [{}, {}]",
            c0.len(),
            h0.len(),
            m.n_layer * conv_row,
            m.n_layer * ssm_row
        );
    }
    let mut xs: Vec<f32> = Vec::with_capacity(tokens.len() * d);
    for &t in tokens {
        ensure!(t >= 0 && (t as usize) < m.vocab, "token {t} outside vocab {}", m.vocab);
        m.push_embed_row(t as usize, &mut xs);
    }
    let mut kept: Vec<usize> = (0..tokens.len()).collect();
    let mut merged: Vec<f32> = vec![1.0; tokens.len()];
    let mut states = Vec::with_capacity(m.n_layer);
    let mode = kernels::mode();
    let simd = matches!(mode, KernelMode::Simd);
    let mut scratch = match mode {
        KernelMode::Scalar => FwdScratch::Scalar(Scratch::new(m)),
        KernelMode::Fused | KernelMode::Simd => {
            FwdScratch::Fused(BlockScratch::new(m, kernels::TOKEN_BLOCK.min(tokens.len())))
        }
    };
    for l in 0..m.n_layer {
        let mut tail = match init {
            Some((c0, _)) => c0[l * conv_row..(l + 1) * conv_row].to_vec(),
            None => vec![0.0f32; conv_row],
        };
        let mut h = match init {
            Some((_, h0)) => h0[l * ssm_row..(l + 1) * ssm_row].to_vec(),
            None => vec![0.0f32; ssm_row],
        };
        let live = kept.len();
        match &mut scratch {
            FwdScratch::Scalar(s) => {
                for t in 0..live {
                    layer_step(m, l, &mut xs[t * d..(t + 1) * d], &mut tail, &mut h, s);
                }
            }
            FwdScratch::Fused(s) => {
                let mut at = 0usize;
                while at < live {
                    let nt = (live - at).min(kernels::TOKEN_BLOCK);
                    let rows = &mut xs[at * d..(at + nt) * d];
                    layer_block(m, l, BlockKind::Seq, rows, &mut tail, &mut h, s, nt, simd);
                    at += nt;
                }
            }
        }
        states.push((tail, h));
        if let Some(p) = plan {
            if let Some(i) = p.locations.iter().position(|&loc| loc == l) {
                let target = *p
                    .seg_lens
                    .get(i + 1)
                    .with_context(|| format!("plan seg_lens too short at location {l}"))?;
                let pol = policy.context("program has a reduction plan but no policy")?;
                pol.reduce(&mut xs, &mut kept, &mut merged, target, d);
            }
        }
    }
    Ok(ForwardOut { xs, kept, states })
}

#[cfg(test)]
mod tests {
    use super::*;

    // The historical reduce_live_set behaviour now lives in
    // reduction::policy (legacy_default / Unified-l2); its exact-vector pin
    // is `policy::tests::unified_l2_matches_legacy_reduce_live_set`.
    // Scalar-vs-fused-vs-parallel bit-identity across the whole executable
    // surface is pinned end to end by `tests/kernels_identity.rs`.

    #[test]
    fn activations_behave() {
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-6);
        assert!(sigmoid(10.0) > 0.999 && sigmoid(-10.0) < 0.001);
        assert!(silu(0.0).abs() < 1e-6);
        let mut out = [0.0f32; 3];
        rmsnorm(&[3.0, 0.0, -4.0], &[1.0, 1.0, 1.0], &mut out);
        let ms: f32 = out.iter().map(|v| v * v).sum::<f32>() / 3.0;
        assert!((ms - 1.0).abs() < 1e-3, "rmsnorm should normalise energy, got {ms}");
    }
}
