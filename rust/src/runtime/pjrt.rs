//! PJRT/XLA backend (cargo feature `pjrt`): load `artifacts/*.hlo.txt`,
//! compile once, execute many.
//!
//! Pattern from /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`/`execute_b`. HLO *text* is the interchange
//! format (the 0.5.1 extension rejects jax≥0.5 64-bit-id protos).
//!
//! Hot-path discipline: weights are uploaded to device once
//! ([`DeviceWeights::Pjrt`]) and passed by reference to `execute_b`; only
//! the small activations (tokens in, logits out) cross the host boundary
//! per request.
//!
//! NOTE: in this offline image `crates/xla` is a type-compatible stub, so
//! `PjrtBackend::cpu()` fails at runtime with a clear message. Link the
//! real bindings crate (swap the path dependency in `rust/Cargo.toml`) to
//! use this backend.

use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::manifest::ModelEntry;
use crate::runtime::{
    Backend, DeviceWeights, Executable, HostTensor, ProgramSpec, TensorData, Weights,
};

pub struct PjrtBackend {
    client: Arc<xla::PjRtClient>,
}

impl PjrtBackend {
    pub fn cpu() -> Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtBackend { client: Arc::new(client) })
    }
}

fn upload(client: &xla::PjRtClient, t: &HostTensor) -> Result<xla::PjRtBuffer> {
    match &t.data {
        TensorData::F32(v) => client
            .buffer_from_host_buffer(v, &t.shape, None)
            .context("uploading f32 buffer"),
        TensorData::I32(v) => client
            .buffer_from_host_buffer(v, &t.shape, None)
            .context("uploading i32 buffer"),
    }
}

impl Backend for PjrtBackend {
    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile(&self, spec: &ProgramSpec) -> Result<Arc<dyn Executable>> {
        let path = spec.hlo_path.to_string_lossy().to_string();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path}"))?;
        Ok(Arc::new(PjrtExecutable {
            exe,
            tag: spec.tag.clone(),
            client: Arc::clone(&self.client),
        }))
    }

    fn upload_weights(&self, model: &ModelEntry, w: &Weights) -> Result<DeviceWeights> {
        ensure!(
            w.tensors.len() == model.params.len(),
            "weights/model param count mismatch"
        );
        let buffers = w
            .tensors
            .iter()
            .map(|t| upload(&self.client, t))
            .collect::<Result<Vec<_>>>()?;
        Ok(DeviceWeights::Pjrt(buffers))
    }
}

pub struct PjrtExecutable {
    exe: xla::PjRtLoadedExecutable,
    tag: String,
    client: Arc<xla::PjRtClient>,
}

impl Executable for PjrtExecutable {
    fn name(&self) -> &str {
        &self.tag
    }

    fn execute(&self, weights: &DeviceWeights, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let DeviceWeights::Pjrt(buffers) = weights else {
            bail!("pjrt executable needs device-resident (pjrt) weights");
        };
        // Weights stay device-resident; activations are uploaded per call.
        let owned: Vec<xla::PjRtBuffer> = inputs
            .iter()
            .map(|t| upload(&self.client, t))
            .collect::<Result<Vec<_>>>()?;
        let mut args: Vec<&xla::PjRtBuffer> = buffers.iter().collect();
        args.extend(owned.iter());
        let bufs = self.exe.execute_b(&args).context("execute_b")?;
        collect(bufs)
    }

    fn execute_raw(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let literals = inputs.iter().map(|t| to_literal(t)).collect::<Result<Vec<_>>>()?;
        let bufs = self.exe.execute(&literals).context("execute")?;
        collect(bufs)
    }
}

fn collect(bufs: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<HostTensor>> {
    ensure!(!bufs.is_empty() && !bufs[0].is_empty(), "empty execution result");
    // Single replica; the root is a tuple (lowered with return_tuple=True).
    let lit = bufs[0][0].to_literal_sync().context("download result")?;
    let parts = lit.to_tuple().context("decompose result tuple")?;
    parts.iter().map(from_literal).collect()
}

pub fn to_literal(t: &HostTensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    let lit = match &t.data {
        TensorData::F32(v) => xla::Literal::vec1(v),
        TensorData::I32(v) => xla::Literal::vec1(v),
    };
    lit.reshape(&dims).context("reshaping literal")
}

pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
    let shape = lit.array_shape().context("literal has no array shape")?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = match shape.ty() {
        xla::ElementType::F32 => TensorData::F32(lit.to_vec::<f32>()?),
        xla::ElementType::S32 => TensorData::I32(lit.to_vec::<i32>()?),
        other => bail!("unsupported element type {other:?}"),
    };
    let t = HostTensor { shape: dims, data };
    ensure!(
        t.len()
            == match &t.data {
                TensorData::F32(v) => v.len(),
                TensorData::I32(v) => v.len(),
            },
        "element count mismatch"
    );
    Ok(t)
}
