//! Fused, cache-blocked decode/forward kernels for the reference backend,
//! plus the process-wide [`KernelMode`] switch between the three tiers
//! (PERFORMANCE.md; DESIGN.md §11, §13).
//!
//! ## Why more than one implementation of the same math
//!
//! The scalar interpreter in [`reference`](super::reference) walks one token
//! through one layer at a time, re-streaming every weight matrix from memory
//! for every token. These kernels restructure the hot path around **token
//! blocks** (a block of `nt` residual rows moves through each fusion stage
//! together) so each weight matrix is streamed once per block instead of
//! once per token, and around **fusion** (RMSNorm folds into the
//! in-projection read, the SiLU gate folds into the scan emit, the output
//! projection accumulates straight into the residual rows) so intermediate
//! buffers stay block-sized and L1-resident. The `simd` tier keeps the
//! fused structure and lowers the per-token inner loops to AVX2+FMA
//! intrinsics when the CPU has them ([`simd_available`]), with portable
//! fallbacks that compute the **same bits** on any architecture.
//!
//! ## The determinism contract, per tier
//!
//! * `scalar` — the plain-loop oracle every other configuration is pinned
//!   against, and the baseline arm of `benches/runtime.rs`.
//! * `fused` — **bit-identical** to scalar, by construction, not by
//!   tolerance: blocking only re-tiles loops over *independent* outputs
//!   (tokens × output channels), so for every accumulated scalar the
//!   sequence of f32 operations — and therefore every intermediate
//!   rounding — is exactly the scalar path's sequence; recurrent state
//!   (the conv window, the scan state `h`) is carried token-sequentially
//!   inside and across blocks, never reassociated; lane parallelism
//!   ([`pool`](super::pool)) only shards *which thread* computes a lane.
//! * `simd` — bit-identical to scalar **everywhere except the f32 head**:
//!   the rank-1 updates ([`axpy`]) and the scan state update
//!   ([`scan_gate_seq`]/[`scan_gate_batch`]) vectorize with the scalar
//!   expressions' exact rounding sequence (separate mul/add, never a
//!   contracted fma), so projections, conv, scan state, residuals and the
//!   reduction `kept` maps carry the same bits as scalar. The one
//!   reassociating reduction is [`head_norm_logits`] over f32 weights,
//!   which switches to the deterministic chunked dot [`dot8`]; its
//!   error-bound contract vs the ascending scalar sum —
//!   `|dot8 − ascending| ≤ 2·d·ε·Σ|xᵢ·yᵢ|`, ε = f32 machine epsilon — is
//!   documented in PERFORMANCE.md §Kernel tiers & weight formats and
//!   pinned by a unit test below. Only final logits can differ, within
//!   that bound.
//!
//! Int8 weights ([`MatRef::I8`], quantized per output channel in
//! [`weights`](super::weights)) change outputs vs f32 by quantization
//! error, but are **bit-identical across all three tiers** at any thread
//! count: every tier accumulates the unscaled i8 dot in the same order,
//! applies the per-channel scale once at the end, and the head uses the
//! shared [`dot8_i8`] reduction in every tier. `tests/kernels_identity.rs`
//! pins all of this end to end.
//!
//! All kernels take raw slices with explicit dims so they are testable
//! without a bound model; the reference backend wires them to its weight
//! views. `nt` is always the number of rows (tokens or decode lanes) in
//! the block.

use std::sync::atomic::{AtomicU8, Ordering};

use anyhow::{bail, Result};

/// Residual rows processed per block by the fused sequence path. Sized so a
/// block's scratch (`nt·proj_w` floats and friends) stays L1-resident at
/// every geometry we run; recurrent state carries across blocks, so the
/// value changes performance, never results.
pub const TOKEN_BLOCK: usize = 16;

// ---------------------------------------------------------------------------
// Kernel mode: scalar interpreter vs fused block kernels vs simd
// ---------------------------------------------------------------------------

/// Which implementation of the reference-backend math runs.
///
/// `Scalar` and `Fused` compute bit-identical results; `Simd` is
/// bit-identical except the f32 head reduction (see the module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelMode {
    /// The original one-token-at-a-time interpreter loops.
    Scalar,
    /// Cache-blocked, fused kernels (this module).
    Fused,
    /// The fused kernels with vectorized inner loops (AVX2+FMA when the
    /// CPU has them, bit-identical portable fallbacks otherwise).
    Simd,
}

impl KernelMode {
    /// Parse a mode name as used by `--kernels` and `TOR_SSM_KERNELS`.
    ///
    /// ```
    /// use tor_ssm::runtime::kernels::KernelMode;
    /// assert_eq!(KernelMode::from_name("scalar").unwrap(), KernelMode::Scalar);
    /// assert_eq!(KernelMode::from_name("fused").unwrap(), KernelMode::Fused);
    /// assert_eq!(KernelMode::from_name("simd").unwrap(), KernelMode::Simd);
    /// assert!(KernelMode::from_name("avx512").is_err());
    /// ```
    pub fn from_name(name: &str) -> Result<KernelMode> {
        match name {
            "scalar" => Ok(KernelMode::Scalar),
            "fused" | "" => Ok(KernelMode::Fused),
            "simd" => Ok(KernelMode::Simd),
            other => bail!("unknown kernel mode {other:?} (expected scalar|fused|simd)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            KernelMode::Scalar => "scalar",
            KernelMode::Fused => "fused",
            KernelMode::Simd => "simd",
        }
    }
}

/// Process-wide mode. 0 = unset (resolve from env on first read),
/// 1 = scalar, 2 = fused, 3 = simd.
static MODE: AtomicU8 = AtomicU8::new(0);

/// The `[warn] ignoring <VAR>: <parse error>; using <fallback>` line both
/// env knobs print for a typo'd value — a typo must not silently measure
/// the wrong configuration. Factored out so the unit tests can pin that
/// the warning enumerates the full accepted set.
pub(crate) fn ignored_env_warning(var: &str, e: &anyhow::Error, fallback: &str) -> String {
    format!("[warn] ignoring {var}: {e:#}; using {fallback}")
}

/// The active kernel mode. Defaults to [`KernelMode::Fused`]; the first
/// read honours `TOR_SSM_KERNELS=scalar|fused|simd`, and [`set_mode`]
/// overrides at any time (benches and the identity tests flip it between
/// runs).
pub fn mode() -> KernelMode {
    // ORDERING: Relaxed — idempotent env resolution; racing first reads
    // resolve identically, and the mode guards no other shared memory.
    match MODE.load(Ordering::Relaxed) {
        1 => KernelMode::Scalar,
        2 => KernelMode::Fused,
        3 => KernelMode::Simd,
        _ => {
            let m = match std::env::var("TOR_SSM_KERNELS") {
                Ok(v) => KernelMode::from_name(&v).unwrap_or_else(|e| {
                    eprintln!("{}", ignored_env_warning("TOR_SSM_KERNELS", &e, "fused"));
                    KernelMode::Fused
                }),
                Err(_) => KernelMode::Fused,
            };
            set_mode(m);
            m
        }
    }
}

/// Override the process-wide kernel mode.
///
/// ```
/// use tor_ssm::runtime::kernels::{mode, set_mode, KernelMode};
/// set_mode(KernelMode::Scalar);
/// assert_eq!(mode(), KernelMode::Scalar);
/// set_mode(KernelMode::Simd);
/// assert_eq!(mode(), KernelMode::Simd);
/// set_mode(KernelMode::Fused);
/// assert_eq!(mode(), KernelMode::Fused);
/// ```
pub fn set_mode(m: KernelMode) {
    let v = match m {
        KernelMode::Scalar => 1,
        KernelMode::Fused => 2,
        KernelMode::Simd => 3,
    };
    // ORDERING: Relaxed — standalone knob write, same contract as mode().
    MODE.store(v, Ordering::Relaxed);
}

/// One-line description of the active execution configuration
/// (`<mode> kernels, <format> weights, <n> decode thread(s)`), for
/// serve/bench banners.
pub fn exec_summary() -> String {
    format!(
        "{} kernels, {} weights, {} decode thread(s)",
        mode().name(),
        super::weights::format().name(),
        super::pool::workers()
    )
}

// ---------------------------------------------------------------------------
// SIMD substrate: feature probe + deterministic vector primitives
// ---------------------------------------------------------------------------

/// Cached CPU probe for the AVX2+FMA fast paths: 0 = unprobed, 1 = absent,
/// 2 = present.
static SIMD_CPU: AtomicU8 = AtomicU8::new(0);

/// Whether the AVX2+FMA intrinsic paths will be used. `simd` mode works —
/// and produces the same bits — either way (the portable fallbacks mirror
/// every rounding); this only selects speed, and is surfaced for tests and
/// bench metadata.
pub fn simd_available() -> bool {
    // ORDERING: Relaxed — idempotent CPU probe; every thread computes the
    // same answer, so the cache needs atomicity only, not ordering.
    match SIMD_CPU.load(Ordering::Relaxed) {
        1 => false,
        2 => true,
        _ => {
            #[cfg(target_arch = "x86_64")]
            let ok = is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma");
            #[cfg(not(target_arch = "x86_64"))]
            let ok = false;
            // ORDERING: Relaxed — caches the idempotent probe result above.
            SIMD_CPU.store(if ok { 2 } else { 1 }, Ordering::Relaxed);
            ok
        }
    }
}

/// Fixed 8-lane horizontal-sum tree shared by every [`dot8`]/[`dot8_i8`]
/// path. The tree shape is part of the determinism contract: both the
/// portable and the AVX2 reductions end in exactly this sequence of adds.
#[inline]
fn hsum8(l: [f32; 8]) -> f32 {
    ((l[0] + l[1]) + (l[2] + l[3])) + ((l[4] + l[5]) + (l[6] + l[7]))
}

/// Deterministic chunked dot product: 8 partial sums advance over chunks
/// of 8 via fused multiply-add, combine through the fixed [`hsum8`] tree,
/// and the tail (`len % 8`) folds in with scalar `mul_add`. The AVX2 path
/// computes the **same bits** (`_mm256_fmadd_ps` is lane-wise
/// `f32::mul_add`), so results never depend on the host CPU.
///
/// This reassociates relative to the ascending scalar sum, so it is used
/// only where the contract allows a tolerance (the f32 `simd` head) or
/// where it *is* the definition (the int8 head in every tier, via
/// [`dot8_i8`]). Error bound vs ascending order:
/// `|dot8 − ascending| ≤ 2·n·ε·Σ|xᵢ·yᵢ|` (pinned by a unit test).
pub fn dot8(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    if simd_available() {
        // SAFETY: AVX2+FMA presence verified by `simd_available`.
        return unsafe { avx2::dot8(x, y) };
    }
    dot8_portable(x, y)
}

fn dot8_portable(x: &[f32], y: &[f32]) -> f32 {
    let n8 = x.len() - x.len() % 8;
    let mut lanes = [0.0f32; 8];
    let mut k = 0;
    while k < n8 {
        for j in 0..8 {
            lanes[j] = x[k + j].mul_add(y[k + j], lanes[j]);
        }
        k += 8;
    }
    let mut total = hsum8(lanes);
    for i in n8..x.len() {
        total = x[i].mul_add(y[i], total);
    }
    total
}

/// [`dot8`] against an i8 row: `Σ x[i]·(q[i] as f32)`, same chunked
/// accumulation, same tree, same tail. The i8→f32 convert is exact, so the
/// portable and AVX2 paths are bit-identical here too. This is the head
/// reduction for int8 weights in **all** kernel tiers — cross-tier int8
/// identity is structural, not a tolerance claim.
pub fn dot8_i8(x: &[f32], q: &[i8]) -> f32 {
    debug_assert_eq!(x.len(), q.len());
    #[cfg(target_arch = "x86_64")]
    if simd_available() {
        // SAFETY: AVX2+FMA presence verified by `simd_available`.
        return unsafe { avx2::dot8_i8(x, q) };
    }
    dot8_i8_portable(x, q)
}

fn dot8_i8_portable(x: &[f32], q: &[i8]) -> f32 {
    let n8 = x.len() - x.len() % 8;
    let mut lanes = [0.0f32; 8];
    let mut k = 0;
    while k < n8 {
        for j in 0..8 {
            lanes[j] = x[k + j].mul_add(q[k + j] as f32, lanes[j]);
        }
        k += 8;
    }
    let mut total = hsum8(lanes);
    for i in n8..x.len() {
        total = x[i].mul_add(q[i] as f32, total);
    }
    total
}

/// `dst[j] += a·src[j]` as a separate multiply and add (two roundings —
/// the scalar rank-1 update's exact expression; deliberately **not** fma),
/// so vectorizing it never changes bits.
pub fn axpy(a: f32, src: &[f32], dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len());
    #[cfg(target_arch = "x86_64")]
    if simd_available() {
        // SAFETY: AVX2 presence verified by `simd_available`.
        unsafe { avx2::axpy(a, src, dst) };
        return;
    }
    for j in 0..dst.len() {
        dst[j] += a * src[j];
    }
}

/// [`axpy`] against an i8 row: `dst[j] += a·(src[j] as f32)` (exact
/// convert, then the same mul/add pair).
pub fn axpy_i8(a: f32, src: &[i8], dst: &mut [f32]) {
    debug_assert_eq!(src.len(), dst.len());
    #[cfg(target_arch = "x86_64")]
    if simd_available() {
        // SAFETY: AVX2 presence verified by `simd_available`.
        unsafe { avx2::axpy_i8(a, src, dst) };
        return;
    }
    for j in 0..dst.len() {
        dst[j] += a * src[j] as f32;
    }
}

/// The scan recurrence's state update `h[j] ← d[j]·h[j] + u·b[j]`, as
/// mul/mul/add — three roundings, the scalar expression's exact sequence —
/// in both the portable and the AVX2 path, so vectorizing the state update
/// never changes bits.
#[inline]
fn scan_update(drow: &[f32], hrow: &mut [f32], ui: f32, brow: &[f32]) {
    #[cfg(target_arch = "x86_64")]
    if simd_available() {
        // SAFETY: AVX2 presence verified by `simd_available`.
        unsafe { avx2::scan_update(drow, hrow, ui, brow) };
        return;
    }
    for j in 0..hrow.len() {
        hrow[j] = drow[j] * hrow[j] + ui * brow[j];
    }
}

/// AVX2+FMA lowerings of the vector primitives. Every function here is
/// bit-identical to its portable counterpart — `_mm256_fmadd_ps` matches
/// lane-wise `f32::mul_add`, the mul/add pairs keep the scalar
/// expressions' two-rounding shape, tails reuse the scalar code — so CPU
/// dispatch changes speed, never results (pinned by
/// `avx2_paths_match_portable_bitwise`).
#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    use super::hsum8;

    /// # Safety
    /// Caller must ensure the CPU supports AVX2+FMA.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot8(x: &[f32], y: &[f32]) -> f32 {
        let n8 = x.len() - x.len() % 8;
        let mut acc = _mm256_setzero_ps();
        let mut k = 0;
        while k < n8 {
            let xv = _mm256_loadu_ps(x.as_ptr().add(k));
            let yv = _mm256_loadu_ps(y.as_ptr().add(k));
            acc = _mm256_fmadd_ps(xv, yv, acc);
            k += 8;
        }
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut total = hsum8(lanes);
        for i in n8..x.len() {
            total = x[i].mul_add(y[i], total);
        }
        total
    }

    /// # Safety
    /// Caller must ensure the CPU supports AVX2+FMA.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot8_i8(x: &[f32], q: &[i8]) -> f32 {
        let n8 = x.len() - x.len() % 8;
        let mut acc = _mm256_setzero_ps();
        let mut k = 0;
        while k < n8 {
            // 8 i8 → sign-extend to 8×i32 → exact convert to 8×f32.
            let qv = _mm_loadl_epi64(q.as_ptr().add(k) as *const __m128i);
            let qf = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(qv));
            let xv = _mm256_loadu_ps(x.as_ptr().add(k));
            acc = _mm256_fmadd_ps(xv, qf, acc);
            k += 8;
        }
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
        let mut total = hsum8(lanes);
        for i in n8..x.len() {
            total = x[i].mul_add(q[i] as f32, total);
        }
        total
    }

    /// # Safety
    /// Caller must ensure the CPU supports AVX2+FMA.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy(a: f32, src: &[f32], dst: &mut [f32]) {
        let n8 = src.len() - src.len() % 8;
        let av = _mm256_set1_ps(a);
        let mut k = 0;
        while k < n8 {
            let s = _mm256_loadu_ps(src.as_ptr().add(k));
            let d = _mm256_loadu_ps(dst.as_ptr().add(k));
            // add(mul) — NOT fmadd: keep the scalar two-rounding shape.
            _mm256_storeu_ps(dst.as_mut_ptr().add(k), _mm256_add_ps(d, _mm256_mul_ps(av, s)));
            k += 8;
        }
        for j in n8..dst.len() {
            dst[j] += a * src[j];
        }
    }

    /// # Safety
    /// Caller must ensure the CPU supports AVX2+FMA.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn axpy_i8(a: f32, src: &[i8], dst: &mut [f32]) {
        let n8 = src.len() - src.len() % 8;
        let av = _mm256_set1_ps(a);
        let mut k = 0;
        while k < n8 {
            let qv = _mm_loadl_epi64(src.as_ptr().add(k) as *const __m128i);
            let s = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(qv));
            let d = _mm256_loadu_ps(dst.as_ptr().add(k));
            _mm256_storeu_ps(dst.as_mut_ptr().add(k), _mm256_add_ps(d, _mm256_mul_ps(av, s)));
            k += 8;
        }
        for j in n8..dst.len() {
            dst[j] += a * src[j] as f32;
        }
    }

    /// # Safety
    /// Caller must ensure the CPU supports AVX2+FMA.
    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn scan_update(drow: &[f32], hrow: &mut [f32], ui: f32, brow: &[f32]) {
        let n8 = hrow.len() - hrow.len() % 8;
        let uv = _mm256_set1_ps(ui);
        let mut k = 0;
        while k < n8 {
            let d = _mm256_loadu_ps(drow.as_ptr().add(k));
            let h = _mm256_loadu_ps(hrow.as_ptr().add(k));
            let b = _mm256_loadu_ps(brow.as_ptr().add(k));
            _mm256_storeu_ps(
                hrow.as_mut_ptr().add(k),
                _mm256_add_ps(_mm256_mul_ps(d, h), _mm256_mul_ps(uv, b)),
            );
            k += 8;
        }
        for j in n8..hrow.len() {
            hrow[j] = drow[j] * hrow[j] + ui * brow[j];
        }
    }
}

// ---------------------------------------------------------------------------
// Weight operands: dense f32 or per-channel int8
// ---------------------------------------------------------------------------

/// A weight-matrix operand for the block kernels: dense f32, or per-channel
/// int8 `(quantized blob, f32 scales)` produced at load time by
/// [`Weights::ensure_quant`](super::weights::Weights::ensure_quant). The
/// scale axis follows the consuming kernel's output channel: matrix
/// columns for the in/out projections, rows for the tied-embedding head.
#[derive(Clone, Copy)]
pub enum MatRef<'a> {
    /// Dense row-major f32, the format everything before this tier used.
    F32(&'a [f32]),
    /// Per-output-channel symmetric int8: `w[r][c] ≈ q[r][c] · scale[ch]`.
    I8 { q: &'a [i8], scales: &'a [f32] },
}

// ---------------------------------------------------------------------------
// Activations + norms (shared by the scalar and fused paths)
// ---------------------------------------------------------------------------

/// Logistic sigmoid.
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// SiLU / swish: `x · sigmoid(x)`.
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// The RMSNorm scale factor `1 / sqrt(mean(x²) + 1e-5)`, with the summation
/// order every caller shares (ascending index — the rounding sequence is
/// part of the determinism contract; this reduction is never vectorized).
pub fn rms_inv(x: &[f32]) -> f32 {
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    1.0 / (ms + 1e-5).sqrt()
}

/// RMSNorm one row: `out[i] = x[i] · rms_inv(x) · g[i]`.
///
/// ```
/// use tor_ssm::runtime::kernels::rmsnorm;
/// let mut out = [0.0f32; 3];
/// rmsnorm(&[3.0, 0.0, -4.0], &[1.0, 1.0, 1.0], &mut out);
/// let ms: f32 = out.iter().map(|v| v * v).sum::<f32>() / 3.0;
/// assert!((ms - 1.0).abs() < 1e-3);
/// ```
pub fn rmsnorm(x: &[f32], g: &[f32], out: &mut [f32]) {
    let inv = rms_inv(x);
    for i in 0..x.len() {
        out[i] = x[i] * inv * g[i];
    }
}

// ---------------------------------------------------------------------------
// Stage 1: fused RMSNorm + in-projection
// ---------------------------------------------------------------------------

/// Fused RMSNorm + in-projection over a block of `nt` residual rows:
/// `proj[t] = rmsnorm(xs[t]) ⊙ g · w` for each row, with `w` (`d × pw`,
/// row-major) streamed **once per block** instead of once per row.
///
/// `inv` is an `nt`-float scratch. Bit-identity: for each `(t, j)` the
/// accumulation runs over `c` ascending, and each addend is
/// `(x·inv)·g · w` — the scalar path's exact expression and order; with
/// `simd` the rank-1 update goes through [`axpy`], which keeps that
/// sequence. For [`MatRef::I8`] the unscaled i8 dot accumulates in the
/// same order in every tier and the per-column scale multiplies once at
/// the end.
///
/// ```
/// use tor_ssm::runtime::kernels::{fused_rmsnorm_inproj, rmsnorm, MatRef};
/// let (nt, d, pw) = (2, 3, 2);
/// let xs = [0.5f32, -1.0, 2.0, 1.5, 0.25, -0.75];
/// let g = [1.0f32, 0.9, 1.1];
/// let w = [0.2f32, -0.1, 0.4, 0.3, -0.5, 0.6]; // d × pw
/// let mut proj = [0.0f32; 4];
/// let mut inv = [0.0f32; 2];
/// fused_rmsnorm_inproj(&xs, &g, MatRef::F32(&w), nt, d, pw, &mut proj, &mut inv, false);
/// // equals the unfused reference: rmsnorm per row, then row · w
/// for t in 0..nt {
///     let mut xn = [0.0f32; 3];
///     rmsnorm(&xs[t * d..(t + 1) * d], &g, &mut xn);
///     for j in 0..pw {
///         let mut acc = 0.0f32;
///         for c in 0..d {
///             acc += xn[c] * w[c * pw + j];
///         }
///         assert_eq!(acc, proj[t * pw + j]);
///     }
/// }
/// ```
#[allow(clippy::too_many_arguments)]
pub fn fused_rmsnorm_inproj(
    xs: &[f32],
    g: &[f32],
    w: MatRef<'_>,
    nt: usize,
    d: usize,
    pw: usize,
    proj: &mut [f32],
    inv: &mut [f32],
    simd: bool,
) {
    debug_assert_eq!(xs.len(), nt * d);
    debug_assert_eq!(g.len(), d);
    debug_assert_eq!(proj.len(), nt * pw);
    debug_assert!(inv.len() >= nt);
    for t in 0..nt {
        inv[t] = rms_inv(&xs[t * d..(t + 1) * d]);
    }
    proj.fill(0.0);
    match w {
        MatRef::F32(w) => {
            debug_assert_eq!(w.len(), d * pw);
            for c in 0..d {
                let row = &w[c * pw..(c + 1) * pw];
                let gc = g[c];
                for t in 0..nt {
                    let xc = xs[t * d + c] * inv[t] * gc;
                    let prow = &mut proj[t * pw..(t + 1) * pw];
                    if simd {
                        axpy(xc, row, prow);
                    } else {
                        for j in 0..pw {
                            prow[j] += xc * row[j];
                        }
                    }
                }
            }
        }
        MatRef::I8 { q, scales } => {
            debug_assert_eq!(q.len(), d * pw);
            debug_assert_eq!(scales.len(), pw);
            for c in 0..d {
                let row = &q[c * pw..(c + 1) * pw];
                let gc = g[c];
                for t in 0..nt {
                    let xc = xs[t * d + c] * inv[t] * gc;
                    let prow = &mut proj[t * pw..(t + 1) * pw];
                    if simd {
                        axpy_i8(xc, row, prow);
                    } else {
                        for j in 0..pw {
                            prow[j] += xc * row[j] as f32;
                        }
                    }
                }
            }
            // One per-column scale multiply at the end — shared by every
            // tier, so int8 identity across tiers is structural.
            for t in 0..nt {
                let prow = &mut proj[t * pw..(t + 1) * pw];
                for j in 0..pw {
                    prow[j] *= scales[j];
                }
            }
        }
    }
}

/// The in-projection column that feeds conv channel `ch`: `u_pre` occupies
/// columns `0..di`, `z` occupies `di..2di`, and (mamba2) `b_pre ++ c_pre`
/// sit at `2di..`. Shared by both conv kernels so the mapping exists once.
#[inline]
fn conv_src_col(ch: usize, di: usize) -> usize {
    if ch < di {
        ch
    } else {
        2 * di + (ch - di)
    }
}

// ---------------------------------------------------------------------------
// Stage 2: blocked depthwise causal conv
// ---------------------------------------------------------------------------

/// Depthwise causal conv over a block of `nt` *sequential* tokens, one
/// evolving window per channel (prefill/eval). `tail` is the `[ch × k1]`
/// rolling window carried in from the previous block and written back out,
/// so block boundaries never change results. Each channel's weights and
/// window are held in registers for the whole block — the per-token
/// re-slicing of the scalar path disappears.
///
/// `inp` is the block's in-projection output (`nt × pw`); channel `ch`
/// reads column `ch` (`< di`) or `2·di + (ch − di)` (mamba2 B/C channels).
/// `out` is `nt × conv_ch`, pre-activation. The conv recurrence is never
/// vectorized — it stays bit-identical in every tier.
#[allow(clippy::too_many_arguments)]
pub fn causal_conv_seq(
    inp: &[f32],
    pw: usize,
    di: usize,
    conv_w: &[f32],
    conv_b: &[f32],
    tail: &mut [f32],
    out: &mut [f32],
    nt: usize,
) {
    let conv_ch = conv_b.len();
    let d_conv = conv_w.len() / conv_ch;
    let k1 = d_conv - 1;
    assert!(k1 >= 1 && k1 <= 8, "conv window k1={k1} outside the supported 1..=8");
    debug_assert_eq!(inp.len(), nt * pw);
    debug_assert_eq!(tail.len(), conv_ch * k1);
    debug_assert_eq!(out.len(), nt * conv_ch);
    for ch in 0..conv_ch {
        let w = &conv_w[ch * d_conv..(ch + 1) * d_conv];
        let b = conv_b[ch];
        let src = conv_src_col(ch, di);
        let t0 = &mut tail[ch * k1..(ch + 1) * k1];
        let mut win = [0.0f32; 8];
        win[..k1].copy_from_slice(t0);
        for t in 0..nt {
            let cur = inp[t * pw + src];
            // Scalar order: bias + w[k1]·cur first, then the window taps
            // ascending — kept verbatim so every rounding matches.
            let mut acc = b + w[k1] * cur;
            for j in 0..k1 {
                acc += w[j] * win[j];
            }
            out[t * conv_ch + ch] = acc;
            for j in 0..k1 - 1 {
                win[j] = win[j + 1];
            }
            win[k1 - 1] = cur;
        }
        t0.copy_from_slice(&win[..k1]);
    }
}

/// Depthwise causal conv, one step for each of `nt` independent decode
/// lanes: lane `t` advances its own window `tails[t]` (`[nt × ch × k1]`,
/// the decode frame's contiguous lane-chunk layout) by one token. No state
/// crosses lanes — the scalar per-lane update runs verbatim, just batched
/// so `conv_w`/`conv_b` stream once per chunk.
#[allow(clippy::too_many_arguments)]
pub fn causal_conv_batch(
    inp: &[f32],
    pw: usize,
    di: usize,
    conv_w: &[f32],
    conv_b: &[f32],
    tails: &mut [f32],
    out: &mut [f32],
    nt: usize,
) {
    let conv_ch = conv_b.len();
    let d_conv = conv_w.len() / conv_ch;
    let k1 = d_conv - 1;
    debug_assert_eq!(inp.len(), nt * pw);
    debug_assert_eq!(tails.len(), nt * conv_ch * k1);
    debug_assert_eq!(out.len(), nt * conv_ch);
    for t in 0..nt {
        let tail = &mut tails[t * conv_ch * k1..(t + 1) * conv_ch * k1];
        for ch in 0..conv_ch {
            let w = &conv_w[ch * d_conv..(ch + 1) * d_conv];
            let cur = inp[t * pw + conv_src_col(ch, di)];
            let tl = &mut tail[ch * k1..(ch + 1) * k1];
            let mut acc = conv_b[ch] + w[k1] * cur;
            for j in 0..k1 {
                acc += w[j] * tl[j];
            }
            for j in 0..k1 - 1 {
                tl[j] = tl[j + 1];
            }
            tl[k1 - 1] = cur;
            out[t * conv_ch + ch] = acc;
        }
    }
}

// ---------------------------------------------------------------------------
// Stage 3: selectivity parameters
// ---------------------------------------------------------------------------

/// `u = silu(conv)` over the first `di` channels of each row.
pub fn silu_channels(conv: &[f32], conv_ch: usize, di: usize, u: &mut [f32], nt: usize) {
    debug_assert_eq!(conv.len(), nt * conv_ch);
    debug_assert_eq!(u.len(), nt * di);
    for t in 0..nt {
        for i in 0..di {
            u[t * di + i] = silu(conv[t * conv_ch + i]);
        }
    }
}

/// Mamba2: `B`/`C` are conv output channels `di..di+n` / `di+n..di+2n`.
pub fn copy_bc_channels(
    conv: &[f32],
    conv_ch: usize,
    di: usize,
    n: usize,
    bs: &mut [f32],
    cs: &mut [f32],
    nt: usize,
) {
    debug_assert_eq!(conv.len(), nt * conv_ch);
    debug_assert_eq!(bs.len(), nt * n);
    debug_assert_eq!(cs.len(), nt * n);
    for t in 0..nt {
        let row = &conv[t * conv_ch..(t + 1) * conv_ch];
        bs[t * n..(t + 1) * n].copy_from_slice(&row[di..di + n]);
        cs[t * n..(t + 1) * n].copy_from_slice(&row[di + n..di + 2 * n]);
    }
}

/// Mamba: derive `B, C` from post-conv `u` via `bc` (`di × 2n`, row-major),
/// streamed once per block. For each `(t, j)` both accumulators run over
/// `i` ascending with `B` then `C` updated per tap — the scalar order.
/// With `simd`, B and C are two [`axpy`] passes per tap: they are disjoint
/// accumulators, so each scalar still sees its exact interleaved-order
/// sequence (`bc_proj` itself stays f32 — it is not a quantized operand).
pub fn bc_project(
    u: &[f32],
    bc: &[f32],
    n: usize,
    bs: &mut [f32],
    cs: &mut [f32],
    nt: usize,
    simd: bool,
) {
    let di = u.len() / nt;
    debug_assert_eq!(bc.len(), di * 2 * n);
    debug_assert_eq!(bs.len(), nt * n);
    debug_assert_eq!(cs.len(), nt * n);
    bs.fill(0.0);
    cs.fill(0.0);
    for i in 0..di {
        let row = &bc[i * 2 * n..(i + 1) * 2 * n];
        for t in 0..nt {
            let ui = u[t * di + i];
            let brow = t * n;
            if simd {
                axpy(ui, &row[..n], &mut bs[brow..brow + n]);
                axpy(ui, &row[n..], &mut cs[brow..brow + n]);
            } else {
                for j in 0..n {
                    bs[brow + j] += ui * row[j];
                    cs[brow + j] += ui * row[n + j];
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Stage 4: selective scan + SiLU gate (fused emit)
// ---------------------------------------------------------------------------

/// Selective scan over `nt` *sequential* tokens with the gate fused into
/// the emit: `h[i][j] ← decay·h + u·B`, `y[t][i] = (Σ_j h·C + D·u) ·
/// silu(z)`. State rows are walked `i`-major so each `h` row stays hot for
/// the whole block; per `(i, j)` the token recurrence still runs strictly
/// ascending (that order IS the scan — it is never reassociated).
///
/// With `simd` the d-state inner loop splits: the state update vectorizes
/// through [`scan_update`] (mul/mul/add — the scalar roundings), then the
/// emit sum runs scalar over the *same* updated values in the same
/// ascending order, so y, h and everything downstream stay bit-identical.
///
/// `zs` points at the in-projection block (`nt × pw`); the gate column for
/// channel `i` is `di + i`.
#[allow(clippy::too_many_arguments)]
pub fn scan_gate_seq(
    u: &[f32],
    bs: &[f32],
    cs: &[f32],
    zs: &[f32],
    pw: usize,
    decay: &[f32],
    d_skip: &[f32],
    n: usize,
    h: &mut [f32],
    y: &mut [f32],
    nt: usize,
    simd: bool,
) {
    let di = d_skip.len();
    debug_assert_eq!(u.len(), nt * di);
    debug_assert_eq!(bs.len(), nt * n);
    debug_assert_eq!(cs.len(), nt * n);
    debug_assert_eq!(zs.len(), nt * pw);
    debug_assert_eq!(decay.len(), di * n);
    debug_assert_eq!(h.len(), di * n);
    debug_assert_eq!(y.len(), nt * di);
    for i in 0..di {
        let hrow = &mut h[i * n..(i + 1) * n];
        let drow = &decay[i * n..(i + 1) * n];
        for t in 0..nt {
            let ui = u[t * di + i];
            let brow = &bs[t * n..(t + 1) * n];
            let crow = &cs[t * n..(t + 1) * n];
            let mut acc = 0.0f32;
            if simd {
                scan_update(drow, hrow, ui, brow);
                for j in 0..n {
                    acc += hrow[j] * crow[j];
                }
            } else {
                for j in 0..n {
                    hrow[j] = drow[j] * hrow[j] + ui * brow[j];
                    acc += hrow[j] * crow[j];
                }
            }
            let z = zs[t * pw + di + i];
            y[t * di + i] = (acc + d_skip[i] * ui) * silu(z);
        }
    }
}

/// Selective scan, one step for each of `nt` independent decode lanes:
/// lane `t` advances its own state `hs[t]` (`[nt × di × n]`, the decode
/// frame's contiguous lane-chunk layout). Identical per-lane math to
/// [`scan_gate_seq`] with a one-token block, including the `simd` split.
#[allow(clippy::too_many_arguments)]
pub fn scan_gate_batch(
    u: &[f32],
    bs: &[f32],
    cs: &[f32],
    zs: &[f32],
    pw: usize,
    decay: &[f32],
    d_skip: &[f32],
    n: usize,
    hs: &mut [f32],
    y: &mut [f32],
    nt: usize,
    simd: bool,
) {
    let di = d_skip.len();
    debug_assert_eq!(hs.len(), nt * di * n);
    debug_assert_eq!(y.len(), nt * di);
    for t in 0..nt {
        let h = &mut hs[t * di * n..(t + 1) * di * n];
        let ui_base = t * di;
        let brow = &bs[t * n..(t + 1) * n];
        let crow = &cs[t * n..(t + 1) * n];
        for i in 0..di {
            let ui = u[ui_base + i];
            let hrow = &mut h[i * n..(i + 1) * n];
            let drow = &decay[i * n..(i + 1) * n];
            let mut acc = 0.0f32;
            if simd {
                scan_update(drow, hrow, ui, brow);
                for j in 0..n {
                    acc += hrow[j] * crow[j];
                }
            } else {
                for j in 0..n {
                    hrow[j] = drow[j] * hrow[j] + ui * brow[j];
                    acc += hrow[j] * crow[j];
                }
            }
            let z = zs[t * pw + di + i];
            y[t * di + i] = (acc + d_skip[i] * ui) * silu(z);
        }
    }
}

// ---------------------------------------------------------------------------
// Stage 5: output projection, accumulated into the residual stream
// ---------------------------------------------------------------------------

/// `xs[t] += y[t] · w` for a block of rows, with `w` (`di × d`, row-major)
/// streamed once per block. Per `(t, c)` the accumulation runs over `i`
/// ascending — the scalar path's order; with `simd` through [`axpy`],
/// which keeps it.
///
/// For [`MatRef::I8`] the unscaled i8 dot accumulates into the `oacc`
/// scratch (`≥ nt × d`, zeroed here) in the same ascending-`i` order in
/// every tier, then folds into the residual with one per-column scale
/// multiply: `xs[t][c] += oacc[t][c] · scale[c]`. `oacc` is untouched for
/// f32 operands.
#[allow(clippy::too_many_arguments)]
pub fn outproj_acc(
    y: &[f32],
    w: MatRef<'_>,
    d: usize,
    xs: &mut [f32],
    oacc: &mut [f32],
    nt: usize,
    simd: bool,
) {
    let di = y.len() / nt;
    debug_assert_eq!(xs.len(), nt * d);
    match w {
        MatRef::F32(w) => {
            debug_assert_eq!(w.len(), di * d);
            for i in 0..di {
                let row = &w[i * d..(i + 1) * d];
                for t in 0..nt {
                    let yi = y[t * di + i];
                    let xrow = &mut xs[t * d..(t + 1) * d];
                    if simd {
                        axpy(yi, row, xrow);
                    } else {
                        for c in 0..d {
                            xrow[c] += yi * row[c];
                        }
                    }
                }
            }
        }
        MatRef::I8 { q, scales } => {
            debug_assert_eq!(q.len(), di * d);
            debug_assert_eq!(scales.len(), d);
            debug_assert!(oacc.len() >= nt * d);
            let oacc = &mut oacc[..nt * d];
            oacc.fill(0.0);
            for i in 0..di {
                let row = &q[i * d..(i + 1) * d];
                for t in 0..nt {
                    let yi = y[t * di + i];
                    let orow = &mut oacc[t * d..(t + 1) * d];
                    if simd {
                        axpy_i8(yi, row, orow);
                    } else {
                        for c in 0..d {
                            orow[c] += yi * row[c] as f32;
                        }
                    }
                }
            }
            for t in 0..nt {
                let xrow = &mut xs[t * d..(t + 1) * d];
                let orow = &oacc[t * d..(t + 1) * d];
                for c in 0..d {
                    xrow[c] += orow[c] * scales[c];
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Head: fused final RMSNorm + tied-embedding logits
// ---------------------------------------------------------------------------

/// Final RMSNorm + tied-embedding head over a block of `nt` residual rows:
/// normalise every row into the `xn` scratch (`nt × d`), then stream the
/// embedding matrix **once per block**, emitting `out[t][v] = xn[t] ·
/// embed[v]`. The scalar path streams all `vocab × d` embedding floats per
/// row; this is the single largest traffic saving in the eval path.
///
/// This is the ONE place the `simd` tier reassociates on f32 weights: the
/// per-logit dot switches from the ascending scalar sum to [`dot8`], with
/// the error bound documented there (PERFORMANCE.md §Kernel tiers & weight
/// formats). Everything upstream of the logits stays bit-identical. For
/// [`MatRef::I8`], every tier uses [`dot8_i8`] · per-row scale, so int8
/// logits are identical across scalar|fused|simd.
#[allow(clippy::too_many_arguments)]
pub fn head_norm_logits(
    xs: &[f32],
    g: &[f32],
    embed: MatRef<'_>,
    vocab: usize,
    out: &mut [f32],
    xn: &mut [f32],
    nt: usize,
    simd: bool,
) {
    let d = g.len();
    debug_assert_eq!(xs.len(), nt * d);
    debug_assert_eq!(out.len(), nt * vocab);
    debug_assert!(xn.len() >= nt * d);
    for t in 0..nt {
        let inv = rms_inv(&xs[t * d..(t + 1) * d]);
        for c in 0..d {
            xn[t * d + c] = xs[t * d + c] * inv * g[c];
        }
    }
    match embed {
        MatRef::F32(embed) => {
            debug_assert_eq!(embed.len(), vocab * d);
            for v in 0..vocab {
                let row = &embed[v * d..(v + 1) * d];
                for t in 0..nt {
                    let xrow = &xn[t * d..(t + 1) * d];
                    out[t * vocab + v] = if simd {
                        dot8(xrow, row)
                    } else {
                        let mut acc = 0.0f32;
                        for c in 0..d {
                            acc += xrow[c] * row[c];
                        }
                        acc
                    };
                }
            }
        }
        MatRef::I8 { q, scales } => {
            debug_assert_eq!(q.len(), vocab * d);
            debug_assert_eq!(scales.len(), vocab);
            for v in 0..vocab {
                let row = &q[v * d..(v + 1) * d];
                for t in 0..nt {
                    let xrow = &xn[t * d..(t + 1) * d];
                    out[t * vocab + v] = dot8_i8(xrow, row) * scales[v];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn randq(rng: &mut Rng, n: usize) -> Vec<i8> {
        (0..n).map(|_| (rng.below(255) as i64 - 127) as i8).collect()
    }

    #[test]
    fn mode_roundtrip_and_parse() {
        for m in [KernelMode::Scalar, KernelMode::Fused, KernelMode::Simd] {
            set_mode(m);
            assert_eq!(mode(), m);
            assert_eq!(KernelMode::from_name(m.name()).unwrap(), m);
        }
        set_mode(KernelMode::Fused);
        let err = KernelMode::from_name("avx").unwrap_err().to_string();
        assert!(err.contains("scalar|fused|simd"), "error must enumerate all modes: {err}");
        assert!(exec_summary().contains("fused"));
    }

    /// The typo'd-env warnings must name the variable and enumerate every
    /// accepted value, for both knobs.
    #[test]
    fn env_warnings_enumerate_the_accepted_sets() {
        let e = KernelMode::from_name("sse2").unwrap_err();
        let w = ignored_env_warning("TOR_SSM_KERNELS", &e, "fused");
        assert!(w.contains("TOR_SSM_KERNELS"), "{w}");
        assert!(w.contains("scalar|fused|simd"), "{w}");
        assert!(w.ends_with("using fused"), "{w}");

        let e = crate::runtime::weights::WeightFormat::from_name("int4").unwrap_err();
        let w = ignored_env_warning("TOR_SSM_WEIGHTS", &e, "f32");
        assert!(w.contains("TOR_SSM_WEIGHTS"), "{w}");
        assert!(w.contains("f32|int8"), "{w}");
        assert!(w.ends_with("using f32"), "{w}");
    }

    /// The block kernels must equal their naive single-row counterparts
    /// bit-for-bit, for any block size.
    #[test]
    fn fused_inproj_matches_unfused_bitwise() {
        let (d, pw) = (8, 20);
        let mut rng = Rng::new(7);
        let g = randv(&mut rng, d);
        let w = randv(&mut rng, d * pw);
        for nt in [1, 2, 5] {
            let xs = randv(&mut rng, nt * d);
            let mut proj = vec![0.0f32; nt * pw];
            let mut inv = vec![0.0f32; nt];
            fused_rmsnorm_inproj(&xs, &g, MatRef::F32(&w), nt, d, pw, &mut proj, &mut inv, false);
            for t in 0..nt {
                let mut xn = vec![0.0f32; d];
                rmsnorm(&xs[t * d..(t + 1) * d], &g, &mut xn);
                let mut want = vec![0.0f32; pw];
                for c in 0..d {
                    let xc = xn[c];
                    for j in 0..pw {
                        want[j] += xc * w[c * pw + j];
                    }
                }
                assert_eq!(&proj[t * pw..(t + 1) * pw], &want[..], "row {t} of block {nt}");
            }
        }
    }

    /// Conv over a sequence must not depend on how the tokens are blocked:
    /// the window carries across block boundaries.
    #[test]
    fn conv_seq_block_boundaries_are_invisible() {
        let (di, n, d_conv) = (4, 2, 4);
        let conv_ch = di + 2 * n;
        let pw = 2 * di + 2 * n;
        let k1 = d_conv - 1;
        let mut rng = Rng::new(9);
        let conv_w = randv(&mut rng, conv_ch * d_conv);
        let conv_b = randv(&mut rng, conv_ch);
        let total = 7;
        let inp = randv(&mut rng, total * pw);

        let run = |chunks: &[usize]| {
            let mut tail = vec![0.0f32; conv_ch * k1];
            let mut out = vec![0.0f32; total * conv_ch];
            let mut at = 0usize;
            for &nt in chunks {
                causal_conv_seq(
                    &inp[at * pw..(at + nt) * pw],
                    pw,
                    di,
                    &conv_w,
                    &conv_b,
                    &mut tail,
                    &mut out[at * conv_ch..(at + nt) * conv_ch],
                    nt,
                );
                at += nt;
            }
            (out, tail)
        };
        let whole = run(&[7]);
        let split = run(&[2, 3, 2]);
        let single = run(&[1; 7]);
        assert_eq!(whole, split);
        assert_eq!(whole, single);
    }

    /// Same invariance for the scan: the state recurrence carries across
    /// blocks, so any blocking gives bit-identical y and final h — and
    /// the simd split must be invisible too.
    #[test]
    fn scan_seq_block_boundaries_are_invisible() {
        let (di, n) = (4, 3);
        let pw = 2 * di;
        let mut rng = Rng::new(11);
        let decay: Vec<f32> = randv(&mut rng, di * n).iter().map(|v| sigmoid(*v)).collect();
        let d_skip = randv(&mut rng, di);
        let total = 6;
        let u = randv(&mut rng, total * di);
        let bs = randv(&mut rng, total * n);
        let cs = randv(&mut rng, total * n);
        let zs = randv(&mut rng, total * pw);

        let run = |chunks: &[usize], simd: bool| {
            let mut h = vec![0.0f32; di * n];
            let mut y = vec![0.0f32; total * di];
            let mut at = 0usize;
            for &nt in chunks {
                scan_gate_seq(
                    &u[at * di..(at + nt) * di],
                    &bs[at * n..(at + nt) * n],
                    &cs[at * n..(at + nt) * n],
                    &zs[at * pw..(at + nt) * pw],
                    pw,
                    &decay,
                    &d_skip,
                    n,
                    &mut h,
                    &mut y[at * di..(at + nt) * di],
                    nt,
                    simd,
                );
                at += nt;
            }
            (y, h)
        };
        assert_eq!(run(&[6], false), run(&[1; 6], false));
        assert_eq!(run(&[6], false), run(&[4, 2], false));
        assert_eq!(run(&[6], false), run(&[6], true));
        assert_eq!(run(&[6], false), run(&[4, 2], true));
    }

    /// The batch kernels are per-lane independent: one 3-lane call equals
    /// three 1-lane calls on the matching lane slices.
    #[test]
    fn batch_kernels_have_no_lane_crosstalk() {
        let (di, n, d_conv) = (4, 2, 4);
        let conv_ch = di; // mamba-style
        let pw = 2 * di;
        let k1 = d_conv - 1;
        let nt = 3;
        let mut rng = Rng::new(13);
        let conv_w = randv(&mut rng, conv_ch * d_conv);
        let conv_b = randv(&mut rng, conv_ch);
        let inp = randv(&mut rng, nt * pw);
        let tails0 = randv(&mut rng, nt * conv_ch * k1);
        let decay: Vec<f32> = randv(&mut rng, di * n).iter().map(|v| sigmoid(*v)).collect();
        let d_skip = randv(&mut rng, di);
        let u = randv(&mut rng, nt * di);
        let bs = randv(&mut rng, nt * n);
        let cs = randv(&mut rng, nt * n);
        let hs0 = randv(&mut rng, nt * di * n);

        let mut tails = tails0.clone();
        let mut out = vec![0.0f32; nt * conv_ch];
        causal_conv_batch(&inp, pw, di, &conv_w, &conv_b, &mut tails, &mut out, nt);
        let mut hs = hs0.clone();
        let mut y = vec![0.0f32; nt * di];
        scan_gate_batch(&u, &bs, &cs, &inp, pw, &decay, &d_skip, n, &mut hs, &mut y, nt, false);

        for t in 0..nt {
            let mut tail1 = tails0[t * conv_ch * k1..(t + 1) * conv_ch * k1].to_vec();
            let mut out1 = vec![0.0f32; conv_ch];
            causal_conv_batch(
                &inp[t * pw..(t + 1) * pw],
                pw,
                di,
                &conv_w,
                &conv_b,
                &mut tail1,
                &mut out1,
                1,
            );
            assert_eq!(&out[t * conv_ch..(t + 1) * conv_ch], &out1[..]);
            assert_eq!(&tails[t * conv_ch * k1..(t + 1) * conv_ch * k1], &tail1[..]);

            let mut h1 = hs0[t * di * n..(t + 1) * di * n].to_vec();
            let mut y1 = vec![0.0f32; di];
            scan_gate_batch(
                &u[t * di..(t + 1) * di],
                &bs[t * n..(t + 1) * n],
                &cs[t * n..(t + 1) * n],
                &inp[t * pw..(t + 1) * pw],
                pw,
                &decay,
                &d_skip,
                n,
                &mut h1,
                &mut y1,
                1,
                false,
            );
            assert_eq!(&y[t * di..(t + 1) * di], &y1[..]);
            assert_eq!(&hs[t * di * n..(t + 1) * di * n], &h1[..]);
        }
    }

    #[test]
    fn head_block_matches_per_row() {
        let (d, vocab) = (6, 11);
        let mut rng = Rng::new(17);
        let g = randv(&mut rng, d);
        let embed = randv(&mut rng, vocab * d);
        let nt = 3;
        let xs = randv(&mut rng, nt * d);
        let mut out = vec![0.0f32; nt * vocab];
        let mut xn = vec![0.0f32; nt * d];
        head_norm_logits(&xs, &g, MatRef::F32(&embed), vocab, &mut out, &mut xn, nt, false);
        for t in 0..nt {
            let mut xn1 = vec![0.0f32; d];
            rmsnorm(&xs[t * d..(t + 1) * d], &g, &mut xn1);
            for v in 0..vocab {
                let mut acc = 0.0f32;
                for c in 0..d {
                    acc += xn1[c] * embed[v * d + c];
                }
                assert_eq!(out[t * vocab + v], acc, "row {t} vocab {v}");
            }
        }
    }

    #[test]
    fn bc_project_matches_scalar_order() {
        let (di, n, nt) = (5, 3, 2);
        let mut rng = Rng::new(19);
        let u = randv(&mut rng, nt * di);
        let bc = randv(&mut rng, di * 2 * n);
        let mut bs = vec![0.0f32; nt * n];
        let mut cs = vec![0.0f32; nt * n];
        bc_project(&u, &bc, n, &mut bs, &mut cs, nt, false);
        for t in 0..nt {
            let mut b1 = vec![0.0f32; n];
            let mut c1 = vec![0.0f32; n];
            for i in 0..di {
                let ui = u[t * di + i];
                let row = &bc[i * 2 * n..(i + 1) * 2 * n];
                for j in 0..n {
                    b1[j] += ui * row[j];
                    c1[j] += ui * row[n + j];
                }
            }
            assert_eq!(&bs[t * n..(t + 1) * n], &b1[..]);
            assert_eq!(&cs[t * n..(t + 1) * n], &c1[..]);
        }
    }

    /// The `simd` flag must be bit-invisible on every kernel except the
    /// f32 head: rank-1 updates and the scan split keep the scalar
    /// rounding sequences exactly (lengths chosen to exercise both the
    /// 8-wide body and the scalar tails).
    #[test]
    fn simd_flag_is_bit_invisible_outside_the_head() {
        let (d, pw, n) = (9, 20, 11);
        let di = pw / 2;
        let nt = 3;
        let mut rng = Rng::new(31);

        let g = randv(&mut rng, d);
        let w = randv(&mut rng, d * pw);
        let xs = randv(&mut rng, nt * d);
        let mut p0 = vec![0.0f32; nt * pw];
        let mut p1 = vec![0.0f32; nt * pw];
        let mut inv = vec![0.0f32; nt];
        fused_rmsnorm_inproj(&xs, &g, MatRef::F32(&w), nt, d, pw, &mut p0, &mut inv, false);
        fused_rmsnorm_inproj(&xs, &g, MatRef::F32(&w), nt, d, pw, &mut p1, &mut inv, true);
        assert_eq!(p0, p1, "in-projection");

        let u = randv(&mut rng, nt * di);
        let bc = randv(&mut rng, di * 2 * n);
        let mut bs0 = vec![0.0f32; nt * n];
        let mut cs0 = vec![0.0f32; nt * n];
        let mut bs1 = vec![0.0f32; nt * n];
        let mut cs1 = vec![0.0f32; nt * n];
        bc_project(&u, &bc, n, &mut bs0, &mut cs0, nt, false);
        bc_project(&u, &bc, n, &mut bs1, &mut cs1, nt, true);
        assert_eq!((&bs0, &cs0), (&bs1, &cs1), "bc_project");

        let decay: Vec<f32> = randv(&mut rng, di * n).iter().map(|v| sigmoid(*v)).collect();
        let d_skip = randv(&mut rng, di);
        let zs = randv(&mut rng, nt * pw);
        let h0full = randv(&mut rng, nt * di * n);
        let mut hs0 = h0full.clone();
        let mut hs1 = h0full.clone();
        let mut y0 = vec![0.0f32; nt * di];
        let mut y1 = vec![0.0f32; nt * di];
        scan_gate_batch(&u, &bs0, &cs0, &zs, pw, &decay, &d_skip, n, &mut hs0, &mut y0, nt, false);
        scan_gate_batch(&u, &bs0, &cs0, &zs, pw, &decay, &d_skip, n, &mut hs1, &mut y1, nt, true);
        assert_eq!((&hs0, &y0), (&hs1, &y1), "scan_gate_batch");

        let wo = randv(&mut rng, di * d);
        let mut x0 = xs.clone();
        let mut x1 = xs.clone();
        let mut oacc = vec![0.0f32; nt * d];
        outproj_acc(&y0, MatRef::F32(&wo), d, &mut x0, &mut oacc, nt, false);
        outproj_acc(&y0, MatRef::F32(&wo), d, &mut x1, &mut oacc, nt, true);
        assert_eq!(x0, x1, "out-projection");
    }

    /// Int8 operands: the fused kernels (simd on AND off) must match the
    /// hand-written scalar-tier order — unscaled ascending i8 accumulation,
    /// one scale multiply at the end — bit for bit. This is the structural
    /// cross-tier identity `tests/kernels_identity.rs` pins end to end.
    #[test]
    fn int8_kernels_are_identical_across_tiers() {
        let (d, pw) = (9, 20);
        let nt = 2;
        let mut rng = Rng::new(37);
        let g = randv(&mut rng, d);
        let xs = randv(&mut rng, nt * d);
        let q = randq(&mut rng, d * pw);
        let scales: Vec<f32> = (0..pw).map(|_| rng.f32() * 0.05 + 1e-3).collect();

        // Scalar-tier order for the in-projection.
        let mut want = vec![0.0f32; nt * pw];
        let mut inv = vec![0.0f32; nt];
        for t in 0..nt {
            inv[t] = rms_inv(&xs[t * d..(t + 1) * d]);
        }
        for c in 0..d {
            let row = &q[c * pw..(c + 1) * pw];
            for t in 0..nt {
                let xc = xs[t * d + c] * inv[t] * g[c];
                for j in 0..pw {
                    want[t * pw + j] += xc * row[j] as f32;
                }
            }
        }
        for t in 0..nt {
            for j in 0..pw {
                want[t * pw + j] *= scales[j];
            }
        }
        let m = MatRef::I8 { q: &q, scales: &scales };
        for simd in [false, true] {
            let mut proj = vec![0.0f32; nt * pw];
            fused_rmsnorm_inproj(&xs, &g, m, nt, d, pw, &mut proj, &mut inv, simd);
            assert_eq!(proj, want, "in-projection simd={simd}");
        }

        // Out-projection: i-ascending unscaled accumulate, scale at end.
        let di = 7;
        let y = randv(&mut rng, nt * di);
        let qo = randq(&mut rng, di * d);
        let so: Vec<f32> = (0..d).map(|_| rng.f32() * 0.05 + 1e-3).collect();
        let mut wantx = xs.clone();
        for t in 0..nt {
            for c in 0..d {
                let mut acc = 0.0f32;
                for i in 0..di {
                    acc += y[t * di + i] * qo[i * d + c] as f32;
                }
                wantx[t * d + c] += acc * so[c];
            }
        }
        let mo = MatRef::I8 { q: &qo, scales: &so };
        for simd in [false, true] {
            let mut x = xs.clone();
            let mut oacc = vec![0.0f32; nt * d];
            outproj_acc(&y, mo, d, &mut x, &mut oacc, nt, simd);
            assert_eq!(x, wantx, "out-projection simd={simd}");
        }

        // Head: dot8_i8 · scale in every tier, simd flag invisible.
        let vocab = 13;
        let qe = randq(&mut rng, vocab * d);
        let se: Vec<f32> = (0..vocab).map(|_| rng.f32() * 0.05 + 1e-3).collect();
        let me = MatRef::I8 { q: &qe, scales: &se };
        let mut out0 = vec![0.0f32; nt * vocab];
        let mut out1 = vec![0.0f32; nt * vocab];
        let mut xn = vec![0.0f32; nt * d];
        head_norm_logits(&xs, &g, me, vocab, &mut out0, &mut xn, nt, false);
        head_norm_logits(&xs, &g, me, vocab, &mut out1, &mut xn, nt, true);
        assert_eq!(out0, out1, "int8 head");
        for t in 0..nt {
            for v in 0..vocab {
                let want =
                    dot8_i8(&xn[t * d..(t + 1) * d], &qe[v * d..(v + 1) * d]) * se[v];
                assert_eq!(out0[t * vocab + v], want, "head row {t} vocab {v}");
            }
        }
    }

    /// The documented error-bound contract for the one reassociating
    /// reduction: `|dot8 − ascending| ≤ 2·n·ε·Σ|xᵢ·yᵢ|`.
    #[test]
    fn chunked_head_dot_error_is_bounded() {
        let mut rng = Rng::new(29);
        for len in [1usize, 7, 8, 9, 32, 100, 257] {
            let x = randv(&mut rng, len);
            let y = randv(&mut rng, len);
            let chunked = dot8(&x, &y);
            let mut asc = 0.0f32;
            let mut mag = 0.0f32;
            for i in 0..len {
                asc += x[i] * y[i];
                mag += (x[i] * y[i]).abs();
            }
            let bound = 2.0 * len as f32 * f32::EPSILON * mag;
            assert!(
                (chunked - asc).abs() <= bound,
                "len {len}: |{chunked} - {asc}| > {bound}"
            );
        }
    }

    /// On AVX2 hosts the intrinsic paths must produce the exact bits of
    /// the portable paths — CPU dispatch is never allowed to change
    /// results. (Vacuously passes elsewhere; CI runs a
    /// `-Ctarget-cpu=native` job so real runners exercise it.)
    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_paths_match_portable_bitwise() {
        if !simd_available() {
            return;
        }
        let mut rng = Rng::new(23);
        for len in [1usize, 5, 8, 13, 16, 31, 64] {
            let x = randv(&mut rng, len);
            let y = randv(&mut rng, len);
            let q = randq(&mut rng, len);
            // SAFETY: guarded by simd_available() above.
            unsafe {
                assert_eq!(dot8_portable(&x, &y).to_bits(), avx2::dot8(&x, &y).to_bits());
                assert_eq!(
                    dot8_i8_portable(&x, &q).to_bits(),
                    avx2::dot8_i8(&x, &q).to_bits()
                );
                let a = 0.37f32;
                let mut d0 = y.clone();
                let mut d1 = y.clone();
                for j in 0..len {
                    d0[j] += a * x[j];
                }
                avx2::axpy(a, &x, &mut d1);
                assert_eq!(d0, d1, "axpy len {len}");

                let mut e0 = y.clone();
                let mut e1 = y.clone();
                for j in 0..len {
                    e0[j] += a * q[j] as f32;
                }
                avx2::axpy_i8(a, &q, &mut e1);
                assert_eq!(e0, e1, "axpy_i8 len {len}");

                let drow = randv(&mut rng, len);
                let brow = randv(&mut rng, len);
                let mut h0 = x.clone();
                let mut h1 = x.clone();
                for j in 0..len {
                    h0[j] = drow[j] * h0[j] + a * brow[j];
                }
                avx2::scan_update(&drow, &mut h1, a, &brow);
                assert_eq!(h0, h1, "scan_update len {len}");
            }
        }
    }
}
