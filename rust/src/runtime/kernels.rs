//! Fused, cache-blocked decode/forward kernels for the reference backend,
//! plus the process-wide [`KernelMode`] switch between them and the legacy
//! scalar interpreter (PERFORMANCE.md; DESIGN.md §11).
//!
//! ## Why a second implementation of the same math
//!
//! The scalar interpreter in [`reference`](super::reference) walks one token
//! through one layer at a time, re-streaming every weight matrix from memory
//! for every token. These kernels restructure the hot path around **token
//! blocks** (a block of `nt` residual rows moves through each fusion stage
//! together) so each weight matrix is streamed once per block instead of
//! once per token, and around **fusion** (RMSNorm folds into the
//! in-projection read, the SiLU gate folds into the scan emit, the output
//! projection accumulates straight into the residual rows) so intermediate
//! buffers stay block-sized and L1-resident.
//!
//! ## The determinism contract
//!
//! Every kernel here is **bit-identical** to the scalar path, by
//! construction, not by tolerance (PERFORMANCE.md §Determinism):
//!
//! * blocking only re-tiles loops over *independent* outputs (tokens ×
//!   output channels); for every accumulated scalar, the sequence of f32
//!   operations — and therefore every intermediate rounding — is exactly
//!   the scalar path's sequence;
//! * recurrent state (the conv window, the scan state `h`) is carried
//!   token-sequentially inside and across blocks, never reassociated;
//! * lane parallelism ([`pool`](super::pool)) only shards *which thread*
//!   computes a lane; no arithmetic moves across lanes.
//!
//! This is what lets every golden / policy / continuous-batching test double
//! as a correctness oracle for the fused and multi-threaded paths, and it is
//! pinned directly by `tests/kernels_identity.rs`.
//!
//! All kernels take raw `&[f32]` slices with explicit dims so they are
//! testable without a bound model; the reference backend wires them to its
//! weight views. `nt` is always the number of rows (tokens or decode lanes)
//! in the block.

use std::sync::atomic::{AtomicU8, Ordering};

use anyhow::{bail, Result};

/// Residual rows processed per block by the fused sequence path. Sized so a
/// block's scratch (`nt·proj_w` floats and friends) stays L1-resident at
/// every geometry we run; recurrent state carries across blocks, so the
/// value changes performance, never results.
pub const TOKEN_BLOCK: usize = 16;

// ---------------------------------------------------------------------------
// Kernel mode: scalar interpreter vs fused block kernels
// ---------------------------------------------------------------------------

/// Which implementation of the reference-backend math runs.
///
/// Both modes compute bit-identical results (see the module docs); `Scalar`
/// is kept as the plain-loop oracle the fused path is pinned against, and as
/// the baseline arm of `benches/runtime.rs`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelMode {
    /// The original one-token-at-a-time interpreter loops.
    Scalar,
    /// Cache-blocked, fused kernels (this module).
    Fused,
}

impl KernelMode {
    /// Parse a mode name as used by `--kernels` and `TOR_SSM_KERNELS`.
    ///
    /// ```
    /// use tor_ssm::runtime::kernels::KernelMode;
    /// assert_eq!(KernelMode::from_name("scalar").unwrap(), KernelMode::Scalar);
    /// assert_eq!(KernelMode::from_name("fused").unwrap(), KernelMode::Fused);
    /// assert!(KernelMode::from_name("simd").is_err());
    /// ```
    pub fn from_name(name: &str) -> Result<KernelMode> {
        match name {
            "scalar" => Ok(KernelMode::Scalar),
            "fused" | "" => Ok(KernelMode::Fused),
            other => bail!("unknown kernel mode {other:?} (expected scalar|fused)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            KernelMode::Scalar => "scalar",
            KernelMode::Fused => "fused",
        }
    }
}

/// Process-wide mode. 0 = unset (resolve from env on first read),
/// 1 = scalar, 2 = fused.
static MODE: AtomicU8 = AtomicU8::new(0);

/// The active kernel mode. Defaults to [`KernelMode::Fused`]; the first
/// read honours `TOR_SSM_KERNELS=scalar|fused`, and [`set_mode`] overrides
/// at any time (benches and the identity tests flip it between runs —
/// results are bit-identical either way, so a mid-flight flip is benign).
pub fn mode() -> KernelMode {
    match MODE.load(Ordering::Relaxed) {
        1 => KernelMode::Scalar,
        2 => KernelMode::Fused,
        _ => {
            let m = match std::env::var("TOR_SSM_KERNELS") {
                Ok(v) => KernelMode::from_name(&v).unwrap_or_else(|e| {
                    // A typo'd env var must not silently measure the wrong
                    // configuration; warn loudly and use the default.
                    eprintln!("[warn] ignoring TOR_SSM_KERNELS: {e:#}; using fused");
                    KernelMode::Fused
                }),
                Err(_) => KernelMode::Fused,
            };
            set_mode(m);
            m
        }
    }
}

/// Override the process-wide kernel mode.
///
/// ```
/// use tor_ssm::runtime::kernels::{mode, set_mode, KernelMode};
/// set_mode(KernelMode::Scalar);
/// assert_eq!(mode(), KernelMode::Scalar);
/// set_mode(KernelMode::Fused);
/// assert_eq!(mode(), KernelMode::Fused);
/// ```
pub fn set_mode(m: KernelMode) {
    let v = match m {
        KernelMode::Scalar => 1,
        KernelMode::Fused => 2,
    };
    MODE.store(v, Ordering::Relaxed);
}

/// One-line description of the active execution configuration
/// (`<mode> kernels, <n> decode thread(s)`), for serve/bench banners.
pub fn exec_summary() -> String {
    format!("{} kernels, {} decode thread(s)", mode().name(), super::pool::workers())
}

// ---------------------------------------------------------------------------
// Activations + norms (shared by the scalar and fused paths)
// ---------------------------------------------------------------------------

/// Logistic sigmoid.
pub fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// SiLU / swish: `x · sigmoid(x)`.
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// The RMSNorm scale factor `1 / sqrt(mean(x²) + 1e-5)`, with the summation
/// order every caller shares (ascending index — the rounding sequence is
/// part of the determinism contract).
pub fn rms_inv(x: &[f32]) -> f32 {
    let ms = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    1.0 / (ms + 1e-5).sqrt()
}

/// RMSNorm one row: `out[i] = x[i] · rms_inv(x) · g[i]`.
///
/// ```
/// use tor_ssm::runtime::kernels::rmsnorm;
/// let mut out = [0.0f32; 3];
/// rmsnorm(&[3.0, 0.0, -4.0], &[1.0, 1.0, 1.0], &mut out);
/// let ms: f32 = out.iter().map(|v| v * v).sum::<f32>() / 3.0;
/// assert!((ms - 1.0).abs() < 1e-3);
/// ```
pub fn rmsnorm(x: &[f32], g: &[f32], out: &mut [f32]) {
    let inv = rms_inv(x);
    for i in 0..x.len() {
        out[i] = x[i] * inv * g[i];
    }
}

// ---------------------------------------------------------------------------
// Stage 1: fused RMSNorm + in-projection
// ---------------------------------------------------------------------------

/// Fused RMSNorm + in-projection over a block of `nt` residual rows:
/// `proj[t] = rmsnorm(xs[t]) ⊙ g · w` for each row, with `w` (`d × pw`,
/// row-major) streamed **once per block** instead of once per row.
///
/// `inv` is an `nt`-float scratch. Bit-identity: for each `(t, j)` the
/// accumulation runs over `c` ascending, and each addend is
/// `(x·inv)·g · w` — the scalar path's exact expression and order.
///
/// ```
/// use tor_ssm::runtime::kernels::{fused_rmsnorm_inproj, rmsnorm};
/// let (nt, d, pw) = (2, 3, 2);
/// let xs = [0.5f32, -1.0, 2.0, 1.5, 0.25, -0.75];
/// let g = [1.0f32, 0.9, 1.1];
/// let w = [0.2f32, -0.1, 0.4, 0.3, -0.5, 0.6]; // d × pw
/// let mut proj = [0.0f32; 4];
/// let mut inv = [0.0f32; 2];
/// fused_rmsnorm_inproj(&xs, &g, &w, nt, d, pw, &mut proj, &mut inv);
/// // equals the unfused reference: rmsnorm per row, then row · w
/// for t in 0..nt {
///     let mut xn = [0.0f32; 3];
///     rmsnorm(&xs[t * d..(t + 1) * d], &g, &mut xn);
///     for j in 0..pw {
///         let mut acc = 0.0f32;
///         for c in 0..d {
///             acc += xn[c] * w[c * pw + j];
///         }
///         assert_eq!(acc, proj[t * pw + j]);
///     }
/// }
/// ```
pub fn fused_rmsnorm_inproj(
    xs: &[f32],
    g: &[f32],
    w: &[f32],
    nt: usize,
    d: usize,
    pw: usize,
    proj: &mut [f32],
    inv: &mut [f32],
) {
    debug_assert_eq!(xs.len(), nt * d);
    debug_assert_eq!(g.len(), d);
    debug_assert_eq!(w.len(), d * pw);
    debug_assert_eq!(proj.len(), nt * pw);
    debug_assert!(inv.len() >= nt);
    for t in 0..nt {
        inv[t] = rms_inv(&xs[t * d..(t + 1) * d]);
    }
    proj.fill(0.0);
    for c in 0..d {
        let row = &w[c * pw..(c + 1) * pw];
        let gc = g[c];
        for t in 0..nt {
            let xc = xs[t * d + c] * inv[t] * gc;
            let prow = &mut proj[t * pw..(t + 1) * pw];
            for j in 0..pw {
                prow[j] += xc * row[j];
            }
        }
    }
}

/// The in-projection column that feeds conv channel `ch`: `u_pre` occupies
/// columns `0..di`, `z` occupies `di..2di`, and (mamba2) `b_pre ++ c_pre`
/// sit at `2di..`. Shared by both conv kernels so the mapping exists once.
#[inline]
fn conv_src_col(ch: usize, di: usize) -> usize {
    if ch < di {
        ch
    } else {
        2 * di + (ch - di)
    }
}

// ---------------------------------------------------------------------------
// Stage 2: blocked depthwise causal conv
// ---------------------------------------------------------------------------

/// Depthwise causal conv over a block of `nt` *sequential* tokens, one
/// evolving window per channel (prefill/eval). `tail` is the `[ch × k1]`
/// rolling window carried in from the previous block and written back out,
/// so block boundaries never change results. Each channel's weights and
/// window are held in registers for the whole block — the per-token
/// re-slicing of the scalar path disappears.
///
/// `inp` is the block's in-projection output (`nt × pw`); channel `ch`
/// reads column `ch` (`< di`) or `2·di + (ch − di)` (mamba2 B/C channels).
/// `out` is `nt × conv_ch`, pre-activation.
pub fn causal_conv_seq(
    inp: &[f32],
    pw: usize,
    di: usize,
    conv_w: &[f32],
    conv_b: &[f32],
    tail: &mut [f32],
    out: &mut [f32],
    nt: usize,
) {
    let conv_ch = conv_b.len();
    let d_conv = conv_w.len() / conv_ch;
    let k1 = d_conv - 1;
    assert!(k1 >= 1 && k1 <= 8, "conv window k1={k1} outside the supported 1..=8");
    debug_assert_eq!(inp.len(), nt * pw);
    debug_assert_eq!(tail.len(), conv_ch * k1);
    debug_assert_eq!(out.len(), nt * conv_ch);
    for ch in 0..conv_ch {
        let w = &conv_w[ch * d_conv..(ch + 1) * d_conv];
        let b = conv_b[ch];
        let src = conv_src_col(ch, di);
        let t0 = &mut tail[ch * k1..(ch + 1) * k1];
        let mut win = [0.0f32; 8];
        win[..k1].copy_from_slice(t0);
        for t in 0..nt {
            let cur = inp[t * pw + src];
            // Scalar order: bias + w[k1]·cur first, then the window taps
            // ascending — kept verbatim so every rounding matches.
            let mut acc = b + w[k1] * cur;
            for j in 0..k1 {
                acc += w[j] * win[j];
            }
            out[t * conv_ch + ch] = acc;
            for j in 0..k1 - 1 {
                win[j] = win[j + 1];
            }
            win[k1 - 1] = cur;
        }
        t0.copy_from_slice(&win[..k1]);
    }
}

/// Depthwise causal conv, one step for each of `nt` independent decode
/// lanes: lane `t` advances its own window `tails[t]` (`[nt × ch × k1]`,
/// the decode frame's contiguous lane-chunk layout) by one token. No state
/// crosses lanes — the scalar per-lane update runs verbatim, just batched
/// so `conv_w`/`conv_b` stream once per chunk.
pub fn causal_conv_batch(
    inp: &[f32],
    pw: usize,
    di: usize,
    conv_w: &[f32],
    conv_b: &[f32],
    tails: &mut [f32],
    out: &mut [f32],
    nt: usize,
) {
    let conv_ch = conv_b.len();
    let d_conv = conv_w.len() / conv_ch;
    let k1 = d_conv - 1;
    debug_assert_eq!(inp.len(), nt * pw);
    debug_assert_eq!(tails.len(), nt * conv_ch * k1);
    debug_assert_eq!(out.len(), nt * conv_ch);
    for t in 0..nt {
        let tail = &mut tails[t * conv_ch * k1..(t + 1) * conv_ch * k1];
        for ch in 0..conv_ch {
            let w = &conv_w[ch * d_conv..(ch + 1) * d_conv];
            let cur = inp[t * pw + conv_src_col(ch, di)];
            let tl = &mut tail[ch * k1..(ch + 1) * k1];
            let mut acc = conv_b[ch] + w[k1] * cur;
            for j in 0..k1 {
                acc += w[j] * tl[j];
            }
            for j in 0..k1 - 1 {
                tl[j] = tl[j + 1];
            }
            tl[k1 - 1] = cur;
            out[t * conv_ch + ch] = acc;
        }
    }
}

// ---------------------------------------------------------------------------
// Stage 3: selectivity parameters
// ---------------------------------------------------------------------------

/// `u = silu(conv)` over the first `di` channels of each row.
pub fn silu_channels(conv: &[f32], conv_ch: usize, di: usize, u: &mut [f32], nt: usize) {
    debug_assert_eq!(conv.len(), nt * conv_ch);
    debug_assert_eq!(u.len(), nt * di);
    for t in 0..nt {
        for i in 0..di {
            u[t * di + i] = silu(conv[t * conv_ch + i]);
        }
    }
}

/// Mamba2: `B`/`C` are conv output channels `di..di+n` / `di+n..di+2n`.
pub fn copy_bc_channels(
    conv: &[f32],
    conv_ch: usize,
    di: usize,
    n: usize,
    bs: &mut [f32],
    cs: &mut [f32],
    nt: usize,
) {
    debug_assert_eq!(conv.len(), nt * conv_ch);
    debug_assert_eq!(bs.len(), nt * n);
    debug_assert_eq!(cs.len(), nt * n);
    for t in 0..nt {
        let row = &conv[t * conv_ch..(t + 1) * conv_ch];
        bs[t * n..(t + 1) * n].copy_from_slice(&row[di..di + n]);
        cs[t * n..(t + 1) * n].copy_from_slice(&row[di + n..di + 2 * n]);
    }
}

/// Mamba: derive `B, C` from post-conv `u` via `bc` (`di × 2n`, row-major),
/// streamed once per block. For each `(t, j)` both accumulators run over
/// `i` ascending with `B` then `C` updated per tap — the scalar order.
pub fn bc_project(u: &[f32], bc: &[f32], n: usize, bs: &mut [f32], cs: &mut [f32], nt: usize) {
    let di = u.len() / nt;
    debug_assert_eq!(bc.len(), di * 2 * n);
    debug_assert_eq!(bs.len(), nt * n);
    debug_assert_eq!(cs.len(), nt * n);
    bs.fill(0.0);
    cs.fill(0.0);
    for i in 0..di {
        let row = &bc[i * 2 * n..(i + 1) * 2 * n];
        for t in 0..nt {
            let ui = u[t * di + i];
            let brow = t * n;
            for j in 0..n {
                bs[brow + j] += ui * row[j];
                cs[brow + j] += ui * row[n + j];
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Stage 4: selective scan + SiLU gate (fused emit)
// ---------------------------------------------------------------------------

/// Selective scan over `nt` *sequential* tokens with the gate fused into
/// the emit: `h[i][j] ← decay·h + u·B`, `y[t][i] = (Σ_j h·C + D·u) ·
/// silu(z)`. State rows are walked `i`-major so each `h` row stays hot for
/// the whole block; per `(i, j)` the token recurrence still runs strictly
/// ascending (that order IS the scan — it is never reassociated).
///
/// `zs` points at the in-projection block (`nt × pw`); the gate column for
/// channel `i` is `di + i`.
pub fn scan_gate_seq(
    u: &[f32],
    bs: &[f32],
    cs: &[f32],
    zs: &[f32],
    pw: usize,
    decay: &[f32],
    d_skip: &[f32],
    n: usize,
    h: &mut [f32],
    y: &mut [f32],
    nt: usize,
) {
    let di = d_skip.len();
    debug_assert_eq!(u.len(), nt * di);
    debug_assert_eq!(bs.len(), nt * n);
    debug_assert_eq!(cs.len(), nt * n);
    debug_assert_eq!(zs.len(), nt * pw);
    debug_assert_eq!(decay.len(), di * n);
    debug_assert_eq!(h.len(), di * n);
    debug_assert_eq!(y.len(), nt * di);
    for i in 0..di {
        let hrow = &mut h[i * n..(i + 1) * n];
        let drow = &decay[i * n..(i + 1) * n];
        for t in 0..nt {
            let ui = u[t * di + i];
            let brow = &bs[t * n..(t + 1) * n];
            let crow = &cs[t * n..(t + 1) * n];
            let mut acc = 0.0f32;
            for j in 0..n {
                hrow[j] = drow[j] * hrow[j] + ui * brow[j];
                acc += hrow[j] * crow[j];
            }
            let z = zs[t * pw + di + i];
            y[t * di + i] = (acc + d_skip[i] * ui) * silu(z);
        }
    }
}

/// Selective scan, one step for each of `nt` independent decode lanes:
/// lane `t` advances its own state `hs[t]` (`[nt × di × n]`, the decode
/// frame's contiguous lane-chunk layout). Identical per-lane math to
/// [`scan_gate_seq`] with a one-token block.
pub fn scan_gate_batch(
    u: &[f32],
    bs: &[f32],
    cs: &[f32],
    zs: &[f32],
    pw: usize,
    decay: &[f32],
    d_skip: &[f32],
    n: usize,
    hs: &mut [f32],
    y: &mut [f32],
    nt: usize,
) {
    let di = d_skip.len();
    debug_assert_eq!(hs.len(), nt * di * n);
    debug_assert_eq!(y.len(), nt * di);
    for t in 0..nt {
        let h = &mut hs[t * di * n..(t + 1) * di * n];
        let ui_base = t * di;
        let brow = &bs[t * n..(t + 1) * n];
        let crow = &cs[t * n..(t + 1) * n];
        for i in 0..di {
            let ui = u[ui_base + i];
            let hrow = &mut h[i * n..(i + 1) * n];
            let drow = &decay[i * n..(i + 1) * n];
            let mut acc = 0.0f32;
            for j in 0..n {
                hrow[j] = drow[j] * hrow[j] + ui * brow[j];
                acc += hrow[j] * crow[j];
            }
            let z = zs[t * pw + di + i];
            y[t * di + i] = (acc + d_skip[i] * ui) * silu(z);
        }
    }
}

// ---------------------------------------------------------------------------
// Stage 5: output projection, accumulated into the residual stream
// ---------------------------------------------------------------------------

/// `xs[t] += y[t] · w` for a block of rows, with `w` (`di × d`, row-major)
/// streamed once per block. Per `(t, c)` the accumulation runs over `i`
/// ascending — the scalar path's order.
pub fn outproj_acc(y: &[f32], w: &[f32], d: usize, xs: &mut [f32], nt: usize) {
    let di = y.len() / nt;
    debug_assert_eq!(w.len(), di * d);
    debug_assert_eq!(xs.len(), nt * d);
    for i in 0..di {
        let row = &w[i * d..(i + 1) * d];
        for t in 0..nt {
            let yi = y[t * di + i];
            let xrow = &mut xs[t * d..(t + 1) * d];
            for c in 0..d {
                xrow[c] += yi * row[c];
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Head: fused final RMSNorm + tied-embedding logits
// ---------------------------------------------------------------------------

/// Final RMSNorm + tied-embedding head over a block of `nt` residual rows:
/// normalise every row into the `xn` scratch (`nt × d`), then stream the
/// embedding matrix **once per block**, emitting `out[t][v] = xn[t] ·
/// embed[v]`. The scalar path streams all `vocab × d` embedding floats per
/// row; this is the single largest traffic saving in the eval path.
pub fn head_norm_logits(
    xs: &[f32],
    g: &[f32],
    embed: &[f32],
    vocab: usize,
    out: &mut [f32],
    xn: &mut [f32],
    nt: usize,
) {
    let d = g.len();
    debug_assert_eq!(xs.len(), nt * d);
    debug_assert_eq!(embed.len(), vocab * d);
    debug_assert_eq!(out.len(), nt * vocab);
    debug_assert!(xn.len() >= nt * d);
    for t in 0..nt {
        let inv = rms_inv(&xs[t * d..(t + 1) * d]);
        for c in 0..d {
            xn[t * d + c] = xs[t * d + c] * inv * g[c];
        }
    }
    for v in 0..vocab {
        let row = &embed[v * d..(v + 1) * d];
        for t in 0..nt {
            let xrow = &xn[t * d..(t + 1) * d];
            let mut acc = 0.0f32;
            for c in 0..d {
                acc += xrow[c] * row[c];
            }
            out[t * vocab + v] = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randv(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn mode_roundtrip_and_parse() {
        for m in [KernelMode::Scalar, KernelMode::Fused] {
            set_mode(m);
            assert_eq!(mode(), m);
            assert_eq!(KernelMode::from_name(m.name()).unwrap(), m);
        }
        set_mode(KernelMode::Fused);
        assert!(KernelMode::from_name("avx").is_err());
        assert!(exec_summary().contains("fused"));
    }

    /// The block kernels must equal their naive single-row counterparts
    /// bit-for-bit, for any block size.
    #[test]
    fn fused_inproj_matches_unfused_bitwise() {
        let (d, pw) = (8, 20);
        let mut rng = Rng::new(7);
        let g = randv(&mut rng, d);
        let w = randv(&mut rng, d * pw);
        for nt in [1, 2, 5] {
            let xs = randv(&mut rng, nt * d);
            let mut proj = vec![0.0f32; nt * pw];
            let mut inv = vec![0.0f32; nt];
            fused_rmsnorm_inproj(&xs, &g, &w, nt, d, pw, &mut proj, &mut inv);
            for t in 0..nt {
                let mut xn = vec![0.0f32; d];
                rmsnorm(&xs[t * d..(t + 1) * d], &g, &mut xn);
                let mut want = vec![0.0f32; pw];
                for c in 0..d {
                    let xc = xn[c];
                    for j in 0..pw {
                        want[j] += xc * w[c * pw + j];
                    }
                }
                assert_eq!(&proj[t * pw..(t + 1) * pw], &want[..], "row {t} of block {nt}");
            }
        }
    }

    /// Conv over a sequence must not depend on how the tokens are blocked:
    /// the window carries across block boundaries.
    #[test]
    fn conv_seq_block_boundaries_are_invisible() {
        let (di, n, d_conv) = (4, 2, 4);
        let conv_ch = di + 2 * n;
        let pw = 2 * di + 2 * n;
        let k1 = d_conv - 1;
        let mut rng = Rng::new(9);
        let conv_w = randv(&mut rng, conv_ch * d_conv);
        let conv_b = randv(&mut rng, conv_ch);
        let total = 7;
        let inp = randv(&mut rng, total * pw);

        let run = |chunks: &[usize]| {
            let mut tail = vec![0.0f32; conv_ch * k1];
            let mut out = vec![0.0f32; total * conv_ch];
            let mut at = 0usize;
            for &nt in chunks {
                causal_conv_seq(
                    &inp[at * pw..(at + nt) * pw],
                    pw,
                    di,
                    &conv_w,
                    &conv_b,
                    &mut tail,
                    &mut out[at * conv_ch..(at + nt) * conv_ch],
                    nt,
                );
                at += nt;
            }
            (out, tail)
        };
        let whole = run(&[7]);
        let split = run(&[2, 3, 2]);
        let single = run(&[1; 7]);
        assert_eq!(whole, split);
        assert_eq!(whole, single);
    }

    /// Same invariance for the scan: the state recurrence carries across
    /// blocks, so any blocking gives bit-identical y and final h.
    #[test]
    fn scan_seq_block_boundaries_are_invisible() {
        let (di, n) = (4, 3);
        let pw = 2 * di;
        let mut rng = Rng::new(11);
        let decay: Vec<f32> = randv(&mut rng, di * n).iter().map(|v| sigmoid(*v)).collect();
        let d_skip = randv(&mut rng, di);
        let total = 6;
        let u = randv(&mut rng, total * di);
        let bs = randv(&mut rng, total * n);
        let cs = randv(&mut rng, total * n);
        let zs = randv(&mut rng, total * pw);

        let run = |chunks: &[usize]| {
            let mut h = vec![0.0f32; di * n];
            let mut y = vec![0.0f32; total * di];
            let mut at = 0usize;
            for &nt in chunks {
                scan_gate_seq(
                    &u[at * di..(at + nt) * di],
                    &bs[at * n..(at + nt) * n],
                    &cs[at * n..(at + nt) * n],
                    &zs[at * pw..(at + nt) * pw],
                    pw,
                    &decay,
                    &d_skip,
                    n,
                    &mut h,
                    &mut y[at * di..(at + nt) * di],
                    nt,
                );
                at += nt;
            }
            (y, h)
        };
        assert_eq!(run(&[6]), run(&[1; 6]));
        assert_eq!(run(&[6]), run(&[4, 2]));
    }

    /// The batch kernels are per-lane independent: one 3-lane call equals
    /// three 1-lane calls on the matching lane slices.
    #[test]
    fn batch_kernels_have_no_lane_crosstalk() {
        let (di, n, d_conv) = (4, 2, 4);
        let conv_ch = di; // mamba-style
        let pw = 2 * di;
        let k1 = d_conv - 1;
        let nt = 3;
        let mut rng = Rng::new(13);
        let conv_w = randv(&mut rng, conv_ch * d_conv);
        let conv_b = randv(&mut rng, conv_ch);
        let inp = randv(&mut rng, nt * pw);
        let tails0 = randv(&mut rng, nt * conv_ch * k1);
        let decay: Vec<f32> = randv(&mut rng, di * n).iter().map(|v| sigmoid(*v)).collect();
        let d_skip = randv(&mut rng, di);
        let u = randv(&mut rng, nt * di);
        let bs = randv(&mut rng, nt * n);
        let cs = randv(&mut rng, nt * n);
        let hs0 = randv(&mut rng, nt * di * n);

        let mut tails = tails0.clone();
        let mut out = vec![0.0f32; nt * conv_ch];
        causal_conv_batch(&inp, pw, di, &conv_w, &conv_b, &mut tails, &mut out, nt);
        let mut hs = hs0.clone();
        let mut y = vec![0.0f32; nt * di];
        scan_gate_batch(&u, &bs, &cs, &inp, pw, &decay, &d_skip, n, &mut hs, &mut y, nt);

        for t in 0..nt {
            let mut tail1 = tails0[t * conv_ch * k1..(t + 1) * conv_ch * k1].to_vec();
            let mut out1 = vec![0.0f32; conv_ch];
            causal_conv_batch(
                &inp[t * pw..(t + 1) * pw],
                pw,
                di,
                &conv_w,
                &conv_b,
                &mut tail1,
                &mut out1,
                1,
            );
            assert_eq!(&out[t * conv_ch..(t + 1) * conv_ch], &out1[..]);
            assert_eq!(&tails[t * conv_ch * k1..(t + 1) * conv_ch * k1], &tail1[..]);

            let mut h1 = hs0[t * di * n..(t + 1) * di * n].to_vec();
            let mut y1 = vec![0.0f32; di];
            scan_gate_batch(
                &u[t * di..(t + 1) * di],
                &bs[t * n..(t + 1) * n],
                &cs[t * n..(t + 1) * n],
                &inp[t * pw..(t + 1) * pw],
                pw,
                &decay,
                &d_skip,
                n,
                &mut h1,
                &mut y1,
                1,
            );
            assert_eq!(&y[t * di..(t + 1) * di], &y1[..]);
            assert_eq!(&hs[t * di * n..(t + 1) * di * n], &h1[..]);
        }
    }

    #[test]
    fn head_block_matches_per_row() {
        let (d, vocab) = (6, 11);
        let mut rng = Rng::new(17);
        let g = randv(&mut rng, d);
        let embed = randv(&mut rng, vocab * d);
        let nt = 3;
        let xs = randv(&mut rng, nt * d);
        let mut out = vec![0.0f32; nt * vocab];
        let mut xn = vec![0.0f32; nt * d];
        head_norm_logits(&xs, &g, &embed, vocab, &mut out, &mut xn, nt);
        for t in 0..nt {
            let mut xn1 = vec![0.0f32; d];
            rmsnorm(&xs[t * d..(t + 1) * d], &g, &mut xn1);
            for v in 0..vocab {
                let mut acc = 0.0f32;
                for c in 0..d {
                    acc += xn1[c] * embed[v * d + c];
                }
                assert_eq!(out[t * vocab + v], acc, "row {t} vocab {v}");
            }
        }
    }

    #[test]
    fn bc_project_matches_scalar_order() {
        let (di, n, nt) = (5, 3, 2);
        let mut rng = Rng::new(19);
        let u = randv(&mut rng, nt * di);
        let bc = randv(&mut rng, di * 2 * n);
        let mut bs = vec![0.0f32; nt * n];
        let mut cs = vec![0.0f32; nt * n];
        bc_project(&u, &bc, n, &mut bs, &mut cs, nt);
        for t in 0..nt {
            let mut b1 = vec![0.0f32; n];
            let mut c1 = vec![0.0f32; n];
            for i in 0..di {
                let ui = u[t * di + i];
                let row = &bc[i * 2 * n..(i + 1) * 2 * n];
                for j in 0..n {
                    b1[j] += ui * row[j];
                    c1[j] += ui * row[n + j];
                }
            }
            assert_eq!(&bs[t * n..(t + 1) * n], &b1[..]);
            assert_eq!(&cs[t * n..(t + 1) * n], &c1[..]);
        }
    }
}
