//! Host-side tensors and conversions to/from XLA literals.

use anyhow::{bail, ensure, Context, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor { shape, data: TensorData::F32(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor { shape, data: TensorData::I32(data) }
    }

    pub fn scalar_i32(v: i32) -> HostTensor {
        HostTensor { shape: vec![], data: TensorData::I32(vec![v]) }
    }

    pub fn zeros_f32(shape: Vec<usize>) -> HostTensor {
        let n = shape.iter().product();
        HostTensor::f32(shape, vec![0.0; n])
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => xla::Literal::vec1(v),
            TensorData::I32(v) => xla::Literal::vec1(v),
        };
        lit.reshape(&dims).context("reshaping literal")
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape().context("literal has no array shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = match shape.ty() {
            xla::ElementType::F32 => TensorData::F32(lit.to_vec::<f32>()?),
            xla::ElementType::S32 => TensorData::I32(lit.to_vec::<i32>()?),
            other => bail!("unsupported element type {other:?}"),
        };
        let t = HostTensor { shape: dims, data };
        ensure!(
            t.len() == match &t.data { TensorData::F32(v) => v.len(), TensorData::I32(v) => v.len() },
            "element count mismatch"
        );
        Ok(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let t = HostTensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn roundtrip_i32() {
        let t = HostTensor::i32(vec![4], vec![7, -1, 0, 3]);
        let back = HostTensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        HostTensor::f32(vec![2, 2], vec![1.0]);
    }
}
