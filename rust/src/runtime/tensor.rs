//! Host-side tensors: the currency of the [`Backend`](super::Backend) API.
//!
//! Backends convert these to whatever device representation they need (the
//! pjrt backend turns them into XLA literals/buffers; the reference backend
//! reads them in place).

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor { shape, data: TensorData::F32(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor { shape, data: TensorData::I32(data) }
    }

    pub fn scalar_i32(v: i32) -> HostTensor {
        HostTensor { shape: vec![], data: TensorData::I32(vec![v]) }
    }

    pub fn zeros_f32(shape: Vec<usize>) -> HostTensor {
        let n = shape.iter().product();
        HostTensor::f32(shape, vec![0.0; n])
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }
}

// ---------------------------------------------------------------------------
// Lane gather/scatter: moving per-sequence decode state between slot storage
// and the `[n_layer, n_lanes, row]` decode frame (DESIGN.md §6).
//
// The decode executables take state frames laid out layer-major
// (`[n_layer, batch, ...]`), while the state store keeps each sequence
// contiguous (`[n_layer, row]`). These helpers are the only place the
// frame stride math lives.
// ---------------------------------------------------------------------------

/// Copy a contiguous per-sequence state (`[n_layer, row]`) into lane `lane`
/// of a `[n_layer, n_lanes, row]` frame buffer.
pub fn write_lane(
    frame: &mut [f32],
    n_layer: usize,
    n_lanes: usize,
    row: usize,
    lane: usize,
    seq: &[f32],
) {
    assert_eq!(frame.len(), n_layer * n_lanes * row, "frame/layout mismatch");
    assert_eq!(seq.len(), n_layer * row, "sequence-state size mismatch");
    assert!(lane < n_lanes, "lane {lane} out of range (frame has {n_lanes})");
    for l in 0..n_layer {
        let dst = (l * n_lanes + lane) * row;
        frame[dst..dst + row].copy_from_slice(&seq[l * row..(l + 1) * row]);
    }
}

/// Zero lane `lane` of a `[n_layer, n_lanes, row]` frame buffer (idle-lane
/// reset).
pub fn zero_lane(frame: &mut [f32], n_layer: usize, n_lanes: usize, row: usize, lane: usize) {
    assert_eq!(frame.len(), n_layer * n_lanes * row, "frame/layout mismatch");
    assert!(lane < n_lanes, "lane {lane} out of range (frame has {n_lanes})");
    for l in 0..n_layer {
        let dst = (l * n_lanes + lane) * row;
        frame[dst..dst + row].fill(0.0);
    }
}

/// Copy lane `lane` of a `[n_layer, n_lanes, row]` frame buffer out into a
/// contiguous per-sequence state (`[n_layer, row]`).
pub fn read_lane(
    frame: &[f32],
    n_layer: usize,
    n_lanes: usize,
    row: usize,
    lane: usize,
    seq: &mut [f32],
) {
    assert_eq!(frame.len(), n_layer * n_lanes * row, "frame/layout mismatch");
    assert_eq!(seq.len(), n_layer * row, "sequence-state size mismatch");
    assert!(lane < n_lanes, "lane {lane} out of range (frame has {n_lanes})");
    for l in 0..n_layer {
        let src = (l * n_lanes + lane) * row;
        seq[l * row..(l + 1) * row].copy_from_slice(&frame[src..src + row]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_accessors() {
        let t = HostTensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.as_f32().unwrap()[4], 5.0);
        assert!(t.as_i32().is_err());

        let s = HostTensor::scalar_i32(9);
        assert_eq!(s.len(), 1);
        assert_eq!(s.as_i32().unwrap(), &[9]);

        let z = HostTensor::zeros_f32(vec![4, 2]);
        assert!(z.as_f32().unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        HostTensor::f32(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn lane_roundtrip() {
        // frame [n_layer=2, n_lanes=3, row=2]
        let mut frame = vec![0.0f32; 2 * 3 * 2];
        let seq = vec![1.0, 2.0, 3.0, 4.0]; // [2, 2]: layer0=[1,2], layer1=[3,4]
        write_lane(&mut frame, 2, 3, 2, 1, &seq);
        // layer-major layout: layer 0 lanes [_, (1,2), _], layer 1 [_, (3,4), _]
        assert_eq!(frame, vec![0., 0., 1., 2., 0., 0., 0., 0., 3., 4., 0., 0.]);
        let mut back = vec![0.0f32; 4];
        read_lane(&frame, 2, 3, 2, 1, &mut back);
        assert_eq!(back, seq);
        // neighbouring lanes untouched
        let mut lane0 = vec![9.0f32; 4];
        read_lane(&frame, 2, 3, 2, 0, &mut lane0);
        assert_eq!(lane0, vec![0.0; 4]);
    }

    #[test]
    fn lanes_are_disjoint() {
        let mut frame = vec![0.0f32; 6]; // [n_layer=1, n_lanes=2, row=3]
        write_lane(&mut frame, 1, 2, 3, 0, &[1.0, 1.0, 1.0]);
        write_lane(&mut frame, 1, 2, 3, 1, &[2.0, 2.0, 2.0]);
        assert_eq!(frame, vec![1.0, 1.0, 1.0, 2.0, 2.0, 2.0]);
        let mut a = vec![0.0f32; 3];
        read_lane(&frame, 1, 2, 3, 0, &mut a);
        assert_eq!(a, vec![1.0; 3]);
        // Zeroing one lane leaves its neighbour intact.
        zero_lane(&mut frame, 1, 2, 3, 1);
        assert_eq!(frame, vec![1.0, 1.0, 1.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    #[should_panic]
    fn write_lane_rejects_out_of_range() {
        let mut frame = vec![0.0f32; 4];
        write_lane(&mut frame, 1, 2, 2, 2, &[1.0, 1.0]);
    }
}
