//! Host-side tensors: the currency of the [`Backend`](super::Backend) API.
//!
//! Backends convert these to whatever device representation they need (the
//! pjrt backend turns them into XLA literals/buffers; the reference backend
//! reads them in place).

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor { shape, data: TensorData::F32(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor { shape, data: TensorData::I32(data) }
    }

    pub fn scalar_i32(v: i32) -> HostTensor {
        HostTensor { shape: vec![], data: TensorData::I32(vec![v]) }
    }

    pub fn zeros_f32(shape: Vec<usize>) -> HostTensor {
        let n = shape.iter().product();
        HostTensor::f32(shape, vec![0.0; n])
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }
}

// ---------------------------------------------------------------------------
// Per-channel symmetric int8 quantization (DESIGN.md §13). Blobs stay
// contiguous row-major so the kernels' unaligned 8-wide vector loads
// (`kernels::dot8_i8`/`axpy_i8`) can chunk them directly — no padding or
// re-layout is needed.
// ---------------------------------------------------------------------------

/// Which axis of a `[rows, cols]` matrix carries the per-channel scales.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantAxis {
    /// One scale per row (the tied embedding/head: output channel = vocab
    /// row, and the same scale serves the embedding-row lookup).
    Row,
    /// One scale per column (in/out projections: output channel = column).
    Col,
}

/// A per-channel symmetric int8 tensor: `w[r][c] ≈ q[r][c] · scale[ch]`
/// with `scale[ch] = max|w[ch]| / 127`, values rounded half away from zero
/// and saturated to ±127 (never −128, so the grid is symmetric). Produced
/// at load time by [`Weights::ensure_quant`](super::weights::Weights::ensure_quant);
/// the kernels consume it through [`MatRef::I8`](super::kernels::MatRef).
/// Locked against the python generator by `tests/quant_golden.rs`.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantTensor {
    pub shape: [usize; 2],
    pub axis: QuantAxis,
    pub q: Vec<i8>,
    pub scales: Vec<f32>,
}

/// One value onto the symmetric grid. `f32::round` rounds half away from
/// zero — the tie rule `python/compile/quant_golden.py` emulates. A
/// `scale == 0` channel (all-zero weights) quantizes to all zeros.
#[inline]
fn quantize_value(v: f32, scale: f32) -> i8 {
    if scale == 0.0 {
        return 0;
    }
    (v / scale).round().clamp(-127.0, 127.0) as i8
}

/// Quantize a `[rows, cols]` matrix with one scale per **row**.
pub fn quantize_rows(data: &[f32], rows: usize, cols: usize) -> QuantTensor {
    assert_eq!(data.len(), rows * cols, "shape/data mismatch");
    let mut scales = vec![0.0f32; rows];
    for r in 0..rows {
        let m = data[r * cols..(r + 1) * cols].iter().fold(0.0f32, |a, v| a.max(v.abs()));
        scales[r] = m / 127.0;
    }
    let mut q = vec![0i8; rows * cols];
    for r in 0..rows {
        let s = scales[r];
        for c in 0..cols {
            q[r * cols + c] = quantize_value(data[r * cols + c], s);
        }
    }
    QuantTensor { shape: [rows, cols], axis: QuantAxis::Row, q, scales }
}

/// Quantize a `[rows, cols]` matrix with one scale per **column**.
pub fn quantize_cols(data: &[f32], rows: usize, cols: usize) -> QuantTensor {
    assert_eq!(data.len(), rows * cols, "shape/data mismatch");
    let mut scales = vec![0.0f32; cols];
    for r in 0..rows {
        for c in 0..cols {
            scales[c] = scales[c].max(data[r * cols + c].abs());
        }
    }
    for s in scales.iter_mut() {
        *s /= 127.0;
    }
    let mut q = vec![0i8; rows * cols];
    for r in 0..rows {
        for c in 0..cols {
            q[r * cols + c] = quantize_value(data[r * cols + c], scales[c]);
        }
    }
    QuantTensor { shape: [rows, cols], axis: QuantAxis::Col, q, scales }
}

// ---------------------------------------------------------------------------
// Lane gather/scatter: moving per-sequence decode state between slot storage
// and the `[n_layer, n_lanes, row]` decode frame (DESIGN.md §6).
//
// The decode executables take state frames laid out layer-major
// (`[n_layer, batch, ...]`), while the state store keeps each sequence
// contiguous (`[n_layer, row]`). These helpers are the only place the
// frame stride math lives.
// ---------------------------------------------------------------------------

/// Copy a contiguous per-sequence state (`[n_layer, row]`) into lane `lane`
/// of a `[n_layer, n_lanes, row]` frame buffer.
pub fn write_lane(
    frame: &mut [f32],
    n_layer: usize,
    n_lanes: usize,
    row: usize,
    lane: usize,
    seq: &[f32],
) {
    assert_eq!(frame.len(), n_layer * n_lanes * row, "frame/layout mismatch");
    assert_eq!(seq.len(), n_layer * row, "sequence-state size mismatch");
    assert!(lane < n_lanes, "lane {lane} out of range (frame has {n_lanes})");
    for l in 0..n_layer {
        let dst = (l * n_lanes + lane) * row;
        frame[dst..dst + row].copy_from_slice(&seq[l * row..(l + 1) * row]);
    }
}

/// Zero lane `lane` of a `[n_layer, n_lanes, row]` frame buffer (idle-lane
/// reset).
pub fn zero_lane(frame: &mut [f32], n_layer: usize, n_lanes: usize, row: usize, lane: usize) {
    assert_eq!(frame.len(), n_layer * n_lanes * row, "frame/layout mismatch");
    assert!(lane < n_lanes, "lane {lane} out of range (frame has {n_lanes})");
    for l in 0..n_layer {
        let dst = (l * n_lanes + lane) * row;
        frame[dst..dst + row].fill(0.0);
    }
}

/// Copy lane `lane` of a `[n_layer, n_lanes, row]` frame buffer out into a
/// contiguous per-sequence state (`[n_layer, row]`).
pub fn read_lane(
    frame: &[f32],
    n_layer: usize,
    n_lanes: usize,
    row: usize,
    lane: usize,
    seq: &mut [f32],
) {
    assert_eq!(frame.len(), n_layer * n_lanes * row, "frame/layout mismatch");
    assert_eq!(seq.len(), n_layer * row, "sequence-state size mismatch");
    assert!(lane < n_lanes, "lane {lane} out of range (frame has {n_lanes})");
    for l in 0..n_layer {
        let src = (l * n_lanes + lane) * row;
        seq[l * row..(l + 1) * row].copy_from_slice(&frame[src..src + row]);
    }
}

// ---------------------------------------------------------------------------
// Lane-chunk views: no-copy, disjoint mutable access to contiguous lane
// ranges of a `[n_layer, n_lanes, row]` frame, one chunk per decode worker
// (DESIGN.md §11; PERFORMANCE.md). `write_lane`/`read_lane` move state in
// and out of the frame; these views let the workers mutate it in place.
// ---------------------------------------------------------------------------

/// A mutable view of lanes `start..start + lanes` of a
/// `[n_layer, n_lanes, row]` frame — every layer's slice of those lanes,
/// without copying the (lane-strided) data out.
///
/// Obtained from [`lane_chunks_mut`], which guarantees chunks are disjoint;
/// that is what makes handing one chunk to each worker thread sound. The
/// view is `Send` (workers own disjoint lanes) but deliberately not
/// `Clone`/`Sync` — exactly one owner may mutate a chunk.
///
/// ```
/// use tor_ssm::runtime::tensor::lane_chunks_mut;
/// // frame [n_layer=2, n_lanes=3, row=2]
/// let mut frame = vec![0.0f32; 12];
/// let mut chunks = lane_chunks_mut(&mut frame, 2, 3, 2, &[0..1, 1..3]).into_iter();
/// let (mut a, mut b) = (chunks.next().unwrap(), chunks.next().unwrap());
/// a.layer_mut(0).fill(1.0); // lane 0, layer 0
/// b.layer_mut(1).fill(2.0); // lanes 1–2, layer 1
/// assert_eq!(frame, vec![1., 1., 0., 0., 0., 0., 0., 0., 2., 2., 2., 2.]);
/// ```
pub struct LaneChunkMut<'a> {
    ptr: *mut f32,
    n_layer: usize,
    n_lanes: usize,
    row: usize,
    start: usize,
    lanes: usize,
    _frame: std::marker::PhantomData<&'a mut [f32]>,
}

// SAFETY: a chunk only ever dereferences frame elements inside its own
// (disjoint, `lane_chunks_mut`-checked) lane range, so moving it to another
// thread cannot alias another chunk's elements.
unsafe impl Send for LaneChunkMut<'_> {}

impl LaneChunkMut<'_> {
    /// Number of lanes in this chunk.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// First frame lane this chunk covers.
    pub fn start(&self) -> usize {
        self.start
    }

    /// Mutable slice of this chunk's lanes at layer `l`: `lanes × row`
    /// elements, contiguous (lanes are adjacent within a layer).
    pub fn layer_mut(&mut self, l: usize) -> &mut [f32] {
        assert!(l < self.n_layer, "layer {l} out of range ({})", self.n_layer);
        let off = (l * self.n_lanes + self.start) * self.row;
        // SAFETY: `off .. off + lanes*row` lies inside the frame (checked
        // at construction) and inside this chunk's exclusive lane range;
        // the &mut self receiver prevents overlapping slices from one chunk.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.add(off), self.lanes * self.row) }
    }
}

/// Split a `[n_layer, n_lanes, row]` frame into per-chunk mutable views,
/// one per entry of `bounds`. Bounds must be ascending, non-overlapping
/// lane ranges within `0..n_lanes` (the decode path builds them with
/// [`pool::partition`](super::pool::partition)); violations panic, so no
/// aliased view can ever be constructed.
pub fn lane_chunks_mut<'a>(
    frame: &'a mut [f32],
    n_layer: usize,
    n_lanes: usize,
    row: usize,
    bounds: &[std::ops::Range<usize>],
) -> Vec<LaneChunkMut<'a>> {
    assert_eq!(frame.len(), n_layer * n_lanes * row, "frame/layout mismatch");
    let mut prev = 0usize;
    for r in bounds {
        assert!(r.start >= prev && r.start <= r.end, "chunk bounds must ascend: {bounds:?}");
        assert!(r.end <= n_lanes, "chunk {r:?} exceeds {n_lanes} lanes");
        prev = r.end;
    }
    let ptr = frame.as_mut_ptr();
    bounds
        .iter()
        .map(|r| LaneChunkMut {
            ptr,
            n_layer,
            n_lanes,
            row,
            start: r.start,
            lanes: r.end - r.start,
            _frame: std::marker::PhantomData,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_accessors() {
        let t = HostTensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.as_f32().unwrap()[4], 5.0);
        assert!(t.as_i32().is_err());

        let s = HostTensor::scalar_i32(9);
        assert_eq!(s.len(), 1);
        assert_eq!(s.as_i32().unwrap(), &[9]);

        let z = HostTensor::zeros_f32(vec![4, 2]);
        assert!(z.as_f32().unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        HostTensor::f32(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn quantize_rows_saturates_and_scales_per_row() {
        // Row 0 peaks at 2.54, row 1 is all zeros, row 2 peaks at 0.127.
        let data = vec![2.54, -1.27, 0.01, 0.0, 0.0, 0.0, -0.127, 0.0635, 0.001];
        let qt = quantize_rows(&data, 3, 3);
        assert_eq!(qt.axis, QuantAxis::Row);
        assert_eq!(qt.shape, [3, 3]);
        assert_eq!(qt.scales[0], 2.54 / 127.0);
        // The channel max lands exactly on ±127; the zero row on scale 0/q 0.
        assert_eq!(qt.q[0], 127);
        assert_eq!(&qt.q[3..6], &[0, 0, 0]);
        assert_eq!(qt.scales[1], 0.0);
        assert_eq!(qt.q[6], -127);
        // Round-trip error per weight is ≤ scale/2 (the grid's half-step).
        for r in 0..3 {
            for c in 0..3 {
                let back = qt.q[r * 3 + c] as f32 * qt.scales[r];
                assert!(
                    (back - data[r * 3 + c]).abs() <= qt.scales[r] / 2.0 + 1e-12,
                    "r{r} c{c}"
                );
            }
        }
    }

    #[test]
    fn quantize_cols_scales_per_column() {
        // Column maxima: 4.0, 0.2.
        let data = vec![1.0, -0.2, -4.0, 0.1];
        let qt = quantize_cols(&data, 2, 2);
        assert_eq!(qt.axis, QuantAxis::Col);
        assert_eq!(qt.scales, vec![4.0 / 127.0, 0.2 / 127.0]);
        assert_eq!(qt.q[2], -127);
        assert_eq!(qt.q[1], -127);
        // 1.0 / (4/127) = 31.75 → rounds half away from zero to 32.
        assert_eq!(qt.q[0], 32);
        // 0.1 / (0.2/127) = 63.5 → ties round away from zero to 64.
        assert_eq!(qt.q[3], 64);
    }

    #[test]
    fn lane_roundtrip() {
        // frame [n_layer=2, n_lanes=3, row=2]
        let mut frame = vec![0.0f32; 2 * 3 * 2];
        let seq = vec![1.0, 2.0, 3.0, 4.0]; // [2, 2]: layer0=[1,2], layer1=[3,4]
        write_lane(&mut frame, 2, 3, 2, 1, &seq);
        // layer-major layout: layer 0 lanes [_, (1,2), _], layer 1 [_, (3,4), _]
        assert_eq!(frame, vec![0., 0., 1., 2., 0., 0., 0., 0., 3., 4., 0., 0.]);
        let mut back = vec![0.0f32; 4];
        read_lane(&frame, 2, 3, 2, 1, &mut back);
        assert_eq!(back, seq);
        // neighbouring lanes untouched
        let mut lane0 = vec![9.0f32; 4];
        read_lane(&frame, 2, 3, 2, 0, &mut lane0);
        assert_eq!(lane0, vec![0.0; 4]);
    }

    #[test]
    fn lanes_are_disjoint() {
        let mut frame = vec![0.0f32; 6]; // [n_layer=1, n_lanes=2, row=3]
        write_lane(&mut frame, 1, 2, 3, 0, &[1.0, 1.0, 1.0]);
        write_lane(&mut frame, 1, 2, 3, 1, &[2.0, 2.0, 2.0]);
        assert_eq!(frame, vec![1.0, 1.0, 1.0, 2.0, 2.0, 2.0]);
        let mut a = vec![0.0f32; 3];
        read_lane(&frame, 1, 2, 3, 0, &mut a);
        assert_eq!(a, vec![1.0; 3]);
        // Zeroing one lane leaves its neighbour intact.
        zero_lane(&mut frame, 1, 2, 3, 1);
        assert_eq!(frame, vec![1.0, 1.0, 1.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    #[should_panic]
    fn write_lane_rejects_out_of_range() {
        let mut frame = vec![0.0f32; 4];
        write_lane(&mut frame, 1, 2, 2, 2, &[1.0, 1.0]);
    }

    #[test]
    fn lane_chunks_cover_disjoint_strided_ranges() {
        // frame [n_layer=2, n_lanes=4, row=3]; chunks {0..2, 2..3, 3..4}
        let (nl, lanes, row) = (2usize, 4usize, 3usize);
        let mut frame = vec![0.0f32; nl * lanes * row];
        let chunks = lane_chunks_mut(&mut frame, nl, lanes, row, &[0..2, 2..3, 3..4]);
        assert_eq!(chunks.len(), 3);
        for mut c in chunks {
            for l in 0..nl {
                let s = c.layer_mut(l);
                assert_eq!(s.len(), c.lanes() * row);
                for (i, v) in s.iter_mut().enumerate() {
                    // tag: layer, absolute lane, row index
                    let lane = c.start() + i / row;
                    *v = (l * 100 + lane * 10 + i % row) as f32;
                }
            }
        }
        // every element written exactly once with its own tag
        for l in 0..nl {
            for lane in 0..lanes {
                for r in 0..row {
                    let got = frame[(l * lanes + lane) * row + r];
                    assert_eq!(got, (l * 100 + lane * 10 + r) as f32, "l{l} lane{lane} r{r}");
                }
            }
        }
    }

    #[test]
    fn lane_chunks_interop_with_write_read_lane() {
        let (nl, lanes, row) = (3usize, 2usize, 4usize);
        let mut frame = vec![0.0f32; nl * lanes * row];
        let seq: Vec<f32> = (0..nl * row).map(|i| i as f32 + 1.0).collect();
        write_lane(&mut frame, nl, lanes, row, 1, &seq);
        {
            let mut chunks = lane_chunks_mut(&mut frame, nl, lanes, row, &[0..1, 1..2]);
            // chunk 1 sees exactly the written lane, layer by layer
            for l in 0..nl {
                assert_eq!(chunks[1].layer_mut(l), &seq[l * row..(l + 1) * row]);
            }
            // mutate through the view…
            for l in 0..nl {
                for v in chunks[1].layer_mut(l).iter_mut() {
                    *v += 0.5;
                }
            }
        }
        // …and read it back through the stride converter
        let mut back = vec![0.0f32; nl * row];
        read_lane(&frame, nl, lanes, row, 1, &mut back);
        for (b, s) in back.iter().zip(&seq) {
            assert_eq!(*b, s + 0.5);
        }
        // lane 0 untouched
        let mut lane0 = vec![9.0f32; nl * row];
        read_lane(&frame, nl, lanes, row, 0, &mut lane0);
        assert!(lane0.iter().all(|&v| v == 0.0));
    }

    #[test]
    #[should_panic]
    fn lane_chunks_reject_overlap() {
        let mut frame = vec![0.0f32; 8];
        let _ = lane_chunks_mut(&mut frame, 1, 4, 2, &[0..2, 1..4]);
    }

    #[test]
    #[should_panic]
    fn lane_chunks_reject_out_of_range() {
        let mut frame = vec![0.0f32; 8];
        let _ = lane_chunks_mut(&mut frame, 1, 4, 2, &[0..5]);
    }
}
