//! Host-side tensors: the currency of the [`Backend`](super::Backend) API.
//!
//! Backends convert these to whatever device representation they need (the
//! pjrt backend turns them into XLA literals/buffers; the reference backend
//! reads them in place).

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor { shape, data: TensorData::F32(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor { shape, data: TensorData::I32(data) }
    }

    pub fn scalar_i32(v: i32) -> HostTensor {
        HostTensor { shape: vec![], data: TensorData::I32(vec![v]) }
    }

    pub fn zeros_f32(shape: Vec<usize>) -> HostTensor {
        let n = shape.iter().product();
        HostTensor::f32(shape, vec![0.0; n])
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_accessors() {
        let t = HostTensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.as_f32().unwrap()[4], 5.0);
        assert!(t.as_i32().is_err());

        let s = HostTensor::scalar_i32(9);
        assert_eq!(s.len(), 1);
        assert_eq!(s.as_i32().unwrap(), &[9]);

        let z = HostTensor::zeros_f32(vec![4, 2]);
        assert!(z.as_f32().unwrap().iter().all(|&x| x == 0.0));
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        HostTensor::f32(vec![2, 2], vec![1.0]);
    }
}
