//! Weight blobs: load/save the concatenated f32 layout described by the
//! manifest's `params` metadata (param_order contract, written by
//! `aot.py::export_weights` for real artifacts and by
//! [`crate::fixtures`] for synthetic ones). Device residency lives behind
//! [`super::Backend::upload_weights`].
//!
//! This module also owns the process-wide [`WeightFormat`] knob
//! (`--weights f32|int8`, env `TOR_SSM_WEIGHTS`, optional per-model
//! manifest default) and the load-time int8 quantization it triggers
//! (DESIGN.md §13): [`Weights::ensure_quant`] derives per-channel i8 blobs
//! for the big matmul operands — the tied embedding/head (per row) and
//! every layer's in/out projection (per column) — while activations, the
//! conv path, `bc_proj`, norms and the SSM state stay f32, so recurrence
//! semantics and the prefix-cache/preemption bit-identity contracts are
//! untouched.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::manifest::{Manifest, ModelEntry};
use crate::runtime::kernels::ignored_env_warning;
use crate::runtime::tensor::{quantize_cols, quantize_rows, QuantAxis, QuantTensor};
use crate::runtime::HostTensor;

// ---------------------------------------------------------------------------
// Weight format knob
// ---------------------------------------------------------------------------

/// Storage format for the big matmul operands. `F32` is the dense format
/// everything before DESIGN.md §13 used; `Int8` quantizes per output
/// channel at load time (symmetric `scale = max|w|/127`, stored as an
/// `(i8 blob, f32 scales)` pair per param). Int8 changes outputs vs f32 by
/// quantization error, but is bit-identical across all three kernel tiers
/// at any thread count (see `runtime/kernels.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightFormat {
    F32,
    Int8,
}

impl WeightFormat {
    /// Parse a format name as used by `--weights` and `TOR_SSM_WEIGHTS`.
    ///
    /// ```
    /// use tor_ssm::runtime::weights::WeightFormat;
    /// assert_eq!(WeightFormat::from_name("f32").unwrap(), WeightFormat::F32);
    /// assert_eq!(WeightFormat::from_name("int8").unwrap(), WeightFormat::Int8);
    /// assert!(WeightFormat::from_name("int4").is_err());
    /// ```
    pub fn from_name(name: &str) -> Result<WeightFormat> {
        match name {
            "f32" | "" => Ok(WeightFormat::F32),
            "int8" => Ok(WeightFormat::Int8),
            other => bail!("unknown weight format {other:?} (expected f32|int8)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            WeightFormat::F32 => "f32",
            WeightFormat::Int8 => "int8",
        }
    }
}

/// Process-wide format. 0 = unset (resolve from env on first read),
/// 1 = f32 (explicit), 2 = int8 (explicit), 3 = defaulted — env absent or
/// typo'd, so a manifest `weights_format` may still override per model.
static FORMAT: AtomicU8 = AtomicU8::new(0);

fn store_format(f: WeightFormat, explicit: bool) {
    let v = match (f, explicit) {
        (WeightFormat::F32, true) => 1,
        (WeightFormat::Int8, true) => 2,
        (_, false) => 3,
    };
    // ORDERING: Relaxed — idempotent knob cache; racing resolutions agree,
    // and no other memory is published through this flag.
    FORMAT.store(v, Ordering::Relaxed);
}

/// The active process-wide weight format. Defaults to
/// [`WeightFormat::F32`]; the first read honours
/// `TOR_SSM_WEIGHTS=f32|int8` (a typo warns loudly and falls back — it
/// must not silently measure the wrong configuration), and [`set_format`]
/// overrides at any time.
pub fn format() -> WeightFormat {
    // ORDERING: Relaxed — idempotent env resolution (same as store_format).
    match FORMAT.load(Ordering::Relaxed) {
        1 | 3 => WeightFormat::F32,
        2 => WeightFormat::Int8,
        _ => {
            let (f, explicit) = match std::env::var("TOR_SSM_WEIGHTS") {
                Ok(v) => match WeightFormat::from_name(&v) {
                    Ok(f) => (f, true),
                    Err(e) => {
                        eprintln!("{}", ignored_env_warning("TOR_SSM_WEIGHTS", &e, "f32"));
                        (WeightFormat::F32, false)
                    }
                },
                Err(_) => (WeightFormat::F32, false),
            };
            store_format(f, explicit);
            f
        }
    }
}

/// Override the process-wide weight format (the `--weights` flag; the
/// bench matrix flips it between cells). An explicit setting beats any
/// manifest `weights_format` default.
///
/// ```
/// use tor_ssm::runtime::weights::{format, set_format, WeightFormat};
/// set_format(WeightFormat::Int8);
/// assert_eq!(format(), WeightFormat::Int8);
/// set_format(WeightFormat::F32);
/// assert_eq!(format(), WeightFormat::F32);
/// ```
pub fn set_format(f: WeightFormat) {
    store_format(f, true);
}

/// The format a model's weights are uploaded in: an explicit knob
/// ([`set_format`] / a valid `TOR_SSM_WEIGHTS`) wins; otherwise the
/// model's optional manifest default (`weights_format`, validated at
/// manifest parse time); otherwise f32. Consulted by
/// `Backend::upload_weights`, so the knob threads through `ProgramSpec`
/// (which carries the [`ModelEntry`]) automatically.
pub fn effective_format(model: &ModelEntry) -> WeightFormat {
    let f = format(); // resolves env on first read
    // ORDERING: Relaxed — re-reads the knob cache format() just resolved.
    match FORMAT.load(Ordering::Relaxed) {
        1 | 2 => f,
        _ => model
            .weights_format
            .as_deref()
            .and_then(|s| WeightFormat::from_name(s).ok())
            .unwrap_or(WeightFormat::F32),
    }
}

// ---------------------------------------------------------------------------
// Host-side parameter set
// ---------------------------------------------------------------------------

/// Host-side parameter set, ordered per the manifest's param layout.
#[derive(Debug, Clone)]
pub struct Weights {
    pub tensors: Vec<HostTensor>,
    /// Per-channel int8 blobs for the big matmul operands, keyed by param
    /// name — populated by [`ensure_quant`](Self::ensure_quant) when the
    /// effective format is int8, `None` otherwise. Behind an `Arc` so
    /// cloning a `Weights` (upload, snapshots) shares the blobs.
    pub quant: Option<Arc<BTreeMap<String, QuantTensor>>>,
}

impl Weights {
    pub fn load(man: &Manifest, model: &ModelEntry, rel_path: &str) -> Result<Weights> {
        let path = man.path(rel_path);
        let bytes = std::fs::read(&path).with_context(|| format!("reading weights {path:?}"))?;
        Self::from_bytes(model, &bytes)
    }

    pub fn load_init(man: &Manifest, model: &ModelEntry) -> Result<Weights> {
        Self::load(man, model, &model.init_weights)
    }

    pub fn from_bytes(model: &ModelEntry, bytes: &[u8]) -> Result<Weights> {
        let total: usize = model.params.iter().map(|p| p.bytes).sum();
        ensure!(
            bytes.len() == total,
            "weight blob is {} bytes, manifest expects {total}",
            bytes.len()
        );
        let mut tensors = Vec::with_capacity(model.params.len());
        for p in &model.params {
            let chunk = &bytes[p.offset..p.offset + p.bytes];
            let data: Vec<f32> = chunk
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            ensure!(
                data.len() == p.shape.iter().product::<usize>(),
                "param {} size mismatch",
                p.name
            );
            tensors.push(HostTensor::f32(p.shape.clone(), data));
        }
        Ok(Weights { tensors, quant: None })
    }

    /// Serialize to the manifest's concatenated little-endian f32 layout —
    /// the exact byte buffer [`Self::from_bytes`] parses and the registry
    /// digests (`runtime/registry.rs`). Bit-preserving both ways: bytes
    /// pass through `f32::from_le_bytes`/`to_le_bytes` with no arithmetic,
    /// so publish → load → publish reproduces identical blobs.
    pub fn to_bytes(&self, model: &ModelEntry) -> Result<Vec<u8>> {
        ensure!(
            self.tensors.len() == model.params.len(),
            "weights have {} tensors, manifest lists {} params",
            self.tensors.len(),
            model.params.len()
        );
        let mut out: Vec<u8> = Vec::new();
        for (t, p) in self.tensors.iter().zip(&model.params) {
            let data = t.as_f32()?;
            ensure!(data.len() * 4 == p.bytes, "param {} changed size", p.name);
            for v in data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        Ok(out)
    }

    pub fn save(&self, model: &ModelEntry, path: impl AsRef<Path>) -> Result<()> {
        let out = self.to_bytes(model)?;
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir).ok();
        }
        std::fs::write(path.as_ref(), out)
            .with_context(|| format!("writing weights {:?}", path.as_ref()))
    }

    /// Quantize the big matmul operands (idempotent): `embedding` per row
    /// — one scale per vocab row serves both the head dot and the
    /// embedding-row lookup — and every `layers.*.in_proj` /
    /// `layers.*.out_proj` per column. All other params (norms, conv,
    /// `bc_proj`, `d_skip`, `a_log`) stay f32. The f32 tensors are kept —
    /// they remain the save/train representation — so int8 is purely an
    /// execution format.
    pub fn ensure_quant(&mut self, model: &ModelEntry) -> Result<()> {
        if self.quant.is_some() {
            return Ok(());
        }
        ensure!(
            self.tensors.len() == model.params.len(),
            "weights have {} tensors, manifest lists {} params",
            self.tensors.len(),
            model.params.len()
        );
        let mut map = BTreeMap::new();
        for (t, p) in self.tensors.iter().zip(&model.params) {
            let axis = if p.name == "embedding" {
                QuantAxis::Row
            } else if p.name.ends_with(".in_proj") || p.name.ends_with(".out_proj") {
                QuantAxis::Col
            } else {
                continue;
            };
            ensure!(t.shape.len() == 2, "quantized param {} must be 2-D", p.name);
            let data = t.as_f32().with_context(|| format!("quantizing {}", p.name))?;
            let qt = match axis {
                QuantAxis::Row => quantize_rows(data, t.shape[0], t.shape[1]),
                QuantAxis::Col => quantize_cols(data, t.shape[0], t.shape[1]),
            };
            map.insert(p.name.clone(), qt);
        }
        ensure!(!map.is_empty(), "no quantizable params found (unexpected param naming?)");
        self.quant = Some(Arc::new(map));
        Ok(())
    }

    /// The quantized blob for `name`, if [`ensure_quant`](Self::ensure_quant)
    /// produced one.
    pub fn quant_of(&self, name: &str) -> Option<&QuantTensor> {
        self.quant.as_ref().and_then(|m| m.get(name))
    }

    /// Mean of |w| across all params — a cheap training-progress fingerprint.
    pub fn mean_abs(&self) -> f64 {
        let mut sum = 0.0;
        let mut n = 0usize;
        for t in &self.tensors {
            if let Ok(v) = t.as_f32() {
                sum += v.iter().map(|x| x.abs() as f64).sum::<f64>();
                n += v.len();
            }
        }
        sum / n.max(1) as f64
    }
}
