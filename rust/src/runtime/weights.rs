//! Weight blobs: load/save the concatenated f32 layout described by the
//! manifest's `params` metadata (param_order contract, written by
//! `aot.py::export_weights` for real artifacts and by
//! [`crate::fixtures`] for synthetic ones). Device residency lives behind
//! [`super::Backend::upload_weights`].

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::manifest::{Manifest, ModelEntry};
use crate::runtime::HostTensor;

/// Host-side parameter set, ordered per the manifest's param layout.
#[derive(Debug, Clone)]
pub struct Weights {
    pub tensors: Vec<HostTensor>,
}

impl Weights {
    pub fn load(man: &Manifest, model: &ModelEntry, rel_path: &str) -> Result<Weights> {
        let path = man.path(rel_path);
        let bytes = std::fs::read(&path).with_context(|| format!("reading weights {path:?}"))?;
        Self::from_bytes(model, &bytes)
    }

    pub fn load_init(man: &Manifest, model: &ModelEntry) -> Result<Weights> {
        Self::load(man, model, &model.init_weights)
    }

    pub fn from_bytes(model: &ModelEntry, bytes: &[u8]) -> Result<Weights> {
        let total: usize = model.params.iter().map(|p| p.bytes).sum();
        ensure!(
            bytes.len() == total,
            "weight blob is {} bytes, manifest expects {total}",
            bytes.len()
        );
        let mut tensors = Vec::with_capacity(model.params.len());
        for p in &model.params {
            let chunk = &bytes[p.offset..p.offset + p.bytes];
            let data: Vec<f32> = chunk
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            ensure!(
                data.len() == p.shape.iter().product::<usize>(),
                "param {} size mismatch",
                p.name
            );
            tensors.push(HostTensor::f32(p.shape.clone(), data));
        }
        Ok(Weights { tensors })
    }

    pub fn save(&self, model: &ModelEntry, path: impl AsRef<Path>) -> Result<()> {
        let mut out: Vec<u8> = Vec::new();
        for (t, p) in self.tensors.iter().zip(&model.params) {
            let data = t.as_f32()?;
            ensure!(data.len() * 4 == p.bytes, "param {} changed size", p.name);
            for v in data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir).ok();
        }
        std::fs::write(path.as_ref(), out)
            .with_context(|| format!("writing weights {:?}", path.as_ref()))
    }

    /// Mean of |w| across all params — a cheap training-progress fingerprint.
    pub fn mean_abs(&self) -> f64 {
        let mut sum = 0.0;
        let mut n = 0usize;
        for t in &self.tensors {
            if let Ok(v) = t.as_f32() {
                sum += v.iter().map(|x| x.abs() as f64).sum::<f64>();
                n += v.len();
            }
        }
        sum / n.max(1) as f64
    }
}
