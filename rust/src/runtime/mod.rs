//! PJRT runtime: load `artifacts/*.hlo.txt`, compile once, execute many.
//!
//! Pattern from /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`/`execute_b`. HLO *text* is the interchange
//! format (the 0.5.1 extension rejects jax≥0.5 64-bit-id protos).
//!
//! Hot-path discipline: weights are uploaded to device once
//! (`DeviceWeights`) and passed by reference to `execute_b`; only the small
//! activations (tokens in, logits out) cross the host boundary per request.

pub mod tensor;
pub mod weights;

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::manifest::{HloEntry, Manifest, ModelEntry};
pub use tensor::{HostTensor, TensorData};
pub use weights::{DeviceWeights, Weights};

pub struct Runtime {
    client: xla::PjRtClient,
    /// Compiled executable cache keyed by HLO file path.
    cache: std::cell::RefCell<HashMap<String, Arc<Executable>>>,
    pub compile_log: std::cell::RefCell<Vec<(String, f64)>>,
}

pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            cache: Default::default(),
            compile_log: Default::default(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text module (cached by path).
    pub fn load(&self, path: impl AsRef<Path>) -> Result<Arc<Executable>> {
        let key = path.as_ref().to_string_lossy().to_string();
        if let Some(e) = self.cache.borrow().get(&key) {
            return Ok(Arc::clone(e));
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&key)
            .with_context(|| format!("parsing HLO text {key}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {key}"))?;
        let dt = t0.elapsed().as_secs_f64();
        self.compile_log.borrow_mut().push((key.clone(), dt));
        let e = Arc::new(Executable { exe, name: key.clone() });
        self.cache.borrow_mut().insert(key, Arc::clone(&e));
        Ok(e)
    }

    pub fn load_entry(&self, man: &Manifest, entry: &HloEntry) -> Result<Arc<Executable>> {
        self.load(man.path(&entry.file))
    }

    /// Upload a host tensor to a device-resident buffer.
    pub fn upload(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        match &t.data {
            TensorData::F32(v) => self
                .client
                .buffer_from_host_buffer(v, &t.shape, None)
                .context("uploading f32 buffer"),
            TensorData::I32(v) => self
                .client
                .buffer_from_host_buffer(v, &t.shape, None)
                .context("uploading i32 buffer"),
        }
    }

    pub fn upload_weights(&self, man: &Manifest, model: &ModelEntry, w: &Weights) -> Result<DeviceWeights> {
        weights::upload(self, man, model, w)
    }
}

impl Executable {
    /// Execute with host literals; returns the decomposed output tuple.
    pub fn run<L: std::borrow::Borrow<xla::Literal>>(&self, args: &[L]) -> Result<Vec<HostTensor>> {
        let bufs = self.exe.execute(args).context("execute")?;
        Self::collect(bufs)
    }

    /// Execute with device-resident buffers (the hot path).
    pub fn run_b(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<HostTensor>> {
        let bufs = self.exe.execute_b(args).context("execute_b")?;
        Self::collect(bufs)
    }

    /// Execute with device buffers but keep outputs on device (tuple buffer).
    pub fn run_b_raw(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::PjRtBuffer>> {
        let mut bufs = self.exe.execute_b(args).context("execute_b")?;
        ensure!(!bufs.is_empty(), "no outputs");
        Ok(bufs.remove(0))
    }

    fn collect(bufs: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<HostTensor>> {
        ensure!(!bufs.is_empty() && !bufs[0].is_empty(), "empty execution result");
        // Single replica; the root is a tuple (lowered with return_tuple=True).
        let lit = bufs[0][0].to_literal_sync().context("download result")?;
        let parts = lit.to_tuple().context("decompose result tuple")?;
        parts.iter().map(HostTensor::from_literal).collect()
    }
}
