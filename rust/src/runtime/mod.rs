//! Execution layer: the pluggable [`Backend`] trait and the [`Runtime`]
//! front-end the rest of the crate talks to.
//!
//! A backend turns a [`ProgramSpec`] (one manifest HLO entry plus the model
//! metadata it belongs to) into an [`Executable`], and owns weight
//! residency via [`DeviceWeights`]. Two implementations exist:
//!
//! * [`reference`] — the **default**: a pure-Rust interpreter of the small
//!   op set our Mamba/Mamba-2 models need (embedding, RMSNorm, depthwise
//!   causal conv, selective scan, gated output projection, tied head) with
//!   plan-driven intra-layer token reduction. Hermetic: no `artifacts/`,
//!   no Python, no XLA. Used by the zero-artifact test suite and
//!   `repro demo`.
//! * `pjrt` *(cargo feature `pjrt`; gated, hence no intra-doc link)* — the
//!   AOT path: parse
//!   `artifacts/*.hlo.txt`, compile once via the PJRT CPU client, execute
//!   many. Weights are uploaded to device once and passed by reference;
//!   only small activations cross the host boundary per request.
//!
//! Hot-path discipline is part of the trait contract: `Executable::execute`
//! takes device-resident weights plus host activations, and backends must
//! keep per-call host traffic proportional to activations, not parameters.
//! See DESIGN.md §2 (backend split), §4 (decode-state shape convention),
//! and §9 (perf) for the full contracts.
//!
//! The reference backend's hot path runs through the fused, cache-blocked
//! kernels of [`kernels`] and shards decode frames across the lane-parallel
//! worker pool of [`pool`] — both bit-identical to the scalar interpreter
//! at every thread count (DESIGN.md §11; PERFORMANCE.md has the threading
//! model and the determinism argument). The `simd` kernel tier keeps that
//! contract everywhere except the f32 logit head, whose per-logit dot
//! reassociates under a documented error bound, and the int8 weight format
//! ([`weights::WeightFormat`]) is bit-identical across all three tiers
//! (DESIGN.md §13).

pub mod kernels;
pub mod pool;
pub mod reference;
pub mod registry;
pub mod tensor;
pub mod weights;

#[cfg(feature = "pjrt")]
pub mod pjrt;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, ensure, Result};

use crate::manifest::{HloEntry, Manifest, ModelEntry, Plan};
use crate::reduction::policy::PolicySpec;

pub use tensor::{HostTensor, TensorData};
pub use weights::Weights;

/// Decode-frame idle-lane sentinel token. A lane whose input token is
/// `IDLE_LANE` holds no sequence this step: interpreting backends skip its
/// model math entirely (state untouched, logits zero) instead of decoding a
/// phantom token. Deliberately distinct from PAD, which is a *real*
/// vocabulary id (0) that a prompt may legitimately contain — conflating
/// the two is exactly the bug the length-aware prefill path fixed
/// (DESIGN.md §6). Engines only emit it when the backend is length-aware
/// ([`Backend::interprets_lengths`]); AOT frames keep decoding PAD.
pub const IDLE_LANE: i32 = -1;

/// What a compiled program computes. Mirrors `HloEntry::kind`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProgramKind {
    /// Full-sequence forward: `(tokens[B,L]) -> (logits[B,out,V], kept[B,out])`.
    Eval,
    /// Prompt ingestion: `(tokens[B,L]) -> (logits[B,V], conv_state, ssm_state)`.
    Prefill,
    /// One decode step: `(tokens[B], conv, ssm) -> (logits[B,V], conv, ssm)`.
    Decode,
    /// Fused train step (params/opt-state threading); PJRT-only today.
    Train,
}

/// Everything a backend needs to materialise one executable: the manifest
/// entry's geometry and reduction plan plus the owning model's metadata.
#[derive(Debug, Clone)]
pub struct ProgramSpec {
    pub tag: String,
    pub kind: ProgramKind,
    pub batch: usize,
    pub seq_len: usize,
    pub out_len: usize,
    /// Static token-reduction plan (None for dense programs).
    pub plan: Option<Plan>,
    /// Which reduction algorithm runs at the plan's boundaries (DESIGN.md
    /// §10). Resolved from the entry's `reduction` block by
    /// [`ProgramSpec::from_entry`]; serving lanes override it per variant
    /// via [`Runtime::load_entry_with_policy`]. `None` for dense programs.
    /// The pjrt backend ignores it — AOT graphs bake their method into the
    /// lowered HLO — which is why overrides are guarded by
    /// [`Backend::interprets_policies`].
    pub policy: Option<PolicySpec>,
    /// Whether this program takes a per-sequence `lengths: [batch]` i32
    /// input after the tokens (prefill programs only; DESIGN.md §6). When
    /// set, the backend stops each sequence's conv window + scan at its
    /// true end, takes last-logits at the true last token, and accepts a
    /// resume state pair `(conv0, ssm0)` for chunked prefill. Resolved from
    /// the manifest entry's `lengths` flag; only interpreting backends
    /// ([`Backend::interprets_lengths`]) may compile such an entry.
    pub takes_lengths: bool,
    /// Path to the AOT-lowered HLO text (used by the pjrt backend only).
    pub hlo_path: PathBuf,
    /// Owning model: dims + param layout contract.
    pub model: ModelEntry,
}

impl ProgramSpec {
    pub fn from_entry(man: &Manifest, model: &ModelEntry, entry: &HloEntry) -> Result<ProgramSpec> {
        let kind = match entry.kind.as_str() {
            "eval" => ProgramKind::Eval,
            "prefill" => ProgramKind::Prefill,
            "decode" => ProgramKind::Decode,
            "train" => ProgramKind::Train,
            other => bail!("unknown HLO kind {other:?} for entry {}", entry.tag),
        };
        let policy = match (&entry.reduction, &entry.plan) {
            (Some(r), Some(_)) => PolicySpec::from_manifest_reduction(r),
            _ => None,
        };
        Ok(ProgramSpec {
            tag: entry.tag.clone(),
            kind,
            batch: entry.batch,
            seq_len: entry.seq_len,
            out_len: entry.out_len,
            plan: entry.plan.clone(),
            policy,
            takes_lengths: entry.takes_lengths,
            hlo_path: man.path(&entry.file),
            model: model.clone(),
        })
    }
}

/// Backend-owned parameter residency. The reference backend keeps weights on
/// the host; the pjrt backend keeps per-param device buffers.
pub enum DeviceWeights {
    Host(Weights),
    #[cfg(feature = "pjrt")]
    Pjrt(Vec<xla::PjRtBuffer>),
}

impl DeviceWeights {
    /// Host view, for backends that execute on the CPU directly.
    // unreachable_patterns: the `_` arm only exists for the pjrt variant.
    #[allow(unreachable_patterns)]
    pub fn host(&self) -> Result<&Weights> {
        match self {
            DeviceWeights::Host(w) => Ok(w),
            _ => bail!("weights are device-resident, not host-resident"),
        }
    }
}

/// A compiled program, ready to execute many times.
pub trait Executable: Send + Sync {
    fn name(&self) -> &str;

    /// Hot path: device-resident weights + host activation tensors in,
    /// host tensors out.
    fn execute(&self, weights: &DeviceWeights, inputs: &[HostTensor]) -> Result<Vec<HostTensor>>;

    /// Raw path (the fused train step): every argument streamed from the
    /// host (by reference — params/opt state can be large), outputs
    /// returned to the host.
    fn execute_raw(&self, inputs: &[&HostTensor]) -> Result<Vec<HostTensor>>;
}

/// An execution substrate: compiles [`ProgramSpec`]s and owns weight upload.
pub trait Backend: Send + Sync {
    fn platform(&self) -> String;
    fn compile(&self, spec: &ProgramSpec) -> Result<Arc<dyn Executable>>;
    fn upload_weights(&self, model: &ModelEntry, w: &Weights) -> Result<DeviceWeights>;

    /// Whether this backend dispatches [`ProgramSpec::policy`] at run time.
    /// Interpreters (the reference backend) return true; AOT backends keep
    /// the default false — their graphs bake the reduction method in, so a
    /// policy override that disagrees with the export must be rejected
    /// rather than silently ignored.
    fn interprets_policies(&self) -> bool {
        false
    }

    /// Whether this backend honours the per-sequence `lengths` input on
    /// prefill programs ([`ProgramSpec::takes_lengths`]) and the
    /// [`IDLE_LANE`] sentinel on decode frames. Interpreters return true;
    /// AOT backends keep the default false — their graphs have a fixed
    /// input arity and scan every frame position unconditionally, so a
    /// lengths-marked entry must be rejected rather than silently padded
    /// back into the PAD-pollution bug (DESIGN.md §6).
    fn interprets_lengths(&self) -> bool {
        false
    }
}

/// Front-end owned by callers: a boxed backend plus a compile cache keyed by
/// `model/tag`, with compile timing kept for reporting.
pub struct Runtime {
    backend: Box<dyn Backend>,
    cache: RefCell<HashMap<String, Arc<dyn Executable>>>,
    pub compile_log: RefCell<Vec<(String, f64)>>,
}

impl Runtime {
    pub fn with_backend(backend: Box<dyn Backend>) -> Runtime {
        Runtime { backend, cache: Default::default(), compile_log: Default::default() }
    }

    /// The default hermetic backend.
    pub fn reference() -> Result<Runtime> {
        Ok(Runtime::with_backend(Box::new(reference::ReferenceBackend::new())))
    }

    /// Back-compat constructor: the default backend (reference).
    pub fn cpu() -> Result<Runtime> {
        Runtime::reference()
    }

    /// PJRT CPU client (requires the `pjrt` cargo feature and the real XLA
    /// extension at link time).
    #[cfg(feature = "pjrt")]
    pub fn pjrt_cpu() -> Result<Runtime> {
        Ok(Runtime::with_backend(Box::new(pjrt::PjrtBackend::cpu()?)))
    }

    /// Select a backend by name: `"reference"` or `"pjrt"`.
    pub fn from_name(name: &str) -> Result<Runtime> {
        match name {
            "reference" | "" => Runtime::reference(),
            #[cfg(feature = "pjrt")]
            "pjrt" => Runtime::pjrt_cpu(),
            #[cfg(not(feature = "pjrt"))]
            "pjrt" => bail!("this binary was built without the `pjrt` feature"),
            other => bail!("unknown backend {other:?} (expected reference|pjrt)"),
        }
    }

    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    /// Whether the active backend honours per-sequence prefill lengths and
    /// the [`IDLE_LANE`] decode sentinel (see [`Backend::interprets_lengths`]).
    pub fn interprets_lengths(&self) -> bool {
        self.backend.interprets_lengths()
    }

    /// Compile (cached) the executable for one manifest entry of `model`,
    /// with the entry's own (manifest-resolved) reduction policy.
    pub fn load_entry(
        &self,
        man: &Manifest,
        model: &ModelEntry,
        entry: &HloEntry,
    ) -> Result<Arc<dyn Executable>> {
        self.load_entry_with_policy(man, model, entry, None)
    }

    /// [`Runtime::load_entry`] with a per-lane reduction-policy override
    /// (DESIGN.md §10): the entry supplies the compiled geometry and the
    /// schedule plan, `policy` supplies the algorithm run at the plan's
    /// boundaries. Cached separately per policy. On backends that execute
    /// AOT-lowered graphs (no run-time dispatch), an override that disagrees
    /// with what the entry bakes in is an error, not a silent no-op.
    pub fn load_entry_with_policy(
        &self,
        man: &Manifest,
        model: &ModelEntry,
        entry: &HloEntry,
        policy: Option<&PolicySpec>,
    ) -> Result<Arc<dyn Executable>> {
        // The manifest root disambiguates same-named models/entries loaded
        // from different manifests through one Runtime (two fixtures in one
        // test, two artifact dirs in one process): without it, the second
        // manifest would silently execute the first one's cached program —
        // and its frame geometry.
        let key = match policy {
            Some(p) => {
                format!("{}::{}/{}#{}", man.root.display(), model.name, entry.tag, p.to_variant())
            }
            None => format!("{}::{}/{}", man.root.display(), model.name, entry.tag),
        };
        if let Some(e) = self.cache.borrow().get(&key) {
            return Ok(Arc::clone(e));
        }
        let mut spec = ProgramSpec::from_entry(man, model, entry)?;
        ensure!(
            !spec.takes_lengths || self.backend.interprets_lengths(),
            "backend {:?} executes AOT-lowered graphs with a fixed input arity: entry {} \
             declares a per-sequence `lengths` input, which only run-time interpreting \
             backends honour (re-export the entry without `lengths` for this backend)",
            self.backend.platform(),
            entry.tag
        );
        if let Some(p) = policy {
            ensure!(
                spec.plan.is_some(),
                "variant {:?} asks for token reduction but entry {} has no schedule plan",
                p.to_variant(),
                entry.tag
            );
            if !self.backend.interprets_policies()
                && !spec.policy.as_ref().is_some_and(|d| d.compatible_with(p))
            {
                bail!(
                    "backend {:?} executes AOT-lowered graphs: entry {} bakes in {:?}, so \
                     policy {:?} needs its own export (run-time policy dispatch is \
                     reference-backend only)",
                    self.backend.platform(),
                    entry.tag,
                    spec.policy.as_ref().map(|d| d.to_variant()),
                    p.to_variant()
                );
            }
            spec.policy = Some(p.clone());
        }
        let t0 = Instant::now();
        let exe = self.backend.compile(&spec)?;
        self.compile_log.borrow_mut().push((key.clone(), t0.elapsed().as_secs_f64()));
        self.cache.borrow_mut().insert(key, Arc::clone(&exe));
        Ok(exe)
    }

    pub fn upload_weights(&self, model: &ModelEntry, w: &Weights) -> Result<DeviceWeights> {
        self.backend.upload_weights(model, w)
    }
}

/// Per-layer decode-state shapes for one model — THE shape convention
/// shared by the serving engine, the reference backend, and the benches
/// (aot.py records the same):
/// mamba  → conv `[nl, B, d_inner, d_conv-1]`, ssm `[nl, B, d_inner, d_state]`;
/// mamba2 → conv `[nl, B, d_inner+2·d_state, d_conv-1]`,
///          ssm `[nl, B, d_inner/headdim, headdim, d_state]`.
pub fn decode_state_shapes(model: &ModelEntry, batch: usize) -> (Vec<usize>, Vec<usize>) {
    let k1 = reference::D_CONV - 1;
    let (nl, di, n) = (model.n_layer, model.d_inner, model.d_state);
    if model.arch == "mamba" {
        (vec![nl, batch, di, k1], vec![nl, batch, di, n])
    } else {
        (
            vec![nl, batch, di + 2 * n, k1],
            vec![nl, batch, di / reference::HEADDIM, reference::HEADDIM, n],
        )
    }
}
