//! Experiment harness: shared context + result cache for regenerating every
//! table and figure in the paper (DESIGN.md §5 experiment index).
//!
//! Results are cached under `artifacts/results/` keyed by
//! (model, variant tag, item count, weights fingerprint) so tables that
//! share variants (e.g. Table 1 and Figure 1) don't recompute; `--fresh`
//! bypasses the cache.

pub mod figures;
pub mod harness;
pub mod tables;

use std::collections::HashMap;

use anyhow::{Context, Result};

use crate::data::{load_tasks, Task};
use crate::eval::{evaluate, EvalResult, TaskResult};
use crate::manifest::{HloEntry, Manifest};
use crate::runtime::{DeviceWeights, Runtime};
use crate::tokenizer::Tokenizer;
use crate::train::load_best_weights;
use crate::util::json::{num, obj, s, Json};

pub struct Ctx {
    pub rt: Runtime,
    pub man: Manifest,
    pub tok: Tokenizer,
    pub tasks: Vec<Task>,
    pub max_items: usize,
    pub fresh: bool,
    weights: HashMap<String, (DeviceWeights, String)>, // model -> (buffers, fingerprint)
}

impl Ctx {
    /// Context on the default (reference) backend.
    pub fn new(artifacts: &str, max_items: usize, fresh: bool) -> Result<Ctx> {
        Ctx::with_backend(artifacts, max_items, fresh, "reference")
    }

    /// Context on a named backend ("reference" | "pjrt").
    pub fn with_backend(
        artifacts: &str,
        max_items: usize,
        fresh: bool,
        backend: &str,
    ) -> Result<Ctx> {
        let man = Manifest::load(artifacts)?;
        let rt = Runtime::from_name(backend)?;
        let tok = Tokenizer::load(man.path(&man.vocab_file))?;
        let tasks = load_tasks(man.path(&man.tasks_file))?;
        Ok(Ctx { rt, man, tok, tasks, max_items, fresh, weights: HashMap::new() })
    }

    fn ensure_weights(&mut self, model: &str) -> Result<String> {
        if !self.weights.contains_key(model) {
            let me = self.man.model(model)?.clone();
            let (w, trained) = load_best_weights(&self.man, &me)?;
            if !trained {
                eprintln!(
                    "[warn] no checkpoint for {model}; evaluating INIT weights. \
                     Run `repro train --model {model}` first for meaningful tables."
                );
            }
            let fp = format!("{}:{:.6}", if trained { "ckpt" } else { "init" }, w.mean_abs());
            let dw = self.rt.upload_weights(&me, &w)?;
            self.weights.insert(model.to_string(), (dw, fp));
        }
        Ok(self.weights[model].1.clone())
    }

    /// Evaluate one exported variant (cached).
    pub fn eval_variant(&mut self, model: &str, entry: &HloEntry) -> Result<EvalResult> {
        self.eval_policy_variant(model, entry, None)
    }

    /// [`Ctx::eval_variant`] with a reduction-policy override (DESIGN.md
    /// §10): the entry supplies the compiled geometry + schedule plan, the
    /// policy supplies the algorithm run at the plan's boundaries. Cached
    /// separately per policy variant.
    pub fn eval_policy_variant(
        &mut self,
        model: &str,
        entry: &HloEntry,
        policy: Option<&crate::reduction::policy::PolicySpec>,
    ) -> Result<EvalResult> {
        let fp = self.ensure_weights(model)?;
        let label = match policy {
            Some(p) => format!("{}__{}", entry.tag, p.to_variant()),
            None => entry.tag.clone(),
        };
        let key = format!("{model}__{label}__{}__{}", self.max_items, fp);
        let cache = self.man.root.join("results").join(format!("{}.json", sanitize(&key)));
        if !self.fresh && cache.exists() {
            if let Ok(r) = read_result(&cache) {
                return Ok(r);
            }
        }
        let me = self.man.model(model)?.clone();
        let (dw, _) = self.weights.get(model).expect("weights ensured");
        let r = evaluate(
            &self.rt, &self.man, &me, entry, dw, &self.tok, &self.tasks, self.max_items, policy,
        )
        .with_context(|| format!("evaluating {model}/{label}"))?;
        write_result(&cache, &r).ok();
        eprintln!(
            "[eval] {model:<13} {:<42} avg_acc={:.3} ppl={:>10.2} ({:.1}s, {} seqs)",
            label,
            r.avg_acc(crate::eval::scoring::Scheme::Truncated),
            r.lambada_ppl(crate::eval::scoring::Scheme::Truncated),
            r.wall_s,
            r.sequences
        );
        Ok(r)
    }

    pub fn find_eval_entry(
        &self,
        model: &str,
        method: &str,
        ratio: f64,
        metric: Option<&str>,
        qh: Option<f64>,
        qr: Option<f64>,
        locations: Option<&[usize]>,
    ) -> Result<HloEntry> {
        Ok(self
            .man
            .model(model)?
            .find_eval(method, ratio, metric, qh, qr, locations)?
            .clone())
    }
}

fn sanitize(s: &str) -> String {
    s.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' || c == '.' { c } else { '_' })
        .collect()
}

fn result_to_json(r: &EvalResult) -> Json {
    obj(vec![
        ("model", s(&r.model)),
        ("variant", s(&r.variant)),
        ("wall_s", num(r.wall_s)),
        ("sequences", num(r.sequences as f64)),
        (
            "tasks",
            Json::Arr(
                r.tasks
                    .iter()
                    .map(|t| {
                        obj(vec![
                            ("name", s(&t.name)),
                            ("n_items", num(t.n_items as f64)),
                            ("acc_aligned", num(t.acc_aligned)),
                            ("acc_truncated", num(t.acc_truncated)),
                            ("ppl_aligned", num(t.ppl_aligned)),
                            ("ppl_truncated", num(t.ppl_truncated)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

fn write_result(path: &std::path::Path, r: &EvalResult) -> Result<()> {
    if let Some(d) = path.parent() {
        std::fs::create_dir_all(d)?;
    }
    std::fs::write(path, result_to_json(r).to_string())?;
    Ok(())
}

fn read_result(path: &std::path::Path) -> Result<EvalResult> {
    let j = Json::parse(&std::fs::read_to_string(path)?)?;
    Ok(EvalResult {
        model: j.str_of("model"),
        variant: j.str_of("variant"),
        wall_s: j.f64_of("wall_s"),
        sequences: j.usize_of("sequences"),
        tasks: j
            .expect("tasks")
            .as_arr()
            .context("tasks not array")?
            .iter()
            .map(|t| TaskResult {
                name: t.str_of("name"),
                n_items: t.usize_of("n_items"),
                acc_aligned: t.f64_of("acc_aligned"),
                acc_truncated: t.f64_of("acc_truncated"),
                ppl_aligned: t.f64_of("ppl_aligned"),
                ppl_truncated: t.f64_of("ppl_truncated"),
            })
            .collect(),
    })
}

/// Write a report file under artifacts/results and echo it to stdout.
pub fn emit_report(man: &Manifest, name: &str, body: &str) -> Result<()> {
    let dir = man.root.join("results");
    std::fs::create_dir_all(&dir)?;
    std::fs::write(dir.join(name), body)?;
    println!("{body}");
    Ok(())
}
