//! Regenerate the paper's Tables 1-6 (DESIGN.md §5).
//!
//! Rows mirror the paper's layout: LAMBADA-analogue PPL, per-task accuracy,
//! and the six-task average, under the paper's truncated-label scoring (the
//! aligned-scheme average is appended as an extra column for context).

use anyhow::Result;

use crate::data::TASK_ORDER;
use crate::eval::scoring::Scheme;
use crate::eval::EvalResult;

use super::{emit_report, Ctx};

const T: Scheme = Scheme::Truncated;
const A: Scheme = Scheme::Aligned;

fn task_acc(r: &EvalResult, name: &str, scheme: Scheme) -> f64 {
    r.tasks
        .iter()
        .find(|t| t.name == name)
        .map(|t| match scheme {
            Scheme::Aligned => t.acc_aligned,
            Scheme::Truncated => t.acc_truncated,
        })
        .unwrap_or(f64::NAN)
}

fn header() -> String {
    let mut h = format!("| {:<22} | {:>6} | {:>10} |", "Method", "FLOPS↓", "PPL↓");
    for t in TASK_ORDER {
        h += &format!(" {:>8} |", t.trim_start_matches("s_"));
    }
    h += &format!(" {:>6} | {:>8} |\n", "Avg↑", "Avg(al)↑");
    let cols = 3 + TASK_ORDER.len() + 2;
    h += &format!("|{}\n", "---|".repeat(cols));
    h
}

fn row(label: &str, ratio: f64, r: &EvalResult) -> String {
    let mut s = format!(
        "| {:<22} | {:>5.0}% | {:>10.2} |",
        label,
        ratio * 100.0,
        r.lambada_ppl(T)
    );
    for t in TASK_ORDER {
        s += &format!(" {:>8.1} |", task_acc(r, t, T) * 100.0);
    }
    s += &format!(" {:>6.1} | {:>8.1} |\n", r.avg_acc(T) * 100.0, r.avg_acc(A) * 100.0);
    s
}

fn main_table(ctx: &mut Ctx, models: &[&str], title: &str, file: &str) -> Result<()> {
    let mut body = format!("# {title}\n\n");
    for model in models {
        let ratios: &[f64] = if model.ends_with("base") { &[0.10, 0.20, 0.30] } else { &[0.10, 0.20] };
        body += &format!("## {model}\n\n{}", header());
        let dense = ctx.find_eval_entry(model, "dense", 0.0, None, None, None, None)?;
        let r = ctx.eval_variant(model, &dense)?;
        body += &row(&format!("{model} (dense)"), 0.0, &r);
        for &ratio in ratios {
            for method in ["pumer", "evit", "utrc"] {
                let e = ctx.find_eval_entry(model, method, ratio, None, None, None, None)?;
                let r = ctx.eval_variant(model, &e)?;
                let label = if method == "utrc" { "+ Ours (UTRC)" } else if method == "evit" { "+ EViT" } else { "+ PuMer" };
                body += &row(label, ratio, &r);
            }
        }
        body += "\n";
    }
    emit_report(&ctx.man, file, &body)
}

/// Table 1: Mamba-2 family (substrates for Mamba-2-1.3B / Mamba-2-2.7B).
pub fn table1(ctx: &mut Ctx) -> Result<()> {
    main_table(
        ctx,
        &["mamba2-small", "mamba2-base"],
        "Table 1 — post-training token reduction on Mamba-2 (paper: Mamba-2-1.3B/2.7B)",
        "table1.md",
    )
}

/// Table 2: Mamba family (substrates for Mamba-1.4B / Mamba-2.8B).
pub fn table2(ctx: &mut Ctx) -> Result<()> {
    main_table(
        ctx,
        &["mamba-small", "mamba-base"],
        "Table 2 — post-training token reduction on Mamba (paper: Mamba-1.4B/2.8B)",
        "table2.md",
    )
}

/// Table 3: importance-metric ablation @20%.
pub fn table3(ctx: &mut Ctx) -> Result<()> {
    let mut body = String::from(
        "# Table 3 — token-importance metric ablation (UTRC @20% FLOPs)\n\n\
         | Model | Metric | PPL↓ | Avg Acc↑ | Avg Acc (aligned)↑ |\n|---|---|---|---|---|\n",
    );
    for model in ["mamba2-base", "mamba-base"] {
        for metric in ["l1", "l2", "noclip", "clip"] {
            let e = ctx.find_eval_entry(model, "utrc", 0.20, Some(metric), None, None, None)?;
            let r = ctx.eval_variant(model, &e)?;
            body += &format!(
                "| {model} | {metric}{} | {:.2} | {:.1} | {:.1} |\n",
                if metric == "clip" { " (ours)" } else { "" },
                r.lambada_ppl(T),
                r.avg_acc(T) * 100.0,
                r.avg_acc(A) * 100.0
            );
        }
    }
    emit_report(&ctx.man, "table3.md", &body)
}

/// Table 4: reduction-location ablation on mamba2-base @20%.
pub fn table4(ctx: &mut Ctx) -> Result<()> {
    let model = "mamba2-base";
    let mut body = String::from(
        "# Table 4 — reduction-location ablation (mamba2-base, UTRC @20%)\n\n\
         | Locations | PPL↓ | Avg Acc↑ | Avg Acc (aligned)↑ |\n|---|---|---|---|\n",
    );
    // Every exported UTRC@20%/clip/default-q variant differing only in schedule.
    let me = ctx.man.model(model)?.clone();
    let mut schedules: Vec<Vec<usize>> = me
        .hlo
        .values()
        .filter(|e| e.kind == "eval")
        .filter_map(|e| e.reduction.as_ref())
        .filter(|r| {
            r.method == "utrc"
                && (r.flops_reduction - 0.20).abs() < 1e-6
                && r.metric == "clip"
                && (r.q_hidden - 0.5).abs() < 1e-6
                && r.q_residual.abs() < 1e-6
        })
        .map(|r| r.locations.clone())
        .collect();
    schedules.sort();
    schedules.dedup();
    for loc in schedules {
        let e = ctx.find_eval_entry(model, "utrc", 0.20, None, None, None, Some(&loc))?;
        let r = ctx.eval_variant(model, &e)?;
        body += &format!(
            "| {loc:?} | {:.2} | {:.1} | {:.1} |\n",
            r.lambada_ppl(T),
            r.avg_acc(T) * 100.0,
            r.avg_acc(A) * 100.0
        );
    }
    emit_report(&ctx.man, "table4.md", &body)
}

/// Table 5: hidden/residual design choices on mamba2-base @30%.
pub fn table5(ctx: &mut Ctx) -> Result<()> {
    let model = "mamba2-base";
    let mut body = String::from(
        "# Table 5 — UTR design choices (mamba2-base, @30% FLOPs)\n\n\
         | Hidden states | Residual | PPL↓ | Avg Acc↑ | Avg Acc (aligned)↑ |\n|---|---|---|---|---|\n",
    );
    let combos: &[(f64, f64, &str, &str)] = &[
        (0.0, 0.0, "M-only", "M-only"),
        (1.0, 1.0, "P-only", "P-only"),
        (0.8, 0.2, "q = 0.8", "q = 0.2"),
        (0.2, 0.8, "q = 0.2", "q = 0.8"),
        (0.5, 0.5, "q = 0.5", "q = 0.5"),
        (0.5, 1.0, "q = 0.5", "P-only"),
        (0.5, 0.0, "q = 0.5", "M-only (ours)"),
    ];
    for &(qh, qr, lh, lr) in combos {
        let e = ctx.find_eval_entry(model, "utrc", 0.30, None, Some(qh), Some(qr), None)?;
        let r = ctx.eval_variant(model, &e)?;
        body += &format!(
            "| {lh} | {lr} | {:.2} | {:.1} | {:.1} |\n",
            r.lambada_ppl(T),
            r.avg_acc(T) * 100.0,
            r.avg_acc(A) * 100.0
        );
    }
    emit_report(&ctx.man, "table5.md", &body)
}

/// Table 6: LTMP baseline comparison on mamba2-base.
pub fn table6(ctx: &mut Ctx) -> Result<()> {
    let model = "mamba2-base";
    let mut body = format!(
        "# Table 6 — LTMP vs UTRC (mamba2-base)\n\n{}",
        header()
    );
    let dense = ctx.find_eval_entry(model, "dense", 0.0, None, None, None, None)?;
    let r = ctx.eval_variant(model, &dense)?;
    body += &row("mamba2-base (dense)", 0.0, &r);
    for &ratio in &[0.10, 0.20, 0.30] {
        for method in ["ltmp", "utrc"] {
            let e = ctx.find_eval_entry(model, method, ratio, None, None, None, None)?;
            let r = ctx.eval_variant(model, &e)?;
            let label = if method == "utrc" { "+ Ours (UTRC)" } else { "+ LTMP" };
            body += &row(label, ratio, &r);
        }
    }
    emit_report(&ctx.man, "table6.md", &body)
}
