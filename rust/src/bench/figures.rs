//! Regenerate the paper's Figures 1, 3, 4, 5, 6.
//!
//! Figure 1  — accuracy collapse of EViT/PuMer vs ours across FLOPs ratios.
//! Figure 3/5 — GPU peak-memory reduction (analytic activation-memory model
//!              at the paper's geometry: generate 2048 tokens, batch 96).
//! Figure 4/6 — generation throughput, MEASURED end-to-end on the serving
//!              engine (prompt 512 = paper's 2048 scaled by the same 1/4 as
//!              the models; batch = prefill batch; greedy decode).

use anyhow::Result;

use crate::coordinator::engine::Engine;
use crate::coordinator::Request;
use crate::eval::scoring::Scheme;
use crate::reduction::{peak_memory_bytes, solve_schedule, Arch, ModelDims, SchedulePlan};
use crate::train::load_best_weights;

use super::{emit_report, Ctx};

/// Figure 1: EViT / PuMer / UTRC average accuracy vs FLOPs reduction on the
/// Mamba-2.8B substrate (mamba-base).
pub fn figure1(ctx: &mut Ctx) -> Result<()> {
    let model = "mamba-base";
    let mut body = String::from(
        "# Figure 1 — direct application of Transformer token reduction fails on SSMs\n\n\
         Average accuracy (%) on mamba-base (paper: Mamba-2.8B), truncated-label scoring.\n\n\
         | FLOPs reduction | EViT (prune) | PuMer (merge) | Ours (UTRC) | dense |\n|---|---|---|---|---|\n",
    );
    let dense_e = ctx.find_eval_entry(model, "dense", 0.0, None, None, None, None)?;
    let dense = ctx.eval_variant(model, &dense_e)?.avg_acc(Scheme::Truncated) * 100.0;
    for &ratio in &[0.10, 0.20, 0.30] {
        let mut cells = Vec::new();
        for method in ["evit", "pumer", "utrc"] {
            let e = ctx.find_eval_entry(model, method, ratio, None, None, None, None)?;
            let r = ctx.eval_variant(model, &e)?;
            cells.push(format!("{:.1}", r.avg_acc(Scheme::Truncated) * 100.0));
        }
        body += &format!(
            "| {:.0}% | {} | {} | {} | {dense:.1} |\n",
            ratio * 100.0,
            cells[0],
            cells[1],
            cells[2]
        );
    }
    emit_report(&ctx.man, "figure1.md", &body)
}

/// The paper's actual checkpoints, for evaluating the analytic memory model
/// at the scale where its logits/late-layer dominance appears (our tiny
/// substrates have V < d+3di, so layer-0 activations dominate instead —
/// both scales are reported; see DESIGN.md §5).
fn paper_dims(name: &str) -> (ModelDims, Vec<usize>) {
    let (arch, d, nl, locs): (Arch, usize, usize, Vec<usize>) = match name {
        "Mamba-1.4B" => (Arch::Mamba, 2048, 48, vec![10, 15, 20, 25, 30, 35]),
        "Mamba-2.8B" => (Arch::Mamba, 2560, 64, vec![12, 17, 22, 27, 32, 37, 42]),
        "Mamba-2-1.3B" => (Arch::Mamba2, 2048, 48, vec![10, 15, 20, 25, 30, 35]),
        _ => (Arch::Mamba2, 2560, 64, vec![12, 17, 22, 27, 32, 37, 42]),
    };
    (
        ModelDims {
            name: name.to_string(),
            arch,
            vocab_size: 50280,
            d_model: d,
            n_layer: nl,
            d_state: if arch == Arch::Mamba2 { 128 } else { 16 },
            expand: 2,
            d_conv: 4,
            headdim: 64,
            chunk: 256,
        },
        locs,
    )
}

/// Figures 3 (base models) and 5 (small models): peak-memory reduction.
pub fn figure_memory(ctx: &mut Ctx, small: bool) -> Result<()> {
    // Paper geometry: generating 2048 tokens with batch 96 — peak memory is
    // dominated by the full-position logits buffer + late-layer activations,
    // both of which shrink with the surviving token count. The analytic
    // model is evaluated (a) at the PAPER's model dims — the headline, the
    // regime the figure describes — and (b) at our substrate dims.
    let (models, paper_models, fig) = if small {
        (["mamba-small", "mamba2-small"], ["Mamba-1.4B", "Mamba-2-1.3B"], "figure5")
    } else {
        (["mamba-base", "mamba2-base"], ["Mamba-2.8B", "Mamba-2-2.7B"], "figure3")
    };
    let batch = 96;
    let seq = 2048;
    let mut body = format!(
        "# {} — GPU peak-memory reduction vs FLOPs reduction\n\n\
         Analytic live-set+logits peak at generation geometry (batch {batch}, {seq} tokens).\n\n\
         ## At the paper's model dims (headline)\n\n\
         | Model | FLOPs reduction | peak GB | reduction vs dense |\n|---|---|---|---|\n",
        if small { "Figure 5" } else { "Figure 3" },
    );
    for name in paper_models {
        let (dims, locations) = paper_dims(name);
        let dense: SchedulePlan = solve_schedule(&dims, seq, &[], 0.0)?;
        let dense_bytes = peak_memory_bytes(&dims, &dense, batch);
        body += &format!("| {name} | 0% | {:.1} | 0.0% |\n", dense_bytes as f64 / 1e9);
        for &ratio in &[0.10, 0.20, 0.30] {
            let plan = solve_schedule(&dims, seq, &locations, ratio)?;
            let bytes = peak_memory_bytes(&dims, &plan, batch);
            body += &format!(
                "| {name} | {:.0}% | {:.1} | {:.1}% |\n",
                ratio * 100.0,
                bytes as f64 / 1e9,
                (1.0 - bytes as f64 / dense_bytes as f64) * 100.0
            );
        }
    }
    body += "\n## At our substrate dims (V≈d+3·d_inner: layer-0 activations co-dominate)\n\n\
             | Model | FLOPs reduction | peak MB | reduction vs dense |\n|---|---|---|---|\n";
    for model in models {
        let me = ctx.man.model(model)?.clone();
        let dims = ModelDims::from_manifest(&me);
        let locations = me.default_locations().unwrap_or_default();
        let dense: SchedulePlan = solve_schedule(&dims, seq, &[], 0.0)?;
        let dense_bytes = peak_memory_bytes(&dims, &dense, batch);
        body += &format!("| {model} | 0% | {:.1} | 0.0% |\n", dense_bytes as f64 / 1e6);
        for &ratio in &[0.10, 0.20, 0.30] {
            let plan = solve_schedule(&dims, seq, &locations, ratio)?;
            let bytes = peak_memory_bytes(&dims, &plan, batch);
            body += &format!(
                "| {model} | {:.0}% | {:.1} | {:.1}% |\n",
                ratio * 100.0,
                bytes as f64 / 1e6,
                (1.0 - bytes as f64 / dense_bytes as f64) * 100.0
            );
        }
    }
    emit_report(&ctx.man, &format!("{fig}.md"), &body)
}

/// Figures 4 (base) and 6 (small): measured generation throughput.
pub fn figure_throughput(ctx: &mut Ctx, small: bool, gen_tokens: usize) -> Result<()> {
    let (models, fig, paper_models) = if small {
        (["mamba-small", "mamba2-small"], "figure6", "Mamba-1.4B / Mamba-2-1.3B")
    } else {
        (["mamba-base", "mamba2-base"], "figure4", "Mamba-2.8B / Mamba-2-2.7B")
    };
    let mut body = format!(
        "# {} — generation throughput vs FLOPs reduction (paper: {paper_models})\n\n\
         Measured on the rust serving engine (prompt {}, {gen_tokens} generated, batch {}).\n\n\
         | Model | Variant | prefill ms | decode ms | tok/s (gen) | speedup |\n|---|---|---|---|---|---|\n",
        if small { "Figure 6" } else { "Figure 4" },
        ctx.man.prefill_seq_len,
        ctx.man.prefill_batch,
    );
    for model in models {
        let me = ctx.man.model(model)?.clone();
        let (w, _) = load_best_weights(&ctx.man, &me)?;
        let mut baseline_tps = 0.0f64;
        for variant in ["dense", "utrc@0.1", "utrc@0.2", "utrc@0.3"] {
            let engine = Engine::new(&ctx.rt, &ctx.man, &me, &w, variant)?;
            let reqs: Vec<Request> = (0..engine.batch)
                .map(|i| Request {
                    id: i as u64,
                    prompt: synth_prompt(ctx, engine.prefill_len),
                    gen_tokens,
                    variant: variant.to_string(),
                    arrived_us: 0,
                    priority: Default::default(),
                })
                .collect();
            // Warmup (compile+cache), then measure.
            engine.serve_batch(&reqs)?;
            let t0 = std::time::Instant::now();
            let resp = engine.serve_batch(&reqs)?;
            let wall = t0.elapsed().as_secs_f64();
            let gen_total: usize = resp.iter().map(|r| r.generated.len()).sum();
            let tps = gen_total as f64 / wall;
            if variant == "dense" {
                baseline_tps = tps;
            }
            body += &format!(
                "| {model} | {variant} | {:.0} | {:.0} | {tps:.2} | {:.2}x |\n",
                resp[0].prefill_us as f64 / 1000.0,
                resp[0].decode_us as f64 / 1000.0,
                tps / baseline_tps.max(1e-9)
            );
        }
    }
    emit_report(&ctx.man, &format!("{fig}.md"), &body)
}

fn synth_prompt(ctx: &Ctx, len: usize) -> Vec<i32> {
    // A real task context repeated to fill the prompt frame.
    let text = &ctx.tasks[0].items[0].context;
    let ids: Vec<i32> = ctx.tok.encode(text).iter().map(|&x| x as i32).collect();
    let mut out = Vec::with_capacity(len);
    while out.len() < len {
        out.extend_from_slice(&ids);
    }
    out.truncate(len);
    out
}
