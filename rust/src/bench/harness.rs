//! Micro-benchmark harness (criterion substitute for `cargo bench`).
//!
//! Usage in a `[[bench]] harness = false` target:
//!
//! ```ignore
//! let mut b = Bench::new("coordinator");
//! b.bench("batcher_push_poll", 1000, || { ... });
//! b.finish();
//! ```

use crate::util::stats::{bench as run_bench, human, Summary};

pub struct Bench {
    pub group: String,
    pub results: Vec<(String, Summary)>,
    warmup: usize,
    iters: usize,
}

impl Bench {
    pub fn new(group: &str) -> Bench {
        // Allow quick runs: REPRO_BENCH_ITERS=10 cargo bench
        let iters = std::env::var("REPRO_BENCH_ITERS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(50);
        let warmup = (iters / 5).max(2);
        println!("== bench group: {group} (warmup {warmup}, iters {iters}) ==");
        Bench { group: group.to_string(), results: Vec::new(), warmup, iters }
    }

    pub fn with_iters(group: &str, warmup: usize, iters: usize) -> Bench {
        println!("== bench group: {group} (warmup {warmup}, iters {iters}) ==");
        Bench { group: group.to_string(), results: Vec::new(), warmup, iters }
    }

    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) {
        let s = run_bench(self.warmup, self.iters, f);
        println!(
            "{:<40} mean {:>12}  p50 {:>12}  p99 {:>12}  (n={})",
            format!("{}/{}", self.group, name),
            human(s.mean_ns),
            human(s.p50_ns),
            human(s.p99_ns),
            s.n
        );
        self.results.push((name.to_string(), s));
    }

    /// Report throughput given items processed per iteration.
    pub fn bench_throughput<F: FnMut()>(&mut self, name: &str, items_per_iter: usize, f: F) {
        let s = run_bench(self.warmup, self.iters, f);
        let per_s = items_per_iter as f64 / (s.mean_ns / 1e9);
        println!(
            "{:<40} mean {:>12}  {:>14.1} items/s  (n={})",
            format!("{}/{}", self.group, name),
            human(s.mean_ns),
            per_s,
            s.n
        );
        self.results.push((name.to_string(), s));
    }

    pub fn finish(self) {
        println!("== {} done: {} benches ==", self.group, self.results.len());
    }
}

// ---------------------------------------------------------------------------
// Golden numerics check: rust runtime vs python-side logits fixture.
// ---------------------------------------------------------------------------

use anyhow::{ensure, Context, Result};

use crate::manifest::Manifest;
use crate::runtime::{HostTensor, Runtime, Weights};

/// Execute the dense eval module with init weights on the deterministic
/// token pattern from `aot.export_golden` and compare the strided logits
/// slice bit-tolerantly. This pins the whole AOT bridge: HLO text parse,
/// compile, param upload order, and numerics — so it is meaningful on the
/// `pjrt` backend (`repro golden --backend pjrt`); the reference backend
/// computes a different (interpreted) model and will not match a
/// python-lowered fixture.
pub fn golden_check(rt: &Runtime, man: &Manifest) -> Result<String> {
    let text = std::fs::read_to_string(man.path("golden.json")).context("golden.json")?;
    let g = crate::util::json::Json::parse(&text)?;
    let model = g.str_of("model");
    let batch = g.usize_of("batch");
    let seq_len = g.usize_of("seq_len");
    let want: Vec<f64> = g
        .expect("values")
        .as_arr()
        .context("values")?
        .iter()
        .map(|v| v.as_f64().unwrap())
        .collect();
    let shape = g.usize_arr_of("shape");

    let me = man.model(&model)?.clone();
    let entry = me.find_eval("dense", 0.0, None, None, None, None)?;
    let exe = rt.load_entry(man, &me, entry)?;
    let w = Weights::load_init(man, &me)?;
    let dw = rt.upload_weights(&me, &w)?;

    let tokens: Vec<i32> = (0..batch * seq_len)
        .map(|i| ((i as i64 * 7) % me.vocab_size as i64) as i32)
        .collect();
    let tok = HostTensor::i32(vec![batch, seq_len], tokens);
    let outs = exe.execute(&dw, &[tok])?;
    let logits = outs[0].as_f32()?;
    let v = me.vocab_size;

    // Slice logits[:, ::16, ::64] in row-major order.
    let mut got = Vec::with_capacity(want.len());
    for b in 0..shape[0] {
        for li in 0..shape[1] {
            for vi in 0..shape[2] {
                got.push(logits[(b * seq_len + li * 16) * v + vi * 64] as f64);
            }
        }
    }
    ensure!(got.len() == want.len(), "slice size mismatch {} vs {}", got.len(), want.len());
    let mut max_err = 0.0f64;
    for (a, b) in got.iter().zip(&want) {
        max_err = max_err.max((a - b).abs() / (1.0 + b.abs()));
    }
    ensure!(
        max_err < 2e-4,
        "golden mismatch: max relative error {max_err:.2e} (rust runtime vs python lowering)"
    );
    Ok(format!(
        "golden OK: {} values, max rel err {max_err:.2e} (model {model}, platform {})",
        want.len(),
        rt.platform()
    ))
}
