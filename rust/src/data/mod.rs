//! Data loaders: the token corpus (train.bin/val.bin), the six benchmark
//! task sets (tasks.json), and a batch sampler for the trainer.

use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::tokenizer::Tokenizer;
use crate::util::json::Json;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct TaskItem {
    pub context: String,
    pub choices: Vec<String>,
    pub answer: usize,
    pub target: String,
}

#[derive(Debug, Clone)]
pub struct Task {
    pub name: String,
    pub items: Vec<TaskItem>,
}

pub const TASK_ORDER: [&str; 6] = [
    "s_lambada", "s_hellaswag", "s_piqa", "s_arc_easy", "s_arc_challenge", "s_wino",
];

pub fn load_tasks(path: impl AsRef<Path>) -> Result<Vec<Task>> {
    let text = std::fs::read_to_string(path.as_ref())
        .with_context(|| format!("reading tasks {:?}", path.as_ref()))?;
    let j = Json::parse(&text).context("parsing tasks.json")?;
    let obj = j.as_obj().context("tasks.json not an object")?;
    let mut out = Vec::new();
    for name in TASK_ORDER {
        let items = obj
            .get(name)
            .with_context(|| format!("missing task {name}"))?
            .as_arr()
            .context("task not an array")?
            .iter()
            .map(|it| TaskItem {
                context: it.str_of("context"),
                choices: it
                    .expect("choices")
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|c| c.as_str().unwrap().to_string())
                    .collect(),
                answer: it.usize_of("answer"),
                target: it.str_or("target", ""),
            })
            .collect();
        out.push(Task { name: name.to_string(), items });
    }
    Ok(out)
}

/// Memory-mapped-style token stream (we just read it; ~2MB).
pub struct Corpus {
    pub tokens: Vec<i32>,
}

impl Corpus {
    pub fn load(path: impl AsRef<Path>) -> Result<Corpus> {
        let bytes = std::fs::read(path.as_ref())
            .with_context(|| format!("reading corpus {:?}", path.as_ref()))?;
        ensure!(bytes.len() % 4 == 0, "corpus not a multiple of 4 bytes");
        let tokens = bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        Ok(Corpus { tokens })
    }

    /// Sample a (batch, seq_len) window batch as a flat row-major buffer.
    pub fn sample_batch(&self, rng: &mut Rng, batch: usize, seq_len: usize) -> Vec<i32> {
        assert!(self.tokens.len() > seq_len + 1, "corpus shorter than a window");
        let mut out = Vec::with_capacity(batch * seq_len);
        for _ in 0..batch {
            let start = rng.below(self.tokens.len() - seq_len - 1);
            out.extend_from_slice(&self.tokens[start..start + seq_len]);
        }
        out
    }

    pub fn validate(&self, vocab_size: usize) -> Result<()> {
        for (i, &t) in self.tokens.iter().enumerate() {
            ensure!(
                (0..vocab_size as i32).contains(&t),
                "token {t} at {i} outside vocab {vocab_size}"
            );
        }
        Ok(())
    }
}

/// Sanity-check that task texts tokenize without <unk> (vocab closure —
/// mirrors the python-side assertion).
pub fn check_tasks_closed(tasks: &[Task], tok: &Tokenizer) -> Result<()> {
    for task in tasks {
        for it in &task.items {
            for text in std::iter::once(&it.context).chain(it.choices.iter()) {
                ensure!(
                    !tok.encode(text).contains(&crate::tokenizer::UNK),
                    "OOV in task {} text {:?}",
                    task.name,
                    text
                );
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_batch_shapes() {
        let c = Corpus { tokens: (0..1000).collect() };
        let mut rng = Rng::new(1);
        let b = c.sample_batch(&mut rng, 4, 64);
        assert_eq!(b.len(), 4 * 64);
        // windows are contiguous slices
        for row in b.chunks(64) {
            for w in row.windows(2) {
                assert_eq!(w[1], w[0] + 1);
            }
        }
    }

    #[test]
    fn corpus_validate_bounds() {
        let c = Corpus { tokens: vec![0, 5, 10] };
        assert!(c.validate(11).is_ok());
        assert!(c.validate(10).is_err());
    }
}
