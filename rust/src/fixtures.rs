//! Deterministic synthetic fixtures: a complete, self-contained artifact
//! directory (manifest, vocab, task sets, corpus, reference-layout weights)
//! generated from a seed via [`crate::util::rng::Rng`] — no Python, no
//! `make artifacts`, no network.
//!
//! The generated manifest speaks the exact same contract as
//! `python/compile/aot.py`'s, but its weight blobs follow the **reference
//! param layout** (`embedding`, `layers.{l}.*`, `norm_f`) interpreted by
//! [`crate::runtime::reference`]. That makes the coordinator's
//! prefill→decode loop, the eval harness, and the bench harness runnable
//! hermetically; it does NOT make fixtures drop-in artifacts for the pjrt
//! backend (those need real AOT exports).
//!
//! Two substrate models are emitted: `ref-mamba` (arch `mamba`) and
//! `ref-mamba2` (arch `mamba2`), each with dense + UTRC eval variants,
//! dense + UTRC prefill variants, a decode step, and a train-step entry
//! (the latter compiles but only executes on the pjrt backend).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::manifest::Manifest;
use crate::reduction::{solve_schedule, Arch, ModelDims, SchedulePlan};
use crate::runtime::reference::D_CONV;
use crate::util::json::{num, obj, s, Json};
use crate::util::rng::Rng;

/// Geometry of one synthetic fixture set. The defaults are sized so the
/// whole hermetic test suite stays fast in debug builds.
#[derive(Debug, Clone)]
pub struct FixtureSpec {
    pub seed: u64,
    /// Non-special vocabulary words (total vocab = this + 4 specials).
    pub vocab_words: usize,
    pub items_per_task: usize,
    pub corpus_tokens: usize,
    pub eval_batch: usize,
    pub eval_seq_len: usize,
    pub prefill_batch: usize,
    pub prefill_seq_len: usize,
    pub train_batch: usize,
    pub train_seq_len: usize,
}

impl Default for FixtureSpec {
    fn default() -> FixtureSpec {
        FixtureSpec {
            seed: 42,
            vocab_words: 120,
            items_per_task: 3,
            corpus_tokens: 8192,
            eval_batch: 4,
            eval_seq_len: 48,
            prefill_batch: 2,
            prefill_seq_len: 32,
            train_batch: 2,
            train_seq_len: 32,
        }
    }
}

/// The two fixture substrates: (name, arch). Dims are shared: d_model 32,
/// 4 layers, d_state 8, expand 2 (d_inner 64 — one mamba2 head).
const MODELS: [(&str, &str); 2] = [("ref-mamba", "mamba"), ("ref-mamba2", "mamba2")];
const D_MODEL: usize = 32;
const N_LAYER: usize = 4;
const D_STATE: usize = 8;
const LOCATIONS: [usize; 2] = [1, 2];
const EVAL_RATIOS: [f64; 2] = [0.10, 0.20];
const PREFILL_RATIOS: [f64; 3] = [0.10, 0.20, 0.30];

/// Generate a fixture set under `dir` (created if needed) and load it back
/// through the ordinary [`Manifest`] path.
pub fn generate(dir: &Path, spec: &FixtureSpec) -> Result<Manifest> {
    std::fs::create_dir_all(dir).with_context(|| format!("creating fixture dir {dir:?}"))?;
    let vocab_size = 4 + spec.vocab_words;
    let mut rng = Rng::new(spec.seed);

    // -- vocab ---------------------------------------------------------------
    let words: Vec<String> = (0..spec.vocab_words).map(|i| format!("w{i:03}")).collect();
    let mut vocab: Vec<Json> = ["<pad>", "<unk>", "<bos>", "<eos>"]
        .iter()
        .map(|w| s(w))
        .collect();
    vocab.extend(words.iter().map(|w| s(w)));
    let vocab_json = obj(vec![("vocab", Json::Arr(vocab))]);
    std::fs::write(dir.join("vocab.json"), vocab_json.to_string())?;

    // -- task sets -----------------------------------------------------------
    let tasks_json = gen_tasks(&mut rng, &words, spec.items_per_task);
    std::fs::write(dir.join("tasks.json"), tasks_json.to_string())?;

    // -- corpus --------------------------------------------------------------
    for file in ["train.bin", "val.bin"] {
        let mut bytes = Vec::with_capacity(spec.corpus_tokens * 4);
        for _ in 0..spec.corpus_tokens {
            let t = 4 + rng.below(vocab_size - 4) as i32;
            bytes.extend_from_slice(&t.to_le_bytes());
        }
        std::fs::write(dir.join(file), bytes)?;
    }

    // -- models: weights + manifest entries ---------------------------------
    let mut models = BTreeMap::new();
    for (name, arch) in MODELS {
        let (params_json, param_count) = write_weights(dir, &mut rng, name, arch, vocab_size)?;
        let hlo = gen_hlo_entries(name, arch, vocab_size, spec)?;
        let config = obj(vec![
            ("d_model", num(D_MODEL as f64)),
            ("n_layer", num(N_LAYER as f64)),
            ("d_state", num(D_STATE as f64)),
            ("expand", num(2.0)),
            ("vocab_size", num(vocab_size as f64)),
        ]);
        let model = obj(vec![
            ("arch", s(arch)),
            ("config", config),
            ("param_count", num(param_count as f64)),
            ("init_weights", s(&format!("init_{name}.bin"))),
            ("params", params_json),
            ("hlo", hlo),
        ]);
        models.insert(name.to_string(), model);
    }

    let manifest = obj(vec![
        (
            "data",
            obj(vec![
                ("vocab", s("vocab.json")),
                ("tasks", s("tasks.json")),
                ("train", s("train.bin")),
                ("val", s("val.bin")),
            ]),
        ),
        (
            "eval",
            obj(vec![
                ("batch", num(spec.eval_batch as f64)),
                ("seq_len", num(spec.eval_seq_len as f64)),
            ]),
        ),
        (
            "prefill",
            obj(vec![
                ("batch", num(spec.prefill_batch as f64)),
                ("seq_len", num(spec.prefill_seq_len as f64)),
            ]),
        ),
        ("decode", obj(vec![("batch", num(spec.prefill_batch as f64))])),
        (
            "train",
            obj(vec![
                ("batch", num(spec.train_batch as f64)),
                ("seq_len", num(spec.train_seq_len as f64)),
                ("total_steps", num(100.0)),
            ]),
        ),
        ("models", Json::Obj(models)),
    ]);
    std::fs::write(dir.join("manifest.json"), manifest.to_string())?;

    Manifest::load(dir).context("reloading generated fixture manifest")
}

/// [`generate`] with the default [`FixtureSpec`].
pub fn generate_default(dir: &Path) -> Result<Manifest> {
    generate(dir, &FixtureSpec::default())
}

/// Load the artifacts manifest if present; otherwise generate (once) and use
/// a synthetic fixture under the system temp dir. Returns `(manifest, true)`
/// when running on the synthetic fixture.
pub fn manifest_or_fixture(artifacts: &str) -> Result<(Manifest, bool)> {
    if let Ok(man) = Manifest::load(artifacts) {
        return Ok((man, false));
    }
    let dir = default_fixture_dir();
    let man = if dir.join("manifest.json").exists() {
        Manifest::load(&dir).or_else(|_| generate_default(&dir))?
    } else {
        generate_default(&dir)?
    };
    Ok((man, true))
}

/// Synthetic serving workload shared by `repro serve`/`repro demo`, the
/// serve example, and the coordinator/reduction/runtime benches (keeps
/// every surface measuring the same trace shape): **length-diverse**
/// prompts — 30% exactly one prefill frame, 20% a quarter-frame (short
/// chat-like), 35% uniform in `1..=frame`, and (when `max_prompt_len >
/// prefill_seq_len`) 15% *longer than the frame*, uniform in
/// `frame+1..=max_prompt_len`, exercising chunked prefill — with uniform
/// 1..=max_gen generation lengths.
///
/// `max_prompt_len` is a hard ceiling on every bucket. Pass
/// `max_prompt_len == prefill_seq_len` to suppress the longer-than-frame
/// bucket (its probability mass folds into the uniform bucket) for engines
/// that cannot chunk — a cap *below* the frame additionally clamps the
/// full-frame/uniform buckets to it. Serving paths derive the cap from
/// their lane set via [`trace_max_prompt`].
///
/// `explicit_variants` mixes policy-variant pinning into the trace: every
/// third request names one of the given lane variants explicitly
/// (round-robin; the variant grammar of DESIGN.md §10), the rest leave the
/// choice to the router. Pass `&[]` for a fully router-driven trace. The
/// RNG stream is identical either way, so traces stay comparable across
/// benches that differ only in pinning.
pub fn synth_requests(
    rng: &mut Rng,
    n_requests: usize,
    max_gen: usize,
    prefill_seq_len: usize,
    max_prompt_len: usize,
    vocab_size: usize,
    explicit_variants: &[&str],
) -> Vec<crate::coordinator::Request> {
    let frame = prefill_seq_len.max(1);
    let cap = max_prompt_len.max(1);
    (0..n_requests)
        .map(|i| {
            let r = rng.f64();
            let plen = if r < 0.30 {
                frame
            } else if r < 0.50 {
                (frame / 4).max(1)
            } else if r < 0.85 || cap <= frame {
                1 + rng.below(frame)
            } else {
                frame + 1 + rng.below(cap - frame)
            };
            // `max_prompt_len` is a HARD ceiling: a lane set capped below
            // the frame (a non-chunkable lane with a smaller per-entry
            // frame — see `trace_max_prompt`) must never be offered a
            // prompt it would refuse. A no-op for the usual cap >= frame,
            // so the RNG stream and distribution are unchanged there.
            let plen = plen.min(cap);
            let variant = if !explicit_variants.is_empty() && i % 3 == 2 {
                explicit_variants[(i / 3) % explicit_variants.len()].to_string()
            } else {
                String::new()
            };
            crate::coordinator::Request {
                id: i as u64,
                prompt: (0..plen).map(|_| rng.below(vocab_size) as i32).collect(),
                gen_tokens: 1 + rng.below(max_gen.max(1)),
                variant,
                arrived_us: 0,
                priority: crate::coordinator::Priority::Normal,
            }
        })
        .collect()
}

/// Shared-system-prompt trace profile (DESIGN.md §12): every request's
/// prompt starts with the **same** `prefix_frames × prefill_seq_len` system
/// prefix (chunk-aligned by construction) followed by a unique tail of
/// `1..=prefill_seq_len` tokens — so with a prefix-state cache attached the
/// first request prefills the shared prefix once and every later request
/// resumes from the cached boundary snapshot and prefills only its tail.
/// The tail is at least 1 token, so a chunk-aligned **proper** cached
/// prefix always exists for every request. All requests are
/// [`Priority::Normal`](crate::coordinator::Priority) with uniform
/// `1..=max_gen` generation lengths.
pub fn synth_shared_prefix_requests(
    rng: &mut Rng,
    n_requests: usize,
    max_gen: usize,
    prefill_seq_len: usize,
    prefix_frames: usize,
    vocab_size: usize,
) -> Vec<crate::coordinator::Request> {
    let frame = prefill_seq_len.max(1);
    let prefix: Vec<i32> = (0..prefix_frames.max(1) * frame)
        .map(|_| rng.below(vocab_size) as i32)
        .collect();
    (0..n_requests)
        .map(|i| {
            let tail = 1 + rng.below(frame);
            let mut prompt = prefix.clone();
            prompt.extend((0..tail).map(|_| rng.below(vocab_size) as i32));
            crate::coordinator::Request {
                id: i as u64,
                prompt,
                gen_tokens: 1 + rng.below(max_gen.max(1)),
                variant: String::new(),
                arrived_us: 0,
                priority: crate::coordinator::Priority::Normal,
            }
        })
        .collect()
}

/// How many prefill frames the longest [`synth_requests`] prompt spans on a
/// fully length-aware lane set — the single knob behind every serving
/// surface's chunked-prefill traffic (serve/demo, the serve example, and
/// the coordinator/reduction/runtime benches).
pub const LONG_PROMPT_FRAMES: usize = 3;

/// The `max_prompt_len` a serving surface should pass to
/// [`synth_requests`] for `engines`: [`LONG_PROMPT_FRAMES`] prefill frames
/// when **every** lane can chunk (length-aware), else the **smallest**
/// non-chunkable frame — engines that cannot chunk refuse longer prompts
/// instead of truncating (DESIGN.md §6), so no prompt the router might
/// hand them may exceed any such lane's frame.
pub fn trace_max_prompt(engines: &[crate::coordinator::engine::Engine]) -> usize {
    if engines.iter().all(|e| e.length_aware) {
        // Any length-aware lane serves any length (chunking); the widest
        // frame just sets the trace's "multi-frame" scale.
        LONG_PROMPT_FRAMES * engines.iter().map(|e| e.prefill_len).max().unwrap_or(0)
    } else {
        engines.iter().filter(|e| !e.length_aware).map(|e| e.prefill_len).min().unwrap_or(0)
    }
}

/// Fixture layout format: BUMP THIS whenever `reference_params`, the model
/// dims/consts, or the `FixtureSpec` defaults change shape — it keys the
/// shared temp-dir cache below, so stale fixtures from older code are never
/// silently reused.
pub const FIXTURE_FORMAT: u32 = 2;

/// Shared location for the on-demand fixture used by benches/examples. The
/// crate version + [`FIXTURE_FORMAT`] in the name bust the cache across
/// layout changes; tests wanting full isolation generate into their own
/// directories instead.
pub fn default_fixture_dir() -> PathBuf {
    std::env::temp_dir().join(format!(
        "tor-ssm-synthetic-fixture-{}-f{FIXTURE_FORMAT}",
        env!("CARGO_PKG_VERSION")
    ))
}

// ---------------------------------------------------------------------------
// internals
// ---------------------------------------------------------------------------

fn arr_usize(v: &[usize]) -> Json {
    Json::Arr(v.iter().map(|&x| num(x as f64)).collect())
}

fn gen_tasks(rng: &mut Rng, words: &[String], items: usize) -> Json {
    let mut map = BTreeMap::new();
    for name in crate::data::TASK_ORDER {
        let mut arr = Vec::new();
        for _ in 0..items {
            let ctx_len = 6 + rng.below(6);
            let context: Vec<&str> = (0..ctx_len)
                .map(|_| words[rng.below(words.len())].as_str())
                .collect();
            let context = context.join(" ");
            let item = if name == "s_lambada" {
                let target = words[rng.below(words.len())].clone();
                obj(vec![
                    ("context", s(&context)),
                    ("choices", Json::Arr(vec![s(&target)])),
                    ("answer", num(0.0)),
                    ("target", s(&target)),
                ])
            } else {
                let nc = 2 + rng.below(2);
                let choices: Vec<Json> = (0..nc)
                    .map(|_| {
                        let cl = 1 + rng.below(2);
                        let c: Vec<&str> =
                            (0..cl).map(|_| words[rng.below(words.len())].as_str()).collect();
                        s(&c.join(" "))
                    })
                    .collect();
                let answer = rng.below(nc);
                obj(vec![
                    ("context", s(&context)),
                    ("choices", Json::Arr(choices)),
                    ("answer", num(answer as f64)),
                    ("target", s("")),
                ])
            };
            arr.push(item);
        }
        map.insert(name.to_string(), Json::Arr(arr));
    }
    Json::Obj(map)
}

/// The reference backend's param layout for one model, in blob order.
fn reference_params(arch: &str, vocab: usize) -> Vec<(String, Vec<usize>)> {
    let (d, n) = (D_MODEL, D_STATE);
    let di = 2 * d;
    let mamba2 = arch != "mamba";
    let conv_ch = if mamba2 { di + 2 * n } else { di };
    let pw = if mamba2 { 2 * di + 2 * n } else { 2 * di };
    let mut out: Vec<(String, Vec<usize>)> = vec![("embedding".to_string(), vec![vocab, d])];
    for l in 0..N_LAYER {
        out.push((format!("layers.{l}.norm"), vec![d]));
        out.push((format!("layers.{l}.in_proj"), vec![d, pw]));
        out.push((format!("layers.{l}.conv_w"), vec![conv_ch, D_CONV]));
        out.push((format!("layers.{l}.conv_b"), vec![conv_ch]));
        if !mamba2 {
            out.push((format!("layers.{l}.bc_proj"), vec![di, 2 * n]));
        }
        out.push((format!("layers.{l}.a_log"), vec![di, n]));
        out.push((format!("layers.{l}.d_skip"), vec![di]));
        out.push((format!("layers.{l}.out_proj"), vec![di, d]));
    }
    out.push(("norm_f".to_string(), vec![d]));
    out
}

fn init_values(rng: &mut Rng, name: &str, shape: &[usize]) -> Vec<f32> {
    let count: usize = shape.iter().product();
    if name.ends_with(".norm") || name == "norm_f" {
        return vec![1.0; count];
    }
    if name.ends_with("conv_b") {
        return vec![0.0; count];
    }
    if name.ends_with("d_skip") {
        return vec![0.1; count];
    }
    if name.ends_with("a_log") {
        return (0..count).map(|_| rng.normal() as f32).collect();
    }
    let scale = if name.ends_with("conv_w") {
        0.3
    } else {
        // projections + embedding: variance-preserving in the fan-in
        1.0 / (shape[0] as f64).sqrt()
    };
    (0..count).map(|_| (rng.normal() * scale) as f32).collect()
}

/// Write the init weight blob for one model; returns (params metadata json,
/// total param count).
fn write_weights(
    dir: &Path,
    rng: &mut Rng,
    name: &str,
    arch: &str,
    vocab: usize,
) -> Result<(Json, u64)> {
    let defs = reference_params(arch, vocab);
    let mut blob: Vec<u8> = Vec::new();
    let mut params = Vec::with_capacity(defs.len());
    let mut offset = 0usize;
    let mut count = 0u64;
    for (pname, shape) in &defs {
        let values = init_values(rng, pname, shape);
        let bytes = values.len() * 4;
        for v in &values {
            blob.extend_from_slice(&v.to_le_bytes());
        }
        params.push(obj(vec![
            ("name", s(pname)),
            ("shape", arr_usize(shape)),
            ("offset", num(offset as f64)),
            ("bytes", num(bytes as f64)),
        ]));
        offset += bytes;
        count += values.len() as u64;
    }
    std::fs::write(dir.join(format!("init_{name}.bin")), blob)?;
    Ok((Json::Arr(params), count))
}

fn dims_for(name: &str, arch: &str, vocab: usize) -> ModelDims {
    ModelDims {
        name: name.to_string(),
        arch: if arch == "mamba" { Arch::Mamba } else { Arch::Mamba2 },
        vocab_size: vocab,
        d_model: D_MODEL,
        n_layer: N_LAYER,
        d_state: D_STATE,
        expand: 2,
        d_conv: D_CONV,
        headdim: 64,
        chunk: 64,
    }
}

fn reduction_json(method: &str, ratio: f64, locations: &[usize]) -> Json {
    obj(vec![
        ("method", s(method)),
        ("flops_reduction", num(ratio)),
        ("locations", arr_usize(locations)),
        ("metric", s("clip")),
        ("q_hidden", num(0.5)),
        ("q_residual", num(0.0)),
    ])
}

fn plan_json(plan: &SchedulePlan) -> Json {
    obj(vec![
        ("seq_len", num(plan.seq_len as f64)),
        ("locations", arr_usize(&plan.locations)),
        ("seg_lens", arr_usize(&plan.seg_lens)),
        ("removed", arr_usize(&plan.removed)),
        ("flops_reduction", num(plan.flops_reduction)),
    ])
}

fn gen_hlo_entries(name: &str, arch: &str, vocab: usize, spec: &FixtureSpec) -> Result<Json> {
    let dims = dims_for(name, arch, vocab);
    let mut hlo = BTreeMap::new();

    // Eval: dense + UTRC ratios.
    hlo.insert(
        "dense".to_string(),
        obj(vec![
            ("file", s(&format!("hlo/{name}.dense.hlo.txt"))),
            ("kind", s("eval")),
            ("batch", num(spec.eval_batch as f64)),
            ("seq_len", num(spec.eval_seq_len as f64)),
            ("out_len", num(spec.eval_seq_len as f64)),
            ("reduction", reduction_json("dense", 0.0, &[])),
        ]),
    );
    for ratio in EVAL_RATIOS {
        let plan = solve_schedule(&dims, spec.eval_seq_len, &LOCATIONS, ratio)
            .with_context(|| format!("{name}: eval schedule @{ratio}"))?;
        let tag = format!("utrc_r{:02}", (ratio * 100.0).round() as usize);
        hlo.insert(
            tag.clone(),
            obj(vec![
                ("file", s(&format!("hlo/{name}.{tag}.hlo.txt"))),
                ("kind", s("eval")),
                ("batch", num(spec.eval_batch as f64)),
                ("seq_len", num(spec.eval_seq_len as f64)),
                ("out_len", num(plan.final_len() as f64)),
                ("reduction", reduction_json("utrc", ratio, &LOCATIONS)),
                ("plan", plan_json(&plan)),
            ]),
        );
    }

    // Prefill: dense + UTRC ratios.
    // Prefill entries are length-aware (`lengths: true`): the reference
    // backend stops each sequence at its true length and accepts the
    // chunked-prefill resume state (DESIGN.md §6).
    hlo.insert(
        "prefill_dense".to_string(),
        obj(vec![
            ("file", s(&format!("hlo/{name}.prefill_dense.hlo.txt"))),
            ("kind", s("prefill")),
            ("batch", num(spec.prefill_batch as f64)),
            ("seq_len", num(spec.prefill_seq_len as f64)),
            ("lengths", Json::Bool(true)),
            ("reduction", reduction_json("dense", 0.0, &[])),
        ]),
    );
    for ratio in PREFILL_RATIOS {
        let plan = solve_schedule(&dims, spec.prefill_seq_len, &LOCATIONS, ratio)
            .with_context(|| format!("{name}: prefill schedule @{ratio}"))?;
        let tag = format!("prefill_utrc_r{:02}", (ratio * 100.0).round() as usize);
        hlo.insert(
            tag.clone(),
            obj(vec![
                ("file", s(&format!("hlo/{name}.{tag}.hlo.txt"))),
                ("kind", s("prefill")),
                ("batch", num(spec.prefill_batch as f64)),
                ("seq_len", num(spec.prefill_seq_len as f64)),
                ("lengths", Json::Bool(true)),
                ("reduction", reduction_json("utrc", ratio, &LOCATIONS)),
                ("plan", plan_json(&plan)),
            ]),
        );
    }

    // Decode + train steps.
    hlo.insert(
        "decode_step".to_string(),
        obj(vec![
            ("file", s(&format!("hlo/{name}.decode.hlo.txt"))),
            ("kind", s("decode")),
            ("batch", num(spec.prefill_batch as f64)),
            ("seq_len", num(1.0)),
        ]),
    );
    hlo.insert(
        "train_step".to_string(),
        obj(vec![
            ("file", s(&format!("hlo/{name}.train.hlo.txt"))),
            ("kind", s("train")),
            ("batch", num(spec.train_batch as f64)),
            ("seq_len", num(spec.train_seq_len as f64)),
        ]),
    );
    Ok(Json::Obj(hlo))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_param_layouts_cover_both_archs() {
        let mamba = reference_params("mamba", 124);
        let mamba2 = reference_params("mamba2", 124);
        assert!(mamba.iter().any(|(n, _)| n == "layers.0.bc_proj"));
        assert!(!mamba2.iter().any(|(n, _)| n.contains("bc_proj")));
        // mamba2 widens conv + in_proj by 2*d_state
        let conv = |defs: &[(String, Vec<usize>)]| {
            defs.iter().find(|(n, _)| n == "layers.0.conv_w").unwrap().1[0]
        };
        assert_eq!(conv(&mamba2) - conv(&mamba), 2 * D_STATE);
    }

    #[test]
    fn init_values_are_finite_and_scaled() {
        let mut rng = Rng::new(1);
        let v = init_values(&mut rng, "layers.0.in_proj", &[32, 128]);
        assert_eq!(v.len(), 32 * 128);
        assert!(v.iter().all(|x| x.is_finite()));
        let norm = init_values(&mut rng, "layers.0.norm", &[32]);
        assert!(norm.iter().all(|&x| x == 1.0));
    }
}
