//! Word-level tokenizer — the runtime mirror of `python/compile/tokenizer.py`.
//! The vocabulary artifact (`vocab.json`) is the shared contract; both sides
//! must agree exactly (pinned by the vocab-golden integration test).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{ensure, Context, Result};

use crate::util::json::Json;

pub const PAD: u32 = 0;
pub const UNK: u32 = 1;
pub const BOS: u32 = 2;
pub const EOS: u32 = 3;
const SPECIALS: [&str; 4] = ["<pad>", "<unk>", "<bos>", "<eos>"];

#[derive(Debug, Clone)]
pub struct Tokenizer {
    vocab: Vec<String>,
    index: HashMap<String, u32>,
}

impl Tokenizer {
    pub fn from_vocab(vocab: Vec<String>) -> Result<Tokenizer> {
        ensure!(vocab.len() >= SPECIALS.len(), "vocab too small");
        for (i, sp) in SPECIALS.iter().enumerate() {
            ensure!(vocab[i] == *sp, "vocab[{i}] must be {sp}, got {}", vocab[i]);
        }
        let index = vocab
            .iter()
            .enumerate()
            .map(|(i, w)| (w.clone(), i as u32))
            .collect();
        Ok(Tokenizer { vocab, index })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Tokenizer> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading vocab {:?}", path.as_ref()))?;
        let j = Json::parse(&text).context("parsing vocab.json")?;
        let vocab = j
            .expect("vocab")
            .as_arr()
            .context("vocab not an array")?
            .iter()
            .map(|v| v.as_str().context("vocab entry not a string").map(String::from))
            .collect::<Result<Vec<_>>>()?;
        Tokenizer::from_vocab(vocab)
    }

    pub fn len(&self) -> usize {
        self.vocab.len()
    }

    pub fn is_empty(&self) -> bool {
        self.vocab.is_empty()
    }

    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.split_whitespace()
            .map(|w| self.index.get(w).copied().unwrap_or(UNK))
            .collect()
    }

    pub fn decode(&self, ids: &[u32]) -> String {
        ids.iter()
            .filter(|&&i| i as usize >= SPECIALS.len() && (i as usize) < self.vocab.len())
            .map(|&i| self.vocab[i as usize].as_str())
            .collect::<Vec<_>>()
            .join(" ")
    }

    pub fn word(&self, id: u32) -> Option<&str> {
        self.vocab.get(id as usize).map(|s| s.as_str())
    }

    pub fn id(&self, word: &str) -> Option<u32> {
        self.index.get(word).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Tokenizer {
        Tokenizer::from_vocab(
            ["<pad>", "<unk>", "<bos>", "<eos>", "the", "lantern", "was", "crimson"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
        )
        .unwrap()
    }

    #[test]
    fn encode_decode() {
        let t = toy();
        let ids = t.encode("the lantern was crimson");
        assert_eq!(ids, vec![4, 5, 6, 7]);
        assert_eq!(t.decode(&ids), "the lantern was crimson");
    }

    #[test]
    fn unk_for_oov() {
        let t = toy();
        assert_eq!(t.encode("the zebra"), vec![4, UNK]);
    }

    #[test]
    fn specials_enforced() {
        let bad = vec!["<unk>".to_string(), "<pad>".to_string()];
        assert!(Tokenizer::from_vocab(bad).is_err());
    }
}
