//! Execution lane for one model variant: prefill → decode loop, generic
//! over the runtime [`Backend`](crate::runtime::Backend).
//!
//! Weights are uploaded once at engine construction and stay backend-
//! resident; the decode loop round-trips the (small, fixed-size) SSM states
//! through the host each step — see DESIGN.md §Perf for the measured cost
//! and why this is acceptable on the CPU paths (the PJRT execute API
//! returns the root tuple as a single buffer, so state cannot stay
//! device-side without input/output aliasing, which our HLO does not
//! declare; the reference backend is host-resident anyway).

use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::manifest::{Manifest, ModelEntry};
use crate::runtime::{DeviceWeights, Executable, HostTensor, Runtime, Weights};

use super::{Request, Response};

pub struct Engine {
    pub variant: String,
    pub model_name: String,
    prefill: Arc<dyn Executable>,
    decode: Arc<dyn Executable>,
    weights: DeviceWeights,
    pub batch: usize,
    pub prefill_len: usize,
    conv_shape: Vec<usize>,
    ssm_shape: Vec<usize>,
    vocab: usize,
}

impl Engine {
    /// Build an engine for `variant` ("dense" or "utrc@<ratio>").
    pub fn new(
        rt: &Runtime,
        man: &Manifest,
        model: &ModelEntry,
        weights: &Weights,
        variant: &str,
    ) -> Result<Engine> {
        let (method, ratio) = parse_variant(variant)?;
        let pf = model.prefill_entry(&method, ratio)?;
        let dec = model.decode_entry()?;
        let prefill = rt.load_entry(man, model, pf)?;
        let decode = rt.load_entry(man, model, dec)?;
        let dw = rt.upload_weights(model, weights)?;
        let (conv_shape, ssm_shape) = crate::runtime::decode_state_shapes(model, dec.batch);
        Ok(Engine {
            variant: variant.to_string(),
            model_name: model.name.clone(),
            prefill,
            decode,
            weights: dw,
            batch: pf.batch,
            prefill_len: pf.seq_len,
            conv_shape,
            ssm_shape,
            vocab: model.vocab_size,
        })
    }

    /// Serve one batch of requests (padded internally to the static batch).
    /// Returns one Response per request, in order.
    pub fn serve_batch(&self, reqs: &[Request]) -> Result<Vec<Response>> {
        ensure!(!reqs.is_empty(), "empty batch");
        ensure!(reqs.len() <= self.batch, "batch overflow: {} > {}", reqs.len(), self.batch);
        let now = Instant::now();

        // ---- prefill ----
        let mut flat = Vec::with_capacity(self.batch * self.prefill_len);
        for r in reqs {
            let mut p = r.prompt.clone();
            p.resize(self.prefill_len, crate::tokenizer::PAD as i32);
            flat.extend_from_slice(&p[..self.prefill_len]);
        }
        flat.resize(self.batch * self.prefill_len, crate::tokenizer::PAD as i32);
        let tokens = HostTensor::i32(vec![self.batch, self.prefill_len], flat);
        let mut outs = self.prefill.execute(&self.weights, &[tokens]).context("prefill")?;
        ensure!(outs.len() == 3, "prefill must return (logits, conv, ssm)");
        let mut ssm = outs.pop().unwrap();
        let mut conv = outs.pop().unwrap();
        let mut logits = outs.pop().unwrap();
        ensure!(
            conv.shape == self.conv_shape,
            "conv state shape {:?} != {:?}",
            conv.shape,
            self.conv_shape
        );
        ensure!(ssm.shape == self.ssm_shape, "ssm state shape mismatch");
        let prefill_us = now.elapsed().as_micros() as u64;

        // ---- decode loop ----
        let t_dec = Instant::now();
        let gen_tokens = reqs.iter().map(|r| r.gen_tokens).max().unwrap_or(0);
        let mut generated: Vec<Vec<i32>> = vec![Vec::new(); reqs.len()];
        for _step in 0..gen_tokens {
            // Greedy sample from last logits.
            let lv = logits.as_f32()?;
            let mut next = vec![0i32; self.batch];
            for (b, nx) in next.iter_mut().enumerate() {
                let row = &lv[b * self.vocab..(b + 1) * self.vocab];
                let mut best = 0usize;
                for (i, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = i;
                    }
                }
                *nx = best as i32;
            }
            for (i, g) in generated.iter_mut().enumerate() {
                if g.len() < reqs[i].gen_tokens {
                    g.push(next[i]);
                }
            }
            // Step.
            let tok_t = HostTensor::i32(vec![self.batch], next);
            let mut outs = self
                .decode
                .execute(&self.weights, &[tok_t, conv, ssm])
                .context("decode step")?;
            ensure!(outs.len() == 3, "decode must return (logits, conv, ssm)");
            ssm = outs.pop().unwrap();
            conv = outs.pop().unwrap();
            logits = outs.pop().unwrap();
        }
        let decode_us = t_dec.elapsed().as_micros() as u64;

        Ok(reqs
            .iter()
            .zip(generated)
            .map(|(r, g)| Response {
                id: r.id,
                generated: g,
                prefill_us,
                decode_us,
                queue_us: 0,
                variant: self.variant.clone(),
            })
            .collect())
    }
}

pub fn parse_variant(variant: &str) -> Result<(String, f64)> {
    if variant == "dense" || variant.is_empty() {
        return Ok(("dense".to_string(), 0.0));
    }
    let (m, r) = variant
        .split_once('@')
        .with_context(|| format!("variant {variant:?} must be 'dense' or 'method@ratio'"))?;
    Ok((m.to_string(), r.parse::<f64>().context("bad ratio")?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_parse() {
        assert_eq!(parse_variant("dense").unwrap(), ("dense".into(), 0.0));
        assert_eq!(parse_variant("utrc@0.2").unwrap(), ("utrc".into(), 0.2));
        assert!(parse_variant("nope").is_err());
    }
}
