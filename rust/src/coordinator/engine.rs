//! Execution lane for one model variant: prefill → decode loop.
//!
//! Weights are uploaded once and stay device-resident (`execute_b`); the
//! decode loop round-trips the (small, fixed-size) SSM states through the
//! host each step — see DESIGN.md §Perf for the measured cost and why this
//! is acceptable on the CPU PJRT client (the crate's execute API returns the
//! root tuple as a single buffer, so state cannot stay device-side without
//! input/output aliasing, which our HLO does not declare).

use std::sync::Arc;
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use crate::manifest::{Manifest, ModelEntry};
use crate::runtime::{DeviceWeights, Executable, HostTensor, Runtime, Weights};

use super::{Request, Response};

pub struct Engine {
    pub variant: String,
    pub model_name: String,
    prefill: Arc<Executable>,
    decode: Arc<Executable>,
    weights: DeviceWeights,
    pub batch: usize,
    pub prefill_len: usize,
    conv_shape: Vec<usize>,
    ssm_shape: Vec<usize>,
    vocab: usize,
}

impl Engine {
    /// Build an engine for `variant` ("dense" or "utrc@<ratio>").
    pub fn new(
        rt: &Runtime,
        man: &Manifest,
        model: &ModelEntry,
        weights: &Weights,
        variant: &str,
    ) -> Result<Engine> {
        let (method, ratio) = parse_variant(variant)?;
        let pf = model.prefill_entry(&method, ratio)?;
        let dec = model.decode_entry()?;
        let prefill = rt.load_entry(man, pf)?;
        let decode = rt.load_entry(man, dec)?;
        let dw = rt.upload_weights(man, model, weights)?;
        // Decode-state shapes come from the manifest's decode entry metadata.
        let conv_shape = decode_state_shape(man, model, true)?;
        let ssm_shape = decode_state_shape(man, model, false)?;
        Ok(Engine {
            variant: variant.to_string(),
            model_name: model.name.clone(),
            prefill,
            decode,
            weights: dw,
            batch: pf.batch,
            prefill_len: pf.seq_len,
            conv_shape,
            ssm_shape,
            vocab: model.vocab_size,
        })
    }

    /// Serve one batch of requests (padded internally to the static batch).
    /// Returns one Response per request, in order.
    pub fn serve_batch(&self, rt: &Runtime, reqs: &[Request]) -> Result<Vec<Response>> {
        ensure!(!reqs.is_empty(), "empty batch");
        ensure!(reqs.len() <= self.batch, "batch overflow: {} > {}", reqs.len(), self.batch);
        let now = Instant::now();

        // ---- prefill ----
        let mut flat = Vec::with_capacity(self.batch * self.prefill_len);
        for r in reqs {
            let mut p = r.prompt.clone();
            p.resize(self.prefill_len, crate::tokenizer::PAD as i32);
            flat.extend_from_slice(&p[..self.prefill_len]);
        }
        flat.resize(self.batch * self.prefill_len, crate::tokenizer::PAD as i32);
        let tokens = HostTensor::i32(vec![self.batch, self.prefill_len], flat);
        let tok_buf = rt.upload(&tokens)?;
        let mut args: Vec<&xla::PjRtBuffer> = self.weights.buffers.iter().collect();
        args.push(&tok_buf);
        let outs = self.prefill.run_b(&args).context("prefill")?;
        ensure!(outs.len() == 3, "prefill must return (logits, conv, ssm)");
        let prefill_us = now.elapsed().as_micros() as u64;

        // ---- decode loop ----
        let t_dec = Instant::now();
        let gen_tokens = reqs.iter().map(|r| r.gen_tokens).max().unwrap_or(0);
        let mut logits = outs[0].clone();
        let mut conv = outs[1].clone();
        let mut ssm = outs[2].clone();
        ensure!(conv.shape == self.conv_shape, "conv state shape {:?} != {:?}", conv.shape, self.conv_shape);
        ensure!(ssm.shape == self.ssm_shape, "ssm state shape mismatch");

        let mut generated: Vec<Vec<i32>> = vec![Vec::new(); reqs.len()];
        for _step in 0..gen_tokens {
            // Greedy sample from last logits.
            let lv = logits.as_f32()?;
            let mut next = vec![0i32; self.batch];
            for (b, nx) in next.iter_mut().enumerate() {
                let row = &lv[b * self.vocab..(b + 1) * self.vocab];
                let mut best = 0usize;
                for (i, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = i;
                    }
                }
                *nx = best as i32;
            }
            for (i, g) in generated.iter_mut().enumerate() {
                if g.len() < reqs[i].gen_tokens {
                    g.push(next[i]);
                }
            }
            // Step.
            let tok_t = HostTensor::i32(vec![self.batch], next);
            let tok_b = rt.upload(&tok_t)?;
            let conv_b = rt.upload(&conv)?;
            let ssm_b = rt.upload(&ssm)?;
            let mut args: Vec<&xla::PjRtBuffer> = self.weights.buffers.iter().collect();
            args.push(&tok_b);
            args.push(&conv_b);
            args.push(&ssm_b);
            let outs = self.decode.run_b(&args).context("decode step")?;
            ensure!(outs.len() == 3, "decode must return (logits, conv, ssm)");
            logits = outs[0].clone();
            conv = outs[1].clone();
            ssm = outs[2].clone();
        }
        let decode_us = t_dec.elapsed().as_micros() as u64;

        Ok(reqs
            .iter()
            .zip(generated)
            .map(|(r, g)| Response {
                id: r.id,
                generated: g,
                prefill_us,
                decode_us,
                queue_us: 0,
                variant: self.variant.clone(),
            })
            .collect())
    }
}

pub fn parse_variant(variant: &str) -> Result<(String, f64)> {
    if variant == "dense" || variant.is_empty() {
        return Ok(("dense".to_string(), 0.0));
    }
    let (m, r) = variant
        .split_once('@')
        .with_context(|| format!("variant {variant:?} must be 'dense' or 'method@ratio'"))?;
    Ok((m.to_string(), r.parse::<f64>().context("bad ratio")?))
}

fn decode_state_shape(_man: &Manifest, model: &ModelEntry, conv: bool) -> Result<Vec<usize>> {
    let e = model.decode_entry()?;
    // Shapes recorded by aot.py in the decode entry.
    let key = if conv { "conv_state_shape" } else { "ssm_state_shape" };
    // HloEntry doesn't carry arbitrary fields; re-read from the raw manifest
    // is avoidable: reconstruct from dims instead.
    let _ = key;
    let nl = model.n_layer;
    let b = e.batch;
    let di = model.d_inner;
    let n = model.d_state;
    let k = 4; // d_conv
    Ok(if model.arch == "mamba" {
        if conv { vec![nl, b, di, k - 1] } else { vec![nl, b, di, n] }
    } else if conv {
        vec![nl, b, di + 2 * n, k - 1]
    } else {
        vec![nl, b, di / 64, 64, n]
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_parse() {
        assert_eq!(parse_variant("dense").unwrap(), ("dense".into(), 0.0));
        assert_eq!(parse_variant("utrc@0.2").unwrap(), ("utrc".into(), 0.2));
        assert!(parse_variant("nope").is_err());
    }
}
