//! Execution lane for one model variant — `"dense"` or a token-reduction
//! policy variant `<policy>@<ratio>[:<metric>]` (DESIGN.md §10) — split into
//! the two phases the continuous-batching scheduler composes (DESIGN.md §6):
//!
//! * [`Engine::prefill`] — ingest up to `batch` prompts through the static
//!   prefill frame and slice the resulting `[n_layer, B, ...]` state frame
//!   into per-sequence states ready for
//!   [`StateStore::admit`](super::state_store::StateStore::admit). On a
//!   length-aware backend each prompt is computed at its **true length**
//!   (frame padding is never scanned into the SSM state), and prompts
//!   longer than the frame run as **chunked prefill**: frame-sized chunks
//!   with the O(1) recurrent state carried across chunks (DESIGN.md §6).
//!   Engines that cannot chunk (AOT entries without a `lengths` input)
//!   refuse over-long prompts with a hard error instead of truncating.
//! * [`Engine::decode_step`] — advance every lane of a [`DecodeFrame`] by
//!   one token.
//!
//! [`Engine::serve_batch`] keeps the legacy lock-step path (prefill a whole
//! batch, decode everyone for `max(gen_tokens)` steps) on top of the same
//! two phases; it is the baseline the scheduler is benchmarked against.
//!
//! Weights are uploaded once at engine construction and stay backend-
//! resident; the decode loop round-trips the (small, fixed-size) SSM states
//! through the host each step — see DESIGN.md §9 (Perf) for the measured
//! cost and why this is acceptable on the CPU paths (the PJRT execute API
//! returns the root tuple as a single buffer, so state cannot stay
//! device-side without input/output aliasing, which our HLO does not
//! declare; the reference backend is host-resident anyway).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock, RwLockReadGuard};
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use crate::manifest::{Manifest, ModelEntry};
use crate::reduction::policy::PolicySpec;
use crate::runtime::tensor::{read_lane, write_lane};
use crate::runtime::{DeviceWeights, Executable, HostTensor, Runtime, TensorData, Weights};

use super::prefix_cache::PrefixCache;
use super::state_store::StateStore;
use super::{Request, Response};

/// Deterministic fault-injection seam (DESIGN.md §15): make the k-th
/// prefill and/or decode **call** of an engine fail with a typed error.
/// Call indices are 1-based over the engine's lifetime, counted at the
/// phase entry points ([`Engine::prefill`] / [`Engine::decode_step`]) —
/// independent of batching, so a plan written against a trace names exact
/// calls. This is a serving-layer test seam pinning the replica pool's
/// failover contract (`tests/replica_faults.rs`); production paths simply
/// never install a plan.
#[derive(Debug, Clone, Default)]
pub struct FailurePlan {
    /// 1-based prefill-call indices that fail.
    pub fail_prefill_calls: Vec<u64>,
    /// 1-based decode-call indices that fail.
    pub fail_decode_calls: Vec<u64>,
}

/// Backend-resident weights plus the registry tag they were loaded under —
/// the unit [`Engine::hot_swap_weights`] replaces atomically.
struct ResidentWeights {
    dev: DeviceWeights,
    tag: String,
}

pub struct Engine {
    pub variant: String,
    pub model_name: String,
    prefill: Arc<dyn Executable>,
    decode: Arc<dyn Executable>,
    /// Interior-mutable so a quiescent engine can swap models without
    /// being rebuilt ([`Engine::hot_swap_weights`], DESIGN.md §15). The
    /// lock is uncontended in steady state: one scheduler thread reads it
    /// per phase call, writers exist only during a rolling upgrade.
    weights: RwLock<ResidentWeights>,
    /// Static prefill frame: at most this many prompts per prefill call.
    pub batch: usize,
    pub prefill_len: usize,
    /// Whether the prefill entry takes a per-sequence `lengths` input
    /// (manifest `lengths: true`, backend-guarded at load time). Length-
    /// aware engines compute every prompt at its true length, chunk prompts
    /// longer than `prefill_len`, and mark idle decode lanes with
    /// [`IDLE_LANE`](crate::runtime::IDLE_LANE) so the backend skips them.
    pub length_aware: bool,
    /// Decode frame width: how many sequences one decode step advances.
    pub decode_batch: usize,
    n_layer: usize,
    /// Per-layer, per-sequence element counts of the two decode states.
    conv_row: usize,
    ssm_row: usize,
    /// Decode-frame state shapes (`[n_layer, decode_batch, ...]`).
    conv_shape: Vec<usize>,
    ssm_shape: Vec<usize>,
    /// Prefill-output state shapes (`[n_layer, batch, ...]`).
    pf_conv_shape: Vec<usize>,
    pf_ssm_shape: Vec<usize>,
    vocab: usize,
    /// Decode-frame executions since construction. This is the iteration
    /// count continuous batching minimises; relaxed ordering — a counter,
    /// not a synchronisation point.
    pub decode_calls: AtomicU64,
    /// Prompt tokens actually packed into executed prefill frames since
    /// construction — **measured at the frame-packing site**, true lengths
    /// only (frame padding and idle chunk lanes never count), incremented
    /// only after the frame executes. Because it counts what was fed, not
    /// what was requested, comparing it against a trace's own token count
    /// detects truncation anywhere in the prefill path — the
    /// zero-truncation gate `benches/runtime.rs` runs in CI. Relaxed
    /// ordering — a counter, not a synchronisation point.
    pub prefill_tokens: AtomicU64,
    /// Prompt tokens *skipped* by resuming from a cached prefix-state
    /// snapshot instead of recomputing them (DESIGN.md §12). Disjoint from
    /// [`Self::prefill_tokens`]: for every request,
    /// `fed + resumed == prompt.len()`, which is how the zero-truncation
    /// gate stays honest on cache-warm traces. Relaxed ordering — a
    /// counter, not a synchronisation point.
    pub resumed_tokens: AtomicU64,
    /// Optional shared content-addressed cache of chunk-aligned prompt
    /// prefix states ([`PrefixCache`], DESIGN.md §12). `None` (the default)
    /// keeps prefill byte-for-byte on the PR 5 path.
    prefix_cache: Option<Arc<PrefixCache>>,
    /// Installed [`FailurePlan`], if any (test seam; `None` in production).
    failure_plan: Mutex<Option<FailurePlan>>,
    /// Lifetime 1-based call counters the failure plan indexes — distinct
    /// from [`Self::decode_calls`], which counts *successful* executes.
    seam_prefill_calls: AtomicU64,
    seam_decode_calls: AtomicU64,
}

/// One prompt's prefill result: the per-sequence decode state (contiguous
/// `[n_layer, row]`, ready for the state store) plus the last-position
/// logits row the first generated token is sampled from.
pub struct PrefilledSeq {
    pub conv: Vec<f32>,
    pub ssm: Vec<f32>,
    pub logits: Vec<f32>,
}

/// The mutable decode frame a serve loop steps: one input token and one
/// conv/ssm state lane per slot, laid out `[n_layer, decode_batch, ...]`.
/// Idle lanes hold [`Engine::idle_token`] + zero state: on a length-aware
/// backend the sentinel makes the backend skip them outright; on AOT
/// backends they decode PAD and the output is simply ignored by callers.
pub struct DecodeFrame {
    pub tokens: Vec<i32>,
    pub conv: Vec<f32>,
    pub ssm: Vec<f32>,
}

impl Engine {
    /// Build an engine for `variant` — `"dense"` or any reduction-policy
    /// variant `<policy>@<ratio>[:<metric>]` (DESIGN.md §10). The variant's
    /// ratio selects the exported schedule plan (a method-matched export is
    /// preferred; any export with the right plan geometry serves on the
    /// reference backend, where the policy dispatches at run time).
    pub fn new(
        rt: &Runtime,
        man: &Manifest,
        model: &ModelEntry,
        weights: &Weights,
        variant: &str,
    ) -> Result<Engine> {
        let policy = parse_variant(variant)?;
        let pf = match &policy {
            None => model.prefill_entry("dense", 0.0)?,
            Some(p) => model
                .prefill_entry(p.kind.manifest_method(), p.ratio)
                .or_else(|_| model.prefill_entry_for_plan(p.ratio))
                .with_context(|| format!("resolving a prefill plan for variant {variant:?}"))?,
        };
        let dec = model.decode_entry()?;
        let prefill = rt.load_entry_with_policy(man, model, pf, policy.as_ref())?;
        let decode = rt.load_entry(man, model, dec)?;
        let dw = rt.upload_weights(model, weights)?;
        let (conv_shape, ssm_shape) = crate::runtime::decode_state_shapes(model, dec.batch);
        let (pf_conv_shape, pf_ssm_shape) = crate::runtime::decode_state_shapes(model, pf.batch);
        let conv_row = conv_shape[2..].iter().product();
        let ssm_row = ssm_shape[2..].iter().product();
        Ok(Engine {
            variant: variant.to_string(),
            model_name: model.name.clone(),
            prefill,
            decode,
            weights: RwLock::new(ResidentWeights { dev: dw, tag: "init".to_string() }),
            batch: pf.batch,
            prefill_len: pf.seq_len,
            length_aware: pf.takes_lengths,
            decode_batch: dec.batch,
            n_layer: model.n_layer,
            conv_row,
            ssm_row,
            conv_shape,
            ssm_shape,
            pf_conv_shape,
            pf_ssm_shape,
            vocab: model.vocab_size,
            decode_calls: AtomicU64::new(0),
            prefill_tokens: AtomicU64::new(0),
            resumed_tokens: AtomicU64::new(0),
            prefix_cache: None,
            failure_plan: Mutex::new(None),
            seam_prefill_calls: AtomicU64::new(0),
            seam_decode_calls: AtomicU64::new(0),
        })
    }

    /// Resident-weights read guard. Poison recovery is safe here: a panic
    /// mid-`execute` cannot leave the weights partially written (swaps
    /// replace the whole `ResidentWeights` value under the write guard).
    fn weights(&self) -> RwLockReadGuard<'_, ResidentWeights> {
        self.weights.read().unwrap_or_else(|e| e.into_inner())
    }

    /// The registry tag of the resident weights: `"init"` from
    /// construction, or whatever tag the last [`Self::hot_swap_weights`]
    /// installed. The replica pool compares this against the upgrade
    /// target to find replicas still awaiting their swap (DESIGN.md §15).
    pub fn weights_tag(&self) -> String {
        self.weights().tag.clone()
    }

    /// Atomically replace the resident weights (rolling upgrade,
    /// DESIGN.md §15). Caller contract: the engine must be **quiescent** —
    /// no queued, ready, or resident sequence on any scheduler driving it —
    /// because in-flight SSM states were produced under the old weights and
    /// decoding them under new ones would mix models within one sequence.
    /// [`ReplicaPool::advance_upgrade`](super::replica::ReplicaPool::advance_upgrade)
    /// enforces this by swapping only Draining+idle replicas. Any attached
    /// [`PrefixCache`] is cleared for the same reason: its snapshots encode
    /// the old weights.
    pub fn hot_swap_weights(&self, dev: DeviceWeights, tag: &str) {
        {
            let mut w = self.weights.write().unwrap_or_else(|e| e.into_inner());
            *w = ResidentWeights { dev, tag: tag.to_string() };
        }
        if let Some(cache) = self.prefix_cache.as_deref() {
            cache.clear();
        }
    }

    /// Install a [`FailurePlan`] (`None` clears it). Takes `&self`: the
    /// seam must be reachable on the shared-reference engines the
    /// scheduler and pool hold.
    pub fn set_failure_plan(&self, plan: Option<FailurePlan>) {
        *self.failure_plan.lock().unwrap_or_else(|e| e.into_inner()) = plan;
    }

    /// Bump the 1-based call counter for `phase` and fail if the installed
    /// plan names this call. The error is typed by message prefix
    /// (`"injected failure:"`) so tests can tell injected faults from real
    /// backend errors.
    fn check_failure_seam(&self, phase: &str, counter: &AtomicU64, decode: bool) -> Result<()> {
        // ORDERING: Relaxed — a monotonic call tally; uniqueness comes from
        // fetch_add's atomicity, and no other data hangs off this counter.
        let call = counter.fetch_add(1, Ordering::Relaxed) + 1;
        let guard = self.failure_plan.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(plan) = guard.as_ref() {
            let hits = if decode { &plan.fail_decode_calls } else { &plan.fail_prefill_calls };
            if hits.contains(&call) {
                bail!("injected failure: {phase} call {call} (FailurePlan)");
            }
        }
        Ok(())
    }

    /// Attach a (shared) prefix-state cache: subsequent length-aware
    /// prefills consult it for warm prefixes and insert chunk-boundary
    /// snapshots (DESIGN.md §12). One cache may serve many engines — the
    /// key space is partitioned by `(model, variant)`.
    pub fn attach_prefix_cache(&mut self, cache: Arc<PrefixCache>) {
        self.prefix_cache = Some(cache);
    }

    /// The attached prefix cache, if any (hit/miss/evict inspection).
    pub fn prefix_cache(&self) -> Option<&PrefixCache> {
        self.prefix_cache.as_deref()
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// `(n_layer, conv_row, ssm_row)` — the per-sequence state geometry.
    pub fn state_dims(&self) -> (usize, usize, usize) {
        (self.n_layer, self.conv_row, self.ssm_row)
    }

    /// A [`StateStore`] sized for this engine's state geometry.
    pub fn new_store(&self, capacity: usize) -> StateStore {
        StateStore::new(capacity, self.n_layer, self.conv_row, self.ssm_row)
    }

    /// Fill token for an idle decode-frame lane. Length-aware engines use
    /// the [`IDLE_LANE`](crate::runtime::IDLE_LANE) sentinel, which the
    /// backend skips outright (no phantom decode, zero logits); engines on
    /// AOT entries keep the legacy PAD fill, which the fixed-arity graph
    /// decodes and the caller discards.
    pub fn idle_token(&self) -> i32 {
        if self.length_aware {
            crate::runtime::IDLE_LANE
        } else {
            crate::tokenizer::PAD as i32
        }
    }

    /// A zeroed decode frame (every lane idle).
    pub fn new_frame(&self) -> DecodeFrame {
        DecodeFrame {
            tokens: vec![self.idle_token(); self.decode_batch],
            conv: vec![0.0; self.conv_shape.iter().product()],
            ssm: vec![0.0; self.ssm_shape.iter().product()],
        }
    }

    /// Phase 1: prefill up to `self.batch` prompts. Returns one per-sequence
    /// state + first-logits row per request, plus the call's wall time in µs.
    ///
    /// On a length-aware engine every prompt is computed at its **true
    /// length** — the frame's trailing padding is never scanned into the
    /// conv/ssm state, the first token is sampled from the logits at the
    /// true last prompt token, and the reduction schedule is solved on the
    /// true length (DESIGN.md §6). Prompts longer than `prefill_len` run as
    /// chunked prefill: `prefill_len`-sized chunks through the same frame,
    /// with each sequence's per-layer recurrent state carried across chunks
    /// (cheap for an SSM — the state is O(1) in sequence length). On the
    /// dense path chunking is bit-invisible; a reduced lane dispatches its
    /// policy per chunk (the chunk's own runtime-solved schedule).
    ///
    /// Engines whose prefill entry takes no `lengths` input (AOT exports)
    /// keep the legacy full-frame padding semantics and **refuse** prompts
    /// longer than the frame — a hard error beats the silent truncation
    /// this path used to perform.
    ///
    /// Each prompt flows through the model independently, so a prompt's
    /// returned state is bit-identical whether it was prefilled alone or
    /// alongside others — the property the continuous scheduler's
    /// "identical output to lock-step" guarantee rests on (and, with
    /// lengths threaded, independent of how much frame padding follows it —
    /// pinned by `tests/prefill_invariance.rs`).
    pub fn prefill(&self, reqs: &[Request]) -> Result<(Vec<PrefilledSeq>, u64)> {
        ensure!(!reqs.is_empty(), "empty prefill batch");
        ensure!(reqs.len() <= self.batch, "prefill overflow: {} > {}", reqs.len(), self.batch);
        for r in reqs {
            ensure!(!r.prompt.is_empty(), "request {}: empty prompt", r.id);
        }
        self.check_failure_seam("prefill", &self.seam_prefill_calls, false)?;
        let t0 = Instant::now();
        let seqs = if self.length_aware {
            self.prefill_chunked(reqs)?
        } else {
            for r in reqs {
                ensure!(
                    r.prompt.len() <= self.prefill_len,
                    "request {}: prompt has {} tokens but the prefill frame is {} and this \
                     engine cannot chunk (entry takes no `lengths` input); refusing to \
                     truncate silently — split the prompt or serve it on a length-aware \
                     backend",
                    r.id,
                    r.prompt.len(),
                    self.prefill_len
                );
            }
            self.prefill_padded(reqs)?
        };
        Ok((seqs, t0.elapsed().as_micros() as u64))
    }

    /// Legacy fixed-frame prefill (entries without a `lengths` input):
    /// right-pad every prompt to `prefill_len` and scan the whole frame.
    fn prefill_padded(&self, reqs: &[Request]) -> Result<Vec<PrefilledSeq>> {
        let mut flat = Vec::with_capacity(self.batch * self.prefill_len);
        let mut packed = 0u64;
        for r in reqs {
            packed += r.prompt.len().min(self.prefill_len) as u64;
            let mut p = r.prompt.clone();
            p.resize(self.prefill_len, crate::tokenizer::PAD as i32);
            flat.extend_from_slice(&p[..self.prefill_len]);
        }
        flat.resize(self.batch * self.prefill_len, crate::tokenizer::PAD as i32);
        let tokens = HostTensor::i32(vec![self.batch, self.prefill_len], flat);
        let (logits, conv_f, ssm_f) = self.exec_prefill_frame(&[tokens])?;
        // ORDERING: Relaxed — stats-only token tally, read by /stats renders.
        self.prefill_tokens.fetch_add(packed, Ordering::Relaxed);
        Ok((0..reqs.len()).map(|i| self.slice_lane(i, &logits, &conv_f, &ssm_f)).collect())
    }

    /// Length-aware prefill: feed true per-sequence lengths with the frame,
    /// looping prompts longer than `prefill_len` through frame-sized chunks
    /// with the `[n_layer, B, ...]` state frames carried chunk to chunk.
    /// Lanes whose prompt ended in an earlier chunk ride along with length
    /// 0 (the backend skips them); each sequence's state + logits are
    /// captured from the chunk its last token lands in.
    ///
    /// With a [`PrefixCache`] attached, each lane first consults it for the
    /// longest chunk-aligned **proper** prefix of its prompt: on a hit the
    /// lane's resume state is seeded from the snapshot via the same
    /// `(conv0, ssm0)` inputs chunked prefill already uses between chunks,
    /// and only the remainder is fed (skipped tokens count in
    /// [`Self::resumed_tokens`], fed tokens in [`Self::prefill_tokens`] —
    /// the two always sum to the true prompt length). Chunk-boundary states
    /// crossed while prefilling are inserted back, warming the cache.
    /// Because snapshots sit only on chunk boundaries, a warm lane's
    /// remainder has the same chunk decomposition the cold run used for
    /// those positions — so the backend's per-length schedule re-solve sees
    /// identical chunk lengths and warm resume is bit-identical to cold
    /// prefill, on dense and reduced lanes alike (DESIGN.md §12, pinned by
    /// `tests/state_cache.rs`).
    fn prefill_chunked(&self, reqs: &[Request]) -> Result<Vec<PrefilledSeq>> {
        let plen = self.prefill_len;
        let (nl, crow, srow) = (self.n_layer, self.conv_row, self.ssm_row);
        let mut done: Vec<Option<PrefilledSeq>> = (0..reqs.len()).map(|_| None).collect();
        // Per-lane progress: how many of the lane's prompt tokens are
        // already absorbed into its carried state (0 = cold start).
        let mut offset = vec![0usize; reqs.len()];
        let mut carry: Option<(Vec<f32>, Vec<f32>)> = None;
        if let Some(cache) = self.prefix_cache.as_deref() {
            let mut conv0 = vec![0.0f32; self.pf_conv_shape.iter().product()];
            let mut ssm0 = vec![0.0f32; self.pf_ssm_shape.iter().product()];
            let mut any = false;
            for (i, r) in reqs.iter().enumerate() {
                let Some((blen, conv, ssm)) =
                    cache.longest_prefix(&self.model_name, &self.variant, &r.prompt, plen)
                else {
                    continue;
                };
                // Geometry guard: a cache shared with a differently-shaped
                // engine must never corrupt a lane (treated as a cold miss).
                if conv.len() != nl * crow || ssm.len() != nl * srow {
                    continue;
                }
                write_lane(&mut conv0, nl, self.batch, crow, i, &conv);
                write_lane(&mut ssm0, nl, self.batch, srow, i, &ssm);
                offset[i] = blen;
                // ORDERING: Relaxed — stats-only tally of resumed tokens.
                self.resumed_tokens.fetch_add(blen as u64, Ordering::Relaxed);
                any = true;
            }
            if any {
                // Cold lanes keep their zero rows: the backend's zero-state
                // init is bit-identical to its no-init start, so one resume
                // frame serves a mixed warm/cold batch.
                carry = Some((conv0, ssm0));
            }
        }
        loop {
            let mut flat = vec![crate::tokenizer::PAD as i32; self.batch * plen];
            let mut lens = vec![0i32; self.batch];
            for (i, r) in reqs.iter().enumerate() {
                if offset[i] >= r.prompt.len() {
                    continue; // finished in an earlier chunk: idle lane
                }
                let end = (offset[i] + plen).min(r.prompt.len());
                let take = end - offset[i];
                flat[i * plen..i * plen + take].copy_from_slice(&r.prompt[offset[i]..end]);
                lens[i] = take as i32;
            }
            let mut inputs = vec![
                HostTensor::i32(vec![self.batch, plen], flat),
                HostTensor::i32(vec![self.batch], lens.clone()),
            ];
            if let Some((c, s)) = carry.take() {
                inputs.push(HostTensor::f32(self.pf_conv_shape.clone(), c));
                inputs.push(HostTensor::f32(self.pf_ssm_shape.clone(), s));
            }
            let (logits, conv_f, ssm_f) = self.exec_prefill_frame(&inputs)?;
            // ORDERING: Relaxed — stats-only token tally.
            self.prefill_tokens
                .fetch_add(lens.iter().map(|&x| x as u64).sum::<u64>(), Ordering::Relaxed);
            for (i, r) in reqs.iter().enumerate() {
                if lens[i] == 0 {
                    continue;
                }
                offset[i] += lens[i] as usize;
                if offset[i] == r.prompt.len() {
                    done[i] = Some(self.slice_lane(i, &logits, &conv_f, &ssm_f));
                }
                // Every chunk-aligned boundary just crossed is a reusable
                // prefix snapshot — insert it (duplicates only touch LRU).
                if offset[i] % plen == 0 {
                    if let Some(cache) = self.prefix_cache.as_deref() {
                        let mut conv = vec![0.0f32; nl * crow];
                        let mut ssm = vec![0.0f32; nl * srow];
                        read_lane(&conv_f, nl, self.batch, crow, i, &mut conv);
                        read_lane(&ssm_f, nl, self.batch, srow, i, &mut ssm);
                        cache.insert(
                            &self.model_name,
                            &self.variant,
                            &r.prompt[..offset[i]],
                            &conv,
                            &ssm,
                        );
                    }
                }
            }
            if done.iter().any(|d| d.is_none()) {
                carry = Some((conv_f, ssm_f));
            } else {
                break;
            }
        }
        Ok(done.into_iter().map(|d| d.expect("every prompt ends in some chunk")).collect())
    }

    /// Execute + shape-validate one prefill frame; returns owned
    /// (logits `[batch·vocab]`, conv frame, ssm frame).
    fn exec_prefill_frame(&self, inputs: &[HostTensor]) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let mut outs = self.prefill.execute(&self.weights().dev, inputs).context("prefill")?;
        ensure!(outs.len() == 3, "prefill must return (logits, conv, ssm)");
        let ssm_t = outs.pop().unwrap();
        let conv_t = outs.pop().unwrap();
        let logits_t = outs.pop().unwrap();
        ensure!(
            conv_t.shape == self.pf_conv_shape,
            "prefill conv state shape {:?} != {:?}",
            conv_t.shape,
            self.pf_conv_shape
        );
        ensure!(
            ssm_t.shape == self.pf_ssm_shape,
            "prefill ssm state shape {:?} != {:?}",
            ssm_t.shape,
            self.pf_ssm_shape
        );
        ensure!(
            logits_t.shape == vec![self.batch, self.vocab],
            "prefill logits shape {:?} != [{}, {}]",
            logits_t.shape,
            self.batch,
            self.vocab
        );
        Ok((into_f32(logits_t)?, into_f32(conv_t)?, into_f32(ssm_t)?))
    }

    /// Slice lane `i` of a prefill output frame into its per-sequence
    /// contiguous `[n_layer, row]` states + logits row.
    fn slice_lane(&self, i: usize, logits: &[f32], conv_f: &[f32], ssm_f: &[f32]) -> PrefilledSeq {
        let mut conv = vec![0.0f32; self.n_layer * self.conv_row];
        let mut ssm = vec![0.0f32; self.n_layer * self.ssm_row];
        read_lane(conv_f, self.n_layer, self.batch, self.conv_row, i, &mut conv);
        read_lane(ssm_f, self.n_layer, self.batch, self.ssm_row, i, &mut ssm);
        PrefilledSeq {
            conv,
            ssm,
            logits: logits[i * self.vocab..(i + 1) * self.vocab].to_vec(),
        }
    }

    /// Phase 2: advance every lane of `frame` one token. The new conv/ssm
    /// states are written back into the frame; the `[decode_batch × vocab]`
    /// logits are returned row-major. On error the frame's original states
    /// are restored, so a long-lived frame stays structurally valid.
    ///
    /// On the reference backend this is the lane-parallel fused hot path:
    /// the frame shards across `min(decode_batch, workers)` threads and
    /// every lane runs the cache-blocked kernels (DESIGN.md §11,
    /// PERFORMANCE.md) — bit-identical to the scalar single-thread
    /// interpreter at any width. The two state buffers move into the call
    /// and back without copies (tokens are cloned — `decode_batch` i32s,
    /// and keeping them intact preserves the frame-restore contract on
    /// error); per step the host traffic is the state round-trip
    /// DESIGN.md §9 budgets.
    pub fn decode_step(&self, frame: &mut DecodeFrame) -> Result<Vec<f32>> {
        ensure!(
            frame.tokens.len() == self.decode_batch,
            "decode frame has {} token lanes, engine expects {}",
            frame.tokens.len(),
            self.decode_batch
        );
        // Seam before the state buffers move out of the frame: an injected
        // decode fault leaves the frame untouched, same as a real error
        // after the restore below.
        self.check_failure_seam("decode", &self.seam_decode_calls, true)?;
        let tok = HostTensor::i32(vec![self.decode_batch], frame.tokens.clone());
        let conv_in = HostTensor::f32(self.conv_shape.clone(), std::mem::take(&mut frame.conv));
        let ssm_in = HostTensor::f32(self.ssm_shape.clone(), std::mem::take(&mut frame.ssm));
        let inputs = [tok, conv_in, ssm_in];
        match self.run_decode(&inputs) {
            Ok((logits, conv, ssm)) => {
                frame.conv = conv;
                frame.ssm = ssm;
                Ok(logits)
            }
            Err(e) => {
                let [_, conv_in, ssm_in] = inputs;
                frame.conv = into_f32(conv_in)?;
                frame.ssm = into_f32(ssm_in)?;
                Err(e)
            }
        }
    }

    /// Execute + validate one decode call; returns owned (logits, conv, ssm).
    fn run_decode(&self, inputs: &[HostTensor; 3]) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let mut outs = self.decode.execute(&self.weights().dev, inputs).context("decode step")?;
        // ORDERING: Relaxed — stats-only call tally.
        self.decode_calls.fetch_add(1, Ordering::Relaxed);
        ensure!(outs.len() == 3, "decode must return (logits, conv, ssm)");
        let ssm_t = outs.pop().unwrap();
        let conv_t = outs.pop().unwrap();
        let logits_t = outs.pop().unwrap();
        ensure!(
            conv_t.shape == self.conv_shape,
            "decode conv state shape {:?} != {:?}",
            conv_t.shape,
            self.conv_shape
        );
        ensure!(ssm_t.shape == self.ssm_shape, "decode ssm state shape mismatch");
        Ok((into_f32(logits_t)?, into_f32(conv_t)?, into_f32(ssm_t)?))
    }

    /// The largest request batch the lock-step `serve_batch` path accepts:
    /// bounded by both the static prefill frame and the decode frame.
    pub fn max_batch(&self) -> usize {
        self.batch.min(self.decode_batch)
    }

    /// Lock-step baseline: serve one batch of requests (padded internally to
    /// the static frames), decoding every lane for `max(gen_tokens)` steps.
    /// Returns one Response per request, in order. Kept as the comparison
    /// path for the continuous scheduler (same phases, so identical tokens).
    pub fn serve_batch(&self, reqs: &[Request]) -> Result<Vec<Response>> {
        ensure!(!reqs.is_empty(), "empty batch");
        ensure!(reqs.len() <= self.batch, "batch overflow: {} > {}", reqs.len(), self.batch);
        ensure!(
            reqs.len() <= self.decode_batch,
            "decode frame overflow: {} > {}",
            reqs.len(),
            self.decode_batch
        );
        let (seqs, prefill_us) = self.prefill(reqs)?;

        let t_dec = Instant::now();
        let mut frame = self.new_frame();
        let mut logits = vec![0.0f32; self.decode_batch * self.vocab];
        for (i, s) in seqs.iter().enumerate() {
            write_lane(&mut frame.conv, self.n_layer, self.decode_batch, self.conv_row, i, &s.conv);
            write_lane(&mut frame.ssm, self.n_layer, self.decode_batch, self.ssm_row, i, &s.ssm);
            logits[i * self.vocab..(i + 1) * self.vocab].copy_from_slice(&s.logits);
        }
        let gen_tokens = reqs.iter().map(|r| r.gen_tokens).max().unwrap_or(0);
        let mut generated: Vec<Vec<i32>> = vec![Vec::new(); reqs.len()];
        for step in 0..gen_tokens {
            // Greedy-sample every lane from the last logits, then step the
            // whole frame once — even lanes that already finished (that is
            // the lock-step waste the scheduler eliminates).
            for (i, g) in generated.iter_mut().enumerate() {
                let next = argmax(&logits[i * self.vocab..(i + 1) * self.vocab]) as i32;
                if g.len() < reqs[i].gen_tokens {
                    g.push(next);
                }
                frame.tokens[i] = next;
            }
            // The final iteration only samples; its decode output would
            // never be consumed, so skip it (a batch needs max(gen)-1
            // decode executions, matching the continuous path's per-request
            // gen-1 count).
            if step + 1 < gen_tokens {
                logits = self.decode_step(&mut frame)?;
            }
        }
        let decode_us = t_dec.elapsed().as_micros() as u64;

        Ok(reqs
            .iter()
            .zip(generated)
            .map(|(r, g)| Response {
                id: r.id,
                generated: g,
                prompt_tokens: r.prompt.len(),
                prefill_us,
                decode_us,
                queue_us: 0,
                variant: self.variant.clone(),
            })
            .collect())
    }
}

fn into_f32(t: HostTensor) -> Result<Vec<f32>> {
    match t.data {
        TensorData::F32(v) => Ok(v),
        TensorData::I32(_) => bail!("expected an f32 tensor"),
    }
}

/// Greedy sampling: index of the maximum logit. First occurrence wins —
/// every serving path uses this same tie-break so outputs stay comparable.
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best
}

/// Parse a serving-lane variant: `"dense"` (→ `None`) or
/// `<policy>@<ratio>[:<metric>]` (DESIGN.md §10). Policy names, the (0, 1)
/// ratio range, and metric applicability are all validated here — a bad
/// variant fails before any engine is built or request queued, not at
/// manifest-lookup time. Thin façade over [`PolicySpec::parse`].
pub fn parse_variant(variant: &str) -> Result<Option<PolicySpec>> {
    PolicySpec::parse(variant)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_parse() {
        use crate::reduction::policy::PolicyKind;
        assert!(parse_variant("dense").unwrap().is_none());
        assert!(parse_variant("").unwrap().is_none());
        let p = parse_variant("utrc@0.2").unwrap().unwrap();
        assert_eq!((p.kind, p.ratio), (PolicyKind::Unified, 0.2));
        // The full policy family parses, including metric suffixes.
        for good in ["prune@0.2", "prune@0.2:l1", "merge@0.3", "unified@0.1:clip", "random@0.4"] {
            assert!(parse_variant(good).unwrap().is_some(), "{good} rejected");
        }
        // Unknown policies and misplaced metrics fail at parse time — before
        // any engine is built or request queued.
        for bad in ["nope", "bogus@0.2", "merge@0.2:l2", "random@0.2:clip", "prune@0.2:l9"] {
            assert!(parse_variant(bad).is_err(), "{bad} accepted");
        }
    }

    #[test]
    fn variant_ratio_must_be_in_unit_interval() {
        let bad = ["utrc@-0.5", "utrc@0", "utrc@1", "utrc@7", "utrc@NaN", "utrc@inf", "utrc@-inf"];
        for b in bad {
            let err = parse_variant(b).unwrap_err();
            let msg = format!("{err:#}");
            assert!(msg.contains("(0, 1)"), "{b}: expected a ratio-range error, got {msg}");
        }
        assert!(parse_variant("utrc@abc").is_err());
        assert!(parse_variant("@0.2").is_err(), "empty method accepted");
        // boundary-adjacent values are fine
        assert!(parse_variant("utrc@0.01").is_ok());
        assert!(parse_variant("utrc@0.99").is_ok());
    }

    #[test]
    fn argmax_first_max_wins() {
        assert_eq!(argmax(&[0.0, 3.0, 3.0, 1.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
        assert_eq!(argmax(&[-2.0, -1.0, -3.0]), 1);
    }
}
