//! L3 serving coordinator: the vLLM-router analogue for reduced-token Mamba
//! serving.
//!
//! Pieces:
//! * [`batcher`] — dynamic batching of incoming generation requests into the
//!   executables' static batch shape (size-or-deadline policy); used by the
//!   lock-step comparison path.
//! * [`state_pool`] — slot manager for per-sequence SSM decode states (the
//!   KV-cache analogue: conv tail + scan state per layer, fixed size).
//! * [`state_store`] — the pool's slots backed by the actual per-sequence
//!   conv/ssm tensors, with gather/scatter into the decode frame.
//! * [`prefix_cache`] — content-addressed cache of chunk-aligned prompt
//!   *prefix* states: shared system prompts prefill once, later requests
//!   resume from the cached constant-size (conv, ssm) snapshot
//!   (DESIGN.md §12).
//! * [`router`] — routes requests across model variants (dense vs reduction
//!   ratios) by policy: explicit variant, or load-aware least-queued.
//! * [`engine`] — one model variant's execution lane, split into
//!   `prefill` / `decode_step` phases (plus the lock-step `serve_batch`
//!   baseline built on them).
//! * [`scheduler`] — the continuous-batching serve loop: iteration-level
//!   admission into decode-frame lanes, immediate retirement (DESIGN.md §6).
//! * [`metrics`] — counters + latency recorder shared by the serve loop.
//! * [`http`] — the zero-dependency HTTP/1.1 front-end that puts the
//!   scheduler behind a real socket, with per-token streaming over chunked
//!   transfer encoding (DESIGN.md §14).
//! * [`replica`] — N engine replicas of one lane behind pluggable
//!   placement (least-loaded / prefix-affine rendezvous hash), per-replica
//!   Up/Draining/Down health with heartbeat-driven failover, and the
//!   rolling hot-upgrade state machine (DESIGN.md §15).

pub mod batcher;
pub mod engine;
pub mod http;
pub mod metrics;
pub mod prefix_cache;
pub mod replica;
pub mod router;
pub mod scheduler;
pub mod state_pool;
pub mod state_store;

/// Scheduling priority class of a [`Request`] (DESIGN.md §12).
///
/// Priorities order lane *placement*, not admission: the queue stays FIFO
/// (arrival order), but once prefilled, a higher class is placed into a
/// decode lane first, and under lane pressure the scheduler **preempts** a
/// strictly lower-priority resident sequence — its fixed-size (conv, ssm)
/// state stays parked in its state-store slot and it resumes bit-identically
/// when a lane frees. Equal priorities never preempt each other, so an
/// all-[`Priority::Normal`] trace behaves exactly like the pre-priority
/// scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub enum Priority {
    /// Preemptible background work (batch eval, speculative traffic).
    Low,
    /// The default class; never preempted by other `Normal` traffic.
    #[default]
    Normal,
    /// Latency-sensitive traffic; may preempt `Low` and `Normal` residents.
    High,
}

/// A generation request entering the system.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Prompt token ids, any non-zero length. Length-aware engines compute
    /// the prompt at its true length (chunking prompts longer than the
    /// prefill frame — never truncating); legacy AOT engines right-pad to
    /// the frame and refuse over-long prompts (DESIGN.md §6).
    pub prompt: Vec<i32>,
    /// Number of tokens to generate.
    pub gen_tokens: usize,
    /// Requested variant key — `"dense"` or a reduction-policy variant
    /// `<policy>@<ratio>[:<metric>]` such as `"unified@0.2"` or
    /// `"prune@0.3:l1"` (DESIGN.md §10) — or empty for router choice.
    pub variant: String,
    /// Caller-side arrival timestamp (µs since the caller's epoch) — carried
    /// as trace metadata only. Serving queue latency is measured by the
    /// scheduler itself, from [`scheduler::Scheduler::submit`].
    pub arrived_us: u64,
    /// Scheduling class (DESIGN.md §12): placement order under lane
    /// pressure, and whether this request may preempt / be preempted.
    pub priority: Priority,
}

/// A completed generation.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub generated: Vec<i32>,
    /// Prompt length as submitted (pre-padding), for throughput accounting.
    pub prompt_tokens: usize,
    pub prefill_us: u64,
    pub decode_us: u64,
    pub queue_us: u64,
    pub variant: String,
}
