//! L3 serving coordinator: the vLLM-router analogue for reduced-token Mamba
//! serving.
//!
//! Pieces:
//! * [`batcher`] — dynamic batching of incoming generation requests into the
//!   executables' static batch shape (size-or-deadline policy).
//! * [`state_pool`] — slot manager for per-sequence SSM decode states (the
//!   KV-cache analogue: conv tail + scan state per layer, fixed size).
//! * [`router`] — routes requests across model variants (dense vs reduction
//!   ratios) by policy: explicit variant, or load-aware least-queued.
//! * [`engine`] — one model variant's execution lane: prefill → decode loop,
//!   weights device-resident, everything else streaming.
//! * [`metrics`] — counters + latency recorder shared by the serve loop.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod router;
pub mod state_pool;

/// A generation request entering the system.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Prompt token ids (will be right-padded/truncated to the prefill frame).
    pub prompt: Vec<i32>,
    /// Number of tokens to generate.
    pub gen_tokens: usize,
    /// Requested variant key ("dense", "utrc@0.2", ...), or empty for router
    /// choice.
    pub variant: String,
    pub arrived_us: u64,
}

/// A completed generation.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub generated: Vec<i32>,
    pub prefill_us: u64,
    pub decode_us: u64,
    pub queue_us: u64,
    pub variant: String,
}
