//! Dynamic batcher: collects requests into the executable's static batch
//! size under a size-or-deadline policy (classic serving batcher, cf. Orca).
//!
//! Invariants (property-tested in rust/tests/prop_coordinator.rs):
//! * a batch never exceeds `batch_size`;
//! * requests leave in arrival order within a variant (FIFO);
//! * no request is dropped or duplicated;
//! * a non-empty queue is flushed no later than `max_wait`.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use super::Request;

#[derive(Debug)]
pub struct Batcher {
    pub batch_size: usize,
    pub max_wait: Duration,
    queue: VecDeque<Request>,
    oldest: Option<Instant>,
    pub enqueued: u64,
    pub dispatched: u64,
}

impl Batcher {
    pub fn new(batch_size: usize, max_wait: Duration) -> Batcher {
        assert!(batch_size > 0);
        Batcher {
            batch_size,
            max_wait,
            queue: VecDeque::new(),
            oldest: None,
            enqueued: 0,
            dispatched: 0,
        }
    }

    pub fn push(&mut self, r: Request) {
        if self.queue.is_empty() {
            self.oldest = Some(Instant::now());
        }
        self.queue.push_back(r);
        self.enqueued += 1;
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Non-blocking poll: returns a full batch immediately, or a partial
    /// batch once the oldest request has waited `max_wait`, else None.
    pub fn poll(&mut self, now: Instant) -> Option<Vec<Request>> {
        if self.queue.len() >= self.batch_size {
            return Some(self.take(self.batch_size));
        }
        match self.oldest {
            Some(t0) if !self.queue.is_empty() && now.duration_since(t0) >= self.max_wait => {
                Some(self.take(self.queue.len()))
            }
            _ => None,
        }
    }

    /// Forced flush (shutdown/drain).
    pub fn drain(&mut self) -> Option<Vec<Request>> {
        if self.queue.is_empty() {
            None
        } else {
            Some(self.take(self.queue.len().min(self.batch_size)))
        }
    }

    fn take(&mut self, n: usize) -> Vec<Request> {
        let out: Vec<Request> = self.queue.drain(..n).collect();
        self.dispatched += out.len() as u64;
        self.oldest = if self.queue.is_empty() { None } else { Some(Instant::now()) };
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request { id, prompt: vec![1], gen_tokens: 1, variant: String::new(), arrived_us: 0 }
    }

    #[test]
    fn full_batch_dispatches_immediately() {
        let mut b = Batcher::new(4, Duration::from_secs(10));
        for i in 0..4 {
            b.push(req(i));
        }
        let batch = b.poll(Instant::now()).unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn partial_waits_for_deadline() {
        let mut b = Batcher::new(4, Duration::from_millis(50));
        b.push(req(0));
        assert!(b.poll(Instant::now()).is_none());
        let later = Instant::now() + Duration::from_millis(60);
        let batch = b.poll(later).unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn overfull_queue_leaves_remainder() {
        let mut b = Batcher::new(2, Duration::from_secs(10));
        for i in 0..5 {
            b.push(req(i));
        }
        assert_eq!(b.poll(Instant::now()).unwrap().len(), 2);
        assert_eq!(b.len(), 3);
        assert_eq!(b.poll(Instant::now()).unwrap().len(), 2);
        assert_eq!(b.len(), 1);
        assert!(b.poll(Instant::now()).is_none()); // partial, not yet due
    }

    #[test]
    fn drain_flushes() {
        let mut b = Batcher::new(8, Duration::from_secs(10));
        b.push(req(0));
        b.push(req(1));
        assert_eq!(b.drain().unwrap().len(), 2);
        assert!(b.drain().is_none());
    }
}
