//! Dynamic batcher: collects requests into the executable's static batch
//! size under a size-or-deadline policy (classic serving batcher, cf. Orca).
//! The continuous scheduler admits directly; this feeds the lock-step path.
//!
//! Invariants (property-tested in rust/tests/prop_coordinator.rs, DESIGN.md
//! §7):
//! * a batch never exceeds `batch_size`;
//! * requests leave in arrival order within a variant (FIFO);
//! * no request is dropped or duplicated;
//! * a non-empty queue is flushed no later than `max_wait` after its oldest
//!   request **arrived** — dispatching a full batch must not restart the
//!   clock for requests left behind (each entry keeps its own enqueue time);
//! * a shutdown [`Batcher::drain`] empties the whole queue — an over-full
//!   queue leaves as several capacity-bounded batches, never stranding the
//!   remainder behind the first one.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use super::Request;

#[derive(Debug)]
pub struct Batcher {
    pub batch_size: usize,
    pub max_wait: Duration,
    /// FIFO of (request, enqueue time): the front entry is always the
    /// oldest, so the deadline check is just a peek.
    queue: VecDeque<(Request, Instant)>,
    pub enqueued: u64,
    pub dispatched: u64,
}

impl Batcher {
    pub fn new(batch_size: usize, max_wait: Duration) -> Batcher {
        assert!(batch_size > 0);
        Batcher {
            batch_size,
            max_wait,
            queue: VecDeque::new(),
            enqueued: 0,
            dispatched: 0,
        }
    }

    pub fn push(&mut self, r: Request) {
        self.push_at(r, Instant::now());
    }

    /// Enqueue with an explicit arrival time (deterministic tests).
    fn push_at(&mut self, r: Request, at: Instant) {
        self.queue.push_back((r, at));
        self.enqueued += 1;
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Non-blocking poll: returns a full batch immediately, or a partial
    /// batch once the oldest queued request has waited `max_wait`, else
    /// None.
    pub fn poll(&mut self, now: Instant) -> Option<Vec<Request>> {
        if self.queue.len() >= self.batch_size {
            return Some(self.take(self.batch_size));
        }
        match self.queue.front() {
            Some((_, t0)) if now.duration_since(*t0) >= self.max_wait => {
                Some(self.take(self.queue.len()))
            }
            _ => None,
        }
    }

    /// Forced flush (shutdown/drain): empty the **whole** queue as a
    /// sequence of `batch_size`-bounded batches, FIFO, the last possibly
    /// partial. Returns an empty vec on an empty queue.
    ///
    /// Regression note: this used to emit at most one batch
    /// (`take(len.min(batch_size))`), so a shutdown drain of an over-full
    /// queue stranded everything behind the first `batch_size` requests
    /// unless the caller happened to loop.
    pub fn drain(&mut self) -> Vec<Vec<Request>> {
        let mut out = Vec::new();
        while !self.queue.is_empty() {
            out.push(self.take(self.queue.len().min(self.batch_size)));
        }
        out
    }

    fn take(&mut self, n: usize) -> Vec<Request> {
        let out: Vec<Request> = self.queue.drain(..n).map(|(r, _)| r).collect();
        self.dispatched += out.len() as u64;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Request {
        Request {
            id,
            prompt: vec![1],
            gen_tokens: 1,
            variant: String::new(),
            arrived_us: 0,
            priority: Default::default(),
        }
    }

    #[test]
    fn full_batch_dispatches_immediately() {
        let mut b = Batcher::new(4, Duration::from_secs(10));
        for i in 0..4 {
            b.push(req(i));
        }
        let batch = b.poll(Instant::now()).unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn partial_waits_for_deadline() {
        let mut b = Batcher::new(4, Duration::from_millis(50));
        b.push(req(0));
        assert!(b.poll(Instant::now()).is_none());
        let later = Instant::now() + Duration::from_millis(60);
        let batch = b.poll(later).unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn overfull_queue_leaves_remainder() {
        let mut b = Batcher::new(2, Duration::from_secs(10));
        for i in 0..5 {
            b.push(req(i));
        }
        assert_eq!(b.poll(Instant::now()).unwrap().len(), 2);
        assert_eq!(b.len(), 3);
        assert_eq!(b.poll(Instant::now()).unwrap().len(), 2);
        assert_eq!(b.len(), 1);
        assert!(b.poll(Instant::now()).is_none()); // partial, not yet due
    }

    #[test]
    fn drain_flushes() {
        let mut b = Batcher::new(8, Duration::from_secs(10));
        b.push(req(0));
        b.push(req(1));
        let batches = b.drain();
        assert_eq!(batches.len(), 1);
        assert_eq!(batches[0].len(), 2);
        assert!(b.drain().is_empty());
    }

    /// Regression: drain used to flush at most ONE batch, stranding the
    /// remainder of an over-full queue at shutdown. With 2×batch_size+1
    /// queued, every request must leave, FIFO, in capacity-bounded batches.
    #[test]
    fn drain_empties_overfull_queue() {
        let cap = 4usize;
        let mut b = Batcher::new(cap, Duration::from_secs(10));
        let n = 2 * cap as u64 + 1;
        for i in 0..n {
            b.push(req(i));
        }
        let batches = b.drain();
        assert_eq!(
            batches.iter().map(|x| x.len()).collect::<Vec<_>>(),
            vec![cap, cap, 1],
            "drain must empty the whole queue in capacity-bounded batches"
        );
        let ids: Vec<u64> = batches.concat().iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..n).collect::<Vec<_>>(), "FIFO across drained batches");
        assert!(b.is_empty());
        assert_eq!(b.dispatched, n);
    }

    /// Regression: dispatching a full batch used to reset the wait timer
    /// for the requests left in the queue (`oldest = Instant::now()`),
    /// silently re-starting the deadline for requests that had already
    /// waited. The remainder must flush `max_wait` after its own arrival.
    #[test]
    fn remainder_keeps_original_deadline() {
        let wait = Duration::from_millis(50);
        let mut b = Batcher::new(2, wait);
        // All three arrived 10ms ago; a full batch leaves one behind.
        let t0 = Instant::now() - Duration::from_millis(10);
        for i in 0..3 {
            b.push_at(req(i), t0);
        }
        assert_eq!(b.poll(t0 + Duration::from_millis(10)).unwrap().len(), 2);
        // Just before t0 + max_wait: not due yet.
        assert!(b.poll(t0 + wait - Duration::from_millis(1)).is_none());
        // At t0 + max_wait the leftover must flush, measured from its TRUE
        // arrival t0 — the buggy reset would have pushed the deadline past
        // the dispatch time instead.
        let batch = b.poll(t0 + wait).expect("remainder flush missed");
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].id, 2);
        assert!(b.is_empty());
    }

    /// The deadline always tracks the oldest *remaining* request even when
    /// arrivals are staggered.
    #[test]
    fn staggered_arrivals_flush_on_oldest() {
        let wait = Duration::from_millis(50);
        let mut b = Batcher::new(8, wait);
        let t0 = Instant::now();
        b.push_at(req(0), t0);
        b.push_at(req(1), t0 + Duration::from_millis(30));
        // Oldest is req 0 (arrived t0): due at t0+50 even though req 1 has
        // only waited 20ms by then.
        let batch = b.poll(t0 + wait).expect("deadline flush missed");
        assert_eq!(batch.len(), 2);
        // After the flush the queue is empty; nothing more is due.
        assert!(b.poll(t0 + Duration::from_secs(10)).is_none());
    }
}
