//! Slot-backed SSM state store: [`StatePool`] slots bound to the actual
//! per-sequence decode tensors (conv tail + scan state), so admission into
//! the continuous-batching scheduler is slot allocation plus two memcpys —
//! the Mamba analogue of vLLM's KV-cache block table, minus the paging
//! (DESIGN.md §6).
//!
//! Layouts:
//! * stored per sequence: conv `[n_layer, conv_row]`, ssm `[n_layer,
//!   ssm_row]`, both contiguous (`conv_row`/`ssm_row` are the per-layer
//!   per-sequence element counts of the model's decode-state shapes, see
//!   [`crate::runtime::decode_state_shapes`]);
//! * the decode frame the engine steps: `[n_layer, n_lanes, row]`,
//!   layer-major. [`StateStore::gather`] / [`StateStore::scatter`] convert
//!   between the two via the lane helpers in [`crate::runtime::tensor`].
//!
//! Preemption (DESIGN.md §12) needs no store support beyond this: the
//! scheduler scatters every lane's state back after each decode step, so a
//! preempted sequence's snapshot is already parked in its slot. Swapping it
//! out is just dropping the lane binding; swapping back in is the same
//! gather any placement does — bit-identical to never having been paused.

use anyhow::{ensure, Result};

use crate::runtime::tensor::{read_lane, write_lane, zero_lane};

use super::state_pool::{slot_bytes_raw, Slot, StatePool};

#[derive(Debug)]
pub struct StateStore {
    pool: StatePool,
    n_layer: usize,
    conv_row: usize,
    ssm_row: usize,
    /// `capacity × n_layer × conv_row`, slot-major.
    conv: Vec<f32>,
    /// `capacity × n_layer × ssm_row`, slot-major.
    ssm: Vec<f32>,
}

impl StateStore {
    pub fn new(capacity: usize, n_layer: usize, conv_row: usize, ssm_row: usize) -> StateStore {
        StateStore {
            pool: StatePool::new(capacity, slot_bytes_raw(n_layer, conv_row, ssm_row)),
            n_layer,
            conv_row,
            ssm_row,
            conv: vec![0.0; capacity * n_layer * conv_row],
            ssm: vec![0.0; capacity * n_layer * ssm_row],
        }
    }

    pub fn capacity(&self) -> usize {
        self.pool.capacity()
    }

    pub fn live(&self) -> usize {
        self.pool.live()
    }

    pub fn free_slots(&self) -> usize {
        self.pool.free_slots()
    }

    pub fn high_water(&self) -> usize {
        self.pool.high_water
    }

    pub fn live_bytes(&self) -> usize {
        self.pool.live_bytes()
    }

    pub fn peak_bytes(&self) -> usize {
        self.pool.peak_bytes()
    }

    fn conv_range(&self, slot: Slot) -> std::ops::Range<usize> {
        let per = self.n_layer * self.conv_row;
        slot.0 * per..(slot.0 + 1) * per
    }

    fn ssm_range(&self, slot: Slot) -> std::ops::Range<usize> {
        let per = self.n_layer * self.ssm_row;
        slot.0 * per..(slot.0 + 1) * per
    }

    /// Allocate a slot and copy one prefilled sequence's decode state into
    /// it. Fails (without copying) when the pool is exhausted.
    pub fn admit(&mut self, conv: &[f32], ssm: &[f32]) -> Result<Slot> {
        ensure!(
            conv.len() == self.n_layer * self.conv_row,
            "conv state has {} elems, store expects {}",
            conv.len(),
            self.n_layer * self.conv_row
        );
        ensure!(
            ssm.len() == self.n_layer * self.ssm_row,
            "ssm state has {} elems, store expects {}",
            ssm.len(),
            self.n_layer * self.ssm_row
        );
        let slot = self.pool.alloc()?;
        self.conv[self.conv_range(slot)].copy_from_slice(conv);
        self.ssm[self.ssm_range(slot)].copy_from_slice(ssm);
        Ok(slot)
    }

    /// Release a finished sequence's slot (double-free rejected).
    pub fn retire(&mut self, slot: Slot) -> Result<()> {
        self.pool.release(slot)
    }

    /// Gather the mapped lanes' slot states into the decode-frame buffers
    /// (`[n_layer, lanes.len(), row]`); unmapped lanes are zeroed.
    pub fn gather(&self, lanes: &[Option<Slot>], conv_frame: &mut [f32], ssm_frame: &mut [f32]) {
        let b = lanes.len();
        for (lane, slot) in lanes.iter().enumerate() {
            match slot {
                Some(s) => {
                    write_lane(
                        conv_frame,
                        self.n_layer,
                        b,
                        self.conv_row,
                        lane,
                        &self.conv[self.conv_range(*s)],
                    );
                    write_lane(
                        ssm_frame,
                        self.n_layer,
                        b,
                        self.ssm_row,
                        lane,
                        &self.ssm[self.ssm_range(*s)],
                    );
                }
                None => {
                    zero_lane(conv_frame, self.n_layer, b, self.conv_row, lane);
                    zero_lane(ssm_frame, self.n_layer, b, self.ssm_row, lane);
                }
            }
        }
    }

    /// Scatter the stepped decode-frame lanes back into their slots; lanes
    /// without a slot are ignored.
    pub fn scatter(&mut self, lanes: &[Option<Slot>], conv_frame: &[f32], ssm_frame: &[f32]) {
        let b = lanes.len();
        for (lane, slot) in lanes.iter().enumerate() {
            if let Some(s) = slot {
                let cr = self.conv_range(*s);
                read_lane(conv_frame, self.n_layer, b, self.conv_row, lane, &mut self.conv[cr]);
                let sr = self.ssm_range(*s);
                read_lane(ssm_frame, self.n_layer, b, self.ssm_row, lane, &mut self.ssm[sr]);
            }
        }
    }

    /// Read one slot's stored (conv, ssm) state — inspection/test aid.
    pub fn state_of(&self, slot: Slot) -> (&[f32], &[f32]) {
        (&self.conv[self.conv_range(slot)], &self.ssm[self.ssm_range(slot)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> StateStore {
        // 3 slots, 2 layers, conv_row 3, ssm_row 2.
        StateStore::new(3, 2, 3, 2)
    }

    #[test]
    fn admit_retire_recycles_without_corruption() {
        let mut st = store();
        let a = st.admit(&[1.0; 6], &[10.0; 4]).unwrap();
        let b = st.admit(&[2.0; 6], &[20.0; 4]).unwrap();
        st.retire(a).unwrap();
        let c = st.admit(&[3.0; 6], &[30.0; 4]).unwrap();
        // b untouched by the recycle of a's slot into c.
        assert!(st.state_of(b).0.iter().all(|&x| x == 2.0));
        assert!(st.state_of(c).1.iter().all(|&x| x == 30.0));
        assert!(st.retire(a).is_err(), "double free accepted");
        assert_eq!(st.live(), 2);
    }

    #[test]
    fn gather_scatter_roundtrip_with_holes() {
        let mut st = store();
        let a = st.admit(&[1.0; 6], &[10.0; 4]).unwrap();
        let b = st.admit(&[2.0; 6], &[20.0; 4]).unwrap();
        let lanes = [Some(a), None, Some(b)];
        let mut conv = vec![7.0f32; 2 * 3 * 3]; // [nl=2, lanes=3, row=3], stale
        let mut ssm = vec![7.0f32; 2 * 3 * 2];
        st.gather(&lanes, &mut conv, &mut ssm);
        // lane 1 zeroed, lanes 0/2 hold the stored states.
        assert_eq!(&conv[0..3], &[1.0; 3]);
        assert_eq!(&conv[3..6], &[0.0; 3]);
        assert_eq!(&conv[6..9], &[2.0; 3]);
        // mutate the frame as a decode step would, scatter back.
        for v in conv.iter_mut() {
            *v += 0.5;
        }
        for v in ssm.iter_mut() {
            *v -= 1.0;
        }
        st.scatter(&lanes, &conv, &ssm);
        assert!(st.state_of(a).0.iter().all(|&x| x == 1.5));
        assert!(st.state_of(b).0.iter().all(|&x| x == 2.5));
        assert!(st.state_of(a).1.iter().all(|&x| x == 9.0));
        assert!(st.state_of(b).1.iter().all(|&x| x == 19.0));
    }

    #[test]
    fn capacity_and_accounting() {
        let mut st = store();
        for _ in 0..3 {
            st.admit(&[0.0; 6], &[0.0; 4]).unwrap();
        }
        assert!(st.admit(&[0.0; 6], &[0.0; 4]).is_err());
        assert_eq!(st.free_slots(), 0);
        assert_eq!(st.high_water(), 3);
        // (2 layers × (3 + 2) rows) × 4 bytes per slot
        assert_eq!(st.live_bytes(), 3 * 2 * 5 * 4);
    }
}
