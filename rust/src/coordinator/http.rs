//! Zero-dependency HTTP/1.1 serving front-end (DESIGN.md §14).
//!
//! `repro serve --listen <addr>` puts the continuous-batching
//! [`Scheduler`] behind a real socket: pure `std::net`, one short-lived
//! connection per request (`Connection: close`), JSON request/response via
//! [`util::json`](crate::util::json) with [`LazyDoc`] lazy field
//! extraction on the hot path, and per-token streaming over chunked
//! transfer encoding with one SSE-style `data:` line per token.
//!
//! Architecture (all threads scoped — [`serve`] returns only after every
//! one of them has exited):
//!
//! * the **caller's thread** runs the scheduler loop: drains the admission
//!   queue into per-lane [`ReplicaPool`]s (installing a [`TokenSink`] per
//!   request that forwards tokens over an mpsc channel), steps every
//!   pool, and publishes completions/failures back to the waiting
//!   connection handlers. [`serve_pooled`] puts `replicas` engines behind
//!   each lane (DESIGN.md §15) — placement is bit-invisible (greedy
//!   argmax, frame-independent sequences), a replica whose step fails is
//!   failed over (queued work re-routed, mid-stream work failed typed as
//!   `500`s) and revived clean, exactly like the pre-pool per-lane
//!   scheduler restart; [`serve`] is the `replicas = 1` special case;
//! * an **acceptor thread** polls the (nonblocking) listener and spawns
//!   one handler thread per connection;
//! * **handler threads** parse + validate one request each, admit it
//!   through the bounded admission queue, then relay events from the
//!   scheduler loop onto the socket (streamed or buffered).
//!
//! Backpressure is a hard bound: admission is guarded by an atomic
//! `pending` count vs [`HttpConfig::queue_cap`] — when full the handler
//! answers `429 Too Many Requests` + `Retry-After` *before* buffering
//! anything, so memory is bounded by admitted work only. Graceful drain
//! (SIGTERM/SIGINT via the caller's shutdown flag) is a two-flag state
//! machine: `draining` stops admission (new work → `503` +
//! `Retry-After`) while every already-admitted sequence runs to
//! completion — its full token stream is delivered before its socket
//! closes — then `drained` releases the acceptor and [`serve`] returns.
//!
//! Error mapping (the typed [`RouteError`] from PR 3 carries the
//! malformed-vs-unserved distinction): malformed JSON / bad fields /
//! empty prompt (PR 5 contract) / malformed variant → `400`; well-formed
//! variant no lane serves → `404`; missing `Content-Length` → `411`;
//! oversized header block → `431`; oversized body → `413`; read timeout
//! (slowloris) → `408`; queue full → `429`; draining → `503`.
//!
//! `GET /stats` composes a [`SeqCounters`] seqlock-consistent counter
//! block at request time (so `admitted == completed + failed + in_flight`
//! holds in **every** response, even mid-burst — DESIGN.md §15 bugfix)
//! with the per-lane/per-replica detail document the scheduler loop
//! renders periodically.

use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::util::json::{num, obj, s, Json, LazyDoc};

use super::engine::Engine;
use super::metrics::Metrics;
use super::prefix_cache::CacheStats;
use super::replica::{Health, Placement, ReplicaPool};
use super::router::{Policy, RouteError, Router};
use super::scheduler::TokenSink;
use super::{Priority, Request, Response};

/// Lock `m`, recovering the data on poisoning. Serving threads are
/// panic-free by construction (the `panic-serving` lint, DESIGN.md §16),
/// so a poisoned mutex means some foreign thread unwound mid-section; the
/// critical sections in this module keep their guarded structures
/// consistent at every step, so continuing with the inner value is sound —
/// and a handler must never die over observability state.
fn lock_mx<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Serving knobs. Defaults are sized for loopback testing and small
/// deployments; every limit exists to keep untrusted input bounded.
#[derive(Debug, Clone)]
pub struct HttpConfig {
    /// Admission bound: requests admitted but not yet completed. Beyond
    /// it, new work gets `429` + `Retry-After` (never unbounded buffering).
    pub queue_cap: usize,
    /// Socket read timeout — a slowloris client dribbling its request
    /// gets `408` when the next read stalls this long.
    pub read_timeout: Duration,
    /// Handler-side bound on waiting for the scheduler to finish an
    /// admitted request (a liveness backstop, not a latency target).
    pub completion_timeout: Duration,
    /// Maximum request-head (request line + headers) bytes → `431`.
    pub max_header_bytes: usize,
    /// Maximum request-body bytes → `413`.
    pub max_body_bytes: usize,
    /// `max_tokens` must be in `1..=max_gen_tokens`.
    pub max_gen_tokens: usize,
    /// Prompt-length cap on length-aware lanes (chunked prefill makes any
    /// length *possible*; this keeps one request from monopolising the
    /// server). Non-length-aware lanes are additionally capped at their
    /// prefill frame, per the engine's no-truncation contract.
    pub max_prompt_tokens: usize,
    /// Value of the `Retry-After` header on 429/503 responses, seconds.
    pub retry_after_s: u64,
    /// `max_tokens` when the request omits it.
    pub default_gen_tokens: usize,
}

impl Default for HttpConfig {
    fn default() -> HttpConfig {
        HttpConfig {
            queue_cap: 64,
            read_timeout: Duration::from_secs(2),
            completion_timeout: Duration::from_secs(120),
            max_header_bytes: 8 * 1024,
            max_body_bytes: 1 << 20,
            max_gen_tokens: 256,
            max_prompt_tokens: 1 << 16,
            retry_after_s: 1,
            default_gen_tokens: 16,
        }
    }
}

/// Replica-pool topology for [`serve_pooled`] (DESIGN.md §15): `replicas`
/// engines behind every lane, placed by `placement`. The engines slice is
/// lane-major — all of lane 0's replicas first, then lane 1's, …
#[derive(Debug, Clone, Copy)]
pub struct PoolConfig {
    pub replicas: usize,
    pub placement: Placement,
}

impl Default for PoolConfig {
    fn default() -> PoolConfig {
        PoolConfig { replicas: 1, placement: Placement::LeastLoaded }
    }
}

/// Serving counters with a seqlock-consistent lock-free reader
/// (DESIGN.md §15 bugfix).
///
/// The pre-§15 `/stats` path snapshotted its counters non-atomically:
/// `completed` came from a stats string the scheduler loop re-rendered
/// only every few ticks while the in-flight count was read fresh from an
/// atomic, so a probe during a burst could observe a document where
/// `admitted != completed + failed + in_flight`. Here writers serialise
/// on a mutex and bump `seq` to odd before / back to even after every
/// increment; the reader never blocks — it retries until it reads one
/// even, unchanged `seq` around the whole triple. `in_flight` is
/// *derived* (`admitted - completed - failed`), so the identity holds in
/// every snapshot by construction and the triple is from a single write
/// epoch (`tests/http_serve.rs` hammers this during a burst).
pub struct SeqCounters {
    /// Odd while an update is in progress, even when consistent.
    seq: AtomicU64,
    /// Serialises writers (admission handlers + the scheduler loop).
    write: Mutex<()>,
    admitted: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
}

/// One consistent reading of a [`SeqCounters`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CounterSnapshot {
    pub admitted: u64,
    pub completed: u64,
    pub failed: u64,
}

impl CounterSnapshot {
    /// Requests admitted but not yet completed or failed. Derived, so
    /// `admitted == completed + failed + in_flight` cannot be violated.
    pub fn in_flight(&self) -> u64 {
        self.admitted - self.completed - self.failed
    }
}

impl SeqCounters {
    pub fn new() -> SeqCounters {
        SeqCounters {
            seq: AtomicU64::new(0),
            write: Mutex::new(()),
            admitted: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
        }
    }

    fn bump(&self, which: &AtomicU64) {
        let _writer = lock_mx(&self.write);
        // ORDERING: the seqlock epoch. AcqRel on both bumps: the Release
        // half publishes the counter store between them to any reader that
        // Acquire-loads an even seq; the Acquire half keeps a writer from
        // hoisting its store above the odd transition. Relaxed here would
        // let a torn triple pass snapshot()'s even/unchanged test.
        self.seq.fetch_add(1, Ordering::AcqRel); // odd: update in progress
        which.fetch_add(1, Ordering::Release);
        self.seq.fetch_add(1, Ordering::AcqRel); // ORDERING: even again, see above
    }

    pub fn admit(&self) {
        self.bump(&self.admitted);
    }

    pub fn complete(&self) {
        self.bump(&self.completed);
    }

    pub fn fail(&self) {
        self.bump(&self.failed);
    }

    /// A consistent snapshot: retry until one even `seq` value brackets
    /// all three loads. Writers hold the seq odd only for three atomic
    /// ops, so the retry loop is effectively bounded.
    pub fn snapshot(&self) -> CounterSnapshot {
        loop {
            // ORDERING: Acquire on the seq epoch load pairs with bump()'s
            // AcqRel transitions — an even value here means every counter
            // store from that write epoch is visible below.
            let before = self.seq.load(Ordering::Acquire);
            if before % 2 == 1 {
                std::hint::spin_loop();
                continue;
            }
            // ORDERING: Acquire loads keep the three counter reads from
            // sinking below the seq re-check that validates them.
            let snap = CounterSnapshot {
                admitted: self.admitted.load(Ordering::Acquire),
                completed: self.completed.load(Ordering::Acquire),
                failed: self.failed.load(Ordering::Acquire),
            };
            // ORDERING: Acquire re-load of the seq epoch; equal-and-even
            // brackets the triple inside one write epoch.
            if self.seq.load(Ordering::Acquire) == before {
                return snap;
            }
        }
    }
}

impl Default for SeqCounters {
    fn default() -> SeqCounters {
        SeqCounters::new()
    }
}

/// What [`serve`] hands back after a graceful drain — the socket-side
/// mirror of the in-process serve loops' reporting.
#[derive(Debug, Default)]
pub struct ServeReport {
    /// Completed-generation latency/throughput record (same [`Metrics`]
    /// the in-process paths fill).
    pub metrics: Metrics,
    /// Requests rejected for a full admission queue.
    pub rejected_429: u64,
    /// Requests rejected because the server was draining.
    pub rejected_503: u64,
}

/// Per-lane validation facts the handlers need without touching engines.
struct LaneInfo {
    name: String,
    vocab: usize,
    length_aware: bool,
    prefill_len: usize,
}

/// One admitted request, queued for the scheduler loop.
struct Admitted {
    req: Request,
    lane: usize,
    events: Sender<Event>,
    stream: bool,
}

/// Scheduler-loop → handler messages for one request. Every `Token` for a
/// request is sent before its `Done` (the final token fires inside the
/// same `step` that returns the response).
enum Event {
    Token(i32),
    Done(Response),
    Fail(String),
}

/// Cross-thread state shared by handlers, acceptor, and scheduler loop.
struct Shared {
    router: Mutex<Router>,
    lanes: Vec<LaneInfo>,
    admission: Mutex<VecDeque<Admitted>>,
    /// Admitted-but-not-completed count, CAS-guarded against `queue_cap`.
    pending: AtomicUsize,
    /// Stop admitting; already-admitted work still runs to completion.
    draining: AtomicBool,
    /// Scheduler loop has exited (admission queue finally empty);
    /// acceptor may return.
    drained: AtomicBool,
    next_id: AtomicU64,
    rejected_429: AtomicU64,
    rejected_503: AtomicU64,
    /// Consistent admitted/completed/failed block for `/stats`
    /// (DESIGN.md §15 bugfix) — written at admission (handlers) and
    /// retirement (scheduler loop), read fresh per `/stats` request.
    counters: SeqCounters,
    /// Pre-rendered `GET /stats` lane/replica detail, refreshed by the
    /// scheduler loop; [`stats_body`] splices the counter block in.
    stats: Mutex<String>,
}

/// Serve HTTP until `shutdown` goes true, then drain gracefully and
/// return the run's [`ServeReport`]. Blocks the calling thread (it *is*
/// the scheduler loop); `lanes[i]` names `engines[i]`'s variant. The
/// listener may be bound to port 0 — read `local_addr` before calling.
pub fn serve(
    engines: &[Engine],
    lanes: &[String],
    policy: Policy,
    listener: TcpListener,
    cfg: HttpConfig,
    shutdown: &AtomicBool,
) -> Result<ServeReport> {
    serve_pooled(engines, lanes, policy, PoolConfig::default(), listener, cfg, shutdown)
}

/// [`serve`] with a [`ReplicaPool`] of `pool.replicas` engines behind
/// every lane (DESIGN.md §15). `engines` is lane-major:
/// `engines[li * replicas .. (li + 1) * replicas]` are lane `li`'s
/// replicas (same model + variant — [`ReplicaPool::new`] enforces it).
/// Cross-replica placement is bit-invisible, so any topology produces
/// token streams identical to `replicas = 1` (`tests/replica_pool.rs`).
pub fn serve_pooled(
    engines: &[Engine],
    lanes: &[String],
    policy: Policy,
    pool: PoolConfig,
    listener: TcpListener,
    cfg: HttpConfig,
    shutdown: &AtomicBool,
) -> Result<ServeReport> {
    anyhow::ensure!(pool.replicas >= 1, "pool needs at least one replica per lane");
    anyhow::ensure!(
        !lanes.is_empty() && engines.len() == lanes.len() * pool.replicas,
        "engine count must be lanes x replicas ({} lanes x {} replicas != {} engines; \
         engines are lane-major: all of lane 0's replicas first)",
        lanes.len(),
        pool.replicas,
        engines.len()
    );
    let lane_refs: Vec<&str> = lanes.iter().map(|s| s.as_str()).collect();
    let shared = Shared {
        router: Mutex::new(Router::new(policy, &lane_refs)),
        lanes: engines
            .chunks(pool.replicas)
            .zip(lanes)
            .filter_map(|(chunk, name)| {
                chunk.first().map(|e| LaneInfo {
                    name: name.clone(),
                    vocab: e.vocab(),
                    length_aware: e.length_aware,
                    prefill_len: e.prefill_len,
                })
            })
            .collect(),
        admission: Mutex::new(VecDeque::new()),
        pending: AtomicUsize::new(0),
        draining: AtomicBool::new(false),
        drained: AtomicBool::new(false),
        next_id: AtomicU64::new(1),
        rejected_429: AtomicU64::new(0),
        rejected_503: AtomicU64::new(0),
        counters: SeqCounters::new(),
        stats: Mutex::new("{}".to_string()),
    };
    listener.set_nonblocking(true)?;

    std::thread::scope(|scope| {
        let shared = &shared;
        let cfg = &cfg;
        scope.spawn(move || acceptor(scope, listener, shared, cfg));
        scheduler_loop(engines, shared, pool, cfg, shutdown)
    })
}

/// Poll the nonblocking listener, one handler thread per connection; exit
/// once the scheduler loop has fully drained.
fn acceptor<'scope>(
    scope: &'scope std::thread::Scope<'scope, '_>,
    listener: TcpListener,
    shared: &'scope Shared,
    cfg: &'scope HttpConfig,
) {
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                scope.spawn(move || handle_connection(stream, shared, cfg));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                // ORDERING: Acquire pairs with the scheduler loop's final
                // Release store — seeing `drained` means the drain sweep
                // and final stats render happened-before we return.
                if shared.drained.load(Ordering::Acquire) {
                    return;
                }
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => {
                // Transient accept error: if we're done, leave; otherwise
                // keep the listener alive (one bad connection must not
                // kill the server).
                // ORDERING: Acquire — same drained/Release pairing as above.
                if shared.drained.load(Ordering::Acquire) {
                    return;
                }
                std::thread::sleep(Duration::from_millis(2));
            }
        }
    }
}

/// The serve loop proper: admission queue → replica pools → event
/// channels.
fn scheduler_loop(
    engines: &[Engine],
    shared: &Shared,
    pcfg: PoolConfig,
    _cfg: &HttpConfig,
    shutdown: &AtomicBool,
) -> Result<ServeReport> {
    let mut pools: Vec<ReplicaPool> = engines
        .chunks(pcfg.replicas)
        .map(|chunk| ReplicaPool::new(chunk, pcfg.placement))
        .collect::<Result<_>>()?;
    let mut inflight: Vec<HashMap<u64, Sender<Event>>> =
        pools.iter().map(|_| HashMap::new()).collect();
    let mut metrics = Metrics::default();
    let t0 = Instant::now();
    let mut ticks = 0u64;
    loop {
        // ORDERING: Relaxed read of the caller's shutdown flag (signal
        // handler does a plain store; no data is published through it) —
        // the Release store on `draining` is what the handlers' Acquire
        // loads synchronise with.
        if shutdown.load(Ordering::Relaxed) {
            shared.draining.store(true, Ordering::Release);
        }
        // Admissions → pools, with a per-request token sink feeding the
        // handler's event channel. The sink travels with the request if
        // the pool re-routes it off an unhealthy replica before prefill.
        let newly: Vec<Admitted> = lock_mx(&shared.admission).drain(..).collect();
        for adm in newly {
            let tx = adm.events.clone();
            let sink: TokenSink = if adm.stream {
                let stream_tx = adm.events.clone();
                Box::new(move |t| {
                    let _ = stream_tx.send(Event::Token(t));
                })
            } else {
                // Non-streamed responses read tokens off the Response;
                // skip the per-token channel traffic.
                Box::new(|_| {})
            };
            let id = adm.req.id;
            let lane_name =
                shared.lanes.get(adm.lane).map(|l| l.name.clone()).unwrap_or_default();
            let submitted = match pools.get_mut(adm.lane) {
                Some(pool) => pool.submit_with_sink(adm.req, sink),
                None => Err(anyhow::anyhow!("admitted to unknown lane index {}", adm.lane)),
            };
            match submitted {
                Ok(_) => {
                    if let Some(lane_inflight) = inflight.get_mut(adm.lane) {
                        lane_inflight.insert(id, adm.events);
                    }
                }
                Err(e) => {
                    // No admitting replica right now (all draining/down):
                    // fail typed instead of parking work on a dead pool.
                    let msg = format!("lane {lane_name:?}: {e:#}");
                    let _ = tx.send(Event::Fail(msg));
                    lock_mx(&shared.router).note_done(&lane_name);
                    // ORDERING: AcqRel keeps the admission-slot release
                    // ordered against the handlers' CAS loop on `pending`
                    // (the backpressure bound must never over-admit).
                    shared.pending.fetch_sub(1, Ordering::AcqRel);
                    shared.counters.fail();
                }
            }
        }
        // One pool step per lane. Pool steps are infallible — a replica
        // whose step errors is failed over *inside* the pool (queued work
        // re-routed to healthy replicas, mid-stream work surfaced through
        // `take_failures`).
        let mut any_active = false;
        for (li, (pool, lane_inflight)) in
            pools.iter_mut().zip(inflight.iter_mut()).enumerate()
        {
            let lane_name = shared.lanes.get(li).map(|l| l.name.as_str()).unwrap_or("");
            if !pool.is_idle() {
                any_active = true;
            }
            for r in pool.step() {
                metrics.record_response(&r);
                lock_mx(&shared.router).note_done(lane_name);
                // ORDERING: AcqRel pairs with the handlers' admission CAS —
                // releasing the slot must not reorder past the counter
                // bump that makes the completion observable.
                shared.pending.fetch_sub(1, Ordering::AcqRel);
                shared.counters.complete();
                if let Some(tx) = lane_inflight.remove(&r.id) {
                    let _ = tx.send(Event::Done(r));
                }
            }
            // Failover fallout: what the pool could not save fails loudly
            // (500s) rather than hanging its handler.
            for f in pool.take_failures() {
                if let Some(tx) = lane_inflight.remove(&f.id) {
                    let _ = tx.send(Event::Fail(format!("lane {lane_name:?}: {}", f.error)));
                }
                lock_mx(&shared.router).note_done(lane_name);
                // ORDERING: AcqRel — same admission-slot release pairing
                // as the completion path above.
                shared.pending.fetch_sub(1, Ordering::AcqRel);
                shared.counters.fail();
            }
            // Revive Down replicas with their already-reset scheduler so
            // the lane keeps serving — the same restart-clean semantics
            // the pre-pool single-scheduler loop had. (In-process pool
            // drivers like the fault tests manage health themselves.)
            for ri in 0..pool.len() {
                if pool.health(ri) == Health::Down {
                    pool.revive(ri);
                }
            }
        }
        ticks += 1;
        if ticks % 8 == 1 || !any_active {
            let rendered = render_stats(shared, &metrics, &pools, engines, pcfg.replicas, t0);
            *lock_mx(&shared.stats) = rendered;
        }
        // ORDERING: Acquire pairs with the Release store above (or a future
        // cross-thread drainer) so the drain decision sees every admission
        // that happened-before the flag flipped.
        if shared.draining.load(Ordering::Acquire)
            && pools.iter().all(|p| p.is_idle())
            && lock_mx(&shared.admission).is_empty()
        {
            break;
        }
        if !any_active {
            std::thread::sleep(Duration::from_millis(1));
        }
    }
    // Final sweep: `draining` was published before this point, so any
    // admission that still slips in past its handler's own recheck is
    // failed here as a drain rejection rather than left waiting.
    let leftovers: Vec<Admitted> = lock_mx(&shared.admission).drain(..).collect();
    for adm in leftovers {
        let _ = adm.events.send(Event::Fail("server draining".to_string()));
        let lane_name = shared.lanes.get(adm.lane).map(|l| l.name.as_str()).unwrap_or("");
        lock_mx(&shared.router).note_done(lane_name);
        // ORDERING: AcqRel — admission-slot release, pairs with the
        // handlers' CAS loop on `pending`.
        shared.pending.fetch_sub(1, Ordering::AcqRel);
        shared.counters.fail();
    }
    metrics.wall = t0.elapsed();
    *lock_mx(&shared.stats) =
        render_stats(shared, &metrics, &pools, engines, pcfg.replicas, t0);
    // ORDERING: Release publishes every post-drain write (final stats,
    // counter state) to the acceptor's Acquire load before it returns.
    shared.drained.store(true, Ordering::Release);
    Ok(ServeReport {
        metrics,
        // ORDERING: Relaxed — plain monotonic tallies read after the
        // scheduler loop is the only thread left touching them.
        rejected_429: shared.rejected_429.load(Ordering::Relaxed),
        rejected_503: shared.rejected_503.load(Ordering::Relaxed),
    })
}

/// Render the `/stats` *detail* document: throughput/latency plus
/// per-lane aggregates and per-replica blocks (health, heartbeat,
/// weights tag — DESIGN.md §15). The admitted/completed/failed counter
/// block is deliberately NOT here: [`stats_body`] splices a fresh
/// seqlock-consistent reading in per request.
fn render_stats(
    shared: &Shared,
    metrics: &Metrics,
    pools: &[ReplicaPool],
    engines: &[Engine],
    replicas: usize,
    t0: Instant,
) -> String {
    let elapsed = t0.elapsed().as_secs_f64().max(1e-9);
    let lanes: Vec<Json> = shared
        .lanes
        .iter()
        .zip(pools)
        .zip(engines.chunks(replicas.max(1)))
        .map(|((info, pool), lane_engines)| {
            let rstats = pool.replica_stats();
            // Aggregate the lane's replica caches so the lane-level
            // `cache` block keeps its pre-pool meaning (with one replica
            // it is bytewise the old document).
            let mut cs = CacheStats::default();
            for e in lane_engines {
                if let Some(c) = e.prefix_cache() {
                    let one = c.stats();
                    cs.hits += one.hits;
                    cs.misses += one.misses;
                    cs.inserts += one.inserts;
                    cs.evictions += one.evictions;
                    cs.used_bytes += one.used_bytes;
                    cs.entries += one.entries;
                }
            }
            let replica_blocks: Vec<Json> = rstats
                .iter()
                .enumerate()
                .map(|(ri, rs)| {
                    obj(vec![
                        ("index", num(ri as f64)),
                        ("health", s(rs.health.name())),
                        ("in_flight", num(rs.in_flight as f64)),
                        ("completed", num(rs.completed as f64)),
                        ("failed", num(rs.failed as f64)),
                        ("prefills", num(rs.prefills as f64)),
                        ("decode_steps", num(rs.decode_steps as f64)),
                        ("preemptions", num(rs.preemptions as f64)),
                        ("recent_errors", num(rs.recent_errors as f64)),
                        ("mean_step_us", num(rs.mean_step_us as f64)),
                        ("weights_tag", s(&rs.weights_tag)),
                    ])
                })
                .collect();
            obj(vec![
                ("name", s(&info.name)),
                ("in_flight", num(pool.in_flight() as f64)),
                ("prefills", num(rstats.iter().map(|r| r.prefills).sum::<u64>() as f64)),
                ("decode_steps", num(rstats.iter().map(|r| r.decode_steps).sum::<u64>() as f64)),
                ("preemptions", num(rstats.iter().map(|r| r.preemptions).sum::<u64>() as f64)),
                ("reroutes", num(pool.reroutes as f64)),
                (
                    "cache",
                    obj(vec![
                        ("hits", num(cs.hits as f64)),
                        ("misses", num(cs.misses as f64)),
                        ("inserts", num(cs.inserts as f64)),
                        ("evictions", num(cs.evictions as f64)),
                        ("used_bytes", num(cs.used_bytes as f64)),
                        ("entries", num(cs.entries as f64)),
                        ("hit_rate", num(cs.hit_rate())),
                    ]),
                ),
                ("replicas", Json::Arr(replica_blocks)),
            ])
        })
        .collect();
    let placement = pools.first().map(|p| p.placement().name()).unwrap_or("least-loaded");
    obj(vec![
        ("replicas_per_lane", num(replicas as f64)),
        ("placement", s(placement)),
        ("generated_tokens", num(metrics.generated_tokens as f64)),
        ("gen_tok_s", num(metrics.generated_tokens as f64 / elapsed)),
        ("p50_e2e_us", num(Metrics::pct(&metrics.e2e_us, 0.5) as f64)),
        ("p99_e2e_us", num(Metrics::pct(&metrics.e2e_us, 0.99) as f64)),
        ("lanes", Json::Arr(lanes)),
    ])
    .to_string()
}

/// Compose the `GET /stats` body at request time: a seqlock-consistent
/// counter block (so `admitted == completed + failed + in_flight` holds
/// in every response — the DESIGN.md §15 bugfix, regression-tested by
/// `tests/http_serve.rs`) spliced with the lane/replica detail the
/// scheduler loop last rendered.
fn stats_body(shared: &Shared) -> String {
    let c = shared.counters.snapshot();
    let head = obj(vec![
        ("admitted", num(c.admitted as f64)),
        ("completed", num(c.completed as f64)),
        ("failed", num(c.failed as f64)),
        ("in_flight", num(c.in_flight() as f64)),
        // ORDERING: Relaxed ×3 — stats-only reads of monotonic tallies and
        // the drain flag; staleness is acceptable, no data depends on them.
        ("rejected_429", num(shared.rejected_429.load(Ordering::Relaxed) as f64)),
        ("rejected_503", num(shared.rejected_503.load(Ordering::Relaxed) as f64)),
        ("draining", Json::Bool(shared.draining.load(Ordering::Relaxed))),
    ])
    .to_string();
    let detail = lock_mx(&shared.stats).clone();
    let inner = detail.trim();
    // Splice `{head...}` + `{detail...}` into one object. The detail is
    // always an object render; before the loop's first render it is the
    // empty `{}` placeholder, in which case the head stands alone.
    match (head.strip_suffix('}'), inner.strip_prefix('{')) {
        (Some(h), Some(rest)) if rest.trim() != "}" => format!("{h},{rest}"),
        _ => head,
    }
}

// ---------------------------------------------------------------------------
// Connection handling
// ---------------------------------------------------------------------------

/// Request-head read outcome short of a parsed request.
enum ReadErr {
    Timeout,
    TooLarge,
    Truncated,
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

/// Read until the head terminator; returns (head, leftover-body-bytes).
fn read_head(stream: &mut TcpStream, max: usize) -> std::result::Result<(Vec<u8>, Vec<u8>), ReadErr> {
    let mut buf = Vec::new();
    let mut chunk = [0u8; 512];
    loop {
        if let Some(pos) = find_subslice(&buf, b"\r\n\r\n") {
            let body = buf.split_off(pos + 4);
            buf.truncate(pos);
            return Ok((buf, body));
        }
        if buf.len() > max {
            return Err(ReadErr::TooLarge);
        }
        match stream.read(&mut chunk) {
            Ok(0) => return Err(ReadErr::Truncated),
            Ok(n) => buf.extend_from_slice(chunk.get(..n).unwrap_or(&[])),
            Err(e) if is_timeout(&e) => return Err(ReadErr::Timeout),
            Err(_) => return Err(ReadErr::Truncated),
        }
    }
}

fn find_subslice(hay: &[u8], needle: &[u8]) -> Option<usize> {
    hay.windows(needle.len()).position(|w| w == needle)
}

struct Head {
    method: String,
    path: String,
    headers: Vec<(String, String)>,
}

impl Head {
    fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }
}

fn parse_head(raw: &[u8]) -> Option<Head> {
    let text = std::str::from_utf8(raw).ok()?;
    let mut lines = text.split("\r\n");
    let mut req_line = lines.next()?.split(' ');
    let method = req_line.next()?.to_string();
    let path = req_line.next()?.to_string();
    let version = req_line.next()?;
    if !version.starts_with("HTTP/1.") || req_line.next().is_some() {
        return None;
    }
    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let (k, v) = line.split_once(':')?;
        headers.push((k.trim().to_string(), v.trim().to_string()));
    }
    Some(Head { method, path, headers })
}

const REASONS: &[(u16, &str)] = &[
    (200, "OK"),
    (400, "Bad Request"),
    (404, "Not Found"),
    (405, "Method Not Allowed"),
    (408, "Request Timeout"),
    (411, "Length Required"),
    (413, "Content Too Large"),
    (429, "Too Many Requests"),
    (431, "Request Header Fields Too Large"),
    (500, "Internal Server Error"),
    (503, "Service Unavailable"),
];

fn reason(status: u16) -> &'static str {
    REASONS.iter().find(|(c, _)| *c == status).map(|(_, r)| *r).unwrap_or("Unknown")
}

/// Write one non-streamed response (JSON body, `Connection: close`).
/// Write errors are swallowed — the client may already be gone, and the
/// connection is single-use either way.
fn respond(stream: &mut TcpStream, status: u16, extra_headers: &[(&str, String)], body: &str) {
    let mut head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n",
        reason(status),
        body.len()
    );
    for (k, v) in extra_headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str("\r\n");
    let _ = stream.write_all(head.as_bytes());
    let _ = stream.write_all(body.as_bytes());
    let _ = stream.flush();
}

fn respond_error(stream: &mut TcpStream, status: u16, msg: &str) {
    respond(stream, status, &[], &obj(vec![("error", s(msg))]).to_string());
}

fn respond_retry(stream: &mut TcpStream, status: u16, msg: &str, retry_after_s: u64) {
    respond(
        stream,
        status,
        &[("Retry-After", retry_after_s.to_string())],
        &obj(vec![("error", s(msg))]).to_string(),
    );
}

/// Write one chunked-transfer chunk: `SIZEHEX\r\n<payload>\r\n`.
fn write_chunk(stream: &mut TcpStream, payload: &[u8]) -> std::io::Result<()> {
    write!(stream, "{:x}\r\n", payload.len())?;
    stream.write_all(payload)?;
    stream.write_all(b"\r\n")
}

/// The completion document shared by the non-streamed response body and
/// the stream's final `data:` event (so the two paths can never drift).
fn response_json(r: &Response) -> Json {
    obj(vec![
        ("id", num(r.id as f64)),
        ("variant", s(&r.variant)),
        ("tokens", Json::Arr(r.generated.iter().map(|&t| num(t as f64)).collect())),
        (
            "usage",
            obj(vec![
                ("prompt_tokens", num(r.prompt_tokens as f64)),
                ("generated_tokens", num(r.generated.len() as f64)),
            ]),
        ),
        (
            "timing_us",
            obj(vec![
                ("queue", num(r.queue_us as f64)),
                ("prefill", num(r.prefill_us as f64)),
                ("decode", num(r.decode_us as f64)),
            ]),
        ),
    ])
}

fn handle_connection(mut stream: TcpStream, shared: &Shared, cfg: &HttpConfig) {
    let _ = stream.set_read_timeout(Some(cfg.read_timeout));
    let _ = stream.set_nodelay(true);
    let (head_raw, leftover) = match read_head(&mut stream, cfg.max_header_bytes) {
        Ok(x) => x,
        Err(ReadErr::Timeout) => return respond_error(&mut stream, 408, "request head read timed out"),
        Err(ReadErr::TooLarge) => return respond_error(&mut stream, 431, "request head too large"),
        Err(ReadErr::Truncated) => return respond_error(&mut stream, 400, "truncated request head"),
    };
    let Some(head) = parse_head(&head_raw) else {
        return respond_error(&mut stream, 400, "malformed request head");
    };
    match (head.method.as_str(), head.path.as_str()) {
        ("GET", "/healthz") => {
            // ORDERING: Relaxed — health probes only need an eventually
            // current flag; no data is read on the strength of this load.
            let draining = shared.draining.load(Ordering::Relaxed);
            let body = obj(vec![
                ("status", s(if draining { "draining" } else { "ok" })),
                (
                    "lanes",
                    Json::Arr(shared.lanes.iter().map(|l| s(&l.name)).collect()),
                ),
            ]);
            respond(&mut stream, 200, &[], &body.to_string());
        }
        ("GET", "/stats") => {
            respond(&mut stream, 200, &[], &stats_body(shared));
        }
        ("POST", "/v1/generate") => handle_generate(&mut stream, &head, leftover, shared, cfg),
        ("GET", _) => respond_error(&mut stream, 404, "unknown path"),
        ("POST", _) => respond_error(&mut stream, 404, "unknown path"),
        _ => respond_error(&mut stream, 405, "method not allowed"),
    }
}

/// Read the request body per `Content-Length`, starting from whatever
/// arrived with the head.
fn read_body(
    stream: &mut TcpStream,
    head: &Head,
    mut body: Vec<u8>,
    cfg: &HttpConfig,
) -> std::result::Result<Vec<u8>, (u16, String)> {
    let Some(cl) = head.header("content-length") else {
        return Err((411, "Content-Length required".to_string()));
    };
    let n: usize = match cl.parse() {
        Ok(n) => n,
        Err(_) => return Err((400, format!("bad Content-Length {cl:?}"))),
    };
    if n > cfg.max_body_bytes {
        return Err((413, format!("body of {n} bytes exceeds cap {}", cfg.max_body_bytes)));
    }
    body.truncate(n.min(body.len()));
    let mut chunk = [0u8; 4096];
    while body.len() < n {
        match stream.read(&mut chunk) {
            Ok(0) => return Err((400, "truncated body".to_string())),
            Ok(k) => {
                let want = n - body.len();
                body.extend_from_slice(chunk.get(..k.min(want)).unwrap_or(&[]));
            }
            Err(e) if is_timeout(&e) => return Err((408, "body read timed out".to_string())),
            Err(e) => return Err((400, format!("body read failed: {e}"))),
        }
    }
    Ok(body)
}

/// Parsed + validated `/v1/generate` request fields.
struct GenRequest {
    prompt: Vec<i32>,
    variant: String,
    gen_tokens: usize,
    stream: bool,
    priority: Priority,
}

/// Lazy-extract and validate the request document (DESIGN.md §14 schema).
fn parse_generate(body: &str, cfg: &HttpConfig) -> std::result::Result<GenRequest, String> {
    let doc = LazyDoc::new(body);
    doc.validate().map_err(|e| format!("malformed JSON: {e}"))?;
    let err = |e: crate::util::json::JsonError| format!("bad field: {e}");
    let prompt = doc
        .i32_array_field("prompt")
        .map_err(err)?
        .ok_or_else(|| "missing field \"prompt\" (array of token ids)".to_string())?;
    if prompt.is_empty() {
        return Err("empty prompt (prompts must contain at least one token)".to_string());
    }
    let variant = doc.str_field("variant").map_err(err)?.unwrap_or_default();
    let gen_tokens = doc.usize_field("max_tokens").map_err(err)?.unwrap_or(cfg.default_gen_tokens);
    if gen_tokens == 0 || gen_tokens > cfg.max_gen_tokens {
        return Err(format!("max_tokens must be in 1..={}", cfg.max_gen_tokens));
    }
    let stream = doc.bool_field("stream").map_err(err)?.unwrap_or(false);
    let priority = match doc.str_field("priority").map_err(err)?.as_deref() {
        None | Some("normal") => Priority::Normal,
        Some("low") => Priority::Low,
        Some("high") => Priority::High,
        Some(p) => return Err(format!("unknown priority {p:?} (low|normal|high)")),
    };
    Ok(GenRequest { prompt, variant, gen_tokens, stream, priority })
}

fn handle_generate(
    stream: &mut TcpStream,
    head: &Head,
    leftover: Vec<u8>,
    shared: &Shared,
    cfg: &HttpConfig,
) {
    let body = match read_body(stream, head, leftover, cfg) {
        Ok(b) => b,
        Err((status, msg)) => return respond_error(stream, status, &msg),
    };
    let Ok(text) = std::str::from_utf8(&body) else {
        return respond_error(stream, 400, "body is not valid UTF-8");
    };
    let gen = match parse_generate(text, cfg) {
        Ok(g) => g,
        Err(msg) => return respond_error(stream, 400, &msg),
    };
    let req = Request {
        // ORDERING: Relaxed — ids only need uniqueness, which fetch_add's
        // atomicity alone guarantees; nothing is published through it.
        id: shared.next_id.fetch_add(1, Ordering::Relaxed),
        prompt: gen.prompt,
        gen_tokens: gen.gen_tokens,
        variant: gen.variant,
        arrived_us: 0,
        priority: gen.priority,
    };
    // Route first (cheap, needs no admission slot); the typed error keeps
    // client mistakes (400) apart from deployment gaps (404).
    let lane_name = match lock_mx(&shared.router).route_checked(&req) {
        Ok(l) => l,
        Err(e @ (RouteError::Malformed { .. } | RouteError::NeedsVariant)) => {
            return respond_error(stream, 400, &e.to_string());
        }
        Err(e @ RouteError::Unserved { .. }) => {
            return respond_error(stream, 404, &e.to_string());
        }
    };
    // The router only hands out names it was built from, but a config/router
    // mismatch must surface as a typed 500, not a worker-thread panic.
    let Some(lane) = shared.lanes.iter().position(|l| l.name == lane_name) else {
        return respond_error(stream, 500, &format!("router picked unknown lane {lane_name:?}"));
    };
    let Some(info) = shared.lanes.get(lane) else {
        return respond_error(stream, 500, &format!("router picked unknown lane {lane_name:?}"));
    };
    // The backends index embeddings by token id unchecked — the socket is
    // where range validation must happen.
    if req.prompt.iter().any(|&t| t < 0 || t as usize >= info.vocab) {
        return respond_error(
            stream,
            400,
            &format!("prompt token out of range (vocab is {})", info.vocab),
        );
    }
    if req.prompt.len() > cfg.max_prompt_tokens {
        return respond_error(
            stream,
            400,
            &format!("prompt of {} tokens exceeds cap {}", req.prompt.len(), cfg.max_prompt_tokens),
        );
    }
    if !info.length_aware && req.prompt.len() > info.prefill_len {
        return respond_error(
            stream,
            400,
            &format!(
                "prompt of {} tokens exceeds lane {:?}'s prefill frame of {} and the lane \
                 cannot chunk",
                req.prompt.len(),
                info.name,
                info.prefill_len
            ),
        );
    }

    // ---- bounded admission (the backpressure point) ---------------------
    // ORDERING: Acquire pairs with the scheduler loop's Release store of
    // `draining` so a rejected request also observes any drain bookkeeping
    // that preceded the flag.
    if shared.draining.load(Ordering::Acquire) {
        // ORDERING: Relaxed — monotonic rejection tally, read only for stats.
        shared.rejected_503.fetch_add(1, Ordering::Relaxed);
        return respond_retry(stream, 503, "server draining", cfg.retry_after_s);
    }
    let mut cur = shared.pending.load(Ordering::Acquire);
    loop {
        if cur >= cfg.queue_cap {
            // ORDERING: Relaxed — monotonic rejection tally, stats only.
            shared.rejected_429.fetch_add(1, Ordering::Relaxed);
            return respond_retry(stream, 429, "admission queue full", cfg.retry_after_s);
        }
        match shared.pending.compare_exchange_weak(
            cur,
            cur + 1,
            // ORDERING: AcqRel on success so slot acquisition synchronizes
            // with the scheduler's AcqRel fetch_sub releases; Acquire on
            // failure to re-read a current count before retrying.
            Ordering::AcqRel,
            Ordering::Acquire,
        ) {
            Ok(_) => break,
            Err(now) => cur = now,
        }
    }
    shared.counters.admit();
    let id = req.id;
    let (tx, rx) = std::sync::mpsc::channel::<Event>();
    lock_mx(&shared.admission).push_back(Admitted { req, lane, events: tx, stream: gen.stream });
    lock_mx(&shared.router).note_enqueued(&lane_name);
    // Drain race: if `draining` latched between our check and the push,
    // the scheduler loop may already have swept past the queue. Reclaim
    // our own entry if it is still there; if the loop took it, the work
    // is admitted and will complete normally.
    // ORDERING: Acquire — pairs with the Release store of `draining`; if we
    // see the flag here, the sweep that might have missed our entry has
    // happened-before this load, so the reclaim check below is decisive.
    if shared.draining.load(Ordering::Acquire) {
        let reclaimed = {
            let mut q = lock_mx(&shared.admission);
            match q.iter().position(|a| a.req.id == id) {
                Some(pos) => {
                    q.remove(pos);
                    true
                }
                None => false,
            }
        };
        if reclaimed {
            lock_mx(&shared.router).note_done(&lane_name);
            // ORDERING: AcqRel — releases the admission slot; pairs with the
            // Acquire side of the CAS loop above.
            shared.pending.fetch_sub(1, Ordering::AcqRel);
            shared.counters.fail();
            // ORDERING: Relaxed — monotonic rejection tally, stats only.
            shared.rejected_503.fetch_add(1, Ordering::Relaxed);
            return respond_retry(stream, 503, "server draining", cfg.retry_after_s);
        }
    }

    if gen.stream {
        stream_events(stream, rx, cfg);
    } else {
        buffered_response(stream, rx, cfg);
    }
}

/// Wait for the completion event and answer with one JSON document.
fn buffered_response(stream: &mut TcpStream, rx: Receiver<Event>, cfg: &HttpConfig) {
    loop {
        match rx.recv_timeout(cfg.completion_timeout) {
            Ok(Event::Token(_)) => continue, // non-streamed sinks don't send these
            Ok(Event::Done(r)) => {
                return respond(stream, 200, &[], &response_json(&r).to_string());
            }
            Ok(Event::Fail(msg)) => {
                if msg.contains("draining") {
                    return respond_retry(stream, 503, &msg, cfg.retry_after_s);
                }
                return respond_error(stream, 500, &msg);
            }
            Err(RecvTimeoutError::Timeout) => {
                return respond_error(stream, 500, "generation timed out");
            }
            Err(RecvTimeoutError::Disconnected) => {
                return respond_error(stream, 500, "scheduler dropped the request");
            }
        }
    }
}

/// Chunked-transfer streaming: one SSE-style `data:` line per token, a
/// final `data:` completion document, then the terminal `0\r\n\r\n`.
fn stream_events(stream: &mut TcpStream, rx: Receiver<Event>, cfg: &HttpConfig) {
    let mut started = false;
    let start = |stream: &mut TcpStream| -> std::io::Result<()> {
        stream.write_all(
            b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\n\
              Transfer-Encoding: chunked\r\nConnection: close\r\n\r\n",
        )
    };
    loop {
        let ev = rx.recv_timeout(cfg.completion_timeout);
        match ev {
            Ok(Event::Token(t)) => {
                if !started {
                    if start(stream).is_err() {
                        return; // client gone; scheduler finishes regardless
                    }
                    started = true;
                }
                let line = format!("data: {{\"token\":{t}}}\n\n");
                if write_chunk(stream, line.as_bytes()).is_err() {
                    return;
                }
            }
            Ok(Event::Done(r)) => {
                if !started && start(stream).is_err() {
                    return;
                }
                let mut done = response_json(&r);
                if let Json::Obj(m) = &mut done {
                    m.insert("done".to_string(), Json::Bool(true));
                }
                let line = format!("data: {done}\n\n");
                let _ = write_chunk(stream, line.as_bytes());
                let _ = stream.write_all(b"0\r\n\r\n");
                let _ = stream.flush();
                return;
            }
            Ok(Event::Fail(msg)) => {
                if started {
                    let line = format!("data: {}\n\n", obj(vec![("error", s(&msg))]));
                    let _ = write_chunk(stream, line.as_bytes());
                    let _ = stream.write_all(b"0\r\n\r\n");
                } else if msg.contains("draining") {
                    respond_retry(stream, 503, &msg, cfg.retry_after_s);
                } else {
                    respond_error(stream, 500, &msg);
                }
                return;
            }
            Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => {
                if started {
                    let _ = write_chunk(stream, b"data: {\"error\":\"generation timed out\"}\n\n");
                    let _ = stream.write_all(b"0\r\n\r\n");
                } else {
                    respond_error(stream, 500, "generation timed out");
                }
                return;
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Loopback client (tests + benches)
// ---------------------------------------------------------------------------

/// Minimal HTTP/1.1 client for the serving tests and `benches/serve.rs`:
/// one request per connection (matching the server's `Connection: close`),
/// strict chunked-transfer validation (every size line must parse, the
/// terminal `0\r\n\r\n` must be present), and SSE `data:` event parsing.
/// Deliberately *not* a general client — it only speaks the subset the
/// server emits, and it fails loudly on any framing deviation so protocol
/// bugs surface in tests rather than being silently tolerated.
pub mod client {
    use std::io::{Read, Write};
    use std::net::{SocketAddr, TcpStream};
    use std::time::{Duration, Instant};

    use crate::util::json::Json;

    /// One parsed response. When the transfer was chunked, `chunks` holds
    /// each chunk payload in order and `body` their concatenation.
    #[derive(Debug)]
    pub struct RawResponse {
        pub status: u16,
        pub headers: Vec<(String, String)>,
        pub body: Vec<u8>,
        pub chunked: bool,
        pub chunks: Vec<Vec<u8>>,
    }

    impl RawResponse {
        pub fn header(&self, name: &str) -> Option<&str> {
            self.headers
                .iter()
                .find(|(k, _)| k.eq_ignore_ascii_case(name))
                .map(|(_, v)| v.as_str())
        }

        pub fn body_str(&self) -> std::borrow::Cow<'_, str> {
            String::from_utf8_lossy(&self.body)
        }

        pub fn body_json(&self) -> std::io::Result<Json> {
            Json::parse(&self.body_str()).map_err(|e| bad(&format!("body is not JSON: {e}")))
        }
    }

    fn bad(msg: &str) -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::InvalidData, msg.to_string())
    }

    /// Send one raw request and read the response to EOF.
    pub fn request(
        addr: SocketAddr,
        method: &str,
        path: &str,
        extra_headers: &[(&str, &str)],
        body: &[u8],
    ) -> std::io::Result<RawResponse> {
        let mut stream = TcpStream::connect(addr)?;
        stream.set_read_timeout(Some(Duration::from_secs(60)))?;
        stream.set_nodelay(true)?;
        let mut head = format!("{method} {path} HTTP/1.1\r\nHost: repro\r\nConnection: close\r\n");
        if method == "POST" || !body.is_empty() {
            head.push_str(&format!("Content-Length: {}\r\n", body.len()));
        }
        for (k, v) in extra_headers {
            head.push_str(&format!("{k}: {v}\r\n"));
        }
        head.push_str("\r\n");
        stream.write_all(head.as_bytes())?;
        stream.write_all(body)?;
        let mut buf = Vec::new();
        stream.read_to_end(&mut buf)?;
        parse_response(&buf)
    }

    pub fn get(addr: SocketAddr, path: &str) -> std::io::Result<RawResponse> {
        request(addr, "GET", path, &[], b"")
    }

    pub fn post_json(addr: SocketAddr, path: &str, json: &str) -> std::io::Result<RawResponse> {
        request(addr, "POST", path, &[("Content-Type", "application/json")], json.as_bytes())
    }

    /// Parse a full captured response, validating chunked framing strictly.
    pub fn parse_response(buf: &[u8]) -> std::io::Result<RawResponse> {
        let head_end = buf
            .windows(4)
            .position(|w| w == b"\r\n\r\n")
            .ok_or_else(|| bad("no header terminator"))?;
        let head = std::str::from_utf8(buf.get(..head_end).unwrap_or(&[]))
            .map_err(|_| bad("head not UTF-8"))?;
        let mut lines = head.split("\r\n");
        let status_line = lines.next().ok_or_else(|| bad("empty head"))?;
        let mut parts = status_line.splitn(3, ' ');
        let version = parts.next().unwrap_or("");
        if !version.starts_with("HTTP/1.") {
            return Err(bad(&format!("bad status line {status_line:?}")));
        }
        let status: u16 = parts
            .next()
            .and_then(|c| c.parse().ok())
            .ok_or_else(|| bad("bad status code"))?;
        let mut headers = Vec::new();
        for line in lines {
            if line.is_empty() {
                continue;
            }
            let (k, v) = line.split_once(':').ok_or_else(|| bad("bad header line"))?;
            headers.push((k.trim().to_string(), v.trim().to_string()));
        }
        let payload = buf.get(head_end + 4..).unwrap_or(&[]);
        let chunked = headers
            .iter()
            .any(|(k, v)| k.eq_ignore_ascii_case("transfer-encoding") && v.eq_ignore_ascii_case("chunked"));
        if chunked {
            let chunks = parse_chunks(payload)?;
            let body = chunks.concat();
            return Ok(RawResponse { status, headers, body, chunked, chunks });
        }
        let body = match headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case("content-length"))
            .map(|(_, v)| v.parse::<usize>())
        {
            Some(Ok(n)) => match payload.get(..n) {
                Some(p) => p.to_vec(),
                None => {
                    return Err(bad(&format!(
                        "body shorter than Content-Length ({} < {n})",
                        payload.len()
                    )));
                }
            },
            Some(Err(_)) => return Err(bad("unparseable Content-Length")),
            None => payload.to_vec(),
        };
        Ok(RawResponse { status, headers, body, chunked: false, chunks: Vec::new() })
    }

    /// Strict chunked-transfer decoding: every size line must be pure hex
    /// followed by CRLF, every chunk must end in CRLF, and the stream must
    /// end with exactly `0\r\n\r\n` — any deviation is an error, which is
    /// what makes the framing round-trip test meaningful.
    fn parse_chunks(mut p: &[u8]) -> std::io::Result<Vec<Vec<u8>>> {
        let mut chunks = Vec::new();
        loop {
            let line_end =
                p.windows(2).position(|w| w == b"\r\n").ok_or_else(|| bad("chunk size line unterminated"))?;
            let size_str = std::str::from_utf8(p.get(..line_end).unwrap_or(&[]))
                .map_err(|_| bad("chunk size not UTF-8"))?;
            if size_str.is_empty() || !size_str.bytes().all(|b| b.is_ascii_hexdigit()) {
                return Err(bad(&format!("malformed chunk size line {size_str:?}")));
            }
            let size = usize::from_str_radix(size_str, 16).map_err(|_| bad("chunk size overflow"))?;
            p = p.get(line_end + 2..).unwrap_or(&[]);
            if size == 0 {
                if p != b"\r\n" {
                    return Err(bad("missing terminal CRLF after last chunk"));
                }
                return Ok(chunks);
            }
            let Some(payload) = p.get(..size) else {
                return Err(bad("truncated chunk payload"));
            };
            if p.get(size..size + 2) != Some(b"\r\n".as_slice()) {
                return Err(bad("chunk payload not CRLF-terminated"));
            }
            chunks.push(payload.to_vec());
            p = p.get(size + 2..).unwrap_or(&[]);
        }
    }

    /// The payloads of a body's SSE `data:` events, in order.
    pub fn sse_data_lines(body: &[u8]) -> Vec<String> {
        String::from_utf8_lossy(body)
            .split("\n\n")
            .filter_map(|ev| ev.trim().strip_prefix("data: ").map(|x| x.to_string()))
            .collect()
    }

    /// Parse a token stream: the `{"token":N}` events in order, plus the
    /// final completion document (the event carrying `"done":true`).
    pub fn sse_tokens(body: &[u8]) -> std::io::Result<(Vec<i32>, Option<Json>)> {
        let mut tokens = Vec::new();
        let mut done = None;
        for line in sse_data_lines(body) {
            let v = Json::parse(&line).map_err(|e| bad(&format!("bad SSE event {line:?}: {e}")))?;
            if let Some(t) = v.get("token").and_then(|t| t.as_f64()) {
                tokens.push(t as i32);
            } else if v.get("done").is_some() {
                done = Some(v);
            } else if v.get("error").is_some() {
                return Err(bad(&format!("stream error event: {line}")));
            }
        }
        Ok((tokens, done))
    }

    /// A timed streaming request: TTFT is first-`data:`-byte arrival,
    /// e2e is send→EOF — the measurements `BENCH_serve.json` reports.
    #[derive(Debug)]
    pub struct StreamTiming {
        pub resp: RawResponse,
        pub ttft_us: u64,
        pub e2e_us: u64,
    }

    pub fn post_json_timed(addr: SocketAddr, path: &str, json: &str) -> std::io::Result<StreamTiming> {
        let mut stream = TcpStream::connect(addr)?;
        stream.set_read_timeout(Some(Duration::from_secs(60)))?;
        stream.set_nodelay(true)?;
        let head = format!(
            "POST {path} HTTP/1.1\r\nHost: repro\r\nConnection: close\r\n\
             Content-Type: application/json\r\nContent-Length: {}\r\n\r\n",
            json.len()
        );
        let t0 = Instant::now();
        stream.write_all(head.as_bytes())?;
        stream.write_all(json.as_bytes())?;
        let mut buf = Vec::new();
        let mut chunk = [0u8; 4096];
        let mut ttft_us = None;
        loop {
            match stream.read(&mut chunk) {
                Ok(0) => break,
                Ok(n) => {
                    buf.extend_from_slice(chunk.get(..n).unwrap_or(&[]));
                    if ttft_us.is_none() {
                        if let Some(he) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
                            let tail = buf.get(he + 4..).unwrap_or(&[]);
                            if tail.windows(5).any(|w| w == b"data:") {
                                ttft_us = Some(t0.elapsed().as_micros() as u64);
                            }
                        }
                    }
                }
                Err(e) => return Err(e),
            }
        }
        let e2e_us = t0.elapsed().as_micros() as u64;
        let resp = parse_response(&buf)?;
        Ok(StreamTiming { resp, ttft_us: ttft_us.unwrap_or(e2e_us), e2e_us })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The handler-side head parser and the client-side response parser
    /// are the two halves of the wire contract; pin the head parser's
    /// accept/reject behaviour here (full socket e2e lives in
    /// `tests/http_serve.rs`).
    #[test]
    fn head_parsing() {
        let h = parse_head(b"POST /v1/generate HTTP/1.1\r\nHost: x\r\nContent-Length: 12")
            .expect("valid head");
        assert_eq!(h.method, "POST");
        assert_eq!(h.path, "/v1/generate");
        assert_eq!(h.header("content-length"), Some("12"));
        assert_eq!(h.header("CONTENT-LENGTH"), Some("12"));
        assert_eq!(h.header("missing"), None);
        for bad in [
            &b"GET /"[..],                      // no version
            b"GET / HTTP/2 extra words here",   // junk after version
            b"\xff\xfe / HTTP/1.1",             // not UTF-8
            b"GET / HTTP/1.1\r\nno-colon-line", // malformed header
        ] {
            assert!(parse_head(bad).is_none(), "{bad:?} accepted");
        }
    }

    #[test]
    fn client_chunk_parser_rejects_malformed_framing() {
        use super::client::parse_response;
        let ok = b"HTTP/1.1 200 OK\r\nTransfer-Encoding: chunked\r\n\r\n5\r\nhello\r\n0\r\n\r\n";
        let r = parse_response(ok).unwrap();
        assert_eq!(r.body, b"hello");
        assert_eq!(r.chunks.len(), 1);
        for bad in [
            &b"HTTP/1.1 200 OK\r\nTransfer-Encoding: chunked\r\n\r\n5\r\nhello\r\n"[..], // no terminal
            b"HTTP/1.1 200 OK\r\nTransfer-Encoding: chunked\r\n\r\nzz\r\nhi\r\n0\r\n\r\n", // bad size
            b"HTTP/1.1 200 OK\r\nTransfer-Encoding: chunked\r\n\r\n5\r\nhelloXX0\r\n\r\n", // no CRLF
            b"HTTP/1.1 200 OK\r\nTransfer-Encoding: chunked\r\n\r\n6\r\nhello\r\n0\r\n\r\n", // short
        ] {
            assert!(parse_response(bad).is_err(), "{:?} accepted", String::from_utf8_lossy(bad));
        }
    }

    #[test]
    fn parse_generate_validates_fields() {
        let cfg = HttpConfig::default();
        let g = parse_generate(
            r#"{"prompt":[1,2,3],"variant":"dense","max_tokens":4,"stream":true,"priority":"high"}"#,
            &cfg,
        )
        .unwrap();
        assert_eq!(g.prompt, vec![1, 2, 3]);
        assert_eq!(g.gen_tokens, 4);
        assert!(g.stream);
        assert_eq!(g.priority, Priority::High);
        // Defaults: normal priority, no streaming, default token budget.
        let g = parse_generate(r#"{"prompt":[7]}"#, &cfg).unwrap();
        assert_eq!(g.gen_tokens, cfg.default_gen_tokens);
        assert!(!g.stream);
        assert_eq!(g.priority, Priority::Normal);
        for (body, frag) in [
            (r#"{"prompt":[]}"#, "empty prompt"),
            (r#"{"max_tokens":4}"#, "missing field"),
            (r#"{"prompt":[1],"max_tokens":0}"#, "max_tokens"),
            (r#"{"prompt":[1],"max_tokens":100000}"#, "max_tokens"),
            (r#"{"prompt":[1],"priority":"urgent"}"#, "priority"),
            (r#"{"prompt":[1],"stream":"yes"}"#, "bad field"),
            (r#"{"prompt":"abc"}"#, "bad field"),
            (r#"not json"#, "malformed JSON"),
            (r#"{"prompt":[1],}"#, "malformed JSON"),
        ] {
            let e = parse_generate(body, &cfg).unwrap_err();
            assert!(e.contains(frag), "{body}: expected {frag:?} in {e:?}");
        }
    }

    /// The §15 counter fix at unit scope: concurrent admit/complete/fail
    /// writers against a spinning snapshot reader — every snapshot must
    /// satisfy `admitted >= completed + failed` (no torn triple), which
    /// plain per-field atomic reads do NOT guarantee. The socket-level
    /// version (hammering `/stats` during a burst) lives in
    /// `tests/http_serve.rs`.
    #[test]
    fn seq_counters_snapshot_is_consistent_under_contention() {
        use std::sync::Arc;
        let c = Arc::new(SeqCounters::new());
        let stop = Arc::new(AtomicBool::new(false));
        let writers: Vec<_> = (0..2)
            .map(|_| {
                let c = c.clone();
                std::thread::spawn(move || {
                    for _ in 0..2000 {
                        c.admit();
                        c.complete();
                    }
                    for _ in 0..500 {
                        c.admit();
                        c.fail();
                    }
                })
            })
            .collect();
        let reader = {
            let c = c.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut reads = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let snap = c.snapshot();
                    assert!(
                        snap.admitted >= snap.completed + snap.failed,
                        "torn counter snapshot: {snap:?}"
                    );
                    reads += 1;
                }
                reads
            })
        };
        for w in writers {
            w.join().unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        assert!(reader.join().unwrap() > 0, "reader never ran");
        let fin = c.snapshot();
        assert_eq!((fin.admitted, fin.completed, fin.failed), (5000, 4000, 1000));
        assert_eq!(fin.in_flight(), 0);
    }

    #[test]
    fn sse_token_roundtrip() {
        let body = b"data: {\"token\":5}\n\ndata: {\"token\":-1}\n\ndata: {\"done\":true,\"tokens\":[5,-1]}\n\n";
        let (toks, done) = client::sse_tokens(body).unwrap();
        assert_eq!(toks, vec![5, -1]);
        assert!(done.is_some());
    }
}
