//! SSM-state slot pool — Mamba's KV-cache analogue.
//!
//! Unlike attention KV caches, Mamba decode state is FIXED SIZE per
//! sequence: (conv tail: d_conv-1 columns) + (scan state: d_inner×d_state or
//! H×P×N). That turns cache management from paging (vLLM's problem) into
//! slot allocation — but the pool still has to enforce capacity, avoid
//! double-free, and recycle slots promptly, which is what this module does
//! and what the property tests pin down.

use std::collections::BTreeSet;

use anyhow::{bail, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct Slot(pub usize);

#[derive(Debug)]
pub struct StatePool {
    capacity: usize,
    free: Vec<usize>,
    live: BTreeSet<usize>,
    /// Bytes per slot (conv + ssm state), for memory accounting.
    pub slot_bytes: usize,
    pub high_water: usize,
}

impl StatePool {
    pub fn new(capacity: usize, slot_bytes: usize) -> StatePool {
        StatePool {
            capacity,
            free: (0..capacity).rev().collect(),
            live: BTreeSet::new(),
            slot_bytes,
            high_water: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn live(&self) -> usize {
        self.live.len()
    }

    pub fn free_slots(&self) -> usize {
        self.free.len()
    }

    pub fn alloc(&mut self) -> Result<Slot> {
        match self.free.pop() {
            Some(i) => {
                self.live.insert(i);
                self.high_water = self.high_water.max(self.live.len());
                Ok(Slot(i))
            }
            None => bail!("state pool exhausted ({} slots)", self.capacity),
        }
    }

    pub fn release(&mut self, s: Slot) -> Result<()> {
        if !self.live.remove(&s.0) {
            bail!("double free of slot {}", s.0);
        }
        self.free.push(s.0);
        Ok(())
    }

    pub fn live_bytes(&self) -> usize {
        self.live.len() * self.slot_bytes
    }

    pub fn peak_bytes(&self) -> usize {
        self.high_water * self.slot_bytes
    }
}

/// Size in bytes (f32) of one slot holding `n_layer × (conv_row + ssm_row)`
/// state elements — the element-count twin of [`slot_bytes`], used by the
/// [`StateStore`](super::state_store::StateStore) which already knows its
/// per-layer row widths.
pub fn slot_bytes_raw(n_layer: usize, conv_row: usize, ssm_row: usize) -> usize {
    n_layer * (conv_row + ssm_row) * 4
}

/// Size of one sequence's decode state in bytes (f32), from model dims.
pub fn slot_bytes(arch: &str, n_layer: usize, d_inner: usize, d_state: usize, d_conv: usize, headdim: usize) -> usize {
    let conv = match arch {
        "mamba" => d_inner * (d_conv - 1),
        _ => (d_inner + 2 * d_state) * (d_conv - 1),
    };
    let ssm = match arch {
        "mamba" => d_inner * d_state,
        _ => (d_inner / headdim) * headdim * d_state, // == d_inner * d_state
    };
    n_layer * (conv + ssm) * 4
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_release_cycle() {
        let mut p = StatePool::new(2, 100);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert!(p.alloc().is_err());
        p.release(a).unwrap();
        let c = p.alloc().unwrap();
        assert_ne!(b, c); // b still live
        assert_eq!(p.live(), 2);
        assert_eq!(p.high_water, 2);
    }

    #[test]
    fn double_free_rejected() {
        let mut p = StatePool::new(1, 8);
        let a = p.alloc().unwrap();
        p.release(a).unwrap();
        assert!(p.release(a).is_err());
    }

    #[test]
    fn byte_accounting() {
        let mut p = StatePool::new(4, 1000);
        let _a = p.alloc().unwrap();
        let _b = p.alloc().unwrap();
        assert_eq!(p.live_bytes(), 2000);
        assert_eq!(p.peak_bytes(), 2000);
    }

    #[test]
    fn slot_bytes_mamba() {
        // 20 layers, di=512, n=16, k=4: (512*3 + 512*16)*20*4 bytes
        let b = slot_bytes("mamba", 20, 512, 16, 4, 64);
        assert_eq!(b, 20 * (512 * 3 + 512 * 16) * 4);
    }
}
