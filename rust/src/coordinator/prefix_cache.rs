//! Content-addressed prefix-state cache (DESIGN.md §12).
//!
//! The SSM-specific structural win over a KV cache: *any* prompt prefix
//! compresses into one constant-size per-layer `(conv tail, ssm state)`
//! pair — exactly the resume pair chunked prefill (DESIGN.md §6) already
//! carries between chunks. This module caches those pairs at chunk-aligned
//! prefix boundaries, keyed by content, so a shared system prompt is
//! prefilled once and every later request that starts with it resumes from
//! the snapshot and prefills only its remainder.
//!
//! Key derivation (why chunk-aligned): snapshots only exist at multiples of
//! the engine's prefill frame (`prefill_len`), because that is where the
//! `(conv0, ssm0)` resume inputs are bit-identical between a cold full
//! prefill and a warm resume — the chunk decomposition of the remainder is
//! the same in both runs, so the backend's per-length schedule re-solve
//! (`plan_for_len`) sees identical chunk lengths and produces identical
//! reduction schedules. A prefix cut at an arbitrary offset would change
//! the remainder's chunking and break bit-identity on reduced lanes.
//!
//! Keys are `(model, variant, prefix_len, fnv1a64(prefix tokens))`; every
//! entry also stores the prefix tokens themselves and **verifies** them on
//! lookup, so a 64-bit hash collision can never serve a wrong snapshot —
//! the bit-identity guarantee does not rest on hash uniqueness.
//!
//! Bounded by a byte budget with LRU eviction (monotonic touch tick);
//! hit/miss/insert/evict counters feed `BENCH_runtime.json` and the CI
//! smoke gate. Interior mutex: the cache is shared across engines/threads
//! behind an `Arc`, and all methods take `&self`.

use std::collections::{BTreeMap, HashMap};
use std::sync::Mutex;

/// FNV-1a 64-bit over the little-endian bytes of `tokens`. Stable, cheap,
/// dependency-free; collisions are tolerated (entries verify tokens).
pub fn fnv1a_tokens(tokens: &[i32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Cache key: model + policy variant + chunk-aligned prefix length + content
/// hash. Model and variant are part of the key because the snapshot encodes
/// the model's weights *and* the variant's reduction schedule — a `dense`
/// prefix state is not a `unified@0.2` prefix state even for identical
/// tokens.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct Key {
    model: String,
    variant: String,
    len: usize,
    hash: u64,
}

struct Entry {
    /// The exact prefix tokens — verified on lookup (collision proof).
    tokens: Vec<i32>,
    /// Per-sequence `[n_layer, conv_row]` snapshot at the boundary.
    conv: Vec<f32>,
    /// Per-sequence `[n_layer, ssm_row]` snapshot at the boundary.
    ssm: Vec<f32>,
    /// LRU touch tick (monotonic; larger = more recent).
    tick: u64,
    bytes: usize,
}

fn entry_bytes(tokens: usize, conv: usize, ssm: usize) -> usize {
    4 * (tokens + conv + ssm)
}

#[derive(Default)]
struct Inner {
    map: HashMap<Key, Entry>,
    /// tick → key index for O(log n) LRU eviction. Ticks are unique
    /// (monotonic counter), so this is a faithful recency order.
    lru: BTreeMap<u64, Key>,
    tick: u64,
    used_bytes: usize,
    hits: u64,
    misses: u64,
    inserts: u64,
    evictions: u64,
}

/// Counter snapshot for benches / logs (`BENCH_runtime.json` §prefix_cache).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups that resumed from a cached boundary (one per request).
    pub hits: u64,
    /// Lookups that found no usable boundary (one per request).
    pub misses: u64,
    pub inserts: u64,
    pub evictions: u64,
    pub used_bytes: usize,
    pub entries: usize,
}

impl CacheStats {
    /// hits / (hits + misses); 0.0 when no lookups happened.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Bounded content-addressed store of chunk-aligned prompt-prefix states.
pub struct PrefixCache {
    budget_bytes: usize,
    inner: Mutex<Inner>,
}

impl PrefixCache {
    /// A cache holding at most `budget_bytes` of snapshots (tokens + conv +
    /// ssm, 4 bytes per element). An entry larger than the whole budget is
    /// rejected at insert rather than thrashing the cache.
    pub fn new(budget_bytes: usize) -> PrefixCache {
        PrefixCache { budget_bytes, inner: Mutex::new(Inner::default()) }
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        // A panic while holding the lock cannot leave partial state that
        // breaks correctness (worst case: a stale counter), so recover.
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Longest cached chunk-aligned **proper** prefix of `prompt`: scans
    /// boundaries `k·chunk` descending from the largest strictly below
    /// `prompt.len()`. Proper because prefill must still feed at least one
    /// remainder token to produce the last-token logits the first sampled
    /// token comes from. Returns `(prefix_len, conv, ssm)` clones; counts
    /// exactly one hit or one miss per call (per request, not per
    /// boundary probed).
    pub fn longest_prefix(
        &self,
        model: &str,
        variant: &str,
        prompt: &[i32],
        chunk: usize,
    ) -> Option<(usize, Vec<f32>, Vec<f32>)> {
        if chunk == 0 || prompt.len() <= chunk {
            return None; // no chunk-aligned proper prefix exists: not a miss
        }
        let mut inner = self.lock();
        let max_k = (prompt.len() - 1) / chunk; // largest k with k·chunk < len
        for k in (1..=max_k).rev() {
            let blen = k * chunk;
            let key = Key {
                model: model.to_string(),
                variant: variant.to_string(),
                len: blen,
                hash: fnv1a_tokens(&prompt[..blen]),
            };
            let Some(e) = inner.map.get(&key) else { continue };
            if e.tokens != prompt[..blen] {
                continue; // 64-bit collision: never serve a wrong snapshot
            }
            let (conv, ssm) = (e.conv.clone(), e.ssm.clone());
            // Touch LRU.
            inner.tick += 1;
            let tick = inner.tick;
            let old = {
                let e = inner.map.get_mut(&key).unwrap();
                std::mem::replace(&mut e.tick, tick)
            };
            inner.lru.remove(&old);
            inner.lru.insert(tick, key);
            inner.hits += 1;
            return Some((blen, conv, ssm));
        }
        inner.misses += 1;
        None
    }

    /// Insert (or touch) the snapshot for `prefix` (the *exact* tokens up to
    /// a chunk boundary). Duplicate keys only refresh recency; entries over
    /// the whole budget are rejected; otherwise LRU entries are evicted
    /// until the new entry fits.
    pub fn insert(&self, model: &str, variant: &str, prefix: &[i32], conv: &[f32], ssm: &[f32]) {
        let bytes = entry_bytes(prefix.len(), conv.len(), ssm.len());
        if bytes > self.budget_bytes || prefix.is_empty() {
            return;
        }
        let key = Key {
            model: model.to_string(),
            variant: variant.to_string(),
            len: prefix.len(),
            hash: fnv1a_tokens(prefix),
        };
        let mut inner = self.lock();
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(e) = inner.map.get_mut(&key) {
            if e.tokens == prefix {
                let old = std::mem::replace(&mut e.tick, tick);
                inner.lru.remove(&old);
                inner.lru.insert(tick, key);
                return;
            }
            // Same key, different tokens (collision): replace — both states
            // are valid for *their* tokens, keep the most recent.
            let old = inner.map.remove(&key).unwrap();
            inner.lru.remove(&old.tick);
            inner.used_bytes -= old.bytes;
        }
        // Evict least-recently-used until the new entry fits.
        while inner.used_bytes + bytes > self.budget_bytes {
            let Some((&old_tick, _)) = inner.lru.iter().next() else { break };
            let old_key = inner.lru.remove(&old_tick).unwrap();
            let old = inner.map.remove(&old_key).unwrap();
            inner.used_bytes -= old.bytes;
            inner.evictions += 1;
        }
        inner.used_bytes += bytes;
        inner.inserts += 1;
        inner.map.insert(
            key.clone(),
            Entry { tokens: prefix.to_vec(), conv: conv.to_vec(), ssm: ssm.to_vec(), tick, bytes },
        );
        inner.lru.insert(tick, key);
    }

    /// Drop every cached snapshot. Called by `Engine::hot_swap_weights`
    /// (DESIGN.md §15): a snapshot encodes the weights that produced it, so
    /// resident entries are poison the instant new weights go live.
    /// Cumulative hit/miss/insert/evict counters survive — only entries die
    /// (the cleared bytes are not counted as evictions; they were not
    /// pushed out by pressure).
    pub fn clear(&self) {
        let mut inner = self.lock();
        inner.map.clear();
        inner.lru.clear();
        inner.used_bytes = 0;
    }

    pub fn stats(&self) -> CacheStats {
        let inner = self.lock();
        CacheStats {
            hits: inner.hits,
            misses: inner.misses,
            inserts: inner.inserts,
            evictions: inner.evictions,
            used_bytes: inner.used_bytes,
            entries: inner.map.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(n: usize, salt: i32) -> Vec<i32> {
        (0..n).map(|i| i as i32 * 3 + salt).collect()
    }

    #[test]
    fn longest_boundary_wins_and_counts_one_hit() {
        let c = PrefixCache::new(1 << 20);
        let p = toks(70, 1);
        c.insert("m", "dense", &p[..32], &[1.0; 8], &[2.0; 4]);
        c.insert("m", "dense", &p[..64], &[3.0; 8], &[4.0; 4]);
        let (len, conv, ssm) = c.longest_prefix("m", "dense", &p, 32).unwrap();
        assert_eq!(len, 64);
        assert_eq!(conv, vec![3.0; 8]);
        assert_eq!(ssm, vec![4.0; 4]);
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (1, 0));
        assert!((s.hit_rate() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn proper_prefix_only_never_whole_prompt() {
        let c = PrefixCache::new(1 << 20);
        let p = toks(64, 2);
        c.insert("m", "dense", &p[..64], &[1.0; 8], &[1.0; 4]);
        c.insert("m", "dense", &p[..32], &[5.0; 8], &[6.0; 4]);
        // A 64-token prompt may resume from 32, never from 64 — at least one
        // remainder token must be prefilled for the last-token logits.
        let (len, ..) = c.longest_prefix("m", "dense", &p, 32).unwrap();
        assert_eq!(len, 32);
        // One-chunk prompts have no usable boundary at all (and are not
        // counted as misses — nothing was probed).
        assert!(c.longest_prefix("m", "dense", &p[..32], 32).is_none());
        assert!(c.longest_prefix("m", "dense", &p[..20], 32).is_none());
        assert_eq!(c.stats().misses, 0);
    }

    #[test]
    fn model_and_variant_partition_the_key_space() {
        let c = PrefixCache::new(1 << 20);
        let p = toks(40, 3);
        c.insert("m", "dense", &p[..32], &[1.0; 8], &[1.0; 4]);
        assert!(c.longest_prefix("m", "unified@0.2", &p, 32).is_none());
        assert!(c.longest_prefix("other", "dense", &p, 32).is_none());
        assert!(c.longest_prefix("m", "dense", &p, 32).is_some());
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (1, 2));
    }

    #[test]
    fn content_mismatch_is_a_miss() {
        let c = PrefixCache::new(1 << 20);
        let p = toks(40, 4);
        c.insert("m", "dense", &p[..32], &[1.0; 8], &[1.0; 4]);
        let mut q = p.clone();
        q[5] ^= 1; // different prefix content, same length
        assert!(c.longest_prefix("m", "dense", &q, 32).is_none());
        assert_eq!(c.stats().misses, 1);
    }

    #[test]
    fn lru_eviction_respects_byte_budget_and_recency() {
        // Each entry: 32 tokens + 8 conv + 4 ssm = 44 elems = 176 bytes.
        let one = entry_bytes(32, 8, 4);
        let c = PrefixCache::new(2 * one);
        let (a, b, d) = (toks(32, 10), toks(32, 11), toks(32, 12));
        c.insert("m", "dense", &a, &[1.0; 8], &[1.0; 4]);
        c.insert("m", "dense", &b, &[2.0; 8], &[2.0; 4]);
        assert_eq!(c.stats().used_bytes, 2 * one);
        // Touch `a` so `b` becomes the LRU victim.
        let mut a_long = a.clone();
        a_long.extend(toks(8, 13));
        assert!(c.longest_prefix("m", "dense", &a_long, 32).is_some());
        c.insert("m", "dense", &d, &[3.0; 8], &[3.0; 4]);
        let s = c.stats();
        assert_eq!(s.evictions, 1);
        assert_eq!(s.entries, 2);
        assert!(s.used_bytes <= 2 * one);
        let mut b_long = b.clone();
        b_long.push(0);
        assert!(c.longest_prefix("m", "dense", &b_long, 32).is_none(), "b was evicted");
        assert!(c.longest_prefix("m", "dense", &a_long, 32).is_some(), "a survived");
        let mut d_long = d.clone();
        d_long.push(0);
        assert!(c.longest_prefix("m", "dense", &d_long, 32).is_some(), "d resident");
    }

    #[test]
    fn oversized_entries_are_rejected_duplicates_only_touch() {
        let c = PrefixCache::new(64);
        c.insert("m", "dense", &toks(32, 5), &[0.0; 64], &[0.0; 64]);
        assert_eq!(c.stats().entries, 0, "entry larger than the budget must be rejected");
        let c = PrefixCache::new(1 << 20);
        let p = toks(32, 6);
        c.insert("m", "dense", &p, &[1.0; 8], &[1.0; 4]);
        c.insert("m", "dense", &p, &[1.0; 8], &[1.0; 4]);
        let s = c.stats();
        assert_eq!(s.inserts, 1, "duplicate insert only refreshes recency");
        assert_eq!(s.entries, 1);
    }

    #[test]
    fn clear_drops_entries_but_keeps_cumulative_counters() {
        let c = PrefixCache::new(1 << 20);
        let p = toks(40, 7);
        c.insert("m", "dense", &p[..32], &[1.0; 8], &[1.0; 4]);
        assert!(c.longest_prefix("m", "dense", &p, 32).is_some());
        c.clear();
        let s = c.stats();
        assert_eq!((s.entries, s.used_bytes), (0, 0));
        assert_eq!((s.hits, s.inserts, s.evictions), (1, 1, 0));
        assert!(c.longest_prefix("m", "dense", &p, 32).is_none(), "stale snapshot served");
    }

    #[test]
    fn fnv_is_stable_and_order_sensitive() {
        // Pinned reference value: the FNV-1a-64 offset basis (empty input).
        assert_eq!(fnv1a_tokens(&[]), 0xcbf2_9ce4_8422_2325);
        assert_ne!(fnv1a_tokens(&[1, 2]), fnv1a_tokens(&[2, 1]));
        assert_eq!(fnv1a_tokens(&[7, 9]), fnv1a_tokens(&[7, 9]));
    }
}
