//! Multi-replica engine pool (DESIGN.md §15): N engine replicas of one
//! serving lane behind pluggable placement, per-replica health, and a
//! rolling hot-upgrade state machine.
//!
//! A [`ReplicaPool`] owns one [`Scheduler`] per replica engine and fans a
//! lane's requests across them. Because every serving path samples with
//! greedy first-max-wins argmax and prompts flow through prefill/decode
//! independently of their frame neighbours (DESIGN.md §6), **placement is
//! bit-invisible**: the tokens a request generates do not depend on which
//! replica served it, how loaded that replica was, or who shared its
//! frames. That is the correctness contract `tests/replica_pool.rs` and
//! the `replicas` section of `BENCH_runtime.json` pin — any pool
//! configuration must produce token streams identical to a single-engine
//! scheduler.
//!
//! ## Placement
//!
//! * [`Placement::LeastLoaded`] — fewest in-flight sequences wins, ties to
//!   the lowest index. Best spread under mixed request lengths.
//! * [`Placement::PrefixHash`] — rendezvous (highest-random-weight) hash of
//!   the prompt's first prefill-frame of tokens. Requests sharing a
//!   chunk-aligned prefix land on the same replica, so that replica's
//!   [`PrefixCache`](super::prefix_cache::PrefixCache) stays hot
//!   (DESIGN.md §12) without any cross-replica cache traffic. Rendezvous
//!   hashing keeps the remap bound on membership change minimal — when a
//!   replica joins or leaves, only the keys whose winner changed move
//!   (≈ K/N of them; property-tested in `tests/prop_replica.rs`).
//!
//! ## Health + heartbeat
//!
//! Each replica is `Up`, `Draining`, or `Down`
//! ([`Health`]), driven by a heartbeat window of its recent step outcomes:
//! a step error marks the replica Down immediately (failover), and a
//! replica whose recent mean step latency exceeds the configured threshold
//! drains until it cools. Non-`Up` replicas **admit nothing** — their
//! queued (never-prefilled, zero tokens emitted) requests re-route to a
//! healthy replica losslessly via [`Scheduler::take_queued`], while
//! `Draining` residents finish where they are and `Down` residents fail
//! typed (their sinks already fired; replaying them elsewhere would
//! duplicate observed tokens).
//!
//! ## Rolling upgrade
//!
//! [`ReplicaPool::advance_upgrade`] walks replicas one at a time:
//! Up → Draining (shed queue, finish residents) → idle → hot-swap weights
//! ([`Engine::hot_swap_weights`], which also clears the prefix cache) →
//! Up. At most one replica is out of service at any tick; a sequence never
//! spans a swap, so weights are never mixed within one request.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::{anyhow, ensure, Result};

use crate::runtime::DeviceWeights;

use super::engine::Engine;
use super::prefix_cache::fnv1a_tokens;
use super::scheduler::{Scheduler, TokenSink};
use super::{Request, Response};

/// Heartbeat window length: step outcomes per replica the health policy
/// looks back over.
const WINDOW: usize = 32;

/// Per-replica serving state (DESIGN.md §15 state machine).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Health {
    /// Serving and admitting.
    Up,
    /// Finishing residents, admitting nothing (shutdown shed, latency
    /// shed, or awaiting an upgrade swap).
    Draining,
    /// Failed: residents failed typed, queue re-routed, scheduler reset.
    Down,
}

impl Health {
    pub fn name(&self) -> &'static str {
        match self {
            Health::Up => "up",
            Health::Draining => "draining",
            Health::Down => "down",
        }
    }
}

/// Placement policy for new requests across a pool's `Up` replicas.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// Fewest in-flight sequences wins; ties break to the lowest index.
    LeastLoaded,
    /// Rendezvous hash of the prompt's first prefill-frame of tokens —
    /// prefix-affine, so per-replica prefix caches stay hot.
    PrefixHash,
}

impl Placement {
    /// Parse the `--placement` flag value.
    pub fn from_name(name: &str) -> Result<Placement> {
        match name {
            "least-loaded" | "" => Ok(Placement::LeastLoaded),
            "hash" | "prefix-hash" => Ok(Placement::PrefixHash),
            other => Err(anyhow!("unknown placement {other:?} (expected least-loaded|hash)")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Placement::LeastLoaded => "least-loaded",
            Placement::PrefixHash => "hash",
        }
    }
}

/// SplitMix64 finalizer: a cheap full-avalanche bijection on `u64` — the
/// mixing step rendezvous scoring relies on.
pub fn mix64(mut x: u64) -> u64 {
    x ^= x >> 33;
    x = x.wrapping_mul(0xff51_afd7_ed55_8ccd);
    x ^= x >> 33;
    x = x.wrapping_mul(0xc4ce_b9fe_1a85_ec53);
    x ^= x >> 33;
    x
}

/// Stable per-replica rendezvous seed. Depends only on the replica's
/// index, never on pool membership — which is exactly why a join/leave
/// remaps only the keys whose argmax changed (`tests/prop_replica.rs`).
pub fn replica_seed(index: usize) -> u64 {
    mix64(0x9e37_79b9_7f4a_7c15u64.wrapping_mul(index as u64 + 1))
}

/// Rendezvous score of `key` on the replica with `seed`.
pub fn hrw_score(key: u64, seed: u64) -> u64 {
    mix64(key ^ seed)
}

/// Highest-random-weight winner among `eligible` replica indices: the
/// index with the maximal [`hrw_score`]; equal scores (measure-zero under
/// `mix64`'s avalanche, but the tie-break must still be total) go to the
/// lowest index. `None` iff `eligible` is empty.
pub fn pick_hrw(key: u64, eligible: &[usize]) -> Option<usize> {
    eligible.iter().copied().max_by(|&a, &b| {
        hrw_score(key, replica_seed(a))
            .cmp(&hrw_score(key, replica_seed(b)))
            .then(b.cmp(&a)) // equal scores: lower index wins the max
    })
}

/// Placement key of a prompt: FNV-1a over its first `chunk` tokens (the
/// whole prompt when shorter). `chunk` is the engine's prefill frame — the
/// same boundary the prefix cache snapshots on — so requests sharing a
/// cached system-prompt prefix hash identically and stay replica-local.
pub fn placement_key(prompt: &[i32], chunk: usize) -> u64 {
    let n = if chunk == 0 { prompt.len() } else { prompt.len().min(chunk) };
    fnv1a_tokens(prompt.get(..n).unwrap_or(prompt))
}

/// A request the pool could not serve: mid-stream on a replica that died
/// (typed, never silently dropped), or re-routable but with no healthy
/// replica left to take it.
#[derive(Debug, Clone)]
pub struct PoolFailure {
    pub id: u64,
    /// Replica the request was on when it failed.
    pub replica: usize,
    pub error: String,
}

/// Counter snapshot of one replica for `/stats` and the bench report.
#[derive(Debug, Clone)]
pub struct ReplicaStat {
    pub health: Health,
    pub in_flight: usize,
    pub completed: u64,
    pub failed: u64,
    pub prefills: u64,
    pub decode_steps: u64,
    pub preemptions: u64,
    /// Errors in the recent heartbeat window.
    pub recent_errors: u32,
    /// Mean step wall time over the recent heartbeat window, µs.
    pub mean_step_us: u64,
    pub weights_tag: String,
}

/// Sliding window of recent step outcomes — the heartbeat the health
/// policy reads.
#[derive(Default)]
struct Heartbeat {
    window: VecDeque<(bool, u64)>,
    errors: u32,
    sum_us: u64,
}

impl Heartbeat {
    fn record(&mut self, ok: bool, us: u64) {
        self.window.push_back((ok, us));
        if !ok {
            self.errors += 1;
        }
        self.sum_us += us;
        while self.window.len() > WINDOW {
            let Some((old_ok, old_us)) = self.window.pop_front() else { break };
            if !old_ok {
                self.errors -= 1;
            }
            self.sum_us -= old_us;
        }
    }

    fn mean_us(&self) -> u64 {
        if self.window.is_empty() {
            0
        } else {
            self.sum_us / self.window.len() as u64
        }
    }

    fn full(&self) -> bool {
        self.window.len() >= WINDOW
    }

    fn reset(&mut self) {
        self.window.clear();
        self.errors = 0;
        self.sum_us = 0;
    }
}

struct Replica<'e> {
    engine: &'e Engine,
    sched: Scheduler<'e>,
    health: Health,
    /// Whether the current `Draining` was imposed by the latency policy
    /// (auto-recovers when the replica cools or empties) rather than by an
    /// explicit drain or an upgrade (which never auto-recover).
    slow_drain: bool,
    beat: Heartbeat,
    completed: u64,
    failed: u64,
}

/// N engine replicas of one serving lane behind one submit/step façade —
/// same driving surface as a single [`Scheduler`], so callers (the HTTP
/// front-end, the trace path, the benches) swap in transparently.
pub struct ReplicaPool<'e> {
    replicas: Vec<Replica<'e>>,
    placement: Placement,
    /// Mean-recent-step-latency threshold (µs) above which an `Up` replica
    /// drains until it cools to half the threshold. `None` disables the
    /// latency policy (errors still drive `Down`).
    slow_step_us: Option<u64>,
    /// Prefix length the hash placement keys on (the engines' prefill
    /// frame).
    chunk: usize,
    /// Requests moved off a non-`Up` replica before prefill (lossless).
    pub reroutes: u64,
    failures: Vec<PoolFailure>,
}

impl<'e> ReplicaPool<'e> {
    /// A pool over `engines`, all replicas of the **same** lane (same
    /// model + variant — placement must be free to pick any of them).
    pub fn new(engines: &'e [Engine], placement: Placement) -> Result<ReplicaPool<'e>> {
        let Some(first) = engines.first() else {
            return Err(anyhow!("replica pool needs at least one engine"));
        };
        for e in engines {
            ensure!(
                e.model_name == first.model_name && e.variant == first.variant,
                "replica pool mixes lanes: {}/{} vs {}/{} (one pool serves one lane; \
                 cross-lane routing is the Router's job)",
                e.model_name,
                e.variant,
                first.model_name,
                first.variant
            );
        }
        Ok(ReplicaPool {
            replicas: engines
                .iter()
                .map(|engine| Replica {
                    engine,
                    sched: Scheduler::new(engine),
                    health: Health::Up,
                    slow_drain: false,
                    beat: Heartbeat::default(),
                    completed: 0,
                    failed: 0,
                })
                .collect(),
            placement,
            slow_step_us: None,
            chunk: first.prefill_len,
            reroutes: 0,
            failures: Vec::new(),
        })
    }

    /// Enable the latency arm of the heartbeat: a full window whose mean
    /// step time exceeds `us` drains the replica until it cools to `us/2`
    /// (or empties).
    pub fn with_slow_threshold(mut self, us: Option<u64>) -> Self {
        self.slow_step_us = us;
        self
    }

    pub fn len(&self) -> usize {
        self.replicas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.replicas.is_empty()
    }

    pub fn placement(&self) -> Placement {
        self.placement
    }

    /// Health of replica `r`. An out-of-range index reads as `Down` (no
    /// such replica is serving) rather than panicking a caller thread.
    pub fn health(&self, r: usize) -> Health {
        self.replicas.get(r).map(|rep| rep.health).unwrap_or(Health::Down)
    }

    /// Explicitly drain replica `r`: admit nothing, finish residents,
    /// re-route its queue on the next heartbeat. Never auto-recovers.
    pub fn set_draining(&mut self, r: usize) {
        if let Some(rep) = self.replicas.get_mut(r) {
            if rep.health == Health::Up {
                rep.health = Health::Draining;
                rep.slow_drain = false;
            }
        }
    }

    /// Return a Draining or Down replica to service with a clean slate.
    pub fn revive(&mut self, r: usize) {
        let Some(rep) = self.replicas.get_mut(r) else { return };
        if rep.health == Health::Down {
            rep.sched = Scheduler::new(rep.engine);
        }
        rep.health = Health::Up;
        rep.slow_drain = false;
        rep.beat.reset();
    }

    /// Typed failures accumulated since the last call (mid-stream requests
    /// on a dead replica, or re-routes with no healthy target). Callers
    /// own delivering these to waiters — the HTTP loop turns them into
    /// `Fail` events; nothing here hangs.
    pub fn take_failures(&mut self) -> Vec<PoolFailure> {
        std::mem::take(&mut self.failures)
    }

    /// True when every replica's scheduler is empty.
    pub fn is_idle(&self) -> bool {
        self.replicas.iter().all(|r| r.sched.is_idle())
    }

    pub fn in_flight(&self) -> usize {
        self.replicas.iter().map(|r| r.sched.in_flight()).sum()
    }

    /// Placement decision for `prompt` over the current `Up` set; `None`
    /// when no replica is admitting.
    fn pick_for(&self, prompt: &[i32]) -> Option<usize> {
        let up = || {
            self.replicas
                .iter()
                .enumerate()
                .filter(|(_, rep)| rep.health == Health::Up)
        };
        match self.placement {
            Placement::LeastLoaded => {
                up().min_by_key(|&(i, rep)| (rep.sched.in_flight(), i)).map(|(i, _)| i)
            }
            Placement::PrefixHash => {
                let eligible: Vec<usize> = up().map(|(i, _)| i).collect();
                pick_hrw(placement_key(prompt, self.chunk), &eligible)
            }
        }
    }

    /// Submit to the placed replica; returns its index (observability +
    /// the Draining-admits-nothing test). Fails only when no replica is
    /// `Up` — placement never silently queues on a draining/dead replica.
    pub fn submit(&mut self, req: Request) -> Result<usize> {
        let r = self
            .pick_for(&req.prompt)
            .ok_or_else(|| anyhow!("no healthy replica (all draining or down)"))?;
        let Some(rep) = self.replicas.get_mut(r) else {
            return Err(anyhow!("placement picked replica {r} out of range"));
        };
        rep.sched.submit(req);
        Ok(r)
    }

    /// [`Self::submit`] with a streaming [`TokenSink`] (survives a
    /// pre-prefill re-route: the sink moves with the request).
    pub fn submit_with_sink(&mut self, req: Request, sink: TokenSink) -> Result<usize> {
        let r = self
            .pick_for(&req.prompt)
            .ok_or_else(|| anyhow!("no healthy replica (all draining or down)"))?;
        let Some(rep) = self.replicas.get_mut(r) else {
            return Err(anyhow!("placement picked replica {r} out of range"));
        };
        rep.sched.submit_with_sink(req, sink);
        Ok(r)
    }

    /// Move replica `r`'s queued (never-prefilled) requests to healthy
    /// replicas. Zero tokens have been emitted for these, so the move is
    /// invisible to clients; with nowhere to go they fail typed instead of
    /// hanging.
    fn shed_queued(&mut self, r: usize) {
        let moved = match self.replicas.get_mut(r) {
            Some(rep) => rep.sched.take_queued(),
            None => return,
        };
        for (req, sink) in moved {
            let placed = match self.pick_for(&req.prompt) {
                Some(target) => self.replicas.get_mut(target),
                None => None,
            };
            match placed {
                Some(rep) => {
                    self.reroutes += 1;
                    match sink {
                        Some(s) => rep.sched.submit_with_sink(req, s),
                        None => rep.sched.submit(req),
                    }
                }
                None => {
                    if let Some(rep) = self.replicas.get_mut(r) {
                        rep.failed += 1;
                    }
                    self.failures.push(PoolFailure {
                        id: req.id,
                        replica: r,
                        error: "no healthy replica to re-route to".to_string(),
                    });
                }
            }
        }
    }

    /// Replica `r`'s step failed: mark it Down, fail its mid-stream
    /// sequences typed (their sinks already fired — transparent replay
    /// would duplicate observed tokens), re-route its untouched queue, and
    /// reset its scheduler so a later [`Self::revive`] starts clean.
    fn fail_replica(&mut self, r: usize, err: &str) {
        let active = {
            let Some(rep) = self.replicas.get_mut(r) else { return };
            rep.health = Health::Down;
            rep.slow_drain = false;
            let active = rep.sched.active_ids();
            rep.failed += active.len() as u64;
            active
        };
        for id in active {
            self.failures.push(PoolFailure {
                id,
                replica: r,
                error: format!("replica {r} down: {err}"),
            });
        }
        self.shed_queued(r);
        if let Some(rep) = self.replicas.get_mut(r) {
            rep.sched = Scheduler::new(rep.engine);
        }
    }

    /// Evaluate every replica's heartbeat window: flip `Up` replicas whose
    /// recent mean step latency exceeds the threshold to `Draining`,
    /// recover latency-drained replicas that cooled or emptied, and shed
    /// the queue of every non-`Up` replica.
    fn heartbeat(&mut self) {
        let thr_opt = self.slow_step_us;
        for r in 0..self.replicas.len() {
            let mut shed = false;
            if let Some(rep) = self.replicas.get_mut(r) {
                if let Some(thr) = thr_opt {
                    match rep.health {
                        Health::Up if rep.beat.full() && rep.beat.mean_us() > thr => {
                            rep.health = Health::Draining;
                            rep.slow_drain = true;
                        }
                        Health::Draining
                            if rep.slow_drain
                                && (rep.beat.mean_us() <= thr / 2 || rep.sched.is_idle()) =>
                        {
                            rep.health = Health::Up;
                            rep.slow_drain = false;
                            rep.beat.reset();
                        }
                        _ => {}
                    }
                }
                shed = rep.health != Health::Up;
            }
            if shed {
                self.shed_queued(r);
            }
        }
    }

    /// One pool iteration: heartbeat, then step every live replica that
    /// has work. Replica errors are absorbed here — failover runs inline
    /// ([`Self::fail_replica`]) and the affected requests surface through
    /// [`Self::take_failures`], so the pool itself never errors out from a
    /// single replica's death.
    pub fn step(&mut self) -> Vec<Response> {
        self.heartbeat();
        let mut done = Vec::new();
        for r in 0..self.replicas.len() {
            // Step inside a scope that borrows only this replica, so the
            // failure path below can take `&mut self` for fail_replica.
            let outcome = {
                let Some(rep) = self.replicas.get_mut(r) else { continue };
                if rep.health == Health::Down || rep.sched.is_idle() {
                    continue;
                }
                let t0 = Instant::now();
                match rep.sched.step() {
                    Ok(resps) => {
                        rep.beat.record(true, t0.elapsed().as_micros() as u64);
                        rep.completed += resps.len() as u64;
                        Ok(resps)
                    }
                    Err(e) => {
                        rep.beat.record(false, 0);
                        Err(format!("{e:#}"))
                    }
                }
            };
            match outcome {
                Ok(resps) => done.extend(resps),
                Err(msg) => self.fail_replica(r, &msg),
            }
        }
        done
    }

    /// Step until idle, collecting every response. Terminates even under
    /// failures: a Down replica's scheduler is reset (idle), its work
    /// re-routed or failed typed.
    pub fn drain(&mut self) -> Vec<Response> {
        let mut out = Vec::new();
        while !self.is_idle() {
            out.extend(self.step());
        }
        out
    }

    /// Drive one tick of a rolling upgrade to registry tag `tag`. At most
    /// one replica is out of service at a time; the rest keep serving.
    /// Sequence per replica (DESIGN.md §15): Up → Draining (queue shed,
    /// residents finish) → idle → `hot_swap_weights(load()?, tag)` → Up.
    /// `Down` replicas swap immediately (their scheduler is already reset)
    /// but stay Down. Returns `Ok(true)` once every replica carries `tag`.
    /// `load` runs once per swap — typically
    /// `|| registry.hot_load(&rt, &model, tag)`.
    pub fn advance_upgrade<F>(&mut self, tag: &str, mut load: F) -> Result<bool>
    where
        F: FnMut() -> Result<DeviceWeights>,
    {
        let Some(r) = self
            .replicas
            .iter()
            .position(|rep| rep.engine.weights_tag() != tag)
        else {
            return Ok(true);
        };
        match self.health(r) {
            Health::Up => {
                if let Some(rep) = self.replicas.get_mut(r) {
                    rep.health = Health::Draining;
                    rep.slow_drain = false;
                }
                self.shed_queued(r);
            }
            Health::Draining => {
                let idle = self.replicas.get(r).is_some_and(|rep| rep.sched.is_idle());
                if idle {
                    let w = load()?;
                    if let Some(rep) = self.replicas.get_mut(r) {
                        rep.engine.hot_swap_weights(w, tag);
                        rep.health = Health::Up;
                    }
                } // else: residents still finishing
            }
            Health::Down => {
                let w = load()?;
                if let Some(rep) = self.replicas.get_mut(r) {
                    rep.engine.hot_swap_weights(w, tag);
                }
            }
        }
        Ok(false)
    }

    /// Per-replica counter snapshot for `/stats` and the bench report.
    pub fn replica_stats(&self) -> Vec<ReplicaStat> {
        self.replicas
            .iter()
            .map(|rep| ReplicaStat {
                health: rep.health,
                in_flight: rep.sched.in_flight(),
                completed: rep.completed,
                failed: rep.failed,
                prefills: rep.sched.prefill_calls,
                decode_steps: rep.sched.decode_steps,
                preemptions: rep.sched.preemptions,
                recent_errors: rep.beat.errors,
                mean_step_us: rep.beat.mean_us(),
                weights_tag: rep.engine.weights_tag(),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_parse_roundtrip() {
        assert_eq!(Placement::from_name("least-loaded").unwrap(), Placement::LeastLoaded);
        assert_eq!(Placement::from_name("").unwrap(), Placement::LeastLoaded);
        assert_eq!(Placement::from_name("hash").unwrap(), Placement::PrefixHash);
        assert_eq!(Placement::from_name("prefix-hash").unwrap(), Placement::PrefixHash);
        assert!(Placement::from_name("random").is_err());
        for p in [Placement::LeastLoaded, Placement::PrefixHash] {
            assert_eq!(Placement::from_name(p.name()).unwrap(), p);
        }
    }

    #[test]
    fn hrw_pick_is_deterministic_and_membership_stable() {
        let key = placement_key(&[5, 6, 7, 8], 32);
        let full: Vec<usize> = (0..4).collect();
        let winner = pick_hrw(key, &full).unwrap();
        assert_eq!(pick_hrw(key, &full).unwrap(), winner, "pure function");
        // Removing a non-winning replica never moves the key: the winner's
        // score is unchanged and still maximal over the subset.
        let without_loser: Vec<usize> =
            full.iter().copied().filter(|&i| i != (winner + 1) % 4).collect();
        assert_eq!(pick_hrw(key, &without_loser).unwrap(), winner);
        assert!(pick_hrw(key, &[]).is_none());
    }

    #[test]
    fn placement_key_is_prefix_bounded() {
        let long: Vec<i32> = (0..100).collect();
        // Only the first `chunk` tokens matter — a shared system prompt
        // maps to one replica regardless of the request's tail.
        assert_eq!(placement_key(&long, 32), placement_key(&long[..32], 32));
        let mut other = long.clone();
        other[80] = -9;
        assert_eq!(placement_key(&long, 32), placement_key(&other, 32));
        other[3] = -9;
        assert_ne!(placement_key(&long, 32), placement_key(&other, 32));
        // chunk == 0 hashes the whole prompt (degenerate but total).
        assert_ne!(placement_key(&long, 0), placement_key(&long[..32], 0));
    }

    #[test]
    fn heartbeat_window_arithmetic() {
        let mut b = Heartbeat::default();
        assert_eq!(b.mean_us(), 0);
        for _ in 0..WINDOW {
            b.record(true, 100);
        }
        assert!(b.full());
        assert_eq!((b.mean_us(), b.errors), (100, 0));
        // Window slides: an error ages out after WINDOW more samples.
        b.record(false, 0);
        assert_eq!(b.errors, 1);
        for _ in 0..WINDOW {
            b.record(true, 200);
        }
        assert_eq!((b.mean_us(), b.errors), (200, 0));
        b.reset();
        assert_eq!((b.mean_us(), b.errors), (0, 0));
    }

    #[test]
    fn mix64_avalanche_sanity() {
        // Pure bijection sanity: distinct inputs stay distinct, and a
        // 1-bit flip moves many output bits (weak avalanche check).
        assert_ne!(mix64(0), mix64(1));
        let d = (mix64(0x1234) ^ mix64(0x1235)).count_ones();
        assert!(d >= 16, "1-bit flip moved only {d} output bits");
        assert_ne!(replica_seed(0), replica_seed(1));
    }
}
