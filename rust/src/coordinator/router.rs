//! Variant router: assigns requests to model-variant lanes.
//!
//! Policies:
//! * explicit — the request names its variant;
//! * least-loaded — pick the lane with the shortest queue (ties broken by
//!   declaration order, making the policy deterministic and testable);
//! * cost-aware — prefer reduced variants for long prompts (they save
//!   proportionally more prefill FLOPs), dense for short ones.
//!
//! Lane names are opaque keys to the router, but in the serving stack they
//! are reduction-policy variants (`dense`, `<policy>@<ratio>[:<metric>]` —
//! DESIGN.md §10), validated by `engine::parse_variant` when each lane's
//! engine is built, before any request is queued. [`Router::route`]
//! distinguishes a malformed explicit variant from a well-formed one that
//! simply has no lane, so callers get an actionable error either way.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::Result;

use super::Request;

/// Typed routing failure. The serving front-end (DESIGN.md §14) maps these
/// onto HTTP statuses — a client-side mistake (`Malformed`, `NeedsVariant`)
/// is 400, a well-formed variant this deployment doesn't serve
/// (`Unserved`) is 404 — so the distinction [`Router::route`] used to
/// encode only in message text is available structurally.
#[derive(Debug)]
pub enum RouteError {
    /// The variant string fails the `<policy>@<ratio>[:<metric>]` grammar.
    Malformed { variant: String, err: String },
    /// The variant is well-formed but no lane serves it.
    Unserved { variant: String, lanes: Vec<String> },
    /// Explicit policy, but the request named no variant.
    NeedsVariant,
}

impl fmt::Display for RouteError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Keep the exact message shapes route() has always produced —
        // callers (and tests) match on these substrings.
        match self {
            RouteError::Malformed { variant, err } => {
                write!(f, "invalid variant {variant:?}: {err}")
            }
            RouteError::Unserved { variant, lanes } => {
                write!(f, "no lane serves variant {variant:?} (lanes: {lanes:?})")
            }
            RouteError::NeedsVariant => write!(f, "explicit policy requires request.variant"),
        }
    }
}

impl std::error::Error for RouteError {}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    Explicit,
    LeastLoaded,
    CostAware { long_prompt: usize },
}

#[derive(Debug)]
pub struct Router {
    pub policy: Policy,
    /// lane name -> current queue depth (maintained by the serve loop).
    depths: BTreeMap<String, usize>,
    /// lanes in declaration order (deterministic tie-break).
    order: Vec<String>,
    pub routed: u64,
}

impl Router {
    pub fn new(policy: Policy, lanes: &[&str]) -> Router {
        Router {
            policy,
            depths: lanes.iter().map(|l| (l.to_string(), 0)).collect(),
            order: lanes.iter().map(|s| s.to_string()).collect(),
            routed: 0,
        }
    }

    pub fn lanes(&self) -> &[String] {
        &self.order
    }

    pub fn note_enqueued(&mut self, lane: &str) {
        *self.depths.get_mut(lane).expect("unknown lane") += 1;
    }

    pub fn note_done(&mut self, lane: &str) {
        let d = self.depths.get_mut(lane).expect("unknown lane");
        *d = d.saturating_sub(1);
    }

    pub fn depth(&self, lane: &str) -> usize {
        self.depths.get(lane).copied().unwrap_or(0)
    }

    pub fn route(&mut self, req: &Request) -> Result<String> {
        self.route_checked(req).map_err(anyhow::Error::from)
    }

    /// [`Router::route`] with a typed error, so HTTP callers can pick a
    /// status code without parsing message text.
    pub fn route_checked(&mut self, req: &Request) -> std::result::Result<String, RouteError> {
        self.routed += 1;
        if !req.variant.is_empty() {
            if !self.depths.contains_key(&req.variant) {
                // Malformed variant vs. valid-but-unserved: different fixes
                // (correct the request vs. add the lane), so say which.
                if let Err(e) = crate::reduction::policy::PolicySpec::parse(&req.variant) {
                    return Err(RouteError::Malformed {
                        variant: req.variant.clone(),
                        err: format!("{e:#}"),
                    });
                }
                return Err(RouteError::Unserved {
                    variant: req.variant.clone(),
                    lanes: self.order.clone(),
                });
            }
            return Ok(req.variant.clone());
        }
        match self.policy {
            Policy::Explicit => Err(RouteError::NeedsVariant),
            Policy::LeastLoaded => Ok(self
                .order
                .iter()
                .min_by_key(|l| self.depths[*l])
                .expect("no lanes")
                .clone()),
            Policy::CostAware { long_prompt } => {
                // Long prompts gain most from token reduction; short prompts
                // keep full fidelity.
                let reduced: Vec<&String> =
                    self.order.iter().filter(|l| l.as_str() != "dense").collect();
                if req.prompt.len() >= long_prompt && !reduced.is_empty() {
                    Ok(reduced
                        .into_iter()
                        .min_by_key(|l| self.depths[*l])
                        .unwrap()
                        .clone())
                } else if self.depths.contains_key("dense") {
                    Ok("dense".to_string())
                } else {
                    Ok(self.order[0].clone())
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(variant: &str, prompt_len: usize) -> Request {
        Request {
            id: 0,
            prompt: vec![1; prompt_len],
            gen_tokens: 1,
            variant: variant.to_string(),
            arrived_us: 0,
            priority: Default::default(),
        }
    }

    #[test]
    fn explicit_route() {
        let mut r = Router::new(Policy::Explicit, &["dense", "utrc@0.2"]);
        assert_eq!(r.route(&req("utrc@0.2", 4)).unwrap(), "utrc@0.2");
        assert!(r.route(&req("nope", 4)).is_err());
        assert!(r.route(&req("", 4)).is_err());
    }

    #[test]
    fn explicit_route_distinguishes_bad_variant_from_missing_lane() {
        let mut r = Router::new(Policy::Explicit, &["dense", "utrc@0.2"]);
        // Malformed variants are rejected as invalid (policy-name/grammar
        // validation), before any queueing could happen.
        for bad in ["bogus@0.5", "utrc@7", "merge@0.2:l2"] {
            let msg = format!("{:#}", r.route(&req(bad, 4)).unwrap_err());
            assert!(msg.contains("invalid variant"), "{bad}: {msg}");
        }
        // A well-formed variant with no serving lane names the real problem.
        let msg = format!("{:#}", r.route(&req("prune@0.3", 4)).unwrap_err());
        assert!(msg.contains("no lane serves"), "{msg}");
    }

    /// The typed error carries the same distinction the message text does,
    /// so the HTTP layer can map Malformed→400 and Unserved→404.
    #[test]
    fn route_checked_is_typed() {
        let mut r = Router::new(Policy::Explicit, &["dense", "utrc@0.2"]);
        assert!(matches!(
            r.route_checked(&req("bogus@0.5", 4)),
            Err(RouteError::Malformed { .. })
        ));
        assert!(matches!(
            r.route_checked(&req("prune@0.3", 4)),
            Err(RouteError::Unserved { .. })
        ));
        assert!(matches!(r.route_checked(&req("", 4)), Err(RouteError::NeedsVariant)));
        assert_eq!(r.route_checked(&req("dense", 4)).unwrap(), "dense");
    }

    #[test]
    fn least_loaded_balances() {
        let mut r = Router::new(Policy::LeastLoaded, &["a", "b"]);
        let l1 = r.route(&req("", 4)).unwrap();
        r.note_enqueued(&l1);
        let l2 = r.route(&req("", 4)).unwrap();
        assert_ne!(l1, l2);
    }

    #[test]
    fn cost_aware_prefers_reduction_for_long() {
        let mut r = Router::new(Policy::CostAware { long_prompt: 100 }, &["dense", "utrc@0.2"]);
        assert_eq!(r.route(&req("", 200)).unwrap(), "utrc@0.2");
        assert_eq!(r.route(&req("", 10)).unwrap(), "dense");
    }

    #[test]
    fn depth_tracking() {
        let mut r = Router::new(Policy::LeastLoaded, &["a"]);
        r.note_enqueued("a");
        r.note_enqueued("a");
        assert_eq!(r.depth("a"), 2);
        r.note_done("a");
        assert_eq!(r.depth("a"), 1);
        r.note_done("a");
        r.note_done("a"); // saturates, no underflow
        assert_eq!(r.depth("a"), 0);
    }
}
