//! Serving metrics: counters + streaming latency percentiles.

use std::time::Duration;

#[derive(Debug, Default, Clone)]
pub struct Metrics {
    pub requests: u64,
    pub completed: u64,
    pub prompt_tokens: u64,
    pub generated_tokens: u64,
    pub prefill_us: Vec<u64>,
    pub decode_us: Vec<u64>,
    pub queue_us: Vec<u64>,
    pub e2e_us: Vec<u64>,
    pub wall: Duration,
}

impl Metrics {
    /// Record one completed [`Response`](super::Response) — the usual entry
    /// point for serve loops (continuous or lock-step).
    pub fn record_response(&mut self, r: &super::Response) {
        self.record(r.prompt_tokens, r.generated.len(), r.prefill_us, r.decode_us, r.queue_us);
    }

    pub fn record(&mut self, prompt: usize, generated: usize, prefill_us: u64, decode_us: u64, queue_us: u64) {
        self.completed += 1;
        self.prompt_tokens += prompt as u64;
        self.generated_tokens += generated as u64;
        self.prefill_us.push(prefill_us);
        self.decode_us.push(decode_us);
        self.queue_us.push(queue_us);
        self.e2e_us.push(prefill_us + decode_us + queue_us);
    }

    pub fn throughput_tok_s(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        self.generated_tokens as f64 / self.wall.as_secs_f64()
    }

    /// End-to-end token throughput including prompt processing (the paper's
    /// generation-throughput metric counts generated tokens over wall time
    /// including prefill; both are reported).
    pub fn total_tok_s(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        (self.prompt_tokens + self.generated_tokens) as f64 / self.wall.as_secs_f64()
    }

    /// Nearest-rank percentile on the **sorted** samples: the smallest
    /// sample with at least `p` of the distribution at or below it,
    /// `v_sorted[⌈p·N⌉ − 1]` (rank clamped to `1..=N`). This is the
    /// percentile definition every `BENCH_*.json` emitter shares
    /// (PERFORMANCE.md §Schema); it never interpolates and never indexes
    /// the unsorted buffer.
    pub fn pct(xs: &[u64], p: f64) -> u64 {
        if xs.is_empty() {
            return 0;
        }
        let mut v = xs.to_vec();
        v.sort_unstable();
        let rank = (p * v.len() as f64).ceil() as usize;
        v[rank.clamp(1, v.len()) - 1]
    }

    pub fn summary(&self) -> String {
        format!(
            "completed={} gen_tok={} wall={:.2}s gen_tok/s={:.1} p50_e2e={}ms p99_e2e={}ms p50_prefill={}ms p50_decode={}ms",
            self.completed,
            self.generated_tokens,
            self.wall.as_secs_f64(),
            self.throughput_tok_s(),
            Self::pct(&self.e2e_us, 0.5) / 1000,
            Self::pct(&self.e2e_us, 0.99) / 1000,
            Self::pct(&self.prefill_us, 0.5) / 1000,
            Self::pct(&self.decode_us, 0.5) / 1000,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let xs: Vec<u64> = (1..=100).collect();
        // nearest-rank: ⌈0.5·100⌉ = 50 → 50th sorted value
        assert_eq!(Metrics::pct(&xs, 0.5), 50);
        assert_eq!(Metrics::pct(&xs, 0.99), 99);
        assert_eq!(Metrics::pct(&xs, 1.0), 100);
        assert_eq!(Metrics::pct(&[], 0.5), 0);
    }

    /// Known 20-sample vector, deliberately unsorted: nearest-rank must
    /// sort first and take ⌈p·20⌉-th smallest — a truncating index into
    /// the unsorted buffer would return arbitrary values here.
    #[test]
    fn percentiles_nearest_rank_20_samples() {
        let mut xs: Vec<u64> = (1..=20).map(|i| i * 10).collect(); // 10,20,...,200
        // shuffle deterministically: reverse + swap pairs
        xs.reverse();
        xs.swap(0, 7);
        xs.swap(3, 15);
        assert_eq!(Metrics::pct(&xs, 0.05), 10); // ⌈1⌉ → 1st
        assert_eq!(Metrics::pct(&xs, 0.50), 100); // ⌈10⌉ → 10th
        assert_eq!(Metrics::pct(&xs, 0.95), 190); // ⌈19⌉ → 19th
        assert_eq!(Metrics::pct(&xs, 0.99), 200); // ⌈19.8⌉=20 → 20th
        assert_eq!(Metrics::pct(&xs, 0.0), 10); // rank clamps to 1
        // p50 of an odd count picks the true median, not a neighbour
        assert_eq!(Metrics::pct(&[5, 1, 9], 0.5), 5);
    }

    #[test]
    fn throughput_math() {
        let mut m = Metrics::default();
        m.record(512, 100, 1000, 2000, 10);
        m.record(512, 100, 1000, 2000, 10);
        m.wall = Duration::from_secs(2);
        assert!((m.throughput_tok_s() - 100.0).abs() < 1e-9);
        assert!((m.total_tok_s() - 612.0).abs() < 1e-9);
    }
}
