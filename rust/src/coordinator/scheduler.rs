//! Iteration-level (continuous) batching — the Orca-style serve loop the
//! fixed-size SSM decode state makes cheap (DESIGN.md §6).
//!
//! Each [`Scheduler::step`] iteration:
//!
//! 1. **admit** — while decode lanes want work — or the ready queue can
//!    still hold one prefill batch of ready-ahead sequences beyond the free
//!    lanes (the store is sized for exactly that) — prefill queued prompts
//!    in chunks of up to the engine's prefill batch and copy each
//!    sequence's state into the slot-backed [`StateStore`];
//! 2. **place** — move prefilled sequences into free decode-frame lanes,
//!    highest [`Priority`](super::Priority) first (FIFO within a class);
//!    under lane pressure a strictly lower-priority resident is
//!    **preempted**: its fixed-size state stays parked in its store slot,
//!    it re-queues as ready, and the preempted interval is added to its
//!    `queue_us` when it is placed again (DESIGN.md §12);
//! 3. **decode** — gather the occupied lanes' slots into the
//!    `[n_layer, B, ...]` decode frame, step the frame ONCE, scatter the
//!    updated states back;
//! 4. **retire** — any sequence that just hit its `gen_tokens` returns its
//!    [`Response`] and releases its slot immediately, so the next arrival
//!    can take the lane on the very next iteration.
//!
//! Requests with `gen_tokens <= 1` complete at admission (their only token
//! is sampled from the prefill logits) and never consume a slot.
//!
//! Unlike the lock-step [`Engine::serve_batch`], no lane ever decodes a
//! finished sequence, and timing is honest per request: `queue_us` is
//! submit→prefill-start plus any post-prefill wait for a free decode lane,
//! `prefill_us` is the request's actual prefill call, `decode_us`
//! accumulates exactly the frame steps the request was resident for.

use std::collections::{HashMap, VecDeque};
use std::time::Instant;

use anyhow::Result;

use super::engine::{argmax, DecodeFrame, Engine};
use super::state_pool::Slot;
use super::state_store::StateStore;
use super::{Priority, Request, Response};

/// Per-request streaming hook: called once per generated token, in
/// generation order, from inside [`Scheduler::step`] — the seam the HTTP
/// front-end (DESIGN.md §14) hangs chunked-transfer streaming on. The
/// tokens a sink observes are exactly the [`Response::generated`] vec of
/// the eventual response (same values, same order); the final token is
/// delivered before the response is returned from `step`. Sinks must not
/// block: the scheduler calls them inline between decode frames.
pub type TokenSink = Box<dyn FnMut(i32) + Send>;

/// One admitted sequence: identity, progress, and per-request timing.
struct Seq {
    id: u64,
    slot: Slot,
    gen_tokens: usize,
    generated: Vec<i32>,
    /// Token to feed on this sequence's next decode step (already recorded
    /// in `generated`).
    next_token: i32,
    prompt_tokens: usize,
    priority: Priority,
    /// When this sequence last entered `ready` (prefill finish, or the
    /// moment it was preempted) — the wait is added to `queue_us` at
    /// placement so no latency phase goes unreported, including every
    /// preempted interval.
    waiting_since: Instant,
    queue_us: u64,
    prefill_us: u64,
    decode_us: u64,
}

pub struct Scheduler<'e> {
    engine: &'e Engine,
    store: StateStore,
    /// Decode-frame lanes; `None` = idle.
    lanes: Vec<Option<Seq>>,
    frame: DecodeFrame,
    /// Submitted, not yet prefilled.
    queue: VecDeque<(Request, Instant)>,
    /// Prefilled (state in the store), waiting for a decode lane.
    ready: VecDeque<Seq>,
    /// Streaming hooks by request id (installed by
    /// [`Scheduler::submit_with_sink`], removed at completion).
    sinks: HashMap<u64, TokenSink>,
    /// Decode-frame executions — the iteration count minimised vs lock-step.
    pub decode_steps: u64,
    /// Wall time of each decode-frame execution, in µs, in step order —
    /// the per-step latency samples `benches/runtime.rs` turns into the
    /// p50/p95 decode-step numbers of `BENCH_runtime.json`
    /// (PERFORMANCE.md §Schema). Bounded by [`Self::MAX_STEP_SAMPLES`] so
    /// a long-lived scheduler stays O(1): the first N steps are sampled,
    /// then sampling stops (bench traces are far below the cap).
    pub decode_step_us: Vec<u64>,
    /// Prefill-frame executions.
    pub prefill_calls: u64,
    /// Residents swapped out of a decode lane for a higher-priority
    /// sequence (state parked in the slot; resumed bit-identically later).
    pub preemptions: u64,
    pub submitted: u64,
    pub completed: u64,
}

impl<'e> Scheduler<'e> {
    /// Cap on [`Self::decode_step_us`]: plenty for every bench trace, and
    /// a hard bound on sample memory for service-style schedulers that
    /// live for millions of steps.
    pub const MAX_STEP_SAMPLES: usize = 1 << 16;

    /// A scheduler whose store holds one slot per decode lane plus one
    /// prefill batch of ready-ahead sequences.
    pub fn new(engine: &'e Engine) -> Scheduler<'e> {
        Scheduler::with_store_slots(engine, engine.decode_batch + engine.batch)
    }

    /// A scheduler with an explicit state-store capacity (at least one slot
    /// per decode lane).
    pub fn with_store_slots(engine: &'e Engine, store_slots: usize) -> Scheduler<'e> {
        let cap = store_slots.max(engine.decode_batch);
        Scheduler {
            engine,
            store: engine.new_store(cap),
            lanes: (0..engine.decode_batch).map(|_| None).collect(),
            frame: engine.new_frame(),
            queue: VecDeque::new(),
            ready: VecDeque::new(),
            sinks: HashMap::new(),
            decode_steps: 0,
            decode_step_us: Vec::new(),
            prefill_calls: 0,
            preemptions: 0,
            submitted: 0,
            completed: 0,
        }
    }

    /// Enqueue a request (FIFO admission; queue time starts now).
    pub fn submit(&mut self, req: Request) {
        self.submitted += 1;
        self.queue.push_back((req, Instant::now()));
    }

    /// [`Scheduler::submit`] plus a [`TokenSink`] that observes each of the
    /// request's generated tokens as it is produced. The sink is dropped
    /// once the request completes. Request ids must be unique among
    /// in-flight sink-carrying requests (the serving front-end allocates
    /// them from a counter).
    pub fn submit_with_sink(&mut self, req: Request, sink: TokenSink) {
        self.sinks.insert(req.id, sink);
        self.submit(req);
    }

    /// True when nothing is queued, ready, or decoding.
    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.ready.is_empty() && self.lanes.iter().all(|l| l.is_none())
    }

    /// Everything submitted but not yet completed (router depth accounting).
    pub fn in_flight(&self) -> usize {
        self.queue.len() + self.ready.len() + self.lanes.iter().filter(|l| l.is_some()).count()
    }

    /// The slot-backed state store (capacity / live / peak inspection).
    pub fn store(&self) -> &StateStore {
        &self.store
    }

    /// Ids of sequences that have already produced at least one token here
    /// (prefilled-and-ready or resident in a decode lane). On a replica
    /// failure these cannot be re-routed transparently — their sinks have
    /// fired, so replaying them elsewhere would duplicate observed tokens —
    /// and the pool fails them typed instead (DESIGN.md §15). Complements
    /// [`Self::take_queued`].
    pub fn active_ids(&self) -> Vec<u64> {
        let mut ids: Vec<u64> = self.ready.iter().map(|s| s.id).collect();
        ids.extend(self.lanes.iter().flatten().map(|s| s.id));
        ids
    }

    /// Pull every submitted-but-not-yet-prefilled request back out, each
    /// with its streaming sink if one was installed. These requests have
    /// produced **zero** tokens — the admit loop copies a chunk out but
    /// drains the queue only after `Engine::prefill` returns Ok — so
    /// re-submitting them to another scheduler is lossless: the seam the
    /// replica pool's failover and drain re-route rides on (DESIGN.md §15).
    /// `submitted` is decremented by the count taken, keeping per-scheduler
    /// accounting at submitted == completed + in_flight.
    pub fn take_queued(&mut self) -> Vec<(Request, Option<TokenSink>)> {
        let drained: Vec<(Request, Instant)> = self.queue.drain(..).collect();
        self.submitted -= drained.len() as u64;
        drained
            .into_iter()
            .map(|(r, _)| {
                let sink = self.sinks.remove(&r.id);
                (r, sink)
            })
            .collect()
    }

    /// Prefilled sequences waiting beyond the currently free lanes — the
    /// ready-ahead depth the store's extra `engine.batch` slots exist for.
    pub fn ready_ahead(&self) -> usize {
        let free = self.lanes.iter().filter(|l| l.is_none()).count();
        self.ready.len().saturating_sub(free)
    }

    /// One scheduler iteration (admit → place → decode → retire). Returns
    /// the responses completed during this iteration; returns quickly with
    /// an empty vec when fully idle.
    pub fn step(&mut self) -> Result<Vec<Response>> {
        let mut done = Vec::new();

        // ---- admit: prefill queued prompts while lanes want work --------
        // Budget: enough prefill chunks to fill every lane once, plus one.
        // Without it a burst of gen_tokens<=1 requests (which complete at
        // admission and never enter `ready`) would keep this loop prefilling
        // the whole queue while resident sequences starve for their next
        // decode step.
        let mut admit_budget = self.lanes.len() / self.engine.batch.max(1) + 1;
        loop {
            let free_lanes = self.lanes.iter().filter(|l| l.is_none()).count();
            // Admit while the ready queue can still cover every free lane
            // *plus* one prefill batch of ready-ahead — the extra
            // `engine.batch` slots `Scheduler::new` sizes the store with.
            // (The old `>= free_lanes` bound halted admission the moment
            // lanes filled, so a retirement always stalled on a fresh
            // prefill and the ready-ahead slots were dead memory.)
            if admit_budget == 0
                || self.queue.is_empty()
                || self.ready.len() >= free_lanes + self.engine.batch
            {
                break;
            }
            admit_budget -= 1;
            let n = self.queue.len().min(self.engine.batch).min(self.store.free_slots());
            if n == 0 {
                break; // store full: wait for a retirement
            }
            // Copy the chunk out but leave it queued until prefill succeeds:
            // a failing backend must not silently drop requests from a
            // long-lived scheduler.
            let queue_us: Vec<u64> = self
                .queue
                .iter()
                .take(n)
                .map(|(_, t)| t.elapsed().as_micros() as u64)
                .collect();
            let reqs: Vec<Request> = self.queue.iter().take(n).map(|(r, _)| r.clone()).collect();
            let (seqs, prefill_us) = self.engine.prefill(&reqs)?;
            self.prefill_calls += 1;
            let _ = self.queue.drain(..n);
            let prefilled_at = Instant::now();
            for ((req, seq), q_us) in reqs.iter().zip(seqs).zip(queue_us) {
                let first = argmax(&seq.logits) as i32;
                let mut generated = Vec::new();
                if req.gen_tokens > 0 {
                    generated.push(first);
                    if let Some(sink) = self.sinks.get_mut(&req.id) {
                        sink(first);
                    }
                }
                if generated.len() >= req.gen_tokens {
                    // 0/1-token requests never need a decode lane or a slot.
                    self.sinks.remove(&req.id);
                    self.completed += 1;
                    done.push(Response {
                        id: req.id,
                        generated,
                        prompt_tokens: req.prompt.len(),
                        prefill_us,
                        decode_us: 0,
                        queue_us: q_us,
                        variant: self.engine.variant.clone(),
                    });
                    continue;
                }
                let slot = self.store.admit(&seq.conv, &seq.ssm)?;
                self.ready.push_back(Seq {
                    id: req.id,
                    slot,
                    gen_tokens: req.gen_tokens,
                    generated,
                    next_token: first,
                    prompt_tokens: req.prompt.len(),
                    priority: req.priority,
                    waiting_since: prefilled_at,
                    queue_us: q_us,
                    prefill_us,
                    decode_us: 0,
                });
            }
        }

        // ---- place: fill lanes from ready, highest priority first -------
        // FIFO within a class (the first ready sequence of the top class
        // wins), so an all-Normal trace places in exactly the old order.
        // When no lane is free, a strictly lower-priority resident is
        // preempted: its state is already parked in its store slot (scatter
        // ran at the end of the previous decode), so swapping it out is
        // just re-queueing its Seq — it resumes bit-identically via gather.
        // Each swap strictly raises the resident priority multiset, so the
        // loop is bounded; equal priorities never preempt (no churn).
        while let Some((best, best_prio)) = self
            .ready
            .iter()
            .enumerate()
            .max_by(|(ia, a), (ib, b)| a.priority.cmp(&b.priority).then(ib.cmp(ia)))
            .map(|(i, s)| (i, s.priority))
        {
            let lane_idx = match self.lanes.iter().position(|l| l.is_none()) {
                Some(free) => free,
                None => {
                    let Some((victim_idx, victim_prio)) = self
                        .lanes
                        .iter()
                        .enumerate()
                        .filter_map(|(i, l)| l.as_ref().map(|s| (i, s.priority)))
                        .min_by(|(ia, a), (ib, b)| a.cmp(b).then(ia.cmp(ib)))
                    else {
                        break; // no lanes at all
                    };
                    if victim_prio >= best_prio {
                        break; // nothing strictly lower-priority to evict
                    }
                    let Some(mut victim) =
                        self.lanes.get_mut(victim_idx).and_then(|l| l.take())
                    else {
                        break; // victim vanished under us: stop placing
                    };
                    victim.waiting_since = Instant::now();
                    self.preemptions += 1;
                    self.ready.push_back(victim);
                    victim_idx
                }
            };
            let Some(mut seq) = self.ready.remove(best) else {
                break; // enumerate index out of range: stop placing
            };
            // Waiting in `ready` for a lane is queueing too — fold it into
            // queue_us so every latency phase (including every preempted
            // interval) is reported.
            seq.queue_us += seq.waiting_since.elapsed().as_micros() as u64;
            match self.lanes.get_mut(lane_idx) {
                Some(lane) => *lane = Some(seq),
                None => {
                    // lane_idx came from position()/enumerate over lanes;
                    // if it is somehow gone, requeue rather than drop.
                    self.ready.push_back(seq);
                    break;
                }
            }
        }

        // ---- decode one frame step + retire finished lanes --------------
        if self.lanes.iter().any(|l| l.is_some()) {
            let slots: Vec<Option<Slot>> =
                self.lanes.iter().map(|l| l.as_ref().map(|s| s.slot)).collect();
            self.store.gather(&slots, &mut self.frame.conv, &mut self.frame.ssm);
            // Idle lanes get the engine's idle fill: on a length-aware
            // backend that is the IDLE_LANE sentinel and the backend skips
            // the lane's model math entirely — a half-empty frame no longer
            // pays full-model decodes for phantom PAD tokens.
            for (tok, lane) in self.frame.tokens.iter_mut().zip(&self.lanes) {
                *tok = match lane {
                    Some(seq) => seq.next_token,
                    None => self.engine.idle_token(),
                };
            }
            let t0 = Instant::now();
            let logits = self.engine.decode_step(&mut self.frame)?;
            let dt = t0.elapsed().as_micros() as u64;
            self.decode_steps += 1;
            if self.decode_step_us.len() < Self::MAX_STEP_SAMPLES {
                self.decode_step_us.push(dt);
            }
            // Write updated states back before any retirement frees a slot.
            self.store.scatter(&slots, &self.frame.conv, &self.frame.ssm);

            // `chunks(vocab)` pairs each lane with its logit row without an
            // index expression (the frame contract is len == lanes·vocab;
            // `.max(1)` only keeps `chunks` well-formed on a malformed 0).
            let vocab = self.engine.vocab().max(1);
            for (lane, lane_logits) in self.lanes.iter_mut().zip(logits.chunks(vocab)) {
                let Some(mut seq) = lane.take() else { continue };
                seq.decode_us += dt;
                let tok = argmax(lane_logits) as i32;
                seq.generated.push(tok);
                seq.next_token = tok;
                if let Some(sink) = self.sinks.get_mut(&seq.id) {
                    sink(tok);
                }
                if seq.generated.len() >= seq.gen_tokens {
                    self.sinks.remove(&seq.id);
                    self.store.retire(seq.slot)?;
                    self.completed += 1;
                    done.push(Response {
                        id: seq.id,
                        generated: seq.generated,
                        prompt_tokens: seq.prompt_tokens,
                        prefill_us: seq.prefill_us,
                        decode_us: seq.decode_us,
                        queue_us: seq.queue_us,
                        variant: self.engine.variant.clone(),
                    });
                } else {
                    *lane = Some(seq);
                }
            }
        }

        Ok(done)
    }

    /// Step until idle, collecting every response produced on the way.
    pub fn drain(&mut self) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        while !self.is_idle() {
            out.extend(self.step()?);
        }
        Ok(out)
    }

    /// Submit a whole trace and drive it to completion.
    pub fn run(&mut self, reqs: Vec<Request>) -> Result<Vec<Response>> {
        for r in reqs {
            self.submit(r);
        }
        self.drain()
    }
}
