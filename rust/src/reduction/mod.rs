//! Token-reduction planning and policies.
//!
//! Two halves:
//!
//! * this module — FLOPs model, schedule solver, and peak-memory model: the
//!   rust mirror of `python/compile/flops.py`. The python side bakes static
//!   keep-counts into HLO exports; this side re-derives the same plans for
//!   reporting (tables, figures) and validates them against the manifest
//!   (integration test `schedule_golden`). A plan decides *how many* tokens
//!   survive each reduction site.
//! * [`policy`] — the pluggable [`ReductionPolicy`](policy::ReductionPolicy)
//!   family (prune / merge / unified / random) deciding *which* tokens
//!   survive and what happens to the rest, dispatched by the reference
//!   backend at every plan boundary (DESIGN.md §10).

pub mod policy;

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct ModelDims {
    pub name: String,
    pub arch: Arch,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layer: usize,
    pub d_state: usize,
    pub expand: usize,
    pub d_conv: usize,
    pub headdim: usize,
    pub chunk: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arch {
    Mamba,
    Mamba2,
}

impl ModelDims {
    pub fn from_manifest(m: &crate::manifest::ModelEntry) -> ModelDims {
        ModelDims {
            name: m.name.clone(),
            arch: if m.arch == "mamba" { Arch::Mamba } else { Arch::Mamba2 },
            vocab_size: m.vocab_size,
            d_model: m.d_model,
            n_layer: m.n_layer,
            d_state: m.d_state,
            expand: m.d_inner / m.d_model,
            d_conv: 4,
            headdim: 64,
            chunk: 64,
        }
    }

    pub fn d_inner(&self) -> usize {
        self.expand * self.d_model
    }

    pub fn dt_rank(&self) -> usize {
        (self.d_model + 15) / 16
    }

    pub fn n_heads(&self) -> usize {
        self.d_inner() / self.headdim
    }

    /// FLOPs for one token through one block; mirrors
    /// `flops.layer_flops_per_token` exactly (keep in lockstep!).
    pub fn layer_flops_per_token(&self) -> f64 {
        let (d, di, n) = (self.d_model as f64, self.d_inner() as f64, self.d_state as f64);
        match self.arch {
            Arch::Mamba => {
                let r = self.dt_rank() as f64;
                2.0 * d * 2.0 * di
                    + 2.0 * di * self.d_conv as f64
                    + 2.0 * di * (r + 2.0 * n)
                    + 2.0 * r * di
                    + 9.0 * di * n
                    + 2.0 * di * d
                    + 5.0 * di
            }
            Arch::Mamba2 => {
                let h = self.n_heads() as f64;
                let c = self.chunk as f64;
                let d_in_proj = 2.0 * di + 2.0 * n + h;
                2.0 * d * d_in_proj
                    + 2.0 * (di + 2.0 * n) * self.d_conv as f64
                    + 2.0 * c * n * 2.0
                    + 2.0 * c * self.headdim as f64 * h / h.max(1.0) * h
                    + 8.0 * di * n
                    + 2.0 * di * d
                    + 6.0 * di
            }
        }
    }

    pub fn head_flops_per_token(&self) -> f64 {
        2.0 * self.d_model as f64 * self.vocab_size as f64
    }

    pub fn param_bytes(&self) -> u64 {
        // f32; matches configs.ModelConfig.param_count * 4 (validated in tests
        // against manifest.param_count).
        let (d, di, n) = (self.d_model, self.d_inner(), self.d_state);
        let per = match self.arch {
            Arch::Mamba => {
                d + d * 2 * di
                    + di * self.d_conv + di
                    + di * (self.dt_rank() + 2 * n)
                    + self.dt_rank() * di + di
                    + di * n + di + di * d
            }
            Arch::Mamba2 => {
                let h = self.n_heads();
                let d_in_proj = 2 * di + 2 * n + h;
                d + d * d_in_proj
                    + (di + 2 * n) * self.d_conv + (di + 2 * n)
                    + h + h + h + di + di * d
            }
        };
        ((self.vocab_size * d + self.n_layer * per + d) * 4) as u64
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct SchedulePlan {
    pub seq_len: usize,
    pub locations: Vec<usize>,
    pub seg_lens: Vec<usize>,
    pub removed: Vec<usize>,
    pub flops_reduction: f64,
}

impl SchedulePlan {
    /// Live token count after the last reduction site.
    ///
    /// Panics (with a diagnosable message) on a degenerate plan with empty
    /// `seg_lens` — such a plan can only be constructed by hand;
    /// [`solve_schedule`] always emits `locations.len() + 1` segments.
    pub fn final_len(&self) -> usize {
        assert!(
            !self.seg_lens.is_empty(),
            "SchedulePlan.seg_lens is empty (degenerate plan: seq_len={}, locations={:?})",
            self.seq_len,
            self.locations
        );
        *self.seg_lens.last().unwrap()
    }

    /// Live token count seen by `layer`. Same degenerate-plan panic
    /// contract as [`SchedulePlan::final_len`].
    pub fn len_at_layer(&self, layer: usize) -> usize {
        assert!(
            !self.seg_lens.is_empty(),
            "SchedulePlan.seg_lens is empty (degenerate plan: seq_len={}, locations={:?})",
            self.seq_len,
            self.locations
        );
        assert_eq!(
            self.seg_lens.len(),
            self.locations.len() + 1,
            "SchedulePlan has {} seg_lens for {} locations",
            self.seg_lens.len(),
            self.locations.len()
        );
        let mut seg = 0;
        for (i, &loc) in self.locations.iter().enumerate() {
            if layer > loc {
                seg = i + 1;
            }
        }
        self.seg_lens[seg]
    }
}

fn even(x: f64) -> usize {
    (((x / 2.0).round() as isize).max(1) * 2) as usize
}

fn plan_for_ratio(dims: &ModelDims, seq_len: usize, locations: &[usize], rho: f64) -> SchedulePlan {
    let mut lens = vec![seq_len];
    let mut removed = Vec::new();
    let mut cur = seq_len;
    for _ in locations {
        let mut nxt = even(cur as f64 * rho).min(cur);
        nxt = nxt.max(cur - cur / 2); // M_A-set limit: at most half removable
        removed.push(cur - nxt);
        lens.push(nxt);
        cur = nxt;
    }
    let dense_lens = vec![seq_len; locations.len() + 1];
    let dense = total_flops(dims, locations, &dense_lens);
    let got = total_flops(dims, locations, &lens);
    SchedulePlan {
        seq_len,
        locations: locations.to_vec(),
        seg_lens: lens,
        removed,
        flops_reduction: 1.0 - got / dense,
    }
}

pub fn total_flops(dims: &ModelDims, locations: &[usize], seg_lens: &[usize]) -> f64 {
    let per = dims.layer_flops_per_token();
    let mut total = 0.0;
    let mut seg = 0;
    for layer in 0..dims.n_layer {
        if seg < locations.len() && layer > locations[seg] {
            seg += 1;
        }
        total += per * seg_lens[seg] as f64;
    }
    total + dims.head_flops_per_token() * *seg_lens.last().unwrap() as f64
}

/// Bisect the fixed per-location keep-ratio to hit the FLOPs target
/// (mirrors `flops.solve_schedule`).
pub fn solve_schedule(
    dims: &ModelDims,
    seq_len: usize,
    locations: &[usize],
    flops_reduction: f64,
) -> Result<SchedulePlan> {
    if seq_len == 0 {
        bail!(
            "cannot solve a schedule for seq_len=0 ({}, locations {:?})",
            dims.name,
            locations
        );
    }
    if flops_reduction <= 0.0 || locations.is_empty() {
        return Ok(plan_for_ratio(dims, seq_len, locations, 1.0));
    }
    for &loc in locations {
        if loc >= dims.n_layer {
            bail!("reduction location {loc} outside model ({} layers)", dims.n_layer);
        }
    }
    let (mut lo, mut hi) = (0.5f64, 1.0f64);
    let mut best = plan_for_ratio(dims, seq_len, locations, 1.0);
    for _ in 0..64 {
        let mid = (lo + hi) / 2.0;
        // One plan per bisection step: compare against the incumbent and
        // steer on the same achieved ratio.
        let plan = plan_for_ratio(dims, seq_len, locations, mid);
        let achieved = plan.flops_reduction;
        if (achieved - flops_reduction).abs() < (best.flops_reduction - flops_reduction).abs() {
            best = plan;
        }
        if achieved > flops_reduction {
            lo = mid;
        } else {
            hi = mid;
        }
        if hi - lo < 1e-6 {
            break;
        }
    }
    if (best.flops_reduction - flops_reduction).abs() > 0.05 {
        bail!(
            "schedule solver missed target {flops_reduction:.3}: achieved {:.3} for {} L={seq_len}",
            best.flops_reduction,
            dims.name
        );
    }
    Ok(best)
}

// ---------------------------------------------------------------------------
// Peak-memory model (Figures 3/5), mirror of flops.peak_memory_bytes.
// ---------------------------------------------------------------------------

const BYTES: u64 = 4;

/// Peak *live* set while computing one block (mirror of
/// `flops.activation_bytes_per_layer`): residual + in-projection output +
/// conv output; later stages are narrower.
pub fn activation_bytes_per_layer(dims: &ModelDims, live_len: usize, batch: usize) -> u64 {
    let (d, di, n) = (dims.d_model as u64, dims.d_inner() as u64, dims.d_state as u64);
    let per_tok = match dims.arch {
        Arch::Mamba => d + 2 * di + di,
        Arch::Mamba2 => d + (2 * di + 2 * n + dims.n_heads() as u64) + (di + 2 * n),
    };
    let state = di * n;
    BYTES * (batch as u64 * live_len as u64 * per_tok + batch as u64 * state)
}

pub fn peak_memory_bytes(dims: &ModelDims, plan: &SchedulePlan, batch: usize) -> u64 {
    let weights = dims.param_bytes();
    let mut widest = 0u64;
    for layer in 0..dims.n_layer {
        let ll = plan.len_at_layer(layer);
        let residual = BYTES * (batch * ll * dims.d_model) as u64;
        widest = widest.max(residual + activation_bytes_per_layer(dims, ll, batch));
    }
    let logits = BYTES * (batch * plan.final_len() * dims.vocab_size) as u64;
    weights + widest.max(logits + BYTES * (batch * plan.final_len() * dims.d_model) as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ModelDims {
        ModelDims {
            name: "t".into(),
            arch: Arch::Mamba,
            vocab_size: 2048,
            d_model: 256,
            n_layer: 20,
            d_state: 16,
            expand: 2,
            d_conv: 4,
            headdim: 64,
            chunk: 64,
        }
    }

    #[test]
    fn dense_plan_is_identity() {
        let p = solve_schedule(&dims(), 128, &[], 0.0).unwrap();
        assert_eq!(p.seg_lens, vec![128]);
        assert_eq!(p.flops_reduction, 0.0);
    }

    #[test]
    fn targets_hit_within_tolerance() {
        let d = dims();
        for target in [0.10, 0.20, 0.30] {
            let p = solve_schedule(&d, 128, &[10, 15], target).unwrap();
            assert!(
                (p.flops_reduction - target).abs() < 0.05,
                "target {target}: got {}",
                p.flops_reduction
            );
            // monotone non-increasing live lengths, all even
            for w in p.seg_lens.windows(2) {
                assert!(w[1] <= w[0]);
            }
            for &l in &p.seg_lens {
                assert_eq!(l % 2, 0);
            }
        }
    }

    #[test]
    fn removal_respects_half_limit() {
        let d = dims();
        let p = solve_schedule(&d, 128, &[10, 15], 0.30).unwrap();
        for (i, &r) in p.removed.iter().enumerate() {
            assert!(r <= p.seg_lens[i] / 2, "removed {r} of {}", p.seg_lens[i]);
        }
    }

    #[test]
    fn memory_decreases_with_reduction() {
        let d = dims();
        let dense = solve_schedule(&d, 128, &[], 0.0).unwrap();
        let red = solve_schedule(&d, 128, &[10, 15], 0.30).unwrap();
        assert!(peak_memory_bytes(&d, &red, 96) < peak_memory_bytes(&d, &dense, 96));
    }

    #[test]
    fn location_out_of_range_rejected() {
        assert!(solve_schedule(&dims(), 128, &[25], 0.2).is_err());
    }

    #[test]
    fn degenerate_inputs_rejected_or_identity() {
        let d = dims();
        // seq_len = 0 is an error regardless of locations or target.
        assert!(solve_schedule(&d, 0, &[], 0.0).is_err());
        assert!(solve_schedule(&d, 0, &[10], 0.2).is_err());
        // Empty locations with a positive seq_len degrade to the identity
        // (dense) plan, never to an empty/NaN one.
        let p = solve_schedule(&d, 64, &[], 0.3).unwrap();
        assert_eq!(p.seg_lens, vec![64]);
        assert!(p.removed.is_empty());
        assert_eq!(p.flops_reduction, 0.0);
        assert_eq!(p.final_len(), 64);
        assert_eq!(p.len_at_layer(0), 64);
        assert_eq!(p.len_at_layer(d.n_layer - 1), 64);
    }

    #[test]
    #[should_panic(expected = "seg_lens is empty")]
    fn empty_plan_final_len_panics_with_message() {
        let p = SchedulePlan {
            seq_len: 0,
            locations: vec![],
            seg_lens: vec![],
            removed: vec![],
            flops_reduction: 0.0,
        };
        let _ = p.final_len();
    }

    #[test]
    #[should_panic(expected = "seg_lens is empty")]
    fn empty_plan_len_at_layer_panics_with_message() {
        let p = SchedulePlan {
            seq_len: 0,
            locations: vec![],
            seg_lens: vec![],
            removed: vec![],
            flops_reduction: 0.0,
        };
        let _ = p.len_at_layer(3);
    }
}
