//! Pluggable token-reduction policies — the runtime half of the paper's
//! algorithm family (DESIGN.md §10).
//!
//! A [`SchedulePlan`](super::SchedulePlan) decides *how many* tokens survive
//! each reduction site; a [`ReductionPolicy`] decides *which* tokens survive
//! and what happens to the rest. The reference backend
//! ([`crate::runtime::reference`]) dispatches the policy at every plan
//! boundary, so the same compiled program geometry can run the paper's
//! unified method, its pruning/merging baselines, or a random control —
//! selected per serving lane by the variant grammar
//! `<policy>@<ratio>[:<metric>]` (parsed by [`PolicySpec::parse`]).
//!
//! | policy    | paper artifact                              | python mirror |
//! |-----------|---------------------------------------------|---------------|
//! | `prune`   | importance-only (Eq. 5; EViT-style baseline) | `reduction._one_evit`, `kernels/importance.py` |
//! | `merge`   | ToMe/PuMer bipartite cosine merge (Eq. 6–7) | `reduction._one_pumer`, `kernels/matching.py` |
//! | `unified` | UTRC: importance keep + merge of the dropped | `reduction._one_utrc` |
//! | `random`  | seeded importance-blind control             | — |
//!
//! The importance metrics (`clip`/`noclip`/`l1`/`l2`) mirror
//! `python/compile/kernels/importance.py` and are locked to it by
//! `tests/reduction_golden.rs`; ranking inside a policy uses unnormalised
//! per-row scores (`d·mean` for clip/noclip/l1, `(d·rms)²` for l2 — strictly
//! monotone transforms of the Eq. 5 metrics) so that `unified`'s default
//! `l2` ranking stays bit-identical to the legacy energy heuristic this
//! module absorbed from the reference backend.
//!
//! # Examples
//!
//! Construct a policy from a variant string and reduce a tiny live set:
//!
//! ```
//! use tor_ssm::reduction::policy::PolicySpec;
//!
//! let spec = PolicySpec::parse("prune@0.5:l1").unwrap().expect("reduced variant");
//! let policy = spec.build();
//!
//! // Four live rows of width 2; rows 2 and 3 carry the most L1 mass.
//! let mut xs = vec![0.1, 0.0, 1.0, 1.0, 3.0, -3.0, 0.5, 2.0];
//! let mut kept = vec![0, 1, 2, 3];
//! let mut merged = vec![1.0; 4];
//! policy.reduce(&mut xs, &mut kept, &mut merged, 2, 2);
//!
//! assert_eq!(kept, vec![2, 3]); // surviving ORIGINAL positions, ascending
//! assert_eq!(xs.len(), 2 * 2);  // live set compacted to `target` rows
//! assert_eq!(merged, vec![1.0, 1.0]); // prune folds nothing
//! ```
//!
//! The unified policy merges every dropped row into a survivor, and the
//! `merged` weights record how many original tokens each survivor absorbed:
//!
//! ```
//! use tor_ssm::reduction::policy::PolicySpec;
//!
//! let spec = PolicySpec::parse("unified@0.5").unwrap().unwrap();
//! let mut xs = vec![0.1, 0.0, 1.0, 1.0, 3.0, -3.0, 0.5, 2.0];
//! let mut kept = vec![0, 1, 2, 3];
//! let mut merged = vec![1.0; 4];
//! spec.build().reduce(&mut xs, &mut kept, &mut merged, 2, 2);
//!
//! assert_eq!(kept, vec![2, 3]);
//! // Rows 0 and 1 folded into row 2 (their nearest surviving successor):
//! assert_eq!(merged, vec![3.0, 1.0]);
//! ```
//!
//! `"dense"` parses to `None` (no reduction), and malformed variants are
//! rejected with the reason:
//!
//! ```
//! use tor_ssm::reduction::policy::PolicySpec;
//! assert!(PolicySpec::parse("dense").unwrap().is_none());
//! assert!(PolicySpec::parse("bogus@0.2").is_err());        // unknown policy
//! assert!(PolicySpec::parse("merge@0.2:l1").is_err());     // merge takes no metric
//! assert!(PolicySpec::parse("prune@1.5").is_err());        // ratio outside (0, 1)
//! ```

use std::cmp::Ordering;

use anyhow::{bail, ensure, Context, Result};

use crate::util::rng::Rng;

/// Seed for the `random` baseline policy. Fixed so that random-control rows
/// in tables/benches are reproducible across runs and machines.
pub const RANDOM_POLICY_SEED: u64 = 0x7042_5EED;

/// Token-importance metric (paper Eq. 5 and the Table-3 ablations); mirrors
/// `python/compile/kernels/importance.py` / `ref.importance_ref`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Metric {
    /// `mean(max(0, y))` — the paper's Eq. 5 (its default).
    Clip,
    /// `mean(y)` — no clipping.
    Noclip,
    /// `mean(|y|)`.
    L1,
    /// `sqrt(mean(y²))` — RMS; rank-equivalent to the legacy residual-energy
    /// heuristic, and therefore `unified`'s default.
    L2,
}

impl Metric {
    pub fn parse(s: &str) -> Result<Metric> {
        match s {
            "clip" => Ok(Metric::Clip),
            "noclip" => Ok(Metric::Noclip),
            "l1" => Ok(Metric::L1),
            "l2" => Ok(Metric::L2),
            other => bail!("unknown importance metric {other:?} (expected clip|noclip|l1|l2)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Metric::Clip => "clip",
            Metric::Noclip => "noclip",
            Metric::L1 => "l1",
            Metric::L2 => "l2",
        }
    }
}

/// Which member of the algorithm family a variant names.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// Importance-only (EViT-style): drop the least-important rows.
    Prune,
    /// ToMe/PuMer-style bipartite cosine merge, importance-blind.
    Merge,
    /// The paper's UTRC hybrid: importance keep, dropped rows merged into
    /// survivors. The repo's legacy heuristic is `unified` with metric `l2`.
    Unified,
    /// Seeded random keep — the importance-blind control baseline.
    Random,
}

impl PolicyKind {
    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::Prune => "prune",
            PolicyKind::Merge => "merge",
            PolicyKind::Unified => "unified",
            PolicyKind::Random => "random",
        }
    }

    fn parse(s: &str) -> Result<PolicyKind> {
        match s {
            "prune" | "evit" => Ok(PolicyKind::Prune),
            "merge" | "pumer" | "tome" => Ok(PolicyKind::Merge),
            "unified" | "utrc" => Ok(PolicyKind::Unified),
            "random" => Ok(PolicyKind::Random),
            other => bail!(
                "unknown reduction policy {other:?} (expected \
                 prune|merge|unified|random — aliases evit, pumer/tome, utrc — or dense)"
            ),
        }
    }

    /// Whether the policy ranks by an importance metric (and therefore
    /// accepts a `:<metric>` suffix in the variant grammar).
    pub fn uses_metric(&self) -> bool {
        matches!(self, PolicyKind::Prune | PolicyKind::Unified)
    }

    /// Default metric for metric-bearing policies: `prune` follows the
    /// paper's Eq. 5 default (`clip`); `unified` keeps the legacy energy
    /// ranking (`l2`) so default-metric outputs are bit-identical to the
    /// pre-policy reference backend.
    fn default_metric(&self) -> Option<Metric> {
        match self {
            PolicyKind::Prune => Some(Metric::Clip),
            PolicyKind::Unified => Some(Metric::L2),
            PolicyKind::Merge | PolicyKind::Random => None,
        }
    }

    /// The `aot.py` reduction-method name whose exports this policy mirrors
    /// (used to prefer a method-matched manifest entry).
    pub fn manifest_method(&self) -> &'static str {
        match self {
            PolicyKind::Prune => "evit",
            PolicyKind::Merge => "pumer",
            PolicyKind::Unified => "utrc",
            PolicyKind::Random => "random",
        }
    }
}

/// A fully parsed reduction variant: which algorithm, at which FLOPs-
/// reduction ratio, ranked by which metric (metric-bearing policies only).
#[derive(Debug, Clone, PartialEq)]
pub struct PolicySpec {
    pub kind: PolicyKind,
    /// Target FLOPs-reduction fraction, strictly inside (0, 1). The
    /// schedule solver turns it into per-site keep counts; the policy only
    /// sees the resulting `target` sizes.
    pub ratio: f64,
    /// `None` for policies that do not rank by importance (merge, random);
    /// always `Some` (default filled in) for prune and unified.
    pub metric: Option<Metric>,
}

impl PolicySpec {
    /// Parse the variant grammar `"dense"` | `"<policy>@<ratio>[:<metric>]"`.
    /// Returns `Ok(None)` for dense (no reduction). Policy names, ratio
    /// range, and metric applicability are all validated here, so a bad
    /// variant fails at parse time — before any request is queued — not at
    /// engine construction.
    pub fn parse(variant: &str) -> Result<Option<PolicySpec>> {
        if variant == "dense" || variant.is_empty() {
            return Ok(None);
        }
        let (name, rest) = variant
            .split_once('@')
            .with_context(|| {
                format!("variant {variant:?} must be 'dense' or '<policy>@<ratio>[:<metric>]'")
            })?;
        ensure!(!name.is_empty(), "variant {variant:?} has an empty policy name");
        let kind = PolicyKind::parse(name).with_context(|| format!("variant {variant:?}"))?;
        let (ratio_s, metric_s) = match rest.split_once(':') {
            Some((r, m)) => (r, Some(m)),
            None => (rest, None),
        };
        let ratio: f64 = ratio_s
            .parse()
            .ok()
            .with_context(|| format!("variant {variant:?}: ratio {ratio_s:?} is not a number"))?;
        ensure!(
            ratio.is_finite() && ratio > 0.0 && ratio < 1.0,
            "variant {variant:?}: reduction ratio must be in (0, 1), got {ratio}"
        );
        let metric = match metric_s {
            Some(m) => {
                ensure!(
                    kind.uses_metric(),
                    "variant {variant:?}: policy {:?} takes no metric suffix",
                    kind.name()
                );
                Some(Metric::parse(m).with_context(|| format!("variant {variant:?}"))?)
            }
            None => kind.default_metric(),
        };
        Ok(Some(PolicySpec { kind, ratio, metric }))
    }

    /// Canonical string form; round-trips through [`PolicySpec::parse`] and
    /// keys runtime compile caches and result caches.
    pub fn to_variant(&self) -> String {
        match self.metric {
            Some(m) => format!("{}@{}:{}", self.kind.name(), self.ratio, m.name()),
            None => format!("{}@{}", self.kind.name(), self.ratio),
        }
    }

    /// The policy an AOT manifest entry's `reduction` block resolves to on
    /// the reference backend. Methods the interpreter has no native
    /// algorithm for (`ltmp`, future exports) fall back to the legacy
    /// unified/`l2` semantics the reference backend always applied, so
    /// existing fixtures and tests keep their outputs bit-for-bit.
    pub fn from_manifest_reduction(r: &crate::manifest::Reduction) -> Option<PolicySpec> {
        if r.method == "dense" || r.flops_reduction <= 0.0 {
            return None;
        }
        let (kind, metric) = match r.method.as_str() {
            "evit" => (
                PolicyKind::Prune,
                Some(Metric::parse(&r.metric).unwrap_or(Metric::Clip)),
            ),
            "pumer" | "tome" => (PolicyKind::Merge, None),
            "random" => (PolicyKind::Random, None),
            // "utrc", "ltmp", and anything unknown: legacy interpreter
            // semantics (see doc comment).
            _ => (PolicyKind::Unified, Some(Metric::L2)),
        };
        Some(PolicySpec { kind, ratio: r.flops_reduction, metric })
    }

    /// Same algorithm + metric at (approximately) the same ratio — used to
    /// decide whether a lane's requested policy matches what an AOT graph
    /// already bakes in.
    pub fn compatible_with(&self, other: &PolicySpec) -> bool {
        self.kind == other.kind
            && self.metric == other.metric
            && (self.ratio - other.ratio).abs() < 1e-6
    }

    /// Instantiate the runnable policy.
    pub fn build(&self) -> Box<dyn ReductionPolicy> {
        match self.kind {
            PolicyKind::Prune => Box::new(Prune { metric: self.metric.unwrap_or(Metric::Clip) }),
            PolicyKind::Merge => Box::new(Merge),
            PolicyKind::Unified => Box::new(Unified { metric: self.metric.unwrap_or(Metric::L2) }),
            PolicyKind::Random => Box::new(Random { seed: RANDOM_POLICY_SEED }),
        }
    }
}

/// What the plan-less reference backend did before policies existed: the
/// unified hybrid ranked by residual energy. Kept as the fallback for
/// hand-built [`ProgramSpec`](crate::runtime::ProgramSpec)s that carry a
/// plan but no policy.
pub fn legacy_default() -> Box<dyn ReductionPolicy> {
    Box::new(Unified { metric: Metric::L2 })
}

/// One token-reduction algorithm, dispatched at every schedule-plan boundary.
///
/// ## Contract (DESIGN.md §10)
///
/// `reduce` shrinks a live set of `kept.len()` rows (each `d` wide, row-major
/// in `xs`) down to exactly `target` rows, in place:
///
/// * `kept` maps live rows to their ORIGINAL sequence positions and must
///   stay strictly ascending — downstream logits/kept-map outputs rely on it;
/// * `merged[i]` is row `i`'s fold weight (how many original tokens it
///   represents); policies that merge must keep it consistent so later sites
///   weight running means correctly;
/// * when `target == 0` or `target >= kept.len()` the call is a no-op (the
///   schedule solver never emits either, but hand-built plans may);
/// * the reduction must be deterministic — identical inputs give identical
///   outputs on every backend, machine, and run.
pub trait ReductionPolicy: Send + Sync {
    fn name(&self) -> &'static str;

    fn reduce(
        &self,
        xs: &mut Vec<f32>,
        kept: &mut Vec<usize>,
        merged: &mut Vec<f32>,
        target: usize,
        d: usize,
    );
}

// ---------------------------------------------------------------------------
// Metric math (locked to python/compile/kernels by tests/reduction_golden.rs)
// ---------------------------------------------------------------------------

/// Per-row token importance over a row-major `(len/d, d)` buffer; the exact
/// Eq. 5 metric values, matching `ref.importance_ref` to float tolerance.
pub fn importance(xs: &[f32], d: usize, metric: Metric) -> Vec<f32> {
    assert!(d > 0 && xs.len() % d == 0, "importance: {} not a multiple of d={d}", xs.len());
    xs.chunks_exact(d)
        .map(|row| match metric {
            Metric::Clip => row.iter().map(|v| v.max(0.0)).sum::<f32>() / d as f32,
            Metric::Noclip => row.iter().sum::<f32>() / d as f32,
            Metric::L1 => row.iter().map(|v| v.abs()).sum::<f32>() / d as f32,
            Metric::L2 => (row.iter().map(|v| v * v).sum::<f32>() / d as f32).sqrt(),
        })
        .collect()
}

/// Best-match under cosine similarity (paper Eq. 6–7); matches
/// `ref.cosine_match_ref`: rows are normalised with a `+1e-6` guard, and for
/// every row of `a` the first maximal match in `b` wins. `a` is `(na, d)`
/// row-major, `b` is `(nb, d)`; returns `(f, g)` — match index into `b` and
/// its similarity, per `a` row.
pub fn cosine_match(a: &[f32], b: &[f32], d: usize) -> (Vec<usize>, Vec<f32>) {
    assert!(d > 0 && a.len() % d == 0 && b.len() % d == 0, "cosine_match: ragged inputs");
    let nb = b.len() / d;
    assert!(nb > 0, "cosine_match: empty b set");
    let normalise = |rows: &[f32]| -> Vec<f32> {
        rows.chunks_exact(d)
            .flat_map(|row| {
                let norm = row.iter().map(|v| v * v).sum::<f32>().sqrt() + 1e-6;
                row.iter().map(move |v| v / norm)
            })
            .collect()
    };
    let an = normalise(a);
    let bn = normalise(b);
    let mut f = Vec::with_capacity(an.len() / d);
    let mut g = Vec::with_capacity(an.len() / d);
    for ar in an.chunks_exact(d) {
        let (mut best, mut best_sim) = (0usize, f32::NEG_INFINITY);
        for (j, br) in bn.chunks_exact(d).enumerate() {
            let sim: f32 = ar.iter().zip(br).map(|(x, y)| x * y).sum();
            if sim > best_sim {
                best = j;
                best_sim = sim;
            }
        }
        f.push(best);
        g.push(best_sim);
    }
    (f, g)
}

/// Unnormalised ranking scores: `d·mean` of the Eq. 5 metrics (and `(d·rms)²`
/// for l2) — strictly monotone in the metric value, so the selected set is
/// identical while the l2 arm stays bit-for-bit the legacy energy score.
fn selection_scores(xs: &[f32], live: usize, d: usize, metric: Metric) -> Vec<f32> {
    (0..live)
        .map(|t| {
            let row = &xs[t * d..(t + 1) * d];
            match metric {
                Metric::Clip => row.iter().map(|v| v.max(0.0)).sum::<f32>(),
                Metric::Noclip => row.iter().sum::<f32>(),
                Metric::L1 => row.iter().map(|v| v.abs()).sum::<f32>(),
                Metric::L2 => row.iter().map(|v| v * v).sum::<f32>(),
            }
        })
        .collect()
}

/// Row indices sorted by score descending, ties to the earlier position
/// (the legacy tie-break, shared by every ranking policy).
fn rank_descending(scores: &[f32]) -> Vec<usize> {
    let mut order: Vec<usize> = (0..scores.len()).collect();
    order.sort_by(|&a, &b| {
        scores[b]
            .partial_cmp(&scores[a])
            .unwrap_or(Ordering::Equal)
            .then(a.cmp(&b))
    });
    order
}

/// Fold row `src` into row `dst` by running weighted mean (weights = fold
/// counts in `merged`); `dst` absorbs `src`'s weight.
fn fold_row(xs: &mut [f32], merged: &mut [f32], src: usize, dst: usize, d: usize) {
    let (ws, wd) = (merged[src], merged[dst]);
    let tot = wd + ws;
    let (lo, hi) = (dst.min(src), dst.max(src));
    let (s1, s2) = xs.split_at_mut(hi * d);
    let row_lo = &mut s1[lo * d..(lo + 1) * d];
    let row_hi = &mut s2[..d];
    let (dst_row, src_row) = if dst < src { (row_lo, row_hi) } else { (row_hi, row_lo) };
    for c in 0..d {
        dst_row[c] = (dst_row[c] * wd + src_row[c] * ws) / tot;
    }
    merged[dst] = tot;
}

/// Rebuild `(xs, kept, merged)` from the surviving row indices (ascending).
fn compact(
    xs: &mut Vec<f32>,
    kept: &mut Vec<usize>,
    merged: &mut Vec<f32>,
    selected: &[usize],
    d: usize,
) {
    let mut new_xs = Vec::with_capacity(selected.len() * d);
    let mut new_kept = Vec::with_capacity(selected.len());
    let mut new_merged = Vec::with_capacity(selected.len());
    for &t in selected {
        new_xs.extend_from_slice(&xs[t * d..(t + 1) * d]);
        new_kept.push(kept[t]);
        new_merged.push(merged[t]);
    }
    *xs = new_xs;
    *kept = new_kept;
    *merged = new_merged;
}

// ---------------------------------------------------------------------------
// The policies
// ---------------------------------------------------------------------------

/// Importance-only pruning (EViT adapted to SSMs, the paper's prune
/// baseline): keep the `target` highest-scoring rows, discard the rest.
pub struct Prune {
    pub metric: Metric,
}

impl ReductionPolicy for Prune {
    fn name(&self) -> &'static str {
        "prune"
    }

    fn reduce(
        &self,
        xs: &mut Vec<f32>,
        kept: &mut Vec<usize>,
        merged: &mut Vec<f32>,
        target: usize,
        d: usize,
    ) {
        let live = kept.len();
        if target >= live || target == 0 {
            return;
        }
        let order = rank_descending(&selection_scores(xs, live, d, self.metric));
        let mut selected = order[..target].to_vec();
        selected.sort_unstable();
        compact(xs, kept, merged, &selected, d);
    }
}

/// ToMe/PuMer-style bipartite merging (paper Eq. 6–7 matching, importance-
/// blind): alternating positions form the candidate set `A` (even) and the
/// target set `B` (odd); the `n_remove` most cosine-similar `A→B`
/// connections are merged into their targets by running weighted mean.
pub struct Merge;

impl ReductionPolicy for Merge {
    fn name(&self) -> &'static str {
        "merge"
    }

    fn reduce(
        &self,
        xs: &mut Vec<f32>,
        kept: &mut Vec<usize>,
        merged: &mut Vec<f32>,
        target: usize,
        d: usize,
    ) {
        let live = kept.len();
        if target >= live || target == 0 {
            return;
        }
        let n_remove = live - target;
        let a_idx: Vec<usize> = (0..live).step_by(2).collect();
        let b_idx: Vec<usize> = (1..live).step_by(2).collect();
        // live >= 2 here (target >= 1 and target < live), so B is non-empty.
        let gather = |idx: &[usize]| -> Vec<f32> {
            let mut out = Vec::with_capacity(idx.len() * d);
            for &i in idx {
                out.extend_from_slice(&xs[i * d..(i + 1) * d]);
            }
            out
        };
        let (f, g) = cosine_match(&gather(&a_idx), &gather(&b_idx), d);

        // Connections by similarity descending; ties to the earlier A position.
        let mut conn: Vec<usize> = (0..a_idx.len()).collect();
        conn.sort_by(|&i, &j| {
            g[j].partial_cmp(&g[i]).unwrap_or(Ordering::Equal).then(a_idx[i].cmp(&a_idx[j]))
        });
        let n_merge = n_remove.min(a_idx.len());
        let mut removed: Vec<(usize, usize)> =
            conn[..n_merge].iter().map(|&c| (a_idx[c], b_idx[f[c]])).collect();
        removed.sort_unstable(); // fold in ascending source order (deterministic)

        let mut dead = vec![false; live];
        for &(a, _) in &removed {
            dead[a] = true;
        }
        // Solver plans guarantee n_remove <= |A|; a hand-built plan that
        // over-removes drops the excess from the tail, unmerged.
        let mut extra = n_remove - n_merge;
        for i in (0..live).rev() {
            if extra == 0 {
                break;
            }
            if !dead[i] {
                dead[i] = true;
                extra -= 1;
            }
        }
        for (a, b) in removed {
            // A tail-drop may have killed a merge target; folding into a row
            // that is itself being dropped would discard the absorbed weight
            // anyway, so skip it — the source is simply pruned instead.
            if !dead[b] {
                fold_row(xs, merged, a, b, d);
            }
        }
        let selected: Vec<usize> = (0..live).filter(|&i| !dead[i]).collect();
        compact(xs, kept, merged, &selected, d);
    }
}

/// The paper's unified method, as the reference backend realises it: rank by
/// importance, keep the top `target`, and fold every dropped row into the
/// nearest surviving row at or before it (first survivor when none precede)
/// by running weighted mean. With the default `l2` metric this is
/// bit-identical to the legacy `reduce_live_set` heuristic it replaced.
pub struct Unified {
    pub metric: Metric,
}

impl ReductionPolicy for Unified {
    fn name(&self) -> &'static str {
        "unified"
    }

    fn reduce(
        &self,
        xs: &mut Vec<f32>,
        kept: &mut Vec<usize>,
        merged: &mut Vec<f32>,
        target: usize,
        d: usize,
    ) {
        let live = kept.len();
        if target >= live || target == 0 {
            return;
        }
        let order = rank_descending(&selection_scores(xs, live, d, self.metric));
        let mut selected: Vec<usize> = order[..target].to_vec();
        selected.sort_unstable();
        let mut dropped: Vec<usize> = order[target..].to_vec();
        dropped.sort_unstable();

        for t in dropped {
            let q = match selected.partition_point(|&sel| sel < t).checked_sub(1) {
                Some(i) => selected[i],
                None => selected[0],
            };
            fold_row(xs, merged, t, q, d);
        }
        compact(xs, kept, merged, &selected, d);
    }
}

/// Seeded random keep — the importance-blind control. Deterministic: the
/// selection depends only on [`RANDOM_POLICY_SEED`] and the (live, target)
/// geometry, so repeated runs (and both serve paths) agree exactly.
pub struct Random {
    pub seed: u64,
}

impl ReductionPolicy for Random {
    fn name(&self) -> &'static str {
        "random"
    }

    fn reduce(
        &self,
        xs: &mut Vec<f32>,
        kept: &mut Vec<usize>,
        merged: &mut Vec<f32>,
        target: usize,
        d: usize,
    ) {
        let live = kept.len();
        if target >= live || target == 0 {
            return;
        }
        let mut rng = Rng::new(self.seed ^ ((live as u64) << 32) ^ target as u64);
        let mut idx: Vec<usize> = (0..live).collect();
        rng.shuffle(&mut idx);
        let mut selected = idx[..target].to_vec();
        selected.sort_unstable();
        compact(xs, kept, merged, &selected, d);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn live_set(rows: &[[f32; 2]]) -> (Vec<f32>, Vec<usize>, Vec<f32>) {
        let xs: Vec<f32> = rows.iter().flatten().copied().collect();
        let kept: Vec<usize> = (0..rows.len()).collect();
        let merged = vec![1.0; rows.len()];
        (xs, kept, merged)
    }

    #[test]
    fn parse_accepts_the_full_grammar() {
        assert!(PolicySpec::parse("dense").unwrap().is_none());
        assert!(PolicySpec::parse("").unwrap().is_none());

        let p = PolicySpec::parse("prune@0.2").unwrap().unwrap();
        assert_eq!((p.kind, p.metric), (PolicyKind::Prune, Some(Metric::Clip)));
        let p = PolicySpec::parse("prune@0.2:l1").unwrap().unwrap();
        assert_eq!(p.metric, Some(Metric::L1));
        let p = PolicySpec::parse("unified@0.3").unwrap().unwrap();
        assert_eq!((p.kind, p.metric), (PolicyKind::Unified, Some(Metric::L2)));
        let p = PolicySpec::parse("unified@0.3:clip").unwrap().unwrap();
        assert_eq!(p.metric, Some(Metric::Clip));
        let p = PolicySpec::parse("merge@0.1").unwrap().unwrap();
        assert_eq!((p.kind, p.metric), (PolicyKind::Merge, None));
        let p = PolicySpec::parse("random@0.5").unwrap().unwrap();
        assert_eq!(p.kind, PolicyKind::Random);

        // Aliases map onto the canonical family.
        assert_eq!(PolicySpec::parse("utrc@0.2").unwrap().unwrap().kind, PolicyKind::Unified);
        assert_eq!(PolicySpec::parse("evit@0.2").unwrap().unwrap().kind, PolicyKind::Prune);
        assert_eq!(PolicySpec::parse("pumer@0.2").unwrap().unwrap().kind, PolicyKind::Merge);
    }

    #[test]
    fn parse_rejects_malformed_variants() {
        for bad in [
            "bogus@0.2",      // unknown policy
            "nope",           // no '@'
            "@0.2",           // empty policy
            "prune@abc",      // non-numeric ratio
            "prune@0",        // ratio not in (0, 1)
            "prune@1",
            "prune@NaN",
            "prune@inf",
            "merge@0.2:l1",   // merge takes no metric
            "random@0.2:l2",  // random takes no metric
            "prune@0.2:l3",   // unknown metric
            "ltmp@0.2",       // no native ltmp policy (manifest-only method)
        ] {
            assert!(PolicySpec::parse(bad).is_err(), "{bad:?} should be rejected");
        }
    }

    #[test]
    fn variant_round_trips_through_canonical_form() {
        for v in ["prune@0.2:clip", "unified@0.3:l2", "merge@0.1", "random@0.5"] {
            let spec = PolicySpec::parse(v).unwrap().unwrap();
            assert_eq!(PolicySpec::parse(&spec.to_variant()).unwrap().unwrap(), spec);
        }
    }

    #[test]
    fn unified_l2_matches_legacy_reduce_live_set() {
        // The exact legacy test case from runtime/reference.rs: 5 rows with
        // energies 1, 100, 4, 100, 0 -> top-3 = rows 1, 3, 2; row 0 merges
        // into row 1 (first survivor), row 4 into row 3.
        let d = 2;
        let mut xs = vec![1.0, 0.0, 10.0, 0.0, 2.0, 0.0, 10.0, 0.0, 0.0, 0.0];
        let mut kept = vec![0, 1, 2, 3, 4];
        let mut merged = vec![1.0; 5];
        legacy_default().reduce(&mut xs, &mut kept, &mut merged, 3, d);
        assert_eq!(kept, vec![1, 2, 3]);
        assert_eq!(xs.len(), 3 * d);
        assert_eq!(merged, vec![2.0, 1.0, 2.0]);
    }

    #[test]
    fn every_policy_is_noop_at_or_above_live_and_at_zero() {
        for spec in ["prune@0.5", "merge@0.5", "unified@0.5", "random@0.5"] {
            let policy = PolicySpec::parse(spec).unwrap().unwrap().build();
            let (mut xs, mut kept, mut merged) = live_set(&[[1.0, 2.0], [3.0, 4.0]]);
            let orig = xs.clone();
            policy.reduce(&mut xs, &mut kept, &mut merged, 2, 2);
            policy.reduce(&mut xs, &mut kept, &mut merged, 5, 2);
            policy.reduce(&mut xs, &mut kept, &mut merged, 0, 2);
            assert_eq!(xs, orig, "{spec} mutated a no-op call");
            assert_eq!(kept, vec![0, 1]);
        }
    }

    #[test]
    fn every_policy_hits_target_with_ascending_kept() {
        let rows: Vec<[f32; 2]> = (0..12)
            .map(|i| [((i * 7 + 3) % 5) as f32 - 2.0, ((i * 11 + 1) % 7) as f32 - 3.0])
            .collect();
        for spec in ["prune@0.5:l1", "merge@0.5", "unified@0.5:clip", "random@0.5"] {
            let policy = PolicySpec::parse(spec).unwrap().unwrap().build();
            for target in [4, 6, 9] {
                let (mut xs, mut kept, mut merged) = live_set(&rows);
                policy.reduce(&mut xs, &mut kept, &mut merged, target, 2);
                assert_eq!(kept.len(), target, "{spec} target {target}");
                assert_eq!(xs.len(), target * 2);
                assert_eq!(merged.len(), target);
                for w in kept.windows(2) {
                    assert!(w[0] < w[1], "{spec}: kept not ascending: {kept:?}");
                }
                // Fold weights conserve the original token count for merging
                // policies; pruning policies drop mass, never invent it.
                let mass: f32 = merged.iter().sum();
                assert!(mass <= rows.len() as f32 + 1e-5, "{spec}: mass {mass}");
            }
        }
    }

    #[test]
    fn merge_conserves_token_mass_and_prefers_similar_pairs() {
        // Rows 0 and 1 are parallel (cos = 1); rows 2 and 3 are orthogonal-ish
        // to each other. Removing one token must merge row 0 into row 1.
        let (mut xs, mut kept, mut merged) =
            live_set(&[[1.0, 0.0], [2.0, 0.0], [0.0, 1.0], [1.0, 0.1]]);
        Merge.reduce(&mut xs, &mut kept, &mut merged, 3, 2);
        assert_eq!(kept, vec![1, 2, 3]);
        let mass: f32 = merged.iter().sum();
        assert!((mass - 4.0).abs() < 1e-6, "merge must conserve mass, got {mass}");
        assert_eq!(merged, vec![2.0, 1.0, 1.0]);
        // Row 1 is now the running mean of rows 0 and 1: (1+2)/2 = 1.5.
        assert!((xs[0] - 1.5).abs() < 1e-6);
    }

    #[test]
    fn prune_ranks_by_the_requested_metric() {
        // Row 0: large negative mass (l1 loves it, clip ignores it).
        let rows = [[-5.0, -5.0], [1.0, 1.0], [0.5, 0.0], [0.1, 0.0]];
        let (mut xs, mut kept, mut merged) = live_set(&rows);
        Prune { metric: Metric::L1 }.reduce(&mut xs, &mut kept, &mut merged, 2, 2);
        assert_eq!(kept, vec![0, 1], "l1 keeps the negative-heavy row");
        let (mut xs, mut kept, mut merged) = live_set(&rows);
        Prune { metric: Metric::Clip }.reduce(&mut xs, &mut kept, &mut merged, 2, 2);
        assert_eq!(kept, vec![1, 2], "clip drops the negative-heavy row");
    }

    #[test]
    fn random_is_deterministic_across_runs() {
        let rows: Vec<[f32; 2]> = (0..10).map(|i| [i as f32, -(i as f32)]).collect();
        let run = || {
            let (mut xs, mut kept, mut merged) = live_set(&rows);
            Random { seed: RANDOM_POLICY_SEED }.reduce(&mut xs, &mut kept, &mut merged, 4, 2);
            (xs, kept)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn importance_matches_the_eq5_formulas() {
        let xs = [1.0f32, -1.0, 2.0, 0.0];
        let d = 2;
        assert_eq!(importance(&xs, d, Metric::Clip), vec![0.5, 1.0]);
        assert_eq!(importance(&xs, d, Metric::Noclip), vec![0.0, 1.0]);
        assert_eq!(importance(&xs, d, Metric::L1), vec![1.0, 1.0]);
        let l2 = importance(&xs, d, Metric::L2);
        assert!((l2[0] - 1.0).abs() < 1e-6 && (l2[1] - 2.0f32.sqrt()).abs() < 1e-6);
    }

    #[test]
    fn cosine_match_picks_the_most_similar_row() {
        // a0 parallel to b1, a1 parallel to b0.
        let a = [1.0f32, 0.0, 0.0, 1.0];
        let b = [0.0f32, 2.0, 3.0, 0.0];
        let (f, g) = cosine_match(&a, &b, 2);
        assert_eq!(f, vec![1, 0]);
        assert!(g.iter().all(|&s| (s - 1.0).abs() < 1e-4), "{g:?}");
    }
}
