//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>, flag_names: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&rest) {
                    out.flags.push(rest.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.options.insert(rest.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env(flag_names: &[&str]) -> Args {
        Args::parse(std::env::args().skip(1), flag_names)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got {v:?}")))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str], flags: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()), flags)
    }

    #[test]
    fn mixed_forms() {
        let a = parse(
            &["table", "--ratio", "0.2", "--model=mamba-small", "--verbose", "1"],
            &["verbose"],
        );
        assert_eq!(a.positional, vec!["table", "1"]);
        assert_eq!(a.get("ratio"), Some("0.2"));
        assert_eq!(a.get("model"), Some("mamba-small"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["--force"], &[]);
        assert!(a.flag("force"));
    }

    #[test]
    fn numeric_option_value() {
        // "--steps 300": 300 must bind to steps even though it looks positional.
        let a = parse(&["--steps", "300"], &[]);
        assert_eq!(a.usize_or("steps", 0), 300);
    }
}
