//! Dependency-light substrates: JSON, CLI parsing, RNG, thread pool, stats.
//!
//! These replace serde_json / clap / rand / rayon, none of which are
//! resolvable in this offline image (see Cargo.toml header note).

pub mod cli;
pub mod json;
pub mod pool;
pub mod rng;
pub mod stats;
