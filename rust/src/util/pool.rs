//! Fixed-size worker thread pool with a scoped `map` — the slice of rayon
//! we actually need (parallel batch scoring, workload generation).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread;

/// Run `f(i)` for i in 0..n on up to `workers` threads; collect results in
/// order. Panics in workers propagate.
pub fn par_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return (0..n).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    // Work-stealing via an atomic index; each worker returns its (index,
    // value) pairs and we reassemble in order afterwards.
    let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
    thread::scope(|scope| {
        let f = &f;
        let next = &next;
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                scope.spawn(move || {
                    let mut local = Vec::new();
                    loop {
                        // ORDERING: Relaxed — work-stealing index; fetch_add's
                        // atomicity alone makes claims unique, and results are
                        // published by the thread join, not this counter.
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, f(i)));
                    }
                    local
                })
            })
            .collect();
        for h in handles {
            for (i, v) in h.join().expect("worker panicked") {
                slots[i] = Some(v);
            }
        }
    });

    slots.into_iter().map(|v| v.expect("worker hole")).collect()
}

/// A persistent pool for request-loop style work: submit closures, they run
/// FIFO on the workers. Used by the coordinator's execution lanes.
pub struct Pool {
    tx: Option<std::sync::mpsc::Sender<Job>>,
    handles: Vec<thread::JoinHandle<()>>,
}

type Job = Box<dyn FnOnce() + Send + 'static>;

impl Pool {
    pub fn new(workers: usize) -> Self {
        let (tx, rx) = std::sync::mpsc::channel::<Job>();
        let rx = Arc::new(std::sync::Mutex::new(rx));
        let handles = (0..workers.max(1))
            .map(|_| {
                let rx = Arc::clone(&rx);
                thread::spawn(move || loop {
                    let job = { rx.lock().unwrap().recv() };
                    match job {
                        Ok(job) => job(),
                        Err(_) => break,
                    }
                })
            })
            .collect();
        Pool { tx: Some(tx), handles }
    }

    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(f))
            .expect("pool workers gone");
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn par_map_order_and_coverage() {
        let out = par_map(1000, 8, |i| i * 2);
        assert_eq!(out.len(), 1000);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * 2);
        }
    }

    #[test]
    fn par_map_single_worker() {
        assert_eq!(par_map(5, 1, |i| i), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn par_map_empty() {
        let out: Vec<usize> = par_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn pool_runs_all_jobs() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = Pool::new(4);
            for _ in 0..100 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // drop waits for completion
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }
}
