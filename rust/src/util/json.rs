//! Minimal JSON parser/serializer.
//!
//! serde/serde_json are not available in this offline image (DESIGN.md §3),
//! and our needs are narrow: the artifact manifest, vocab, task sets, and
//! report emission. This is a strict recursive-descent parser over the JSON
//! grammar (RFC 8259) with `\uXXXX` escapes, plus a compact writer.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Panicking accessor for manifest plumbing where absence is a build bug.
    pub fn expect(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing json key {key:?}"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn usize_of(&self, key: &str) -> usize {
        self.expect(key)
            .as_usize()
            .unwrap_or_else(|| panic!("json key {key:?} is not a number"))
    }

    pub fn f64_of(&self, key: &str) -> f64 {
        self.expect(key)
            .as_f64()
            .unwrap_or_else(|| panic!("json key {key:?} is not a number"))
    }

    pub fn str_of(&self, key: &str) -> String {
        self.expect(key)
            .as_str()
            .unwrap_or_else(|| panic!("json key {key:?} is not a string"))
            .to_string()
    }

    pub fn usize_arr_of(&self, key: &str) -> Vec<usize> {
        self.expect(key)
            .as_arr()
            .unwrap_or_else(|| panic!("json key {key:?} is not an array"))
            .iter()
            .map(|v| v.as_usize().expect("array element is not a number"))
            .collect()
    }

    // -- writer -------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

fn write_escaped(sv: &str, out: &mut String) {
    out.push('"');
    for c in sv.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect_byte(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect_byte(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs: rare in our artifacts; handle anyway.
                            if (0xD800..0xDC00).contains(&cp) {
                                self.i += 5;
                                if self.peek() != Some(b'\\') {
                                    return Err(self.err("lone surrogate"));
                                }
                                self.i += 1;
                                if self.peek() != Some(b'u') {
                                    return Err(self.err("lone surrogate"));
                                }
                                let hex2 = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                                let lo = u32::from_str_radix(hex2, 16)
                                    .map_err(|_| self.err("bad \\u escape"))?;
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                out.push(char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?);
                                self.i += 4; // the final +1 below covers the last hex digit
                            } else {
                                out.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                                self.i += 4;
                            }
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect_byte(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let t = r#"{"a": [1, 2.5, -3e2], "b": "hi\nthere", "c": null, "d": true}"#;
        let v = Json::parse(t).unwrap();
        assert_eq!(v.expect("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.str_of("b"), "hi\nthere");
        assert_eq!(v.expect("c"), &Json::Null);
        assert_eq!(v.expect("d").as_bool(), Some(true));
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""café 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café 😀");
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn nested_deep() {
        let v = Json::parse(r#"{"m":{"n":{"o":[{"p":1}]}}}"#).unwrap();
        assert_eq!(
            v.expect("m").expect("n").expect("o").as_arr().unwrap()[0].usize_of("p"),
            1
        );
    }

    #[test]
    fn number_formats() {
        for (t, want) in [("0", 0.0), ("-1", -1.0), ("3.25", 3.25), ("1e3", 1000.0), ("2E-2", 0.02)] {
            assert_eq!(Json::parse(t).unwrap().as_f64(), Some(want), "{t}");
        }
    }
}
