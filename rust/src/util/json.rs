//! Minimal JSON parser/serializer + lazy field extraction.
//!
//! serde/serde_json are not available in this offline image (DESIGN.md §3),
//! and our needs are narrow: the artifact manifest, vocab, task sets, report
//! emission, and the HTTP serving front-end's request bodies (DESIGN.md
//! §14). This is a strict recursive-descent parser over the JSON grammar
//! (RFC 8259) with `\uXXXX` escapes, plus a compact writer, plus
//! [`LazyDoc`] — single-pass, allocation-free extraction of individual
//! top-level fields for hot request paths that must not pay for a full
//! tree build (the mik-sdk ADR-002 idiom: lazy path extraction beats a
//! full-tree parse by an order of magnitude on large skipped payloads).
//!
//! Hardening (the serving front-end feeds this parser untrusted bytes):
//! * nesting depth is capped at [`MAX_DEPTH`] — deeply nested input fails
//!   with a [`JsonError`] instead of overflowing the parse stack;
//! * numbers follow the RFC 8259 grammar strictly (no leading zeros, no
//!   bare `-`/`-.5`/`1.`), and values that overflow f64 to ±inf are
//!   rejected — `NaN`/`Infinity` literals never existed in the grammar, so
//!   a parsed document can never materialise a non-finite number;
//! * truncated `\uXXXX` escapes and malformed surrogate pairs (lone highs,
//!   lone lows, a high followed by a non-low) are errors, never panics.

use std::collections::BTreeMap;
use std::fmt;

/// Maximum container nesting the parser accepts. Recursive descent keeps a
/// stack frame per level, so this bound is what turns hostile
/// `[[[[…]]]]` input into a clean [`JsonError`] instead of a stack
/// overflow. Far above anything our manifests or request bodies nest.
pub const MAX_DEPTH: usize = 128;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0, depth: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Panicking accessor for manifest plumbing where absence is a build bug.
    pub fn expect(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing json key {key:?}"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key)
            .and_then(|v| v.as_str())
            .unwrap_or(default)
            .to_string()
    }

    pub fn usize_of(&self, key: &str) -> usize {
        self.expect(key)
            .as_usize()
            .unwrap_or_else(|| panic!("json key {key:?} is not a number"))
    }

    pub fn f64_of(&self, key: &str) -> f64 {
        self.expect(key)
            .as_f64()
            .unwrap_or_else(|| panic!("json key {key:?} is not a number"))
    }

    pub fn str_of(&self, key: &str) -> String {
        self.expect(key)
            .as_str()
            .unwrap_or_else(|| panic!("json key {key:?} is not a string"))
            .to_string()
    }

    pub fn usize_arr_of(&self, key: &str) -> Vec<usize> {
        self.expect(key)
            .as_arr()
            .unwrap_or_else(|| panic!("json key {key:?} is not an array"))
            .iter()
            .map(|v| v.as_usize().expect("array element is not a number"))
            .collect()
    }

    // -- writer -------------------------------------------------------------

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Compact (no-whitespace) serialization; `.to_string()` comes with it via
/// the blanket [`ToString`] impl.
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut out = String::new();
        self.write(&mut out);
        f.write_str(&out)
    }
}

pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

fn write_escaped(sv: &str, out: &mut String) {
    out.push('"');
    for c in sv.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------------------------------------------------------------------
// Lazy field extraction
// ---------------------------------------------------------------------------

/// Single-pass field extraction over one JSON **object** document, without
/// building a [`Json`] tree: the serving front-end's hot request path
/// (DESIGN.md §14) reads a handful of small scalar fields (`variant`,
/// `max_tokens`, `stream`, `priority`) next to one potentially-huge value
/// (`prompt`, a token array), and a full-tree parse would allocate a node
/// per token just to look at the scalars.
///
/// Every scan *skips* values it is not asked for — structurally validated
/// (string escapes, strict number grammar, [`MAX_DEPTH`]) but never
/// allocated. [`LazyDoc::validate`] runs that allocation-free skip over
/// the whole document once; after it passes, per-field extraction can
/// early-return at its match without re-validating the tail. `LazyDoc`
/// accepts exactly the object documents [`Json::parse`] accepts (pinned by
/// unit test).
///
/// ```
/// use tor_ssm::util::json::LazyDoc;
/// let doc = LazyDoc::new(r#"{"prompt":[1,2,3],"stream":true,"max_tokens":8}"#);
/// doc.validate().unwrap();
/// assert_eq!(doc.i32_array_field("prompt").unwrap(), Some(vec![1, 2, 3]));
/// assert_eq!(doc.bool_field("stream").unwrap(), Some(true));
/// assert_eq!(doc.usize_field("max_tokens").unwrap(), Some(8));
/// assert_eq!(doc.raw_field("missing").unwrap(), None);
/// ```
pub struct LazyDoc<'a> {
    text: &'a str,
}

impl<'a> LazyDoc<'a> {
    pub fn new(text: &'a str) -> LazyDoc<'a> {
        LazyDoc { text }
    }

    /// Validate the whole document (one JSON object, nothing trailing) in a
    /// single allocation-free pass. Error positions are byte offsets into
    /// the document, same as [`Json::parse`].
    pub fn validate(&self) -> Result<(), JsonError> {
        let mut p = Parser { b: self.text.as_bytes(), i: 0, depth: 0 };
        p.ws();
        if p.peek() != Some(b'{') {
            return Err(p.err("document must be a JSON object"));
        }
        p.skip_value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(())
    }

    /// The raw text span of top-level field `key` (`None` when absent).
    /// Scans keys in document order, skipping every other value without
    /// allocating, and returns at the match — the lazy-extraction core the
    /// typed helpers build on.
    pub fn raw_field(&self, key: &str) -> Result<Option<&'a str>, JsonError> {
        let mut p = Parser { b: self.text.as_bytes(), i: 0, depth: 0 };
        p.ws();
        if p.peek() != Some(b'{') {
            return Err(p.err("document must be a JSON object"));
        }
        p.i += 1;
        p.depth = 1;
        p.ws();
        if p.peek() == Some(b'}') {
            return Ok(None);
        }
        loop {
            p.ws();
            let key_start = p.i;
            p.skip_string()?;
            let matched = key_matches(&self.text[key_start..p.i], key);
            p.ws();
            p.expect_byte(b':')?;
            p.ws();
            let start = p.i;
            p.skip_value()?;
            if matched {
                return Ok(Some(&self.text[start..p.i]));
            }
            p.ws();
            match p.peek() {
                Some(b',') => p.i += 1,
                Some(b'}') => return Ok(None),
                _ => return Err(p.err("expected , or }")),
            }
        }
    }

    /// Top-level string field, unescaped.
    pub fn str_field(&self, key: &str) -> Result<Option<String>, JsonError> {
        match self.raw_field(key)? {
            None => Ok(None),
            Some(raw) => {
                let mut p = Parser { b: raw.as_bytes(), i: 0, depth: 0 };
                if p.peek() != Some(b'"') {
                    return Err(p.err("field is not a string"));
                }
                Ok(Some(p.string()?))
            }
        }
    }

    /// Top-level number field.
    pub fn f64_field(&self, key: &str) -> Result<Option<f64>, JsonError> {
        match self.raw_field(key)? {
            None => Ok(None),
            Some(raw) => {
                let mut p = Parser { b: raw.as_bytes(), i: 0, depth: 0 };
                match p.peek() {
                    Some(c) if c == b'-' || c.is_ascii_digit() => match p.number()? {
                        Json::Num(x) => Ok(Some(x)),
                        _ => unreachable!("number() only builds Num"),
                    },
                    _ => Err(p.err("field is not a number")),
                }
            }
        }
    }

    /// Top-level non-negative integer field (rejects fractions and
    /// negatives — request knobs like `max_tokens` must be exact counts).
    pub fn usize_field(&self, key: &str) -> Result<Option<usize>, JsonError> {
        match self.f64_field(key)? {
            None => Ok(None),
            Some(x) if x.fract() == 0.0 && (0.0..=u32::MAX as f64).contains(&x) => {
                Ok(Some(x as usize))
            }
            Some(_) => Err(JsonError {
                msg: format!("field {key:?} is not a non-negative integer"),
                pos: 0,
            }),
        }
    }

    /// Top-level boolean field.
    pub fn bool_field(&self, key: &str) -> Result<Option<bool>, JsonError> {
        match self.raw_field(key)? {
            None => Ok(None),
            Some("true") => Ok(Some(true)),
            Some("false") => Ok(Some(false)),
            Some(_) => Err(JsonError { msg: format!("field {key:?} is not a bool"), pos: 0 }),
        }
    }

    /// Top-level array-of-i32 field, parsed straight into a `Vec<i32>`
    /// with no per-element [`Json`] nodes — the `prompt` hot path. Elements
    /// must be exact integers in i32 range.
    pub fn i32_array_field(&self, key: &str) -> Result<Option<Vec<i32>>, JsonError> {
        let raw = match self.raw_field(key)? {
            None => return Ok(None),
            Some(raw) => raw,
        };
        let mut p = Parser { b: raw.as_bytes(), i: 0, depth: 0 };
        if p.peek() != Some(b'[') {
            return Err(p.err("field is not an array"));
        }
        p.i += 1;
        let mut v = Vec::new();
        p.ws();
        if p.peek() == Some(b']') {
            return Ok(Some(v));
        }
        loop {
            p.ws();
            let x = match p.peek() {
                Some(c) if c == b'-' || c.is_ascii_digit() => match p.number()? {
                    Json::Num(x) => x,
                    _ => unreachable!("number() only builds Num"),
                },
                _ => return Err(p.err("array element is not a number")),
            };
            if x.fract() != 0.0 || !(i32::MIN as f64..=i32::MAX as f64).contains(&x) {
                return Err(p.err("array element is not an i32"));
            }
            v.push(x as i32);
            p.ws();
            match p.peek() {
                Some(b',') => p.i += 1,
                Some(b']') => return Ok(Some(v)),
                _ => return Err(p.err("expected , or ]")),
            }
        }
    }
}

/// Does a raw key span (still quoted, escapes intact) equal `key`? Fast
/// path: no backslash in the span → direct byte compare of the interior.
/// Escaped keys fall back to a real unescape (rare; our request fields are
/// plain ASCII).
fn key_matches(raw: &str, key: &str) -> bool {
    let interior = &raw[1..raw.len() - 1];
    if !interior.contains('\\') {
        return interior == key;
    }
    let mut p = Parser { b: raw.as_bytes(), i: 0, depth: 0 };
    p.string().map(|k| k == key).unwrap_or(false)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    /// Current container nesting, bounded by [`MAX_DEPTH`].
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect_byte(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn enter(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting deeper than MAX_DEPTH"));
        }
        Ok(())
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    /// Skip one value: full structural validation (escapes, number
    /// grammar, depth), zero allocation — the lazy-extraction workhorse.
    fn skip_value(&mut self) -> Result<(), JsonError> {
        match self.peek() {
            Some(b'{') => {
                self.i += 1;
                self.enter()?;
                self.ws();
                if self.peek() == Some(b'}') {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(());
                }
                loop {
                    self.ws();
                    self.skip_string()?;
                    self.ws();
                    self.expect_byte(b':')?;
                    self.ws();
                    self.skip_value()?;
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b'}') => {
                            self.i += 1;
                            self.depth -= 1;
                            return Ok(());
                        }
                        _ => return Err(self.err("expected , or }")),
                    }
                }
            }
            Some(b'[') => {
                self.i += 1;
                self.enter()?;
                self.ws();
                if self.peek() == Some(b']') {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(());
                }
                loop {
                    self.ws();
                    self.skip_value()?;
                    self.ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b']') => {
                            self.i += 1;
                            self.depth -= 1;
                            return Ok(());
                        }
                        _ => return Err(self.err("expected , or ]")),
                    }
                }
            }
            Some(b'"') => self.skip_string(),
            Some(b't') => self.lit("true", Json::Null).map(|_| ()),
            Some(b'f') => self.lit("false", Json::Null).map(|_| ()),
            Some(b'n') => self.lit("null", Json::Null).map(|_| ()),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number().map(|_| ()),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    /// Consume ASCII digits; returns how many.
    fn digits(&mut self) -> usize {
        let n0 = self.i;
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        self.i - n0
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        // Strict RFC 8259 int: "0" or nonzero-digit digits. A bare "-",
        // "-.5", "1.", "1e" and leading zeros ("01") are malformed — the
        // serving front-end must not be more lenient than the grammar it
        // documents.
        let int_start = self.i;
        if self.digits() == 0 {
            return Err(self.err("bad number: missing integer digits"));
        }
        if self.i - int_start > 1 && self.b[int_start] == b'0' {
            return Err(self.err("bad number: leading zero"));
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            if self.digits() == 0 {
                return Err(self.err("bad number: missing fraction digits"));
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            if self.digits() == 0 {
                return Err(self.err("bad number: missing exponent digits"));
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        let x: f64 = text.parse().map_err(|_| self.err("bad number"))?;
        // The grammar admits magnitudes that overflow f64 ("1e999"); those
        // must not materialise ±inf into a document (`NaN` never parses —
        // no grammar production reaches it).
        if !x.is_finite() {
            return Err(self.err("number overflows f64"));
        }
        Ok(Json::Num(x))
    }

    /// Read the 4 hex digits of a `\uXXXX` escape. On entry `self.i` is at
    /// the `u`; on success it is left at the **last hex digit** (callers
    /// advance past it). Bounds-checked: truncated input is an error, not a
    /// slice panic.
    fn hex4_after_u(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 >= self.b.len() {
            return Err(self.err("bad \\u escape"));
        }
        let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
            .map_err(|_| self.err("bad \\u escape"))?;
        let cp = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.i += 4;
        Ok(cp)
    }

    /// Parse + validate a `\uXXXX` escape (surrogate pairs included),
    /// leaving `self.i` at the last consumed byte. Shared by the
    /// allocating and skipping string scanners so both enforce identical
    /// rules: a high surrogate must be followed by an in-range low
    /// surrogate escape, and a lone low surrogate is malformed.
    fn unicode_escape(&mut self) -> Result<char, JsonError> {
        let cp = self.hex4_after_u()?;
        if (0xD800..0xDC00).contains(&cp) {
            self.i += 1;
            if self.peek() != Some(b'\\') {
                return Err(self.err("lone surrogate"));
            }
            self.i += 1;
            if self.peek() != Some(b'u') {
                return Err(self.err("lone surrogate"));
            }
            let lo = self.hex4_after_u()?;
            if !(0xDC00..0xE000).contains(&lo) {
                return Err(self.err("bad low surrogate"));
            }
            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
            char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))
        } else {
            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect_byte(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => out.push(self.unicode_escape()?),
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    /// Skip a string with full escape validation and zero allocation. The
    /// input is `&str`, so bare (non-escape) bytes are already valid UTF-8
    /// and can be hopped byte-wise — UTF-8 continuation bytes never equal
    /// `"` or `\`.
    fn skip_string(&mut self) -> Result<(), JsonError> {
        self.expect_byte(b'"')?;
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(());
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(
                            b'"' | b'\\' | b'/' | b'b' | b'f' | b'n' | b'r' | b't',
                        ) => {}
                        Some(b'u') => {
                            self.unicode_escape()?;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => self.i += 1,
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'[')?;
        self.enter()?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'{')?;
        self.enter()?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            self.depth -= 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect_byte(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    self.depth -= 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let t = r#"{"a": [1, 2.5, -3e2], "b": "hi\nthere", "c": null, "d": true}"#;
        let v = Json::parse(t).unwrap();
        assert_eq!(v.expect("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.str_of("b"), "hi\nthere");
        assert_eq!(v.expect("c"), &Json::Null);
        assert_eq!(v.expect("d").as_bool(), Some(true));
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""café 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "café 😀");
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn nested_deep() {
        let v = Json::parse(r#"{"m":{"n":{"o":[{"p":1}]}}}"#).unwrap();
        assert_eq!(
            v.expect("m").expect("n").expect("o").as_arr().unwrap()[0].usize_of("p"),
            1
        );
    }

    #[test]
    fn number_formats() {
        for (t, want) in [("0", 0.0), ("-1", -1.0), ("3.25", 3.25), ("1e3", 1000.0), ("2E-2", 0.02)] {
            assert_eq!(Json::parse(t).unwrap().as_f64(), Some(want), "{t}");
        }
        // Strict-grammar accepts: zero ints, signed exponents, -0.
        for t in ["-0", "0.5", "10", "1E+3", "0e0", "0.0e-1"] {
            assert!(Json::parse(t).is_ok(), "{t} rejected");
        }
    }

    /// RFC 8259 number grammar is enforced strictly, and values that
    /// overflow f64 (the only road to a non-finite number — `NaN` and
    /// `Infinity` have no grammar production) are rejected rather than
    /// materialised as ±inf.
    #[test]
    fn number_edge_cases_rejected() {
        for t in [
            "1e999", "-1e999", // overflow to ±inf
            "01", "-01", "00", // leading zeros
            "-", "-.5", ".5", "1.", "1e", "1e+", "+1", // grammar violations
            "NaN", "Infinity", "-Infinity", "nan", "inf", // non-literals
        ] {
            assert!(Json::parse(t).is_err(), "{t:?} accepted");
        }
        // Large-but-finite survives.
        assert_eq!(Json::parse("1e308").unwrap().as_f64(), Some(1e308));
    }

    /// Escape-sequence battery: surrogate pairs decode; every truncated or
    /// malformed surrogate form is a clean error (the truncated forms used
    /// to slice out of bounds, and an out-of-range low surrogate used to
    /// underflow in debug builds).
    #[test]
    fn surrogate_pairs_and_truncations() {
        assert_eq!(Json::parse(r#""😀""#).unwrap().as_str().unwrap(), "😀");
        assert_eq!(Json::parse(r#""Aé""#).unwrap().as_str().unwrap(), "Aé");
        for bad in [
            r#""\u"#,            // truncated escape, no hex
            r#""\u00"#,          // truncated hex
            r#""\ud83d"#,        // high surrogate, string truncated
            r#""\ud83d\"#,       // high surrogate, escape truncated
            r#""\ud83d\u"#,      // second escape with no hex
            r#""\ud83d\ud"#,     // second escape, truncated hex
            r#""\ud83d\ude0"#,   // second escape, 3 hex digits then EOF
            r#""\ud83dA""#, // high surrogate + non-surrogate
            r#""\ud83d\ud83d""#, // high surrogate + high surrogate
            r#""\ude00""#,       // lone low surrogate
            r#""\ud83dx""#,      // high surrogate + bare char
        ] {
            assert!(Json::parse(bad).is_err(), "{bad:?} accepted");
        }
    }

    /// Hostile nesting fails with a JsonError at MAX_DEPTH, not a stack
    /// overflow; nesting under the cap still parses.
    #[test]
    fn depth_limit() {
        let deep_ok = format!("{}1{}", "[".repeat(MAX_DEPTH - 1), "]".repeat(MAX_DEPTH - 1));
        assert!(Json::parse(&deep_ok).is_ok());
        let deep_bad = format!("{}1{}", "[".repeat(MAX_DEPTH + 1), "]".repeat(MAX_DEPTH + 1));
        let err = Json::parse(&deep_bad).unwrap_err();
        assert!(err.msg.contains("MAX_DEPTH"), "{err}");
        // Far past the limit must still be an error, not an abort.
        let hostile = "[".repeat(100_000);
        assert!(Json::parse(&hostile).is_err());
        // Mixed object/array nesting counts every level.
        let mixed = format!("{}1{}", r#"{"k":["#.repeat(70), "]}".repeat(70));
        assert!(Json::parse(&mixed).is_err());
        // The lazy skip path shares the same cap.
        let doc = format!(r#"{{"deep":{},"x":1}}"#, hostile);
        assert!(LazyDoc::new(&doc).raw_field("x").is_err());
    }

    #[test]
    fn lazy_extracts_fields_without_full_parse() {
        let doc = LazyDoc::new(
            r#"{"prompt": [3, 1, 4, 1, 5], "variant": "unified@0.2", "stream": true,
               "max_tokens": 12, "priority": "high", "temp": 0.5}"#,
        );
        doc.validate().unwrap();
        assert_eq!(doc.i32_array_field("prompt").unwrap(), Some(vec![3, 1, 4, 1, 5]));
        assert_eq!(doc.str_field("variant").unwrap(), Some("unified@0.2".into()));
        assert_eq!(doc.bool_field("stream").unwrap(), Some(true));
        assert_eq!(doc.usize_field("max_tokens").unwrap(), Some(12));
        assert_eq!(doc.f64_field("temp").unwrap(), Some(0.5));
        assert_eq!(doc.raw_field("missing").unwrap(), None);
        // Type mismatches are errors, not coercions.
        assert!(doc.bool_field("variant").is_err());
        assert!(doc.str_field("stream").is_err());
        assert!(doc.i32_array_field("variant").is_err());
        assert!(doc.usize_field("temp").is_err());
    }

    #[test]
    fn lazy_skips_large_and_nested_values() {
        // The scalar lives AFTER a large token array and a nested object —
        // both must be skipped structurally without tree allocation.
        let prompt: Vec<String> = (0..10_000).map(|i| i.to_string()).collect();
        let doc_text = format!(
            r#"{{"prompt":[{}],"meta":{{"a":[1,{{"b":"x\nA"}}],"c":null}},"stream":false}}"#,
            prompt.join(",")
        );
        let doc = LazyDoc::new(&doc_text);
        doc.validate().unwrap();
        assert_eq!(doc.bool_field("stream").unwrap(), Some(false));
        assert_eq!(doc.i32_array_field("prompt").unwrap().unwrap().len(), 10_000);
    }

    #[test]
    fn lazy_i32_array_rejects_non_i32_elements() {
        for bad in [
            r#"{"p":[1.5]}"#,
            r#"{"p":[3000000000]}"#,
            r#"{"p":[-3000000000]}"#,
            r#"{"p":["x"]}"#,
            r#"{"p":[1,]}"#,
            r#"{"p":1}"#,
        ] {
            assert!(LazyDoc::new(bad).i32_array_field("p").is_err(), "{bad} accepted");
        }
        assert_eq!(LazyDoc::new(r#"{"p":[]}"#).i32_array_field("p").unwrap(), Some(vec![]));
        assert_eq!(
            LazyDoc::new(r#"{"p":[-2147483648,2147483647]}"#).i32_array_field("p").unwrap(),
            Some(vec![i32::MIN, i32::MAX])
        );
    }

    /// Escaped keys still match (slow path), and the fast path never
    /// matches a key whose raw bytes differ.
    #[test]
    fn lazy_escaped_keys() {
        let doc = LazyDoc::new(r#"{"a\nb": 1, "ab": 2}"#);
        assert_eq!(doc.f64_field("a\nb").unwrap(), Some(1.0));
        assert_eq!(doc.f64_field("ab").unwrap(), Some(2.0));
    }

    /// The lazy validator accepts exactly the object documents the
    /// full-tree parser accepts, and extracted spans re-parse to the same
    /// value the tree holds — the pin that keeps the two parsers from
    /// drifting.
    #[test]
    fn lazy_agrees_with_full_tree_parser() {
        let good = [
            r#"{}"#,
            r#"{"a":1}"#,
            r#"{"prompt":[1,2,3],"variant":"dense","stream":true,"max_tokens":4}"#,
            r#"{"s":"café 😀","n":-2.5e-3,"z":null,"o":{"k":[{}]}}"#,
            "{ \"ws\" :\t[ 1 ,\n2 ] }",
        ];
        for t in good {
            let tree = Json::parse(t).expect(t);
            let lazy = LazyDoc::new(t);
            lazy.validate().unwrap_or_else(|e| panic!("{t}: {e}"));
            if let Json::Obj(m) = &tree {
                for (k, v) in m {
                    let raw = lazy.raw_field(k).unwrap().expect("field present");
                    assert_eq!(&Json::parse(raw).unwrap(), v, "field {k} of {t}");
                }
            }
        }
        let bad = [
            "",
            "[1,2]",     // not an object (LazyDoc is object-only)
            "42",        // not an object
            r#"{"a":}"#,
            r#"{"a":1,}"#,
            r#"{"a" 1}"#,
            r#"{"a":01}"#,
            r#"{"a":1e999}"#,
            r#"{"a":"\ud83d"}"#,
            r#"{"a":"unterminated}"#,
            r#"{"a":tru}"#,
            r#"{"a":1} extra"#,
            r#"{"a":[1,2}"#,
        ];
        for t in bad {
            assert!(LazyDoc::new(t).validate().is_err(), "lazy accepted {t:?}");
            // Full parser agrees on everything except the object-only rule.
            if !t.is_empty() && !t.starts_with('[') && t != "42" {
                assert!(Json::parse(t).is_err(), "tree parser accepted {t:?}");
            }
        }
    }
}
