//! Timing statistics for the bench harness (criterion substitute).

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub max_ns: f64,
}

impl Summary {
    pub fn from_durations(mut ns: Vec<f64>) -> Summary {
        assert!(!ns.is_empty());
        ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = ns.len();
        let mean = ns.iter().sum::<f64>() / n as f64;
        let var = ns.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let q = |p: f64| ns[((n as f64 - 1.0) * p).round() as usize];
        Summary {
            n,
            mean_ns: mean,
            std_ns: var.sqrt(),
            min_ns: ns[0],
            p50_ns: q(0.5),
            p99_ns: q(0.99),
            max_ns: ns[n - 1],
        }
    }

    pub fn mean(&self) -> Duration {
        Duration::from_nanos(self.mean_ns as u64)
    }
}

pub fn human(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Run `f` with warmup, then measure `iters` iterations (each possibly
/// batched internally by the caller). Returns per-iteration stats.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    Summary::from_durations(samples)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_quantiles() {
        let s = Summary::from_durations((1..=100).map(|x| x as f64).collect());
        assert_eq!(s.n, 100);
        assert_eq!(s.min_ns, 1.0);
        assert_eq!(s.max_ns, 100.0);
        assert!((s.mean_ns - 50.5).abs() < 1e-9);
        assert!((s.p50_ns - 50.0).abs() <= 1.0);
        assert!(s.p99_ns >= 98.0);
    }

    #[test]
    fn human_formats() {
        assert_eq!(human(500.0), "500 ns");
        assert!(human(1.5e3).contains("µs"));
        assert!(human(2.0e6).contains("ms"));
        assert!(human(3.0e9).contains("s"));
    }
}
