//! Timing statistics for the bench harness (criterion substitute).

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
    pub max_ns: f64,
}

impl Summary {
    /// Summarise a sample vector. Quantiles use the **nearest-rank**
    /// definition `v_sorted[⌈p·N⌉ − 1]` (rank clamped to 1..=N) — the same
    /// definition as `coordinator::metrics::Metrics::pct`, so every bench
    /// emitter reports identical percentile semantics (PERFORMANCE.md
    /// §Schema; the two implementations are pinned against each other on a
    /// shared test vector). An empty sample vector returns the documented
    /// all-zero `Summary` (`n == 0`) instead of panicking, matching
    /// `Metrics::pct`'s 0-on-empty — a zero-iteration bench config reports
    /// an empty row, it does not abort the run.
    pub fn from_durations(mut ns: Vec<f64>) -> Summary {
        if ns.is_empty() {
            return Summary {
                n: 0,
                mean_ns: 0.0,
                std_ns: 0.0,
                min_ns: 0.0,
                p50_ns: 0.0,
                p99_ns: 0.0,
                max_ns: 0.0,
            };
        }
        ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = ns.len();
        let mean = ns.iter().sum::<f64>() / n as f64;
        let var = ns.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let q = |p: f64| ns[((p * n as f64).ceil() as usize).clamp(1, n) - 1];
        Summary {
            n,
            mean_ns: mean,
            std_ns: var.sqrt(),
            min_ns: ns[0],
            p50_ns: q(0.5),
            p99_ns: q(0.99),
            max_ns: ns[n - 1],
        }
    }

    pub fn mean(&self) -> Duration {
        Duration::from_nanos(self.mean_ns as u64)
    }
}

pub fn human(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Run `f` with warmup, then measure `iters` iterations (each possibly
/// batched internally by the caller). Returns per-iteration stats.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    Summary::from_durations(samples)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_quantiles() {
        let s = Summary::from_durations((1..=100).map(|x| x as f64).collect());
        assert_eq!(s.n, 100);
        assert_eq!(s.min_ns, 1.0);
        assert_eq!(s.max_ns, 100.0);
        assert!((s.mean_ns - 50.5).abs() < 1e-9);
        // Nearest-rank exactly: ⌈0.5·100⌉ = 50th, ⌈0.99·100⌉ = 99th.
        assert_eq!(s.p50_ns, 50.0);
        assert_eq!(s.p99_ns, 99.0);
    }

    /// The shared pinned vector from `coordinator::metrics`: the same known
    /// 20 samples, deliberately unsorted, must produce the same nearest-rank
    /// answers here AND through `Metrics::pct` — the two percentile
    /// implementations are pinned against each other so they can never
    /// silently diverge again (PERFORMANCE.md §Schema).
    #[test]
    fn summary_quantiles_agree_with_metrics_pct_on_pinned_vector() {
        use crate::coordinator::metrics::Metrics;
        let mut xs: Vec<u64> = (1..=20).map(|i| i * 10).collect(); // 10,20,...,200
        // shuffle deterministically: reverse + swap pairs (same as the
        // metrics-side test)
        xs.reverse();
        xs.swap(0, 7);
        xs.swap(3, 15);
        let s = Summary::from_durations(xs.iter().map(|&x| x as f64).collect());
        assert_eq!(s.n, 20);
        assert_eq!(s.p50_ns, 100.0); // ⌈0.50·20⌉ = 10th smallest
        assert_eq!(s.p99_ns, 200.0); // ⌈0.99·20⌉ = 20th smallest
        assert_eq!(s.min_ns, 10.0);
        assert_eq!(s.max_ns, 200.0);
        // Cross-pin: both emitters give identical answers on the vector.
        assert_eq!(Metrics::pct(&xs, 0.5) as f64, s.p50_ns);
        assert_eq!(Metrics::pct(&xs, 0.99) as f64, s.p99_ns);
        // Odd count: the true median, not a neighbour (matches
        // `Metrics::pct(&[5, 1, 9], 0.5) == 5`).
        let s3 = Summary::from_durations(vec![5.0, 1.0, 9.0]);
        assert_eq!(s3.p50_ns, 5.0);
        assert_eq!(Metrics::pct(&[5, 1, 9], 0.5) as f64, s3.p50_ns);
    }

    /// An empty sample vector is a reportable empty row, not a panic —
    /// matching `Metrics::pct`'s 0-on-empty semantics.
    #[test]
    fn summary_of_empty_samples_is_all_zero() {
        let s = Summary::from_durations(Vec::new());
        assert_eq!(s.n, 0);
        assert_eq!(s.mean_ns, 0.0);
        assert_eq!(s.std_ns, 0.0);
        assert_eq!(s.min_ns, 0.0);
        assert_eq!(s.p50_ns, 0.0);
        assert_eq!(s.p99_ns, 0.0);
        assert_eq!(s.max_ns, 0.0);
        assert_eq!(s.mean(), Duration::ZERO);
    }

    #[test]
    fn human_formats() {
        assert_eq!(human(500.0), "500 ns");
        assert!(human(1.5e3).contains("µs"));
        assert!(human(2.0e6).contains("ms"));
        assert!(human(3.0e9).contains("s"));
    }
}
