//! Zero-shot evaluation harness: the lm-eval-harness analogue driving the
//! six synthetic benchmarks through an AOT-compiled model variant.
//!
//! Per task item, each choice becomes one padded sequence (context ++
//! choice); sequences are batched to the executable's static (B, L) and the
//! choice with the best length-normalized log-prob wins. s-lambada is scored
//! as cloze: PPL of the target token + greedy accuracy.

pub mod scoring;

use anyhow::{ensure, Context, Result};

use crate::data::{Task, TaskItem};
use crate::manifest::{HloEntry, Manifest, ModelEntry};
use crate::reduction::policy::PolicySpec;
use crate::runtime::{DeviceWeights, HostTensor, Runtime};
use crate::tokenizer::Tokenizer;
use crate::util::pool::par_map;
use scoring::{Scheme, SeqLogits};

// The harness is backend-agnostic: every forward goes through
// `Executable::execute` with backend-resident weights, so the same code
// drives AOT-compiled modules (pjrt) and the hermetic reference backend.

/// One encoded scoring request: a fixed-length token buffer plus the span
/// of positions (original frame) belonging to the choice.
#[derive(Debug, Clone)]
pub struct EncodedSeq {
    pub tokens: Vec<i32>,
    pub span: (usize, usize),
    /// (task_idx, item_idx, choice_idx)
    pub key: (usize, usize, usize),
}

#[derive(Debug, Clone, Default)]
pub struct TaskResult {
    pub name: String,
    pub n_items: usize,
    pub acc_aligned: f64,
    pub acc_truncated: f64,
    /// s-lambada only (else 0): target-token perplexity.
    pub ppl_aligned: f64,
    pub ppl_truncated: f64,
}

#[derive(Debug, Clone, Default)]
pub struct EvalResult {
    pub model: String,
    pub variant: String,
    pub tasks: Vec<TaskResult>,
    pub wall_s: f64,
    pub sequences: usize,
}

impl EvalResult {
    pub fn avg_acc(&self, scheme: Scheme) -> f64 {
        let accs: Vec<f64> = self
            .tasks
            .iter()
            .map(|t| match scheme {
                Scheme::Aligned => t.acc_aligned,
                Scheme::Truncated => t.acc_truncated,
            })
            .collect();
        accs.iter().sum::<f64>() / accs.len().max(1) as f64
    }

    pub fn lambada_ppl(&self, scheme: Scheme) -> f64 {
        self.tasks
            .iter()
            .find(|t| t.name == "s_lambada")
            .map(|t| match scheme {
                Scheme::Aligned => t.ppl_aligned,
                Scheme::Truncated => t.ppl_truncated,
            })
            .unwrap_or(f64::NAN)
    }
}

pub fn encode_tasks(
    tok: &Tokenizer,
    tasks: &[Task],
    seq_len: usize,
    max_items: usize,
) -> Result<Vec<EncodedSeq>> {
    let mut out = Vec::new();
    for (ti, task) in tasks.iter().enumerate() {
        for (ii, item) in task.items.iter().take(max_items).enumerate() {
            for (ci, seq) in encode_item(tok, item, seq_len, (ti, ii))?.into_iter().enumerate() {
                debug_assert_eq!(seq.key.2, ci);
                out.push(seq);
            }
        }
    }
    Ok(out)
}

fn encode_item(
    tok: &Tokenizer,
    item: &TaskItem,
    seq_len: usize,
    key2: (usize, usize),
) -> Result<Vec<EncodedSeq>> {
    let ctx: Vec<i32> = tok.encode(&item.context).iter().map(|&x| x as i32).collect();
    let mut out = Vec::new();
    for (ci, choice) in item.choices.iter().enumerate() {
        let ch: Vec<i32> = tok.encode(choice).iter().map(|&x| x as i32).collect();
        ensure!(!ch.is_empty(), "empty choice");
        let mut tokens = ctx.clone();
        let start = tokens.len();
        tokens.extend_from_slice(&ch);
        let end = tokens.len();
        ensure!(
            end <= seq_len,
            "sequence too long for eval frame: {} > {seq_len}",
            end
        );
        tokens.resize(seq_len, crate::tokenizer::PAD as i32);
        out.push(EncodedSeq { tokens, span: (start, end), key: (key2.0, key2.1, ci) });
    }
    Ok(out)
}

/// Raw per-choice scores, indexed like the task items.
#[derive(Debug, Clone, Copy, Default)]
pub struct ChoiceScore {
    pub lp_aligned: f64,
    pub n_aligned: usize,
    pub lp_truncated: f64,
    pub n_truncated: usize,
    pub greedy_hit_aligned: bool,
    pub greedy_hit_truncated: bool,
}

/// Run every sequence through the executable in static batches; return one
/// ChoiceScore per sequence (same order). `policy` optionally overrides the
/// entry's reduction algorithm at its plan boundaries (DESIGN.md §10) —
/// reference backend only.
pub fn run_scoring(
    rt: &Runtime,
    man: &Manifest,
    model: &ModelEntry,
    entry: &HloEntry,
    weights: &DeviceWeights,
    seqs: &[EncodedSeq],
    vocab: usize,
    policy: Option<&PolicySpec>,
) -> Result<Vec<ChoiceScore>> {
    let exe = rt.load_entry_with_policy(man, model, entry, policy)?;
    let (b, l, out_len) = (entry.batch, entry.seq_len, entry.out_len);
    let mut scores = vec![ChoiceScore::default(); seqs.len()];

    for (chunk_idx, chunk) in seqs.chunks(b).enumerate() {
        let mut flat = Vec::with_capacity(b * l);
        for s in chunk {
            flat.extend_from_slice(&s.tokens);
        }
        flat.resize(b * l, crate::tokenizer::PAD as i32); // ragged tail batch
        let tokens = HostTensor::i32(vec![b, l], flat);
        let outs = exe.execute(weights, &[tokens]).context("eval forward")?;
        ensure!(outs.len() == 2, "eval executable must return (logits, kept)");
        let logits = outs[0].as_f32()?;
        let kept = outs[1].as_i32()?;
        ensure!(outs[0].shape == vec![b, out_len, vocab], "bad logits shape {:?}", outs[0].shape);

        // Score this chunk's sequences in parallel (pure host math).
        let chunk_scores = par_map(chunk.len(), 8, |i| {
            let sl = SeqLogits {
                logits: &logits[i * out_len * vocab..(i + 1) * out_len * vocab],
                out_len,
                vocab,
                kept: &kept[i * out_len..(i + 1) * out_len],
            };
            let s = &chunk[i];
            let (la, na) = sl.aligned_span_lp(&s.tokens, s.span);
            let (lt, nt) = sl.truncated_span_lp(&s.tokens, s.span);
            // Greedy hit on the span's first token (cloze accuracy).
            let ga = sl.aligned_argmax(s.span.0) == Some(s.tokens[s.span.0]);
            let gt = s.span.0 >= 1 && s.span.0 < out_len && {
                let row = &logits[(i * out_len + s.span.0 - 1) * vocab
                    ..(i * out_len + s.span.0) * vocab];
                let mut best = 0usize;
                for (j, &v) in row.iter().enumerate() {
                    if v > row[best] {
                        best = j;
                    }
                }
                best as i32 == s.tokens[s.span.0]
            };
            ChoiceScore {
                lp_aligned: la,
                n_aligned: na,
                lp_truncated: lt,
                n_truncated: nt,
                greedy_hit_aligned: ga,
                greedy_hit_truncated: gt,
            }
        });
        for (i, cs) in chunk_scores.into_iter().enumerate() {
            scores[chunk_idx * b + i] = cs;
        }
    }
    Ok(scores)
}

/// Aggregate per-sequence scores into per-task accuracy / PPL.
pub fn aggregate(
    tasks: &[Task],
    seqs: &[EncodedSeq],
    scores: &[ChoiceScore],
    max_items: usize,
) -> Vec<TaskResult> {
    // Group scores per (task, item).
    let mut per_item: Vec<Vec<Vec<(usize, ChoiceScore)>>> = tasks
        .iter()
        .map(|t| vec![Vec::new(); t.items.len().min(max_items)])
        .collect();
    for (s, sc) in seqs.iter().zip(scores) {
        let (ti, ii, ci) = s.key;
        per_item[ti][ii].push((ci, *sc));
    }

    tasks
        .iter()
        .enumerate()
        .map(|(ti, task)| {
            let items = &per_item[ti];
            let mut hit_a = 0usize;
            let mut hit_t = 0usize;
            let mut nll_a = 0.0f64;
            let mut nll_t = 0.0f64;
            let mut nll_na = 0usize;
            let mut nll_nt = 0usize;
            let is_cloze = task.name == "s_lambada";

            for (ii, choices) in items.iter().enumerate() {
                let answer = task.items[ii].answer;
                if is_cloze {
                    // Single choice: PPL of the target + greedy accuracy.
                    let (_, sc) = choices[0];
                    if sc.n_aligned > 0 {
                        nll_a += -sc.lp_aligned;
                        nll_na += sc.n_aligned;
                    }
                    if sc.n_truncated > 0 {
                        nll_t += -sc.lp_truncated;
                        nll_nt += sc.n_truncated;
                    }
                    hit_a += sc.greedy_hit_aligned as usize;
                    hit_t += sc.greedy_hit_truncated as usize;
                } else {
                    // Length-normalized choice comparison.
                    let norm = |lp: f64, n: usize| if n == 0 { f64::NEG_INFINITY } else { lp / n as f64 };
                    let pick = |f: &dyn Fn(&ChoiceScore) -> f64| {
                        choices
                            .iter()
                            .max_by(|(_, a), (_, b)| f(a).partial_cmp(&f(b)).unwrap())
                            .map(|(ci, _)| *ci)
                    };
                    if pick(&|sc| norm(sc.lp_aligned, sc.n_aligned)) == Some(answer) {
                        hit_a += 1;
                    }
                    if pick(&|sc| norm(sc.lp_truncated, sc.n_truncated)) == Some(answer) {
                        hit_t += 1;
                    }
                }
            }

            let n = items.len().max(1);
            TaskResult {
                name: task.name.clone(),
                n_items: items.len(),
                acc_aligned: hit_a as f64 / n as f64,
                acc_truncated: hit_t as f64 / n as f64,
                ppl_aligned: if nll_na > 0 { (nll_a / nll_na as f64).exp() } else { 0.0 },
                ppl_truncated: if nll_nt > 0 { (nll_t / nll_nt as f64).exp() } else { 0.0 },
            }
        })
        .collect()
}

/// Full evaluation of one model variant. With a `policy` override, the
/// result's `variant` carries the policy's canonical variant string instead
/// of the manifest tag, so report rows name the algorithm actually run.
pub fn evaluate(
    rt: &Runtime,
    man: &Manifest,
    model: &ModelEntry,
    entry: &HloEntry,
    weights: &DeviceWeights,
    tok: &Tokenizer,
    tasks: &[Task],
    max_items: usize,
    policy: Option<&PolicySpec>,
) -> Result<EvalResult> {
    let t0 = std::time::Instant::now();
    let seqs = encode_tasks(tok, tasks, entry.seq_len, max_items)?;
    let scores = run_scoring(rt, man, model, entry, weights, &seqs, model.vocab_size, policy)?;
    let tasks_out = aggregate(tasks, &seqs, &scores, max_items);
    Ok(EvalResult {
        model: model.name.clone(),
        variant: match policy {
            Some(p) => p.to_variant(),
            None => entry.tag.clone(),
        },
        tasks: tasks_out,
        wall_s: t0.elapsed().as_secs_f64(),
        sequences: seqs.len(),
    })
}
