//! Logit scoring under token reduction.
//!
//! Two schemes (DESIGN.md, "Evaluation Details" in the paper):
//!
//! * `truncated` — the paper's: with m% of output positions gone, labels are
//!   truncated to the first (1-m)% and compared index-to-index against the
//!   reduced logits. Misalignment is intentional: it is exactly how the
//!   paper evaluates, and why weak reduction methods explode in PPL.
//! * `aligned` — uses the kept-index map the executables emit: the token at
//!   original position p is scored with the logits at the last surviving
//!   position strictly before p (the model's best available prediction).
//!
//! Both are reported; tables print the paper's scheme for comparability.

/// Log-softmax denominator for one row of logits.
fn log_z(row: &[f32]) -> f32 {
    let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let s: f32 = row.iter().map(|&x| (x - m).exp()).sum();
    m + s.ln()
}

pub struct SeqLogits<'a> {
    /// (out_len, vocab) row-major logits for one sequence.
    pub logits: &'a [f32],
    pub out_len: usize,
    pub vocab: usize,
    /// Original position of each surviving output row (ascending).
    pub kept: &'a [i32],
}

impl<'a> SeqLogits<'a> {
    fn row(&self, i: usize) -> &'a [f32] {
        &self.logits[i * self.vocab..(i + 1) * self.vocab]
    }

    /// Log-prob of `token` at logits row `i`.
    fn lp(&self, i: usize, token: i32) -> f32 {
        let row = self.row(i);
        row[token as usize] - log_z(row)
    }

    /// Aligned scheme: logits row predicting ORIGINAL position `pos`
    /// (i.e. the last surviving row with kept[i] < pos).
    pub fn row_predicting(&self, pos: usize) -> Option<usize> {
        // kept is ascending; binary search for the last kept < pos.
        let mut lo = 0usize;
        let mut hi = self.out_len;
        while lo < hi {
            let mid = (lo + hi) / 2;
            if (self.kept[mid] as usize) < pos {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        lo.checked_sub(1)
    }

    /// Sum of aligned log-probs of `tokens[span.0..span.1]` (original
    /// positions). Returns (sum, count_scored).
    pub fn aligned_span_lp(&self, tokens: &[i32], span: (usize, usize)) -> (f64, usize) {
        let mut sum = 0.0f64;
        let mut n = 0usize;
        for pos in span.0..span.1 {
            if let Some(row) = self.row_predicting(pos) {
                sum += self.lp(row, tokens[pos]) as f64;
                n += 1;
            }
        }
        (sum, n)
    }

    /// Paper's truncated scheme: logits row i scores the token at index i+1
    /// of the truncated label sequence (labels cut to out_len). Span is in
    /// original positions; positions beyond out_len are unscoreable.
    pub fn truncated_span_lp(&self, tokens: &[i32], span: (usize, usize)) -> (f64, usize) {
        let mut sum = 0.0f64;
        let mut n = 0usize;
        for pos in span.0..span.1 {
            if pos == 0 || pos >= self.out_len {
                continue; // row pos-1 must exist in the reduced frame
            }
            sum += self.lp(pos - 1, tokens[pos]) as f64;
            n += 1;
        }
        (sum, n)
    }

    /// Greedy prediction for original position `pos` under the aligned map.
    pub fn aligned_argmax(&self, pos: usize) -> Option<i32> {
        let row = self.row_predicting(pos)?;
        let r = self.row(row);
        let mut best = 0usize;
        for (i, &v) in r.iter().enumerate() {
            if v > r[best] {
                best = i;
            }
        }
        Some(best as i32)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    Aligned,
    Truncated,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(out_len: usize, vocab: usize, kept: Vec<i32>) -> (Vec<f32>, Vec<i32>) {
        // logits row i puts mass on token (i % vocab)
        let mut logits = vec![0.0f32; out_len * vocab];
        for i in 0..out_len {
            logits[i * vocab + (i % vocab)] = 5.0;
        }
        (logits, kept)
    }

    #[test]
    fn row_predicting_dense() {
        let (logits, kept) = mk(4, 3, vec![0, 1, 2, 3]);
        let s = SeqLogits { logits: &logits, out_len: 4, vocab: 3, kept: &kept };
        assert_eq!(s.row_predicting(0), None); // nothing precedes pos 0
        assert_eq!(s.row_predicting(1), Some(0));
        assert_eq!(s.row_predicting(4), Some(3));
    }

    #[test]
    fn row_predicting_reduced() {
        // kept original positions 0,2,5
        let (logits, kept) = mk(3, 3, vec![0, 2, 5]);
        let s = SeqLogits { logits: &logits, out_len: 3, vocab: 3, kept: &kept };
        assert_eq!(s.row_predicting(1), Some(0));
        assert_eq!(s.row_predicting(2), Some(0));
        assert_eq!(s.row_predicting(3), Some(1));
        assert_eq!(s.row_predicting(6), Some(2));
    }

    #[test]
    fn span_lp_counts() {
        let (logits, kept) = mk(4, 3, vec![0, 1, 2, 3]);
        let s = SeqLogits { logits: &logits, out_len: 4, vocab: 3, kept: &kept };
        let tokens = vec![0, 1, 2, 0, 1];
        let (_, n_a) = s.aligned_span_lp(&tokens, (1, 5));
        assert_eq!(n_a, 4);
        let (_, n_t) = s.truncated_span_lp(&tokens, (1, 5));
        assert_eq!(n_t, 3); // pos 4 >= out_len
    }

    #[test]
    fn lp_is_log_prob() {
        let (logits, kept) = mk(2, 4, vec![0, 1]);
        let s = SeqLogits { logits: &logits, out_len: 2, vocab: 4, kept: &kept };
        // sum over vocab of exp(lp) == 1
        let total: f32 = (0..4).map(|t| s.lp(0, t).exp()).sum();
        assert!((total - 1.0).abs() < 1e-5);
    }
}
