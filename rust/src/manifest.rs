//! Typed view of `artifacts/manifest.json` — the contract written by
//! `python/compile/aot.py`. Everything the runtime needs (param layout, HLO
//! module inventory, schedule plans, data file locations) flows through here;
//! the rust side never re-derives shapes from HLO text.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ParamMeta {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub bytes: usize,
}

#[derive(Debug, Clone, Default)]
pub struct Reduction {
    pub method: String,
    pub flops_reduction: f64,
    pub locations: Vec<usize>,
    pub metric: String,
    pub q_hidden: f64,
    pub q_residual: f64,
}

#[derive(Debug, Clone)]
pub struct Plan {
    pub seq_len: usize,
    pub locations: Vec<usize>,
    pub seg_lens: Vec<usize>,
    pub removed: Vec<usize>,
    pub flops_reduction: f64,
}

#[derive(Debug, Clone)]
pub struct HloEntry {
    pub tag: String,
    pub file: String,
    pub kind: String, // eval | prefill | decode | train
    pub batch: usize,
    pub seq_len: usize,
    pub out_len: usize,
    pub reduction: Option<Reduction>,
    pub plan: Option<Plan>,
    pub peak_memory_bytes: Option<u64>,
    /// Whether this program takes a per-sequence `lengths: [batch]` i32
    /// input after the tokens (prefill entries; manifest key `lengths`).
    /// Length-aware entries stop each sequence at its true end and accept a
    /// resume state for chunked prefill — see DESIGN.md §6. Absent/false
    /// for AOT exports, whose graphs have a fixed input arity.
    pub takes_lengths: bool,
}

#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub name: String,
    pub arch: String, // mamba | mamba2
    pub n_layer: usize,
    pub d_model: usize,
    pub d_state: usize,
    pub d_inner: usize,
    pub vocab_size: usize,
    pub param_count: u64,
    pub params: Vec<ParamMeta>,
    pub init_weights: String,
    /// Optional default weight storage format for this model
    /// (`"f32"` | `"int8"`, manifest key `weights_format`). Validated at
    /// parse time; an explicit `--weights` / `TOR_SSM_WEIGHTS` setting
    /// overrides it — see `runtime::weights::effective_format`.
    pub weights_format: Option<String>,
    pub hlo: BTreeMap<String, HloEntry>,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub vocab_file: String,
    pub tasks_file: String,
    pub train_file: String,
    pub val_file: String,
    pub eval_batch: usize,
    pub eval_seq_len: usize,
    pub prefill_batch: usize,
    pub prefill_seq_len: usize,
    pub decode_batch: usize,
    pub train_batch: usize,
    pub train_seq_len: usize,
    pub train_total_steps: usize,
    pub models: BTreeMap<String, ModelEntry>,
}

fn parse_reduction(j: &Json) -> Reduction {
    Reduction {
        method: j.str_of("method"),
        flops_reduction: j.f64_of("flops_reduction"),
        locations: j.usize_arr_of("locations"),
        metric: j.str_or("metric", "clip"),
        q_hidden: j.f64_of("q_hidden"),
        q_residual: j.f64_of("q_residual"),
    }
}

fn parse_plan(j: &Json) -> Plan {
    Plan {
        seq_len: j.usize_of("seq_len"),
        locations: j.usize_arr_of("locations"),
        seg_lens: j.usize_arr_of("seg_lens"),
        removed: j.usize_arr_of("removed"),
        flops_reduction: j.f64_of("flops_reduction"),
    }
}

impl Manifest {
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Manifest> {
        let root = artifacts_dir.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let data = j.expect("data");
        let eval = j.expect("eval");
        let prefill = j.expect("prefill");
        let decode = j.expect("decode");
        let train = j.expect("train");

        let mut models = BTreeMap::new();
        for (name, m) in j.expect("models").as_obj().context("models not an object")? {
            let cfg = m.expect("config");
            let expand = cfg.usize_of("expand");
            let d_model = cfg.usize_of("d_model");
            let params = m
                .expect("params")
                .as_arr()
                .context("params not an array")?
                .iter()
                .map(|p| ParamMeta {
                    name: p.str_of("name"),
                    shape: p.usize_arr_of("shape"),
                    offset: p.usize_of("offset"),
                    bytes: p.usize_of("bytes"),
                })
                .collect();

            let mut hlo = BTreeMap::new();
            for (tag, h) in m.expect("hlo").as_obj().context("hlo not an object")? {
                let kind = h.str_of("kind");
                let seq_len = h.get("seq_len").and_then(|v| v.as_usize()).unwrap_or(1);
                let entry = HloEntry {
                    tag: tag.clone(),
                    file: h.str_of("file"),
                    kind: kind.clone(),
                    batch: h.usize_of("batch"),
                    seq_len,
                    out_len: h.get("out_len").and_then(|v| v.as_usize()).unwrap_or(seq_len),
                    reduction: h.get("reduction").map(parse_reduction),
                    plan: h.get("plan").map(parse_plan),
                    peak_memory_bytes: h
                        .get("peak_memory_bytes")
                        .and_then(|v| v.as_f64())
                        .map(|v| v as u64),
                    takes_lengths: h
                        .get("lengths")
                        .and_then(|v| v.as_bool())
                        .unwrap_or(false),
                };
                hlo.insert(tag.clone(), entry);
            }

            let weights_format = m
                .get("weights_format")
                .and_then(|v| v.as_str())
                .map(str::to_string);
            if let Some(f) = &weights_format {
                crate::runtime::weights::WeightFormat::from_name(f)
                    .with_context(|| format!("model {name:?}: bad weights_format"))?;
            }

            models.insert(
                name.clone(),
                ModelEntry {
                    name: name.clone(),
                    arch: m.str_of("arch"),
                    n_layer: cfg.usize_of("n_layer"),
                    d_model,
                    d_state: cfg.usize_of("d_state"),
                    d_inner: expand * d_model,
                    vocab_size: cfg.usize_of("vocab_size"),
                    param_count: m.f64_of("param_count") as u64,
                    params,
                    init_weights: m.str_of("init_weights"),
                    weights_format,
                    hlo,
                },
            );
        }

        Ok(Manifest {
            root,
            vocab_file: data.str_of("vocab"),
            tasks_file: data.str_of("tasks"),
            train_file: data.str_of("train"),
            val_file: data.str_of("val"),
            eval_batch: eval.usize_of("batch"),
            eval_seq_len: eval.usize_of("seq_len"),
            prefill_batch: prefill.usize_of("batch"),
            prefill_seq_len: prefill.usize_of("seq_len"),
            decode_batch: decode.usize_of("batch"),
            train_batch: train.usize_of("batch"),
            train_seq_len: train.usize_of("seq_len"),
            train_total_steps: train.usize_of("total_steps"),
            models,
        })
    }

    pub fn path(&self, rel: &str) -> PathBuf {
        self.root.join(rel)
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .get(name)
            .with_context(|| format!("model {name:?} not in manifest (have: {:?})", self.models.keys().collect::<Vec<_>>()))
    }
}

impl ModelEntry {
    /// Param-layout metadata by name — the mapping the content-addressed
    /// registry uses to tie a schema-2 named blob back to its slice of the
    /// concatenated weight buffer (`runtime/registry.rs`, DESIGN.md §15).
    pub fn param(&self, name: &str) -> Option<&ParamMeta> {
        self.params.iter().find(|p| p.name == name)
    }

    /// Find the eval HLO variant matching a (method, ratio, metric, q, locations)
    /// query; `None` fields are wildcards matched against the export defaults.
    pub fn find_eval(
        &self,
        method: &str,
        flops_reduction: f64,
        metric: Option<&str>,
        q_hidden: Option<f64>,
        q_residual: Option<f64>,
        locations: Option<&[usize]>,
    ) -> Result<&HloEntry> {
        let close_f = |a: f64, b: f64| (a - b).abs() < 1e-6;
        for e in self.hlo.values() {
            if e.kind != "eval" {
                continue;
            }
            let Some(r) = &e.reduction else { continue };
            if r.method != method {
                continue;
            }
            if method == "dense" {
                return Ok(e);
            }
            if !close_f(r.flops_reduction, flops_reduction) {
                continue;
            }
            if metric.map_or(r.metric == "clip", |m| r.metric == m)
                && q_hidden.map_or(close_f(r.q_hidden, 0.5), |q| close_f(r.q_hidden, q))
                && q_residual.map_or(close_f(r.q_residual, 0.0), |q| close_f(r.q_residual, q))
                && locations.map_or(true, |l| r.locations == l)
            {
                // Default-location check when locations not specified: prefer
                // entries whose tag has no custom suffix — handled by matching
                // against *every* candidate; ambiguity resolved by exactness.
                if locations.is_none() {
                    // Accept only the default-schedule export: the ablation
                    // schedules all specify locations explicitly.
                    if let Some(dflt) = self.default_locations() {
                        if r.locations != dflt {
                            continue;
                        }
                    }
                }
                return Ok(e);
            }
        }
        bail!(
            "no eval HLO for model={} method={} ratio={} metric={:?} qh={:?} qr={:?} loc={:?}",
            self.name, method, flops_reduction, metric, q_hidden, q_residual, locations
        )
    }

    /// The default schedule = locations of the dense-adjacent standard export
    /// (most frequent across utrc exports).
    pub fn default_locations(&self) -> Option<Vec<usize>> {
        let mut counts: BTreeMap<Vec<usize>, usize> = BTreeMap::new();
        for e in self.hlo.values() {
            if let Some(r) = &e.reduction {
                if r.method == "utrc" && r.metric == "clip" {
                    *counts.entry(r.locations.clone()).or_default() += 1;
                }
            }
        }
        counts.into_iter().max_by_key(|(_, c)| *c).map(|(l, _)| l)
    }

    pub fn decode_entry(&self) -> Result<&HloEntry> {
        self.hlo.get("decode_step").context("no decode_step HLO")
    }

    pub fn train_entry(&self) -> Result<&HloEntry> {
        self.hlo.get("train_step").context("no train_step HLO")
    }

    pub fn prefill_entry(&self, method: &str, flops_reduction: f64) -> Result<&HloEntry> {
        for e in self.hlo.values() {
            if e.kind != "prefill" {
                continue;
            }
            let Some(r) = &e.reduction else { continue };
            if r.method == method
                && (method == "dense" || (r.flops_reduction - flops_reduction).abs() < 1e-6)
            {
                return Ok(e);
            }
        }
        bail!("no prefill HLO for {} method={method} ratio={flops_reduction}", self.name)
    }

    /// Any prefill export whose schedule plan hits `ratio`, regardless of
    /// the reduction method it was lowered with. Used by run-time policy
    /// dispatch on the reference backend (DESIGN.md §10), where the entry
    /// only supplies the plan geometry and the policy supplies the
    /// algorithm. Deterministic: first matching tag in BTreeMap order.
    pub fn prefill_entry_for_plan(&self, flops_reduction: f64) -> Result<&HloEntry> {
        self.entry_for_plan("prefill", flops_reduction)
    }

    /// [`ModelEntry::prefill_entry_for_plan`], for eval exports.
    pub fn eval_entry_for_plan(&self, flops_reduction: f64) -> Result<&HloEntry> {
        self.entry_for_plan("eval", flops_reduction)
    }

    /// Eval lookup for run-time policy dispatch, mirroring how
    /// `Engine::new` resolves prefill entries: prefer an export lowered
    /// with `method` at `ratio` (so AOT backends bind the graph that
    /// actually bakes the algorithm in), else fall back to any export whose
    /// plan hits the ratio (the reference backend only needs the geometry).
    pub fn eval_entry_for_policy(&self, method: &str, flops_reduction: f64) -> Result<&HloEntry> {
        self.find_eval(method, flops_reduction, None, None, None, None)
            .or_else(|_| self.eval_entry_for_plan(flops_reduction))
    }

    fn entry_for_plan(&self, kind: &str, flops_reduction: f64) -> Result<&HloEntry> {
        for e in self.hlo.values() {
            if e.kind != kind || e.plan.is_none() {
                continue;
            }
            let Some(r) = &e.reduction else { continue };
            if (r.flops_reduction - flops_reduction).abs() < 1e-6 {
                return Ok(e);
            }
        }
        bail!(
            "no {kind} HLO with a schedule plan at ratio {flops_reduction} for {} \
             (exported plan ratios decide which policy ratios can run)",
            self.name
        )
    }
}
