//! `repro` — leader binary: train, evaluate, serve, and regenerate every
//! table/figure of the paper.
//!
//! ```text
//! repro info                                # artifact + model inventory
//! repro demo                                # hermetic serve+eval on a synthetic fixture
//! repro train --model mamba-small --steps 400 --backend pjrt
//! repro train-all --steps 400               # all four models
//! repro eval  --model mamba2-base --method utrc --ratio 0.2
//! repro table 1|2|3|4|5|6 [--items 60] [--fresh]
//! repro table all
//! repro figure 1|3|4|5|6
//! repro golden --backend pjrt               # rust-vs-python numerics check
//! repro serve --requests 16 --policy cost-aware
//! repro serve --listen 127.0.0.1:8080       # HTTP/1.1 front-end (DESIGN.md §14)
//! ```
//!
//! `--backend reference|pjrt` selects the execution backend (default:
//! reference — pure Rust, hermetic). The pjrt backend additionally needs
//! the `pjrt` cargo feature and real `make artifacts` exports.

use anyhow::{bail, Context, Result};

use tor_ssm::bench::{figures, tables, Ctx};
use tor_ssm::coordinator::engine::Engine;
use tor_ssm::coordinator::prefix_cache::PrefixCache;
use tor_ssm::coordinator::replica::{Placement, ReplicaPool};
use tor_ssm::coordinator::router::{Policy, Router};
use tor_ssm::coordinator::scheduler::Scheduler;
use tor_ssm::coordinator::metrics::Metrics;
use tor_ssm::eval::scoring::Scheme;
use tor_ssm::manifest::Manifest;
use tor_ssm::reduction::policy::PolicySpec;
use tor_ssm::runtime::Runtime;
use tor_ssm::train::load_best_weights;
use tor_ssm::util::cli::Args;
use tor_ssm::util::rng::Rng;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env(&["fresh", "aligned", "quiet"]);
    let artifacts = args.get_or("artifacts", &tor_ssm::artifacts_dir());
    // Execution knobs for the reference backend's hot path (DESIGN.md
    // §11/§13, PERFORMANCE.md). `--threads` and `--kernels scalar|fused`
    // are bit-identity-preserving; `--kernels simd` reassociates only the
    // f32 logit head (documented error bound), and `--weights int8`
    // trades logits accuracy for speed (bit-identical across tiers).
    if let Some(t) = args.get("threads") {
        let n: usize = t.parse().with_context(|| format!("--threads {t:?} is not a count"))?;
        tor_ssm::runtime::pool::set_workers(n);
    }
    if let Some(k) = args.get("kernels") {
        tor_ssm::runtime::kernels::set_mode(tor_ssm::runtime::kernels::KernelMode::from_name(k)?);
    }
    if let Some(f) = args.get("weights") {
        tor_ssm::runtime::weights::set_format(tor_ssm::runtime::weights::WeightFormat::from_name(
            f,
        )?);
    }
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");

    match cmd {
        "info" => info(&artifacts),
        "demo" => demo(&args),
        "train" => train(&args, &artifacts),
        "train-all" => train_all(&args, &artifacts),
        "eval" => eval_one(&args, &artifacts),
        "table" => table(&args, &artifacts),
        "figure" => figure(&args, &artifacts),
        "golden" => golden(&args, &artifacts),
        "serve" => serve(&args, &artifacts),
        _ => {
            println!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "repro — Rethinking Token Reduction for SSMs (EMNLP 2024) reproduction
commands:
  info                         artifact inventory
  demo                         hermetic serve+eval on a synthetic fixture (no artifacts)
  train --model M --steps N    train one model via the AOT train step (pjrt backend)
  train-all --steps N          train all four models
  eval --model M --method X --ratio R [--metric m] [--items N]
       methods: dense|utrc|evit|pumer|ltmp (AOT exports) or a reduction
       policy prune|merge|unified|random dispatched at run time; or pass the
       variant grammar directly: --variant <policy>@<ratio>[:<metric>]
  table 1..6|all [--items N] [--fresh]
  figure 1|3|4|5|6 [--gen-tokens N]
  golden                       rust-vs-python numerics cross-check (pjrt backend)
  serve --requests N [--policy explicit|least-loaded|cost-aware]
        [--lanes dense,unified@0.2,prune@0.2,merge@0.2,random@0.2]
        [--replicas N] engine replicas per lane behind a ReplicaPool
        (DESIGN.md §15); [--placement least-loaded|hash] places requests
        across a lane's replicas (hash = prefix-affine rendezvous, keeps
        per-replica prefix caches hot) — placement never changes tokens
        [--listen ADDR]              serve HTTP/1.1 on ADDR instead of the
        synthetic trace: POST /v1/generate (JSON; set \"stream\":true for
        SSE-over-chunked token streaming), GET /healthz, GET /stats;
        [--queue-cap N] bounds admission (429 beyond it); SIGINT/SIGTERM
        drains gracefully (DESIGN.md §14)
common: --artifacts DIR (default ./artifacts, or $REPRO_ARTIFACTS)
        --backend reference|pjrt (default reference; pjrt needs the cargo feature)
        --threads N (decode worker threads; default: all cores, env TOR_SSM_THREADS)
        --kernels scalar|fused|simd (reference-backend kernels; default fused,
        env TOR_SSM_KERNELS — scalar|fused change speed, never outputs;
        simd additionally vectorizes the f32 logit head under a documented
        error bound, so sampled tokens may differ)
        --weights f32|int8 (weight storage; default f32, env TOR_SSM_WEIGHTS —
        int8 quantizes the projection/embedding matrices per channel at load
        time; outputs shift by quantization error but are identical across
        kernel tiers and thread counts)";

fn backend_of(args: &Args) -> String {
    args.get_or("backend", "reference")
}

/// Manifest for `artifacts`. An explicitly passed --artifacts must load (a
/// typo'd path should be an error, not a silent fall-back); only the
/// default location falls back to the shared synthetic fixture (generated
/// on demand), keeping `eval` and `serve` drivable with zero artifacts,
/// exactly like `demo` and the benches.
fn manifest_or_default_fixture(args: &Args, artifacts: &str) -> Result<Manifest> {
    if args.get("artifacts").is_some() {
        return Manifest::load(artifacts);
    }
    let (man, synthetic) = tor_ssm::fixtures::manifest_or_fixture(artifacts)?;
    if synthetic {
        eprintln!("[info] no artifacts at {artifacts:?}: using the synthetic fixture {:?}", man.root);
    }
    Ok(man)
}

fn info(artifacts: &str) -> Result<()> {
    let man = Manifest::load(artifacts)?;
    println!("artifacts: {:?}", man.root);
    println!(
        "eval frame: B={} L={}; prefill: B={} L={}; decode B={}; train: B={} L={}",
        man.eval_batch, man.eval_seq_len, man.prefill_batch, man.prefill_seq_len,
        man.decode_batch, man.train_batch, man.train_seq_len
    );
    for (name, m) in &man.models {
        let ckpt = tor_ssm::train::checkpoint_path(&man, name);
        println!(
            "  {name:<13} arch={:<6} layers={:>2} d_model={:>3} params={:>9} hlo_variants={:>2} trained={}",
            m.arch,
            m.n_layer,
            m.d_model,
            m.param_count,
            m.hlo.len(),
            ckpt.exists()
        );
    }
    Ok(())
}

/// Hermetic end-to-end demo: generate a synthetic fixture, run the
/// coordinator (router → continuous scheduler prefill/decode) and the
/// zero-shot eval harness on the reference backend. No artifacts, no
/// Python, no XLA.
fn demo(args: &Args) -> Result<()> {
    let dir = match args.get("dir") {
        Some(d) => std::path::PathBuf::from(d),
        None => tor_ssm::fixtures::default_fixture_dir(),
    };
    let man = tor_ssm::fixtures::generate_default(&dir)?;
    println!("synthetic fixture: {:?} ({} models)", man.root, man.models.len());

    let rt = Runtime::reference()?;
    println!("exec: {}", tor_ssm::runtime::kernels::exec_summary());
    let model = args.get_or("model", "ref-mamba");
    let me = man.model(&model)?.clone();
    let (w, _) = load_best_weights(&man, &me)?;

    // ---- serve a small trace across the policy family's lanes ----
    let lanes = ["dense", "unified@0.2", "prune@0.2", "merge@0.2"];
    let mut engines: Vec<Engine> = lanes
        .iter()
        .map(|v| Engine::new(&rt, &man, &me, &w, v))
        .collect::<Result<_>>()?;
    // Content-addressed prefix cache (DESIGN.md §12): requests sharing a
    // chunk-aligned prompt prefix resume from a cached state snapshot
    // instead of re-running prefill over the shared tokens.
    for e in &mut engines {
        e.attach_prefix_cache(std::sync::Arc::new(PrefixCache::new(8 << 20)));
    }
    let mut router = Router::new(Policy::CostAware { long_prompt: man.prefill_seq_len / 2 }, &lanes);
    let mut schedulers: Vec<Scheduler> = engines.iter().map(Scheduler::new).collect();
    let mut metrics = Metrics::default();
    let n_requests = args.usize_or("requests", 6);
    let gen_tokens = args.usize_or("gen-tokens", 4);
    // Length-aware lanes serve multi-frame prompts via chunked prefill;
    // otherwise the trace caps at the frame (no silent truncation).
    let max_prompt = tor_ssm::fixtures::trace_max_prompt(&engines);
    serve_trace(
        &lanes,
        &mut router,
        &mut schedulers,
        &mut metrics,
        n_requests,
        gen_tokens,
        man.prefill_seq_len,
        max_prompt,
        me.vocab_size,
    )?;
    println!("serve: {}", metrics.summary());
    for ((lane, s), e) in lanes.iter().zip(&schedulers).zip(&engines) {
        let cs = e.prefix_cache().map(|c| c.stats()).unwrap_or_default();
        println!(
            "  {lane:<9} prefills={} decode_steps={} peak_state={} slots ({} B) \
             preempts={} cache_hits={} misses={} hit_rate={:.2}",
            s.prefill_calls,
            s.decode_steps,
            s.store().high_water(),
            s.store().peak_bytes(),
            s.preemptions,
            cs.hits,
            cs.misses,
            cs.hit_rate()
        );
    }

    // ---- zero-shot eval: dense vs the full policy family at one ratio ----
    let items = args.usize_or("items", 2);
    let mut ctx = Ctx::new(&dir.to_string_lossy(), items, true)?;
    for variant in ["dense", "unified@0.2", "prune@0.2", "merge@0.2", "random@0.2"] {
        let r = match PolicySpec::parse(variant)? {
            None => {
                let e = ctx.find_eval_entry(&model, "dense", 0.0, None, None, None, None)?;
                ctx.eval_variant(&model, &e)?
            }
            Some(spec) => {
                let e = ctx
                    .man
                    .model(&model)?
                    .eval_entry_for_policy(spec.kind.manifest_method(), spec.ratio)?
                    .clone();
                ctx.eval_policy_variant(&model, &e, Some(&spec))?
            }
        };
        println!(
            "eval {variant:<12} avg_acc={:.3} ppl={:.2} ({} seqs)",
            r.avg_acc(Scheme::Truncated),
            r.lambada_ppl(Scheme::Truncated),
            r.sequences
        );
    }
    println!("demo OK: coordinator + eval harness ran hermetically on the reference backend");
    Ok(())
}

fn train(args: &Args, artifacts: &str) -> Result<()> {
    let man = Manifest::load(artifacts)?;
    let model = args.get("model").context("--model required")?;
    let steps = args.usize_or("steps", man.train_total_steps);
    let rt = Runtime::from_name(&backend_of(args))?;
    let me = man.model(model)?.clone();
    let report = tor_ssm::train::train(&rt, &man, &me, steps, 42, 20)?;
    println!(
        "trained {model}: {} steps, loss {:.4} -> {:.4}, {:.1}s, checkpoint {:?}",
        report.steps,
        report.losses.first().unwrap_or(&f32::NAN),
        report.losses.last().unwrap_or(&f32::NAN),
        report.wall_s,
        report.checkpoint
    );
    Ok(())
}

fn train_all(args: &Args, artifacts: &str) -> Result<()> {
    let man = Manifest::load(artifacts)?;
    let steps = args.usize_or("steps", man.train_total_steps);
    let rt = Runtime::from_name(&backend_of(args))?;
    for name in man.models.keys().cloned().collect::<Vec<_>>() {
        let me = man.model(&name)?.clone();
        let ckpt = tor_ssm::train::checkpoint_path(&man, &name);
        if ckpt.exists() && !args.flag("fresh") {
            println!("skip {name}: checkpoint exists");
            continue;
        }
        let report = tor_ssm::train::train(&rt, &man, &me, steps, 42, 20)?;
        println!(
            "trained {name}: loss {:.4} -> {:.4} in {:.1}s",
            report.losses.first().unwrap_or(&f32::NAN),
            report.losses.last().unwrap_or(&f32::NAN),
            report.wall_s
        );
    }
    Ok(())
}

fn eval_one(args: &Args, artifacts: &str) -> Result<()> {
    let model = args.get("model").context("--model required")?.to_string();
    let method = args.get_or("method", "dense");
    let ratio = args.f64_or("ratio", 0.0);
    let items = args.usize_or("items", 16);
    let man = manifest_or_default_fixture(args, artifacts)?;
    let dir = man.root.to_string_lossy().to_string();
    let mut ctx = Ctx::with_backend(&dir, items, args.flag("fresh"), &backend_of(args))?;
    // Two roads to a result (DESIGN.md §10): AOT-exported methods go through
    // the manifest's (method, ratio, metric) index; reduction-policy
    // variants (`--variant prune@0.2:l1`, or `--method prune --ratio 0.2
    // [--metric l1]`) resolve a plan-matched entry and dispatch the policy
    // at run time on the reference backend.
    let variant_arg = args.get("variant").map(|v| v.to_string()).or_else(|| {
        matches!(method.as_str(), "prune" | "merge" | "unified" | "random").then(|| {
            match args.get("metric") {
                Some(m) => format!("{method}@{ratio}:{m}"),
                None => format!("{method}@{ratio}"),
            }
        })
    });
    let r = match variant_arg.as_deref().map(PolicySpec::parse).transpose()?.flatten() {
        Some(spec) => {
            let entry = ctx
                .man
                .model(&model)?
                .eval_entry_for_policy(spec.kind.manifest_method(), spec.ratio)?
                .clone();
            ctx.eval_policy_variant(&model, &entry, Some(&spec))?
        }
        None => {
            let entry =
                ctx.find_eval_entry(&model, &method, ratio, args.get("metric"), None, None, None)?;
            ctx.eval_variant(&model, &entry)?
        }
    };
    let scheme = if args.flag("aligned") { Scheme::Aligned } else { Scheme::Truncated };
    println!("model={model} variant={}", r.variant);
    for t in &r.tasks {
        println!(
            "  {:<16} acc(trunc)={:.3} acc(aligned)={:.3} ppl(trunc)={:.2} ppl(aligned)={:.2}",
            t.name, t.acc_truncated, t.acc_aligned, t.ppl_truncated, t.ppl_aligned
        );
    }
    println!("  avg acc = {:.3} ({:?})", r.avg_acc(scheme), scheme);
    Ok(())
}

fn table(args: &Args, artifacts: &str) -> Result<()> {
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let items = args.usize_or("items", 16);
    let mut ctx = Ctx::with_backend(artifacts, items, args.flag("fresh"), &backend_of(args))?;
    let run = |ctx: &mut Ctx, n: &str| -> Result<()> {
        match n {
            "1" => tables::table1(ctx),
            "2" => tables::table2(ctx),
            "3" => tables::table3(ctx),
            "4" => tables::table4(ctx),
            "5" => tables::table5(ctx),
            "6" => tables::table6(ctx),
            _ => bail!("unknown table {n}"),
        }
    };
    if which == "all" {
        // Core results first, ablations after (partial runs stay useful; the
        // per-variant result cache makes re-runs incremental).
        for n in ["1", "2", "6", "3", "5", "4"] {
            run(&mut ctx, n)?;
        }
        Ok(())
    } else {
        run(&mut ctx, which)
    }
}

fn figure(args: &Args, artifacts: &str) -> Result<()> {
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let items = args.usize_or("items", 16);
    let gen_tokens = args.usize_or("gen-tokens", 100);
    let mut ctx = Ctx::with_backend(artifacts, items, args.flag("fresh"), &backend_of(args))?;
    let run = |ctx: &mut Ctx, n: &str| -> Result<()> {
        match n {
            "1" => figures::figure1(ctx),
            "3" => figures::figure_memory(ctx, false),
            "5" => figures::figure_memory(ctx, true),
            "4" => figures::figure_throughput(ctx, false, gen_tokens),
            "6" => figures::figure_throughput(ctx, true, gen_tokens),
            _ => bail!("unknown figure {n}"),
        }
    };
    if which == "all" {
        for n in ["1", "3", "5", "4", "6"] {
            run(&mut ctx, n)?;
        }
        Ok(())
    } else {
        run(&mut ctx, which)
    }
}

fn golden(args: &Args, artifacts: &str) -> Result<()> {
    let man = Manifest::load(artifacts)?;
    let rt = Runtime::from_name(&backend_of(args))?;
    let report = tor_ssm::bench::harness::golden_check(&rt, &man)?;
    println!("{report}");
    Ok(())
}

fn serve(args: &Args, artifacts: &str) -> Result<()> {
    let man = manifest_or_default_fixture(args, artifacts)?;
    let rt = Runtime::from_name(&backend_of(args))?;
    let default_model = man.models.keys().next().context("manifest has no models")?.clone();
    let model = args.get_or("model", &default_model);
    let n_requests = args.usize_or("requests", 16);
    let gen_tokens = args.usize_or("gen-tokens", 16);
    let policy = match args.get_or("policy", "cost-aware").as_str() {
        "explicit" => Policy::Explicit,
        "least-loaded" => Policy::LeastLoaded,
        _ => Policy::CostAware { long_prompt: man.prefill_seq_len / 2 },
    };

    let me = man.model(&model)?.clone();
    let (w, trained) = load_best_weights(&man, &me)?;
    if !trained {
        eprintln!("[warn] serving INIT weights (no checkpoint)");
    }
    // Any mix of policy variants serves side by side; each lane is validated
    // by parse_variant inside Engine::new before a single request queues.
    let lanes_arg = args.get_or("lanes", "dense,utrc@0.2");
    let lanes_owned: Vec<String> =
        lanes_arg.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect();
    if lanes_owned.is_empty() {
        bail!("--lanes must name at least one variant (e.g. dense,prune@0.2,merge@0.2)");
    }
    let lanes: Vec<&str> = lanes_owned.iter().map(|s| s.as_str()).collect();
    // Replica pool topology (DESIGN.md §15): N engines per lane behind a
    // ReplicaPool; placement spreads requests across a lane's replicas
    // without ever changing the tokens they generate.
    let replicas = args.usize_or("replicas", 1);
    if replicas == 0 {
        bail!("--replicas must be >= 1");
    }
    let placement = Placement::from_name(&args.get_or("placement", "least-loaded"))?;
    if backend_of(args) == "reference" {
        println!("exec: {}", tor_ssm::runtime::kernels::exec_summary());
    }
    println!("building engines for {lanes:?} (x{replicas} replicas)...");
    // Lane-major: all of lane 0's replicas first — the layout
    // http::serve_pooled and ReplicaPool::new expect.
    let mut engines: Vec<Engine> = Vec::with_capacity(lanes.len() * replicas);
    for v in &lanes {
        for _ in 0..replicas {
            engines.push(Engine::new(&rt, &man, &me, &w, v)?);
        }
    }
    // Shared-prefix requests resume from chunk-boundary state snapshots
    // (DESIGN.md §12); the cache is per-replica because snapshots encode
    // the engine's resident weights (and keys partition by model/variant
    // anyway) — `--placement hash` keeps each one hot by prefix affinity.
    for e in &mut engines {
        e.attach_prefix_cache(std::sync::Arc::new(PrefixCache::new(8 << 20)));
    }
    if let Some(listen) = args.get("listen") {
        let pool = tor_ssm::coordinator::http::PoolConfig { replicas, placement };
        return serve_http(listen, &engines, &lanes_owned, policy, pool, args);
    }
    let mut router = Router::new(policy, &lanes);
    let mut pools: Vec<ReplicaPool> = engines
        .chunks(replicas)
        .map(|chunk| ReplicaPool::new(chunk, placement))
        .collect::<Result<_>>()?;
    let mut metrics = Metrics::default();
    let max_prompt = tor_ssm::fixtures::trace_max_prompt(&engines);
    let failed = serve_trace_pooled(
        &lanes,
        &mut router,
        &mut pools,
        &mut metrics,
        n_requests,
        gen_tokens,
        man.prefill_seq_len,
        max_prompt,
        me.vocab_size,
    )?;
    println!(
        "routing: {} requests over {:?} (replicas={replicas} placement={})",
        router.routed,
        lanes,
        placement.name()
    );
    println!("{}", metrics.summary());
    for (li, lane) in lanes.iter().enumerate() {
        let mut cache = tor_ssm::coordinator::prefix_cache::CacheStats::default();
        for e in &engines[li * replicas..(li + 1) * replicas] {
            if let Some(c) = e.prefix_cache() {
                let one = c.stats();
                cache.hits += one.hits;
                cache.misses += one.misses;
            }
        }
        for (ri, rs) in pools[li].replica_stats().iter().enumerate() {
            println!(
                "  {lane:<10} r{ri} [{}] prefills={} decode_steps={} preempts={} \
                 completed={} failed={} tag={}",
                rs.health.name(),
                rs.prefills,
                rs.decode_steps,
                rs.preemptions,
                rs.completed,
                rs.failed,
                rs.weights_tag
            );
        }
        println!(
            "  {lane:<10} reroutes={} cache_hits={} misses={} hit_rate={:.2}",
            pools[li].reroutes,
            cache.hits,
            cache.misses,
            cache.hit_rate()
        );
    }
    if failed > 0 {
        bail!("{failed} trace requests failed (no healthy replica)");
    }
    Ok(())
}

/// Process-wide drain flag, set by SIGINT/SIGTERM and polled by the HTTP
/// scheduler loop (DESIGN.md §14 drain state machine).
static SHUTDOWN: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

extern "C" fn on_shutdown_signal(_sig: i32) {
    // Async-signal-safe: a single atomic store, nothing else.
    // ORDERING: SeqCst — strongest order for the cheapest reasoning at a
    // signal boundary; this fires once, so the cost is irrelevant.
    SHUTDOWN.store(true, std::sync::atomic::Ordering::SeqCst);
}

/// Route SIGINT/SIGTERM to the drain flag. `std` already links libc, so a
/// direct `signal(2)` declaration keeps the zero-dependency rule intact.
#[cfg(unix)]
fn install_drain_signals() {
    extern "C" {
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    // SAFETY: signal(2) is declared with its true C ABI; the handler is an
    // extern "C" fn that only performs one async-signal-safe atomic store,
    // and installing a handler has no memory-safety preconditions.
    unsafe {
        signal(SIGINT, on_shutdown_signal);
        signal(SIGTERM, on_shutdown_signal);
    }
}

#[cfg(not(unix))]
fn install_drain_signals() {}

/// `repro serve --listen ADDR`: put the lanes behind a real socket via the
/// zero-dependency HTTP/1.1 front-end, then report the drained run.
fn serve_http(
    listen: &str,
    engines: &[Engine],
    lanes: &[String],
    policy: Policy,
    pool: tor_ssm::coordinator::http::PoolConfig,
    args: &Args,
) -> Result<()> {
    use tor_ssm::coordinator::http::{self, HttpConfig};
    let listener = std::net::TcpListener::bind(listen)
        .with_context(|| format!("cannot listen on {listen:?}"))?;
    let addr = listener.local_addr()?;
    let defaults = HttpConfig::default();
    let cfg = HttpConfig {
        queue_cap: args.usize_or("queue-cap", defaults.queue_cap),
        max_gen_tokens: args.usize_or("max-gen-tokens", defaults.max_gen_tokens),
        default_gen_tokens: args.usize_or("gen-tokens", defaults.default_gen_tokens),
        ..defaults
    };
    install_drain_signals();
    println!(
        "listening on http://{addr} lanes={lanes:?} queue_cap={} replicas={} placement={}",
        cfg.queue_cap,
        pool.replicas,
        pool.placement.name()
    );
    println!("POST /v1/generate | GET /healthz | GET /stats — SIGINT/SIGTERM drains");
    let report = http::serve_pooled(engines, lanes, policy, pool, listener, cfg, &SHUTDOWN)?;
    println!("drained: {}", report.metrics.summary());
    println!("rejected: {} over-capacity (429), {} during drain (503)",
        report.rejected_429, report.rejected_503);
    Ok(())
}

/// The `repro serve` trace loop over replica pools: same length-diverse
/// synthetic workload as [`serve_trace`], driven through one
/// [`ReplicaPool`] per lane (DESIGN.md §15). Returns the number of
/// requests the pools failed (zero on healthy engines — the trace has no
/// fault injection).
#[allow(clippy::too_many_arguments)]
fn serve_trace_pooled(
    lanes: &[&str],
    router: &mut Router,
    pools: &mut [ReplicaPool<'_>],
    metrics: &mut Metrics,
    n_requests: usize,
    max_gen: usize,
    prefill_seq_len: usize,
    max_prompt_len: usize,
    vocab_size: usize,
) -> Result<u64> {
    let mut rng = Rng::new(7);
    let t0 = std::time::Instant::now();
    let trace = tor_ssm::fixtures::synth_requests(
        &mut rng,
        n_requests,
        max_gen,
        prefill_seq_len,
        max_prompt_len,
        vocab_size,
        lanes,
    );
    let mut failed = 0u64;
    for req in trace {
        let lane = router.route(&req)?;
        let li = lanes.iter().position(|l| *l == lane).unwrap();
        router.note_enqueued(&lane);
        pools[li].submit(req)?;
        metrics.requests += 1;
        for (pi, p) in pools.iter_mut().enumerate() {
            for resp in p.step() {
                metrics.record_response(&resp);
                router.note_done(lanes[pi]);
            }
            failed += p.take_failures().len() as u64;
        }
    }
    for (pi, p) in pools.iter_mut().enumerate() {
        for resp in p.drain() {
            metrics.record_response(&resp);
            router.note_done(lanes[pi]);
        }
        failed += p.take_failures().len() as u64;
    }
    metrics.wall = t0.elapsed();
    Ok(failed)
}

/// The shared open-loop serving trace (used by `serve` and `demo`): feed a
/// synthetic length-diverse workload (short, mid, full-frame, and — on
/// length-aware lanes — longer-than-frame chunked-prefill prompts; uniform
/// 1..=max_gen generation lengths) through router → continuous schedulers,
/// stepping every scheduler once per arrival and draining at the end.
#[allow(clippy::too_many_arguments)]
fn serve_trace(
    lanes: &[&str],
    router: &mut Router,
    schedulers: &mut [Scheduler<'_>],
    metrics: &mut Metrics,
    n_requests: usize,
    max_gen: usize,
    prefill_seq_len: usize,
    max_prompt_len: usize,
    vocab_size: usize,
) -> Result<()> {
    let mut rng = Rng::new(7);
    let t0 = std::time::Instant::now();
    let trace = tor_ssm::fixtures::synth_requests(
        &mut rng,
        n_requests,
        max_gen,
        prefill_seq_len,
        max_prompt_len,
        vocab_size,
        lanes, // every third request pins a lane variant explicitly
    );
    for req in trace {
        let lane = router.route(&req)?;
        let li = lanes.iter().position(|l| *l == lane).unwrap();
        router.note_enqueued(&lane);
        schedulers[li].submit(req);
        metrics.requests += 1;

        // Iteration-level progress: one scheduler step per arrival keeps
        // decode interleaved with admission (requests retire and free their
        // lane while later arrivals are still queueing).
        for (si, s) in schedulers.iter_mut().enumerate() {
            for resp in s.step()? {
                metrics.record_response(&resp);
                router.note_done(lanes[si]);
            }
        }
    }
    // Drain everything still in flight.
    for (si, s) in schedulers.iter_mut().enumerate() {
        for resp in s.drain()? {
            metrics.record_response(&resp);
            router.note_done(lanes[si]);
        }
    }
    metrics.wall = t0.elapsed();
    Ok(())
}
