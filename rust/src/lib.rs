//! # tor-ssm — Rethinking Token Reduction for State Space Models
//!
//! Rust + JAX + Pallas reproduction of Zhan et al., EMNLP 2024
//! (DOI 10.18653/V1/2024.EMNLP-MAIN.100).
//!
//! Three-layer architecture (see DESIGN.md):
//! * **L1** Pallas kernels (selective scan, SSD, importance, matching) —
//!   `python/compile/kernels/`, build-time only.
//! * **L2** JAX Mamba/Mamba-2 models with the UTRC token-reduction graph
//!   transform — `python/compile/`, AOT-lowered to HLO text.
//! * **L3** this crate: PJRT runtime, serving coordinator (router/batcher/
//!   state pool), zero-shot eval harness, trainer, and the bench harness
//!   that regenerates every table and figure in the paper.
//!
//! Python never runs at request time: `make artifacts` produces
//! `artifacts/*.hlo.txt` + data once, and the `repro` binary is then
//! self-contained.

pub mod bench;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod manifest;
pub mod reduction;
pub mod runtime;
pub mod tokenizer;
pub mod train;
pub mod util;

/// Default artifacts directory (overridable with --artifacts or
/// REPRO_ARTIFACTS).
pub fn artifacts_dir() -> String {
    std::env::var("REPRO_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
}
