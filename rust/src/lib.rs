//! # tor-ssm — Rethinking Token Reduction for State Space Models
//!
//! Rust + JAX + Pallas reproduction of Zhan et al., EMNLP 2024
//! (DOI 10.18653/V1/2024.EMNLP-MAIN.100).
//!
//! Three-layer architecture (see DESIGN.md):
//! * **L1** Pallas kernels (selective scan, SSD, importance, matching) —
//!   `python/compile/kernels/`, build-time only.
//! * **L2** JAX Mamba/Mamba-2 models with the UTRC token-reduction graph
//!   transform — `python/compile/`, AOT-lowered to HLO text.
//! * **L3** this crate: the pluggable execution layer ([`runtime`]), the
//!   serving coordinator (router/batcher/state pool), zero-shot eval
//!   harness, trainer, and the bench harness that regenerates every table
//!   and figure in the paper.
//!
//! ## Backends
//!
//! Execution is abstracted behind [`runtime::Backend`] (compile a program
//! spec → [`runtime::Executable`]; own weight residency):
//!
//! * `reference` *(default)* — a pure-Rust interpreter of the op set our
//!   models need ([`runtime::reference`]). Fully hermetic: the whole test
//!   suite, `repro demo`, and the bench harness run with **no `artifacts/`
//!   directory, no Python, and no XLA**, against deterministic synthetic
//!   fixtures from [`fixtures`].
//! * `pjrt` *(cargo feature `pjrt`)* — the production AOT path
//!   (`runtime::pjrt`; the module only exists with the feature on, so no
//!   intra-doc link here): Python lowers models to HLO text once
//!   (`make artifacts`), the PJRT client compiles and executes them.
//!   Python never runs at request time; the `repro` binary is then
//!   self-contained.
//!
//! Select at the CLI with `--backend reference|pjrt`. See README §Backends
//! for the full testing story.

// Lint policy: numeric-kernel style. The interpreter and scoring code index
// heavily into flat buffers where explicit `for i in 0..n` loops mirror the
// math; keep clippy strict everywhere else.
#![allow(clippy::too_many_arguments, clippy::needless_range_loop, clippy::manual_range_contains)]
#![allow(clippy::inherent_to_string)] // util::json::Json::to_string predates the refactor

pub mod bench;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod fixtures;
pub mod manifest;
pub mod reduction;
pub mod runtime;
pub mod tokenizer;
pub mod train;
pub mod util;

/// Default artifacts directory (overridable with --artifacts or
/// REPRO_ARTIFACTS).
pub fn artifacts_dir() -> String {
    std::env::var("REPRO_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
}
