//! Type-compatible **stub** of the XLA/PJRT extension bindings.
//!
//! The real `xla` crate links the PJRT C-API extension and is not present in
//! this offline image. This stub mirrors the API surface that
//! `tor_ssm::runtime::pjrt` uses — enough for `cargo build --features pjrt`
//! and `cargo clippy` to type-check the PJRT backend — and fails **at
//! runtime** with an unambiguous message the moment a client is created.
//!
//! Deployments with the real extension replace the `crates/xla` path
//! dependency in `rust/Cargo.toml` with the actual bindings crate; no
//! source change in `tor-ssm` is needed because the signatures match the
//! usage documented in `runtime/pjrt.rs`.

use std::fmt;
use std::path::Path;

#[derive(Debug)]
pub struct Error(pub String);

pub type Result<T> = std::result::Result<T, Error>;

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: the XLA/PJRT extension is not linked in this build \
         (crates/xla is a stub; swap it for the real bindings crate)"
    ))
}

/// Element dtypes the runtime layer distinguishes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    F32,
    F64,
}

/// Marker for host types that can cross the literal/buffer boundary.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }

    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

pub struct Literal(());

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(unavailable("Literal::array_shape"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loudly() {
        let err = PjRtClient::cpu().err().expect("stub must not succeed");
        assert!(err.to_string().contains("not linked"), "{err}");
    }
}
