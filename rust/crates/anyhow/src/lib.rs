//! Vendored minimal subset of the `anyhow` API.
//!
//! This offline image cannot reach crates.io, so the workspace vendors the
//! slice of `anyhow` the codebase actually uses: [`Error`], [`Result`],
//! the [`Context`] extension trait for `Result` and `Option`, and the
//! `anyhow!` / `bail!` / `ensure!` macros. Semantics follow the real crate:
//!
//! * `Display` prints the outermost context only;
//! * alternate `Display` (`{:#}`) prints the whole chain, outermost first,
//!   separated by `": "`;
//! * any `E: std::error::Error + Send + Sync + 'static` converts into
//!   [`Error`] via `?`;
//! * [`Error::downcast_ref`] recovers the root-cause error by type (the
//!   typed-error contract `runtime::registry` exposes through its
//!   `anyhow::Result` API); context frames do not disturb the payload.
//!
//! Not implemented (unused here): backtraces, `Error::chain`, downcasting
//! to *intermediate* chain links (only the root cause is retained).

use std::any::Any;
use std::fmt;

/// A context-carrying error. Frames are ordered outermost-first; the last
/// frame is the root cause. When built from a concrete `std::error::Error`
/// (via `?`, [`Error::new`], or `.context(..)` on a typed `Result`), the
/// root-cause value itself rides along for [`Error::downcast_ref`].
pub struct Error {
    frames: Vec<String>,
    payload: Option<Box<dyn Any + Send + Sync>>,
}

/// `anyhow`-style result alias with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { frames: vec![message.to_string()], payload: None }
    }

    /// Build an error from a concrete std error, retaining the value for
    /// [`Error::downcast_ref`].
    pub fn new<E>(error: E) -> Error
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        Error { frames: vec![error.to_string()], payload: Some(Box::new(error)) }
    }

    /// Wrap this error with an outer context frame.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.frames.insert(0, context.to_string());
        self
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.frames.last().map(|s| s.as_str()).unwrap_or("")
    }

    /// The root-cause error as a `T`, if this error was built from one.
    /// Context frames added on the way up do not disturb the payload.
    pub fn downcast_ref<T: 'static>(&self) -> Option<&T> {
        self.payload.as_ref()?.downcast_ref::<T>()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.frames.join(": "))
        } else {
            f.write_str(self.frames.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.frames.join(": "))
    }
}

// Like the real crate: a blanket conversion from any std error. `Error`
// itself deliberately does NOT implement `std::error::Error`, which is what
// keeps this impl coherent next to core's reflexive `From<T> for T`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::new(e)
    }
}

mod private {
    /// Sealed helper unifying "a std error" and "an anyhow::Error" so that
    /// `Context` can be implemented for `Result` over both (the same trick
    /// the real crate uses with its internal `ext::StdError`).
    pub trait ErrLike {
        fn into_error(self) -> crate::Error;
    }

    impl<E> ErrLike for E
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        fn into_error(self) -> crate::Error {
            crate::Error::new(self)
        }
    }

    impl ErrLike for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option`.
pub trait Context<T, E>: Sized {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: private::ErrLike> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into_error().context(context)),
        }
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into_error().context(f())),
        }
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(f())),
        }
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn context_chain_formats() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading config")
            .unwrap_err()
            .context("loading manifest");
        assert_eq!(format!("{e}"), "loading manifest");
        assert_eq!(format!("{e:#}"), "loading manifest: reading config: missing file");
        assert_eq!(e.root_cause(), "missing file");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("key absent").unwrap_err();
        assert_eq!(format!("{e}"), "key absent");
        let w: Option<u32> = Some(7);
        assert_eq!(w.with_context(|| "unused").unwrap(), 7);
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{:#}", inner().unwrap_err()), "missing file");
    }

    #[test]
    fn macros() {
        fn f(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", f(3).unwrap_err()), "three is right out");
        let e = anyhow!("coded {}", 42);
        assert_eq!(format!("{e}"), "coded 42");
    }

    #[test]
    fn downcast_ref_reaches_the_root_error() {
        // Payload survives both `?` conversion and added context frames.
        let e: Error = Err::<(), _>(io_err()).context("outer").unwrap_err();
        let io = e.downcast_ref::<std::io::Error>().expect("typed root cause retained");
        assert_eq!(io.kind(), std::io::ErrorKind::NotFound);
        // Wrong type and message-only errors both miss.
        assert!(e.downcast_ref::<std::fmt::Error>().is_none());
        assert!(anyhow!("plain text").downcast_ref::<std::io::Error>().is_none());
    }

    #[test]
    fn context_on_anyhow_result() {
        fn inner() -> Result<()> {
            bail!("root")
        }
        let e = inner().context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: root");
    }
}
