//! L3 coordinator micro-benchmarks (pure host path — no XLA): batcher,
//! router, state pool, JSON substrate, scoring math. These are the pieces
//! that must never be the serving bottleneck (DESIGN.md §9).

use std::time::Duration;

use tor_ssm::bench::harness::Bench;
use tor_ssm::coordinator::batcher::Batcher;
use tor_ssm::coordinator::router::{Policy, Router};
use tor_ssm::coordinator::state_pool::StatePool;
use tor_ssm::coordinator::Request;
use tor_ssm::eval::scoring::SeqLogits;
use tor_ssm::util::json::Json;
use tor_ssm::util::rng::Rng;

fn req(id: u64, plen: usize) -> Request {
    Request { id, prompt: vec![1; plen], gen_tokens: 8, variant: String::new(), arrived_us: 0 }
}

fn main() {
    let mut b = Bench::new("coordinator");

    b.bench_throughput("batcher_push_poll_1k", 1000, || {
        let mut batcher = Batcher::new(8, Duration::from_millis(1));
        for i in 0..1000u64 {
            batcher.push(req(i, 16));
            while batcher.poll(std::time::Instant::now()).is_some() {}
        }
        while batcher.drain().is_some() {}
        assert_eq!(batcher.dispatched, 1000);
    });

    b.bench_throughput("router_cost_aware_10k", 10_000, || {
        let mut r = Router::new(Policy::CostAware { long_prompt: 256 }, &["dense", "utrc@0.2"]);
        let long = req(0, 512);
        let short = req(1, 32);
        for i in 0..10_000 {
            let lane = r.route(if i % 2 == 0 { &long } else { &short }).unwrap();
            r.note_enqueued(&lane);
            r.note_done(&lane);
        }
    });

    b.bench_throughput("state_pool_alloc_release_10k", 10_000, || {
        let mut p = StatePool::new(128, 1 << 20);
        let mut live = Vec::new();
        for i in 0..10_000 {
            if i % 3 == 2 {
                if let Some(s) = live.pop() {
                    p.release(s).unwrap();
                }
            } else if let Ok(s) = p.alloc() {
                live.push(s);
            }
        }
        for s in live {
            p.release(s).unwrap();
        }
    });

    // Scoring hot path: log-softmax span scoring over realistic shapes.
    let vocab = 2048;
    let out_len = 115;
    let mut rng = Rng::new(5);
    let logits: Vec<f32> = (0..out_len * vocab).map(|_| rng.f32()).collect();
    let kept: Vec<i32> = (0..out_len as i32).map(|i| i + (i / 10)).collect();
    let tokens: Vec<i32> = (0..140).map(|_| rng.below(vocab) as i32).collect();
    b.bench("score_one_sequence_span16", || {
        let sl = SeqLogits { logits: &logits, out_len, vocab, kept: &kept };
        let (lp, n) = sl.aligned_span_lp(&tokens, (100, 116));
        assert!(lp.is_finite() && n > 0);
    });

    // JSON substrate on a manifest-sized document.
    let doc = {
        let mut items = Vec::new();
        for i in 0..200 {
            items.push(format!(
                r#"{{"name":"t{i}","shape":[{i},128],"offset":{},"bytes":{}}}"#,
                i * 512,
                i * 4096
            ));
        }
        format!(r#"{{"params":[{}]}}"#, items.join(","))
    };
    b.bench("json_parse_manifest_sized", || {
        let v = Json::parse(&doc).unwrap();
        assert_eq!(v.expect("params").as_arr().unwrap().len(), 200);
    });

    b.finish();
}
