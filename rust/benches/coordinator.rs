//! L3 coordinator benchmarks (pure host path — no XLA): batcher, router,
//! state pool, JSON substrate, scoring math — the pieces that must never be
//! the serving bottleneck (DESIGN.md §9) — plus the headline serving
//! comparison: lock-step `serve_batch` vs the continuous-batching
//! [`Scheduler`] on a mixed-generation-length trace, emitted to
//! `BENCH_coordinator.json` so CI accumulates the perf trajectory.
//!
//! Env knobs: `REPRO_BENCH_ITERS` (micro-bench iterations, default 50),
//! `REPRO_BENCH_REQS` (serving-trace requests, default 48),
//! `REPRO_BENCH_GEN` (max generation length, uniform 1..=N, default 24).

use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

use tor_ssm::bench::harness::Bench;
use tor_ssm::coordinator::batcher::Batcher;
use tor_ssm::coordinator::engine::Engine;
use tor_ssm::coordinator::metrics::Metrics;
use tor_ssm::coordinator::router::{Policy, Router};
use tor_ssm::coordinator::scheduler::Scheduler;
use tor_ssm::coordinator::state_pool::StatePool;
use tor_ssm::coordinator::Request;
use tor_ssm::eval::scoring::SeqLogits;
use tor_ssm::fixtures;
use tor_ssm::runtime::Runtime;
use tor_ssm::train::load_best_weights;
use tor_ssm::util::json::{num, obj, s, Json};
use tor_ssm::util::rng::Rng;

fn req(id: u64, plen: usize) -> Request {
    Request {
        id,
        prompt: vec![1; plen],
        gen_tokens: 8,
        variant: String::new(),
        arrived_us: 0,
        priority: Default::default(),
    }
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let mut b = Bench::new("coordinator");

    b.bench_throughput("batcher_push_poll_1k", 1000, || {
        let mut batcher = Batcher::new(8, Duration::from_millis(1));
        for i in 0..1000u64 {
            batcher.push(req(i, 16));
            while batcher.poll(std::time::Instant::now()).is_some() {}
        }
        batcher.drain();
        assert_eq!(batcher.dispatched, 1000);
    });

    b.bench_throughput("router_cost_aware_10k", 10_000, || {
        let mut r = Router::new(Policy::CostAware { long_prompt: 256 }, &["dense", "utrc@0.2"]);
        let long = req(0, 512);
        let short = req(1, 32);
        for i in 0..10_000 {
            let lane = r.route(if i % 2 == 0 { &long } else { &short }).unwrap();
            r.note_enqueued(&lane);
            r.note_done(&lane);
        }
    });

    b.bench_throughput("state_pool_alloc_release_10k", 10_000, || {
        let mut p = StatePool::new(128, 1 << 20);
        let mut live = Vec::new();
        for i in 0..10_000 {
            if i % 3 == 2 {
                if let Some(s) = live.pop() {
                    p.release(s).unwrap();
                }
            } else if let Ok(s) = p.alloc() {
                live.push(s);
            }
        }
        for s in live {
            p.release(s).unwrap();
        }
    });

    // Scoring hot path: log-softmax span scoring over realistic shapes.
    let vocab = 2048;
    let out_len = 115;
    let mut rng = Rng::new(5);
    let logits: Vec<f32> = (0..out_len * vocab).map(|_| rng.f32()).collect();
    let kept: Vec<i32> = (0..out_len as i32).map(|i| i + (i / 10)).collect();
    let tokens: Vec<i32> = (0..140).map(|_| rng.below(vocab) as i32).collect();
    b.bench("score_one_sequence_span16", || {
        let sl = SeqLogits { logits: &logits, out_len, vocab, kept: &kept };
        let (lp, n) = sl.aligned_span_lp(&tokens, (100, 116));
        assert!(lp.is_finite() && n > 0);
    });

    // JSON substrate on a manifest-sized document.
    let doc = {
        let mut items = Vec::new();
        for i in 0..200 {
            items.push(format!(
                r#"{{"name":"t{i}","shape":[{i},128],"offset":{},"bytes":{}}}"#,
                i * 512,
                i * 4096
            ));
        }
        format!(r#"{{"params":[{}]}}"#, items.join(","))
    };
    b.bench("json_parse_manifest_sized", || {
        let v = Json::parse(&doc).unwrap();
        assert_eq!(v.expect("params").as_arr().unwrap().len(), 200);
    });

    b.finish();

    serving_comparison();
}

/// Lock-step vs continuous batching on an identical mixed-gen-length trace,
/// end to end on the reference backend + synthetic fixture. Writes the
/// headline numbers (tokens/s, p50/p95 e2e latency, decode-step counts) to
/// BENCH_coordinator.json.
fn serving_comparison() {
    let n_requests = env_usize("REPRO_BENCH_REQS", 48);
    let max_gen = env_usize("REPRO_BENCH_GEN", 24).max(1);

    let (man, _) = match fixtures::manifest_or_fixture(&tor_ssm::artifacts_dir()) {
        Ok(v) => v,
        Err(e) => {
            println!("SKIP serving comparison: {e:#}");
            return;
        }
    };
    let rt = Runtime::reference().expect("reference backend");
    let model_name = man.models.keys().next().expect("models").clone();
    let model = man.model(&model_name).expect("model").clone();
    let (w, _) = load_best_weights(&man, &model).expect("weights");
    let engine = Engine::new(&rt, &man, &model, &w, "dense").expect("engine");

    let mut rng = Rng::new(17);
    let trace: Vec<Request> = fixtures::synth_requests(
        &mut rng,
        n_requests,
        max_gen,
        man.prefill_seq_len,
        // length-diverse incl. chunked-prefill prompts
        fixtures::trace_max_prompt(std::slice::from_ref(&engine)),
        model.vocab_size,
        &[], // single-lane comparison: no explicit variant pinning
    );

    // ---- lock-step: arrival-order batches, every batch decodes max(gen) --
    let calls0 = engine.decode_calls.load(Ordering::Relaxed);
    let mut lock = Metrics::default();
    let t0 = Instant::now();
    for chunk in trace.chunks(engine.max_batch()) {
        for resp in engine.serve_batch(chunk).expect("lock-step serve") {
            lock.record_response(&resp);
        }
    }
    lock.wall = t0.elapsed();
    let lock_steps = engine.decode_calls.load(Ordering::Relaxed) - calls0;

    // ---- continuous: iteration-level scheduler over the same trace -------
    let calls1 = engine.decode_calls.load(Ordering::Relaxed);
    let mut cont = Metrics::default();
    let mut sched = Scheduler::new(&engine);
    let t1 = Instant::now();
    let responses = sched.run(trace.clone()).expect("continuous serve");
    cont.wall = t1.elapsed();
    for resp in &responses {
        cont.record_response(resp);
    }
    let cont_steps = engine.decode_calls.load(Ordering::Relaxed) - calls1;
    assert_eq!(cont_steps, sched.decode_steps, "scheduler step counter drifted");
    assert_eq!(responses.len(), n_requests);
    assert!(
        cont_steps <= lock_steps,
        "continuous used MORE decode steps ({cont_steps}) than lock-step ({lock_steps})"
    );

    println!(
        "coordinator/serving: {n_requests} reqs, gen 1..={max_gen}: lock-step {} tok/s \
         ({lock_steps} steps) vs continuous {} tok/s ({cont_steps} steps)",
        lock.throughput_tok_s().round(),
        cont.throughput_tok_s().round()
    );

    let section = |m: &Metrics, steps: u64| {
        obj(vec![
            ("decode_steps", num(steps as f64)),
            ("wall_s", num(m.wall.as_secs_f64())),
            ("gen_tok_s", num(m.throughput_tok_s())),
            ("total_tok_s", num(m.total_tok_s())),
            ("p50_e2e_us", num(Metrics::pct(&m.e2e_us, 0.5) as f64)),
            ("p95_e2e_us", num(Metrics::pct(&m.e2e_us, 0.95) as f64)),
            ("p50_decode_us", num(Metrics::pct(&m.decode_us, 0.5) as f64)),
        ])
    };
    let report = obj(vec![
        ("bench", s("coordinator_serving")),
        ("model", s(&model_name)),
        ("requests", num(n_requests as f64)),
        ("max_gen_tokens", num(max_gen as f64)),
        ("gen_distribution", s("uniform 1..=max_gen")),
        ("lockstep", section(&lock, lock_steps)),
        ("continuous", section(&cont, cont_steps)),
        (
            "step_reduction",
            num(1.0 - cont_steps as f64 / (lock_steps.max(1)) as f64),
        ),
    ]);
    // Cargo runs bench binaries with CWD = the package root (rust/);
    // REPRO_BENCH_OUT overrides the destination.
    let out = std::env::var("REPRO_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_coordinator.json".to_string());
    std::fs::write(&out, report.to_string()).expect("writing BENCH_coordinator.json");
    println!("wrote {out}");
}
