//! End-to-end serving throughput (Figures 4/6 machinery as a bench target):
//! prefill + decode through the Engine for dense vs UTRC variants.
//! REPRO_BENCH_GEN controls generated tokens (default 16 — keep `cargo
//! bench` fast; the figures use 100 via `repro figure 4`). Runs against the
//! synthetic fixture on the reference backend when no artifacts exist.

use tor_ssm::bench::harness::Bench;
use tor_ssm::coordinator::engine::Engine;
use tor_ssm::coordinator::Request;
use tor_ssm::fixtures;
use tor_ssm::runtime::Runtime;
use tor_ssm::train::load_best_weights;

fn main() {
    let artifacts = tor_ssm::artifacts_dir();
    let (man, synthetic) = match fixtures::manifest_or_fixture(&artifacts) {
        Ok(v) => v,
        Err(e) => {
            println!("SKIP throughput bench: {e:#}");
            return;
        }
    };
    let gen_tokens: usize = std::env::var("REPRO_BENCH_GEN")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(16);
    let rt = Runtime::cpu().expect("default backend");
    println!(
        "throughput bench on {} ({})",
        rt.platform(),
        if synthetic { "synthetic fixture" } else { "real artifacts" }
    );
    let model_name = man.models.keys().next().expect("models").clone();
    let model = man.model(&model_name).expect("model").clone();
    let (w, _) = load_best_weights(&man, &model).expect("weights");

    let mut b = Bench::with_iters("throughput", 1, 5);
    for variant in ["dense", "utrc@0.1", "utrc@0.2", "utrc@0.3"] {
        let engine = match Engine::new(&rt, &man, &model, &w, variant) {
            Ok(e) => e,
            Err(err) => {
                println!("skip {variant}: {err:#}");
                continue;
            }
        };
        let reqs: Vec<Request> = (0..engine.batch)
            .map(|i| Request {
                id: i as u64,
                prompt: (0..engine.prefill_len)
                    .map(|t| (t % model.vocab_size) as i32)
                    .collect(),
                gen_tokens,
                variant: variant.to_string(),
                arrived_us: 0,
                priority: Default::default(),
            })
            .collect();
        let total_tokens = engine.batch * (engine.prefill_len + gen_tokens);
        b.bench_throughput(&format!("serve_batch_{variant}"), total_tokens, || {
            let resp = engine.serve_batch(&reqs).unwrap();
            assert_eq!(resp.len(), reqs.len());
        });
    }
    b.finish();
}
